//! Serving-tier integration tests: deterministic pool accounting,
//! admission control, and loss-free error handling.
//!
//! The acceptance contract of the sharded tier (ISSUE 3):
//! * every submitted request is answered exactly once — with a class or
//!   with the batch's inference error, never a dropped channel;
//! * the per-shard meters of a worker's striped buffer sum to what one
//!   unsharded array of the same capacity charges for the identical
//!   workload (exact for SRAM, within 1 % for the functional MCAIMem
//!   array whose per-shard weak-cell populations differ);
//! * admission rejects begin only above the configured high-water mark.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use mcaimem::coordinator::loadgen::{self, Arrival, LoadConfig};
use mcaimem::coordinator::pool::{InferEngine, PoolConfig, SubmitError, SyntheticEngine, WorkerPool};
use mcaimem::coordinator::scheduler::DispatchMode;
use mcaimem::coordinator::{BufferManager, TensorHandle};
use mcaimem::mem::backend::BackendSpec;
use mcaimem::report::serving::{rate_sweep, rate_sweep_json, RateSweepConfig};

fn pool_cfg(spec: BackendSpec, workers: usize, shards: usize) -> PoolConfig {
    PoolConfig {
        backend: spec,
        workers,
        shards,
        buffer_bytes: shards * 16 * 1024,
        batch_window: Duration::ZERO, // deterministic single-request batches
        high_water: 100_000,
        seed: 0x5EED,
        ..PoolConfig::default()
    }
}

fn instant_engines(workers: usize) -> Vec<Box<dyn InferEngine>> {
    (0..workers)
        .map(|_| {
            Box::new(SyntheticEngine { exec_latency: Duration::ZERO, ..Default::default() })
                as Box<dyn InferEngine>
        })
        .collect()
}

/// Replay the exact staging workload a single pool worker runs (store the
/// real rows of each window through a sub-handle over the stage region,
/// tick the compute window, load them back) on a fresh unsharded manager,
/// returning (total_j, bytes_rw). Mirrors the pool's continuous batching:
/// only `real × dim` bytes move per window, never the padded batch.
fn replay_unsharded(spec: &BackendSpec, bytes: usize, rows: &[Vec<i8>]) -> (f64, u64) {
    let engine = SyntheticEngine::default();
    let (batch, dim) = (engine.batch, engine.dim);
    let mut bm = BufferManager::from_spec(spec, bytes, 1);
    let stage = bm.alloc(batch * dim).unwrap();
    for row in rows {
        // one-request window → one real row staged through the sub-handle
        let h = TensorHandle { offset: stage.offset, len: dim, id: stage.id };
        let mut x = vec![0u8; dim];
        for (dst, &src) in x.iter_mut().zip(row.iter()) {
            *dst = src as u8;
        }
        bm.store(h, &x).unwrap();
        bm.tick(PoolConfig::default().sim_compute_s);
        let _ = bm.load(h);
    }
    let m = bm.mem.meter();
    (m.total_j(), m.bytes_read + m.bytes_written)
}

#[test]
fn every_request_is_answered_exactly_once_and_meters_match_unsharded() {
    // SRAM is exact up to float summation order; the functional MCAIMem
    // array carries per-shard weak-cell wobble → 1 %
    for (spec, tol) in [(BackendSpec::Sram, 1e-9), (BackendSpec::mcaimem_default(), 0.01)] {
        let cfg = pool_cfg(spec.clone(), 1, 4);
        let total_bytes = cfg.buffer_bytes;
        let pool = WorkerPool::start_with_engines(cfg, instant_engines(1)).unwrap();
        let rows: Vec<Vec<i8>> =
            (0..48).map(|i| (0..784).map(|j| ((i * 31 + j) % 127) as i8).collect()).collect();
        // sequential classify → deterministic batch-of-1 staging sequence
        let mut classes = Vec::new();
        for row in &rows {
            let (class, _lat) = pool.classify(row.clone()).unwrap();
            classes.push(class);
        }
        let stats = pool.shutdown();
        assert_eq!(stats.requests, 48, "{spec}: every request answered");
        assert_eq!(stats.errors, 0, "{spec}");
        assert_eq!(stats.batches, 48, "{spec}: batch window zero → one per batch");
        assert_eq!(stats.shards.len(), 4, "{spec}");

        // per-shard meters must sum to the unsharded meter for the same
        // workload
        let (flat_j, flat_rw) = replay_unsharded(&spec, total_bytes, &rows);
        let pool_j: f64 = stats.shards.iter().map(|s| s.energy_j).sum();
        let pool_rw: u64 = stats.shards.iter().map(|s| s.bytes_rw).sum();
        assert_eq!(pool_rw, flat_rw, "{spec}: striping conserves bytes");
        let rel = (pool_j - flat_j).abs() / flat_j.max(1e-30);
        assert!(rel <= tol, "{spec}: sharded {pool_j} vs unsharded {flat_j} (rel {rel})");

        // striping balances: every shard carried traffic, ~1/4 each
        for s in &stats.shards {
            assert!((s.occupancy - 0.25).abs() < 0.05, "{spec}: shard {} occ {}", s.shard, s.occupancy);
        }

        // determinism across an identical second pool
        let pool2 =
            WorkerPool::start_with_engines(pool_cfg(spec.clone(), 1, 4), instant_engines(1)).unwrap();
        let classes2: Vec<usize> =
            rows.iter().map(|r| pool2.classify(r.clone()).unwrap().0).collect();
        let _ = pool2.shutdown();
        assert_eq!(classes, classes2, "{spec}: fixed seeds → identical classes");
    }
}

/// Engine that parks on an atomic gate, signalling when the first request
/// reached it — lets the test hold the worker busy with a known queue
/// state.
struct GatedEngine {
    gate: Arc<AtomicBool>,
    started: mpsc::Sender<()>,
}

impl InferEngine for GatedEngine {
    fn batch(&self) -> usize {
        1
    }

    fn dim(&self) -> usize {
        16
    }

    fn infer(&mut self, x: &[i8]) -> anyhow::Result<Vec<usize>> {
        let _ = self.started.send(());
        while !self.gate.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_micros(200));
        }
        Ok(vec![0; x.len() / 16])
    }
}

#[test]
fn admission_rejects_begin_only_above_the_high_water_mark() {
    const HIGH_WATER: usize = 5;
    let gate = Arc::new(AtomicBool::new(false));
    let (started_tx, started_rx) = mpsc::channel();
    let cfg = PoolConfig {
        backend: BackendSpec::Sram,
        workers: 1,
        shards: 1,
        buffer_bytes: 16 * 1024,
        batch_window: Duration::ZERO,
        high_water: HIGH_WATER,
        ..PoolConfig::default()
    };
    let engine = GatedEngine { gate: Arc::clone(&gate), started: started_tx };
    let pool = WorkerPool::start_with_engines(cfg, vec![Box::new(engine)]).unwrap();

    // first request occupies the worker (popped from the queue → depth 0)
    let rx0 = pool.submit(vec![1i8; 16]).expect("first request admitted");
    started_rx.recv_timeout(Duration::from_secs(5)).expect("worker started");

    // exactly HIGH_WATER more are admitted…
    let mut rxs = vec![rx0];
    for i in 0..HIGH_WATER {
        rxs.push(pool.submit(vec![i as i8; 16]).unwrap_or_else(|e| {
            panic!("request {i} below the mark must be admitted: {e}")
        }));
    }
    assert_eq!(pool.depth(), HIGH_WATER);

    // …and the next one is rejected with a positive retry-after hint
    match pool.submit(vec![9i8; 16]) {
        Err(SubmitError::Rejected { depth, retry_after }) => {
            assert_eq!(depth, HIGH_WATER);
            assert!(retry_after > Duration::ZERO);
        }
        other => panic!("expected rejection above the mark, got {other:?}"),
    }

    // release the worker: every admitted request still completes
    gate.store(true, Ordering::SeqCst);
    for rx in rxs {
        let reply = rx.recv_timeout(Duration::from_secs(5)).expect("no lost replies");
        assert!(reply.is_ok());
    }
    let stats = pool.shutdown();
    assert_eq!(stats.requests, 1 + HIGH_WATER as u64);
    assert_eq!(stats.rejected, 1);
    assert!(stats.queue_depth_p99 >= 1.0);
}

/// Engine whose every other batch fails — the injected-error half of the
/// acceptance criteria.
struct FlakyEngine {
    calls: AtomicUsize,
}

impl InferEngine for FlakyEngine {
    fn batch(&self) -> usize {
        4
    }

    fn dim(&self) -> usize {
        32
    }

    fn infer(&mut self, x: &[i8]) -> anyhow::Result<Vec<usize>> {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        anyhow::ensure!(n % 2 == 0, "injected failure on batch {n}");
        Ok(vec![1; x.len() / 32])
    }
}

#[test]
fn injected_inference_errors_lose_zero_replies() {
    let cfg = PoolConfig {
        backend: BackendSpec::Sram,
        workers: 2,
        shards: 2,
        buffer_bytes: 2 * 16 * 1024,
        batch_window: Duration::ZERO,
        ..PoolConfig::default()
    };
    let engines: Vec<Box<dyn InferEngine>> =
        (0..2).map(|_| Box::new(FlakyEngine { calls: AtomicUsize::new(0) }) as _).collect();
    let pool = WorkerPool::start_with_engines(cfg, engines).unwrap();

    let n = 60usize;
    let rxs: Vec<_> = (0..n).map(|i| pool.submit(vec![i as i8; 32]).expect("admitted")).collect();
    let mut ok = 0usize;
    let mut failed = 0usize;
    for rx in rxs {
        // every receiver must resolve — an Err *reply* is fine, a closed
        // channel is a lost reply and a bug
        match rx.recv_timeout(Duration::from_secs(10)).expect("no lost replies") {
            Ok(_) => ok += 1,
            Err(e) => {
                assert!(e.to_string().contains("injected failure"), "{e}");
                failed += 1;
            }
        }
    }
    assert_eq!(ok + failed, n, "every request resolved exactly once");
    assert!(failed > 0, "the fault injection must actually fire");
    let stats = pool.shutdown();
    assert_eq!(stats.requests + stats.errors, n as u64);
    assert_eq!(stats.errors as usize, failed);
}

#[test]
fn open_loop_poisson_completes_everything_below_saturation() {
    let cfg = PoolConfig {
        backend: BackendSpec::Sram,
        workers: 2,
        shards: 2,
        buffer_bytes: 2 * 16 * 1024,
        seed: 77,
        ..PoolConfig::default()
    };
    let pool = WorkerPool::start_with_engines(cfg, instant_engines(2)).unwrap();
    let load = LoadConfig {
        arrival: Arrival::OpenPoisson { rps: 2_000.0 },
        requests: 100,
        seed: 7,
        ..LoadConfig::default()
    };
    let report = loadgen::run(&pool, &load);
    let stats = pool.shutdown();
    assert_eq!(report.offered, 100);
    assert_eq!(report.completed, 100, "no shedding far below saturation");
    assert_eq!(report.rejected, 0);
    assert_eq!(report.errors, 0);
    assert!(report.achieved_rps > 0.0);
    assert!(report.p99_latency_us >= report.p50_latency_us);
    assert_eq!(stats.requests, 100);
}

#[test]
fn closed_loop_retries_through_a_tiny_high_water_mark() {
    // high_water 2 with 4 clients: rejects must occur, but retries mean
    // every request eventually completes
    let cfg = PoolConfig {
        backend: BackendSpec::Sram,
        workers: 1,
        shards: 1,
        buffer_bytes: 16 * 1024,
        high_water: 2,
        est_service_us: 50,
        seed: 78,
        ..PoolConfig::default()
    };
    let pool = WorkerPool::start_with_engines(
        cfg,
        vec![Box::new(SyntheticEngine {
            exec_latency: Duration::from_micros(300),
            ..Default::default()
        })],
    )
    .unwrap();
    let load = LoadConfig {
        arrival: Arrival::ClosedLoop { clients: 4 },
        requests: 80,
        retry_rejects: true,
        seed: 9,
        ..LoadConfig::default()
    };
    let report = loadgen::run(&pool, &load);
    let stats = pool.shutdown();
    assert_eq!(report.completed, 80, "retries drain every request");
    assert_eq!(stats.requests, 80);
    assert_eq!(stats.rejected, report.rejected);
}

#[test]
fn refresh_aware_dispatch_keeps_the_stall_off_the_request_tail() {
    // the pinned scheduler comparison (mcaimem@0.8, same seeded load):
    // with a modeled stall of 3 µs per refresh slot, the oblivious
    // dispatcher must charge refresh to the request tail while the aware
    // one reports zero refresh-attributable p99.9 and pays the identical
    // stall in inter-window slack. The virtual refresh schedule — and so
    // the per-shard meters — must not differ between the modes.
    let run = |dispatch: DispatchMode| {
        let cfg = PoolConfig {
            backend: BackendSpec::mcaimem_default(),
            workers: 1,
            shards: 2,
            buffer_bytes: 2 * 64 * 1024,
            batch_window: Duration::ZERO,
            high_water: 100_000,
            dispatch,
            refresh_stall: Duration::from_micros(3),
            seed: 0xAB5E,
            ..PoolConfig::default()
        };
        let pool = WorkerPool::start_with_engines(cfg, instant_engines(1)).unwrap();
        let load = LoadConfig {
            arrival: Arrival::OpenPoisson { rps: 3_000.0 },
            requests: 64,
            retry_rejects: false,
            seed: 41,
            ..LoadConfig::default()
        }
        .validated()
        .unwrap();
        let report = loadgen::run(&pool, &load);
        let stats = pool.shutdown();
        assert_eq!(report.completed, 64, "{dispatch}: nothing shed at this rate");
        stats
    };
    let oblivious = run(DispatchMode::Oblivious);
    let aware = run(DispatchMode::RefreshAware);

    assert!(
        oblivious.refresh_stall_p999_us > 0.0,
        "oblivious dispatch must attribute refresh stall to requests"
    );
    assert_eq!(
        aware.refresh_stall_p999_us, 0.0,
        "aware dispatch must keep the request tail refresh-free"
    );
    assert!(
        aware.refresh_stall_p999_us < oblivious.refresh_stall_p999_us,
        "the refresh-attributable p99.9 must drop under aware dispatch"
    );
    assert!(
        aware.refresh_slack_total_us > 0.0,
        "the stall does not vanish — it is absorbed into slack"
    );
    assert!(oblivious.refresh_stall_total_us > 0.0);
    assert_eq!(aware.refresh_stall_total_us, 0.0);
    // identical virtual schedule either way
    let refreshes =
        |s: &mcaimem::coordinator::ServerStats| s.shards.iter().map(|x| x.refreshes).sum::<u64>();
    assert_eq!(
        refreshes(&oblivious),
        refreshes(&aware),
        "dispatch mode must never change the refresh schedule itself"
    );
}

#[test]
fn rate_sweep_holds_100k_rps_and_reports_the_slo_tail() {
    // the 100k+ req/s gate: a seeded open-loop sweep over the paper's
    // backend must offer every request at the target rate, read a p99.9,
    // and serialize the artifact CI uploads
    let cfg = RateSweepConfig {
        workers: 2,
        shards: 2,
        requests: 2000,
        dispatch: DispatchMode::RefreshAware,
        refresh_stall: Duration::ZERO,
        seed: 0xCAFE,
    };
    let (table, points) =
        rate_sweep(&BackendSpec::mcaimem_default(), &[100_000.0], &cfg).unwrap();
    assert_eq!(points.len(), 1);
    let p = &points[0];
    assert_eq!(p.target_rps, 100_000.0);
    assert_eq!(p.offered, 2000, "open loop offers the whole schedule");
    assert!(p.completed + p.rejected as usize <= p.offered);
    assert!(p.p999_latency_us >= p.p99_latency_us, "tail ordering");
    assert!(p.p999_latency_us > 0.0, "the SLO tail must be measured");
    assert!(table.render().contains("p99.9"));
    // the artifact round-trips through the repo's JSON layer
    let doc = rate_sweep_json(&BackendSpec::mcaimem_default(), &cfg, &points);
    let text = doc.to_pretty();
    assert_eq!(mcaimem::util::json::Json::parse(&text).unwrap(), doc);
    assert!(text.contains("p999_latency_us"));
}
