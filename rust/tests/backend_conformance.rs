//! Backend conformance suite: the shared invariants every
//! [`MemoryBackend`] must uphold, property-tested across the whole
//! `BackendSpec` grammar with the in-tree mini-framework seeds.
//!
//! Contract under test (mirrors the trait rustdoc and EXPERIMENTS.md
//! §Backends):
//!
//! 1. `BackendSpec` `FromStr`/`Display` round-trip (including random
//!    V_REF points).
//! 2. Load-after-store round-trips for SRAM/RRAM/eDRAM and *fresh*
//!    MCAIMem state (both encoder settings, aligned and ragged accesses).
//! 3. `EnergyMeter.total_j()` is monotone over any op sequence.
//! 4. `bytes_read`/`bytes_written`/`reads`/`writes` account payloads
//!    exactly.
//! 5. `refresh_due` matches the technology (only MCAIMem asks the manager
//!    to drive refresh).

use mcaimem::mem::backend::{build, BackendSpec, MemoryBackend};
use mcaimem::util::rng::Pcg64;

/// Every spec shape the grammar can produce (several V_REF points).
fn all_specs() -> Vec<BackendSpec> {
    BackendSpec::parse_list(
        "sram,edram2t,rram,mcaimem@0.8,mcaimem@0.8-noenc,mcaimem@0.7,mcaimem@0.5-noenc",
    )
    .unwrap()
}

#[test]
fn spec_fromstr_display_roundtrip() {
    for spec in all_specs() {
        let s = spec.to_string();
        let back: BackendSpec = s.parse().unwrap();
        assert_eq!(back, spec, "{s}");
        assert_eq!(back.to_string(), s, "{s}");
    }
    // property: random V_REF points round-trip through the grammar
    let mut rng = Pcg64::new(0xC0FF);
    for _ in 0..256 {
        // f64 Display prints the shortest representation that re-parses to
        // the same bits, so any representable V_REF round-trips; stay a
        // little inside the 0.3..=1.1 grammar bound so fp rounding of the
        // sum cannot cross it
        let vref = (rng.next_u64() % 780) as f64 / 1000.0 + 0.3;
        let encode = rng.next_u64() % 2 == 0;
        let ecc = rng.next_u64() % 2 == 0;
        let spec = BackendSpec::Mcaimem { vref, encode, ecc };
        let back: BackendSpec = spec.to_string().parse().unwrap();
        assert_eq!(back, spec, "vref={vref} encode={encode} ecc={ecc}");
    }
}

#[test]
fn spec_grammar_error_paths() {
    for s in ["", "sram@0.8", "mcaimem@", "mcaimem@x", "rram-noenc", "mcaimem@1.2", "6t"] {
        assert!(s.parse::<BackendSpec>().is_err(), "`{s}` must be rejected");
    }
    assert!(BackendSpec::parse_list("sram,,edram2t").is_ok(), "empty segments are skipped");
    assert!(BackendSpec::parse_list("sram,bogus").is_err());
}

#[test]
fn load_after_store_roundtrips_fresh() {
    // fresh state: the first access after power-on, then an immediate
    // re-read — every backend must return the stored bytes exactly
    // (MCAIMem's weakest cells need µs-scale staleness to flip; ns-scale
    // reads are inside every cell's retention)
    for spec in all_specs() {
        let mut b = build(&spec, 64 * 1024, 0xF00D);
        let mut rng = Pcg64::new(42);
        let mut t = 0.0;
        // aligned block, ragged head/tail, single byte
        for (addr, len) in [(0usize, 256usize), (13, 131), (64, 64), (1000, 1)] {
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            t += 1e-9;
            b.store(addr, &data, t);
            t += 1e-9;
            assert_eq!(b.load(addr, len, t), data, "{spec} @{addr}+{len}");
        }
    }
}

#[test]
fn meter_total_is_monotone_over_any_op_sequence() {
    for spec in all_specs() {
        let mut b = build(&spec, 32 * 1024, 7);
        let mut rng = Pcg64::new(spec.to_string().len() as u64);
        let mut t = 0.0;
        let mut last = b.meter().total_j();
        for i in 0..200 {
            t += 1e-7;
            match rng.next_u64() % 3 {
                0 => {
                    let len = 1 + (rng.next_u64() % 300) as usize;
                    let addr = (rng.next_u64() as usize) % (b.capacity() - len);
                    let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
                    b.store(addr, &data, t);
                }
                1 => {
                    let len = 1 + (rng.next_u64() % 300) as usize;
                    let addr = (rng.next_u64() as usize) % (b.capacity() - len);
                    let _ = b.load(addr, len, t);
                }
                _ => b.tick(t),
            }
            let now = b.meter().total_j();
            assert!(
                now >= last && now.is_finite(),
                "{spec}: total_j regressed at op {i}: {last} -> {now}"
            );
            last = now;
        }
    }
}

#[test]
fn bytes_and_ops_accounting_is_exact() {
    for spec in all_specs() {
        let mut b = build(&spec, 32 * 1024, 9);
        let mut rng = Pcg64::new(17);
        let (mut wrote, mut read, mut stores, mut loads) = (0u64, 0u64, 0u64, 0u64);
        let mut t = 0.0;
        for _ in 0..64 {
            let len = (rng.next_u64() % 500) as usize;
            let addr = (rng.next_u64() as usize) % (b.capacity() - len.max(1));
            t += 1e-8;
            if rng.next_u64() % 2 == 0 {
                b.store(addr, &vec![0xA5; len], t);
                wrote += len as u64;
                stores += 1;
            } else {
                assert_eq!(b.load(addr, len, t).len(), len, "{spec}");
                read += len as u64;
                loads += 1;
            }
        }
        let m = b.meter();
        assert_eq!(m.bytes_written, wrote, "{spec}");
        assert_eq!(m.bytes_read, read, "{spec}");
        assert_eq!(m.writes, stores, "{spec}");
        assert_eq!(m.reads, loads, "{spec}");
        // zero-length accesses must not poison energy with NaN
        assert!(m.total_j().is_finite(), "{spec}");
    }
}

#[test]
fn refresh_due_matches_technology() {
    let cases = [
        ("sram", None),
        ("rram", None),
        // the conventional 2T self-charges its 1.3 µs stream in tick()
        ("edram2t", None),
        ("mcaimem@0.8", Some(12.57e-6)),
    ];
    for (s, expect) in cases {
        let spec: BackendSpec = s.parse().unwrap();
        let b = build(&spec, 16 * 1024, 1);
        match (b.refresh_due(), expect) {
            (None, None) => {}
            (Some(t), Some(e)) => {
                assert!((t - e).abs() / e < 1e-2, "{s}: period {t} vs {e}");
                assert!(b.rows_per_bank() > 1, "{s}");
            }
            (got, want) => panic!("{s}: refresh_due {got:?}, expected {want:?}"),
        }
    }
    // lower V_REF ⇒ shorter refresh period (the §IV-B lever)
    let hi = build(&"mcaimem@0.8".parse().unwrap(), 16 * 1024, 1).refresh_due().unwrap();
    let lo = build(&"mcaimem@0.5".parse().unwrap(), 16 * 1024, 1).refresh_due().unwrap();
    assert!(lo < hi / 5.0, "lo={lo} hi={hi}");
}

#[test]
fn build_reports_consistent_identity() {
    for spec in all_specs() {
        let b = build(&spec, 48 * 1024, 3);
        assert_eq!(b.spec(), spec);
        assert_eq!(b.label(), spec.label());
        assert_eq!(b.spec().to_string(), spec.to_string());
        // capacity rounds up to whole 16 KB banks
        assert_eq!(b.capacity() % (16 * 1024), 0, "{spec}");
        assert!(b.capacity() >= 48 * 1024, "{spec}");
        assert!(b.area() > 0.0, "{spec}");
        // the card agrees with the spec-level card on refresh policy
        assert_eq!(
            b.energy_card().refresh_period.is_some(),
            spec.energy_card().refresh_period.is_some(),
            "{spec}"
        );
    }
}

#[test]
fn static_energy_ranking_holds_on_live_backends() {
    // run the same idle hour-of-µs on every technology: SRAM burns the
    // most standby power, RRAM none — the Fig. 14/15 ordering, measured
    // on the functional objects rather than the closed form
    let idle = |s: &str| {
        let spec: BackendSpec = s.parse().unwrap();
        let mut b = build(&spec, 64 * 1024, 5);
        // park real DNN-like data so the asymmetric cards see a mixed
        // ones fraction
        let data: Vec<u8> = (0..4096u32).map(|i| (i % 7) as u8).collect();
        b.store(0, &data, 1e-9);
        b.tick(1e-3);
        b.meter().static_j
    };
    let sram = idle("sram");
    let ours = idle("mcaimem@0.8");
    let edram = idle("edram2t");
    let rram = idle("rram");
    assert!(sram > ours && ours > edram, "sram={sram} ours={ours} edram={edram}");
    assert_eq!(rram, 0.0);
}
