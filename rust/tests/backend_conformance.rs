//! Backend conformance suite: the shared invariants every
//! [`MemoryBackend`] must uphold, property-tested across the whole
//! `BackendSpec` grammar with the in-tree mini-framework seeds.
//!
//! Contract under test (mirrors the trait rustdoc and EXPERIMENTS.md
//! §Backends):
//!
//! 1. `BackendSpec` `FromStr`/`Display` round-trip (including random
//!    V_REF points).
//! 2. Load-after-store round-trips for SRAM/RRAM/eDRAM and *fresh*
//!    MCAIMem state (both encoder settings, aligned and ragged accesses).
//! 3. `EnergyMeter.total_j()` is monotone over any op sequence.
//! 4. `bytes_read`/`bytes_written`/`reads`/`writes` account payloads
//!    exactly.
//! 5. `refresh_due` matches the technology (only MCAIMem asks the manager
//!    to drive refresh).

use mcaimem::mem::backend::{build, BackendSpec, MemoryBackend};
use mcaimem::mem::mcaimem::EnergyMeter;
use mcaimem::util::rng::Pcg64;

/// Every flat spec shape the grammar can produce (several V_REF points,
/// both MRAM classes, a relaxed-retention point).
fn all_specs() -> Vec<BackendSpec> {
    BackendSpec::parse_list(
        "sram,edram2t,rram,mcaimem@0.8,mcaimem@0.8-noenc,mcaimem@0.7,mcaimem@0.5-noenc,\
         sttmram,sotmram,sotmram@ret=1e-3",
    )
    .unwrap()
}

/// Tiered (two-level) spec shapes. Kept out of [`all_specs`]: the exact
/// byte-accounting test counts payload bytes only, and a tiered device
/// legitimately moves extra fill/write-back traffic between its tiers.
fn tiered_specs() -> Vec<BackendSpec> {
    BackendSpec::parse_list(
        "tiered=sram:32k+sotmram,tiered=sram:16k+rram,tiered=sram:16k+mcaimem@0.8,\
         tiered=(tiered=sram:16k+edram2t):32k+sttmram",
    )
    .unwrap()
}

#[test]
fn spec_fromstr_display_roundtrip() {
    for spec in all_specs() {
        let s = spec.to_string();
        let back: BackendSpec = s.parse().unwrap();
        assert_eq!(back, spec, "{s}");
        assert_eq!(back.to_string(), s, "{s}");
    }
    // property: random V_REF points round-trip through the grammar
    let mut rng = Pcg64::new(0xC0FF);
    for _ in 0..256 {
        // f64 Display prints the shortest representation that re-parses to
        // the same bits, so any representable V_REF round-trips; stay a
        // little inside the 0.3..=1.1 grammar bound so fp rounding of the
        // sum cannot cross it
        let vref = (rng.next_u64() % 780) as f64 / 1000.0 + 0.3;
        let encode = rng.next_u64() % 2 == 0;
        let ecc = rng.next_u64() % 2 == 0;
        let spec = BackendSpec::Mcaimem { vref, encode, ecc };
        let back: BackendSpec = spec.to_string().parse().unwrap();
        assert_eq!(back, spec, "vref={vref} encode={encode} ecc={ecc}");
    }
}

#[test]
fn spec_grammar_error_paths() {
    for s in [
        "",
        "sram@0.8",
        "mcaimem@",
        "mcaimem@x",
        "rram-noenc",
        "mcaimem@1.2",
        "6t",
        "sttmram@",
        "sotmram@ret=",
        "sotmram@ret=1e-9", // below the 1 µs physical floor
        "sttmram@ret=1e9",  // above the archival ceiling
        "tiered=",
        "tiered=sram:32k",
        "tiered=sram:31+rram",
        "sttmram+ecc",
    ] {
        assert!(s.parse::<BackendSpec>().is_err(), "`{s}` must be rejected");
    }
    assert!(BackendSpec::parse_list("sram,,edram2t").is_ok(), "empty segments are skipped");
    assert!(BackendSpec::parse_list("sram,bogus").is_err());
}

#[test]
fn retention_knob_roundtrips_through_the_grammar() {
    // the knob is part of the spec identity: distinct retentions are
    // distinct specs, the default collapses to the bare name
    let relaxed: BackendSpec = "sotmram@ret=1e-3".parse().unwrap();
    let archival: BackendSpec = "sotmram".parse().unwrap();
    assert_ne!(relaxed, archival);
    assert_eq!(relaxed.to_string().parse::<BackendSpec>().unwrap(), relaxed);
    assert_eq!(archival.to_string(), "sotmram");
    // and it survives a trip through a tiered composition
    let spec: BackendSpec = "tiered=sram:32k+sotmram@ret=1e-3".parse().unwrap();
    let again: BackendSpec = spec.to_string().parse().unwrap();
    assert_eq!(again, spec);
    let BackendSpec::Tiered(_, _, back) = spec else { panic!() };
    assert_eq!(*back, relaxed);
}

/// A random spec tree of paren depth ≤ `depth` (leaves include random
/// V_REF and retention knobs — every value the grammar can carry).
fn random_spec(rng: &mut Pcg64, depth: usize) -> BackendSpec {
    if depth > 0 && rng.next_u64() % 3 == 0 {
        let front = random_spec(rng, depth - 1);
        let back = random_spec(rng, depth - 1);
        let bytes = 64 * (1 + (rng.next_u64() % 2048) as usize);
        return BackendSpec::Tiered(Box::new(front), bytes, Box::new(back));
    }
    match rng.next_u64() % 6 {
        0 => BackendSpec::Sram,
        1 => BackendSpec::Edram2t,
        2 => BackendSpec::Rram,
        3 => BackendSpec::Mcaimem {
            vref: (rng.next_u64() % 780) as f64 / 1000.0 + 0.3,
            encode: rng.next_u64() % 2 == 0,
            ecc: rng.next_u64() % 2 == 0,
        },
        4 => BackendSpec::Sttmram {
            ret: if rng.next_u64() % 4 == 0 {
                BackendSpec::RET_DEFAULT
            } else {
                1e-6 + (rng.next_u64() % 1_000_000) as f64 * 1e-4
            },
        },
        _ => BackendSpec::Sotmram {
            ret: if rng.next_u64() % 4 == 0 {
                BackendSpec::RET_DEFAULT
            } else {
                1e-6 + (rng.next_u64() % 1_000_000) as f64 * 1e-4
            },
        },
    }
}

#[test]
fn random_spec_trees_roundtrip_through_the_grammar() {
    // property: parse(display(s)) == s over random spec trees up to two
    // tiering levels deep — f64 Display prints the shortest representation
    // that re-parses to the same bits, so knob values survive exactly
    let mut rng = Pcg64::new(0x5EED_72EE);
    for i in 0..512 {
        let spec = random_spec(&mut rng, 2);
        let s = spec.to_string();
        let back: BackendSpec = s.parse().unwrap_or_else(|e| panic!("#{i} `{s}`: {e}"));
        assert_eq!(back, spec, "#{i} `{s}`");
        assert_eq!(back.to_string(), s, "#{i} display must be canonical");
    }
}

#[test]
fn load_after_store_roundtrips_fresh() {
    // fresh state: the first access after power-on, then an immediate
    // re-read — every backend must return the stored bytes exactly
    // (MCAIMem's weakest cells need µs-scale staleness to flip; ns-scale
    // reads are inside every cell's retention)
    for spec in all_specs() {
        let mut b = build(&spec, 64 * 1024, 0xF00D);
        let mut rng = Pcg64::new(42);
        let mut t = 0.0;
        // aligned block, ragged head/tail, single byte
        for (addr, len) in [(0usize, 256usize), (13, 131), (64, 64), (1000, 1)] {
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            t += 1e-9;
            b.store(addr, &data, t);
            t += 1e-9;
            assert_eq!(b.load(addr, len, t), data, "{spec} @{addr}+{len}");
        }
    }
}

#[test]
fn meter_total_is_monotone_over_any_op_sequence() {
    for spec in all_specs() {
        let mut b = build(&spec, 32 * 1024, 7);
        let mut rng = Pcg64::new(spec.to_string().len() as u64);
        let mut t = 0.0;
        let mut last = b.meter().total_j();
        for i in 0..200 {
            t += 1e-7;
            match rng.next_u64() % 3 {
                0 => {
                    let len = 1 + (rng.next_u64() % 300) as usize;
                    let addr = (rng.next_u64() as usize) % (b.capacity() - len);
                    let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
                    b.store(addr, &data, t);
                }
                1 => {
                    let len = 1 + (rng.next_u64() % 300) as usize;
                    let addr = (rng.next_u64() as usize) % (b.capacity() - len);
                    let _ = b.load(addr, len, t);
                }
                _ => b.tick(t),
            }
            let now = b.meter().total_j();
            assert!(
                now >= last && now.is_finite(),
                "{spec}: total_j regressed at op {i}: {last} -> {now}"
            );
            last = now;
        }
    }
}

#[test]
fn bytes_and_ops_accounting_is_exact() {
    for spec in all_specs() {
        let mut b = build(&spec, 32 * 1024, 9);
        let mut rng = Pcg64::new(17);
        let (mut wrote, mut read, mut stores, mut loads) = (0u64, 0u64, 0u64, 0u64);
        let mut t = 0.0;
        for _ in 0..64 {
            let len = (rng.next_u64() % 500) as usize;
            let addr = (rng.next_u64() as usize) % (b.capacity() - len.max(1));
            t += 1e-8;
            if rng.next_u64() % 2 == 0 {
                b.store(addr, &vec![0xA5; len], t);
                wrote += len as u64;
                stores += 1;
            } else {
                assert_eq!(b.load(addr, len, t).len(), len, "{spec}");
                read += len as u64;
                loads += 1;
            }
        }
        let m = b.meter();
        assert_eq!(m.bytes_written, wrote, "{spec}");
        assert_eq!(m.bytes_read, read, "{spec}");
        assert_eq!(m.writes, stores, "{spec}");
        assert_eq!(m.reads, loads, "{spec}");
        // zero-length accesses must not poison energy with NaN
        assert!(m.total_j().is_finite(), "{spec}");
    }
}

#[test]
fn refresh_due_matches_technology() {
    let cases = [
        ("sram", None),
        ("rram", None),
        // the conventional 2T self-charges its 1.3 µs stream in tick()
        ("edram2t", None),
        ("mcaimem@0.8", Some(12.57e-6)),
    ];
    for (s, expect) in cases {
        let spec: BackendSpec = s.parse().unwrap();
        let b = build(&spec, 16 * 1024, 1);
        match (b.refresh_due(), expect) {
            (None, None) => {}
            (Some(t), Some(e)) => {
                assert!((t - e).abs() / e < 1e-2, "{s}: period {t} vs {e}");
                assert!(b.rows_per_bank() > 1, "{s}");
            }
            (got, want) => panic!("{s}: refresh_due {got:?}, expected {want:?}"),
        }
    }
    // lower V_REF ⇒ shorter refresh period (the §IV-B lever)
    let hi = build(&"mcaimem@0.8".parse().unwrap(), 16 * 1024, 1).refresh_due().unwrap();
    let lo = build(&"mcaimem@0.5".parse().unwrap(), 16 * 1024, 1).refresh_due().unwrap();
    assert!(lo < hi / 5.0, "lo={lo} hi={hi}");
}

#[test]
fn build_reports_consistent_identity() {
    for spec in all_specs() {
        let b = build(&spec, 48 * 1024, 3);
        assert_eq!(b.spec(), spec);
        assert_eq!(b.label(), spec.label());
        assert_eq!(b.spec().to_string(), spec.to_string());
        // capacity rounds up to whole 16 KB banks
        assert_eq!(b.capacity() % (16 * 1024), 0, "{spec}");
        assert!(b.capacity() >= 48 * 1024, "{spec}");
        assert!(b.area() > 0.0, "{spec}");
        // the card agrees with the spec-level card on refresh policy
        assert_eq!(
            b.energy_card().refresh_period.is_some(),
            spec.energy_card().refresh_period.is_some(),
            "{spec}"
        );
    }
}

#[test]
fn static_energy_ranking_holds_on_live_backends() {
    // run the same idle hour-of-µs on every technology: SRAM burns the
    // most standby power, RRAM none — the Fig. 14/15 ordering, measured
    // on the functional objects rather than the closed form
    let idle = |s: &str| {
        let spec: BackendSpec = s.parse().unwrap();
        let mut b = build(&spec, 64 * 1024, 5);
        // park real DNN-like data so the asymmetric cards see a mixed
        // ones fraction
        let data: Vec<u8> = (0..4096u32).map(|i| (i % 7) as u8).collect();
        b.store(0, &data, 1e-9);
        b.tick(1e-3);
        b.meter().static_j
    };
    let sram = idle("sram");
    let ours = idle("mcaimem@0.8");
    let edram = idle("edram2t");
    let rram = idle("rram");
    assert!(sram > ours && ours > edram, "sram={sram} ours={ours} edram={edram}");
    assert_eq!(rram, 0.0);
}

#[test]
fn tiered_load_after_store_roundtrips_fresh() {
    // the device contract holds through the write-back buffer: stored
    // bytes come back exactly, aligned or ragged, hit or miss
    for spec in tiered_specs() {
        let mut b = build(&spec, 64 * 1024, 0xF00D);
        let mut rng = Pcg64::new(42);
        let mut t = 0.0;
        for (addr, len) in [(0usize, 256usize), (13, 131), (64, 64), (1000, 1), (65, 63)] {
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            t += 1e-9;
            b.store(addr, &data, t);
            t += 1e-9;
            assert_eq!(b.load(addr, len, t), data, "{spec} @{addr}+{len}");
        }
    }
}

#[test]
fn tiered_meter_total_is_monotone() {
    for spec in tiered_specs() {
        let mut b = build(&spec, 64 * 1024, 7);
        let mut rng = Pcg64::new(spec.to_string().len() as u64);
        let mut t = 0.0;
        let mut last = b.meter().total_j();
        for i in 0..200 {
            t += 1e-7;
            match rng.next_u64() % 3 {
                0 => {
                    let len = 1 + (rng.next_u64() % 300) as usize;
                    let addr = (rng.next_u64() as usize) % (b.capacity() - len);
                    let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
                    b.store(addr, &data, t);
                }
                1 => {
                    let len = 1 + (rng.next_u64() % 300) as usize;
                    let addr = (rng.next_u64() as usize) % (b.capacity() - len);
                    let _ = b.load(addr, len, t);
                }
                _ => b.tick(t),
            }
            let now = b.meter().total_j();
            assert!(
                now >= last && now.is_finite(),
                "{spec}: total_j regressed at op {i}: {last} -> {now}"
            );
            last = now;
        }
    }
}

#[test]
fn tiered_refresh_due_matches_the_member_technologies() {
    // non-volatile stacks never ask the manager for refresh; a volatile
    // member's stream surfaces through the composition
    for (s, some) in [
        ("tiered=sram:32k+sotmram", false),
        ("tiered=sram:16k+rram", false),
        ("tiered=sram:16k+mcaimem@0.8", true),
        ("tiered=(tiered=sram:16k+edram2t):32k+sttmram", false),
    ] {
        let spec: BackendSpec = s.parse().unwrap();
        let b = build(&spec, 64 * 1024, 1);
        assert_eq!(b.refresh_due().is_some(), some, "{s}");
        if some {
            assert!(b.rows_per_bank() > 1, "{s}");
        }
    }
}

#[test]
fn tiered_replays_the_flat_op_stream_bit_exactly() {
    // the same op stream through `tiered=sram:32k+X` and flat `X` must
    // return identical payloads (the buffer is transparent), and the
    // tiered device's per-tier meters must sum field-wise to its totals
    for back in ["sotmram", "rram", "sttmram@ret=1e-3"] {
        let tiered_spec: BackendSpec = format!("tiered=sram:32k+{back}").parse().unwrap();
        let flat_spec: BackendSpec = back.parse().unwrap();
        let mut tiered = build(&tiered_spec, 64 * 1024, 0xC0FFEE);
        let mut flat = build(&flat_spec, 64 * 1024, 0xC0FFEE);
        assert_eq!(tiered.capacity(), flat.capacity());

        let mut rng = Pcg64::new(99);
        let mut t = 0.0;
        for _ in 0..300 {
            t += 1e-7;
            let len = 1 + (rng.next_u64() % 200) as usize;
            let addr = (rng.next_u64() as usize) % (tiered.capacity() - len);
            if rng.next_u64() % 2 == 0 {
                let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
                tiered.store(addr, &data, t);
                flat.store(addr, &data, t);
            } else {
                assert_eq!(
                    tiered.load(addr, len, t),
                    flat.load(addr, len, t),
                    "{back} @{addr}+{len}"
                );
            }
        }
        // per-tier accounting survives the composition exactly
        let tiers = tiered.shard_meters();
        assert_eq!(tiers.len(), 2, "{back}");
        let mut sum = EnergyMeter::default();
        sum.merge(&tiers[0]);
        sum.merge(&tiers[1]);
        assert_eq!(&sum, tiered.meter(), "{back}: [front, back] must sum to the totals");
        // the write buffer's whole point: the slow-write back tier sees
        // less programming energy than the flat twin paid
        assert!(
            tiers[1].write_j < flat.meter().write_j,
            "{back}: back rail {} !< flat {}",
            tiers[1].write_j,
            flat.meter().write_j
        );
    }
}
