//! Acceptance tests for the design-space exploration subsystem (ISSUE 5):
//!
//! * a ≥200-point grid (ratio × V_REF × geometry) completes and is
//!   deterministic — same seed ⇒ byte-identical frontier JSON;
//! * evaluation fans out over `util::par` (checked by equivalence of the
//!   parallel batch path and direct sequential evaluation);
//! * the discovered frontier contains the paper's 1S7E@V_REF=0.8
//!   configuration, dominating SRAM by ≥40 % area and ≥3× energy per
//!   inference.

use mcaimem::dse::search::{ExhaustiveGrid, SearchStrategy};
use mcaimem::dse::{evaluate, DesignPoint, EvalCache, EvalContext, Space, TierConfig};
use mcaimem::report::pareto::ExploreOutcome;
use mcaimem::scalesim::{network, AcceleratorConfig};

/// The explore default: ResNet50 on Eyeriss, pinned seed.
fn default_ctx(fidelity: usize) -> EvalContext {
    EvalContext::new(network::resnet50(), AcceleratorConfig::eyeriss(), 42, fidelity)
}

fn run_default_grid(ctx: &EvalContext) -> (ExploreOutcome, EvalCache) {
    let space = Space::parse(Space::DEFAULT).unwrap();
    assert!(space.len() >= 200, "acceptance demands a ≥200-point grid, got {}", space.len());
    let cache = EvalCache::new();
    let report = ExhaustiveGrid.run(&space, ctx, &cache).unwrap();
    (ExploreOutcome::new(report, ctx, &cache, 42, &space.spec), cache)
}

#[test]
fn default_grid_is_deterministic_and_byte_identical() {
    let ctx = default_ctx(1024);
    let (a, _) = run_default_grid(&ctx);
    let (b, _) = run_default_grid(&ctx);
    let ja = a.to_json().to_pretty();
    let jb = b.to_json().to_pretty();
    assert_eq!(ja, jb, "same seed must give a byte-identical frontier artifact");
    // and a fresh context with the same seed reproduces it too
    let ctx2 = default_ctx(1024);
    let (c, _) = run_default_grid(&ctx2);
    assert_eq!(ja, c.to_json().to_pretty());
}

#[test]
fn paper_point_is_on_the_frontier_and_dominates_sram() {
    let ctx = default_ctx(1024);
    let (outcome, _) = run_default_grid(&ctx);
    assert!(
        outcome.frontier.contains(&DesignPoint::paper()),
        "1S7E@0.8 must be on the discovered frontier"
    );
    let area_red = outcome.paper_area_reduction().unwrap();
    let energy_gain = outcome.paper_energy_gain().unwrap();
    assert!(area_red >= 0.40, "area reduction vs SRAM {area_red} < 40%");
    assert!(energy_gain >= 3.0, "energy gain vs SRAM {energy_gain} < 3x");
    assert_eq!(outcome.paper_ok(), Some(true));
    assert!(outcome.hypervolume > 0.0);
}

#[test]
fn parallel_batch_matches_sequential_evaluation() {
    // evaluate_many shards over util::par with a fixed shard count; the
    // objectives must be identical to direct sequential evaluation
    let ctx = default_ctx(512);
    let cache = EvalCache::new();
    let points: Vec<DesignPoint> = Space::parse("ratio=1..15,vref=0.7|0.8")
        .unwrap()
        .expand()
        .unwrap();
    let batch = mcaimem::dse::evaluate_many(&points, &ctx, &cache);
    assert_eq!(batch.len(), 30);
    for (p, o) in points.iter().zip(&batch) {
        assert_eq!(*o, evaluate(p, &ctx), "{p}");
    }
    assert_eq!(cache.misses(), 30);
    // a second batch is served entirely from the memo cache
    let again = mcaimem::dse::evaluate_many(&points, &ctx, &cache);
    assert_eq!(cache.misses(), 30);
    assert_eq!(cache.hits(), 30);
    assert_eq!(batch, again);
}

#[test]
fn quick_grid_gates_the_paper_point() {
    // the CI smoke path: the pinned quick grid must keep the paper point
    // on the frontier with the same dominance margins
    let ctx = default_ctx(1024);
    let space = Space::parse(Space::QUICK).unwrap();
    let cache = EvalCache::new();
    let report = ExhaustiveGrid.run(&space, &ctx, &cache).unwrap();
    let outcome = ExploreOutcome::new(report, &ctx, &cache, 42, &space.spec);
    assert_eq!(outcome.paper_ok(), Some(true));
    // the artifact round-trips through the diff loader
    let json = outcome.to_json().to_pretty();
    let f = mcaimem::report::pareto::frontier_from_artifact(&json).unwrap();
    let d = mcaimem::dse::diff(&f, &outcome.frontier);
    assert!(d.is_unchanged());
}

#[test]
fn paper_point_survives_the_tier_axis() {
    // the hierarchy axis (ISSUE 8): crossing the quick grid with
    // tier=none|sram:16k|32k|64k quadruples the space, but the flat
    // 1S7E@0.8 must keep its frontier slot — a tiered twin adds front
    // silicon, so it can never dominate its flat sibling on area
    let ctx = default_ctx(1024);
    let spec = format!("{},tier=none|sram:16k|sram:32k|sram:64k", Space::QUICK);
    let space = Space::parse(&spec).unwrap();
    assert_eq!(space.len(), 4 * Space::parse(Space::QUICK).unwrap().len());
    let cache = EvalCache::new();
    let report = ExhaustiveGrid.run(&space, &ctx, &cache).unwrap();
    let outcome = ExploreOutcome::new(report, &ctx, &cache, 42, &space.spec);
    assert!(
        outcome.frontier.contains(&DesignPoint::paper()),
        "1S7E@0.8 must stay on the frontier with the tier axis enabled"
    );
    assert_eq!(outcome.paper_ok(), Some(true));
    // structural guarantee behind the acceptance bar: every tiered twin
    // carries strictly more silicon than its flat sibling at otherwise
    // identical retention exposure, so no flat point can be evicted
    let flat = evaluate(&DesignPoint::paper(), &ctx);
    let tiered = evaluate(
        &DesignPoint { tier: TierConfig::SramFront { kib: 32 }, ..DesignPoint::paper() },
        &ctx,
    );
    assert!(tiered.area_mm2 > flat.area_mm2);
    assert_eq!(tiered.err_proxy, flat.err_proxy);
}

#[test]
fn frontier_spans_the_three_way_tradeoff() {
    // the frontier must expose real trade-offs, not a single winner: its
    // extremes in area, energy and accuracy are different designs
    let ctx = default_ctx(1024);
    let (outcome, _) = run_default_grid(&ctx);
    let pts = &outcome.frontier.points;
    assert!(pts.len() >= 5, "a 200-point grid must keep a non-trivial frontier");
    let min_by = |f: fn(&mcaimem::dse::Objectives) -> f64| {
        pts.iter()
            .min_by(|a, b| f(&a.objectives).partial_cmp(&f(&b.objectives)).unwrap())
            .unwrap()
            .point
            .clone()
    };
    let best_area = min_by(|o| o.area_mm2);
    let best_err = min_by(|o| o.err_proxy);
    assert_ne!(best_area, best_err, "area and accuracy must pull apart");
    // the area extreme is the most eDRAM-heavy ratio in the space
    assert_eq!(best_area.ratio, 15);
}
