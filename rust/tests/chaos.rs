//! Chaos acceptance tests (ISSUE 6): one seeded drill exercising all six
//! fault classes across both tiers — the memory tier must stay bit- and
//! meter-exact against the fault-aware oracle (flat and sharded ×4,
//! with and without the ECC plane), the degraded serving pool must lose
//! zero replies, an unrecoverable defect under an active fault plan must
//! shrink to a ≤20-op replayable trace, and sharded per-shard meters must
//! merge exactly while the plan is live.
//!
//! The CLI drill (`mcaimem chaos --quick --seed 42`) runs the same
//! machinery; these tests keep op counts test-suite friendly.

use mcaimem::faults::FaultPlan;
use mcaimem::mem::backend::{BackendSpec, MemoryBackend};
use mcaimem::mem::mcaimem::EnergyMeter;
use mcaimem::sim::campaign::{self, minimize, CampaignConfig};
use mcaimem::sim::chaos::{self, ChaosConfig};
use mcaimem::sim::replay::replay;
use mcaimem::sim::trace::Op;

/// The memory-tier fault classes (the engine classes would be inert in a
/// backend-only campaign).
const MEMORY_PLAN: &str =
    "retention-tail@0.01,stuck-at@0.005,vref-drift@0.005,refresh-stall@3,shard-outage@1e-4";

#[test]
fn full_drill_survives_all_six_fault_classes_with_zero_lost_replies() {
    // the acceptance drill: the default plan (all six classes at once)
    // over mcaimem@0.8 and mcaimem@0.8+ecc, flat and sharded ×4, plus a
    // degraded worker pool — seeded, so the run is reproducible
    let cfg = ChaosConfig {
        ops: 600,
        bytes: 32 * 1024,
        shards: 4,
        requests: 96,
        ..ChaosConfig::default()
    };
    let outcome = chaos::run(&cfg).unwrap();

    // the plan really carries every class
    assert!(outcome.plan.retention_tail.is_some());
    assert!(outcome.plan.stuck_at.is_some());
    assert!(outcome.plan.vref_drift.is_some());
    assert!(outcome.plan.refresh_stall.is_some());
    assert!(outcome.plan.shard_outage.is_some());
    assert!(outcome.plan.engine_timeout.is_some());
    assert!(outcome.plan.engine_crash.is_some());

    // memory tier: 2 specs × (flat + sharded ×4), every geometry bit- and
    // meter-exact against the fault-aware oracle
    assert_eq!(outcome.memory.len(), 4);
    for o in &outcome.memory {
        assert!(o.ok(), "{} {}: {:?}", o.spec, o.geometry(), o.failures);
        assert_eq!(o.oracle_ok, Some(true), "{} {}", o.spec, o.geometry());
        assert!(o.counts.3 > 0, "{} {}: the drill must exercise refresh", o.spec, o.geometry());
    }
    assert!(outcome
        .memory
        .iter()
        .any(|o| o.shards == 4 && matches!(o.spec, BackendSpec::Mcaimem { ecc: false, .. })));
    assert!(outcome
        .memory
        .iter()
        .any(|o| o.shards == 4 && matches!(o.spec, BackendSpec::Mcaimem { ecc: true, .. })));

    // serving tier: the fatal crash takes exactly one worker, injected
    // engine faults surface as error replies, and nothing vanishes
    let s = &outcome.serving;
    assert_eq!(s.lost, 0, "{s:?}");
    assert_eq!(s.offered, 96);
    assert_eq!(s.alive_workers, s.workers - 1, "{s:?}");
    assert!(s.errors > 0, "injected engine faults must surface as error replies: {s:?}");
    assert!(outcome.ok());
}

#[test]
fn unrecoverable_fault_shrinks_to_a_replayable_minimal_trace() {
    // a defect the plan cannot absorb (a corrupted load path) recorded
    // UNDER an active fault plan must ddmin-shrink to a ≤20-op trace that
    // still carries the plan in its header, replays exactly on a good
    // target and still diverges on the defective one
    let plan: FaultPlan = MEMORY_PLAN.parse().unwrap();
    let cfg = CampaignConfig {
        ops: 200,
        seed: 7,
        bytes: 32 * 1024,
        shards: 2,
        shrink: true,
        faults: Some(plan.clone()),
    };
    let spec: BackendSpec = "mcaimem@0.8".parse().unwrap();
    let trace = campaign::record(&spec, 0, &cfg).unwrap();
    assert_eq!(trace.faults, Some(plan.clone()), "the plan must ride the header");
    assert!(
        trace.entries.iter().any(|e| matches!(e.op, Op::Load { len, .. } if len > 64)),
        "op stream must contain a load long enough to trip the defect"
    );

    let minimal = minimize(
        &trace,
        &mut || trace.build_target().unwrap(),
        &mut || {
            Box::new(Corrupting { inner: trace.build_target().unwrap() })
                as Box<dyn MemoryBackend>
        },
    );
    assert!(!minimal.entries.is_empty());
    assert!(minimal.entries.len() <= 20, "shrunk to {} ops", minimal.entries.len());
    assert_eq!(minimal.faults, Some(plan), "the shrunk artifact must stay fault-aware");
    // internally consistent: exact on a good (fault-wrapped) target …
    let mut good = minimal.build_target().unwrap();
    assert!(replay(&minimal, good.as_mut()).exact());
    // … and still failing on the defective one
    let mut bad = Corrupting { inner: minimal.build_target().unwrap() };
    assert!(replay(&minimal, &mut bad).divergence.is_some());
}

#[test]
fn sharded_meters_merge_exactly_under_an_active_fault_plan() {
    // satellite: EnergyMeter::merge on the serving read-out path, with
    // faults live — per-shard meters must fold into the trait-level merged
    // meter, the merged meter must match the recorded expectation, and
    // striping must conserve bytes against the flat geometry
    let plan: FaultPlan = MEMORY_PLAN.parse().unwrap();
    let cfg = CampaignConfig {
        ops: 400,
        seed: 9,
        bytes: 32 * 1024,
        shards: 4,
        shrink: false,
        faults: Some(plan),
    };
    let spec: BackendSpec = "mcaimem@0.8".parse().unwrap();

    let sharded = campaign::record(&spec, 4, &cfg).unwrap();
    let mut target = sharded.build_target().unwrap();
    let rep = replay(&sharded, target.as_mut());
    assert!(rep.exact(), "sharded self-replay under faults: {}", rep.divergence.unwrap());

    // the fault wrapper forwards the per-shard break-down; the field-wise
    // merge reproduces the merged read-out
    let per = target.shard_meters();
    assert_eq!(per.len(), 4);
    let mut sum = EnergyMeter::default();
    for m in &per {
        sum.merge(m);
    }
    let merged = target.meter();
    assert!((sum.total_j() - merged.total_j()).abs() < 1e-18);
    assert_eq!(sum.reads, merged.reads);
    assert_eq!(sum.writes, merged.writes);
    assert_eq!(sum.refreshes, merged.refreshes);
    assert_eq!(sum.bytes_read, merged.bytes_read);
    assert_eq!(sum.bytes_written, merged.bytes_written);
    assert_eq!(sum.flips_committed, merged.flips_committed);
    assert_eq!(sum.ecc_corrected, merged.ecc_corrected);
    // meter-exactness: the replayed merged meter IS the last recorded
    // expectation (replay checks every snapshot; pin the final one)
    assert_eq!(sharded.entries.last().unwrap().expect.meter, *merged);

    // flat geometry under the same plan: the identical op stream conserves
    // bytes exactly (striping splits events, never payloads; the fault
    // wrapper drops the same refresh slots in both geometries) and lands
    // within the per-shard weak-cell wobble on energy
    let flat = campaign::record(&spec, 0, &cfg).unwrap();
    let mut ftarget = flat.build_target().unwrap();
    assert!(replay(&flat, ftarget.as_mut()).exact());
    let fm = ftarget.meter();
    assert_eq!(fm.bytes_written, merged.bytes_written);
    assert_eq!(fm.bytes_read, merged.bytes_read);
    assert!(
        (fm.total_j() - merged.total_j()).abs() / fm.total_j() < 0.02,
        "flat {} J vs sharded {} J",
        fm.total_j(),
        merged.total_j()
    );
}

/// Test double: corrupts the first byte of any load longer than 64 B —
/// a defect no fault plan explains, so conformance must flag and shrink it.
struct Corrupting {
    inner: Box<dyn MemoryBackend>,
}

impl MemoryBackend for Corrupting {
    fn spec(&self) -> BackendSpec {
        self.inner.spec()
    }
    fn capacity(&self) -> usize {
        self.inner.capacity()
    }
    fn now(&self) -> f64 {
        self.inner.now()
    }
    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        self.inner.store(addr, data, now)
    }
    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        let mut out = self.inner.load(addr, len, now);
        if out.len() > 64 {
            out[0] ^= 1;
        }
        out
    }
    fn tick(&mut self, now: f64) {
        self.inner.tick(now)
    }
    fn refresh_due(&self) -> Option<f64> {
        self.inner.refresh_due()
    }
    fn refresh_row(&mut self, row: usize, now: f64) {
        self.inner.refresh_row(row, now)
    }
    fn rows_per_bank(&self) -> usize {
        self.inner.rows_per_bank()
    }
    fn meter(&self) -> &EnergyMeter {
        self.inner.meter()
    }
    fn energy_card(&self) -> &mcaimem::mem::energy::EnergyCard {
        self.inner.energy_card()
    }
}
