//! Integration tests over the AOT artifacts + PJRT runtime — the
//! cross-language correctness seam: the L1 Pallas kernels (compiled into
//! the HLO) must agree bit-for-bit with the independent Rust
//! implementations, and the L2 model must reproduce the Fig. 11 behaviour
//! when driven from Rust.
//!
//! These tests skip (with a note) when `artifacts/` has not been built;
//! `make test` builds it first.

use mcaimem::encode::one_enhancement::{decode_byte, encode, encode_byte};
use mcaimem::inject::{inject, Mode};
use mcaimem::mem::backend::BackendSpec;
use mcaimem::runtime::executor::ModelRunner;
use mcaimem::util::rng::Pcg64;

const CLEAN: BackendSpec = BackendSpec::Sram;
const AGED: BackendSpec = BackendSpec::mcaimem_default();
const AGED_NOENC: BackendSpec = BackendSpec::Mcaimem { vref: 0.8, encode: false, ecc: false };

fn runner() -> Option<ModelRunner> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ModelRunner::new(&dir) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping (artifacts not built): {e}");
            None
        }
    }
}

#[test]
fn pallas_encode_matches_rust_encode_bit_for_bit() {
    let Some(mut r) = runner() else { return };
    let mut rng = Pcg64::new(11);
    let x: Vec<i8> = (0..4096).map(|_| rng.next_u64() as i8).collect();
    let pallas = r.encode_only(&x).unwrap();
    assert_eq!(pallas, encode(&x));
}

#[test]
fn pallas_store_path_matches_rust_store_path() {
    let Some(mut r) = runner() else { return };
    let mut rng = Pcg64::new(13);
    for p in [0.0, 0.05, 0.5, 1.0] {
        let x: Vec<i8> = (0..4096).map(|_| rng.next_u64() as i8).collect();
        let mask = ModelRunner::draw_mask(&mut rng, x.len(), p);
        let pallas = r.encoder_roundtrip(&x, &mask).unwrap();
        // rust reference: encode → or-in masked zeros → decode
        let rust: Vec<i8> = x
            .iter()
            .zip(&mask)
            .map(|(&v, &m)| {
                let e = encode_byte(v as u8);
                decode_byte(e | (m as u8 & !e & 0x7f)) as i8
            })
            .collect();
        assert_eq!(pallas, rust, "p={p}");
    }
}

#[test]
fn store_path_statistics_match_rust_inject_model() {
    // same transform, independent mask draws: the *distribution* of damage
    // must agree between the PJRT path and rust/src/inject
    let Some(mut r) = runner() else { return };
    let mut rng = Pcg64::new(17);
    let p = 0.1;
    let x: Vec<i8> = (0..4096).map(|_| (rng.normal() * 8.0) as i8).collect(); // roundtrip artifact is fixed at 4096

    let mask = ModelRunner::draw_mask(&mut rng, x.len(), p);
    let pallas = r.encoder_roundtrip(&x, &mask).unwrap();
    let err_pallas: f64 = x
        .iter()
        .zip(&pallas)
        .map(|(&a, &b)| (a as i16 - b as i16).abs() as f64)
        .sum::<f64>()
        / x.len() as f64;

    let mut rust = x.clone();
    inject(&mut rust, p, Mode::WithOneEnhancement, &mut rng);
    let err_rust: f64 = x
        .iter()
        .zip(&rust)
        .map(|(&a, &b)| (a as i16 - b as i16).abs() as f64)
        .sum::<f64>()
        / x.len() as f64;

    let rel = (err_pallas - err_rust).abs() / err_rust.max(1e-9);
    assert!(rel < 0.15, "pallas={err_pallas} rust={err_rust}");
}

#[test]
fn clean_accuracy_matches_manifest() {
    let Some(mut r) = runner() else { return };
    let acc = r.accuracy(&CLEAN, 0.0, 8, 3).unwrap();
    assert!((acc - r.artifacts.int8_clean_acc).abs() < 0.05, "acc={acc}");
    assert!(acc > 0.9);
}

#[test]
fn clean_inference_is_deterministic() {
    let Some(mut r) = runner() else { return };
    let x = r.artifacts.tensor("x_test_i8").unwrap().as_i8().unwrap();
    let batch = r.artifacts.batch * r.artifacts.input_dim;
    let mut rng = Pcg64::new(5);
    let a = r.infer(&x[..batch], &CLEAN, 0.0, &mut rng).unwrap();
    let b = r.infer(&x[..batch], &CLEAN, 0.0, &mut rng).unwrap();
    assert_eq!(a, b);
}

#[test]
fn fig11_ordering_holds_through_pjrt() {
    let Some(mut r) = runner() else { return };
    let with = r.accuracy(&AGED, 0.10, 4, 7).unwrap();
    let without = r.accuracy(&AGED_NOENC, 0.10, 4, 7).unwrap();
    assert!(
        with > without + 0.3,
        "one-enhancement must dominate at 10%: with={with} without={without}"
    );
    // without-encoder at 25% collapses toward chance (paper: "plummets")
    let collapsed = r.accuracy(&AGED_NOENC, 0.25, 4, 9).unwrap();
    assert!(collapsed < 0.35, "collapsed={collapsed}");
}

#[test]
fn zero_flip_rate_equals_clean_through_aged_graph() {
    let Some(mut r) = runner() else { return };
    let clean = r.accuracy(&CLEAN, 0.0, 4, 1).unwrap();
    let aged0 = r.accuracy(&AGED, 0.0, 4, 1).unwrap();
    let aged0n = r.accuracy(&AGED_NOENC, 0.0, 4, 1).unwrap();
    assert_eq!(clean, aged0);
    assert_eq!(clean, aged0n);
}
