//! Whole-system integration: the paper's headline claims as executable
//! gates, report generation, and closed-form ↔ event-driven agreement.

use mcaimem::coordinator::scheduler::simulate_inference;
use mcaimem::energy::opswatt::opswatt_gain;
use mcaimem::energy::system_eval::{evaluate, mcaimem_gain};
use mcaimem::mem::area::AreaModel;
use mcaimem::mem::backend::BackendSpec;
use mcaimem::mem::MemKind;
use mcaimem::scalesim::accelerator::AcceleratorConfig;
use mcaimem::scalesim::{network, simulate_network};
use mcaimem::util::units::MIB;

#[test]
fn headline_area_reduction_is_48_percent() {
    let red = AreaModel::lp45().mcaimem_reduction(MIB);
    assert!((red - 0.48).abs() < 0.005, "reduction={red}");
}

#[test]
fn headline_energy_gain_near_3_4x_on_the_benchmark_suite() {
    // the paper's single headline number is the suite-level gain; per
    // workload it varies. Gate: geometric mean across CNNs on Eyeriss
    // within [2.7, 4.2] and every workload > 2.2×.
    let acc = AcceleratorConfig::eyeriss();
    let mut logsum = 0.0;
    let mut n = 0.0;
    for net in network::all_networks() {
        let t = simulate_network(&net, &acc);
        let g = mcaimem_gain(&t, &acc);
        assert!(g > 2.2, "{}: gain={g}", net.name);
        logsum += g.ln();
        n += 1.0;
    }
    let gmean = (logsum / n).exp();
    assert!(gmean > 2.7 && gmean < 4.2, "geometric-mean gain={gmean}");
}

#[test]
fn opswatt_band_matches_fig16() {
    for acc in AcceleratorConfig::paper_platforms() {
        for net in network::all_networks() {
            let t = simulate_network(&net, &acc);
            let g = opswatt_gain(&t, &acc, &BackendSpec::mcaimem_default());
            assert!(
                g > 0.20 && g < 0.55,
                "{}@{}: ops/W gain {g} out of band",
                net.name,
                acc.name
            );
        }
    }
}

#[test]
fn memory_ranking_is_stable_across_workloads_and_platforms() {
    // total energy: MCAIMem < SRAM < RRAM on every (net, platform);
    // eDRAM is refresh-crippled: always worse than MCAIMem
    for acc in AcceleratorConfig::paper_platforms() {
        for net in network::all_networks() {
            let t = simulate_network(&net, &acc);
            let m = evaluate(&t, &acc, &BackendSpec::mcaimem_default()).total_j();
            let s = evaluate(&t, &acc, &BackendSpec::Sram).total_j();
            let e = evaluate(&t, &acc, &BackendSpec::Edram2t).total_j();
            let r = evaluate(&t, &acc, &BackendSpec::Rram).total_j();
            assert!(m < s && s < r, "{}@{}", net.name, acc.name);
            assert!(m < e, "{}@{}", net.name, acc.name);
        }
    }
}

#[test]
fn all_reports_generate_with_nonempty_rows() {
    for id in mcaimem::report::ALL_IDS {
        if id == "fig11" {
            continue; // artifact-dependent; covered in integration_runtime
        }
        let tables = mcaimem::report::generate(id, None, true).unwrap();
        assert!(!tables.is_empty());
        for t in tables {
            assert!(!t.rows.is_empty(), "{id}");
            // CSV mirror renders
            assert!(t.to_csv().lines().count() >= 2);
        }
    }
}

#[test]
fn event_driven_and_closed_form_agree_on_scale() {
    // over several networks the two estimates stay within 2× (different
    // data-occupancy assumptions; see scheduler.rs doc-comment)
    let acc = AcceleratorConfig::eyeriss();
    for name in ["LeNet", "VGG11"] {
        let net = network::by_name(name).unwrap();
        let sim = simulate_inference(&net, &acc, &BackendSpec::mcaimem_default(), 3).unwrap();
        let t = simulate_network(&net, &acc);
        let cf = evaluate(&t, &acc, &BackendSpec::mcaimem_default());
        let ratio = sim.total_j() / cf.total_j();
        assert!(ratio > 0.5 && ratio < 2.0, "{name}: ratio={ratio}");
    }
}

#[test]
fn cell_area_ordering_matches_table1() {
    use mcaimem::mem::area::cell_area_rel;
    assert!(cell_area_rel(MemKind::Edram1t1c) < cell_area_rel(MemKind::Edram3t));
    assert!(cell_area_rel(MemKind::Edram3t) < cell_area_rel(MemKind::Edram2t));
    assert!(cell_area_rel(MemKind::Edram2t) < 1.0);
}

#[test]
fn tpu_and_eyeriss_scale_static_power_correctly() {
    // TPUv1's 8 MB buffer must burn ~76× the static power of Eyeriss' 108 KB
    let e = AcceleratorConfig::eyeriss();
    let t = AcceleratorConfig::tpuv1();
    let ratio = t.buffer_scale_vs_1mb() / e.buffer_scale_vs_1mb();
    assert!((ratio - 8.0 * 1024.0 / 108.0).abs() < 0.5, "ratio={ratio}");
}
