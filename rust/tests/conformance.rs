//! End-to-end conformance acceptance tests (ISSUE 4): every backend
//! replays its own recorded trace exactly; MCAIMem (word-parallel, flat
//! and sharded ×4) matches the golden model bit- and meter-exactly; the
//! recorder threads through `BufferManager` and `WorkerPool` unchanged; an
//! intentionally injected off-by-one is caught and shrunk to a minimal
//! reproducing trace; and failure artifacts round-trip through JSON.
//!
//! The CLI campaign (`mcaimem conform --ops 20000 ...`) runs the same
//! machinery at full depth; these tests keep op counts test-suite friendly.

use std::time::Duration;

use mcaimem::coordinator::buffer_manager::BufferManager;
use mcaimem::coordinator::pool::{InferEngine, PoolConfig, SyntheticEngine, WorkerPool};
use mcaimem::mem::backend::{self, BackendSpec, MemoryBackend};
use mcaimem::mem::energy::EnergyCard;
use mcaimem::mem::mcaimem::EnergyMeter;
use mcaimem::mem::sharded::ShardedBackend;
use mcaimem::sim::campaign::{self, minimize, CampaignConfig};
use mcaimem::sim::oracle::OracleBackend;
use mcaimem::sim::replay::replay;
use mcaimem::sim::trace::{Trace, TracingBackend};

fn acceptance_specs() -> Vec<BackendSpec> {
    BackendSpec::parse_list("sram,edram2t,rram,mcaimem@0.8,mcaimem@0.7-noenc").unwrap()
}

#[test]
fn every_backend_replays_its_own_campaign_trace_exactly() {
    let cfg = CampaignConfig {
        ops: 300,
        seed: 7,
        bytes: 64 * 1024,
        shards: 4,
        shrink: false,
        faults: None,
    };
    for spec in acceptance_specs() {
        for shards in [0usize, 4] {
            let trace = campaign::record(&spec, shards, &cfg).unwrap();
            let rep = campaign::verify_self(&trace).unwrap();
            assert!(
                rep.exact(),
                "{spec} shards={shards}: {}",
                rep.divergence.unwrap()
            );
        }
    }
}

#[test]
fn mcaimem_sharded_x4_matches_the_golden_model_bit_and_meter_exactly() {
    // the acceptance configuration: word-parallel mcaimem@0.8 striped
    // across 4 shards, diffed against the naive byte-per-cell oracle
    let cfg = CampaignConfig {
        ops: 400,
        seed: 7,
        bytes: 64 * 1024,
        shards: 4,
        shrink: false,
        faults: None,
    };
    for spec in ["mcaimem@0.8", "mcaimem@0.7-noenc"] {
        let spec: BackendSpec = spec.parse().unwrap();
        for shards in [0usize, 4] {
            let trace = campaign::record(&spec, shards, &cfg).unwrap();
            let rep = campaign::verify_oracle(&trace).unwrap();
            assert!(
                rep.exact(),
                "{spec} shards={shards} diverged from the oracle: {}",
                rep.divergence.unwrap()
            );
            assert_eq!(rep.ops, trace.entries.len());
        }
    }
}

#[test]
fn tracing_backend_threads_through_buffer_manager() {
    // the recorder sits below the manager: allocation, refresh-controller
    // slots and tensor traffic all land in the trace, and the trace
    // replays exactly on a fresh identical backend
    let spec = BackendSpec::mcaimem_default();
    let inner = backend::build(&spec, 64 * 1024, 11);
    let (traced, log) = TracingBackend::wrap(inner, 64 * 1024, 11, 0);
    let mut bm = BufferManager::from_backend(traced);
    let h = bm.alloc(1000).unwrap();
    let data: Vec<u8> = (0..1000u32).map(|i| (i * 13) as u8).collect();
    bm.store(h, &data).unwrap();
    for _ in 0..40 {
        bm.tick(1e-6); // fires refresh slots into the recorded backend
    }
    assert_eq!(bm.load(h), data);
    let trace = log.lock().unwrap().clone();
    let (_, _, _, refreshes) = trace.op_counts();
    assert!(refreshes > 0, "manager-driven refresh must appear in the trace");
    let mut target = trace.build_target().unwrap();
    let rep = replay(&trace, target.as_mut());
    assert!(rep.exact(), "{}", rep.divergence.unwrap());
    // and the same trace matches the golden model
    let mut orc = OracleBackend::for_trace(&trace).unwrap();
    let rep = replay(&trace, &mut orc);
    assert!(rep.exact(), "oracle: {}", rep.divergence.unwrap());
}

#[test]
fn tracing_backend_threads_through_the_worker_pool() {
    // record real serving traffic: a worker stages every batch through its
    // buffer (store → tick → load), all below the recorder. Wall-clock
    // batching is nondeterministic; the recorded device schedule replays
    // exactly regardless.
    let spec = BackendSpec::mcaimem_default();
    let sharded = ShardedBackend::new(&spec, 2, 64 * 1024, 21).unwrap();
    let (traced, log) = TracingBackend::wrap(Box::new(sharded), 64 * 1024, 21, 2);
    let buffers = vec![BufferManager::from_backend(traced)];
    let cfg = PoolConfig {
        backend: spec,
        workers: 1,
        shards: 2,
        buffer_bytes: 64 * 1024,
        batch_window: Duration::from_micros(50),
        high_water: 10_000,
        seed: 21,
        ..PoolConfig::default()
    };
    let engines: Vec<Box<dyn InferEngine>> = vec![Box::new(SyntheticEngine {
        exec_latency: Duration::ZERO,
        ..Default::default()
    })];
    let pool = WorkerPool::start_with_buffers(cfg, engines, buffers).unwrap();
    for i in 0..12 {
        let (_, _) = pool.classify(vec![i as i8; 784]).unwrap();
    }
    let stats = pool.shutdown();
    assert_eq!(stats.requests, 12);

    let trace = log.lock().unwrap().clone();
    assert!(!trace.entries.is_empty(), "serving traffic must be recorded");
    let (stores, loads, _, _) = trace.op_counts();
    assert!(stores > 0 && loads > 0, "staged batches are stores+loads");
    let mut target = trace.build_target().unwrap();
    let rep = replay(&trace, target.as_mut());
    assert!(rep.exact(), "{}", rep.divergence.unwrap());
}

/// The "scratch branch with an off-by-one" of the acceptance criteria:
/// loads of ≥ 2 bytes return the byte at `len-2` in the last position.
struct OffByOne {
    inner: Box<dyn MemoryBackend>,
}

impl MemoryBackend for OffByOne {
    fn spec(&self) -> BackendSpec {
        self.inner.spec()
    }
    fn capacity(&self) -> usize {
        self.inner.capacity()
    }
    fn now(&self) -> f64 {
        self.inner.now()
    }
    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        self.inner.store(addr, data, now)
    }
    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        let mut out = self.inner.load(addr, len, now);
        if let [.., a, b] = out.as_mut_slice() {
            *b = *a; // the off-by-one: last byte fetched from len-2
        }
        out
    }
    fn tick(&mut self, now: f64) {
        self.inner.tick(now)
    }
    fn refresh_due(&self) -> Option<f64> {
        self.inner.refresh_due()
    }
    fn refresh_row(&mut self, row: usize, now: f64) {
        self.inner.refresh_row(row, now)
    }
    fn rows_per_bank(&self) -> usize {
        self.inner.rows_per_bank()
    }
    fn meter(&self) -> &EnergyMeter {
        self.inner.meter()
    }
    fn energy_card(&self) -> &EnergyCard {
        self.inner.energy_card()
    }
}

#[test]
fn injected_off_by_one_is_caught_and_shrunk_to_a_minimal_trace() {
    let cfg =
        CampaignConfig { ops: 500, seed: 7, bytes: 64 * 1024, shards: 0, ..Default::default() };
    let spec = BackendSpec::mcaimem_default();
    let trace = campaign::record(&spec, 0, &cfg).unwrap();

    // the bug is caught...
    let mut buggy = OffByOne { inner: trace.build_target().unwrap() };
    let rep = replay(&trace, &mut buggy);
    let div = rep.divergence.expect("the off-by-one must be caught");
    assert_eq!(div.field, "bytes", "a byte-level bug diverges on bytes: {div}");

    // ...and shrunk to a minimal reproducing trace of at most 20 ops
    let minimal = minimize(
        &trace,
        &mut || trace.build_target().unwrap(),
        &mut || Box::new(OffByOne { inner: trace.build_target().unwrap() }) as Box<dyn MemoryBackend>,
    );
    assert!(
        (1..=20).contains(&minimal.entries.len()),
        "shrunk to {} ops (acceptance bound: ≤ 20)",
        minimal.entries.len()
    );
    // the minimal trace is a real reproduction: exact on the good build,
    // diverging on the buggy one
    let mut good = trace.build_target().unwrap();
    assert!(replay(&minimal, good.as_mut()).exact());
    let mut bad = OffByOne { inner: trace.build_target().unwrap() };
    assert!(replay(&minimal, &mut bad).divergence.is_some());

    // failure artifact round-trip: save → load → still reproduces (what a
    // CI artifact replayed locally via `mcaimem conform --replay` does)
    let path = std::env::temp_dir().join("mcaimem_conformance_minimal_trace.json");
    minimal.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded, minimal);
    let mut bad = OffByOne { inner: loaded.build_target().unwrap() };
    assert!(replay(&loaded, &mut bad).divergence.is_some());
    let _ = std::fs::remove_file(path);
}

#[test]
fn campaign_runner_end_to_end_is_green_for_the_acceptance_sweep() {
    // the `mcaimem conform` path in miniature: all five acceptance specs,
    // flat + sharded ×4, self-replay + oracle where applicable
    let cfg =
        CampaignConfig { ops: 150, seed: 7, bytes: 64 * 1024, shards: 4, ..Default::default() };
    let outcomes = campaign::run(&acceptance_specs(), &cfg).unwrap();
    assert_eq!(outcomes.len(), 10, "5 specs × (flat + sharded)");
    for o in &outcomes {
        assert!(o.ok(), "{} {}: {:?}", o.spec, o.geometry(), o.failures);
        assert!(o.failures.is_empty());
    }
    // oracle coverage exactly on the mcaimem specs
    let oracled = outcomes.iter().filter(|o| o.oracle_ok == Some(true)).count();
    assert_eq!(oracled, 4, "2 mcaimem specs × 2 geometries");
}
