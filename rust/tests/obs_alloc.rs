//! Pinned zero-cost guarantee: with tracing disabled (the default), the
//! hot-path telemetry hooks — sink emits on the submit/reply path and
//! histogram recording — perform **zero heap allocations**. This test
//! binary installs a counting global allocator (own integration binary, so
//! no other test shares the allocator) and pins the delta at exactly 0.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use mcaimem::obs::{worker_track, Event, EventKind, LogHistogram, ObsSink, TRACK_POOL};

struct CountingAlloc;

// Per-thread count so the two tests in this binary (which the harness runs
// on parallel threads) can't pollute each other's measured window.
// Const-initialized Cell: the TLS access itself never allocates; `try_with`
// shrugs off accesses during thread teardown.
thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn bump() {
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

#[test]
fn disabled_tracing_allocates_nothing_on_the_hot_path() {
    let sink = ObsSink::disabled();
    let mut hist = LogHistogram::new(); // allocates its bucket vec ONCE, here

    // warm up any lazy one-time state outside the measured window
    sink.emit(Event::instant(EventKind::Admit, TRACK_POOL, 0.0, 0, 0));
    hist.record(1.0);

    let before = alloc_count();
    for i in 0..10_000u64 {
        // the submit-path and reply-path emits the pool makes per request
        sink.emit(Event::instant(EventKind::Admit, TRACK_POOL, i as f64, i, 0));
        sink.emit(Event::span_begin(EventKind::Stage, worker_track(0), i as f64, i, 0));
        sink.emit(Event::span_end(EventKind::Stage, worker_track(0), i as f64 + 1.0, i, 0));
        sink.emit(Event::instant(EventKind::Reply, worker_track(0), i as f64 + 1.0, i, 0));
        // the per-request latency record every reply performs
        hist.record(100.0 + (i % 977) as f64);
    }
    let after = alloc_count();
    assert_eq!(
        after - before,
        0,
        "disabled-sink emit + histogram record must not touch the heap"
    );
    assert!(!sink.is_enabled());
    assert_eq!(hist.count(), 10_001);
}

#[test]
fn enabled_ring_pushes_do_not_allocate_after_construction() {
    // the ring buffer is one up-front allocation; steady-state pushes are
    // allocation-free even when tracing is ON (required for bounded,
    // non-perturbing capture on the serving path)
    let sink = ObsSink::enabled(1 << 10);
    sink.emit(Event::instant(EventKind::Admit, TRACK_POOL, 0.0, 0, 0));

    let before = alloc_count();
    for i in 0..50_000u64 {
        sink.emit(Event::instant(EventKind::Reply, worker_track(0), i as f64, i, 0));
    }
    let after = alloc_count();
    assert_eq!(after - before, 0, "steady-state ring pushes must not allocate");
    // the ring wrapped many times over: drops counted, capacity bounded
    assert!(sink.dropped_events() >= 50_000 - 1024);
    assert!(sink.events().len() <= 1024);
}
