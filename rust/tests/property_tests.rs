//! Property-based tests over the crate's core invariants, using the
//! in-tree mini-framework (`util::check`) — seeded, reproducible, with
//! counterexample reporting.

use mcaimem::encode::one_enhancement::{decode, encode, encode_byte};
use mcaimem::encode::stats::bit_histogram;
use mcaimem::inject::{flip_zeros_byte, inject, Mode};
use mcaimem::mem::bank::MemoryMap;
use mcaimem::mem::energy::EnergyCard;
use mcaimem::mem::mcaimem::MixedCellMemory;
use mcaimem::util::check::{self, Config};
use mcaimem::util::json::Json;
use mcaimem::util::rng::Pcg64;
use mcaimem::util::stats::{normal_cdf, normal_quantile};

fn cfg(cases: usize, seed: u64) -> Config {
    Config { cases, seed }
}

#[test]
fn prop_encoder_is_involution() {
    check::forall(
        cfg(512, 1),
        |r| check::uniform_i8(r, 257),
        |xs| decode(&encode(xs)) == *xs,
    );
}

#[test]
fn prop_encoder_preserves_sign_and_order_of_magnitude_bits() {
    check::forall(
        cfg(512, 2),
        |r| r.next_u64() as u8,
        |&b| {
            let e = encode_byte(b);
            // sign plane untouched; transform is a bijection on the low 7
            e & 0x80 == b & 0x80 && encode_byte(e) == b
        },
    );
}

#[test]
fn prop_encoding_never_reduces_ones_for_nonnegative() {
    // for v ≥ 0 near zero, the encoder adds ones; globally it's a bijection
    // so we check the *distributional* property on DNN-like data
    check::forall(
        cfg(64, 3),
        |r| check::dnn_i8(r, 2048, 9.0),
        |xs| {
            let before = bit_histogram(xs).edram_ones_frac();
            let after = bit_histogram(&encode(xs)).edram_ones_frac();
            after >= before
        },
    );
}

#[test]
fn prop_inject_only_adds_bits_and_never_touches_sign() {
    check::forall_explain(
        cfg(256, 4),
        |r| {
            let xs = check::uniform_i8(r, 300);
            let p = r.f64();
            let seed = r.next_u64();
            (xs, p, seed)
        },
        |(xs, p, seed)| {
            let mut rng = Pcg64::new(*seed);
            let mut ys = xs.clone();
            inject(&mut ys, *p, Mode::WithoutOneEnhancement, &mut rng);
            for (&a, &b) in xs.iter().zip(&ys) {
                let (a, b) = (a as u8, b as u8);
                if b & a != a {
                    return Err(format!("bit removed: {a:08b} → {b:08b}"));
                }
                if (a ^ b) & 0x80 != 0 {
                    return Err("sign flipped".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_flip_zeros_byte_idempotent_at_p1() {
    check::forall(
        cfg(256, 5),
        |r| (r.next_u64() as u8, r.next_u64()),
        |&(b, seed)| {
            let mut rng = Pcg64::new(seed);
            // at p = 1 every low-7 zero flips: result is exactly b | 0x7f
            flip_zeros_byte(b, 1.0, &mut rng) == (b | 0x7f)
        },
    );
}

#[test]
fn prop_memory_roundtrip_is_exact_when_fresh() {
    check::forall_explain(
        cfg(48, 6),
        |r| {
            let data = check::bytes(r, 512);
            let offset = r.below(1024) as usize;
            let seed = r.next_u64();
            (data, offset, seed)
        },
        |(data, offset, seed)| {
            if data.is_empty() {
                return Ok(());
            }
            let mut m = MixedCellMemory::new(16 * 1024, *seed);
            m.write(*offset, data, 1e-9);
            let back = m.read(*offset, data.len(), 2e-9);
            if back != *data {
                return Err("fresh read mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_word_parallel_path_matches_scalar_reference() {
    // The tentpole invariant: the SWAR word-parallel access path must be
    // bit-exact against the retained scalar reference — returned bytes,
    // committed retention flips, the eDRAM ones census AND every
    // EnergyMeter field (ones counts feed the energy model) — across
    // random lengths, alignments, staleness gaps and encoder settings.
    check::forall_explain(
        cfg(32, 13),
        |r| {
            let seed = r.next_u64();
            let encode_enabled = r.bernoulli(0.8);
            // a mixed op sequence: (addr, len, staleness, is_write)
            let ops: Vec<(usize, usize, f64, bool)> = (0..8)
                .map(|_| {
                    (
                        r.below(12 * 1024) as usize,
                        r.below(900) as usize,
                        r.range(0.0, 40e-6),
                        r.bernoulli(0.5),
                    )
                })
                .collect();
            let fill = r.next_u64();
            (seed, encode_enabled, ops, fill)
        },
        |(seed, encode_enabled, ops, fill)| {
            let mut fast = MixedCellMemory::new(16 * 1024, *seed);
            let mut slow = MixedCellMemory::new(16 * 1024, *seed);
            fast.encode_enabled = *encode_enabled;
            slow.encode_enabled = *encode_enabled;
            slow.word_parallel = false;
            let mut data_rng = Pcg64::new(*fill);
            let mut now = 0.0;
            for &(addr, len, stale, is_write) in ops {
                now += stale;
                if is_write {
                    let mut data = vec![0u8; len];
                    data_rng.fill_bytes(&mut data);
                    fast.write(addr, &data, now);
                    slow.write(addr, &data, now);
                } else {
                    let a = fast.read(addr, len, now);
                    let b = slow.read(addr, len, now);
                    if a != b {
                        let diff = a.iter().zip(&b).filter(|(x, y)| x != y).count();
                        return Err(format!(
                            "read mismatch at addr={addr} len={len} now={now}: {diff} bytes differ"
                        ));
                    }
                }
                if fast.meter != slow.meter {
                    return Err(format!(
                        "meter diverged after op (addr={addr} len={len} write={is_write}):\n fast={:?}\n slow={:?}",
                        fast.meter, slow.meter
                    ));
                }
                if fast.edram_ones_frac() != slow.edram_ones_frac() {
                    return Err("ones census diverged".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_memory_errors_monotone_in_staleness() {
    // reading later never yields fewer corrupted bytes (flips only add)
    check::forall_explain(
        cfg(24, 7),
        |r| r.next_u64(),
        |&seed| {
            let mut m = MixedCellMemory::new(16 * 1024, seed);
            m.encode_enabled = false;
            let data = vec![0u8; 128];
            m.write(0, &data, 0.0);
            let t1 = m.read(0, 128, 20e-6);
            let e1 = t1.iter().filter(|&&b| b != 0).count();
            let t2 = m.read(0, 128, 60e-6);
            let e2 = t2.iter().filter(|&&b| b != 0).count();
            if e2 < e1 {
                return Err(format!("errors shrank: {e1} → {e2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mixed_card_is_weighted_average_of_components() {
    // the 1:7 composition law holds for every ones-fraction, not just the
    // table-II endpoints
    check::forall(
        cfg(256, 8),
        |r| r.f64(),
        |&f| {
            let s = EnergyCard::sram();
            let e = EnergyCard::edram2t();
            let m = EnergyCard::mcaimem_default();
            let blend = |sv: f64, ev: f64| (sv + 7.0 * ev) / 8.0;
            let ok = |a: f64, b: f64| (a - b).abs() < 1e-18 + 1e-9 * b.abs();
            ok(
                m.static_power(1 << 20, f),
                blend(s.static_power(1 << 20, f), e.static_power(1 << 20, f)),
            ) && ok(
                m.read_energy(1024, f),
                blend(s.read_energy(1024, f), e.read_energy(1024, f)),
            ) && ok(
                m.write_energy(1024, f),
                blend(s.write_energy(1024, f), e.write_energy(1024, f)),
            )
        },
    );
}

#[test]
fn prop_memorymap_locate_is_bijective() {
    check::forall(
        cfg(512, 9),
        |r| {
            let banks = 1 + r.below(32) as usize;
            let addr_frac = r.f64();
            (banks, addr_frac)
        },
        |&(banks, addr_frac)| {
            let map = MemoryMap::with_capacity(banks * 16 * 1024);
            let addr = ((map.capacity() - 1) as f64 * addr_frac) as usize;
            let (b, r_, c) = map.locate(addr);
            b * map.bank.bytes + r_ * map.bank.row_bytes + c == addr
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Num((rng.next_u64() as i32 as f64) / 8.0),
            3 => Json::Str(format!("s{}", rng.below(1000))),
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check::forall(
        cfg(256, 10),
        |r| random_json(r, 3),
        |j| {
            Json::parse(&j.to_string()).unwrap() == *j
                && Json::parse(&j.to_pretty()).unwrap() == *j
        },
    );
}

#[test]
fn prop_normal_quantile_inverts_cdf() {
    check::forall(
        cfg(512, 11),
        |r| 0.001 + 0.998 * r.f64(),
        |&p| (normal_cdf(normal_quantile(p)) - p).abs() < 1e-5,
    );
}

#[test]
fn prop_flip_model_monotone_in_time_and_vref() {
    let model = mcaimem::circuit::flip_model::FlipModel::mcaimem_85c();
    check::forall(
        cfg(256, 12),
        |r| {
            let t1 = r.range(0.0, 30e-6);
            let t2 = t1 + r.range(0.0, 30e-6);
            let v1 = r.range(0.45, 0.75);
            let v2 = v1 + r.range(0.0, 0.85 - v1);
            (t1, t2, v1, v2)
        },
        |&(t1, t2, v1, v2)| {
            model.flip_prob(t2, v1) + 1e-12 >= model.flip_prob(t1, v1)
                && model.flip_prob(t1, v2) <= model.flip_prob(t1, v1) + 1e-12
        },
    );
}
