//! Pallas↔Rust inject cross-check (the test `inject::mod` docs promise).
//!
//! `python/compile/kernels/gen_inject_fixtures.py` runs the L1 Pallas
//! retention-injection kernels (`inject_raw`, `mcaimem_store`,
//! interpret=True) over deterministic vectors and checks the outputs into
//! `tests/fixtures/inject_fixtures.json`. This test replays the identical
//! transform through `inject::apply_flip_mask` / `inject::inject_with_mask`
//! and asserts byte-identical results — Pallas is the recorded side, so no
//! Python runs at test time.

use std::path::Path;

use mcaimem::inject::{apply_flip_mask, inject_with_mask, Mode};
use mcaimem::util::json::Json;

fn fixture_i8(case: &Json, key: &str) -> Vec<i8> {
    case.get(key)
        .unwrap_or_else(|e| panic!("fixture case missing `{key}`: {e}"))
        .as_arr()
        .expect("fixture arrays are JSON arrays")
        .iter()
        .map(|v| v.as_f64().expect("fixture entries are numbers") as i64 as i8)
        .collect()
}

#[test]
fn rust_inject_matches_pallas_fixture_vectors() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/inject_fixtures.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let fixtures = Json::parse(&text).expect("fixture JSON parses");
    let cases = fixtures.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 4, "fixture file should carry several cases");

    let mut vectors = 0usize;
    for case in cases {
        let name = case.get("name").unwrap().as_str().unwrap_or("?").to_string();
        let x = fixture_i8(case, "x");
        let mask = fixture_i8(case, "mask");
        let raw = fixture_i8(case, "raw");
        let store = fixture_i8(case, "store");
        assert_eq!(x.len(), mask.len(), "{name}");
        assert_eq!(x.len(), raw.len(), "{name}");
        assert_eq!(x.len(), store.len(), "{name}");

        // inject_raw: flips applied to the raw stored image
        let mut got_raw = x.clone();
        inject_with_mask(&mut got_raw, &mask, Mode::WithoutOneEnhancement);
        assert_eq!(got_raw, raw, "{name}: inject_raw path diverged from Pallas");

        // mcaimem_store: encode → age → decode
        let mut got_store = x.clone();
        inject_with_mask(&mut got_store, &mask, Mode::WithOneEnhancement);
        assert_eq!(got_store, store, "{name}: mcaimem_store path diverged from Pallas");

        // byte-level form agrees with the slice-level form
        for ((&xv, &mv), &rv) in x.iter().zip(&mask).zip(&raw) {
            assert_eq!(apply_flip_mask(xv as u8, mv as u8), rv as u8, "{name}");
        }
        vectors += x.len();
    }
    assert!(vectors > 3000, "fixtures should pin thousands of vectors, got {vectors}");
}

#[test]
fn fixture_masks_respect_the_edram_plane_domain() {
    // defense for regenerated fixtures: masks must never carry the sign bit
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/inject_fixtures.json");
    let fixtures = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    for case in fixtures.get("cases").unwrap().as_arr().unwrap() {
        for m in fixture_i8(case, "mask") {
            assert_eq!(m as u8 & 0x80, 0, "mask byte {m} touches the sign plane");
        }
        // and the outputs only ever ADD bits relative to the input image
        let x = fixture_i8(case, "x");
        let raw = fixture_i8(case, "raw");
        for (&before, &after) in x.iter().zip(&raw) {
            assert_eq!(after as u8 & before as u8, before as u8);
            assert_eq!(after as u8 & 0x80, before as u8 & 0x80);
        }
    }
}
