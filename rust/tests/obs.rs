//! Cross-thread and schema-level tests of the telemetry backbone: the
//! multi-writer event ring under real contention, per-track ordering
//! through the Chrome trace exporter, and the log-bucketed histogram's
//! error bound checked property-style against an exact sort.

use std::sync::Arc;
use std::thread;

use mcaimem::obs::export::chrome_trace;
use mcaimem::obs::{worker_track, Event, EventKind, EventRing, LogHistogram, ObsSink};
use mcaimem::util::json::Json;
use mcaimem::util::rng::Pcg64;

/// Concurrent writers never tear a payload: every event is written with
/// `a == b == t_us` (as bits), so any interleaved payload write would
/// surface as a mismatched triple in the snapshot.
#[test]
fn concurrent_writers_never_tear_events() {
    const WRITERS: u64 = 8;
    const PER_WRITER: u64 = 4_000;
    // deliberately smaller than the offered volume so laps + collisions
    // actually happen while the snapshot invariant still must hold
    let ring = Arc::new(EventRing::new(1 << 10));
    let handles: Vec<_> = (0..WRITERS)
        .map(|w| {
            let ring = Arc::clone(&ring);
            thread::spawn(move || {
                for i in 0..PER_WRITER {
                    let tag = w * PER_WRITER + i;
                    ring.push(Event::instant(
                        EventKind::Reply,
                        worker_track(w as usize),
                        tag as f64,
                        tag,
                        tag,
                    ));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let got = ring.snapshot();
    assert!(!got.is_empty());
    for (_, e) in &got {
        assert_eq!(e.a, e.b, "torn payload: {e:?}");
        assert_eq!(e.t_us, e.a as f64, "torn payload: {e:?}");
    }
    // conservation: everything offered is either published or counted
    assert_eq!(
        got.len() as u64 + ring.dropped(),
        WRITERS * PER_WRITER,
        "events neither published nor counted as dropped"
    );
    // tickets are unique (each snapshot slot holds a distinct claim)
    let mut tickets: Vec<u64> = got.iter().map(|&(t, _)| t).collect();
    tickets.dedup();
    assert_eq!(tickets.len(), got.len());
}

/// Events interleaved across threads/tracks come back with each track's
/// own ordering preserved, and the exporter keeps every (pid, tid) series
/// monotone in the emitted JSON.
#[test]
fn export_preserves_per_track_ordering() {
    let sink = ObsSink::enabled(1 << 12);
    let handles: Vec<_> = (0..4u32)
        .map(|w| {
            let sink = sink.clone();
            thread::spawn(move || {
                for i in 0..200u64 {
                    // per-track timestamps strictly increase; tracks overlap
                    sink.emit(Event::instant(
                        EventKind::Reply,
                        worker_track(w as usize),
                        i as f64,
                        i,
                        0,
                    ));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(sink.dropped_events(), 0);

    // source-level check: within one track, ticket order == time order
    let events = sink.events();
    for w in 0..4u32 {
        let times: Vec<f64> = events
            .iter()
            .filter(|(_, e)| e.track == worker_track(w as usize))
            .map(|(_, e)| e.t_us)
            .collect();
        assert_eq!(times.len(), 200);
        assert!(times.windows(2).all(|p| p[0] < p[1]), "track {w} out of order");
    }

    // exporter-level check: the JSON round-trips and every tid's ts series
    // is monotone non-decreasing
    let doc = chrome_trace(&events, sink.dropped_events());
    let parsed = Json::parse(&doc.to_pretty()).unwrap();
    let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
    let mut last: std::collections::HashMap<(u64, u64), f64> = std::collections::HashMap::new();
    let mut seen = 0usize;
    for e in &evs {
        if e.get("ph").unwrap().as_str() == Some("M") {
            continue; // metadata carries no ts
        }
        let pid = e.get("pid").unwrap().as_f64().unwrap() as u64;
        let tid = e.get("tid").unwrap().as_f64().unwrap() as u64;
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        let prev = last.insert((pid, tid), ts);
        assert!(prev.map_or(true, |p| p <= ts), "tid {tid} went backwards");
        seen += 1;
    }
    assert_eq!(seen, 800);
}

/// Property test: on seeded heavy-tailed samples, every histogram
/// quantile lands within the bucket scheme's advertised relative error of
/// the exact (sort-based) quantile, and merge equals recording the
/// concatenation.
#[test]
fn histogram_quantiles_track_exact_sort_within_error_bound() {
    let mut rng = Pcg64::new(0x0B5_CAFE);
    for round in 0..5u64 {
        let n = 4_000 + 1_500 * round as usize;
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut exact: Vec<u64> = Vec::with_capacity(n);
        for i in 0..n {
            // heavy-tailed mix: mostly ~µs-scale, occasional large outliers
            let v = if rng.bernoulli(0.02) {
                rng.below(5_000_000) + 1
            } else {
                (rng.lognormal(5.0, 1.0).round() as u64).max(1)
            };
            exact.push(v);
            if i % 2 == 0 { a.record_u64(v) } else { b.record_u64(v) };
        }
        exact.sort_unstable();
        a.merge(&b);
        assert_eq!(a.count(), n as u64);

        for &q in &[0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            let truth = exact[rank - 1] as f64;
            let est = a.quantile(q);
            // the estimate sits inside the truth's bucket: its bounds are
            // within one bucket width (≤ truth/32, plus 1 for integer
            // rounding at the low end) of the exact order statistic
            let tol = truth * LogHistogram::relative_error() + 1.0;
            assert!(
                (est - truth).abs() <= tol,
                "round {round} q={q}: est {est} vs exact {truth} (tol {tol})"
            );
        }
        // exact aggregates survive bucketing and merging untouched
        assert_eq!(a.min(), exact[0]);
        assert_eq!(a.max(), *exact.last().unwrap());
        assert_eq!(a.sum(), exact.iter().map(|&v| v as f64).sum::<f64>());
    }
}

/// The disabled sink is inert end-to-end: no ring, no events, and an
/// export of it is just the empty (but well-formed) trace document.
#[test]
fn disabled_sink_exports_an_empty_valid_trace() {
    let sink = ObsSink::disabled();
    sink.emit(Event::instant(EventKind::Admit, worker_track(0), 1.0, 1, 1));
    assert!(!sink.is_enabled());
    assert!(sink.events().is_empty());
    assert_eq!(sink.dropped_events(), 0);
    let doc = chrome_trace(&sink.events(), 0);
    let parsed = Json::parse(&doc.to_pretty()).unwrap();
    let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    // only the process_name metadata record remains
    assert_eq!(evs.len(), 1);
    assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("M"));
}
