//! Detect whether the offline `xla` crate is wired into this checkout.
//!
//! The `pjrt` cargo feature expresses *intent* to run AOT artifacts through
//! PJRT, but the `xla` crate (0.1.6 / xla_extension 0.5.1) is not vendored
//! into this tree — it must be added manually as a path dependency. Gating
//! the real executor on the feature alone would break `--features pjrt`
//! builds everywhere the crate is absent (including the CI build matrix),
//! so the real module additionally requires the `mcaimem_xla` cfg, emitted
//! here only when `MCAIMEM_XLA_DIR` points at the offline crate. Without
//! it, `--features pjrt` compiles the API-identical stub whose constructors
//! explain what is missing.

fn main() {
    println!("cargo::rustc-check-cfg=cfg(mcaimem_xla)");
    println!("cargo::rerun-if-env-changed=MCAIMEM_XLA_DIR");
    if std::env::var_os("MCAIMEM_XLA_DIR").is_some() {
        println!("cargo::rustc-cfg=mcaimem_xla");
    }
}
