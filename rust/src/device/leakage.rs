//! Storage-node leakage model for the modified 2T gain cell — the
//! calibrated core of every retention result in the paper.
//!
//! ## Physics (paper §III-B1)
//!
//! In the MCAIMem cell the storage NMOS's drain/source are tied to VDD, so
//! the node is *pulled up* by gate tunneling from VDD plus the write-PMOS
//! junction/gate leakage. A stored bit-0 (node written to `V0 = 0.18 V`)
//! therefore drifts toward VDD and eventually reads as bit-1 — the only
//! retention failure mode (bit-1 is refilled by the same leakage and never
//! fails: the asymmetry the one-enhancement encoder exploits).
//!
//! Gate tunneling falls exponentially with the oxide voltage, and the oxide
//! voltage here is `VDD − V(node)`, so the pull-up current collapses as the
//! node rises:
//!
//! ```text
//!   I_up(V) = I0(W) · exp(−alpha · (V − V0)) · 2^((T−85°C)/10)
//!   C(W) · dV/dt = I_up(V)
//!   ⇒ exp(alpha·V(t)) = exp(alpha·V0) + K(W,T) · t           (closed form)
//!   ⇒ t_cross(V_REF) = (exp(alpha·V_REF) − exp(alpha·V0)) / K(W,T)
//! ```
//!
//! ## Calibration anchors (DESIGN.md §4)
//!
//! * `alpha` is solved so `t_cross(0.8 V) / t_cross(0.5 V) = 12.57 / 1.3`
//!   (paper Fig. 12b's two 1 %-flip points).
//! * `K` is scaled so the 1 % flip quantile at V_REF = 0.8 V, 85 °C, on the
//!   4×-width MCAIMem cell is exactly 12.57 µs.
//! * Per-cell variation is lognormal in the leakage magnitude with
//!   `sigma_ln` solved from the paper's steepness statement (<1 % before
//!   12.57 µs, >25 % past 13 µs): `sigma_ln = ln(13/12.57)/(z₀.₂₅−z₀.₀₁)`.
//! * The width dependence splits `I0` into a fixed part (write-device
//!   junction/gate leakage) and a width-proportional part (storage gate
//!   tunneling) with `I_fixed = 2·I_width` at 1× width, which makes a
//!   4×-width cell exactly 2× slower to charge — the paper's Fig. 7b anchor.

use crate::util::stats::{normal_cdf, normal_quantile};
use crate::util::rng::Pcg64;

/// Paper anchor: node voltage right after writing a bit-0 (Fig. 7b).
pub const V0_WRITTEN: f64 = 0.18;
/// Paper anchor: 1 % flip at V_REF = 0.8 V happens at 12.57 µs (85 °C, 4×W).
pub const T_1PCT_VREF08: f64 = 12.57e-6;
/// Paper anchor: 1 % flip at V_REF = 0.5 V happens at 1.3 µs.
pub const T_1PCT_VREF05: f64 = 1.3e-6;
/// Paper anchor: flip probability exceeds 25 % past 13 µs at V_REF = 0.8 V.
pub const T_25PCT_VREF08: f64 = 13.0e-6;
/// The MCAIMem storage device is widened 4× to pitch-match 6T SRAM (§III-B1).
pub const MCAIMEM_WIDTH_MULT: f64 = 4.0;

/// Calibrated storage-node leakage model.
#[derive(Clone, Debug)]
pub struct StorageLeakage {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Gate-tunneling voltage exponent (1/V) — solved at construction.
    pub alpha: f64,
    /// Charging-rate constant at 4× width, 85 °C (units: 1/s in
    /// exp(alpha·V) space).
    pub k_ref: f64,
    /// Lognormal sigma of per-cell leakage variation.
    pub sigma_ln: f64,
    /// Fraction of pull-up leakage that does NOT scale with storage width
    /// (write-device junction/gate component), measured at 1× width.
    pub fixed_frac: f64,
}

impl StorageLeakage {
    /// Build the model calibrated to the paper's anchors for a given VDD
    /// (use 1.0 V for the lp45 card).
    pub fn calibrated(vdd: f64) -> Self {
        let ratio = T_1PCT_VREF08 / T_1PCT_VREF05;
        let alpha = solve_alpha(ratio, V0_WRITTEN, 0.5, 0.8);
        // ln(t25/t01) = (z25 − z01)·sigma with z01 = Φ⁻¹(0.01), z25 = Φ⁻¹(0.25)
        let sigma_ln = (T_25PCT_VREF08 / T_1PCT_VREF08).ln()
            / (normal_quantile(0.25) - normal_quantile(0.01));
        // t_1% = t_nom · exp(z01 · sigma) with z01 = Φ⁻¹(0.01) < 0
        let z01 = normal_quantile(0.01);
        let t_nom_08 = T_1PCT_VREF08 / (z01 * sigma_ln).exp();
        let k_ref = ((alpha * 0.8).exp() - (alpha * V0_WRITTEN).exp()) / t_nom_08;
        StorageLeakage { vdd, alpha, k_ref, sigma_ln, fixed_frac: 2.0 / 3.0 }
    }

    /// Width scaling of the charge time: t ∝ C(W)/I0(W) with
    /// C ∝ W, I0 = I_fix + I_w·W and I_fix = 2·I_w at W = 1.
    /// Normalized so `width_time_factor(4) / width_time_factor(1) = 2`.
    pub fn width_time_factor(&self, width_mult: f64) -> f64 {
        assert!(width_mult > 0.0);
        // g(W) = W·(a+b)/(a+b·W), a = fixed, b = 1-fixed at W=1.
        let a = self.fixed_frac;
        let b = 1.0 - self.fixed_frac;
        width_mult * (a + b) / (a + b * width_mult)
    }

    /// Charging-rate constant for a given width multiple and temperature.
    fn k(&self, width_mult: f64, temp_c: f64) -> f64 {
        // k_ref is calibrated at the 4×-width MCAIMem cell and 85 °C.
        let width_rel = self.width_time_factor(MCAIMEM_WIDTH_MULT) / self.width_time_factor(width_mult);
        self.k_ref * width_rel * 2f64.powf((temp_c - 85.0) / 10.0)
    }

    /// Nominal (median-cell) time for a written bit-0 to charge up to
    /// voltage `v` (seconds).
    pub fn charge_time(&self, v: f64, width_mult: f64, temp_c: f64) -> f64 {
        assert!(v > V0_WRITTEN && v < self.vdd + 1e-9, "target voltage {v} out of range");
        ((self.alpha * v).exp() - (self.alpha * V0_WRITTEN).exp()) / self.k(width_mult, temp_c)
    }

    /// Node voltage at time `t` for a cell whose leakage is `leak_mult`
    /// times the median (closed-form integration of the ODE).
    pub fn voltage_at(&self, t: f64, width_mult: f64, temp_c: f64, leak_mult: f64) -> f64 {
        let k = self.k(width_mult, temp_c) * leak_mult;
        let e = (self.alpha * V0_WRITTEN).exp() + k * t;
        (e.ln() / self.alpha).min(self.vdd)
    }

    /// Closed-form 0→1 flip probability at access time `t` against a sense
    /// reference `vref` (paper Fig. 12 model): the cell flips if its sampled
    /// leakage multiple pushed the node above `vref` by time `t`.
    pub fn flip_prob(&self, t: f64, vref: f64, width_mult: f64, temp_c: f64) -> f64 {
        if t <= 0.0 {
            return 0.0;
        }
        let t_nom = self.charge_time(vref, width_mult, temp_c);
        // flip iff leak_mult > t_nom/t  ⇔  ln(mult) > ln(t_nom/t);
        // ln(mult) ~ N(0, sigma_ln)
        normal_cdf((t / t_nom).ln() / self.sigma_ln)
    }

    /// Sample one cell's flip time (time at which its node crosses `vref`).
    pub fn sample_flip_time(
        &self,
        rng: &mut Pcg64,
        vref: f64,
        width_mult: f64,
        temp_c: f64,
    ) -> f64 {
        let mult = rng.lognormal(0.0, self.sigma_ln);
        self.charge_time(vref, width_mult, temp_c) / mult
    }

    /// Sample a cell's leakage multiple (shared by all VREFs for that cell).
    pub fn sample_leak_mult(&self, rng: &mut Pcg64) -> f64 {
        rng.lognormal(0.0, self.sigma_ln)
    }

    /// Refresh period that bounds the flip probability to `max_flip`
    /// (the paper uses 1 %, §IV-B) at temperature `temp_c`.
    pub fn refresh_period(&self, vref: f64, max_flip: f64, width_mult: f64, temp_c: f64) -> f64 {
        let t_nom = self.charge_time(vref, width_mult, temp_c);
        t_nom * (normal_quantile(max_flip) * self.sigma_ln).exp()
    }
}

/// Solve the gate-tunneling exponent alpha from the anchor ratio
/// r = (e^{a·v_hi} − e^{a·v0}) / (e^{a·v_lo} − e^{a·v0}) by bisection.
fn solve_alpha(ratio: f64, v0: f64, v_lo: f64, v_hi: f64) -> f64 {
    let f = |a: f64| -> f64 {
        (((a * v_hi).exp() - (a * v0).exp()) / ((a * v_lo).exp() - (a * v0).exp())) - ratio
    };
    let (mut lo, mut hi) = (0.1, 50.0);
    assert!(f(lo) < 0.0 && f(hi) > 0.0, "alpha bracket invalid");
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if f(mid) < 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> StorageLeakage {
        StorageLeakage::calibrated(1.0)
    }

    #[test]
    fn anchor_1pct_at_vref08_is_12_57us() {
        let m = model();
        let p = m.flip_prob(12.57e-6, 0.8, MCAIMEM_WIDTH_MULT, 85.0);
        assert!((p - 0.01).abs() < 5e-4, "p={p}");
    }

    #[test]
    fn anchor_1pct_at_vref05_is_1_3us() {
        let m = model();
        let p = m.flip_prob(1.3e-6, 0.5, MCAIMEM_WIDTH_MULT, 85.0);
        assert!((p - 0.01).abs() < 5e-4, "p={p}");
    }

    #[test]
    fn anchor_25pct_past_13us() {
        let m = model();
        let p = m.flip_prob(13.0e-6, 0.8, MCAIMEM_WIDTH_MULT, 85.0);
        assert!(p >= 0.245, "p={p}");
    }

    #[test]
    fn anchor_width_4x_doubles_charge_time() {
        let m = model();
        let t1 = m.charge_time(0.8, 1.0, 85.0);
        let t4 = m.charge_time(0.8, 4.0, 85.0);
        assert!((t4 / t1 - 2.0).abs() < 1e-9, "ratio={}", t4 / t1);
    }

    #[test]
    fn refresh_period_matches_anchor() {
        let m = model();
        let t = m.refresh_period(0.8, 0.01, MCAIMEM_WIDTH_MULT, 85.0);
        assert!((t - 12.57e-6).abs() / 12.57e-6 < 1e-3, "t={t}");
        let t05 = m.refresh_period(0.5, 0.01, MCAIMEM_WIDTH_MULT, 85.0);
        assert!((t05 - 1.3e-6).abs() / 1.3e-6 < 1e-3, "t05={t05}");
    }

    #[test]
    fn vref_08_extends_refresh_nearly_10x() {
        let m = model();
        let lo = m.refresh_period(0.5, 0.01, MCAIMEM_WIDTH_MULT, 85.0);
        let hi = m.refresh_period(0.8, 0.01, MCAIMEM_WIDTH_MULT, 85.0);
        let ext = hi / lo;
        assert!(ext > 9.0 && ext < 10.5, "extension={ext}"); // "nearly 10×"
    }

    #[test]
    fn flip_prob_monotone_in_time_and_vref() {
        let m = model();
        let mut last = 0.0;
        for i in 1..40 {
            let p = m.flip_prob(i as f64 * 0.5e-6, 0.8, 4.0, 85.0);
            assert!(p >= last);
            last = p;
        }
        // higher vref → later flips → lower prob at same t
        let p_lo = m.flip_prob(5e-6, 0.5, 4.0, 85.0);
        let p_hi = m.flip_prob(5e-6, 0.8, 4.0, 85.0);
        assert!(p_lo > p_hi);
    }

    #[test]
    fn colder_retains_longer() {
        let m = model();
        let hot = m.charge_time(0.8, 4.0, 85.0);
        let cold = m.charge_time(0.8, 4.0, 25.0);
        assert!((cold / hot - 64.0).abs() < 1.0); // 2^6 from 60 °C delta
    }

    #[test]
    fn voltage_curve_reaches_targets_at_charge_times() {
        let m = model();
        for vref in [0.5, 0.65, 0.8] {
            let t = m.charge_time(vref, 4.0, 85.0);
            let v = m.voltage_at(t, 4.0, 85.0, 1.0);
            assert!((v - vref).abs() < 1e-9, "vref={vref} v={v}");
        }
    }

    #[test]
    fn voltage_saturates_at_vdd() {
        let m = model();
        let v = m.voltage_at(1.0, 4.0, 85.0, 1.0); // one full second
        assert!((v - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sampled_flip_times_match_closed_form() {
        let m = model();
        let mut rng = Pcg64::new(99);
        let n = 100_000;
        let t_test = 12.57e-6;
        let flips = (0..n)
            .filter(|_| m.sample_flip_time(&mut rng, 0.8, 4.0, 85.0) < t_test)
            .count();
        let emp = flips as f64 / n as f64;
        let model_p = m.flip_prob(t_test, 0.8, 4.0, 85.0);
        assert!((emp - model_p).abs() < 2e-3, "emp={emp} model={model_p}");
    }

    #[test]
    fn alpha_solver_reproduces_ratio() {
        let a = solve_alpha(9.669, 0.18, 0.5, 0.8);
        let r = (((a * 0.8f64).exp() - (a * 0.18f64).exp()))
            / (((a * 0.5f64).exp() - (a * 0.18f64).exp()));
        assert!((r - 9.669).abs() < 1e-6);
        assert!(a > 6.0 && a < 9.0, "alpha={a} should be a few decades/volt");
    }
}
