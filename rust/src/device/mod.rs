//! Analytical device models — the SPICE substitute.
//!
//! The paper characterizes its cells with SPICE on 45 nm (and Table I on
//! 65 nm) low-power CMOS. This repo has no PDK, so [`tech`] provides
//! technology cards, [`transistor`] a compact MOSFET I-V model (square-law +
//! subthreshold, enough for VTC/SNM work), [`leakage`] the storage-node
//! leakage composition that drives eDRAM retention, and [`variation`] the
//! process-variation sampling used by every Monte-Carlo experiment.
//!
//! Calibration: all free constants are pinned to the paper's published
//! anchors (see `DESIGN.md §4`) — e.g. the gate-tunneling exponent `alpha`
//! is solved so the 1 %-flip time ratio between V_REF = 0.8 V and 0.5 V is
//! 12.57 µs / 1.3 µs, and the width-scaled vs fixed leakage split is solved
//! so a 4× storage width doubles the 0.18 V → 0.8 V charge time (paper
//! Fig. 7b).

pub mod leakage;
pub mod tech;
pub mod transistor;
pub mod variation;

pub use leakage::StorageLeakage;
pub use tech::TechNode;
pub use transistor::{Mosfet, MosKind, VthClass};
pub use variation::VariationModel;
