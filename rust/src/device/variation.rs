//! Process-variation sampling for Monte-Carlo experiments.
//!
//! The paper runs SPICE Monte-Carlo at two scales: 1 Mb-macro cell-to-cell
//! retention spreads (Fig. 2), and 1000-sample write-yield analysis at
//! 25 °C (Fig. 9b), plus the 100 000-sample flip-probability model at 85 °C
//! (Fig. 12). This module centralizes how per-instance parameters are drawn:
//! threshold-voltage mismatch is Gaussian (Pelgrom scaling), which makes
//! subthreshold/gate leakage lognormal.

use crate::util::rng::Pcg64;

/// Variation configuration for one device/cell family.
#[derive(Clone, Copy, Debug)]
pub struct VariationModel {
    /// σ of Vth mismatch in volts (per device).
    pub sigma_vth: f64,
    /// σ of ln(leakage multiplier) (per storage node). For the widened
    /// MCAIMem cell this is small (large-area averaging, paper's very steep
    /// Fig. 12b CDF); conventional minimum-size gain cells spread widely
    /// (paper Fig. 2).
    pub sigma_ln_leak: f64,
}

impl VariationModel {
    /// Conventional minimum-size gain cell (Fig. 2 retention spreads).
    pub fn conventional_gain_cell() -> Self {
        VariationModel { sigma_vth: 0.035, sigma_ln_leak: 0.35 }
    }

    /// The 4×-width MCAIMem storage cell: Pelgrom ⇒ σ ∝ 1/√(W·L), and the
    /// paper's Fig. 12b anchors imply σ_ln ≈ 0.020 (solved in
    /// [`super::leakage::StorageLeakage::calibrated`]).
    pub fn mcaimem_cell() -> Self {
        VariationModel { sigma_vth: 0.0175, sigma_ln_leak: 0.0204 }
    }

    /// 6T SRAM transistors at 45 nm (write-yield MC of Fig. 9b).
    pub fn sram_45nm() -> Self {
        VariationModel { sigma_vth: 0.030, sigma_ln_leak: 0.30 }
    }

    /// Draw a Vth offset (V).
    pub fn sample_dvth(&self, rng: &mut Pcg64) -> f64 {
        rng.normal_ms(0.0, self.sigma_vth)
    }

    /// Draw a leakage multiplier (lognormal, median 1).
    pub fn sample_leak_mult(&self, rng: &mut Pcg64) -> f64 {
        rng.lognormal(0.0, self.sigma_ln_leak)
    }

    /// Pelgrom area scaling: mismatch σ shrinks with √(area multiple).
    pub fn scaled_by_area(&self, area_mult: f64) -> VariationModel {
        assert!(area_mult > 0.0);
        VariationModel {
            sigma_vth: self.sigma_vth / area_mult.sqrt(),
            sigma_ln_leak: self.sigma_ln_leak / area_mult.sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leak_mult_median_is_one() {
        let v = VariationModel::conventional_gain_cell();
        let mut rng = Pcg64::new(1);
        let mut xs: Vec<f64> = (0..20_001).map(|_| v.sample_leak_mult(&mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[xs.len() / 2];
        assert!((med - 1.0).abs() < 0.02, "median={med}");
    }

    #[test]
    fn dvth_centred_with_right_spread() {
        let v = VariationModel::sram_45nm();
        let mut rng = Pcg64::new(2);
        let xs: Vec<f64> = (0..50_000).map(|_| v.sample_dvth(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 1e-3);
        assert!((var.sqrt() - 0.030).abs() < 1e-3);
    }

    #[test]
    fn pelgrom_scaling() {
        let v = VariationModel::conventional_gain_cell();
        let wide = v.scaled_by_area(4.0);
        assert!((wide.sigma_vth - v.sigma_vth / 2.0).abs() < 1e-12);
    }

    #[test]
    fn mcaimem_cell_tighter_than_conventional() {
        assert!(
            VariationModel::mcaimem_cell().sigma_ln_leak
                < VariationModel::conventional_gain_cell().sigma_ln_leak / 10.0
        );
    }
}
