//! Technology cards for the two nodes the paper uses.
//!
//! Table I is characterized at 65 nm low-power CMOS; everything else
//! (retention, SNM, Table II, the system results) at 45 nm low-power CMOS.
//! The numbers here are representative LP-process values from the public
//! literature; the retention-critical constants are *calibrated* against the
//! paper's anchors in [`super::leakage`].

/// A CMOS technology card.
#[derive(Clone, Debug, PartialEq)]
pub struct TechNode {
    pub name: &'static str,
    /// Feature size in nm.
    pub feature_nm: f64,
    /// Nominal supply voltage (V).
    pub vdd: f64,
    /// Regular-Vth NMOS / PMOS threshold magnitudes (V).
    pub vth_n: f64,
    pub vth_p: f64,
    /// Low-Vth option (V) — the conventional 2T cell's read device.
    pub vth_low: f64,
    /// Subthreshold slope ideality factor n (S = n·vt·ln10).
    pub subvt_n: f64,
    /// Gate-oxide capacitance per area (F/m²).
    pub cox: f64,
    /// Process transconductance µCox for NMOS (A/V²); PMOS is
    /// `pmos_beta_ratio` weaker.
    pub k_n: f64,
    pub pmos_beta_ratio: f64,
    /// Channel-length modulation λ (1/V).
    pub lambda: f64,
    /// Area of one layout lambda² in m² (for F²-based cell area estimates):
    /// one "F²" = feature² .
    pub f2_area: f64,
}

impl TechNode {
    /// 65 nm low-power CMOS — Table I comparisons [paper §I, ref 9].
    pub fn lp65() -> Self {
        TechNode {
            name: "lp65",
            feature_nm: 65.0,
            vdd: 1.2,
            vth_n: 0.45,
            vth_p: 0.45,
            vth_low: 0.25,
            subvt_n: 1.5,
            cox: 1.1e-2, // ~1.6nm EOT → ~11 fF/µm² = 1.1e-2 F/m²
            k_n: 3.0e-4,
            pmos_beta_ratio: 0.45,
            lambda: 0.10,
            f2_area: 65.0e-9 * 65.0e-9,
        }
    }

    /// 45 nm low-power CMOS — the paper's main evaluation node (§V).
    pub fn lp45() -> Self {
        TechNode {
            name: "lp45",
            feature_nm: 45.0,
            vdd: 1.0,
            vth_n: 0.40,
            vth_p: 0.42,
            vth_low: 0.22,
            subvt_n: 1.45,
            cox: 1.25e-2, // ~1.4nm EOT
            k_n: 3.4e-4,
            pmos_beta_ratio: 0.42,
            lambda: 0.12,
            f2_area: 45.0e-9 * 45.0e-9,
        }
    }

    /// Thermal voltage at temperature (°C).
    pub fn vt(&self, temp_c: f64) -> f64 {
        crate::util::units::thermal_voltage(temp_c)
    }

    /// Leakage temperature scaling relative to the paper's 85 °C Monte-Carlo
    /// condition: leakage roughly doubles every 10 °C.
    pub fn leak_temp_factor(&self, temp_c: f64) -> f64 {
        2f64.powf((temp_c - 85.0) / 10.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cards_are_distinct_nodes() {
        let a = TechNode::lp65();
        let b = TechNode::lp45();
        assert!(a.feature_nm > b.feature_nm);
        assert!(a.vdd > b.vdd);
        assert_ne!(a, b);
    }

    #[test]
    fn vth_ordering() {
        for t in [TechNode::lp65(), TechNode::lp45()] {
            assert!(t.vth_low < t.vth_n, "{}: LVT must be below RVT", t.name);
            assert!(t.vth_n < t.vdd / 2.0, "{}: RVT below VDD/2", t.name);
        }
    }

    #[test]
    fn leak_temp_factor_anchored_at_85c() {
        let t = TechNode::lp45();
        assert!((t.leak_temp_factor(85.0) - 1.0).abs() < 1e-12);
        assert!((t.leak_temp_factor(95.0) - 2.0).abs() < 1e-12);
        assert!((t.leak_temp_factor(25.0) - 2f64.powf(-6.0)).abs() < 1e-9);
    }

    #[test]
    fn f2_area_is_feature_squared() {
        let t = TechNode::lp45();
        assert!((t.f2_area - 2.025e-15).abs() < 1e-18);
    }
}
