//! Compact MOSFET model: square-law strong inversion + exponential
//! subthreshold, with channel-length modulation.
//!
//! Accuracy target: the *relative* device behaviours the paper's circuit
//! results rest on — VTC shapes for the butterfly/SNM analysis (Fig. 9),
//! access-vs-latch strength ratios, and subthreshold leakage orders of
//! magnitude. This is the level of fidelity a hand analysis or a
//! lecture-grade simulator provides; absolute currents are not silicon.

use super::tech::TechNode;

/// Device polarity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MosKind {
    Nmos,
    Pmos,
}

/// Threshold-voltage flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VthClass {
    Regular,
    Low,
    /// Regular Vth shifted by an externally applied bias trick (e.g. the
    /// paper's VDD+0.4 V gate bias on the 2T write PMOS, §III-B2).
    Shifted(i32), // shift in mV, positive = stronger off
}

/// A sized MOSFET instance.
#[derive(Clone, Debug)]
pub struct Mosfet {
    pub kind: MosKind,
    pub vth_class: VthClass,
    /// Drawn width/length in multiples of the feature size.
    pub w_f: f64,
    pub l_f: f64,
}

impl Mosfet {
    pub fn nmos(w_f: f64, l_f: f64) -> Self {
        Mosfet { kind: MosKind::Nmos, vth_class: VthClass::Regular, w_f, l_f }
    }

    pub fn pmos(w_f: f64, l_f: f64) -> Self {
        Mosfet { kind: MosKind::Pmos, vth_class: VthClass::Regular, w_f, l_f }
    }

    pub fn low_vth(mut self) -> Self {
        self.vth_class = VthClass::Low;
        self
    }

    /// Threshold magnitude (V) for this device on `tech`, with an optional
    /// extra shift `dvth` from variation sampling.
    pub fn vth(&self, tech: &TechNode, dvth: f64) -> f64 {
        let base = match (self.kind, self.vth_class) {
            (_, VthClass::Low) => tech.vth_low,
            (MosKind::Nmos, VthClass::Regular) => tech.vth_n,
            (MosKind::Pmos, VthClass::Regular) => tech.vth_p,
            (MosKind::Nmos, VthClass::Shifted(mv)) => tech.vth_n + mv as f64 * 1e-3,
            (MosKind::Pmos, VthClass::Shifted(mv)) => tech.vth_p + mv as f64 * 1e-3,
        };
        base + dvth
    }

    /// Transconductance factor β = k' · W/L (A/V²).
    pub fn beta(&self, tech: &TechNode) -> f64 {
        let kp = match self.kind {
            MosKind::Nmos => tech.k_n,
            MosKind::Pmos => tech.k_n * tech.pmos_beta_ratio,
        };
        kp * self.w_f / self.l_f
    }

    /// Drain current magnitude (A) in terms of *overdrive-referenced*
    /// voltages: `vgs`, `vds` are magnitudes w.r.t. the source of this
    /// device (positive numbers for a conducting configuration).
    ///
    /// Regions: subthreshold (exponential, with DIBL-free simple model),
    /// triode, saturation with λ.
    pub fn ids(&self, tech: &TechNode, vgs: f64, vds: f64, temp_c: f64, dvth: f64) -> f64 {
        if vds <= 0.0 {
            return 0.0;
        }
        let vth = self.vth(tech, dvth);
        let vt = tech.vt(temp_c);
        let vov = vgs - vth;
        let beta = self.beta(tech);
        if vov <= 0.0 {
            // Subthreshold: I = β·(n-1)·vt²·exp(vov/(n·vt))·(1-exp(-vds/vt))
            let n = tech.subvt_n;
            beta * (n - 1.0) * vt * vt * (vov / (n * vt)).exp() * (1.0 - (-vds / vt).exp())
        } else if vds < vov {
            // Triode
            beta * (vov * vds - 0.5 * vds * vds)
        } else {
            // Saturation
            0.5 * beta * vov * vov * (1.0 + tech.lambda * (vds - vov))
        }
    }

    /// Gate capacitance (F): Cox·W·L.
    pub fn cgate(&self, tech: &TechNode) -> f64 {
        let f = tech.feature_nm * 1e-9;
        tech.cox * (self.w_f * f) * (self.l_f * f)
    }

    /// Off-state subthreshold leakage at Vgs = 0, Vds = `vds` (A).
    pub fn ioff(&self, tech: &TechNode, vds: f64, temp_c: f64, dvth: f64) -> f64 {
        self.ids(tech, 0.0, vds, temp_c, dvth) * tech.leak_temp_factor(temp_c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> TechNode {
        TechNode::lp45()
    }

    #[test]
    fn regions_are_continuous_at_boundaries() {
        let m = Mosfet::nmos(2.0, 1.0);
        let tech = t();
        // triode/saturation boundary at vds = vov
        let vgs = 0.8;
        let vov = vgs - m.vth(&tech, 0.0);
        let below = m.ids(&tech, vgs, vov - 1e-9, 25.0, 0.0);
        let above = m.ids(&tech, vgs, vov + 1e-9, 25.0, 0.0);
        assert!((below - above).abs() / above < 1e-3);
    }

    #[test]
    fn saturation_current_grows_with_overdrive() {
        let m = Mosfet::nmos(2.0, 1.0);
        let tech = t();
        let i1 = m.ids(&tech, 0.6, 1.0, 25.0, 0.0);
        let i2 = m.ids(&tech, 0.9, 1.0, 25.0, 0.0);
        assert!(i2 > i1 * 2.0);
    }

    #[test]
    fn subthreshold_is_exponential_in_vgs() {
        let m = Mosfet::nmos(2.0, 1.0);
        let tech = t();
        let vt = tech.vt(25.0);
        let n = tech.subvt_n;
        let i1 = m.ids(&tech, 0.1, 1.0, 25.0, 0.0);
        let i2 = m.ids(&tech, 0.2, 1.0, 25.0, 0.0);
        let expected_ratio = (0.1 / (n * vt)).exp();
        assert!((i2 / i1 - expected_ratio).abs() / expected_ratio < 1e-6);
    }

    #[test]
    fn pmos_weaker_than_nmos_at_same_size() {
        let n = Mosfet::nmos(2.0, 1.0);
        let p = Mosfet::pmos(2.0, 1.0);
        let tech = t();
        assert!(p.beta(&tech) < n.beta(&tech));
    }

    #[test]
    fn low_vth_leaks_more() {
        let tech = t();
        let rvt = Mosfet::nmos(1.0, 1.0);
        let lvt = Mosfet::nmos(1.0, 1.0).low_vth();
        assert!(lvt.ioff(&tech, 1.0, 25.0, 0.0) > 100.0 * rvt.ioff(&tech, 1.0, 25.0, 0.0));
    }

    #[test]
    fn hot_leaks_more_than_cold() {
        let tech = t();
        let m = Mosfet::nmos(1.0, 1.0);
        let cold = m.ioff(&tech, 1.0, 25.0, 0.0);
        let hot = m.ioff(&tech, 1.0, 85.0, 0.0);
        assert!(hot > 10.0 * cold);
    }

    #[test]
    fn vth_shift_reduces_leakage() {
        let tech = t();
        let mut m = Mosfet::pmos(1.0, 1.0);
        let base = m.ioff(&tech, 1.0, 85.0, 0.0);
        // The paper's +0.4 V gate bias on the 2T write PMOS (§III-B2)
        m.vth_class = VthClass::Shifted(400);
        let biased = m.ioff(&tech, 1.0, 85.0, 0.0);
        assert!(biased < base * 1e-3);
    }

    #[test]
    fn gate_cap_scales_with_width() {
        let tech = t();
        let c1 = Mosfet::nmos(1.0, 1.0).cgate(&tech);
        let c4 = Mosfet::nmos(4.0, 1.0).cgate(&tech);
        assert!((c4 / c1 - 4.0).abs() < 1e-12);
    }

    #[test]
    fn zero_vds_means_zero_current() {
        let tech = t();
        let m = Mosfet::nmos(1.0, 1.0);
        assert_eq!(m.ids(&tech, 1.0, 0.0, 25.0, 0.0), 0.0);
    }
}
