//! Retention-error injection for DNN tensors (paper §IV-A).
//!
//! Bridges the physical flip model to tensor-level experiments: given a flip
//! probability `p` (swept 1 %–25 % in Fig. 11), corrupt int8 data the way
//! the mixed array would — **only 0→1 flips, only on the 7 eDRAM-mapped
//! bits, never on the SRAM-protected sign bit** — in two modes:
//!
//! * *without* one-enhancement: flips hit the raw stored image;
//! * *with* one-enhancement: data is encoded, flipped, then decoded —
//!   reproducing the paper's "errors are injected into bit-0 post-encoder,
//!   pre-decoder" methodology.
//!
//! The same kernel exists at L1 as a Pallas kernel
//! (`python/compile/kernels/inject.py`); `rust/tests/` cross-checks the two
//! through the AOT artifacts.

use crate::encode::one_enhancement::{decode_byte, encode_byte};
use crate::util::rng::Pcg64;

/// Injection mode (Fig. 11's two curves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    WithOneEnhancement,
    WithoutOneEnhancement,
}

/// Apply a pre-drawn per-bit flip mask to one stored byte — the pure
/// memory-path transform `aged = stored | (mask & !stored & 0x7f)`, exactly
/// the Pallas `_inject_kernel` in `python/compile/kernels/inject.py`. Only
/// 0→1, only the 7 eDRAM bits; the sign plane and every stored 1 absorb
/// mask hits. [`flip_zeros_byte`] is the probabilistic form (it draws the
/// mask bit-by-bit); this deterministic form is what the Pallas↔Rust
/// fixture cross-check in `tests/inject_fixtures.rs` pins.
#[inline]
pub fn apply_flip_mask(stored: u8, mask: u8) -> u8 {
    stored | (mask & !stored & 0x7f)
}

/// Corrupt a tensor with pre-drawn per-byte flip masks (7 low bits each) —
/// the deterministic twin of [`inject`], mirroring the Pallas kernels:
/// `Mode::WithoutOneEnhancement` is `inject_raw`, `Mode::WithOneEnhancement`
/// is `mcaimem_store` (encode → age in the array → decode).
pub fn inject_with_mask(data: &mut [i8], masks: &[i8], mode: Mode) {
    assert_eq!(data.len(), masks.len(), "one mask byte per data byte");
    for (v, &m) in data.iter_mut().zip(masks) {
        let stored = match mode {
            Mode::WithoutOneEnhancement => *v as u8,
            Mode::WithOneEnhancement => encode_byte(*v as u8),
        };
        let aged = apply_flip_mask(stored, m as u8);
        *v = match mode {
            Mode::WithoutOneEnhancement => aged as i8,
            Mode::WithOneEnhancement => decode_byte(aged) as i8,
        };
    }
}

/// Flip each stored 0-bit among the 7 eDRAM bits to 1 with probability `p`.
#[inline]
pub fn flip_zeros_byte(stored: u8, p: f64, rng: &mut Pcg64) -> u8 {
    let mut b = stored;
    let mut zeros = !b & 0x7f;
    while zeros != 0 {
        let bit = zeros & zeros.wrapping_neg(); // lowest set zero-position
        if rng.bernoulli(p) {
            b |= bit;
        }
        zeros ^= bit;
    }
    b
}

/// Corrupt a tensor in place according to the retention model.
///
/// Implementation: geometric-jump sampling over the flat bit-position
/// space (`len × 7` candidate positions). A Bernoulli(p) process's gaps
/// between hits are Geometric(p), so we draw `skip = ⌊ln U / ln(1−p)⌋`
/// per hit and touch only O(p·n) positions — exact, and ~100× faster than
/// per-bit draws at the paper's 1 % operating point. Hits that land on a
/// stored 1 are absorbed (bit-1 never flips), exactly as in the per-bit
/// formulation. §Perf (EXPERIMENTS.md) records the before/after.
pub fn inject(data: &mut [i8], p: f64, mode: Mode, rng: &mut Pcg64) {
    if p <= 0.0 || data.is_empty() {
        return;
    }
    if p >= 1.0 {
        for v in data.iter_mut() {
            let b = match mode {
                Mode::WithoutOneEnhancement => *v as u8,
                Mode::WithOneEnhancement => encode_byte(*v as u8),
            };
            let aged = b | 0x7f;
            *v = match mode {
                Mode::WithoutOneEnhancement => aged as i8,
                Mode::WithOneEnhancement => decode_byte(aged) as i8,
            };
        }
        return;
    }
    let total_bits = data.len() as u64 * 7;
    let ln_q = (1.0 - p).ln();
    let mut pos: u64 = 0;
    loop {
        // gap to the next Bernoulli hit (geometric, support ≥ 0)
        let skip = (rng.f64_open().ln() / ln_q) as u64;
        pos = match pos.checked_add(skip) {
            Some(v) => v,
            None => break,
        };
        if pos >= total_bits {
            break;
        }
        let byte = (pos / 7) as usize;
        let bit = (pos % 7) as u8;
        let stored = match mode {
            Mode::WithoutOneEnhancement => data[byte] as u8,
            Mode::WithOneEnhancement => encode_byte(data[byte] as u8),
        };
        let aged = stored | (1 << bit); // 0→1 only; a stored 1 absorbs the hit
        data[byte] = match mode {
            Mode::WithoutOneEnhancement => aged as i8,
            Mode::WithOneEnhancement => decode_byte(aged) as i8,
        };
        pos += 1;
    }
}

/// Expected absolute perturbation of a single near-zero value under each
/// mode — the analytical intuition behind Fig. 11: without the encoder a
/// small positive value has 1-bits injected into high positions (huge error);
/// with it, the already-one MSBs can't flip and damage is confined to LSBs.
pub fn expected_abs_error(value: i8, p: f64, mode: Mode, trials: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed);
    let mut total = 0.0;
    for _ in 0..trials {
        let mut v = [value];
        inject(&mut v, p, mode, &mut rng);
        total += (v[0] as i16 - value as i16).abs() as f64;
    }
    total / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_probability_is_identity() {
        let mut rng = Pcg64::new(1);
        let data: Vec<i8> = (-64..64).collect();
        for mode in [Mode::WithOneEnhancement, Mode::WithoutOneEnhancement] {
            let mut d = data.clone();
            inject(&mut d, 0.0, mode, &mut rng);
            assert_eq!(d, data);
        }
    }

    #[test]
    fn sign_bit_never_flips() {
        let mut rng = Pcg64::new(2);
        let mut data: Vec<i8> = (0..1000).map(|i| (i % 256) as u8 as i8).collect();
        let signs: Vec<bool> = data.iter().map(|&v| v < 0).collect();
        inject(&mut data, 1.0, Mode::WithoutOneEnhancement, &mut rng);
        let after: Vec<bool> = data.iter().map(|&v| v < 0).collect();
        assert_eq!(signs, after);
    }

    #[test]
    fn p_one_saturates_all_zero_bits() {
        let mut rng = Pcg64::new(3);
        let mut data = vec![0i8; 16];
        inject(&mut data, 1.0, Mode::WithoutOneEnhancement, &mut rng);
        assert!(data.iter().all(|&v| v == 0x7f));
        // with one-enhancement, 0 encodes to 0x7f (no zero bits) → unharmed
        let mut data2 = vec![0i8; 16];
        inject(&mut data2, 1.0, Mode::WithOneEnhancement, &mut rng);
        assert!(data2.iter().all(|&v| v == 0));
    }

    #[test]
    fn flip_rate_matches_p() {
        let mut rng = Pcg64::new(4);
        let n = 100_000;
        let mut data = vec![0i8; n];
        inject(&mut data, 0.1, Mode::WithoutOneEnhancement, &mut rng);
        let flipped: u32 = data.iter().map(|&v| (v as u8).count_ones()).sum();
        let rate = flipped as f64 / (7 * n) as f64;
        assert!((rate - 0.1).abs() < 0.005, "rate={rate}");
    }

    #[test]
    fn encoder_shrinks_error_for_near_zero_positives() {
        // Fig. 11's mechanism, quantified per value: small positives are
        // 0-dominant raw (MSB flips are catastrophic) but 1-dominant encoded
        for v in [0i8, 1, 2, 5, 9] {
            let without = expected_abs_error(v, 0.05, Mode::WithoutOneEnhancement, 4000, 7);
            let with = expected_abs_error(v, 0.05, Mode::WithOneEnhancement, 4000, 7);
            assert!(
                with < without * 0.35,
                "v={v}: with={with} without={without}"
            );
        }
    }

    #[test]
    fn negatives_already_one_dominant_encoder_neutral() {
        // two's-complement negatives near zero are natively 1-dominant; the
        // encoder passes them through, so both modes damage them equally
        for v in [-3i8, -7] {
            let without = expected_abs_error(v, 0.05, Mode::WithoutOneEnhancement, 4000, 7);
            let with = expected_abs_error(v, 0.05, Mode::WithOneEnhancement, 4000, 7);
            assert!((with - without).abs() < 1e-9, "v={v}");
        }
    }

    #[test]
    fn errors_are_monotone_in_p() {
        let e1 = expected_abs_error(3, 0.01, Mode::WithoutOneEnhancement, 8000, 9);
        let e2 = expected_abs_error(3, 0.10, Mode::WithoutOneEnhancement, 8000, 9);
        let e3 = expected_abs_error(3, 0.25, Mode::WithoutOneEnhancement, 8000, 9);
        assert!(e1 < e2 && e2 < e3, "{e1} {e2} {e3}");
    }

    #[test]
    fn apply_flip_mask_matches_the_kernel_algebra() {
        for b in 0..=255u8 {
            for m in [0x00u8, 0x7f, 0x55, 0x2a, 0x13] {
                let after = apply_flip_mask(b, m);
                assert_eq!(after & b, b, "bits may only be added");
                assert_eq!(after & 0x80, b & 0x80, "sign plane untouched");
                // hits on stored 1s are absorbed; hits on stored 0s land
                assert_eq!(after, b | (m & !b & 0x7f));
            }
        }
    }

    #[test]
    fn flip_zeros_byte_saturates_to_the_full_mask() {
        // p = 1 must equal the deterministic transform with an all-ones
        // mask — the bridge between the probabilistic and masked forms
        let mut rng = Pcg64::new(21);
        for b in 0..=255u8 {
            assert_eq!(flip_zeros_byte(b, 1.0, &mut rng), apply_flip_mask(b, 0x7f));
        }
    }

    #[test]
    fn inject_with_mask_modes_compose_like_the_pallas_kernels() {
        let data: Vec<i8> = (0..=255u8).map(|b| b as i8).collect();
        let masks = vec![0x29i8; 256];
        let mut raw = data.clone();
        inject_with_mask(&mut raw, &masks, Mode::WithoutOneEnhancement);
        for (&before, &after) in data.iter().zip(&raw) {
            assert_eq!(after as u8, apply_flip_mask(before as u8, 0x29));
        }
        let mut enc = data.clone();
        inject_with_mask(&mut enc, &masks, Mode::WithOneEnhancement);
        for (&before, &after) in data.iter().zip(&enc) {
            let e = encode_byte(before as u8);
            assert_eq!(after as u8, decode_byte(apply_flip_mask(e, 0x29)));
        }
    }

    #[test]
    fn flip_zeros_byte_only_adds_bits() {
        let mut rng = Pcg64::new(11);
        for b in 0..=255u8 {
            let after = flip_zeros_byte(b, 0.5, &mut rng);
            assert_eq!(after & b, b, "bits may only be added");
            assert_eq!(after & 0x80, b & 0x80, "sign plane untouched");
        }
    }
}
