//! Deterministic fault injection across the memory and serving tiers
//! (PR 6, §Faults).
//!
//! The paper trades SRAM's "never decays" for area; this module makes the
//! failure side of that trade a first-class, *seeded* input instead of an
//! assumption. A [`FaultPlan`] is a parseable schedule of fault clauses; a
//! [`FaultyBackend`] wraps any [`MemoryBackend`] — flat, sharded, tracing —
//! and applies the plan's memory-tier transforms outside the array, so the
//! production path and the golden model ([`crate::sim::oracle`]) can be
//! wrapped in the *same* plan and stay bit- and meter-exact under faults:
//! agreement is structural, not coincidental.
//!
//! Fault classes (grammar in [`FaultPlan::GRAMMAR`]):
//!
//! * `retention-tail@RATE` — a weak-cell tail population beyond the
//!   calibrated flip model: each stored payload byte takes a seeded 0→1
//!   flip mask over the 7 eDRAM-mapped bits at per-bit probability `RATE`
//!   (the [`crate::inject::apply_flip_mask`] algebra — the SRAM/sign plane
//!   is immune).
//! * `stuck-at[@DENSITY]` — a manufacturing stuck-at-1 cell map drawn once
//!   from the plan seed and the array capacity: the affected byte reads
//!   and writes with that bit forced, idempotently.
//! * `vref-drift@P` — CVSA mis-sense under reference drift: each loaded
//!   eDRAM bit independently reads 1→0 with probability `P`.
//! * `refresh-stall@K` — every K-th manager-driven refresh slot is dropped
//!   (a stalled refresh engine), so rows age past their guarantee.
//! * `shard-outage@T[/S]` — shard `S` (default 0) dies at device time `T`:
//!   the wrapper calls [`MemoryBackend::quarantine_shard`] on the first op
//!   at or after `T` (a no-op on backends without failover provisioning).
//! * `engine-timeout@K` / `engine-crash@K` — serving-tier faults consumed
//!   by [`FaultyEngine`]: every K-th batch errors transiently, or the K-th
//!   batch kills its worker fatally (the pool must degrade, not drop
//!   replies).
//!
//! Determinism: the wrapper owns one [`Pcg64`] stream seeded from the plan;
//! every probabilistic draw is made *unconditionally* per candidate bit, so
//! the stream position depends only on the op sequence (addresses and
//! lengths), never on data values — record, replay and the differential
//! oracle all see identical masks.

use anyhow::{anyhow, bail, Result};

use crate::inject::apply_flip_mask;
use crate::mem::backend::{BackendSpec, MemoryBackend};
use crate::mem::energy::EnergyCard;
use crate::mem::mcaimem::EnergyMeter;
use crate::util::rng::Pcg64;

/// Marker carried by an injected *fatal* engine crash: the worker loop
/// treats an inference error containing this marker as unrecoverable for
/// that worker (it replies errors to its batch, then exits), while plain
/// errors — including injected timeouts — are transient.
pub const FATAL_MARKER: &str = "fatal injected engine crash";

/// Default plan seed (`seed=N` overrides).
pub const DEFAULT_PLAN_SEED: u64 = 0xFA_0175;

/// Default stuck-cell density for a bare `stuck-at` clause: one affected
/// byte per 4096 (a realistic shipped-part defect tail).
pub const DEFAULT_STUCK_DENSITY: f64 = 1.0 / 4096.0;

/// A seeded, reproducible fault schedule — the one parseable fault type
/// the CLI, the trace header and the chaos campaigns all share.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the wrapper's draw stream and the stuck-cell map.
    pub seed: u64,
    /// Per-bit 0→1 store-path flip probability (7 eDRAM bits).
    pub retention_tail: Option<f64>,
    /// Per-byte probability of carrying one stuck-at-1 eDRAM bit.
    pub stuck_at: Option<f64>,
    /// Per-bit 1→0 load-path mis-sense probability (7 eDRAM bits).
    pub vref_drift: Option<f64>,
    /// Drop every K-th manager-driven refresh slot.
    pub refresh_stall: Option<u64>,
    /// Quarantine shard `.1` at device time `.0` (s).
    pub shard_outage: Option<(f64, usize)>,
    /// Every K-th inference batch fails transiently.
    pub engine_timeout: Option<u64>,
    /// The K-th inference batch kills its worker fatally.
    pub engine_crash: Option<u64>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: DEFAULT_PLAN_SEED,
            retention_tail: None,
            stuck_at: None,
            vref_drift: None,
            refresh_stall: None,
            shard_outage: None,
            engine_timeout: None,
            engine_crash: None,
        }
    }
}

impl FaultPlan {
    pub const GRAMMAR: &'static str = "comma-separated clauses: retention-tail@RATE | \
         stuck-at[@DENSITY] | vref-drift@P | refresh-stall@K | shard-outage@T[/SHARD] | \
         engine-timeout@K | engine-crash@K | seed=N  (rates in 0..=1, K >= 1, T in seconds)";

    /// Does the plan carry any memory-tier clause (one a [`FaultyBackend`]
    /// acts on)?
    pub fn has_memory_faults(&self) -> bool {
        self.retention_tail.is_some()
            || self.stuck_at.is_some()
            || self.vref_drift.is_some()
            || self.refresh_stall.is_some()
            || self.shard_outage.is_some()
    }

    /// Does the plan carry any serving-tier engine clause (one a
    /// [`FaultyEngine`] acts on)?
    pub fn has_engine_faults(&self) -> bool {
        self.engine_timeout.is_some() || self.engine_crash.is_some()
    }
}

impl std::str::FromStr for FaultPlan {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let mut plan = FaultPlan::default();
        let mut any = false;
        let rate = |clause: &str, v: &str| -> Result<f64> {
            let r: f64 = v
                .parse()
                .map_err(|_| anyhow!("bad rate `{v}` in `{clause}` ({})", Self::GRAMMAR))?;
            if !(0.0..=1.0).contains(&r) {
                bail!("rate {r} out of 0..=1 in `{clause}` ({})", Self::GRAMMAR);
            }
            Ok(r)
        };
        let every = |clause: &str, v: &str| -> Result<u64> {
            let k: u64 = v
                .parse()
                .map_err(|_| anyhow!("bad count `{v}` in `{clause}` ({})", Self::GRAMMAR))?;
            if k == 0 {
                bail!("count must be >= 1 in `{clause}` ({})", Self::GRAMMAR);
            }
            Ok(k)
        };
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            any = true;
            let lower = part.to_ascii_lowercase();
            let (key, val) = match lower.split_once('@') {
                Some((k, v)) => (k, Some(v)),
                None => (lower.as_str(), None),
            };
            match (key, val) {
                ("retention-tail", Some(v)) => plan.retention_tail = Some(rate(part, v)?),
                ("stuck-at", None) => plan.stuck_at = Some(DEFAULT_STUCK_DENSITY),
                ("stuck-at", Some(v)) => plan.stuck_at = Some(rate(part, v)?),
                ("vref-drift", Some(v)) => plan.vref_drift = Some(rate(part, v)?),
                ("refresh-stall", Some(v)) => plan.refresh_stall = Some(every(part, v)?),
                ("shard-outage", Some(v)) => {
                    let (t_str, shard) = match v.split_once('/') {
                        Some((t, sh)) => (
                            t,
                            sh.parse::<usize>().map_err(|_| {
                                anyhow!("bad shard `{sh}` in `{part}` ({})", Self::GRAMMAR)
                            })?,
                        ),
                        None => (v, 0),
                    };
                    let t: f64 = t_str.parse().map_err(|_| {
                        anyhow!("bad outage time `{t_str}` in `{part}` ({})", Self::GRAMMAR)
                    })?;
                    if !(t >= 0.0) {
                        bail!("outage time must be >= 0 in `{part}` ({})", Self::GRAMMAR);
                    }
                    plan.shard_outage = Some((t, shard));
                }
                ("engine-timeout", Some(v)) => plan.engine_timeout = Some(every(part, v)?),
                ("engine-crash", Some(v)) => plan.engine_crash = Some(every(part, v)?),
                _ => {
                    if let Some(v) = lower.strip_prefix("seed=") {
                        plan.seed = v
                            .parse()
                            .map_err(|_| anyhow!("bad seed `{v}` ({})", Self::GRAMMAR))?;
                    } else {
                        bail!("unknown fault clause `{part}` ({})", Self::GRAMMAR);
                    }
                }
            }
        }
        if !any {
            bail!("empty fault plan ({})", Self::GRAMMAR);
        }
        Ok(plan)
    }
}

impl std::fmt::Display for FaultPlan {
    /// Canonical clause order (the parse order of the grammar), `seed=N`
    /// last and only when non-default — `parse(display(p)) == p` always,
    /// and `display(parse(s)) == s` for canonical inputs (pinned by the
    /// round-trip test; the trace JSON stores this string).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut parts: Vec<String> = Vec::new();
        if let Some(r) = self.retention_tail {
            parts.push(format!("retention-tail@{r}"));
        }
        if let Some(d) = self.stuck_at {
            parts.push(format!("stuck-at@{d}"));
        }
        if let Some(p) = self.vref_drift {
            parts.push(format!("vref-drift@{p}"));
        }
        if let Some(k) = self.refresh_stall {
            parts.push(format!("refresh-stall@{k}"));
        }
        if let Some((t, s)) = self.shard_outage {
            parts.push(format!("shard-outage@{t}/{s}"));
        }
        if let Some(k) = self.engine_timeout {
            parts.push(format!("engine-timeout@{k}"));
        }
        if let Some(k) = self.engine_crash {
            parts.push(format!("engine-crash@{k}"));
        }
        if self.seed != DEFAULT_PLAN_SEED {
            parts.push(format!("seed={}", self.seed));
        }
        write!(f, "{}", parts.join(","))
    }
}

/// Wrap any backend in a reproducible fault schedule. Implements
/// [`MemoryBackend`] by delegation; the plan's memory-tier transforms sit
/// *outside* the wrapped array, so wrapping the production backend and the
/// golden oracle in the same plan preserves their bit/meter agreement.
pub struct FaultyBackend {
    inner: Box<dyn MemoryBackend>,
    plan: FaultPlan,
    /// The op-stream draw source (store masks, load mis-sense).
    rng: Pcg64,
    /// Per-byte stuck-at-1 masks (empty when the clause is absent).
    stuck: Vec<u8>,
    refresh_calls: u64,
    outage_fired: bool,
    /// Telemetry sink + shard-track base (fault firings land on the track
    /// of the shard they hit).
    obs: crate::obs::ObsSink,
    obs_base: u32,
}

impl FaultyBackend {
    pub fn wrap(inner: Box<dyn MemoryBackend>, plan: &FaultPlan) -> Self {
        // the stuck-cell map is a manufacturing property: drawn once from
        // the plan seed and the capacity, on a stream separate from the
        // per-op draws so op traffic cannot shift it
        let stuck = match plan.stuck_at {
            Some(density) => {
                let mut map_rng = Pcg64::new(plan.seed ^ 0x57C4_A7B1);
                (0..inner.capacity())
                    .map(|_| {
                        // unconditional position draw keeps the stream
                        // capacity-indexed (one pair of draws per byte)
                        let hit = map_rng.bernoulli(density);
                        let bit = map_rng.below(7) as u8;
                        if hit {
                            1u8 << bit
                        } else {
                            0
                        }
                    })
                    .collect()
            }
            None => Vec::new(),
        };
        FaultyBackend {
            rng: Pcg64::new(plan.seed),
            stuck,
            inner,
            plan: plan.clone(),
            refresh_calls: 0,
            outage_fired: false,
            obs: crate::obs::ObsSink::disabled(),
            obs_base: 0,
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Stuck cells in the map (test/report introspection).
    pub fn stuck_cells(&self) -> usize {
        self.stuck.iter().filter(|&&m| m != 0).count()
    }

    fn maybe_outage(&mut self, now: f64) {
        if let Some((t, shard)) = self.plan.shard_outage {
            if !self.outage_fired && now >= t {
                self.outage_fired = true;
                self.obs.emit(crate::obs::Event::instant(
                    crate::obs::EventKind::FaultFired,
                    self.obs_base + shard as u32,
                    now * 1e6,
                    crate::obs::fault_code::SHARD_OUTAGE,
                    shard as u64,
                ));
                self.inner.quarantine_shard(shard, now);
            }
        }
    }

    /// Seeded 7-bit mask at per-bit probability `p` — drawn unconditionally
    /// so the stream position is data-independent.
    #[inline]
    fn draw_mask(&mut self, p: f64) -> u8 {
        let mut mask = 0u8;
        for bit in 0..7 {
            if self.rng.bernoulli(p) {
                mask |= 1 << bit;
            }
        }
        mask
    }
}

impl MemoryBackend for FaultyBackend {
    fn spec(&self) -> BackendSpec {
        self.inner.spec()
    }

    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn now(&self) -> f64 {
        self.inner.now()
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        self.maybe_outage(now);
        if self.plan.retention_tail.is_none() && self.stuck.is_empty() {
            return self.inner.store(addr, data, now);
        }
        let mut d = data.to_vec();
        if let Some(rate) = self.plan.retention_tail {
            for b in d.iter_mut() {
                let mask = self.draw_mask(rate);
                *b = apply_flip_mask(*b, mask);
            }
        }
        if !self.stuck.is_empty() {
            for (i, b) in d.iter_mut().enumerate() {
                *b |= self.stuck[addr + i];
            }
        }
        self.inner.store(addr, &d, now);
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        self.maybe_outage(now);
        let mut out = self.inner.load(addr, len, now);
        if let Some(p) = self.plan.vref_drift {
            for b in out.iter_mut() {
                // CVSA mis-sense: a stored 1 reads as 0 (never the SRAM
                // plane); draws are unconditional per bit position
                let mask = self.draw_mask(p);
                *b &= !(mask & 0x7f) | 0x80;
            }
        }
        if !self.stuck.is_empty() {
            for (i, b) in out.iter_mut().enumerate() {
                *b |= self.stuck[addr + i];
            }
        }
        out
    }

    fn tick(&mut self, now: f64) {
        self.maybe_outage(now);
        self.inner.tick(now);
    }

    fn refresh_due(&self) -> Option<f64> {
        self.inner.refresh_due()
    }

    fn refresh_row(&mut self, row: usize, now: f64) {
        self.maybe_outage(now);
        self.refresh_calls += 1;
        if let Some(k) = self.plan.refresh_stall {
            if self.refresh_calls % k == 0 {
                // stalled slot: the row silently ages on — silent to the
                // manager, visible in the trace
                self.obs.emit(crate::obs::Event::instant(
                    crate::obs::EventKind::FaultFired,
                    self.obs_base,
                    now * 1e6,
                    crate::obs::fault_code::REFRESH_STALL,
                    row as u64,
                ));
                return;
            }
        }
        self.inner.refresh_row(row, now);
    }

    fn rows_per_bank(&self) -> usize {
        self.inner.rows_per_bank()
    }

    fn meter(&self) -> &EnergyMeter {
        self.inner.meter()
    }

    fn shard_meters(&self) -> Vec<EnergyMeter> {
        self.inner.shard_meters()
    }

    fn energy_card(&self) -> &EnergyCard {
        self.inner.energy_card()
    }

    fn area(&self) -> f64 {
        self.inner.area()
    }

    fn quarantine_shard(&mut self, shard: usize, now: f64) -> bool {
        self.inner.quarantine_shard(shard, now)
    }

    fn attach_obs(&mut self, sink: &crate::obs::ObsSink, track_base: u32) {
        self.obs = sink.clone();
        self.obs_base = track_base;
        self.inner.attach_obs(sink, track_base);
    }

    fn label(&self) -> String {
        format!("{} [faults: {}]", self.inner.label(), self.plan)
    }
}

/// Wrap an inference engine in the plan's serving-tier clauses: every
/// `engine-timeout@K`-th batch fails transiently (the pool replies errors
/// and keeps the worker), and the `engine-crash@K`-th batch fails with
/// [`FATAL_MARKER`] (the worker replies errors to its batch and exits; the
/// pool degrades admission to the survivors).
pub struct FaultyEngine {
    inner: Box<dyn crate::coordinator::pool::InferEngine>,
    plan: FaultPlan,
    calls: u64,
}

impl FaultyEngine {
    pub fn wrap(inner: Box<dyn crate::coordinator::pool::InferEngine>, plan: &FaultPlan) -> Self {
        FaultyEngine { inner, plan: plan.clone(), calls: 0 }
    }
}

impl crate::coordinator::pool::InferEngine for FaultyEngine {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn infer(&mut self, x: &[i8]) -> Result<Vec<usize>> {
        self.calls += 1;
        if let Some(k) = self.plan.engine_crash {
            if self.calls == k {
                bail!("{FATAL_MARKER} at batch {k}");
            }
        }
        if let Some(k) = self.plan.engine_timeout {
            if self.calls % k == 0 {
                bail!("injected engine timeout at batch {}", self.calls);
            }
        }
        self.inner.infer(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::backend;

    fn plan(s: &str) -> FaultPlan {
        s.parse().unwrap()
    }

    #[test]
    fn plan_grammar_roundtrips_canonical_forms() {
        for s in [
            "retention-tail@0.01",
            "stuck-at@0.001",
            "vref-drift@0.0005",
            "refresh-stall@7",
            "shard-outage@0.002/1",
            "engine-timeout@5",
            "engine-crash@9",
            "retention-tail@0.01,stuck-at@0.001,vref-drift@0.0005,refresh-stall@7,shard-outage@0.002/1,engine-timeout@5,engine-crash@9,seed=42",
        ] {
            let p = plan(s);
            assert_eq!(p.to_string(), s, "{s}");
            let again: FaultPlan = p.to_string().parse().unwrap();
            assert_eq!(again, p, "{s}");
        }
        // sugar: bare stuck-at takes the default density, bare outage
        // takes shard 0, seed is elided from Display when default
        assert_eq!(plan("stuck-at").stuck_at, Some(DEFAULT_STUCK_DENSITY));
        assert_eq!(plan("shard-outage@0.01").shard_outage, Some((0.01, 0)));
        assert_eq!(plan("refresh-stall@3").seed, DEFAULT_PLAN_SEED);
        assert_eq!(plan("refresh-stall@3").to_string(), "refresh-stall@3");
    }

    #[test]
    fn plan_grammar_rejects_garbage() {
        for s in [
            "",
            " , ,",
            "retention-tail",
            "retention-tail@1.5",
            "vref-drift@-0.1",
            "refresh-stall@0",
            "engine-crash@x",
            "shard-outage@-1",
            "shard-outage@0.1/x",
            "seed=abc",
            "unknown-fault@1",
        ] {
            assert!(s.parse::<FaultPlan>().is_err(), "`{s}` must not parse");
        }
    }

    #[test]
    fn plan_classifies_tiers() {
        assert!(plan("retention-tail@0.01").has_memory_faults());
        assert!(!plan("retention-tail@0.01").has_engine_faults());
        assert!(plan("engine-crash@3").has_engine_faults());
        assert!(!plan("engine-crash@3").has_memory_faults());
        assert!(plan("shard-outage@0.01").has_memory_faults());
    }

    #[test]
    fn wrapping_with_same_plan_is_deterministic() {
        // two independently wrapped SRAM arrays under one plan must agree
        // byte-for-byte: the whole fault layer is a function of (plan, op
        // sequence)
        let p = plan("retention-tail@0.05,stuck-at@0.01,vref-drift@0.03,seed=7");
        let mk = || FaultyBackend::wrap(backend::build(&BackendSpec::Sram, 16 * 1024, 1), &p);
        let (mut a, mut b) = (mk(), mk());
        let data: Vec<u8> = (0..777u32).map(|i| (i * 13) as u8).collect();
        for (i, addr) in [(1u64, 0usize), (2, 131), (3, 64), (4, 1000)].iter().enumerate().map(|(i, &(t, a))| ((i as f64 + 1.0) * 1e-6 * t as f64, a)) {
            a.store(addr, &data, i);
            b.store(addr, &data, i);
            assert_eq!(a.load(addr, data.len(), i + 1e-9), b.load(addr, data.len(), i + 1e-9));
        }
        assert_eq!(a.meter(), b.meter());
    }

    #[test]
    fn retention_tail_spares_the_sign_plane() {
        let p = plan("retention-tail@1,seed=3");
        let mut f = FaultyBackend::wrap(backend::build(&BackendSpec::Sram, 16 * 1024, 1), &p);
        f.store(0, &[0u8; 64], 1e-6);
        let out = f.load(0, 64, 2e-6);
        // rate 1: every eDRAM zero flips; bit 7 never does
        assert!(out.iter().all(|&b| b == 0x7f), "{out:?}");
    }

    #[test]
    fn vref_drift_only_clears_edram_bits() {
        let p = plan("vref-drift@1,seed=3");
        let mut f = FaultyBackend::wrap(backend::build(&BackendSpec::Sram, 16 * 1024, 1), &p);
        f.store(0, &[0xffu8; 64], 1e-6);
        let out = f.load(0, 64, 2e-6);
        assert!(out.iter().all(|&b| b == 0x80), "sign survives mis-sense: {out:?}");
        // the array itself is untouched: a clean wrapper reads it back
        let mut clean = FaultyBackend::wrap(backend::build(&BackendSpec::Sram, 16 * 1024, 1), &plan("refresh-stall@1000"));
        clean.store(0, &[0xffu8; 64], 1e-6);
        assert!(clean.load(0, 64, 2e-6).iter().all(|&b| b == 0xff));
    }

    #[test]
    fn stuck_cells_force_bits_idempotently() {
        let p = plan("stuck-at@0.5,seed=11");
        let mut f = FaultyBackend::wrap(backend::build(&BackendSpec::Sram, 16 * 1024, 1), &p);
        assert!(f.stuck_cells() > 3000, "{}", f.stuck_cells());
        f.store(0, &[0u8; 256], 1e-6);
        let once = f.load(0, 256, 2e-6);
        // store-side and load-side forcing agree: re-reading changes nothing
        let twice = f.load(0, 256, 3e-6);
        assert_eq!(once, twice);
        assert!(once.iter().any(|&b| b != 0), "density 0.5 must hit something");
        assert!(once.iter().all(|&b| b & 0x80 == 0), "stuck map covers eDRAM bits only");
    }

    #[test]
    fn refresh_stall_drops_every_kth_slot() {
        let p = plan("refresh-stall@3");
        let spec = BackendSpec::mcaimem_default();
        let mut f = FaultyBackend::wrap(backend::build(&spec, 16 * 1024, 1), &p);
        for i in 0..9usize {
            f.refresh_row(i % 256, (i + 1) as f64 * 1e-7);
        }
        assert_eq!(f.meter().refreshes, 6, "3 of 9 slots stalled");
    }

    #[test]
    fn faulty_engine_injects_timeouts_and_a_fatal_crash() {
        use crate::coordinator::pool::{InferEngine, SyntheticEngine};
        let inner = Box::new(SyntheticEngine {
            exec_latency: std::time::Duration::ZERO,
            ..Default::default()
        });
        let mut eng = FaultyEngine::wrap(inner, &plan("engine-timeout@3,engine-crash@5"));
        let x = vec![1i8; eng.batch() * eng.dim()];
        let outcomes: Vec<bool> = (0..6).map(|_| eng.infer(&x).is_ok()).collect();
        // calls 3 and 6 time out; call 5 crashes
        assert_eq!(outcomes, vec![true, true, false, true, false, false]);
        let err = {
            let mut eng2 = FaultyEngine::wrap(
                Box::new(SyntheticEngine { exec_latency: std::time::Duration::ZERO, ..Default::default() }),
                &plan("engine-crash@1"),
            );
            eng2.infer(&x).unwrap_err().to_string()
        };
        assert!(err.contains(FATAL_MARKER), "{err}");
    }
}
