//! System-level reports: Figs. 14, 15a, 15b, 16 (§V-B).

use crate::energy::opswatt::opswatt_gain;
use crate::energy::system_eval::{evaluate, MemChoice};
use crate::scalesim::accelerator::AcceleratorConfig;
use crate::scalesim::network::all_networks;
use crate::scalesim::simulate_network;
use crate::util::table::{fnum, Table};

fn uj(j: f64) -> String {
    fnum(j * 1e6, 2)
}

/// Fig. 14 — static energy per network on Eyeriss and TPUv1.
pub fn fig14() -> Vec<Table> {
    AcceleratorConfig::paper_platforms()
        .into_iter()
        .map(|acc| {
            let mut t = Table::new(
                &format!("Fig. 14 — static energy per inference on {} (µJ)", acc.name),
                &["network", "SRAM", "eDRAM(2T)", "MCAIMem", "SRAM/MCAIMem"],
            );
            for net in all_networks() {
                let trace = simulate_network(&net, &acc);
                let s = evaluate(&trace, &acc, &MemChoice::Sram).static_j;
                let e = evaluate(&trace, &acc, &MemChoice::Edram2t).static_j;
                let m = evaluate(&trace, &acc, &MemChoice::Mcaimem { vref: 0.8 }).static_j;
                t.row(vec![
                    net.name.into(),
                    uj(s),
                    uj(e),
                    uj(m),
                    format!("{}x", fnum(s / m, 2)),
                ]);
            }
            t
        })
        .collect()
}

/// Fig. 15a — refresh energy: conventional 2T vs MCAIMem per V_REF.
pub fn fig15a() -> Vec<Table> {
    AcceleratorConfig::paper_platforms()
        .into_iter()
        .map(|acc| {
            let mut t = Table::new(
                &format!("Fig. 15a — refresh energy per inference on {} (µJ)", acc.name),
                &[
                    "network",
                    "eDRAM(2T) C-S/A",
                    "MCAIMem@0.5",
                    "MCAIMem@0.6",
                    "MCAIMem@0.7",
                    "MCAIMem@0.8",
                ],
            );
            for net in all_networks() {
                let trace = simulate_network(&net, &acc);
                let mut row = vec![net.name.to_string()];
                row.push(uj(evaluate(&trace, &acc, &MemChoice::Edram2t).refresh_j));
                for vref in [0.5, 0.6, 0.7, 0.8] {
                    row.push(uj(evaluate(&trace, &acc, &MemChoice::Mcaimem { vref }).refresh_j));
                }
                t.row(row);
            }
            t
        })
        .collect()
}

/// Fig. 15b — total buffer energy: SRAM / RRAM / eDRAM / MCAIMem.
pub fn fig15b() -> Vec<Table> {
    AcceleratorConfig::paper_platforms()
        .into_iter()
        .map(|acc| {
            let mut t = Table::new(
                &format!("Fig. 15b — total buffer energy per inference on {} (µJ)", acc.name),
                &["network", "SRAM", "RRAM", "eDRAM(2T)", "MCAIMem@0.8", "SRAM/MCAIMem"],
            );
            for net in all_networks() {
                let trace = simulate_network(&net, &acc);
                let s = evaluate(&trace, &acc, &MemChoice::Sram).total_j();
                let r = evaluate(&trace, &acc, &MemChoice::Rram).total_j();
                let e = evaluate(&trace, &acc, &MemChoice::Edram2t).total_j();
                let m = evaluate(&trace, &acc, &MemChoice::Mcaimem { vref: 0.8 }).total_j();
                t.row(vec![
                    net.name.into(),
                    uj(s),
                    uj(r),
                    uj(e),
                    uj(m),
                    format!("{}x", fnum(s / m, 2)),
                ]);
            }
            t
        })
        .collect()
}

/// Fig. 16 — normalized ops/W improvement vs the SRAM buffer.
pub fn fig16() -> Vec<Table> {
    let mut t = Table::new(
        "Fig. 16 — ops/W improvement with MCAIMem@0.8 vs SRAM buffer (paper: 35.4%–43.2%)",
        &["network", "Eyeriss", "TPUv1"],
    );
    let platforms = AcceleratorConfig::paper_platforms();
    for net in all_networks() {
        let mut row = vec![net.name.to_string()];
        for acc in &platforms {
            let trace = simulate_network(&net, acc);
            let g = opswatt_gain(&trace, acc, &MemChoice::Mcaimem { vref: 0.8 });
            row.push(format!("{}%", fnum(g * 100.0, 1)));
        }
        t.row(row);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14_covers_all_networks_on_both_platforms() {
        let tables = fig14();
        assert_eq!(tables.len(), 2);
        for t in &tables {
            assert_eq!(t.rows.len(), 7);
        }
    }

    #[test]
    fn fig15a_refresh_monotone_in_vref_every_row() {
        for t in fig15a() {
            for row in &t.rows {
                let vals: Vec<f64> = row[2..6].iter().map(|c| c.parse().unwrap()).collect();
                for w in vals.windows(2) {
                    assert!(w[1] <= w[0] + 1e-9, "{row:?}");
                }
            }
        }
    }

    #[test]
    fn fig16_gains_positive() {
        let t = &fig16()[0];
        for row in &t.rows {
            for cell in &row[1..] {
                let v: f64 = cell.trim_end_matches('%').parse().unwrap();
                assert!(v > 10.0 && v < 60.0, "{row:?}");
            }
        }
    }
}
