//! System-level reports: Figs. 14, 15a, 15b, 16 (§V-B).
//!
//! Every driver iterates a `Vec<BackendSpec>` — the same spec the CLI
//! parses (`--backend sram,edram2t,rram,mcaimem@0.8`) — so a sweep over
//! any backend set (and any number of V_REF points) runs through one code
//! path instead of bespoke match arms per figure.

use crate::energy::opswatt::opswatt_gain;
use crate::energy::system_eval::evaluate;
use crate::mem::backend::BackendSpec;
use crate::scalesim::accelerator::AcceleratorConfig;
use crate::scalesim::network::all_networks;
use crate::scalesim::simulate_network;
use crate::util::table::{fnum, Table};

fn uj(j: f64) -> String {
    fnum(j * 1e6, 2)
}

fn spec(s: &str) -> BackendSpec {
    s.parse().expect("static spec")
}

/// Header columns for a backend sweep plus a baseline/ours ratio column.
fn sweep_header(specs: &[BackendSpec]) -> Vec<String> {
    let mut h = vec!["network".to_string()];
    h.extend(specs.iter().map(BackendSpec::label));
    h.push(format!(
        "{}/{}",
        specs.first().expect("non-empty sweep").label(),
        specs.last().expect("non-empty sweep").label()
    ));
    h
}

/// Fig. 14 — static energy per network on Eyeriss and TPUv1, for any
/// backend sweep (first spec is the baseline of the ratio column, last
/// the proposal).
pub fn fig14_for(specs: &[BackendSpec]) -> Vec<Table> {
    let header = sweep_header(specs);
    AcceleratorConfig::paper_platforms()
        .into_iter()
        .map(|acc| {
            let mut t = Table::new(
                &format!("Fig. 14 — static energy per inference on {} (µJ)", acc.name),
                &header.iter().map(String::as_str).collect::<Vec<_>>(),
            );
            for net in all_networks() {
                let trace = simulate_network(&net, &acc);
                let vals: Vec<f64> =
                    specs.iter().map(|s| evaluate(&trace, &acc, s).static_j).collect();
                let mut row = vec![net.name.to_string()];
                row.extend(vals.iter().map(|&v| uj(v)));
                row.push(format!("{}x", fnum(vals[0] / vals[vals.len() - 1], 2)));
                t.row(row);
            }
            t
        })
        .collect()
}

/// Fig. 14 with the paper's default sweep.
pub fn fig14() -> Vec<Table> {
    fig14_for(&[spec("sram"), spec("edram2t"), spec("mcaimem@0.8")])
}

/// Fig. 15a — refresh energy per backend (the paper sweeps the
/// conventional 2T against MCAIMem at several V_REF points; any spec list
/// works).
pub fn fig15a_for(specs: &[BackendSpec]) -> Vec<Table> {
    let mut header = vec!["network".to_string()];
    header.extend(specs.iter().map(BackendSpec::label));
    AcceleratorConfig::paper_platforms()
        .into_iter()
        .map(|acc| {
            let mut t = Table::new(
                &format!("Fig. 15a — refresh energy per inference on {} (µJ)", acc.name),
                &header.iter().map(String::as_str).collect::<Vec<_>>(),
            );
            for net in all_networks() {
                let trace = simulate_network(&net, &acc);
                let mut row = vec![net.name.to_string()];
                row.extend(specs.iter().map(|s| uj(evaluate(&trace, &acc, s).refresh_j)));
                t.row(row);
            }
            t
        })
        .collect()
}

/// Fig. 15a with the paper's V_REF sweep.
pub fn fig15a() -> Vec<Table> {
    fig15a_for(&[
        spec("edram2t"),
        spec("mcaimem@0.5"),
        spec("mcaimem@0.6"),
        spec("mcaimem@0.7"),
        spec("mcaimem@0.8"),
    ])
}

/// Fig. 15b — total buffer energy across technologies.
pub fn fig15b_for(specs: &[BackendSpec]) -> Vec<Table> {
    let header = sweep_header(specs);
    AcceleratorConfig::paper_platforms()
        .into_iter()
        .map(|acc| {
            let mut t = Table::new(
                &format!("Fig. 15b — total buffer energy per inference on {} (µJ)", acc.name),
                &header.iter().map(String::as_str).collect::<Vec<_>>(),
            );
            for net in all_networks() {
                let trace = simulate_network(&net, &acc);
                let vals: Vec<f64> =
                    specs.iter().map(|s| evaluate(&trace, &acc, s).total_j()).collect();
                let mut row = vec![net.name.to_string()];
                row.extend(vals.iter().map(|&v| uj(v)));
                row.push(format!("{}x", fnum(vals[0] / vals[vals.len() - 1], 2)));
                t.row(row);
            }
            t
        })
        .collect()
}

/// Fig. 15b with the paper's technology set.
pub fn fig15b() -> Vec<Table> {
    fig15b_for(&[spec("sram"), spec("rram"), spec("edram2t"), spec("mcaimem@0.8")])
}

/// Fig. 16 — normalized ops/W improvement vs the SRAM buffer.
pub fn fig16() -> Vec<Table> {
    let mut t = Table::new(
        "Fig. 16 — ops/W improvement with MCAIMem@0.8 vs SRAM buffer (paper: 35.4%–43.2%)",
        &["network", "Eyeriss", "TPUv1"],
    );
    let platforms = AcceleratorConfig::paper_platforms();
    for net in all_networks() {
        let mut row = vec![net.name.to_string()];
        for acc in &platforms {
            let trace = simulate_network(&net, acc);
            let g = opswatt_gain(&trace, acc, &BackendSpec::mcaimem_default());
            row.push(format!("{}%", fnum(g * 100.0, 1)));
        }
        t.row(row);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14_covers_all_networks_on_both_platforms() {
        let tables = fig14();
        assert_eq!(tables.len(), 2);
        for t in &tables {
            assert_eq!(t.rows.len(), 7);
        }
    }

    #[test]
    fn fig15a_refresh_monotone_in_vref_every_row() {
        for t in fig15a() {
            for row in &t.rows {
                let vals: Vec<f64> = row[2..6].iter().map(|c| c.parse().unwrap()).collect();
                for w in vals.windows(2) {
                    assert!(w[1] <= w[0] + 1e-9, "{row:?}");
                }
            }
        }
    }

    #[test]
    fn fig16_gains_positive() {
        let t = &fig16()[0];
        for row in &t.rows {
            for cell in &row[1..] {
                let v: f64 = cell.trim_end_matches('%').parse().unwrap();
                assert!(v > 10.0 && v < 60.0, "{row:?}");
            }
        }
    }

    #[test]
    fn custom_sweeps_drive_the_same_drivers() {
        // the api_redesign promise: a user-supplied spec list (several
        // V_REF points included) flows through the identical driver
        let specs = BackendSpec::parse_list("sram,mcaimem@0.6,mcaimem@0.7,mcaimem@0.8").unwrap();
        let tables = fig15b_for(&specs);
        assert_eq!(tables.len(), 2);
        for t in &tables {
            // network + 4 backends + ratio
            assert_eq!(t.header.len(), 6, "{:?}", t.header);
            for row in &t.rows {
                assert_eq!(row.len(), 6);
            }
        }
    }
}
