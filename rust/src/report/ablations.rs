//! Ablations over MCAIMem's design choices (DESIGN.md §3 extension).
//!
//! * **SRAM:eDRAM ratio** — the paper fixes one SRAM cell per byte ("the
//!   proportion ratio of one SRAM and seven eDRAM cells", §I) to protect
//!   exactly the sign/control bit. Sweeping k = MSBs-in-SRAM ∈ {0..3}
//!   exposes the trade: more SRAM ⇒ less area saving and more static
//!   power, but more bits immune to retention flips.
//! * **RANA-style refresh elimination** — related work [39] skips refresh
//!   when data lifetime < retention. The refresh controller has the
//!   switch; this ablation quantifies when it is safe on our workloads.

use crate::encode::one_enhancement::encode_byte;
use crate::mem::energy::EnergyCard;
use crate::scalesim::accelerator::AcceleratorConfig;
use crate::scalesim::network::all_networks;
use crate::scalesim::simulate_network;
use crate::util::rng::Pcg64;
use crate::util::table::{fnum, Table};

/// Relative area of one widened 2T cell vs a 6T SRAM cell.
const EDRAM_CELL_REL: f64 = crate::circuit::edram2t::MCAIMEM_AREA_REL;

/// Expected |error| of a stored int8 value when its low `8-k` bits are
/// exposed to 0→1 flips at rate `p` (one-enhancement applied), averaged
/// over DNN-like data. Monte-Carlo with the shared inject kernel.
fn expected_abs_error_k(k: usize, p: f64, seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed);
    let n = 20_000;
    let data = crate::encode::stats::resnet50_like_weights(seed ^ 0xAB, n);
    let protect_mask: u8 = !(0xffu8 >> k); // top k bits protected (incl. sign at k≥1)
    let mut total = 0.0;
    for &v in &data {
        let enc = encode_byte(v as u8);
        let mut aged = enc;
        for bit in 0..(8 - k) {
            if aged & (1 << bit) == 0 && rng.bernoulli(p) {
                aged |= 1 << bit;
            }
        }
        // protected bits cannot have flipped by construction of the loop;
        // decode with the (protected) sign bit
        let _ = protect_mask;
        let dec = crate::encode::one_enhancement::decode_byte(aged);
        total += ((dec as i8) as i16 - v as i16).abs() as f64;
    }
    total / n as f64
}

/// The ratio sweep: k MSBs per byte in SRAM, 8−k in eDRAM.
pub fn ratio_sweep() -> Vec<Table> {
    let mut t = Table::new(
        "ablation — SRAM:eDRAM ratio per byte (paper picks k=1: the sign bit)",
        &[
            "k (SRAM bits)",
            "area vs SRAM",
            "static min (mW/MB)",
            "static max (mW/MB)",
            "E|err| @p=1%",
            "E|err| @p=10%",
        ],
    );
    let s = EnergyCard::sram();
    let e = EnergyCard::edram2t();
    for k in 0..=3usize {
        let frac_sram = k as f64 / 8.0;
        let area = frac_sram + (1.0 - frac_sram) * EDRAM_CELL_REL;
        let smin = (s.static_power(1 << 20, 1.0) * frac_sram
            + e.static_power(1 << 20, 1.0) * (1.0 - frac_sram))
            * 1e3;
        let smax = (s.static_power(1 << 20, 0.0) * frac_sram
            + e.static_power(1 << 20, 0.0) * (1.0 - frac_sram))
            * 1e3;
        t.row(vec![
            k.to_string(),
            format!("{}%", fnum(area * 100.0, 1)),
            fnum(smin, 2),
            fnum(smax, 2),
            fnum(expected_abs_error_k(k, 0.01, 17), 3),
            fnum(expected_abs_error_k(k, 0.10, 18), 3),
        ]);
    }
    vec![t]
}

/// RANA-style refresh elimination: for each network/platform, compare the
/// per-layer data residency time against the retention window — when every
/// layer turns its activations over faster than 12.57 µs, refresh can be
/// gated off entirely (related work [39]; the paper notes this assumption
/// erodes as activations grow).
pub fn rana_analysis() -> Vec<Table> {
    let mut t = Table::new(
        "ablation — RANA [39] refresh elimination viability (V_REF=0.8, 12.57 µs retention)",
        &[
            "network@platform",
            "max layer time (µs)",
            "layers > retention",
            "refresh energy saved if gated (µJ)",
        ],
    );
    let retention = 12.57e-6;
    for acc in AcceleratorConfig::paper_platforms() {
        for net in all_networks() {
            let trace = simulate_network(&net, &acc);
            let max_t = trace
                .layers
                .iter()
                .map(|l| l.time_s)
                .fold(0.0f64, f64::max);
            let over = trace.layers.iter().filter(|l| l.time_s > retention).count();
            let saved = crate::energy::system_eval::evaluate(
                &trace,
                &acc,
                &crate::mem::backend::BackendSpec::mcaimem_default(),
            )
            .refresh_j;
            t.row(vec![
                format!("{}@{}", net.name, acc.name),
                fnum(max_t * 1e6, 2),
                format!("{over}/{}", trace.layers.len()),
                fnum(saved * 1e6, 2),
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_sram_bits_mean_more_area_and_less_error() {
        let tables = ratio_sweep();
        let rows = &tables[0].rows;
        assert_eq!(rows.len(), 4);
        // area monotone increasing in k
        let area = |r: &Vec<String>| r[1].trim_end_matches('%').parse::<f64>().unwrap();
        let err10 = |r: &Vec<String>| r[5].parse::<f64>().unwrap();
        for w in rows.windows(2) {
            assert!(area(&w[1]) > area(&w[0]));
            assert!(err10(&w[1]) <= err10(&w[0]) + 1e-9);
        }
        // the paper's k=1 point: ~52% area
        assert!((area(&rows[1]) - 52.2).abs() < 1.0, "{}", area(&rows[1]));
    }

    #[test]
    fn k0_exposes_the_sign_bit() {
        // without the SRAM plane even the sign bit flips (positive values
        // read back negative) — mean error roughly doubles vs k=1
        let e_k0 = expected_abs_error_k(0, 0.10, 1);
        let e_k1 = expected_abs_error_k(1, 0.10, 1);
        assert!(e_k0 > 1.5 * e_k1, "k0={e_k0} k1={e_k1}");
    }

    #[test]
    fn rana_rows_cover_all_combinations() {
        let t = &rana_analysis()[0];
        assert_eq!(t.rows.len(), 14); // 7 networks × 2 platforms
    }
}
