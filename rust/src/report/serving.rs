//! Serving-tier read-outs: pool statistics tables and the saturation
//! sweep (workers × shards → sustained req/s).
//!
//! The sweep is the system-level counterpart of the paper's per-macro
//! claims: it measures how far the banked buffer + worker pool scales the
//! serving rate on one host, and it is what CI/benches print to check the
//! ≥3× scaling of `--shards 4 --workers 4` over `--shards 1 --workers 1`.

use crate::coordinator::loadgen::{self, Arrival, LoadConfig};
use crate::coordinator::pool::{PoolConfig, WorkerPool};
use crate::coordinator::server::ServerStats;
use crate::mem::backend::BackendSpec;
use crate::util::table::{fnum, Table};
use crate::Result;

/// Render the tier-level stats block (one row) plus the per-shard
/// break-down.
pub fn stats_tables(stats: &ServerStats) -> Vec<Table> {
    let mut summary = Table::new(
        "serving-tier statistics",
        &[
            "requests", "errors", "rejected", "batches", "occupancy", "req/s", "KB/s",
            "p50 (µs)", "p99 (µs)", "queue p99",
        ],
    );
    summary.row(vec![
        stats.requests.to_string(),
        stats.errors.to_string(),
        stats.rejected.to_string(),
        stats.batches.to_string(),
        fnum(stats.occupancy, 3),
        fnum(stats.requests_per_s, 0),
        fnum(stats.bytes_per_s / 1024.0, 1),
        fnum(stats.p50_latency_us, 0),
        fnum(stats.p99_latency_us, 0),
        fnum(stats.queue_depth_p99, 1),
    ]);
    let mut out = vec![summary];
    if !stats.shards.is_empty() {
        let mut t = Table::new(
            "per-shard break-down (striping should balance occupancy at ~1/N)",
            &["shard", "worker", "bytes r+w", "occupancy", "refresh ops", "energy (µJ)"],
        );
        for s in &stats.shards {
            t.row(vec![
                s.shard.to_string(),
                s.worker.to_string(),
                s.bytes_rw.to_string(),
                fnum(s.occupancy, 3),
                s.refreshes.to_string(),
                fnum(s.energy_j * 1e6, 3),
            ]);
        }
        out.push(t);
    }
    out
}

/// One point of the saturation sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub workers: usize,
    pub shards: usize,
    pub achieved_rps: f64,
    pub p99_latency_us: f64,
    pub rejected: u64,
    /// Speedup over the (1, 1) single-worker/single-shard point.
    pub speedup: f64,
}

/// Closed-loop saturation sweep: for each (workers, shards) combo, drive
/// the tier with `4 × workers` clients for `requests` requests and record
/// the sustained req/s. Returns the rendered table plus the raw points
/// (the first combo is the speedup baseline).
pub fn saturation_sweep(
    backend: &BackendSpec,
    combos: &[(usize, usize)],
    requests: usize,
    seed: u64,
) -> Result<(Table, Vec<SweepPoint>)> {
    let mut t = Table::new(
        &format!("saturation sweep — {} (closed loop, sustained req/s)", backend.label()),
        &["workers", "shards", "req/s", "p99 (µs)", "rejected", "speedup vs 1×1"],
    );
    let mut points: Vec<SweepPoint> = Vec::with_capacity(combos.len());
    for &(workers, shards) in combos {
        let cfg = PoolConfig {
            backend: *backend,
            workers,
            shards,
            buffer_bytes: shards * 64 * 1024,
            seed,
            ..PoolConfig::default()
        };
        let pool = WorkerPool::start(cfg)?;
        let load = LoadConfig {
            arrival: Arrival::ClosedLoop { clients: 4 * workers },
            requests,
            seed,
            ..LoadConfig::default()
        };
        let report = loadgen::run(&pool, &load);
        let _ = pool.shutdown();
        let base = points.first().map(|p: &SweepPoint| p.achieved_rps).unwrap_or(0.0);
        let speedup =
            if base > 0.0 { report.achieved_rps / base } else { 1.0 };
        t.row(vec![
            workers.to_string(),
            shards.to_string(),
            fnum(report.achieved_rps, 0),
            fnum(report.p99_latency_us, 0),
            report.rejected.to_string(),
            format!("{}x", fnum(speedup, 2)),
        ]);
        points.push(SweepPoint {
            workers,
            shards,
            achieved_rps: report.achieved_rps,
            p99_latency_us: report.p99_latency_us,
            rejected: report.rejected,
            speedup,
        });
    }
    Ok((t, points))
}

/// The default sweep grid: single worker, scale workers+shards together.
pub const DEFAULT_SWEEP: [(usize, usize); 4] = [(1, 1), (2, 2), (4, 4), (4, 8)];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::ShardStat;

    #[test]
    fn stats_tables_render_shard_rows() {
        let mut m = crate::coordinator::metrics::Metrics::default();
        m.record_latency(std::time::Duration::from_micros(100));
        m.record_batch(1, 4);
        let mut stats = ServerStats::from_metrics(&m);
        stats.shards = vec![ShardStat {
            shard: 0,
            worker: 0,
            bytes_rw: 1024,
            occupancy: 1.0,
            refreshes: 3,
            energy_j: 1e-6,
        }];
        stats.rejected = 7;
        let tables = stats_tables(&stats);
        assert_eq!(tables.len(), 2);
        let rendered = tables[1].render();
        assert!(rendered.contains("1024"), "{rendered}");
        assert!(tables[0].render().contains('7'));
    }

    #[test]
    fn tiny_sweep_produces_monotone_points() {
        // smallest possible sweep — just proves the plumbing end-to-end
        let (t, points) =
            saturation_sweep(&BackendSpec::Sram, &[(1, 1)], 24, 3).unwrap();
        assert_eq!(points.len(), 1);
        assert!(points[0].achieved_rps > 0.0);
        assert!((points[0].speedup - 1.0).abs() < 1e-12);
        assert!(t.render().contains("req/s"));
    }
}
