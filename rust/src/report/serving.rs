//! Serving-tier read-outs: pool statistics tables, the saturation sweep
//! (workers × shards → sustained req/s) and the open-loop rate sweep
//! (offered req/s → tail latency + schedule slip).
//!
//! The sweeps are the system-level counterpart of the paper's per-macro
//! claims: the saturation sweep measures how far the banked buffer +
//! worker pool scales the serving rate on one host (CI/benches check the
//! ≥3× scaling of `--shards 4 --workers 4` over `--shards 1 --workers 1`),
//! and the rate sweep holds the tier at fixed offered rates — 100k+ req/s —
//! and reads the p99.9 SLO tail plus the load generator's own schedule
//! slip, which is what gates the event-loop dispatcher.

use crate::coordinator::loadgen::{self, Arrival, LoadConfig};
use crate::coordinator::pool::{PoolConfig, WorkerPool};
use crate::coordinator::scheduler::DispatchMode;
use crate::coordinator::server::ServerStats;
use crate::mem::backend::BackendSpec;
use crate::util::json::Json;
use crate::util::table::{fnum, Table};
use crate::Result;
use std::time::Duration;

/// True when a quantile estimate rests on fewer than one expected tail
/// sample (`n·(1−q) < 1`) — e.g. p99.9 below 1000 completions. Rendered
/// with a `*` marker so sweep readers don't gate on noise.
fn quantile_starved(requests: u64, q: f64) -> bool {
    (requests as f64) * (1.0 - q) < 1.0
}

/// Render the tier-level stats block (one row), the refresh-stall
/// attribution when stall modeling was on, the per-shard break-down, and
/// (under `--features obs-profile` with profiling on) the hot-path phase
/// table.
pub fn stats_tables(stats: &ServerStats) -> Vec<Table> {
    let q_cell = |v: f64, q: f64| {
        if quantile_starved(stats.requests, q) {
            format!("{}*", fnum(v, 0))
        } else {
            fnum(v, 0)
        }
    };
    let any_starved = [0.5, 0.99, 0.999].iter().any(|&q| quantile_starved(stats.requests, q));
    let title = if any_starved {
        "serving-tier statistics (* = sample-starved quantile: fewer than one expected tail sample)"
    } else {
        "serving-tier statistics"
    };
    let mut summary = Table::new(
        title,
        &[
            "requests", "errors", "rejected", "batches", "occupancy", "req/s", "KB/s",
            "p50 (µs)", "p99 (µs)", "p99.9 (µs)", "queue p99",
        ],
    );
    summary.row(vec![
        stats.requests.to_string(),
        stats.errors.to_string(),
        stats.rejected.to_string(),
        stats.batches.to_string(),
        fnum(stats.occupancy, 3),
        fnum(stats.requests_per_s, 0),
        fnum(stats.bytes_per_s / 1024.0, 1),
        q_cell(stats.p50_latency_us, 0.5),
        q_cell(stats.p99_latency_us, 0.99),
        q_cell(stats.p999_latency_us, 0.999),
        fnum(stats.queue_depth_p99, 1),
    ]);
    let mut out = vec![summary];
    if stats.refresh_stall_total_us > 0.0 || stats.refresh_slack_total_us > 0.0 {
        let mut t = Table::new(
            "refresh stall attribution (on-path stall vs slack-absorbed)",
            &["stall p99.9 (µs)", "stall total (µs)", "slack total (µs)"],
        );
        t.row(vec![
            fnum(stats.refresh_stall_p999_us, 2),
            fnum(stats.refresh_stall_total_us, 1),
            fnum(stats.refresh_slack_total_us, 1),
        ]);
        out.push(t);
    }
    if !stats.shards.is_empty() {
        let mut t = Table::new(
            "per-shard break-down (striping should balance occupancy at ~1/N)",
            &["shard", "worker", "bytes r+w", "occupancy", "refresh ops", "energy (µJ)"],
        );
        for s in &stats.shards {
            t.row(vec![
                s.shard.to_string(),
                s.worker.to_string(),
                s.bytes_rw.to_string(),
                fnum(s.occupancy, 3),
                s.refreshes.to_string(),
                fnum(s.energy_j * 1e6, 3),
            ]);
        }
        out.push(t);
    }
    // phase breakdown only exists when the binary was built with
    // --features obs-profile and profiling was switched on for the run
    let phases = crate::obs::profile::snapshot();
    if !phases.is_empty() {
        let mut t = Table::new(
            "hot-path phase breakdown (host wall time; --features obs-profile)",
            &["phase", "calls", "total (ms)", "mean (µs)"],
        );
        for s in &phases {
            t.row(vec![
                s.phase.name().to_string(),
                s.calls.to_string(),
                fnum(s.total_ns as f64 / 1e6, 3),
                fnum(s.total_ns as f64 / 1e3 / s.calls.max(1) as f64, 2),
            ]);
        }
        out.push(t);
    }
    out
}

/// One point of the saturation sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub workers: usize,
    pub shards: usize,
    pub achieved_rps: f64,
    pub p99_latency_us: f64,
    pub p999_latency_us: f64,
    pub rejected: u64,
    /// Speedup over the (1, 1) single-worker/single-shard point.
    pub speedup: f64,
}

/// Closed-loop saturation sweep: for each (workers, shards) combo, drive
/// the tier with `4 × workers` clients for `requests` requests and record
/// the sustained req/s. Returns the rendered table plus the raw points
/// (the first combo is the speedup baseline).
pub fn saturation_sweep(
    backend: &BackendSpec,
    combos: &[(usize, usize)],
    requests: usize,
    seed: u64,
) -> Result<(Table, Vec<SweepPoint>)> {
    let mut t = Table::new(
        &format!("saturation sweep — {} (closed loop, sustained req/s)", backend.label()),
        &["workers", "shards", "req/s", "p99 (µs)", "p99.9 (µs)", "rejected", "speedup vs 1×1"],
    );
    let mut points: Vec<SweepPoint> = Vec::with_capacity(combos.len());
    for &(workers, shards) in combos {
        let cfg = PoolConfig {
            backend: backend.clone(),
            workers,
            shards,
            buffer_bytes: shards * 64 * 1024,
            seed,
            ..PoolConfig::default()
        };
        let pool = WorkerPool::start(cfg)?;
        let load = LoadConfig {
            arrival: Arrival::ClosedLoop { clients: 4 * workers },
            requests,
            seed,
            ..LoadConfig::default()
        };
        let report = loadgen::run(&pool, &load);
        let _ = pool.shutdown();
        let base = points.first().map(|p: &SweepPoint| p.achieved_rps).unwrap_or(0.0);
        let speedup =
            if base > 0.0 { report.achieved_rps / base } else { 1.0 };
        t.row(vec![
            workers.to_string(),
            shards.to_string(),
            fnum(report.achieved_rps, 0),
            fnum(report.p99_latency_us, 0),
            fnum(report.p999_latency_us, 0),
            report.rejected.to_string(),
            format!("{}x", fnum(speedup, 2)),
        ]);
        points.push(SweepPoint {
            workers,
            shards,
            achieved_rps: report.achieved_rps,
            p99_latency_us: report.p99_latency_us,
            p999_latency_us: report.p999_latency_us,
            rejected: report.rejected,
            speedup,
        });
    }
    Ok((t, points))
}

/// The default sweep grid: single worker, scale workers+shards together.
pub const DEFAULT_SWEEP: [(usize, usize); 4] = [(1, 1), (2, 2), (4, 4), (4, 8)];

/// Machine-readable saturation-sweep artifact (what `mcaimem serve --sweep
/// --json` writes; CI uploads it from the serve-smoke job).
pub fn saturation_sweep_json(backend: &BackendSpec, points: &[SweepPoint]) -> Json {
    Json::obj(vec![
        ("backend", Json::Str(backend.label())),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("workers", Json::Num(p.workers as f64)),
                            ("shards", Json::Num(p.shards as f64)),
                            ("achieved_rps", Json::Num(p.achieved_rps)),
                            ("p99_latency_us", Json::Num(p.p99_latency_us)),
                            ("p999_latency_us", Json::Num(p.p999_latency_us)),
                            ("rejected", Json::Num(p.rejected as f64)),
                            ("speedup", Json::Num(p.speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Offered rates (req/s) for the default open-loop rate sweep — the top
/// point is the 100k+ req/s target the event-loop dispatcher is gated on.
pub const DEFAULT_RATES: [f64; 3] = [50_000.0, 100_000.0, 200_000.0];

/// Pool/traffic shape for the open-loop rate sweep (one pool per rate).
#[derive(Clone, Debug)]
pub struct RateSweepConfig {
    pub workers: usize,
    pub shards: usize,
    /// Requests offered per rate point.
    pub requests: usize,
    pub dispatch: DispatchMode,
    /// Modeled wall-clock stall per refresh slot (zero = off).
    pub refresh_stall: Duration,
    pub seed: u64,
}

impl Default for RateSweepConfig {
    fn default() -> Self {
        RateSweepConfig {
            workers: 4,
            shards: 4,
            requests: 4096,
            dispatch: DispatchMode::RefreshAware,
            refresh_stall: Duration::ZERO,
            seed: 0x5E21E,
        }
    }
}

/// One point of the open-loop rate sweep.
#[derive(Clone, Debug)]
pub struct RatePoint {
    /// Offered (target) arrival rate, req/s.
    pub target_rps: f64,
    pub offered: usize,
    pub completed: usize,
    pub rejected: u64,
    pub achieved_rps: f64,
    pub p99_latency_us: f64,
    /// The SLO tail the sweep is gated on.
    pub p999_latency_us: f64,
    /// p99 of how far arrivals slipped behind the Poisson schedule — the
    /// honesty meter for the offered rate (a generator that cannot keep
    /// its own schedule is not really offering `target_rps`).
    pub sched_lag_p99_us: f64,
}

/// Open-loop rate sweep: hold the tier at each offered rate (Poisson
/// arrivals, rejects are lost, not retried) and read the tail. Fully
/// deterministic given `cfg.seed`: the same seed draws the same arrival
/// schedule and tenant sequence at every rate.
pub fn rate_sweep(
    backend: &BackendSpec,
    rates: &[f64],
    cfg: &RateSweepConfig,
) -> Result<(Table, Vec<RatePoint>)> {
    let mut t = Table::new(
        &format!(
            "rate sweep — {} ({} dispatch, open loop)",
            backend.label(),
            cfg.dispatch
        ),
        &[
            "target req/s", "offered", "completed", "rejected", "req/s",
            "p99 (µs)", "p99.9 (µs)", "sched lag p99 (µs)",
        ],
    );
    let mut points = Vec::with_capacity(rates.len());
    for &rps in rates {
        let pool_cfg = PoolConfig {
            backend: backend.clone(),
            workers: cfg.workers,
            shards: cfg.shards,
            buffer_bytes: cfg.shards * 64 * 1024,
            dispatch: cfg.dispatch,
            refresh_stall: cfg.refresh_stall,
            seed: cfg.seed,
            ..PoolConfig::default()
        };
        let pool = WorkerPool::start(pool_cfg)?;
        let load = LoadConfig {
            arrival: Arrival::OpenPoisson { rps },
            requests: cfg.requests,
            retry_rejects: false,
            seed: cfg.seed,
            ..LoadConfig::default()
        }
        .validated()?;
        let report = loadgen::run(&pool, &load);
        let _ = pool.shutdown();
        t.row(vec![
            fnum(rps, 0),
            report.offered.to_string(),
            report.completed.to_string(),
            report.rejected.to_string(),
            fnum(report.achieved_rps, 0),
            fnum(report.p99_latency_us, 0),
            fnum(report.p999_latency_us, 0),
            fnum(report.sched_lag_p99_us, 0),
        ]);
        points.push(RatePoint {
            target_rps: rps,
            offered: report.offered,
            completed: report.completed,
            rejected: report.rejected,
            achieved_rps: report.achieved_rps,
            p99_latency_us: report.p99_latency_us,
            p999_latency_us: report.p999_latency_us,
            sched_lag_p99_us: report.sched_lag_p99_us,
        });
    }
    Ok((t, points))
}

/// Machine-readable rate-sweep artifact (what `mcaimem serve --rates …
/// --json` writes; CI uploads it from the serve-smoke job).
pub fn rate_sweep_json(backend: &BackendSpec, cfg: &RateSweepConfig, points: &[RatePoint]) -> Json {
    Json::obj(vec![
        ("backend", Json::Str(backend.label())),
        ("dispatch", Json::Str(cfg.dispatch.to_string())),
        ("workers", Json::Num(cfg.workers as f64)),
        ("shards", Json::Num(cfg.shards as f64)),
        ("requests_per_rate", Json::Num(cfg.requests as f64)),
        ("refresh_stall_us", Json::Num(cfg.refresh_stall.as_secs_f64() * 1e6)),
        ("seed", Json::Num(cfg.seed as f64)),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("target_rps", Json::Num(p.target_rps)),
                            ("offered", Json::Num(p.offered as f64)),
                            ("completed", Json::Num(p.completed as f64)),
                            ("rejected", Json::Num(p.rejected as f64)),
                            ("achieved_rps", Json::Num(p.achieved_rps)),
                            ("p99_latency_us", Json::Num(p.p99_latency_us)),
                            ("p999_latency_us", Json::Num(p.p999_latency_us)),
                            ("sched_lag_p99_us", Json::Num(p.sched_lag_p99_us)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::ShardStat;

    #[test]
    fn stats_tables_render_shard_rows() {
        let mut m = crate::coordinator::metrics::Metrics::default();
        m.record_latency(std::time::Duration::from_micros(100));
        m.record_batch(1, 4);
        let mut stats = ServerStats::from_metrics(&m);
        stats.shards = vec![ShardStat {
            shard: 0,
            worker: 0,
            bytes_rw: 1024,
            occupancy: 1.0,
            refreshes: 3,
            energy_j: 1e-6,
        }];
        stats.rejected = 7;
        let tables = stats_tables(&stats);
        assert_eq!(tables.len(), 2);
        let rendered = tables[1].render();
        assert!(rendered.contains("1024"), "{rendered}");
        assert!(tables[0].render().contains('7'));
        assert!(tables[0].render().contains("p99.9"), "summary must show the SLO tail");
        // refresh attribution appears only when stall modeling ran
        stats.refresh_slack_total_us = 12.5;
        let tables = stats_tables(&stats);
        assert_eq!(tables.len(), 3);
        assert!(tables[1].render().contains("slack"));
    }

    #[test]
    fn starved_quantiles_are_marked_not_hidden() {
        let mut m = crate::coordinator::metrics::Metrics::default();
        m.record_latency(std::time::Duration::from_micros(100));
        m.record_batch(1, 4);
        let mut stats = ServerStats::from_metrics(&m);
        // 500 completions: p50/p99 are honest, p99.9 expects < 1 tail
        // sample — the summary must carry the * marker and the footnote
        stats.requests = 500;
        let rendered = stats_tables(&stats)[0].render();
        assert!(rendered.contains("sample-starved"), "{rendered}");
        assert!(rendered.contains('*'), "{rendered}");
        // plenty of samples: marker and footnote both disappear
        stats.requests = 100_000;
        let rendered = stats_tables(&stats)[0].render();
        assert!(!rendered.contains("sample-starved"), "{rendered}");
    }

    #[test]
    fn tiny_sweep_produces_monotone_points() {
        // smallest possible sweep — just proves the plumbing end-to-end
        let (t, points) =
            saturation_sweep(&BackendSpec::Sram, &[(1, 1)], 24, 3).unwrap();
        assert_eq!(points.len(), 1);
        assert!(points[0].achieved_rps > 0.0);
        assert!((points[0].speedup - 1.0).abs() < 1e-12);
        assert!(t.render().contains("req/s"));
    }

    #[test]
    fn rate_sweep_reports_the_tail_and_serializes() {
        // one fast point end-to-end: offered == requested (open loop,
        // nothing closes early), p99.9 present, JSON round-trips
        let cfg = RateSweepConfig {
            workers: 1,
            shards: 1,
            requests: 64,
            seed: 9,
            ..RateSweepConfig::default()
        };
        let (t, points) = rate_sweep(&BackendSpec::Sram, &[50_000.0], &cfg).unwrap();
        assert_eq!(points.len(), 1);
        assert_eq!(points[0].offered, 64);
        assert!(points[0].p999_latency_us >= points[0].p99_latency_us);
        assert!(t.render().contains("p99.9"));
        let doc = rate_sweep_json(&BackendSpec::Sram, &cfg, &points);
        let parsed = Json::parse(&doc.to_pretty()).unwrap();
        assert_eq!(parsed, doc);
        match &doc {
            Json::Obj(map) => {
                assert!(matches!(map.get("points"), Some(Json::Arr(a)) if a.len() == 1));
            }
            _ => panic!("rate sweep artifact must be an object"),
        }
    }
}
