//! Chaos drill report — the table `mcaimem chaos` renders.
//!
//! One row per memory-tier campaign run (backend × geometry, conformance
//! verdicts under the active fault plan) plus one row for the serving-tier
//! drill (reply accounting and surviving workers). Failing minimal traces
//! reuse the conformance artifact format, so CI uploads them and anyone
//! can replay with `mcaimem conform --replay <file>`.

use crate::sim::chaos::{self, ChaosConfig, ChaosOutcome};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::Result;

/// Run the drill and render the outcome table. Returns the table, the raw
/// outcome, and whether everything passed.
pub fn chaos(cfg: &ChaosConfig) -> Result<(Table, ChaosOutcome, bool)> {
    let out = chaos::run(cfg)?;
    let mut t = Table::new(
        &format!("chaos drill — plan `{}`, seed {}", cfg.plan, cfg.seed),
        &["tier", "target", "geometry", "checks", "verdict"],
    );
    for o in &out.memory {
        let (s, l, k, r) = o.counts;
        t.row(vec![
            "memory".into(),
            o.spec.label(),
            o.geometry(),
            format!("{s} stores / {l} loads / {k} ticks / {r} refreshes"),
            if o.ok() {
                "exact (self + oracle)".into()
            } else {
                let f = o.failures.first();
                format!(
                    "DIVERGED: {}",
                    f.map(|f| format!(
                        "{} (minimal {} ops)",
                        f.divergence,
                        f.minimal.entries.len()
                    ))
                    .unwrap_or_else(|| "see failures".into())
                )
            },
        ]);
    }
    let s = &out.serving;
    t.row(vec![
        "serving".into(),
        format!("mcaimem@0.8 pool, {} workers", s.workers),
        "failover pairs".into(),
        format!(
            "{} offered: {} ok / {} errors / {} abandoned / {} rejects; {}/{} workers alive",
            s.offered, s.completed, s.errors, s.abandoned, s.rejected, s.alive_workers, s.workers
        ),
        if s.ok() { "0 lost replies".into() } else { format!("{} LOST replies", s.lost) },
    ]);
    let ok = out.ok();
    Ok((t, out, ok))
}

/// Machine-readable drill report for `mcaimem chaos --json`.
pub fn outcome_json(out: &ChaosOutcome, cfg: &ChaosConfig) -> Json {
    let memory: Vec<Json> = out
        .memory
        .iter()
        .map(|o| {
            Json::obj(vec![
                ("backend", Json::Str(o.spec.to_string())),
                ("geometry", Json::Str(o.geometry().replace('×', "x"))),
                ("self_replay_ok", Json::Bool(o.self_replay_ok)),
                (
                    "oracle_ok",
                    match o.oracle_ok {
                        None => Json::Null,
                        Some(b) => Json::Bool(b),
                    },
                ),
                ("failures", Json::Num(o.failures.len() as f64)),
            ])
        })
        .collect();
    let s = &out.serving;
    Json::obj(vec![
        ("plan", Json::Str(cfg.plan.to_string())),
        ("seed", Json::Num(cfg.seed as f64)),
        ("ops", Json::Num(cfg.ops as f64)),
        ("ok", Json::Bool(out.ok())),
        ("memory", Json::Arr(memory)),
        (
            "serving",
            Json::obj(vec![
                ("offered", Json::Num(s.offered as f64)),
                ("completed", Json::Num(s.completed as f64)),
                ("errors", Json::Num(s.errors as f64)),
                ("abandoned", Json::Num(s.abandoned as f64)),
                ("rejected", Json::Num(s.rejected as f64)),
                ("lost", Json::Num(s.lost as f64)),
                ("workers", Json::Num(s.workers as f64)),
                ("alive_workers", Json::Num(s.alive_workers as f64)),
            ]),
        ),
    ])
}
