//! Regeneration of every table and figure in the paper's evaluation.
//!
//! Each `fn <id>() -> Vec<Table>` prints the same rows/series the paper
//! reports (DESIGN.md §3 maps ids to modules); `run` dispatches by id and
//! mirrors everything to CSV under `results/`.

pub mod ablations;
pub mod chaos;
pub mod circuit_reports;
pub mod conformance;
pub mod fig11;
pub mod macro_spec;
pub mod pareto;
pub mod serving;
pub mod system_reports;

use std::path::Path;

use crate::mem::backend::BackendSpec;
use crate::util::table::Table;
use crate::Result;

/// All report ids, in paper order.
pub const ALL_IDS: [&str; 14] = [
    "table1", "table2", "fig1", "fig2", "fig5", "fig7", "fig9", "fig11", "fig12", "fig13",
    "fig14", "fig15a", "fig15b", "fig16",
];

/// Generate the tables for one id with each figure's default backend
/// sweep. `artifacts` is only needed by fig11 (the DNN-accuracy experiment
/// runs the AOT model through PJRT).
pub fn generate(id: &str, artifacts: Option<&Path>, quick: bool) -> Result<Vec<Table>> {
    generate_with(id, artifacts, quick, None)
}

/// Generate the tables for one id; `backends` (the CLI's `--backend` list)
/// overrides the backend sweep of the system-level figures.
pub fn generate_with(
    id: &str,
    artifacts: Option<&Path>,
    quick: bool,
    backends: Option<&[BackendSpec]>,
) -> Result<Vec<Table>> {
    Ok(match id {
        "table1" => circuit_reports::table1(),
        "table2" => circuit_reports::table2(),
        "fig1" => circuit_reports::fig1(),
        "fig2" => circuit_reports::fig2(quick),
        "fig5" => circuit_reports::fig5(artifacts),
        "fig7" => circuit_reports::fig7(),
        "fig9" => circuit_reports::fig9(quick),
        "fig11" => fig11::fig11(
            artifacts.ok_or_else(|| anyhow::anyhow!("fig11 needs --artifacts <dir>"))?,
            quick,
        )?,
        "fig12" => circuit_reports::fig12(quick),
        "fig13" => circuit_reports::fig13(),
        "fig14" => match backends {
            Some(specs) => system_reports::fig14_for(specs),
            None => system_reports::fig14(),
        },
        "fig15a" => match backends {
            Some(specs) => system_reports::fig15a_for(specs),
            None => system_reports::fig15a(),
        },
        "fig15b" => match backends {
            Some(specs) => system_reports::fig15b_for(specs),
            None => system_reports::fig15b(),
        },
        "fig16" => system_reports::fig16(),
        "ablation-ratio" => ablations::ratio_sweep(),
        "ablation-rana" => ablations::rana_analysis(),
        other => anyhow::bail!(
            "unknown report id `{other}` (try one of {ALL_IDS:?}, ablation-ratio, ablation-rana)"
        ),
    })
}

/// Print tables and mirror them to CSV.
pub fn run(
    id: &str,
    artifacts: Option<&Path>,
    csv_dir: Option<&Path>,
    quick: bool,
    backends: Option<&[BackendSpec]>,
) -> Result<()> {
    let ids: Vec<&str> = if id == "all" {
        ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let tables = generate_with(id, artifacts, quick, backends)?;
        for (i, t) in tables.iter().enumerate() {
            println!("{}", t.render());
            if let Some(dir) = csv_dir {
                let name = if tables.len() == 1 {
                    id.to_string()
                } else {
                    format!("{id}_{i}")
                };
                t.write_csv(dir, &name)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_non_artifact_report_generates() {
        for id in ALL_IDS {
            if id == "fig11" {
                continue; // needs artifacts + PJRT
            }
            let tables = generate(id, None, true).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert!(!tables.is_empty(), "{id} produced no tables");
            for t in tables {
                assert!(!t.rows.is_empty(), "{id} produced an empty table");
            }
        }
    }

    #[test]
    fn unknown_id_is_error() {
        assert!(generate("fig99", None, true).is_err());
    }
}
