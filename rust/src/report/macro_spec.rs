//! Render a compiled [`MacroSpec`] as block-level area/energy breakdown
//! tables — the human-readable face of `mcaimem compile --table`.
//!
//! Two tables:
//!
//! 1. **Structure** — what the compiler generated: bank organization,
//!    cell population and striping mask, decoder/mux fanout, refresh
//!    domains, row cycle and the derived totals.
//! 2. **Blocks** — every generated block with its instance count, area,
//!    share of the macro, and the energy rail it carries (static leakage
//!    on the array, per-byte access energy on the S/A and write-driver
//!    stripes, refresh power on the V_REF/FSM block, scrub energy on the
//!    ECC plane). Energy attribution is presentation: it reads the same
//!    [`EnergyCard`] the evaluator charges, it does not re-model it.

use crate::mem::compiler::MacroSpec;
use crate::mem::energy::EnergyCard;
use crate::util::table::{fnum, Table};
use crate::util::units::{si, to_um2};

/// Typical DNN-buffer ones-fraction used for the representative energy
/// column (the evaluator uses the workload's measured fractions; a static
/// table needs one number).
const ONES_FRAC: f64 = 0.5;

/// The block-level breakdown of one compiled macro.
pub fn breakdown(spec: &MacroSpec) -> Vec<Table> {
    let card = EnergyCard::from_macro(spec);

    let mut s = Table::new(
        &format!("Compiled macro — {} ({} B requested)", spec.point, spec.bytes),
        &["property", "value"],
    );
    s.row(vec![
        "organization".into(),
        format!("{} banks x {} rows x {} B ({} bit cols)", spec.banks, spec.rows, spec.row_bytes, spec.cols),
    ]);
    s.row(vec![
        "cells (SRAM / eDRAM)".into(),
        format!(
            "{} / {} of {} ({} eDRAM)",
            spec.cells_sram,
            spec.cells_edram,
            spec.cells_total,
            fnum(100.0 * spec.edram_frac(), 1) + " %"
        ),
    ]);
    s.row(vec![
        "SRAM stripe mask".into(),
        match spec.sram_mask {
            Some(m) => format!("{m:#04x} per byte"),
            None => "per-cell striping (non-tiling ratio)".into(),
        },
    ]);
    s.row(vec![
        "row decoder / column mux".into(),
        format!("{} address bits / {} select bits", spec.row_decoder_bits, spec.col_mux_bits),
    ]);
    s.row(vec![
        "refresh".into(),
        match spec.refresh_period_s {
            Some(t) if spec.refresh_domains > 0 => {
                format!("{} domains @ {}", spec.refresh_domains, si(t, "s"))
            }
            Some(t) => format!("gated (retention window {})", si(t, "s")),
            None => "none (pure SRAM)".into(),
        },
    ]);
    s.row(vec!["row cycle t_rc".into(), si(spec.t_rc_s, "s")]);
    s.row(vec!["access-energy scale".into(), fnum(spec.dyn_scale, 3)]);
    s.row(vec!["macro area".into(), format!("{} mm²", fnum(spec.area_m2 * 1e6, 4))]);

    let mut b = Table::new(
        "Block breakdown (bottom-up)",
        &["block", "count", "area (µm²)", "share", "energy rail"],
    );
    for blk in &spec.blocks {
        let rail = match blk.name {
            "bitcell_array" => {
                format!("static {} @ {:.0}% ones", si(card.static_power(spec.bytes, ONES_FRAC), "W"), ONES_FRAC * 100.0)
            }
            "sense_amps" => {
                format!("read {} / B", si(spec.dyn_scale * card.read_energy(1, ONES_FRAC), "J"))
            }
            "write_drivers" => {
                format!("write {} / B", si(spec.dyn_scale * card.write_energy(1, ONES_FRAC), "J"))
            }
            "vref_refresh_fsm" => match spec.refresh_period_s {
                Some(_) if spec.refresh_domains > 0 => {
                    format!("refresh {}", si(card.refresh_power(spec.bytes, ONES_FRAC), "W"))
                }
                _ => "refresh gated".into(),
            },
            "ecc_check_plane" => {
                format!("scrub {} / pass", si(card.ecc_scrub_energy(spec.bytes), "J"))
            }
            _ => "—".into(),
        };
        b.row(vec![
            blk.name.into(),
            blk.count.to_string(),
            fnum(to_um2(blk.area_m2), 1),
            fnum(100.0 * blk.area_m2 / spec.area_m2, 2) + " %",
            rail,
        ]);
    }
    vec![s, b]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::space::DesignPoint;
    use crate::mem::compiler::compile;
    use crate::util::units::MIB;

    #[test]
    fn breakdown_renders_every_block_and_the_shares_close() {
        let spec = compile(&DesignPoint::paper(), MIB).unwrap();
        let tables = breakdown(&spec);
        assert_eq!(tables.len(), 2);
        let blocks = &tables[1];
        assert_eq!(blocks.rows.len(), spec.blocks.len());
        let text = blocks.render();
        for name in ["bitcell_array", "sense_amps", "vref_refresh_fsm", "one_enh_encoder"] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        // shares re-sum to the whole macro
        let total: f64 = spec.blocks.iter().map(|b| 100.0 * b.area_m2 / spec.area_m2).sum();
        assert!((total - 100.0).abs() < 1e-9, "{total}");
        // the structure table names the striping mask and the refresh plan
        let s = tables[0].render();
        assert!(s.contains("0x80"), "{s}");
        assert!(s.contains("64 domains"), "{s}");
    }

    #[test]
    fn pure_sram_macro_reads_as_such() {
        let spec = compile(&DesignPoint { ratio: 0, ..DesignPoint::paper() }, MIB).unwrap();
        let tables = breakdown(&spec);
        let s = tables[0].render();
        assert!(s.contains("none (pure SRAM)"), "{s}");
        let b = tables[1].render();
        assert!(!b.contains("vref_refresh_fsm"), "{b}");
    }
}
