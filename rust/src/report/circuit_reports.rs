//! Circuit-level reports: Tables I–II, Figs. 1, 2, 5, 7, 9, 12, 13.

use std::path::Path;

use crate::circuit::flip_model::{FlipModel, VREF_CANDIDATES};
use crate::circuit::retention;
use crate::circuit::snm::{CellMismatch, SnmAnalysis, FS_CORNER};
use crate::circuit::sram6t::Sram6t;
use crate::circuit::{edram1t1c, edram2t, edram3t};
use crate::device::{StorageLeakage, TechNode};
use crate::encode::one_enhancement::{encode, ENCODER_COST_45NM};
use crate::encode::stats::{bit_histogram, resnet50_like_weights};
use crate::mem::area::{cell_area_rel, AreaModel};
use crate::mem::energy::EnergyCard;
use crate::mem::MemKind;
use crate::util::rng::Pcg64;
use crate::util::table::{fnum, Table};
use crate::util::units::{to_um2, to_us};

fn mc_n(quick: bool, full: usize) -> usize {
    if quick {
        (full / 20).max(500)
    } else {
        full
    }
}

/// Table I — eRAM comparison at 65 nm.
pub fn table1() -> Vec<Table> {
    let mut t = Table::new(
        "Table I — embedded-RAM comparison at 65nm CMOS (ratios vs 6T SRAM)",
        &["eRAM type", "Cell size", "Avg. static power", "Refresh", "Leakage", "Extra material"],
    );
    let rows: [(&str, f64, f64, &str, &str, &str); 4] = [
        ("SRAM (6T)", 1.0, 1.0, "No Ref.", "High", "No"),
        ("eDRAM (1T1C)", edram1t1c::AREA_REL, edram1t1c::STATIC_REL, "Low Freq.", "Low", "Yes"),
        ("Symmetric eDRAM (3T)", edram3t::AREA_REL, edram3t::STATIC_REL, "High Freq.", "Low", "No"),
        ("Asymmetric eDRAM (2T)", edram2t::CONV_AREA_REL, edram2t::CONV_STATIC_REL, "High Freq.", "Low", "No"),
    ];
    for (name, area, power, refresh, leak, mat) in rows {
        t.row(vec![
            name.into(),
            format!("{}x", fnum(area, 2)),
            format!("{}x", fnum(power, 2)),
            refresh.into(),
            leak.into(),
            mat.into(),
        ]);
    }
    vec![t]
}

/// Table II — 1 MB characterization at 45 nm.
pub fn table2() -> Vec<Table> {
    let mut t = Table::new(
        "Table II — characterization of 1MB designs at 45nm (min/max over data patterns)",
        &["eRAM type", "Static power (mW)", "Read (pJ/B)", "Write (pJ/B)", "Refresh period"],
    );
    for card in [EnergyCard::sram(), EnergyCard::edram2t(), EnergyCard::mcaimem_default()] {
        let (smin, smax, rmin, rmax, wmin, wmax) = card.table2_row();
        let (s, r, w) = if smin == smax {
            (fnum(smin, 2), fnum(rmin, 5), fnum(wmin, 5))
        } else {
            (
                format!("{} – {}", fnum(smin, 2), fnum(smax, 2)),
                format!("{} – {}", fnum(rmin, 5), fnum(rmax, 5)),
                format!("{} – {}", fnum(wmin, 5), fnum(wmax, 5)),
            )
        };
        let refresh = match card.refresh_period {
            None => "none".to_string(),
            Some(p) => format!("{} µs", fnum(to_us(p), 2)),
        };
        t.row(vec![card.kind.label().into(), s, r, w, refresh]);
    }
    vec![t]
}

/// Fig. 1 — Eyeriss breakdown + the headline summary.
pub fn fig1() -> Vec<Table> {
    let mut a = Table::new(
        "Fig. 1a — SRAM share of the Eyeriss chip [5]",
        &["resource", "SRAM share"],
    );
    a.row(vec!["chip area".into(), "79.2%".into()]);
    a.row(vec!["chip power".into(), "42.5%".into()]);

    let area = AreaModel::lp45();
    let reduction = area.mcaimem_reduction(crate::util::units::MIB);
    // idle-buffer power ratio at the encoded DNN operating point
    let sram = EnergyCard::sram();
    let ours = EnergyCard::mcaimem_default();
    let frac = 0.8; // encoded DNN ones fraction (Fig. 5)
    let p_sram = sram.static_power(crate::util::units::MIB, frac);
    let p_ours = ours.static_power(crate::util::units::MIB, frac)
        + ours.refresh_power(crate::util::units::MIB, frac);
    let mut b = Table::new(
        "Fig. 1b — MCAIMem headline vs 6T SRAM (this repo's models)",
        &["metric", "paper", "measured"],
    );
    b.row(vec![
        "area reduction".into(),
        "48%".into(),
        format!("{}%", fnum(reduction * 100.0, 1)),
    ]);
    b.row(vec![
        "buffer power ratio (idle, encoded data)".into(),
        "3.4x".into(),
        format!("{}x", fnum(p_sram / p_ours, 2)),
    ]);
    vec![a, b]
}

/// Fig. 2 — conventional 3T / 2T retention-time Monte-Carlo distributions.
pub fn fig2(quick: bool) -> Vec<Table> {
    let n = mc_n(quick, 100_000);
    let (b1, b0) = retention::retention_3t(0xF162, n);
    let d2 = retention::retention_2t_conventional(0xF162, n, 0.65);
    let mut t = Table::new(
        "Fig. 2 — gain-cell retention at 45nm LP, 85C, 0.65V read reference (MC)",
        &["cell / bit", "median (µs)", "p1 (µs)", "p99 (µs)", "sigma/median"],
    );
    for d in [&b1, &b0, &d2] {
        t.row(vec![
            d.label.clone(),
            fnum(to_us(d.summary.median), 3),
            fnum(to_us(d.summary.p01), 3),
            fnum(to_us(d.summary.p99), 3),
            fnum(d.summary.std / d.summary.median, 3),
        ]);
    }
    let mut h = Table::new(
        "Fig. 2 (2T bit-0 histogram series)",
        &["retention bin center (µs)", "density"],
    );
    for (c, dens) in d2.histogram.centers().iter().zip(d2.histogram.densities()) {
        h.row(vec![fnum(to_us(*c), 3), fnum(dens, 5)]);
    }
    vec![t, h]
}

/// Fig. 3b/5 — bit-position histogram of quantized weights pre/post encoder.
/// Uses the *actually trained* model weights when artifacts are present,
/// falling back to the ResNet-50-statistics generator.
pub fn fig5(artifacts: Option<&Path>) -> Vec<Table> {
    let (weights, source): (Vec<i8>, &str) = artifacts
        .and_then(|dir| {
            let a = crate::runtime::artifact::Artifacts::load(dir).ok()?;
            let mut all = Vec::new();
            for i in 0..a.layer_sizes.len() {
                all.extend(a.tensor(&format!("w{i}")).ok()?.as_i8().ok()?);
            }
            Some((all, "trained int8 model (artifacts)"))
        })
        .unwrap_or_else(|| {
            (resnet50_like_weights(0xF165, 500_000), "ResNet-50-statistics generator")
        });
    let before = bit_histogram(&weights);
    let after = bit_histogram(&encode(&weights));
    let mut t = Table::new(
        &format!("Fig. 5 — ones fraction per bit position, {source}"),
        &["bit position", "raw", "one-enhanced"],
    );
    for pos in (0..8).rev() {
        let name = if pos == 7 { "7 (sign, SRAM)".to_string() } else { format!("{pos} (eDRAM)") };
        t.row(vec![
            name,
            fnum(before.ones_frac[pos], 3),
            fnum(after.ones_frac[pos], 3),
        ]);
    }
    t.row(vec![
        "eDRAM planes mean".into(),
        fnum(before.edram_ones_frac(), 3),
        fnum(after.edram_ones_frac(), 3),
    ]);
    vec![t]
}

/// Fig. 7b — retention vs storage-node width.
pub fn fig7() -> Vec<Table> {
    let leak = StorageLeakage::calibrated(1.0);
    let mut t = Table::new(
        "Fig. 7b — bit-0 charge time 0.18V → 0.8V vs storage width (median cell, 85C)",
        &["width multiple", "charge time (µs)", "vs 1x"],
    );
    let base = leak.charge_time(0.8, 1.0, 85.0);
    for w in [1.0, 2.0, 3.0, 4.0] {
        let tt = leak.charge_time(0.8, w, 85.0);
        t.row(vec![
            fnum(w, 0),
            fnum(to_us(tt), 3),
            format!("{}x", fnum(tt / base, 2)),
        ]);
    }
    vec![t]
}

/// Fig. 9 — 6T SRAM SNM + write-yield vs word-line under-drive.
pub fn fig9(quick: bool) -> Vec<Table> {
    let tech = TechNode::lp45();
    let nominal = CellMismatch::default();
    let a_n = SnmAnalysis::new(&tech, Sram6t::conventional());
    let a_p = SnmAnalysis::new(&tech, Sram6t::mcaimem());
    let mut t = Table::new(
        "Fig. 9a — read SNM by access-transistor polarity (nominal, 25C)",
        &["access", "read SNM (mV)", "paper"],
    );
    t.row(vec![
        "NMOS".into(),
        fnum(a_n.read_snm(&nominal) * 1000.0, 1),
        "90 mV".into(),
    ]);
    t.row(vec![
        "PMOS".into(),
        fnum(a_p.read_snm(&nominal) * 1000.0, 1),
        "100 mV".into(),
    ]);

    let n = if quick { 200 } else { 1000 };
    let mut y = Table::new(
        &format!("Fig. 9b — write yield vs WL under-drive (FS corner, {n} MC samples, 25C)"),
        &["WL voltage (V)", "PMOS access yield", "NMOS access yield"],
    );
    let ap = SnmAnalysis::new(&tech, Sram6t::mcaimem()).at_corner(FS_CORNER);
    let an = SnmAnalysis::new(&tech, Sram6t::conventional()).at_corner(FS_CORNER);
    let mut rng = Pcg64::new(0xF169);
    let nmos_yield = an.write_yield(&mut rng, 0.05, tech.vdd, n);
    for wl in [0.0, -0.05, -0.10, -0.15, -0.20] {
        let py = ap.write_yield(&mut rng, 0.05, wl, n);
        y.row(vec![fnum(wl, 2), fnum(py, 3), fnum(nmos_yield, 3)]);
    }
    vec![t, y]
}

/// Fig. 12 — 0→1 flip probability vs access time per V_REF (model + MC).
pub fn fig12(quick: bool) -> Vec<Table> {
    let model = FlipModel::mcaimem_85c();
    let mut t = Table::new(
        "Fig. 12b — 0→1 flip probability vs access time (closed-form model, 85C)",
        &["access time (µs)", "VREF=0.5", "VREF=0.6", "VREF=0.7", "VREF=0.8"],
    );
    for i in 0..=20 {
        let time = i as f64 * 1e-6;
        let mut row = vec![fnum(to_us(time), 1)];
        for vref in VREF_CANDIDATES {
            row.push(fnum(model.flip_prob(time, vref), 4));
        }
        t.row(row);
    }
    let mut p = Table::new(
        "Fig. 12b — refresh period at the 1% DNN bound per V_REF",
        &["VREF (V)", "refresh period (µs)", "paper anchor"],
    );
    for vref in VREF_CANDIDATES {
        let period = model.refresh_period(vref, 0.01);
        let anchor = match vref {
            v if v == 0.5 => "1.3 µs",
            v if v == 0.8 => "12.57 µs",
            _ => "—",
        };
        p.row(vec![fnum(vref, 1), fnum(to_us(period), 2), anchor.into()]);
    }
    // MC cross-check (Fig. 12a methodology): empirical flip rates
    let n = mc_n(quick, 100_000);
    let times: Vec<f64> = (1..=8).map(|i| i as f64 * 2e-6).collect();
    let curves = retention::flip_curves_mc(0xF12A, n, &times, &[0.5, 0.8]);
    let mut mc = Table::new(
        &format!("Fig. 12a — Monte-Carlo cross-check ({n} samples/point, CVSA offset included)"),
        &["access time (µs)", "MC P(flip) @0.5V", "model @0.5V", "MC @0.8V", "model @0.8V"],
    );
    for (i, &time) in times.iter().enumerate() {
        mc.row(vec![
            fnum(to_us(time), 1),
            fnum(curves[0].1[i].1, 4),
            fnum(model.flip_prob(time, 0.5), 4),
            fnum(curves[1].1[i].1, 4),
            fnum(model.flip_prob(time, 0.8), 4),
        ]);
    }
    vec![t, p, mc]
}

/// Fig. 13 — 16 KB bank area comparison.
pub fn fig13() -> Vec<Table> {
    let m = AreaModel::lp45();
    let mut t = Table::new(
        "Fig. 13 — 16KB bank layout area (1MB = 64 banks)",
        &["design", "bank area (µm²)", "vs SRAM", "cell ratio"],
    );
    let sram = m.bank16k_area(MemKind::Sram6t);
    for kind in [MemKind::Sram6t, MemKind::Edram2t, MemKind::Mcaimem] {
        let a = m.bank16k_area(kind);
        t.row(vec![
            kind.label().into(),
            fnum(to_um2(a), 0),
            format!("{}%", fnum(a / sram * 100.0, 1)),
            format!("{}x", fnum(cell_area_rel(kind), 3)),
        ]);
    }
    let mut h = Table::new("Fig. 13 — headline", &["metric", "value"]);
    h.row(vec![
        "MCAIMem area reduction @16KB bank".into(),
        format!("{}%", fnum(m.mcaimem_reduction(16 * 1024) * 100.0, 1)),
    ]);
    h.row(vec![
        "encoder area overhead".into(),
        format!("{} µm²  ({}% of 108KB macro)", ENCODER_COST_45NM.area_um2, fnum(
            ENCODER_COST_45NM.area_um2 / to_um2(m.macro_area(MemKind::Mcaimem, 108 * 1024)) * 100.0,
            4
        )),
    ]);
    vec![t, h]
}
