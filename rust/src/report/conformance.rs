//! Conformance campaign report — the table `mcaimem conform` renders.
//!
//! One row per (backend, geometry): the generated op mix, whether the
//! backend replayed its own recorded trace exactly, whether the MCAIMem
//! specs matched the golden model ([`crate::sim::oracle`]) bit- and
//! meter-exactly, and — on failure — the size of the shrunk minimal
//! reproducing trace plus the first divergence. Failing minimal traces are
//! saved as JSON artifacts so CI uploads them and anyone can replay with
//! `mcaimem conform --replay <file>`.

use std::path::{Path, PathBuf};

use crate::mem::backend::BackendSpec;
use crate::sim::campaign::{self, CampaignConfig, SpecOutcome};
use crate::util::table::Table;
use crate::Result;

/// Run the campaign over `specs` and render the outcome table. Returns the
/// table, the raw outcomes, and whether everything passed.
pub fn conformance(
    specs: &[BackendSpec],
    cfg: &CampaignConfig,
) -> Result<(Table, Vec<SpecOutcome>, bool)> {
    let outcomes = campaign::run(specs, cfg)?;
    let mut t = Table::new(
        &format!(
            "conformance campaign — {} ops/run, seed {}, {} KB buffers (self-replay + golden-model oracle)",
            cfg.ops,
            cfg.seed,
            cfg.bytes / 1024
        ),
        &[
            "backend",
            "geometry",
            "stores",
            "loads",
            "ticks",
            "refreshes",
            "self-replay",
            "vs oracle",
            "failure",
        ],
    );
    let mut all_ok = true;
    for o in &outcomes {
        all_ok &= o.ok();
        let (s, l, k, r) = o.counts;
        let failure = match o.failures.first() {
            None => "—".to_string(),
            Some(f) => format!("{} (minimal {} ops)", f.divergence, f.minimal.entries.len()),
        };
        t.row(vec![
            o.spec.label(),
            o.geometry(),
            s.to_string(),
            l.to_string(),
            k.to_string(),
            r.to_string(),
            if o.self_replay_ok { "exact".into() } else { "DIVERGED".into() },
            match o.oracle_ok {
                None => "—".into(),
                Some(true) => "exact".into(),
                Some(false) => "DIVERGED".into(),
            },
            failure,
        ]);
    }
    Ok((t, outcomes, all_ok))
}

/// Machine-readable campaign report for `mcaimem conform --json`
/// (serde-free via [`crate::util::json`]): config echo, one record per
/// (backend, geometry) run with op counts and verdicts, and the overall
/// pass flag — what CI diffs instead of scraping the table.
pub fn outcomes_json(outcomes: &[SpecOutcome], cfg: &CampaignConfig) -> crate::util::json::Json {
    use crate::util::json::Json;
    let runs: Vec<Json> = outcomes
        .iter()
        .map(|o| {
            let (s, l, k, r) = o.counts;
            Json::obj(vec![
                ("backend", Json::Str(o.spec.to_string())),
                ("geometry", Json::Str(o.geometry().replace('×', "x"))),
                ("stores", Json::Num(s as f64)),
                ("loads", Json::Num(l as f64)),
                ("ticks", Json::Num(k as f64)),
                ("refreshes", Json::Num(r as f64)),
                ("self_replay_ok", Json::Bool(o.self_replay_ok)),
                (
                    "oracle_ok",
                    match o.oracle_ok {
                        None => Json::Null,
                        Some(b) => Json::Bool(b),
                    },
                ),
                (
                    "failures",
                    Json::Arr(
                        o.failures
                            .iter()
                            .map(|f| {
                                Json::obj(vec![
                                    ("stage", Json::Str(f.stage.to_string())),
                                    ("divergence", Json::Str(f.divergence.clone())),
                                    (
                                        "minimal_ops",
                                        Json::Num(f.minimal.entries.len() as f64),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("ops", Json::Num(cfg.ops as f64)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("bytes", Json::Num(cfg.bytes as f64)),
        ("shards", Json::Num(cfg.shards as f64)),
        ("ok", Json::Bool(outcomes.iter().all(|o| o.ok()))),
        ("runs", Json::Arr(runs)),
    ])
}

/// Save every failing minimal trace under `dir` as
/// `conformance_failure_<spec>_<geometry>_<stage>.json`. Returns the paths
/// written (empty when everything passed).
pub fn save_failures(outcomes: &[SpecOutcome], dir: &Path) -> Result<Vec<PathBuf>> {
    let mut written = Vec::new();
    for o in outcomes {
        for f in &o.failures {
            let name = format!(
                "conformance_failure_{}_{}_{}.json",
                o.spec.to_string().replace(['@', '.'], "_"),
                o.geometry().replace('×', "x").replace(' ', "-"),
                f.stage
            );
            let path = dir.join(name);
            f.minimal.save(&path)?;
            written.push(path);
        }
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_conformance_table_renders_green() {
        let cfg = CampaignConfig {
            ops: 80,
            seed: 3,
            bytes: 32 * 1024,
            shards: 2,
            shrink: false,
            faults: None,
        };
        let specs = [BackendSpec::Sram, BackendSpec::mcaimem_default()];
        let (table, outcomes, ok) = conformance(&specs, &cfg).unwrap();
        assert!(ok, "{outcomes:?}");
        assert_eq!(
            outcomes.len(),
            5,
            "flat + sharded per spec, plus one compiled-geometry pass for the MCAIMem spec"
        );
        assert_eq!(outcomes.iter().filter(|o| o.geom.is_some()).count(), 1);
        let rendered = table.render();
        assert!(rendered.contains("exact"), "{rendered}");
        assert!(!rendered.contains("DIVERGED"), "{rendered}");
        // nothing to save when green
        let dir = std::env::temp_dir();
        assert!(save_failures(&outcomes, &dir).unwrap().is_empty());

        // the --json report round-trips and carries the verdicts
        let j = crate::util::json::Json::parse(&outcomes_json(&outcomes, &cfg).to_pretty())
            .unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("seed").unwrap().as_usize(), Some(3));
        let runs = j.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), outcomes.len());
        for r in runs {
            assert_eq!(r.get("self_replay_ok").unwrap().as_bool(), Some(true));
            assert!(r.get("backend").unwrap().as_str().is_some());
        }
    }
}
