//! Fig. 11 — DNN accuracy under retention-error injection, with and
//! without the one-enhancement encoder.
//!
//! This is the experiment that runs the *real* three-layer stack: the AOT
//! HLO (L2 jax graph calling the L1 Pallas kernels) executes through PJRT
//! from Rust, with flip-candidate masks drawn per computation by the Rust
//! PCG64 (cumulative weight + activation injection, exactly the paper's
//! §IV-A protocol). The error-rate sweep is the paper's 1 %–25 %.

use std::path::Path;

use crate::mem::backend::BackendSpec;
use crate::runtime::executor::ModelRunner;
use crate::util::table::{fnum, Table};
use crate::Result;

/// The paper's injection sweep.
pub const ERROR_RATES: [f64; 6] = [0.01, 0.02, 0.05, 0.10, 0.15, 0.25];

pub fn fig11(artifacts: &Path, quick: bool) -> Result<Vec<Table>> {
    let mut runner = ModelRunner::new(artifacts)?;
    let batches = if quick { 2 } else { 8 };
    // an ideal (SRAM) buffer serves the clean baseline
    let clean = runner.accuracy(&BackendSpec::Sram, 0.0, batches, 1)?;

    let mut t = Table::new(
        &format!(
            "Fig. 11 — accuracy vs injected 0→1 flip rate (clean int8 acc {}, {} batches)",
            fnum(clean, 4),
            batches
        ),
        &["flip rate", "with one-enhancement", "without one-enhancement"],
    );
    for (i, &p) in ERROR_RATES.iter().enumerate() {
        let with = runner.accuracy(&BackendSpec::mcaimem_default(), p, batches, 100 + i as u64)?;
        let without = runner.accuracy(
            &BackendSpec::Mcaimem { vref: 0.8, encode: false, ecc: false },
            p,
            batches,
            200 + i as u64,
        )?;
        t.row(vec![
            format!("{}%", fnum(p * 100.0, 0)),
            fnum(with, 4),
            fnum(without, 4),
        ]);
    }
    Ok(vec![t])
}
