//! The `mcaimem explore` report: ASCII frontier table, the paper-point
//! verdict, and the machine-readable `frontier.json` artifact CI diffs.

use crate::dse::eval::{EvalCache, EvalContext, Objectives};
use crate::dse::pareto::{normalized_hypervolume, Frontier, FrontierDiff};
use crate::dse::search::SearchReport;
use crate::dse::space::DesignPoint;
use crate::util::json::Json;
use crate::util::table::{fnum, Table};
use crate::Result;

/// Everything one explore run produced, bundled for rendering/serializing.
pub struct ExploreOutcome {
    pub report: SearchReport,
    pub frontier: Frontier,
    /// Normalized hypervolume of the evaluated set (reference 1.1/dim).
    pub hypervolume: f64,
    /// The SRAM reference design and its objectives.
    pub sram: (DesignPoint, Objectives),
    /// The paper's 1S·7E@0.8 point — always evaluated (force-appended
    /// like the SRAM reference when the search skipped it).
    pub paper: Option<Objectives>,
    pub seed: u64,
    pub space_spec: String,
}

impl ExploreOutcome {
    /// Assemble the outcome from a finished search. The SRAM reference
    /// *and* the paper's 1S·7E@0.8 point are evaluated (through the same
    /// cache) even when the search didn't visit them — the baseline
    /// belongs on the chart, and the paper-point gate must always have a
    /// real verdict, including under pruning (halving) or subsampling
    /// (random) strategies that might otherwise skip the point.
    pub fn new(
        mut report: SearchReport,
        ctx: &EvalContext,
        cache: &EvalCache,
        seed: u64,
        space_spec: &str,
    ) -> Self {
        for anchor in [DesignPoint::sram_reference(), DesignPoint::paper()] {
            if !report.evaluated.iter().any(|(p, _)| *p == anchor) {
                let o = crate::dse::eval::evaluate_cached(&anchor, ctx, cache);
                report.evals += 1;
                report.evaluated.push((anchor, o));
            }
        }
        let sram = report
            .evaluated
            .iter()
            .find(|(p, _)| *p == DesignPoint::sram_reference())
            .map(|(p, o)| (p.clone(), *o))
            .expect("sram reference just inserted");
        let paper = report
            .evaluated
            .iter()
            .find(|(p, _)| *p == DesignPoint::paper())
            .map(|(_, o)| *o);
        let vectors: Vec<Vec<f64>> = report
            .evaluated
            .iter()
            .map(|(_, o)| o.vector().to_vec())
            .collect();
        let frontier = Frontier::from_evaluated(&report.evaluated);
        let hypervolume = normalized_hypervolume(&vectors);
        ExploreOutcome {
            report,
            frontier,
            hypervolume,
            sram,
            paper,
            seed,
            space_spec: space_spec.to_string(),
        }
    }

    /// Area reduction of the paper point vs the SRAM reference (0.48 ≈ the
    /// headline), if the paper point was evaluated.
    pub fn paper_area_reduction(&self) -> Option<f64> {
        self.paper.map(|o| 1.0 - o.area_mm2 / self.sram.1.area_mm2)
    }

    /// Energy-per-inference gain of the paper point vs SRAM (≈3.4×).
    pub fn paper_energy_gain(&self) -> Option<f64> {
        self.paper.map(|o| self.sram.1.energy_j / o.energy_j)
    }

    /// The acceptance verdict: the paper point is on the frontier AND
    /// dominates SRAM by ≥40 % area and ≥3× energy. `None` when the paper
    /// point wasn't part of this run's space.
    pub fn paper_ok(&self) -> Option<bool> {
        self.paper?;
        let on_frontier = self.frontier.contains(&DesignPoint::paper());
        let area_ok = self.paper_area_reduction().unwrap_or(0.0) >= 0.40;
        let energy_ok = self.paper_energy_gain().unwrap_or(0.0) >= 3.0;
        Some(on_frontier && area_ok && energy_ok)
    }

    /// The frontier table plus the summary lines `mcaimem explore` prints.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Pareto frontier — {} points evaluated ({} strategy, seed {}), {} on the frontier, hypervolume {}",
                self.report.evals,
                self.report.strategy,
                self.seed,
                self.frontier.points.len(),
                fnum(self.hypervolume, 4),
            ),
            &[
                "design",
                "area (mm²)",
                "energy/inf (µJ)",
                "latency (ms)",
                "refresh (mW/MB-scale)",
                "E|err|/byte",
                "vs SRAM",
            ],
        );
        let sram_o = &self.sram.1;
        for fp in &self.frontier.points {
            let o = &fp.objectives;
            let vs = format!(
                "{}% area, {}x energy",
                fnum((1.0 - o.area_mm2 / sram_o.area_mm2) * 100.0, 1),
                fnum(sram_o.energy_j / o.energy_j.max(1e-30), 2)
            );
            t.row(vec![
                fp.point.short_label(),
                fnum(o.area_mm2, 3),
                fnum(o.energy_j * 1e6, 2),
                fnum(o.latency_s * 1e3, 3),
                fnum(o.refresh_w * 1e3, 3),
                fnum(o.err_proxy, 3),
                vs,
            ]);
        }
        t
    }

    /// The machine-readable artifact (`--json`): run metadata, the SRAM
    /// anchor, the paper-point verdict and the full frontier, all in
    /// deterministic order — same seed ⇒ byte-identical file.
    pub fn to_json(&self) -> Json {
        let paper_json = match self.paper {
            None => Json::Null,
            Some(o) => Json::obj(vec![
                ("objectives", o.to_json()),
                (
                    "on_frontier",
                    Json::Bool(self.frontier.contains(&DesignPoint::paper())),
                ),
                (
                    "area_reduction_vs_sram",
                    Json::Num(self.paper_area_reduction().unwrap_or(0.0)),
                ),
                (
                    "energy_gain_vs_sram",
                    Json::Num(self.paper_energy_gain().unwrap_or(0.0)),
                ),
                ("ok", Json::Bool(self.paper_ok().unwrap_or(false))),
            ]),
        };
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("seed", Json::Num(self.seed as f64)),
            ("strategy", Json::Str(self.report.strategy.to_string())),
            ("space", Json::Str(self.space_spec.clone())),
            ("points_evaluated", Json::Num(self.report.evals as f64)),
            ("hypervolume", Json::Num(self.hypervolume)),
            (
                "sram_reference",
                Json::obj(vec![
                    ("point", Json::Str(self.sram.0.to_string())),
                    ("objectives", self.sram.1.to_json()),
                ]),
            ),
            ("paper_point", paper_json),
            ("frontier", self.frontier.to_json()),
        ])
    }
}

/// Load a frontier back out of an explore artifact (for `--diff`).
pub fn frontier_from_artifact(text: &str) -> Result<Frontier> {
    let j = Json::parse(text)?;
    Frontier::from_json(j.get("frontier")?)
}

/// Render a frontier diff for the terminal.
pub fn render_diff(d: &FrontierDiff) -> String {
    if d.is_unchanged() {
        return format!("frontier unchanged ({} points)", d.kept.len());
    }
    let mut s = format!(
        "frontier changed: {} kept, {} added, {} removed\n",
        d.kept.len(),
        d.added.len(),
        d.removed.len()
    );
    for p in &d.added {
        s.push_str(&format!("  + {p}\n"));
    }
    for p in &d.removed {
        s.push_str(&format!("  - {p}\n"));
    }
    s.pop();
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::search::{ExhaustiveGrid, SearchStrategy};
    use crate::dse::space::Space;
    use crate::scalesim::{network, AcceleratorConfig};

    fn outcome() -> ExploreOutcome {
        // ResNet50 on Eyeriss is the explore default — the workload the
        // paper-point verdict (≥40 % area, ≥3× energy vs SRAM) is pinned on
        let ctx = EvalContext::new(network::resnet50(), AcceleratorConfig::eyeriss(), 11, 512);
        let cache = EvalCache::new();
        let space = Space::parse("ratio=3|7|15,vref=0.7|0.8|0.9").unwrap();
        let report = ExhaustiveGrid.run(&space, &ctx, &cache).unwrap();
        ExploreOutcome::new(report, &ctx, &cache, 11, &space.spec)
    }

    #[test]
    fn outcome_renders_and_serializes() {
        let o = outcome();
        assert!(o.hypervolume > 0.0);
        let t = o.table();
        assert!(!t.rows.is_empty());
        assert!(t.render().contains("1S7E@0.8"), "{}", t.render());
        let json = o.to_json().to_pretty();
        let f = frontier_from_artifact(&json).unwrap();
        assert_eq!(f.points.len(), o.frontier.points.len());
    }

    #[test]
    fn paper_point_verdict_holds_on_the_small_grid() {
        let o = outcome();
        assert_eq!(o.paper_ok(), Some(true), "area {:?}, energy {:?}, frontier {}",
            o.paper_area_reduction(), o.paper_energy_gain(),
            o.frontier.contains(&DesignPoint::paper()));
    }

    #[test]
    fn diff_rendering() {
        let o = outcome();
        let d = crate::dse::pareto::diff(&o.frontier, &o.frontier);
        assert!(render_diff(&d).contains("unchanged"));
        let empty = Frontier::default();
        let d = crate::dse::pareto::diff(&o.frontier, &empty);
        let s = render_diff(&d);
        assert!(s.contains("removed") && s.contains("- ratio="));
    }
}
