//! The memory macro compiler: a [`DesignPoint`] compiles to a structural
//! [`MacroSpec`] whose area/energy/timing are derived **bottom-up** from
//! per-block component models, replacing the hand-calibrated periphery law
//! of [`super::area`] with a generated netlist summary.
//!
//! ## What gets generated
//!
//! * **Bitcell array** — `banks × rows × row_bytes` tiled by the
//!   [`sram_plane_mask`] striping law: one 6T SRAM cell per `(ratio + 1)`
//!   cells anchored at the sign bit, the rest widened 2T eDRAM.
//! * **Row stripe** — word-line drivers plus a row decoder sized from the
//!   integer log₂ fanout (`ceil_log2(rows)` address bits); decoders deeper
//!   than the reference bank pay an excess-levels term.
//! * **Column stripe** — one CVSA sense amp and one write driver per
//!   column, plus the column mux (sized from the column/IO-word fanout)
//!   with its own excess-levels term.
//! * **Conditional periphery** — the V_REF generator + refresh FSM and the
//!   one-enhancement encoder/decoder exist only when the composition has
//!   eDRAM cells (`ratio > 0`); the encoder block is emitted whenever the
//!   reference machinery is (the `enc=off` ablation *bypasses* it, it does
//!   not remove the silicon).
//! * **ECC check plane** — SECDED check columns (one check byte per 8 data
//!   bytes) when `ecc=on` and there are eDRAM bits to protect.
//! * **Refresh domains** — one per bank (banks refresh one row each in
//!   parallel) under the periodic policy; zero when refresh is gated or
//!   the array is pure SRAM.
//!
//! ## The calibration contract
//!
//! At the reference bank ([`geometry::REF_ROWS`] × [`geometry::REF_COLS`],
//! i.e. 256 rows × 64 bytes) the bottom-up composition reproduces the
//! analytic cards **bit-exactly** — pinned by test at the paper point
//! (N = 7). This is engineered, not approximated:
//!
//! * the array block uses the identical
//!   [`AreaModel::array_area_mixed`] expression;
//! * the stripe split always computes the *major* share (≥ ½) by
//!   multiplication and the minor by subtraction, so by Sterbenz's lemma
//!   the two stripes sum back to the periphery total exactly;
//! * sub-splits within a stripe are dyadic (halves and quarters), and the
//!   final fold re-associates in an order where every partial sum is
//!   exact;
//! * decoder/mux depth uses integer `ceil_log2` (never `f64::log2`, which
//!   is not guaranteed correctly rounded), so the excess-levels terms are
//!   exactly `0.0` at the reference depths (8 row bits, 9 column bits).
//!
//! Off the reference shape the compiled macro *diverges on purpose*: extra
//! decoder/mux levels cost area ([`EXCESS_K`] per doubling beyond the
//! reference depth) and deeper rows stretch the row cycle
//! ([`T_RC_SLOPE`]) — structure the interpolated analytic law cannot see.
//! That divergence is what `mcaimem explore --compiled` surfaces as a
//! frontier diff. Both excess terms are second-order by construction
//! (`EXCESS_K` is small enough that amortization still wins everywhere in
//! the legal space at realistic aspect ratios), so compiled area stays
//! monotone in rows, columns and eDRAM share — property-tested below.
//!
//! ## Serialization
//!
//! [`MacroSpec::to_json`] emits a deterministic netlist-summary artifact
//! (version-tagged, keys sorted, floats in shortest-round-trip form);
//! [`MacroSpec::from_json`] re-*compiles* from the header and bit-compares
//! the derived totals, so a stale artifact from a different calibration is
//! rejected instead of silently trusted, and re-serialization is
//! byte-identical.

use anyhow::{bail, ensure};

use super::area::AreaModel;
use super::energy::EnergyCard;
use super::geometry::{self, PERIPHERY_FRAC, REF_COLS, REF_ROWS};
use super::mcaimem::sram_plane_mask;
use crate::dse::eval::T_RC;
use crate::dse::space::{DesignPoint, RefreshPolicy};
use crate::util::json::Json;
use crate::Result;

/// Netlist-summary artifact version (see [`MacroSpec::to_json`]).
pub const MACRO_SPEC_VERSION: u64 = 1;

/// Relative area cost of one extra decoder/mux level beyond the reference
/// depth, charged against the stripe that owns the structure. Small enough
/// that bank-growth amortization dominates across the legal design space
/// (monotonicity is property-tested), large enough that off-reference
/// geometries measurably diverge from the analytic interpolation.
pub const EXCESS_K: f64 = 0.12;

/// Row-cycle stretch per extra row-decoder level beyond the reference
/// depth: deeper word-line fanout slows the activation edge.
pub const T_RC_SLOPE: f64 = 0.15;

/// Integer ceil(log₂ n): the address-bit / tree-depth count of an n-way
/// structure. Exact by construction (unlike `f64::log2`, which libm does
/// not guarantee correctly rounded even at powers of two).
#[inline]
pub fn ceil_log2(n: usize) -> u32 {
    usize::BITS - (n.max(1) - 1).leading_zeros()
}

/// Split `total` into (major, minor) shares with `major_share ∈ [0.5, 1]`.
/// The major part is computed by multiplication, the minor by subtraction:
/// `major = fl(total·s)` lands in `[total/2, total]`, so by Sterbenz's
/// lemma the subtraction is exact and `major + minor == total` bit-for-bit.
#[inline]
fn split(total: f64, major_share: f64) -> (f64, f64) {
    debug_assert!((0.5..=1.0).contains(&major_share));
    let major = total * major_share;
    (major, total - major)
}

/// One generated periphery/array block of the macro.
#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    pub name: &'static str,
    /// Instance count (cells, drivers, decoders, …).
    pub count: u64,
    /// Total area of all instances (m²).
    pub area_m2: f64,
}

/// The compiled structural macro: what the compiler generated, with its
/// bottom-up derived area/timing totals. Energy attribution per block is
/// presentation (see [`crate::report::macro_spec`]); the access/refresh
/// energy *card* derives via [`EnergyCard::from_macro`].
#[derive(Clone, Debug, PartialEq)]
pub struct MacroSpec {
    /// The design point this macro realizes.
    pub point: DesignPoint,
    /// Requested capacity (bytes); the array rounds up to whole banks.
    pub bytes: usize,
    pub banks: usize,
    pub rows: usize,
    pub row_bytes: usize,
    /// Bit columns per bank (`row_bytes × 8`).
    pub cols: usize,
    /// Cell counts over the rounded (whole-bank) capacity.
    pub cells_total: u64,
    pub cells_sram: u64,
    pub cells_edram: u64,
    /// The per-byte SRAM stripe mask, when the ratio tiles a byte
    /// (N ∈ {0, 1, 3, 7}); non-tiling ratios stripe per-cell only.
    pub sram_mask: Option<u8>,
    /// Row-decoder address bits (`ceil_log2(rows)`).
    pub row_decoder_bits: u32,
    /// Column-mux select bits down to the 8-byte IO word.
    pub col_mux_bits: u32,
    /// One CVSA per column per bank.
    pub sense_amps: u64,
    /// One write driver per column per bank.
    pub write_drivers: u64,
    /// SECDED check-plane columns (0 when ECC is off or vacuous).
    pub ecc_check_cols: u64,
    /// Per-bank refresh domains under the periodic policy (0 otherwise).
    pub refresh_domains: usize,
    /// The whole-array refresh period the V_REF choice buys (s); `None`
    /// for a pure-SRAM composition.
    pub refresh_period_s: Option<f64>,
    /// The generated block list, array first (presentation order).
    pub blocks: Vec<Block>,
    /// Bottom-up macro area (m²), shard periphery excluded (the evaluator
    /// charges sharding on top, exactly like the analytic path).
    pub area_m2: f64,
    /// Row cycle time (s) after the decoder-depth stretch.
    pub t_rc_s: f64,
    /// Per-access dynamic-energy scale vs the reference bank
    /// ([`geometry::access_scale`]).
    pub dyn_scale: f64,
}

/// Compile `point` into a structural macro of `bytes` requested capacity.
/// Rejects out-of-space points (the same bounds the DSE grammar enforces)
/// and degenerate capacities.
pub fn compile(point: &DesignPoint, bytes: usize) -> Result<MacroSpec> {
    point.validate()?;
    ensure!(bytes > 0, "cannot compile a zero-byte macro");
    let rows = point.rows;
    let row_bytes = point.row_bytes;
    let cols = row_bytes * 8;
    let bank_bytes = rows * row_bytes;
    let banks = bytes.div_ceil(bank_bytes);
    let ratio = point.ratio;

    // -- bitcell array: the same per-bit composition the analytic model
    // charges (identical expression ⇒ identical bits), tiled by the
    // sram_plane_mask striping law
    let model = AreaModel::lp45();
    let array = model.array_area_mixed(bytes, ratio);
    let cells_total = (banks * bank_bytes) as u64 * 8;
    let cells_sram = cells_total.div_ceil(ratio as u64 + 1);
    let cells_edram = cells_total - cells_sram;
    let sram_mask = (ratio <= 7 && 8 % (ratio + 1) == 0).then(|| sram_plane_mask(ratio));

    // -- periphery budget at this bank shape, split into the two stripes.
    // The row stripe (WL drivers + row decoder) instantiates per row, so
    // its per-bit weight is 1/cols; the column stripe (S/A, write drivers,
    // mux) instantiates per column, weight 1/rows. Always split major-first
    // so the stripes re-sum exactly (Sterbenz).
    let periph0 = array * (PERIPHERY_FRAC * geometry::periphery_factor(rows, row_bytes));
    let inv_rows = 1.0 / rows as f64; // column-stripe weight
    let inv_cols = 1.0 / cols as f64; // row-stripe weight
    let denom = inv_rows + inv_cols;
    let col_share = inv_rows / denom;
    let (col_stripe, row_stripe) = if col_share >= 0.5 {
        split(periph0, col_share)
    } else {
        let (r, c) = split(periph0, inv_cols / denom);
        (c, r)
    };

    // row stripe: ¾ word-line drivers, ¼ decoder tree (dyadic — exact)
    let wl = row_stripe * 0.75;
    let dec = row_stripe - wl;
    // column stripe: ½ sense amps, then the rest halves into write
    // drivers and the column mux (all dyadic — exact)
    let sa = col_stripe * 0.5;
    let rest = col_stripe - sa;
    let wr = rest * 0.5;
    let mux = rest - wr;

    // excess tree levels beyond the reference depths (integer log₂, so
    // exactly 0.0 at the 256-row / 512-column calibration bank)
    let row_bits = ceil_log2(rows);
    let col_bits = ceil_log2(cols);
    let dec_excess =
        row_stripe * (EXCESS_K * (row_bits as f64 / ceil_log2(REF_ROWS) as f64 - 1.0));
    let mux_excess =
        col_stripe * (EXCESS_K * (col_bits as f64 / ceil_log2(REF_COLS) as f64 - 1.0));

    // -- conditional periphery: reference machinery exists iff there are
    // eDRAM cells. ⅔ V_REF DAC + refresh FSM, the rest encoder/decoder
    // (major-first again, so the pair re-sums exactly).
    let extras = AreaModel::mixed_extras(ratio);
    let (vref_fsm, encoder) = split(extras, 2.0 / 3.0);

    // -- ECC check plane: vacuous without eDRAM bits (same gate as the
    // evaluator and the backend factory)
    let ecc_active = point.ecc && ratio > 0;
    let ecc_area = if ecc_active { model.ecc_overhead(bytes) } else { 0.0 };
    let ecc_check_cols = if ecc_active { (banks * cols) as u64 / 8 } else { 0 };

    // -- bottom-up total. The fold order is chosen so every partial sum is
    // exact where the analytic law has no corresponding rounding step:
    // each stripe re-sums to its split total, the stripes re-sum to
    // periph0, and the excess terms add exact zeros at the reference bank
    // — reproducing fl(fl(array + periph) + extras) + ecc bit-for-bit.
    let row_total = wl + dec;
    let col_total = sa + (wr + mux);
    let periph_total = (row_total + col_total) + dec_excess + mux_excess;
    let area_m2 = ((array + periph_total) + (vref_fsm + encoder)) + ecc_area;

    // -- timing: deeper row decoders stretch the activation edge
    let t_rc_s =
        T_RC * (1.0 + T_RC_SLOPE * (row_bits as f64 / ceil_log2(REF_ROWS) as f64 - 1.0));

    // -- refresh organization rides the energy card's V_REF law
    let card = EnergyCard::mcaimem_ratio(point.vref, ratio);
    let refreshed = point.refresh == RefreshPolicy::Periodic && card.refresh_period.is_some();

    let mut blocks = vec![
        Block { name: "bitcell_array", count: cells_total, area_m2: array },
        Block { name: "wordline_drivers", count: (banks * rows) as u64, area_m2: wl },
        Block { name: "row_decoder", count: banks as u64, area_m2: dec + dec_excess },
        Block { name: "sense_amps", count: (banks * cols) as u64, area_m2: sa },
        Block { name: "write_drivers", count: (banks * cols) as u64, area_m2: wr },
        Block { name: "column_mux", count: banks as u64, area_m2: mux + mux_excess },
    ];
    if ratio > 0 {
        blocks.push(Block { name: "vref_refresh_fsm", count: 1, area_m2: vref_fsm });
        blocks.push(Block { name: "one_enh_encoder", count: 1, area_m2: encoder });
    }
    if ecc_active {
        blocks.push(Block { name: "ecc_check_plane", count: ecc_check_cols, area_m2: ecc_area });
    }

    Ok(MacroSpec {
        point: point.clone(),
        bytes,
        banks,
        rows,
        row_bytes,
        cols,
        cells_total,
        cells_sram,
        cells_edram,
        sram_mask,
        row_decoder_bits: row_bits,
        col_mux_bits: ceil_log2(row_bytes.div_ceil(8)),
        sense_amps: (banks * cols) as u64,
        write_drivers: (banks * cols) as u64,
        ecc_check_cols,
        refresh_domains: if refreshed { banks } else { 0 },
        refresh_period_s: card.refresh_period,
        blocks,
        area_m2,
        t_rc_s,
        dyn_scale: geometry::access_scale(rows, row_bytes),
    })
}

impl MacroSpec {
    /// The deterministic netlist-summary artifact: version-tagged, keys
    /// sorted (the JSON layer stores objects in a BTreeMap), floats in
    /// shortest-round-trip form — same point + bytes ⇒ byte-identical
    /// file, and re-serializing a parsed artifact is byte-identical too.
    pub fn to_json(&self) -> Json {
        let blocks: Vec<Json> = self
            .blocks
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("name", Json::Str(b.name.into())),
                    ("count", Json::Num(b.count as f64)),
                    ("area_m2", Json::Num(b.area_m2)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(MACRO_SPEC_VERSION as f64)),
            ("point", Json::Str(self.point.to_string())),
            ("bytes", Json::Num(self.bytes as f64)),
            ("banks", Json::Num(self.banks as f64)),
            ("rows", Json::Num(self.rows as f64)),
            ("row_bytes", Json::Num(self.row_bytes as f64)),
            ("cols", Json::Num(self.cols as f64)),
            ("cells_total", Json::Num(self.cells_total as f64)),
            ("cells_sram", Json::Num(self.cells_sram as f64)),
            ("cells_edram", Json::Num(self.cells_edram as f64)),
            (
                "sram_mask",
                match self.sram_mask {
                    Some(m) => Json::Num(m as f64),
                    None => Json::Null,
                },
            ),
            ("row_decoder_bits", Json::Num(self.row_decoder_bits as f64)),
            ("col_mux_bits", Json::Num(self.col_mux_bits as f64)),
            ("sense_amps", Json::Num(self.sense_amps as f64)),
            ("write_drivers", Json::Num(self.write_drivers as f64)),
            ("ecc_check_cols", Json::Num(self.ecc_check_cols as f64)),
            ("refresh_domains", Json::Num(self.refresh_domains as f64)),
            (
                "refresh_period_s",
                match self.refresh_period_s {
                    Some(t) => Json::Num(t),
                    None => Json::Null,
                },
            ),
            ("blocks", Json::Arr(blocks)),
            ("area_m2", Json::Num(self.area_m2)),
            ("t_rc_s", Json::Num(self.t_rc_s)),
            ("dyn_scale", Json::Num(self.dyn_scale)),
        ])
    }

    /// Parse an artifact by **re-compiling** its header (point + bytes)
    /// and bit-comparing the derived totals against the stored ones: an
    /// artifact produced under a different component-model calibration is
    /// rejected, never silently trusted. The round trip is therefore
    /// byte-identical by construction.
    pub fn from_json(j: &Json) -> Result<MacroSpec> {
        let version = j.get("version")?.as_f64().unwrap_or(0.0) as u64;
        if version != MACRO_SPEC_VERSION {
            bail!("macro spec version {version} (this build compiles version {MACRO_SPEC_VERSION})");
        }
        let point: DesignPoint = j.get("point")?.as_str().unwrap_or("").parse()?;
        let bytes = j
            .get("bytes")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("macro spec `bytes` is not an integer"))?;
        let spec = compile(&point, bytes)?;
        for (name, stored, derived) in [
            ("area_m2", j.get("area_m2")?.as_f64(), spec.area_m2),
            ("t_rc_s", j.get("t_rc_s")?.as_f64(), spec.t_rc_s),
        ] {
            match stored {
                Some(v) if v.to_bits() == derived.to_bits() => {}
                _ => bail!(
                    "macro spec `{name}` {stored:?} does not match the recompiled value \
                     {derived} — artifact from a different component-model calibration"
                ),
            }
        }
        Ok(spec)
    }

    /// Write the artifact, creating missing parent directories.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        crate::util::json::save_pretty(path, &self.to_json())
    }

    /// eDRAM share of the cell population (0.0 for pure SRAM).
    pub fn edram_frac(&self) -> f64 {
        self.cells_edram as f64 / self.cells_total.max(1) as f64
    }
}

impl EnergyCard {
    /// The Table II energy card of a compiled macro. The card composes the
    /// same per-plane component models the compiler's blocks are built
    /// from (SRAM plane at density `1/(N+1)`, widened-2T planes at the
    /// compiled V_REF), so this is exactly the ratio-parameterized
    /// composition law — bit-identical to the analytic card by the
    /// calibration contract.
    pub fn from_macro(spec: &MacroSpec) -> EnergyCard {
        EnergyCard::mcaimem_ratio(spec.point.vref, spec.point.ratio)
    }
}

impl AreaModel {
    /// The component-model basis a compiled macro is characterized on
    /// (lp45 — the node every per-block model in this repo is drawn at).
    /// The spec's own `area_m2` is the bottom-up total; this model is for
    /// cross-checking individual blocks against the analytic expressions.
    pub fn from_macro(_spec: &MacroSpec) -> AreaModel {
        AreaModel::lp45()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::space::Space;
    use crate::mem::MemKind;
    use crate::util::units::MIB;

    fn paper_at(rows: usize, row_bytes: usize) -> DesignPoint {
        DesignPoint { rows, row_bytes, ..DesignPoint::paper() }
    }

    #[test]
    fn calibration_point_reproduces_the_analytic_cards_bit_exactly() {
        // the contract the whole subsystem hangs on: at N=7, 256×512 the
        // bottom-up composition is the analytic model, to the last bit
        let model = AreaModel::lp45();
        for bytes in [16 * 1024, 108 * 1024, MIB] {
            let spec = compile(&DesignPoint::paper(), bytes).unwrap();
            let analytic = model.macro_area_banked(bytes, 7, 256, 64) + 0.0;
            assert_eq!(spec.area_m2.to_bits(), analytic.to_bits(), "bytes={bytes}");
            assert_eq!(spec.t_rc_s.to_bits(), T_RC.to_bits());
            assert_eq!(spec.dyn_scale.to_bits(), 1.0f64.to_bits());

            // the derived energy card is the analytic card, field by field
            // (EnergyCard has no PartialEq; Asym does)
            let card = EnergyCard::from_macro(&spec);
            let legacy = EnergyCard::mcaimem_ratio(0.8, 7);
            assert_eq!(card.static_w_per_mb, legacy.static_w_per_mb);
            assert_eq!(card.read_j_per_byte, legacy.read_j_per_byte);
            assert_eq!(card.write_j_per_byte, legacy.write_j_per_byte);
            assert_eq!(card.refresh_period, legacy.refresh_period);
            assert_eq!(card.edram_frac, legacy.edram_frac);

            // and the Table I 48 % headline falls out of the compiled total
            let sram = model.macro_area(MemKind::Sram6t, bytes);
            let red = 1.0 - spec.area_m2 / sram;
            assert!((red - 0.48).abs() < 0.005, "reduction={red} at {bytes}B");
        }
        // same contract with the ECC plane on top
        let ecc = DesignPoint { ecc: true, ..DesignPoint::paper() };
        let spec = compile(&ecc, MIB).unwrap();
        let analytic = model.macro_area_banked(MIB, 7, 256, 64) + model.ecc_overhead(MIB);
        assert_eq!(spec.area_m2.to_bits(), analytic.to_bits());
        assert_eq!(spec.ecc_check_cols, (spec.banks * spec.cols) as u64 / 8);
    }

    #[test]
    fn structure_matches_the_striping_and_fanout_laws() {
        let spec = compile(&DesignPoint::paper(), MIB).unwrap();
        assert_eq!(spec.banks, 64);
        assert_eq!((spec.rows, spec.row_bytes, spec.cols), (256, 64, 512));
        assert_eq!(spec.cells_total, 64 * 16 * 1024 * 8);
        assert_eq!(spec.cells_sram, spec.cells_total / 8, "1 SRAM cell per byte at N=7");
        assert_eq!(spec.sram_mask, Some(0x80), "the sign plane");
        assert_eq!(spec.row_decoder_bits, 8);
        assert_eq!(spec.sense_amps, (64 * 512) as u64);
        assert_eq!(spec.write_drivers, spec.sense_amps);
        assert_eq!(spec.refresh_domains, 64, "one per bank under periodic refresh");
        assert!(spec.refresh_period_s.is_some());
        // non-tiling ratios stripe per-cell, no per-byte mask
        let spec5 = compile(&DesignPoint { ratio: 5, ..DesignPoint::paper() }, MIB).unwrap();
        assert_eq!(spec5.sram_mask, None);
        // pure SRAM: no reference machinery, no refresh, no eDRAM cells
        let spec0 = compile(&DesignPoint { ratio: 0, ..DesignPoint::paper() }, MIB).unwrap();
        assert_eq!(spec0.cells_edram, 0);
        assert_eq!(spec0.refresh_domains, 0);
        assert_eq!(spec0.refresh_period_s, None);
        assert!(spec0.blocks.iter().all(|b| b.name != "vref_refresh_fsm"));
    }

    #[test]
    fn every_point_of_the_default_grid_compiles() {
        let space = Space::parse(Space::DEFAULT).unwrap();
        let points = space.expand().unwrap();
        assert_eq!(points.len(), 420, "the default grid the issue pins");
        for p in &points {
            let spec = compile(p, MIB).unwrap_or_else(|e| panic!("{p}: {e}"));
            assert!(spec.area_m2.is_finite() && spec.area_m2 > 0.0, "{p}");
            assert!(spec.t_rc_s >= T_RC, "{p}");
            assert_eq!(spec.cells_sram + spec.cells_edram, spec.cells_total, "{p}");
        }
    }

    #[test]
    fn compiled_area_is_monotone_in_rows_cols_and_edram_share() {
        // area falls as banks grow (periphery amortizes faster than the
        // excess decoder levels accrue) and as the eDRAM share rises
        let mut last = f64::INFINITY;
        for rows in [64, 128, 256, 512, 1024, 2048] {
            let a = compile(&paper_at(rows, 64), MIB).unwrap().area_m2;
            assert!(a < last, "area must fall with rows: {rows}");
            last = a;
        }
        let mut last = f64::INFINITY;
        for row_bytes in [16, 32, 64, 128, 256] {
            let a = compile(&paper_at(256, row_bytes), MIB).unwrap().area_m2;
            assert!(a < last, "area must fall with cols: {row_bytes}");
            last = a;
        }
        let mut last = f64::INFINITY;
        for ratio in 0..=15u32 {
            let a = compile(&DesignPoint { ratio, ..DesignPoint::paper() }, MIB).unwrap().area_m2;
            assert!(a < last, "area must fall with eDRAM share: {ratio}");
            last = a;
        }
    }

    #[test]
    fn compiled_access_energy_is_monotone_in_rows_cols_and_edram_share() {
        // longer lines cost access energy; more eDRAM cells cost less
        let e = |p: &DesignPoint| {
            let spec = compile(p, MIB).unwrap();
            spec.dyn_scale * EnergyCard::from_macro(&spec).read_energy(1024, 0.5)
        };
        let mut last = 0.0;
        for rows in [64, 128, 256, 512, 1024, 2048] {
            let v = e(&paper_at(rows, 64));
            assert!(v > last, "access energy must rise with rows: {rows}");
            last = v;
        }
        let mut last = 0.0;
        for row_bytes in [16, 32, 64, 128, 256] {
            let v = e(&paper_at(256, row_bytes));
            assert!(v > last, "access energy must rise with cols: {row_bytes}");
            last = v;
        }
        let mut last = f64::INFINITY;
        for ratio in 0..=15u32 {
            let v = e(&DesignPoint { ratio, ..DesignPoint::paper() });
            assert!(v < last, "access energy must fall with eDRAM share: {ratio}");
            last = v;
        }
    }

    #[test]
    fn off_reference_geometries_diverge_from_the_analytic_law() {
        // the divergence --compiled frontier diffs surface: at 512×64 the
        // 9th decoder level costs area and stretches t_rc — structure the
        // interpolated analytic law cannot see
        let model = AreaModel::lp45();
        let spec = compile(&paper_at(512, 64), MIB).unwrap();
        let analytic = model.macro_area_banked(MIB, 7, 512, 64);
        assert!(spec.area_m2 > analytic, "{} vs {analytic}", spec.area_m2);
        assert!(spec.t_rc_s > T_RC);
        // but still below the reference bank's area: amortization dominates
        assert!(spec.area_m2 < compile(&DesignPoint::paper(), MIB).unwrap().area_m2);
    }

    #[test]
    fn blocks_account_for_the_whole_macro() {
        // the block list is the area: its sum re-folds to the total within
        // float re-association slack
        for p in [
            DesignPoint::paper(),
            paper_at(512, 128),
            DesignPoint { ratio: 0, ..DesignPoint::paper() },
            DesignPoint { ecc: true, ..DesignPoint::paper() },
        ] {
            let spec = compile(&p, MIB).unwrap();
            let sum: f64 = spec.blocks.iter().map(|b| b.area_m2).sum();
            assert!(
                (sum / spec.area_m2 - 1.0).abs() < 1e-12,
                "{p}: blocks {sum} vs total {}",
                spec.area_m2
            );
        }
    }

    #[test]
    fn json_artifact_roundtrips_byte_identically() {
        let spec = compile(&DesignPoint::paper(), MIB).unwrap();
        let first = spec.to_json().to_pretty();
        let back = MacroSpec::from_json(&Json::parse(&first).unwrap()).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.to_json().to_pretty(), first, "byte-identical re-serialization");

        // determinism across independent compiles
        let again = compile(&DesignPoint::paper(), MIB).unwrap().to_json().to_pretty();
        assert_eq!(again, first);

        // a tampered total is a calibration mismatch, not a trusted value
        let mut j = Json::parse(&first).unwrap();
        if let Json::Obj(o) = &mut j {
            o.insert("area_m2".into(), Json::Num(1.0));
        }
        let err = MacroSpec::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("calibration"), "{err}");
        // and a future version is rejected outright
        let mut j = Json::parse(&first).unwrap();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::Num(99.0));
        }
        assert!(MacroSpec::from_json(&j).is_err());
    }

    #[test]
    fn compiler_rejects_out_of_space_points() {
        assert!(compile(&DesignPoint { ratio: 99, ..DesignPoint::paper() }, MIB).is_err());
        assert!(compile(&DesignPoint { rows: 5, ..DesignPoint::paper() }, MIB).is_err());
        assert!(compile(&DesignPoint::paper(), 0).is_err());
    }
}
