//! MRAM-class on-chip-buffer baselines: STT-MRAM and SOT-MRAM with a
//! retention knob.
//!
//! The two MRAM co-design papers in PAPERS.md quantify the same trade the
//! MCAIMem paper argues from: magnetic tunnel junctions read fast and pack
//! small, but *writing* one means flipping a free layer whose thermal
//! stability factor Δ also sets how long it retains data. Both papers'
//! co-optimization lever is to **relax retention** — an on-chip buffer
//! only needs its data to live for milliseconds, not ten years — which
//! shrinks the critical switching current and with it write energy and
//! write latency, roughly in proportion to Δ:
//!
//! * **STT-MRAM** (arxiv 2104.02199): the write current passes *through*
//!   the junction — ~10 ns pulses and tens of pJ/byte at the 10-year
//!   corner, the classic slow/hungry NVM write.
//! * **SOT-MRAM** (arxiv 2303.12310): a separate spin-orbit-torque write
//!   line decouples the read and write paths — ~1.5 ns writes at a few
//!   pJ/byte nominal, converging toward SRAM-class writes once retention
//!   is relaxed.
//!
//! Δ scales with the ln of the retention target (`Δ = ln(t_ret/τ₀)`,
//! attempt period τ₀ ≈ 1 ns), so the knob is logarithmic: ten *orders of
//! magnitude* of retention buy ~2.5× on the write rail. Like RRAM, both
//! are non-volatile — zero standby power, no refresh — and charge their
//! programming latency through `EnergyMeter.busy_s`.

use crate::mem::MemKind;
use crate::util::units::PICO;

/// Attempt period τ₀ of the thermal-activation retention law (s).
pub const TAU0_S: f64 = 1e-9;
/// Nominal (spec-default) retention target: 10 years, the archival corner
/// both papers start from before relaxing it.
pub const RET_NOMINAL_S: f64 = 3.156e8;
/// Shortest sensible retention target (s): below ~1 µs the junction no
/// longer holds data across a refresh-free buffer residency at all.
pub const RET_MIN_S: f64 = 1e-6;

/// Thermal-stability scale factor for a retention target: `Δ(t)/Δ(nominal)`
/// with `Δ(t) = ln(t/τ₀)`. 1.0 at the 10-year corner, ~0.34 at 1 ms.
pub fn retention_scale(retention_s: f64) -> f64 {
    (retention_s / TAU0_S).ln() / (RET_NOMINAL_S / TAU0_S).ln()
}

/// MRAM per-access energy/latency card (per byte), STT or SOT flavoured.
#[derive(Clone, Copy, Debug)]
pub struct MramCard {
    pub kind: MemKind,
    pub read_j_per_byte: f64,
    pub write_j_per_byte: f64,
    pub read_latency_ns: f64,
    pub write_latency_ns: f64,
    /// The retention target this card was scaled to (s).
    pub retention_s: f64,
}

impl MramCard {
    /// STT-MRAM after the 2104.02199-class reporting: SRAM-like reads, a
    /// through-junction write path that needs ~10 ns and ~20 pJ/byte at
    /// the 10-year corner.
    pub fn stt(retention_s: f64) -> Self {
        Self::scaled(MemKind::Sttmram, 2.4, 19.2, 3.0, 10.0, retention_s)
    }

    /// SOT-MRAM after the 2303.12310-class reporting: the separate
    /// spin-orbit write line cuts both the pulse width and the energy —
    /// ~1.5 ns and ~5 pJ/byte nominal.
    pub fn sot(retention_s: f64) -> Self {
        Self::scaled(MemKind::Sotmram, 1.6, 4.8, 2.0, 1.5, retention_s)
    }

    fn scaled(
        kind: MemKind,
        read_pj: f64,
        write_pj_nominal: f64,
        read_ns: f64,
        write_ns_nominal: f64,
        retention_s: f64,
    ) -> Self {
        let s = retention_scale(retention_s);
        MramCard {
            kind,
            read_j_per_byte: read_pj * PICO,
            write_j_per_byte: write_pj_nominal * PICO * s,
            read_latency_ns: read_ns,
            write_latency_ns: write_ns_nominal * s,
            retention_s,
        }
    }

    /// Read energy (J) for `bytes`.
    pub fn read_energy(&self, bytes: usize) -> f64 {
        self.read_j_per_byte * bytes as f64
    }

    /// Write energy (J) for `bytes`.
    pub fn write_energy(&self, bytes: usize) -> f64 {
        self.write_j_per_byte * bytes as f64
    }

    /// Non-volatile: no refresh, no standby power.
    pub fn static_power(&self) -> f64 {
        0.0
    }

    /// Write-to-read energy asymmetry — the quantity the retention knob
    /// exists to shrink.
    pub fn write_read_ratio(&self) -> f64 {
        self.write_j_per_byte / self.read_j_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retention_knob_is_logarithmic_and_normalized() {
        assert!((retention_scale(RET_NOMINAL_S) - 1.0).abs() < 1e-12);
        let ms = retention_scale(1e-3);
        assert!(ms > 0.3 && ms < 0.4, "1 ms ≈ 0.34×: {ms}");
        // monotone in the target
        assert!(retention_scale(1.0) > ms);
        assert!(retention_scale(RET_MIN_S) < ms);
        assert!(retention_scale(RET_MIN_S) > 0.0);
    }

    #[test]
    fn sot_beats_stt_on_the_write_rail() {
        let stt = MramCard::stt(RET_NOMINAL_S);
        let sot = MramCard::sot(RET_NOMINAL_S);
        assert!(sot.write_j_per_byte < stt.write_j_per_byte / 3.0);
        assert!(sot.write_latency_ns < stt.write_latency_ns / 5.0);
        // both still write-asymmetric at the archival corner
        assert!(stt.write_read_ratio() > 5.0);
        assert!(sot.write_read_ratio() > 2.0);
    }

    #[test]
    fn relaxed_retention_cuts_write_cost_not_read() {
        let archival = MramCard::sot(RET_NOMINAL_S);
        let relaxed = MramCard::sot(1e-3);
        assert!(relaxed.write_j_per_byte < 0.4 * archival.write_j_per_byte);
        assert!(relaxed.write_latency_ns < 0.4 * archival.write_latency_ns);
        assert_eq!(relaxed.read_j_per_byte, archival.read_j_per_byte);
        assert_eq!(relaxed.read_latency_ns, archival.read_latency_ns);
        assert_eq!(relaxed.static_power(), 0.0);
    }
}
