//! The memory subsystem: area/energy characterization, bank organization,
//! refresh + V_REF control, the functional mixed-cell memory, the baseline
//! buffer designs, and the **unified backend API** that lets every consumer
//! treat them interchangeably.
//!
//! Two levels of naming, one spec:
//!
//! * [`MemKind`] — the *circuit-level characterization key*: which Table
//!   I/II row, which cell layout. Used by the [`area`] and [`energy`] cards.
//! * [`backend::BackendSpec`] — the *system-level spec* (`"sram"`,
//!   `"edram2t"`, `"rram"`, `"mcaimem@0.8"`, `"mcaimem@0.7-noenc"`): the
//!   one parseable type the CLI, the buffer manager, the inference server,
//!   the closed-form evaluator and the report sweeps all accept. It maps
//!   onto `MemKind` via [`backend::BackendSpec::kind`].
//!
//! Modules:
//!
//! * [`backend`] — the [`backend::MemoryBackend`] trait
//!   (`store`/`load`/`tick`/`refresh_due`/`meter`/`energy_card`/`area`/
//!   `label`), the `BackendSpec` grammar, and the
//!   `build(spec, bytes, seed)` factory producing any buffer design behind
//!   one device API.
//! * [`area`] — parametric layout-area model (Fig. 13, Table I ratios, the
//!   48 % headline).
//! * [`energy`] — Table II characterization cards and the 1:7 composition
//!   law; data-value-dependent static/read/write energy.
//! * [`bank`] — 16 KB bank geometry; 1 MB = 64 banks (Fig. 13 caption).
//! * [`bitplane`] — SWAR 8×64 bit-matrix transpose powering the
//!   word-parallel access path of [`mcaimem`].
//! * [`compiler`] — the macro compiler: a [`crate::dse::DesignPoint`]
//!   compiles to a structural [`compiler::MacroSpec`] (tiled bitcell array,
//!   sized decoders, S/A stripe, conditional V_REF/encoder/ECC periphery,
//!   refresh domains) whose area/energy/timing are derived bottom-up from
//!   per-block component models — bit-identical to the analytic cards at
//!   the calibration bank.
//! * [`geometry`] — the single source of truth for the 256 × 512 bank-shape
//!   calibration point (periphery and access-energy scaling laws).
//! * [`ecc`] — the SECDED check-byte plane specification shared by the
//!   functional array and the golden oracle (`mcaimem@V+ecc` specs).
//! * [`refresh`] — the global periodic row-refresh controller (§III-C).
//! * [`vref`] — the reference-voltage controller and its refresh-period
//!   lever (§IV-B).
//! * [`mcaimem`] — the *functional* mixed-cell memory: real bytes, real
//!   bit-planes, physical 0→1 flips on the eDRAM plane, refresh-by-read.
//! * [`rram`] — the non-volatile on-chip-buffer baseline of Fig. 15b.
//! * [`mram`] — the STT/SOT-MRAM cards with the retention-relaxation knob
//!   (the two MRAM co-design papers' lever: shorter retention ⇒ cheaper,
//!   faster writes).
//! * [`sharded`] — N independently-clocked bank shards of any backend
//!   behind one device API: striped addresses, merged meters, staggered
//!   refresh (the serving tier's banked buffer).
//! * [`tiered`] — the two-level hybrid: a small SRAM write-back buffer in
//!   front of any slow-write backend (`tiered=sram:32k+sotmram`), behind
//!   the same device API.
//!
//! See EXPERIMENTS.md §Backends for the spec grammar, the trait contract
//! and the functional-vs-analytic table.

pub mod area;
pub mod backend;
pub mod bank;
pub mod bitplane;
pub mod compiler;
pub mod ecc;
pub mod energy;
pub mod geometry;
pub mod mcaimem;
pub mod mram;
pub mod refresh;
pub mod rram;
pub mod sharded;
pub mod tiered;
pub mod vref;

pub use backend::{build, BackendSpec, Builder, MemoryBackend, SpecError};
pub use sharded::ShardedBackend;
pub use tiered::TieredBackend;

/// The embedded-memory kinds the paper compares — the circuit-level
/// characterization key (see [`backend::BackendSpec`] for the system-level
/// spec that selects a runnable backend).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemKind {
    Sram6t,
    Edram1t1c,
    Edram3t,
    Edram2t,
    Mcaimem,
    Rram,
    Sttmram,
    Sotmram,
}

impl MemKind {
    pub fn label(&self) -> &'static str {
        match self {
            MemKind::Sram6t => "SRAM",
            MemKind::Edram1t1c => "eDRAM (1T1C)",
            MemKind::Edram3t => "Symmetric eDRAM (3T)",
            MemKind::Edram2t => "Asymmetric eDRAM (2T)",
            MemKind::Mcaimem => "MCAIMem",
            MemKind::Rram => "RRAM",
            MemKind::Sttmram => "STT-MRAM",
            MemKind::Sotmram => "SOT-MRAM",
        }
    }
}
