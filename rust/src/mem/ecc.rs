//! SECDED check-byte plane for the eDRAM-mapped bits (PR 6, §Faults).
//!
//! The paper's mixed cell trades SRAM's "never decays" for area; the
//! protection story that makes the trade credible end-to-end is a standard
//! single-error-correct / double-error-detect plane over each 64-bit data
//! word, scrubbed on the refresh cadence the array already pays for
//! (§III-C refresh-by-read): the CVSA pass senses the row anyway, so the
//! scrub costs only the check-plane read (1 check byte per 8 data bytes)
//! plus a correction write-back when a syndrome fires.
//!
//! The code here is the *specification* shared by the functional array
//! ([`super::mcaimem::MixedCellMemory`]) and the golden model
//! ([`crate::sim::oracle`]): both must compute bit-identical check bytes
//! and apply bit-identical corrections for the conformance campaigns to
//! stay meaningful under ECC.
//!
//! Construction: each of the 64 data-bit positions `i` carries the 7-bit
//! nonzero label `i + 1`; the check byte is the XOR-fold of the labels of
//! the word's set bits (bits 6..0) plus the word's overall parity (bit 7).
//! The check plane itself is modeled as 6T SRAM cells (it protects the
//! decaying plane, so it must not decay) — its 12.5 % cell overhead is
//! charged through [`super::area::AreaModel::ecc_overhead`] and its scrub
//! energy through [`super::energy::EnergyCard::ecc_scrub_energy`].
//!
//! * single bit-error in the data word: parity mismatches and the syndrome
//!   is the flipped bit's label → corrected;
//! * double error: parity matches but the syndrome is nonzero → detected,
//!   not corrected (left for the differential oracle to agree on);
//! * check bits never err (SRAM plane).

/// Bytes of data covered by one check byte (a 64-bit word).
pub const WORD_BYTES: usize = 8;

/// SECDED check byte for one 64-bit data word: low 7 bits are the XOR-fold
/// of label `i + 1` over the word's set bit positions, bit 7 is the word's
/// overall parity.
#[inline]
pub fn check_byte(word: u64) -> u8 {
    let mut syn = 0u8;
    let mut w = word;
    while w != 0 {
        let i = w.trailing_zeros() as u8;
        syn ^= i + 1;
        w &= w - 1;
    }
    (syn & 0x7f) | (((word.count_ones() as u8) & 1) << 7)
}

/// Diagnosis of one stored word against its check byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Syndrome {
    /// Word and check byte agree.
    Clean,
    /// Exactly one data bit flipped; the payload is the bit index (0..64)
    /// to flip back.
    Correct(u8),
    /// Multi-bit damage (even parity with a nonzero syndrome, or an
    /// out-of-range label): detected, not correctable.
    Detect,
}

/// Diagnose a stored word against the check byte recorded at store time.
#[inline]
pub fn diagnose(stored: u64, check: u8) -> Syndrome {
    let s = check ^ check_byte(stored);
    if s == 0 {
        return Syndrome::Clean;
    }
    let parity_flipped = s & 0x80 != 0;
    let label = s & 0x7f;
    if parity_flipped && (1..=64).contains(&label) {
        Syndrome::Correct(label - 1)
    } else {
        Syndrome::Detect
    }
}

/// Scrub one stored word: return the corrected word (and the corrected bit
/// index) for a single-bit error, or `None` when the word is clean or the
/// damage is uncorrectable.
#[inline]
pub fn scrub_word(stored: u64, check: u8) -> Option<(u64, u8)> {
    match diagnose(stored, check) {
        Syndrome::Correct(bit) => Some((stored ^ (1u64 << bit), bit)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_words_diagnose_clean() {
        for w in [0u64, u64::MAX, 0xdead_beef_0bad_f00d, 1, 1 << 63] {
            assert_eq!(diagnose(w, check_byte(w)), Syndrome::Clean, "{w:#x}");
            assert_eq!(scrub_word(w, check_byte(w)), None);
        }
    }

    #[test]
    fn every_single_bit_error_is_corrected() {
        for w in [0u64, u64::MAX, 0x0123_4567_89ab_cdef, 0x8000_0000_0000_0001] {
            let c = check_byte(w);
            for bit in 0..64u8 {
                let damaged = w ^ (1u64 << bit);
                assert_eq!(diagnose(damaged, c), Syndrome::Correct(bit), "{w:#x} bit {bit}");
                let (fixed, b) = scrub_word(damaged, c).unwrap();
                assert_eq!(fixed, w);
                assert_eq!(b, bit);
            }
        }
    }

    #[test]
    fn double_errors_detect_not_correct() {
        let w = 0x0f0f_1234_5678_9abcu64;
        let c = check_byte(w);
        for (a, b) in [(0u8, 1u8), (3, 40), (62, 63), (7, 56)] {
            let damaged = w ^ (1u64 << a) ^ (1u64 << b);
            assert_eq!(diagnose(damaged, c), Syndrome::Detect, "bits {a},{b}");
            assert_eq!(scrub_word(damaged, c), None);
        }
    }

    #[test]
    fn labels_are_distinct_and_nonzero() {
        // the correction map is injective: 64 distinct nonzero labels
        let mut seen = [false; 128];
        for i in 0..64usize {
            let label = check_byte(1u64 << i) & 0x7f;
            assert_ne!(label, 0, "bit {i}");
            assert!(!seen[label as usize], "bit {i} collides");
            seen[label as usize] = true;
        }
    }

    #[test]
    fn check_byte_is_linear_in_xor() {
        // check(a ^ b) == check(a) ^ check(b): the property the syndrome
        // computation relies on
        for (a, b) in [(0x1u64, 0x2u64), (0xffff, 0xff00), (u64::MAX, 0x5555_5555_5555_5555)] {
            assert_eq!(check_byte(a ^ b), check_byte(a) ^ check_byte(b));
        }
    }
}
