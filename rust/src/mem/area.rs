//! Layout-area model (Table I ratios, Fig. 13 bank layouts, the 48 %
//! headline).
//!
//! Cell areas come from the circuit layer ([`crate::circuit`]); this module
//! composes them into arrays, banks and macros with a peripheral-overhead
//! factor (decoders, sense amps, write drivers, control). The paper's 48 %
//! reduction is a *cell-dominated* comparison of equal-capacity 16 KB banks,
//! so the peripheral factor is applied symmetrically; MCAIMem's extras
//! (reference-voltage + refresh controller, one-enhancement encoder) are
//! charged explicitly and shown to be negligible, as in §III-A1.

use super::MemKind;
use crate::circuit::{edram1t1c, edram2t, edram3t, sram6t};
use crate::device::TechNode;
use crate::encode::one_enhancement::ENCODER_COST_45NM;

/// Fraction of a memory macro spent on peripheral circuitry (row/col
/// decoders, S/A stripe, write drivers, timing). Representative of compiled
/// SRAM macros at this capacity.
pub const PERIPHERY_FRAC: f64 = 0.25;

/// Relative cell area (vs 6T SRAM = 1.0) for each comparable kind.
pub fn cell_area_rel(kind: MemKind) -> f64 {
    match kind {
        MemKind::Sram6t => 1.0,
        MemKind::Edram1t1c => edram1t1c::AREA_REL,
        MemKind::Edram3t => edram3t::AREA_REL,
        MemKind::Edram2t => edram2t::CONV_AREA_REL,
        // per byte: 1 SRAM + 7 widened 2T cells, averaged per bit
        MemKind::Mcaimem => {
            (1.0 + 7.0 * edram2t::MCAIMEM_AREA_REL) / 8.0
        }
        // RRAM crossbar bit-cell (4F² ideal, ~0.1× SRAM with select device)
        MemKind::Rram => 0.10,
    }
}

/// Area model for a memory macro of `bytes` capacity on `tech`.
#[derive(Clone, Debug)]
pub struct AreaModel {
    pub tech: TechNode,
}

impl AreaModel {
    pub fn lp45() -> Self {
        AreaModel { tech: TechNode::lp45() }
    }

    pub fn lp65() -> Self {
        AreaModel { tech: TechNode::lp65() }
    }

    /// Area of the cell array only (m²).
    pub fn array_area(&self, kind: MemKind, bytes: usize) -> f64 {
        let sram_cell = sram6t::AREA_F2 * self.tech.f2_area;
        (bytes * 8) as f64 * cell_area_rel(kind) * sram_cell
    }

    /// Full macro area including periphery and, for MCAIMem, the encoder +
    /// V_REF/refresh controller overhead (m²).
    pub fn macro_area(&self, kind: MemKind, bytes: usize) -> f64 {
        let array = self.array_area(kind, bytes);
        let periph = array * PERIPHERY_FRAC;
        let extras = match kind {
            MemKind::Mcaimem => {
                // encoder/decoder (35.2 µm² per macro) + V_REF DAC & refresh
                // FSM (charged at 2× the encoder as a conservative bound)
                3.0 * ENCODER_COST_45NM.area_um2 * 1e-12
            }
            _ => 0.0,
        };
        array + periph + extras
    }

    /// The Fig. 13 comparison: area of a 16 KB bank.
    pub fn bank16k_area(&self, kind: MemKind) -> f64 {
        self.macro_area(kind, 16 * 1024)
    }

    /// Area reduction of MCAIMem vs SRAM at equal capacity — the headline.
    pub fn mcaimem_reduction(&self, bytes: usize) -> f64 {
        1.0 - self.macro_area(MemKind::Mcaimem, bytes) / self.macro_area(MemKind::Sram6t, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MIB;

    #[test]
    fn headline_48pct_reduction() {
        let m = AreaModel::lp45();
        for bytes in [16 * 1024, MIB] {
            let red = m.mcaimem_reduction(bytes);
            assert!((red - 0.48).abs() < 0.005, "reduction={red} at {bytes}B");
        }
    }

    #[test]
    fn table1_cell_ordering() {
        // 1T1C < 3T < 2T < MCAIMem-mixed < SRAM
        let order = [
            MemKind::Edram1t1c,
            MemKind::Edram3t,
            MemKind::Edram2t,
            MemKind::Mcaimem,
            MemKind::Sram6t,
        ];
        for w in order.windows(2) {
            assert!(
                cell_area_rel(w[0]) < cell_area_rel(w[1]),
                "{:?} < {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn encoder_area_negligible() {
        let m = AreaModel::lp45();
        let with = m.macro_area(MemKind::Mcaimem, 108 * 1024);
        let array = m.array_area(MemKind::Mcaimem, 108 * 1024) * (1.0 + PERIPHERY_FRAC);
        let overhead = (with - array) / with;
        // paper §III-A1 quotes 0.004 % against its (larger) SRAM-referenced
        // macro; on our tighter layout model the bound is still ≤0.1 %
        assert!(overhead < 1e-3, "overhead={overhead}");
    }

    #[test]
    fn area_scales_linearly_with_capacity() {
        let m = AreaModel::lp45();
        let a1 = m.array_area(MemKind::Sram6t, 16 * 1024);
        let a64 = m.array_area(MemKind::Sram6t, MIB);
        assert!((a64 / a1 - 64.0).abs() < 1e-9);
    }

    #[test]
    fn sram_1mb_macro_is_milli_mm2_scale() {
        // sanity: 1 MB of 0.324 µm² cells ≈ 2.7 mm² array + periphery
        let m = AreaModel::lp45();
        let a = m.macro_area(MemKind::Sram6t, MIB);
        let mm2 = a / 1e-6;
        assert!(mm2 > 2.0 && mm2 < 5.0, "area={mm2} mm²");
    }
}
