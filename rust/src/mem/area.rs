//! Layout-area model (Table I ratios, Fig. 13 bank layouts, the 48 %
//! headline).
//!
//! Cell areas come from the circuit layer ([`crate::circuit`]); this module
//! composes them into arrays, banks and macros with a peripheral-overhead
//! factor (decoders, sense amps, write drivers, control). The paper's 48 %
//! reduction is a *cell-dominated* comparison of equal-capacity 16 KB banks,
//! so the peripheral factor is applied symmetrically; MCAIMem's extras
//! (reference-voltage + refresh controller, one-enhancement encoder) are
//! charged explicitly and shown to be negligible, as in §III-A1.
//!
//! ## The mixed-cell ratio as a parameter
//!
//! The paper fixes the composition at **1S·7E** (one 6T SRAM cell per seven
//! widened 2T eDRAM cells — one byte, sign bit in SRAM). The design-space
//! explorer ([`crate::dse`]) sweeps the ratio **1S·NE for N ∈ 0..=15**, so
//! the area model takes N as a parameter: [`mixed_cell_area_rel`],
//! [`AreaModel::array_area_mixed`], [`AreaModel::macro_area_mixed`]. The
//! fixed-kind entry points delegate to N = 7 and are bit-identical to the
//! pre-parameterized model; N = 0 degenerates to pure SRAM (and matches the
//! SRAM macro exactly — no encoder/V_REF extras without eDRAM cells).
//!
//! [`AreaModel::macro_area_banked`] additionally exposes the bank geometry
//! (rows × row-bytes): periphery is split into row circuitry (word-line
//! drivers + row decoder, ∝ rows) and column circuitry (S/A stripe, write
//! drivers, column mux, ∝ columns), so per-bit periphery goes as
//! `1/cols + 1/rows` — normalized to [`PERIPHERY_FRAC`] at the paper's
//! 256 × 64 B bank. Squarer, larger banks amortize periphery; skewed or
//! small banks pay for it. (The energy cost of longer lines is the
//! evaluator's side of the trade — see `dse::eval`.)

use super::MemKind;
use crate::circuit::{edram1t1c, edram2t, edram3t, sram6t};
use crate::device::TechNode;
use crate::encode::one_enhancement::ENCODER_COST_45NM;

// The calibration constants moved to the shared [`super::geometry`] module
// (one source of truth for this model, `dse::eval` and `mem::compiler`);
// re-exported here so existing call sites keep their paths.
pub use super::geometry::{PERIPHERY_FRAC, REF_COLS, REF_ROWS};

/// Relative cell area (vs 6T SRAM = 1.0) of the 1S·NE mixed composition:
/// one 6T SRAM cell per `n` widened 2T eDRAM cells, averaged per bit.
/// `n = 7` is the paper's cell; `n = 0` is pure SRAM (rel = 1.0).
pub fn mixed_cell_area_rel(n: u32) -> f64 {
    let n = n as f64;
    (1.0 + n * edram2t::MCAIMEM_AREA_REL) / (n + 1.0)
}

/// Relative cell area (vs 6T SRAM = 1.0) for each comparable kind.
pub fn cell_area_rel(kind: MemKind) -> f64 {
    match kind {
        MemKind::Sram6t => 1.0,
        MemKind::Edram1t1c => edram1t1c::AREA_REL,
        MemKind::Edram3t => edram3t::AREA_REL,
        MemKind::Edram2t => edram2t::CONV_AREA_REL,
        // per byte: 1 SRAM + 7 widened 2T cells, averaged per bit
        MemKind::Mcaimem => mixed_cell_area_rel(7),
        // RRAM crossbar bit-cell (4F² ideal, ~0.1× SRAM with select device)
        MemKind::Rram => 0.10,
        // 1T1MTJ STT cell (~25 F² with the write-current-sized access
        // transistor) — the density pitch of arxiv 2104.02199
        MemKind::Sttmram => 0.17,
        // SOT cell pays a second (write-line) transistor over STT
        MemKind::Sotmram => 0.24,
    }
}

/// Area model for a memory macro of `bytes` capacity on `tech`.
#[derive(Clone, Debug)]
pub struct AreaModel {
    pub tech: TechNode,
}

impl AreaModel {
    pub fn lp45() -> Self {
        AreaModel { tech: TechNode::lp45() }
    }

    pub fn lp65() -> Self {
        AreaModel { tech: TechNode::lp65() }
    }

    /// Area of the cell array only (m²).
    pub fn array_area(&self, kind: MemKind, bytes: usize) -> f64 {
        let sram_cell = sram6t::AREA_F2 * self.tech.f2_area;
        (bytes * 8) as f64 * cell_area_rel(kind) * sram_cell
    }

    /// Cell-array area (m²) of a 1S·NE mixed macro of `bytes` capacity.
    pub fn array_area_mixed(&self, bytes: usize, ratio: u32) -> f64 {
        let sram_cell = sram6t::AREA_F2 * self.tech.f2_area;
        (bytes * 8) as f64 * mixed_cell_area_rel(ratio) * sram_cell
    }

    /// The encoder + V_REF DAC + refresh-FSM extras charged to a mixed
    /// macro (m²): encoder/decoder (35.2 µm² per macro) plus V_REF DAC &
    /// refresh FSM at 2× the encoder as a conservative bound. Zero for a
    /// pure-SRAM composition (`ratio == 0`): no eDRAM cells means no
    /// reference voltage, no refresh and nothing to encode for.
    pub(crate) fn mixed_extras(ratio: u32) -> f64 {
        if ratio == 0 {
            0.0
        } else {
            3.0 * ENCODER_COST_45NM.area_um2 * 1e-12
        }
    }

    /// Full macro area including periphery and, for MCAIMem, the encoder +
    /// V_REF/refresh controller overhead (m²).
    pub fn macro_area(&self, kind: MemKind, bytes: usize) -> f64 {
        let array = self.array_area(kind, bytes);
        let periph = array * PERIPHERY_FRAC;
        let extras = match kind {
            MemKind::Mcaimem => Self::mixed_extras(7),
            _ => 0.0,
        };
        array + periph + extras
    }

    /// Full 1S·NE mixed-macro area (m²) at the paper's reference bank
    /// geometry. `ratio = 7` is bit-identical to
    /// `macro_area(MemKind::Mcaimem, bytes)`; `ratio = 0` to
    /// `macro_area(MemKind::Sram6t, bytes)`.
    pub fn macro_area_mixed(&self, bytes: usize, ratio: u32) -> f64 {
        self.macro_area_banked(bytes, ratio, REF_ROWS, 64)
    }

    /// Full 1S·NE mixed-macro area (m²) for banks of `rows` × `row_bytes`.
    /// Periphery splits into row circuitry (∝ rows per bank) and column
    /// circuitry (∝ columns), so the per-bit overhead is
    /// `(1/cols + 1/rows)` normalized to [`PERIPHERY_FRAC`] at the
    /// 256 × 512-column reference bank.
    pub fn macro_area_banked(
        &self,
        bytes: usize,
        ratio: u32,
        rows: usize,
        row_bytes: usize,
    ) -> f64 {
        assert!(rows > 0 && row_bytes > 0, "degenerate bank geometry");
        let array = self.array_area_mixed(bytes, ratio);
        let periph = array * (PERIPHERY_FRAC * super::geometry::periphery_factor(rows, row_bytes));
        array + periph + Self::mixed_extras(ratio)
    }

    /// SECDED check-plane overhead (m²) for a protected macro of `bytes`
    /// data capacity: one 6T SRAM check byte per 8 data bytes (12.5 % of
    /// the cells, but in the dense SRAM corner of the layout), carrying the
    /// same periphery fraction as the array it rides in. Charged on top of
    /// [`Self::macro_area_mixed`] by `mcaimem@V+ecc` backends and the
    /// `ecc=on` axis of the design-space explorer.
    pub fn ecc_overhead(&self, bytes: usize) -> f64 {
        self.array_area(MemKind::Sram6t, bytes.div_ceil(8)) * (1.0 + PERIPHERY_FRAC)
    }

    /// The Fig. 13 comparison: area of a 16 KB bank.
    pub fn bank16k_area(&self, kind: MemKind) -> f64 {
        self.macro_area(kind, 16 * 1024)
    }

    /// Area reduction of MCAIMem vs SRAM at equal capacity — the headline.
    pub fn mcaimem_reduction(&self, bytes: usize) -> f64 {
        1.0 - self.macro_area(MemKind::Mcaimem, bytes) / self.macro_area(MemKind::Sram6t, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MIB;

    #[test]
    fn headline_48pct_reduction() {
        let m = AreaModel::lp45();
        for bytes in [16 * 1024, MIB] {
            let red = m.mcaimem_reduction(bytes);
            assert!((red - 0.48).abs() < 0.005, "reduction={red} at {bytes}B");
        }
    }

    #[test]
    fn table1_cell_ordering() {
        // 1T1C < 3T < 2T < MCAIMem-mixed < SRAM
        let order = [
            MemKind::Edram1t1c,
            MemKind::Edram3t,
            MemKind::Edram2t,
            MemKind::Mcaimem,
            MemKind::Sram6t,
        ];
        for w in order.windows(2) {
            assert!(
                cell_area_rel(w[0]) < cell_area_rel(w[1]),
                "{:?} < {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn encoder_area_negligible() {
        let m = AreaModel::lp45();
        let with = m.macro_area(MemKind::Mcaimem, 108 * 1024);
        let array = m.array_area(MemKind::Mcaimem, 108 * 1024) * (1.0 + PERIPHERY_FRAC);
        let overhead = (with - array) / with;
        // paper §III-A1 quotes 0.004 % against its (larger) SRAM-referenced
        // macro; on our tighter layout model the bound is still ≤0.1 %
        assert!(overhead < 1e-3, "overhead={overhead}");
    }

    #[test]
    fn area_scales_linearly_with_capacity() {
        let m = AreaModel::lp45();
        let a1 = m.array_area(MemKind::Sram6t, 16 * 1024);
        let a64 = m.array_area(MemKind::Sram6t, MIB);
        assert!((a64 / a1 - 64.0).abs() < 1e-9);
    }

    #[test]
    fn ratio_area_monotone_in_edram_share() {
        // property: every extra eDRAM cell per SRAM cell shrinks both the
        // relative cell and the full macro (2T cell < SRAM cell, extras are
        // sub-0.1 % of any macro at these capacities)
        let m = AreaModel::lp45();
        for bytes in [16 * 1024, MIB] {
            for n in 0..15u32 {
                assert!(
                    mixed_cell_area_rel(n + 1) < mixed_cell_area_rel(n),
                    "cell rel must fall: n={n}"
                );
                assert!(
                    m.macro_area_mixed(bytes, n + 1) < m.macro_area_mixed(bytes, n),
                    "macro must shrink: n={n} bytes={bytes}"
                );
            }
        }
    }

    #[test]
    fn ratio7_reproduces_table1_and_the_48pct_headline_exactly() {
        // N = 7 is the paper's cell: the parameterized model must be
        // bit-identical to the fixed-kind entry points that pin Table I and
        // the 48 % headline
        let m = AreaModel::lp45();
        assert_eq!(mixed_cell_area_rel(7), cell_area_rel(MemKind::Mcaimem));
        let rel = mixed_cell_area_rel(7);
        assert!((rel - 0.52).abs() < 1e-12, "Table I: mixed cell = 52 % of SRAM, got {rel}");
        for bytes in [16 * 1024, 108 * 1024, MIB] {
            assert_eq!(
                m.macro_area_mixed(bytes, 7),
                m.macro_area(MemKind::Mcaimem, bytes),
                "bytes={bytes}"
            );
            let red = 1.0 - m.macro_area_mixed(bytes, 7) / m.macro_area(MemKind::Sram6t, bytes);
            assert!((red - 0.48).abs() < 0.005, "reduction={red} at {bytes}B");
        }
    }

    #[test]
    fn ratio0_degenerates_to_the_sram_macro() {
        // N = 0 (no eDRAM cells) must match the SRAM model exactly — cell,
        // macro (no encoder/V_REF extras), and the built SRAM backend's area
        let m = AreaModel::lp45();
        assert_eq!(mixed_cell_area_rel(0), 1.0);
        for bytes in [16 * 1024, MIB] {
            assert_eq!(m.macro_area_mixed(bytes, 0), m.macro_area(MemKind::Sram6t, bytes));
        }
        use crate::mem::backend::MemoryBackend;
        let sram = crate::mem::backend::build(&crate::mem::BackendSpec::Sram, MIB, 1);
        assert_eq!(m.macro_area_mixed(MIB, 0), sram.area());
    }

    #[test]
    fn banked_geometry_periphery_model() {
        let m = AreaModel::lp45();
        let bytes = MIB;
        let reference = m.macro_area_banked(bytes, 7, 256, 64);
        // the reference geometry is the calibration point
        assert_eq!(reference, m.macro_area_mixed(bytes, 7));
        // larger banks amortize periphery; smaller banks pay more
        assert!(m.macro_area_banked(bytes, 7, 512, 64) < reference);
        assert!(m.macro_area_banked(bytes, 7, 128, 32) > reference);
        // the split is symmetric in rows vs columns: 512×32 B (256 cols)
        // has the same 1/cols + 1/rows as the 256×64 B reference
        let skewed = m.macro_area_banked(bytes, 7, 512, 32);
        assert!((skewed / reference - 1.0).abs() < 1e-12, "{skewed} vs {reference}");
    }

    #[test]
    fn ecc_overhead_is_a_modest_sram_plane() {
        let m = AreaModel::lp45();
        for bytes in [16 * 1024, MIB] {
            let base = m.macro_area_mixed(bytes, 7);
            let ecc = m.ecc_overhead(bytes);
            // 1 SRAM check byte per 8 data bytes: 12.5 % of the *SRAM*
            // macro, i.e. ~24 % of the (48 %-smaller) mixed macro — the
            // protection still beats unprotected SRAM by a wide margin
            assert!(ecc > 0.0 && ecc < 0.30 * base, "ecc={ecc} base={base}");
            assert!(base + ecc < m.macro_area(MemKind::Sram6t, bytes));
        }
        // scales linearly with capacity like the plane it shadows
        assert!((m.ecc_overhead(MIB) / m.ecc_overhead(16 * 1024) - 64.0).abs() < 1e-9);
    }

    #[test]
    fn sram_1mb_macro_is_milli_mm2_scale() {
        // sanity: 1 MB of 0.324 µm² cells ≈ 2.7 mm² array + periphery
        let m = AreaModel::lp45();
        let a = m.macro_area(MemKind::Sram6t, MIB);
        let mm2 = a / 1e-6;
        assert!(mm2 > 2.0 && mm2 < 5.0, "area={mm2} mm²");
    }
}
