//! SWAR 8×64 bit-matrix transpose between byte order and bit-plane order.
//!
//! The functional array stores each byte as one bit in each of 8 bit-planes
//! (plane `p` bit `j` = bit `p` of byte `j`). The scalar path moves data one
//! byte at a time — 8 masked read-modify-writes per byte. The word-parallel
//! path instead converts 64 bytes at once into 8 whole plane words (and
//! back) with an 8×8-blocked bit-matrix transpose:
//!
//! 1. load the 64 bytes as eight `u64`s (8 bytes each, little-endian),
//! 2. transpose each `u64` as an 8×8 bit matrix ([`transpose8x8`],
//!    Hacker's Delight §7-3 — three mask/shift/xor swap stages),
//! 3. gather byte `p` of each transposed word into plane word `p`
//!    (an 8×8 *byte* transpose, plain shifts).
//!
//! The inverse runs the same two steps backwards; `transpose8x8` is an
//! involution, so round-tripping is exact by construction (and property
//! tested below against the bit-by-bit reference).
//!
//! §Perf: ~0.2 k ALU ops per 64-byte block versus ~3 k bit-indexed
//! read-modify-writes on the scalar path — the transform that makes
//! `MixedCellMemory::{read,write}` word-parallel (see `mem::mcaimem`).

/// Transpose a `u64` viewed as an 8×8 bit matrix (row `r` = byte `r`,
/// column `c` = bit `c` within the byte). Involution.
#[inline]
pub fn transpose8x8(mut x: u64) -> u64 {
    let t = (x ^ (x >> 7)) & 0x00AA_00AA_00AA_00AA;
    x ^= t ^ (t << 7);
    let t = (x ^ (x >> 14)) & 0x0000_CCCC_0000_CCCC;
    x ^= t ^ (t << 14);
    let t = (x ^ (x >> 28)) & 0x0000_0000_F0F0_F0F0;
    x ^= t ^ (t << 28);
    x
}

/// 64 bytes → 8 plane words: `planes[p]` bit `j` = bit `p` of `bytes[j]`.
#[inline]
pub fn bytes_to_planes(bytes: &[u8; 64]) -> [u64; 8] {
    let mut planes = [0u64; 8];
    for i in 0..8 {
        let w = u64::from_le_bytes(bytes[8 * i..8 * i + 8].try_into().unwrap());
        let t = transpose8x8(w);
        for (p, plane) in planes.iter_mut().enumerate() {
            *plane |= ((t >> (8 * p)) & 0xff) << (8 * i);
        }
    }
    planes
}

/// 8 plane words → 64 bytes: exact inverse of [`bytes_to_planes`].
#[inline]
pub fn planes_to_bytes(planes: &[u64; 8]) -> [u8; 64] {
    let mut out = [0u8; 64];
    for i in 0..8 {
        let mut t = 0u64;
        for (p, plane) in planes.iter().enumerate() {
            t |= ((plane >> (8 * i)) & 0xff) << (8 * p);
        }
        let w = transpose8x8(t);
        out[8 * i..8 * i + 8].copy_from_slice(&w.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Bit-by-bit reference for the forward transform.
    fn reference_planes(bytes: &[u8; 64]) -> [u64; 8] {
        let mut planes = [0u64; 8];
        for (j, &b) in bytes.iter().enumerate() {
            for (p, plane) in planes.iter_mut().enumerate() {
                *plane |= (((b >> p) & 1) as u64) << j;
            }
        }
        planes
    }

    #[test]
    fn transpose8x8_is_involution() {
        let mut rng = Pcg64::new(1);
        for _ in 0..10_000 {
            let x = rng.next_u64();
            assert_eq!(transpose8x8(transpose8x8(x)), x);
        }
    }

    #[test]
    fn transpose8x8_known_patterns() {
        // identity matrix (bit r of byte r set) is symmetric
        let ident = (0..8).fold(0u64, |acc, r| acc | (1u64 << (8 * r + r)));
        assert_eq!(transpose8x8(ident), ident);
        // row 0 all-ones ↔ bit 0 of every byte
        assert_eq!(transpose8x8(0xff), 0x0101_0101_0101_0101);
        assert_eq!(transpose8x8(0x0101_0101_0101_0101), 0xff);
        assert_eq!(transpose8x8(0), 0);
        assert_eq!(transpose8x8(u64::MAX), u64::MAX);
    }

    #[test]
    fn forward_matches_bit_reference() {
        let mut rng = Pcg64::new(2);
        for _ in 0..2_000 {
            let mut bytes = [0u8; 64];
            rng.fill_bytes(&mut bytes);
            assert_eq!(bytes_to_planes(&bytes), reference_planes(&bytes));
        }
    }

    #[test]
    fn roundtrip_is_exact() {
        let mut rng = Pcg64::new(3);
        for _ in 0..2_000 {
            let mut bytes = [0u8; 64];
            rng.fill_bytes(&mut bytes);
            assert_eq!(planes_to_bytes(&bytes_to_planes(&bytes)), bytes);
        }
    }

    #[test]
    fn plane_semantics() {
        // byte 5 = 0x80 → only plane 7 (the SRAM sign plane) has bit 5
        let mut bytes = [0u8; 64];
        bytes[5] = 0x80;
        let planes = bytes_to_planes(&bytes);
        for (p, plane) in planes.iter().enumerate() {
            assert_eq!(*plane, if p == 7 { 1 << 5 } else { 0 }, "plane {p}");
        }
    }
}
