//! The unified device API: one trait for every buffer design.
//!
//! The paper's argument is a *comparison* across buffer technologies
//! (SRAM vs eDRAM vs RRAM vs MCAIMem), so the repo needs exactly one way
//! to say "which memory is this" and exactly one surface through which the
//! scheduler, server and reports talk to a buffer. That is:
//!
//! * [`BackendSpec`] — the parseable spec (`"sram"`, `"edram2t"`,
//!   `"rram"`, `"mcaimem@0.8"`, `"mcaimem@0.7-noenc"`,
//!   `"mcaimem@0.8+ecc"`), with
//!   `FromStr`/`Display` round-tripping. This is the *only* spec type: the
//!   CLI parses it, `BufferManager`/`InferenceServer`/`system_eval` and the
//!   report drivers all accept it. ([`super::MemKind`] remains the
//!   circuit-level characterization key used by the area/energy cards;
//!   `BackendSpec` maps onto it via [`BackendSpec::kind`].)
//! * [`MemoryBackend`] — the device trait
//!   (`store`/`load`/`tick`/`refresh_due`/`meter`/`energy_card`/`area`/
//!   `label`): every backend moves real bytes and charges real energy
//!   through the shared [`EnergyMeter`], so one scheduler/serving path can
//!   sweep them all.
//! * [`build`] — the factory: `build(spec, bytes, seed)` →
//!   `Box<dyn MemoryBackend>`.
//!
//! Backends (see EXPERIMENTS.md §Backends for the contract table):
//!
//! | spec                | storage     | aging        | refresh            |
//! |---------------------|-------------|--------------|--------------------|
//! | `mcaimem@V[-noenc]` | functional  | physical     | manager-driven     |
//! | `sram`              | functional  | none         | none               |
//! | `edram2t`           | functional  | none (analytic energy) | self-charged in `tick` |
//! | `rram`              | functional  | none (non-volatile) | none          |
//!
//! "Functional" means `load` returns the bytes `store` put there;
//! "analytic" means the energy/refresh stream is charged from the
//! characterization card rather than simulated per row. The conventional
//! 2T's 1.3 µs C-S/A refresh would be ~10× the event count of MCAIMem's
//! 12.57 µs stream, so its cost is integrated continuously in `tick`
//! (energy-equivalent) instead of being driven row-by-row; its data is kept
//! intact — the baseline refreshes fast enough that it never corrupts.

use std::fmt;
use std::str::FromStr;

use anyhow::{anyhow, bail, Result};

use super::area::AreaModel;
use super::bank::MemoryMap;
use super::energy::EnergyCard;
use super::mcaimem::{EnergyMeter, MixedCellMemory};
use super::rram::RramCard;
use super::MemKind;

/// Which buffer design to build/evaluate — the one spec type of the repo.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BackendSpec {
    /// 6T SRAM: no flips, no refresh.
    Sram,
    /// Conventional asymmetric 2T eDRAM with C-S/A (the paper's eDRAM
    /// baseline) — no encoder, 1.3 µs refresh charged analytically.
    Edram2t,
    /// MCAIMem at a given V_REF; `encode = false` is the Fig. 11
    /// "without one-enhancement" ablation; `ecc = true` adds the SECDED
    /// check-byte plane scrubbed on the refresh pass ([`super::ecc`]).
    Mcaimem { vref: f64, encode: bool, ecc: bool },
    /// Chimera-like non-volatile RRAM buffer (Fig. 15b).
    Rram,
}

impl BackendSpec {
    /// The paper's operating point: V_REF = 0.8 V, encoder on.
    pub const fn mcaimem_default() -> Self {
        BackendSpec::Mcaimem { vref: 0.8, encode: true, ecc: false }
    }

    /// Pretty label for tables/reports (the grammar form is `Display`).
    pub fn label(&self) -> String {
        match self {
            BackendSpec::Sram => "SRAM".into(),
            BackendSpec::Edram2t => "eDRAM(2T)".into(),
            BackendSpec::Mcaimem { vref, encode, ecc } => format!(
                "MCAIMem@{vref}{}{}",
                if *encode { "" } else { "-noenc" },
                if *ecc { "+ECC" } else { "" }
            ),
            BackendSpec::Rram => "RRAM".into(),
        }
    }

    /// The circuit-level kind this spec is characterized by (area model,
    /// Table I/II cards).
    pub fn kind(&self) -> MemKind {
        match self {
            BackendSpec::Sram => MemKind::Sram6t,
            BackendSpec::Edram2t => MemKind::Edram2t,
            BackendSpec::Mcaimem { .. } => MemKind::Mcaimem,
            BackendSpec::Rram => MemKind::Rram,
        }
    }

    /// The Table II characterization card for this spec.
    pub fn energy_card(&self) -> EnergyCard {
        match self {
            BackendSpec::Sram => EnergyCard::sram(),
            BackendSpec::Edram2t => EnergyCard::edram2t(),
            BackendSpec::Mcaimem { vref, .. } => EnergyCard::mcaimem(*vref),
            BackendSpec::Rram => EnergyCard::rram(),
        }
    }

    /// Does data pass through the one-enhancement encoder in front of the
    /// array?
    pub fn encoded(&self) -> bool {
        matches!(self, BackendSpec::Mcaimem { encode: true, .. })
    }

    /// Parse a comma-separated sweep list (`"sram,edram2t,mcaimem@0.8"`).
    /// Repeated specs are deduplicated order-preserving (first occurrence
    /// wins), so a sweep like `--backend sram,sram,mcaimem@0.8` doesn't
    /// evaluate — and print — the same column twice. Dedup happens on the
    /// *parsed* value, so textual variants (`mcaimem@0.80`, `MCAIMem@0.8`)
    /// of one spec collapse too.
    pub fn parse_list(s: &str) -> Result<Vec<BackendSpec>> {
        let mut specs: Vec<BackendSpec> = Vec::new();
        for part in s.split(',').filter(|p| !p.trim().is_empty()) {
            let spec: BackendSpec = part.parse()?;
            if !specs.contains(&spec) {
                specs.push(spec);
            }
        }
        if specs.is_empty() {
            bail!("empty backend list `{s}`");
        }
        Ok(specs)
    }

    /// The default cross-technology sweep (Fig. 15b order).
    pub fn default_sweep() -> Vec<BackendSpec> {
        vec![
            BackendSpec::Sram,
            BackendSpec::Rram,
            BackendSpec::Edram2t,
            BackendSpec::mcaimem_default(),
        ]
    }
}

const GRAMMAR: &str =
    "sram | edram2t | rram | mcaimem[@VREF[-noenc]][+ecc]  (VREF in volts, 0.3..=1.1)";

impl FromStr for BackendSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let t = s.trim().to_ascii_lowercase();
        let (t, ecc) = match t.strip_suffix("+ecc") {
            Some(t) => (t.to_string(), true),
            None => (t, false),
        };
        match t.as_str() {
            "sram" | "edram2t" | "rram" if ecc => {
                bail!("`+ecc` applies to mcaimem specs only (grammar: {GRAMMAR})")
            }
            "sram" => return Ok(BackendSpec::Sram),
            "edram2t" => return Ok(BackendSpec::Edram2t),
            "rram" => return Ok(BackendSpec::Rram),
            "mcaimem" => return Ok(BackendSpec::Mcaimem { vref: 0.8, encode: true, ecc }),
            _ => {}
        }
        let rest = t
            .strip_prefix("mcaimem@")
            .ok_or_else(|| anyhow!("unknown backend spec `{s}` (grammar: {GRAMMAR})"))?;
        let (v, encode) = match rest.strip_suffix("-noenc") {
            Some(v) => (v, false),
            None => (rest, true),
        };
        let vref: f64 = v
            .parse()
            .map_err(|_| anyhow!("bad V_REF `{v}` in backend spec `{s}` (grammar: {GRAMMAR})"))?;
        if !(0.3..=1.1).contains(&vref) {
            bail!("V_REF {vref} out of range in backend spec `{s}` (grammar: {GRAMMAR})");
        }
        Ok(BackendSpec::Mcaimem { vref, encode, ecc })
    }
}

impl fmt::Display for BackendSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BackendSpec::Sram => write!(f, "sram"),
            BackendSpec::Edram2t => write!(f, "edram2t"),
            BackendSpec::Rram => write!(f, "rram"),
            BackendSpec::Mcaimem { vref, encode, ecc } => write!(
                f,
                "mcaimem@{vref}{}{}",
                if *encode { "" } else { "-noenc" },
                if *ecc { "+ecc" } else { "" }
            ),
        }
    }
}

/// One device API for every buffer design.
///
/// Contract (property-tested in `tests/backend_conformance.rs`):
///
/// * time is monotone: `store`/`load`/`tick` take an absolute `now` that
///   never decreases; `tick` integrates time-proportional costs (static
///   power, analytic refresh streams) up to `now`;
/// * `load` after `store` round-trips exactly for non-volatile and
///   unaged/fresh volatile state;
/// * every access charges the shared [`EnergyMeter`], whose `total_j` is
///   non-decreasing and whose `bytes_read`/`bytes_written` count payload
///   bytes exactly;
/// * `refresh_due` is the whole-array refresh period the *manager* must
///   honor by driving [`MemoryBackend::refresh_row`] (None = the backend
///   needs no manager-driven refresh — static, non-volatile, or
///   self-charged analytically in `tick`).
///
/// Backends are `Send` (plain simulated state), so a worker pool can own
/// one buffer manager per thread.
pub trait MemoryBackend: Send {
    /// The spec this backend was built from (round-trips through `build`).
    fn spec(&self) -> BackendSpec;

    /// Usable capacity in bytes (rounded up to whole 16 KB banks).
    fn capacity(&self) -> usize;

    /// Current device clock (s).
    fn now(&self) -> f64;

    /// Write `data` at `addr`, time `now`.
    fn store(&mut self, addr: usize, data: &[u8], now: f64);

    /// Read `len` bytes at `addr`, time `now`.
    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8>;

    /// Advance the device clock without an access (integrates static and
    /// any analytic refresh energy).
    fn tick(&mut self, now: f64);

    /// Whole-array refresh period the manager must honor, or None.
    fn refresh_due(&self) -> Option<f64>;

    /// Apply one manager-driven refresh slot (row across all banks).
    /// No-op for backends with `refresh_due() == None`.
    fn refresh_row(&mut self, _row: usize, _now: f64) {}

    /// Rows per bank — how many refresh slots one `refresh_due` period is
    /// divided into. 1 for backends without manager-driven refresh.
    fn rows_per_bank(&self) -> usize {
        1
    }

    /// Quarantine a failed shard at time `now`, remapping its addresses to
    /// failover storage. Returns whether the request was honored; the
    /// default (single-array backends, or a
    /// [`super::sharded::ShardedBackend`] built without failover
    /// provisioning) ignores it — dying without a standby replica is not a
    /// recoverable event.
    fn quarantine_shard(&mut self, _shard: usize, _now: f64) -> bool {
        false
    }

    /// The shared energy/event meter.
    fn meter(&self) -> &EnergyMeter;

    /// Per-shard meter snapshots. Single-array backends report one shard
    /// (their own meter); [`super::sharded::ShardedBackend`] overrides this
    /// with one entry per bank shard so the serving tier can surface
    /// per-shard occupancy/refresh counters.
    fn shard_meters(&self) -> Vec<EnergyMeter> {
        vec![self.meter().clone()]
    }

    /// The Table II characterization card energy is charged from.
    fn energy_card(&self) -> &EnergyCard;

    /// Macro area (m²) of this buffer at its capacity on 45 nm LP.
    fn area(&self) -> f64 {
        AreaModel::lp45().macro_area(self.spec().kind(), self.capacity())
    }

    /// Pretty label (delegates to the spec).
    fn label(&self) -> String {
        self.spec().label()
    }
}

/// Build a backend from its spec: the single construction point every
/// consumer (CLI, buffer manager, server, sweeps) goes through.
pub fn build(spec: &BackendSpec, bytes: usize, seed: u64) -> Box<dyn MemoryBackend> {
    match spec {
        BackendSpec::Sram => Box::new(SramBackend::new(bytes)),
        BackendSpec::Edram2t => Box::new(Edram2tBackend::new(bytes)),
        BackendSpec::Rram => Box::new(RramBackend::new(bytes)),
        BackendSpec::Mcaimem { vref, encode, ecc } => {
            let mut b = McaimemBackend::new(bytes, *vref, *encode, seed);
            b.mem.ecc_enabled = *ecc;
            Box::new(b)
        }
    }
}

// ---------------------------------------------------------------------------
// MCAIMem — the functional mixed-cell array (full aging path).
// ---------------------------------------------------------------------------

/// The functional mixed-cell array behind the trait: real bit-planes,
/// physical flips, manager-driven refresh-by-read.
pub struct McaimemBackend {
    pub mem: MixedCellMemory,
}

impl McaimemBackend {
    pub fn new(bytes: usize, vref: f64, encode: bool, seed: u64) -> Self {
        Self::with_ratio(bytes, vref, encode, 7, seed)
    }

    /// A functional mixed array at an explicit 1S·NE cell ratio (one of
    /// the byte-tiling ratios 0/1/3/7 — see
    /// [`MixedCellMemory::with_geometry`]). `BackendSpec` deliberately has
    /// no ratio field (the paper's 1S·7E is *the* spec); non-default
    /// ratios are a design-space-exploration construction, so
    /// [`MemoryBackend::spec`] reports the nearest spec while `area` and
    /// `label` reflect the true composition.
    pub fn with_ratio(bytes: usize, vref: f64, encode: bool, ratio: u32, seed: u64) -> Self {
        let mut mem = MixedCellMemory::with_geometry(bytes, vref, ratio, seed);
        mem.encode_enabled = encode;
        McaimemBackend { mem }
    }

    /// A functional array over a compiled macro's generated geometry: the
    /// [`crate::mem::compiler::MacroSpec`]'s bank organization becomes the
    /// runnable memory map, so conformance traces replay through the exact
    /// structure the compiler emitted. Fails on compositions the
    /// byte-oriented functional array cannot represent (non-byte-tiling
    /// ratios — the analytic evaluator covers those) and on row widths the
    /// word-parallel access path cannot scan (must be whole 64-byte words).
    pub fn from_macro(spec: &crate::mem::compiler::MacroSpec, seed: u64) -> crate::Result<Self> {
        let p = &spec.point;
        anyhow::ensure!(
            p.functional_ratio(),
            "1S·{}E does not tile a byte — no functional array for this macro",
            p.ratio
        );
        anyhow::ensure!(
            spec.row_bytes % 64 == 0,
            "compiled row width {} B is not whole 64-byte words",
            spec.row_bytes
        );
        let bank = crate::mem::bank::BankGeometry {
            bytes: spec.rows * spec.row_bytes,
            rows: spec.rows,
            row_bytes: spec.row_bytes,
        };
        let map = crate::mem::bank::MemoryMap::with_geometry(spec.bytes, bank);
        let mut mem = MixedCellMemory::with_map(map, p.vref, p.ratio, seed);
        mem.encode_enabled = p.encode;
        mem.ecc_enabled = p.ecc && p.ratio > 0;
        Ok(McaimemBackend { mem })
    }
}

/// [`build`] with an explicit bank geometry — the conformance campaign's
/// entry point for exercising compiler-generated organizations. Only the
/// functional mixed-cell array is geometry-parameterized; the closed-form
/// baselines have no banked state to re-shape.
pub fn build_with_geometry(
    spec: &BackendSpec,
    bytes: usize,
    bank: crate::mem::bank::BankGeometry,
    seed: u64,
) -> crate::Result<Box<dyn MemoryBackend>> {
    match spec {
        BackendSpec::Mcaimem { vref, encode, ecc } => {
            anyhow::ensure!(
                bank.row_bytes % 64 == 0,
                "row width {} B is not whole 64-byte words",
                bank.row_bytes
            );
            let map = crate::mem::bank::MemoryMap::with_geometry(bytes, bank);
            let mut mem = MixedCellMemory::with_map(map, *vref, 7, seed);
            mem.encode_enabled = *encode;
            mem.ecc_enabled = *ecc;
            Ok(Box::new(McaimemBackend { mem }))
        }
        other => anyhow::bail!("{} has no banked geometry to re-shape", other.label()),
    }
}

impl MemoryBackend for McaimemBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::Mcaimem {
            vref: self.mem.vref,
            encode: self.mem.encode_enabled,
            ecc: self.mem.ecc_enabled,
        }
    }

    fn capacity(&self) -> usize {
        self.mem.capacity()
    }

    fn now(&self) -> f64 {
        self.mem.now()
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        self.mem.write(addr, data, now);
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        self.mem.read(addr, len, now)
    }

    fn tick(&mut self, now: f64) {
        self.mem.advance_to(now);
    }

    fn refresh_due(&self) -> Option<f64> {
        self.mem.card.refresh_period
    }

    fn refresh_row(&mut self, row: usize, now: f64) {
        self.mem.refresh_row(row, now);
    }

    fn rows_per_bank(&self) -> usize {
        self.mem.map.bank.rows
    }

    fn meter(&self) -> &EnergyMeter {
        &self.mem.meter
    }

    fn energy_card(&self) -> &EnergyCard {
        &self.mem.card
    }

    fn area(&self) -> f64 {
        let m = AreaModel::lp45();
        let base = m.macro_area_mixed(self.capacity(), self.mem.ratio);
        if self.mem.ecc_enabled {
            base + m.ecc_overhead(self.capacity())
        } else {
            base
        }
    }

    fn label(&self) -> String {
        if self.mem.ratio == 7 {
            self.spec().label()
        } else {
            format!("{} (1S{}E)", self.spec().label(), self.mem.ratio)
        }
    }
}

// ---------------------------------------------------------------------------
// SRAM — functional bytes, no flips, no refresh.
// ---------------------------------------------------------------------------

/// The 6T SRAM baseline: bytes are stored faithfully forever; energy is
/// charged from the (symmetric) Table II card.
pub struct SramBackend {
    data: Vec<u8>,
    card: EnergyCard,
    meter: EnergyMeter,
    now: f64,
}

impl SramBackend {
    pub fn new(bytes: usize) -> Self {
        let cap = MemoryMap::with_capacity(bytes).capacity();
        SramBackend {
            data: vec![0; cap],
            card: EnergyCard::sram(),
            meter: EnergyMeter::default(),
            now: 0.0,
        }
    }

    fn advance_to(&mut self, now: f64) {
        assert!(now + 1e-15 >= self.now, "time must be monotone");
        let dt = now - self.now;
        if dt > 0.0 {
            // the 6T card is data-symmetric; any ones fraction gives the
            // same static power
            self.meter.static_j += self.card.static_power(self.data.len(), 0.5) * dt;
        }
        self.now = now;
    }
}

impl MemoryBackend for SramBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::Sram
    }

    fn capacity(&self) -> usize {
        self.data.len()
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.data.len(), "write out of range");
        self.advance_to(now);
        self.data[addr..addr + data.len()].copy_from_slice(data);
        self.meter.write_j += self.card.write_energy(data.len(), 0.5);
        self.meter.writes += 1;
        self.meter.bytes_written += data.len() as u64;
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.data.len(), "read out of range");
        self.advance_to(now);
        self.meter.read_j += self.card.read_energy(len, 0.5);
        self.meter.reads += 1;
        self.meter.bytes_read += len as u64;
        self.data[addr..addr + len].to_vec()
    }

    fn tick(&mut self, now: f64) {
        self.advance_to(now);
    }

    fn refresh_due(&self) -> Option<f64> {
        None
    }

    fn meter(&self) -> &EnergyMeter {
        &self.meter
    }

    fn energy_card(&self) -> &EnergyCard {
        &self.card
    }
}

// ---------------------------------------------------------------------------
// Conventional 2T eDRAM — functional bytes, analytic refresh stream.
// ---------------------------------------------------------------------------

/// The conventional asymmetric 2T baseline. Bytes are stored faithfully
/// (its 1.3 µs C-S/A refresh keeps data alive by construction); the price
/// of that refresh stream and the data-dependent static power are charged
/// analytically in `tick` from a live ones census, so the asymmetric card
/// sees the actual resident data.
pub struct Edram2tBackend {
    data: Vec<u8>,
    /// Ones census over all 8 bit-planes (every bit is eDRAM here).
    ones: u64,
    card: EnergyCard,
    meter: EnergyMeter,
    /// Fractional whole-array refresh passes not yet counted in the meter.
    refresh_frac: f64,
    now: f64,
}

impl Edram2tBackend {
    pub fn new(bytes: usize) -> Self {
        let cap = MemoryMap::with_capacity(bytes).capacity();
        Edram2tBackend {
            // power-on state: pull-up leakage parks every cell at bit-1
            data: vec![0xff; cap],
            ones: (cap * 8) as u64,
            card: EnergyCard::edram2t(),
            meter: EnergyMeter::default(),
            refresh_frac: 0.0,
            now: 0.0,
        }
    }

    fn ones_frac(&self) -> f64 {
        self.ones as f64 / (self.data.len() * 8) as f64
    }

    fn advance_to(&mut self, now: f64) {
        assert!(now + 1e-15 >= self.now, "time must be monotone");
        let dt = now - self.now;
        if dt > 0.0 {
            let f = self.ones_frac();
            self.meter.static_j += self.card.static_power(self.data.len(), f) * dt;
            self.meter.refresh_j += self.card.refresh_power(self.data.len(), f) * dt;
            let period = self.card.refresh_period.expect("2T eDRAM refreshes");
            let passes = self.refresh_frac + dt / period;
            self.meter.refreshes += passes as u64;
            self.refresh_frac = passes.fract();
        }
        self.now = now;
    }
}

impl MemoryBackend for Edram2tBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::Edram2t
    }

    fn capacity(&self) -> usize {
        self.data.len()
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.data.len(), "write out of range");
        self.advance_to(now);
        let mut old_ones = 0u64;
        let mut new_ones = 0u64;
        for (slot, &new) in self.data[addr..addr + data.len()].iter_mut().zip(data) {
            old_ones += slot.count_ones() as u64;
            new_ones += new.count_ones() as u64;
            *slot = new;
        }
        self.ones = self.ones + new_ones - old_ones;
        let frac = new_ones as f64 / (data.len() * 8).max(1) as f64;
        self.meter.write_j += self.card.write_energy(data.len(), frac);
        self.meter.writes += 1;
        self.meter.bytes_written += data.len() as u64;
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.data.len(), "read out of range");
        self.advance_to(now);
        let out = self.data[addr..addr + len].to_vec();
        let ones: u64 = out.iter().map(|b| b.count_ones() as u64).sum();
        let frac = ones as f64 / (len * 8).max(1) as f64;
        self.meter.read_j += self.card.read_energy(len, frac);
        self.meter.reads += 1;
        self.meter.bytes_read += len as u64;
        out
    }

    fn tick(&mut self, now: f64) {
        self.advance_to(now);
    }

    /// None: the C-S/A refresh stream is charged analytically in `tick`
    /// (driving its 1.3 µs period per-row would multiply the event count
    /// ~10× over MCAIMem for an energy-identical result).
    fn refresh_due(&self) -> Option<f64> {
        None
    }

    fn meter(&self) -> &EnergyMeter {
        &self.meter
    }

    fn energy_card(&self) -> &EnergyCard {
        &self.card
    }
}

// ---------------------------------------------------------------------------
// RRAM — non-volatile, write-asymmetric.
// ---------------------------------------------------------------------------

/// The Chimera-like non-volatile buffer: zero standby power and no refresh,
/// but the SET/RESET write path is ~100× a read in energy and ~20× in
/// latency — both charged through the shared meter (`busy_s` carries the
/// programming time).
pub struct RramBackend {
    data: Vec<u8>,
    rram: RramCard,
    card: EnergyCard,
    meter: EnergyMeter,
    now: f64,
}

impl RramBackend {
    pub fn new(bytes: usize) -> Self {
        let cap = MemoryMap::with_capacity(bytes).capacity();
        RramBackend {
            data: vec![0; cap],
            rram: RramCard::chimera_like(),
            card: EnergyCard::rram(),
            meter: EnergyMeter::default(),
            now: 0.0,
        }
    }

    fn advance_to(&mut self, now: f64) {
        assert!(now + 1e-15 >= self.now, "time must be monotone");
        // non-volatile: no static power, nothing to integrate
        self.now = now;
    }
}

impl MemoryBackend for RramBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::Rram
    }

    fn capacity(&self) -> usize {
        self.data.len()
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.data.len(), "write out of range");
        self.advance_to(now);
        self.data[addr..addr + data.len()].copy_from_slice(data);
        self.meter.write_j += self.rram.write_energy(data.len());
        self.meter.busy_s += self.rram.write_latency_ns * 1e-9;
        self.meter.writes += 1;
        self.meter.bytes_written += data.len() as u64;
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.data.len(), "read out of range");
        self.advance_to(now);
        self.meter.read_j += self.rram.read_energy(len);
        self.meter.busy_s += self.rram.read_latency_ns * 1e-9;
        self.meter.reads += 1;
        self.meter.bytes_read += len as u64;
        self.data[addr..addr + len].to_vec()
    }

    fn tick(&mut self, now: f64) {
        self.advance_to(now);
    }

    fn refresh_due(&self) -> Option<f64> {
        None
    }

    fn meter(&self) -> &EnergyMeter {
        &self.meter
    }

    fn energy_card(&self) -> &EnergyCard {
        &self.card
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiled_macro_becomes_a_runnable_backend() {
        use crate::dse::space::DesignPoint;
        use crate::mem::compiler::compile;

        // a non-default generated geometry: 512 × 128 B banks
        let point =
            DesignPoint { rows: 512, row_bytes: 128, ecc: true, ..DesignPoint::paper() };
        let mspec = compile(&point, 64 * 1024).unwrap();
        let mut b = McaimemBackend::from_macro(&mspec, 0xC0DE).unwrap();
        assert_eq!(b.capacity(), 64 * 1024);
        assert_eq!(b.mem.map.bank.rows, 512);
        assert_eq!(b.rows_per_bank(), 512);
        assert!(b.mem.ecc_enabled && b.mem.encode_enabled);
        let data: Vec<u8> = (0..256).map(|i| i as u8).collect();
        b.store(777, &data, 1e-9);
        assert_eq!(b.load(777, data.len(), 2e-9), data);

        // the compiler refuses to hand non-representable compositions to
        // the functional array
        let odd = compile(&DesignPoint { ratio: 5, ..DesignPoint::paper() }, 64 * 1024).unwrap();
        assert!(McaimemBackend::from_macro(&odd, 1).is_err());

        // geometry-parameterized build: only the mixed-cell array re-shapes
        let bank = crate::mem::bank::BankGeometry::new(16 * 1024, 128);
        let g = build_with_geometry(&BackendSpec::mcaimem_default(), 64 * 1024, bank, 7);
        assert_eq!(g.unwrap().capacity(), 64 * 1024);
        assert!(build_with_geometry(&BackendSpec::Sram, 64 * 1024, bank, 7).is_err());
    }

    #[test]
    fn spec_roundtrip_canonical_forms() {
        for s in [
            "sram",
            "edram2t",
            "rram",
            "mcaimem@0.8",
            "mcaimem@0.7-noenc",
            "mcaimem@0.55",
            "mcaimem@0.8+ecc",
            "mcaimem@0.7-noenc+ecc",
        ] {
            let spec: BackendSpec = s.parse().unwrap();
            assert_eq!(spec.to_string(), s, "{s}");
            let again: BackendSpec = spec.to_string().parse().unwrap();
            assert_eq!(again, spec, "{s}");
        }
    }

    #[test]
    fn spec_aliases_and_normalization() {
        assert_eq!("mcaimem".parse::<BackendSpec>().unwrap(), BackendSpec::mcaimem_default());
        assert_eq!("MCAIMem@0.80".parse::<BackendSpec>().unwrap().to_string(), "mcaimem@0.8");
        assert_eq!(" SRAM ".parse::<BackendSpec>().unwrap(), BackendSpec::Sram);
    }

    #[test]
    fn spec_grammar_rejects_garbage() {
        for s in [
            "",
            "sram@0.8",
            "mcaimem@",
            "mcaimem@abc",
            "edram",
            "mcaimem@0.8-enc",
            "mcaimem@9.9",
            "sram+ecc",
            "rram+ecc",
            "mcaimem@0.8+ecc2",
        ] {
            assert!(s.parse::<BackendSpec>().is_err(), "`{s}` must not parse");
        }
    }

    #[test]
    fn parse_list_sweeps() {
        let specs = BackendSpec::parse_list("sram, edram2t ,mcaimem@0.8,mcaimem@0.7-noenc").unwrap();
        assert_eq!(specs.len(), 4);
        assert!(BackendSpec::parse_list("  ,, ").is_err());
    }

    #[test]
    fn parse_list_dedupes_order_preserving() {
        // repeated specs collapse to the first occurrence, order kept
        let specs = BackendSpec::parse_list("sram,sram,mcaimem@0.8,sram,edram2t").unwrap();
        assert_eq!(
            specs,
            vec![BackendSpec::Sram, BackendSpec::mcaimem_default(), BackendSpec::Edram2t]
        );
        // dedup is on the parsed value: textual variants of one spec merge
        let specs = BackendSpec::parse_list("mcaimem@0.80,MCAIMem@0.8,mcaimem").unwrap();
        assert_eq!(specs, vec![BackendSpec::mcaimem_default()]);
        // distinct V_REFs / encoder settings are distinct specs
        let specs =
            BackendSpec::parse_list("mcaimem@0.8,mcaimem@0.7,mcaimem@0.8-noenc").unwrap();
        assert_eq!(specs.len(), 3);
    }

    #[test]
    fn ratio_backend_area_and_label() {
        let default = McaimemBackend::new(64 * 1024, 0.8, true, 1);
        let r7 = McaimemBackend::with_ratio(64 * 1024, 0.8, true, 7, 1);
        assert_eq!(
            MemoryBackend::area(&default),
            MemoryBackend::area(&r7),
            "ratio 7 is the default composition"
        );
        assert_eq!(r7.label(), "MCAIMem@0.8");
        let r3 = McaimemBackend::with_ratio(64 * 1024, 0.8, true, 3, 1);
        assert!(
            MemoryBackend::area(&r3) > MemoryBackend::area(&r7),
            "more SRAM cells per byte must cost area"
        );
        assert_eq!(r3.label(), "MCAIMem@0.8 (1S3E)");
        // a ratio-3 array still round-trips data
        let mut r3 = r3;
        let data: Vec<u8> = (0..=255).collect();
        r3.store(0, &data, 1e-9);
        assert_eq!(r3.load(0, 256, 2e-9), data);
    }

    #[test]
    fn factory_builds_every_default_spec() {
        for spec in BackendSpec::default_sweep() {
            let b = build(&spec, 32 * 1024, 1);
            assert_eq!(b.spec(), spec);
            assert_eq!(b.capacity(), 32 * 1024);
            assert!(b.area() > 0.0);
            assert_eq!(b.label(), spec.label());
        }
    }

    #[test]
    fn simple_backends_roundtrip_bytes() {
        for spec in [BackendSpec::Sram, BackendSpec::Edram2t, BackendSpec::Rram] {
            let mut b = build(&spec, 16 * 1024, 3);
            let data: Vec<u8> = (0..=255).collect();
            b.store(100, &data, 1e-6);
            assert_eq!(b.load(100, 256, 2e-6), data, "{spec}");
            assert_eq!(b.meter().bytes_written, 256);
            assert_eq!(b.meter().bytes_read, 256);
        }
    }

    #[test]
    fn sram_and_rram_static_behaviour() {
        let mut s = build(&BackendSpec::Sram, 16 * 1024, 1);
        s.tick(1e-3);
        assert!(s.meter().static_j > 0.0, "SRAM leaks");
        let mut r = build(&BackendSpec::Rram, 16 * 1024, 1);
        r.tick(1e-3);
        assert_eq!(r.meter().static_j, 0.0, "RRAM is non-volatile");
        assert_eq!(r.refresh_due(), None);
        assert_eq!(s.refresh_due(), None);
    }

    #[test]
    fn edram2t_charges_refresh_with_time() {
        let mut e = build(&BackendSpec::Edram2t, 16 * 1024, 1);
        e.tick(13.1e-6); // just past ten 1.3 µs refresh periods
        assert!(e.meter().refresh_j > 0.0);
        assert_eq!(e.meter().refreshes, 10);
        // the all-ones power-on state is the cheap corner of the asymmetric
        // card: writing zeros must raise the static *and* refresh power
        let p0 = e.meter().total_j();
        let zeros = vec![0u8; 4096];
        e.store(0, &zeros, 14e-6);
        e.tick(26e-6);
        let grew_dirty = e.meter().total_j() - p0;
        assert!(grew_dirty > 0.0);
    }

    #[test]
    fn rram_write_asymmetry_through_the_meter() {
        let mut r = build(&BackendSpec::Rram, 16 * 1024, 1);
        r.store(0, &[7u8; 1024], 1e-6);
        let _ = r.load(0, 1024, 2e-6);
        let m = r.meter();
        assert!(m.write_j > 50.0 * m.read_j, "write {} vs read {}", m.write_j, m.read_j);
        assert!(m.busy_s > 0.0, "programming latency must accrue");
    }

    #[test]
    fn mcaimem_backend_is_the_functional_array() {
        let spec = BackendSpec::Mcaimem { vref: 0.8, encode: true, ecc: false };
        let mut b = build(&spec, 16 * 1024, 0xBEEF);
        assert!(b.refresh_due().is_some());
        assert_eq!(b.rows_per_bank(), 256);
        let data: Vec<u8> = (0..64).collect();
        b.store(0, &data, 1e-9);
        assert_eq!(b.load(0, 64, 2e-9), data);
        assert!(b.meter().write_j > 0.0 && b.meter().read_j > 0.0);
    }

    #[test]
    fn ecc_spec_builds_a_protected_array() {
        let spec: BackendSpec = "mcaimem@0.8+ecc".parse().unwrap();
        assert_eq!(spec, BackendSpec::Mcaimem { vref: 0.8, encode: true, ecc: true });
        assert_eq!(spec.label(), "MCAIMem@0.8+ECC");
        let mut b = build(&spec, 16 * 1024, 0xBEEF);
        assert_eq!(b.spec(), spec, "spec round-trips through build");
        // the check plane costs area but keeps the functional contract
        let plain = build(&BackendSpec::mcaimem_default(), 16 * 1024, 0xBEEF);
        assert!(b.area() > plain.area());
        let data: Vec<u8> = (0..64).collect();
        b.store(0, &data, 1e-9);
        assert_eq!(b.load(0, 64, 2e-9), data);
        // quarantine is refused by a flat array (no failover provisioning)
        assert!(!b.quarantine_shard(0, 3e-9));
    }

    #[test]
    fn area_ordering_matches_the_headline() {
        let sram = build(&BackendSpec::Sram, 1024 * 1024, 1).area();
        let ours = build(&BackendSpec::mcaimem_default(), 1024 * 1024, 1).area();
        let red = 1.0 - ours / sram;
        assert!((red - 0.48).abs() < 0.005, "reduction={red}");
    }
}
