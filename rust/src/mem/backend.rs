//! The unified device API: one trait for every buffer design.
//!
//! The paper's argument is a *comparison* across buffer technologies
//! (SRAM vs eDRAM vs RRAM vs MCAIMem), so the repo needs exactly one way
//! to say "which memory is this" and exactly one surface through which the
//! scheduler, server and reports talk to a buffer. That is:
//!
//! * [`BackendSpec`] — the parseable spec (`"sram"`, `"edram2t"`,
//!   `"rram"`, `"mcaimem@0.8"`, `"mcaimem@0.7-noenc"`,
//!   `"mcaimem@0.8+ecc"`), with
//!   `FromStr`/`Display` round-tripping. This is the *only* spec type: the
//!   CLI parses it, `BufferManager`/`InferenceServer`/`system_eval` and the
//!   report drivers all accept it. ([`super::MemKind`] remains the
//!   circuit-level characterization key used by the area/energy cards;
//!   `BackendSpec` maps onto it via [`BackendSpec::kind`].)
//! * [`MemoryBackend`] — the device trait
//!   (`store`/`load`/`tick`/`refresh_due`/`meter`/`energy_card`/`area`/
//!   `label`): every backend moves real bytes and charges real energy
//!   through the shared [`EnergyMeter`], so one scheduler/serving path can
//!   sweep them all.
//! * [`build`] — the factory: `build(spec, bytes, seed)` →
//!   `Box<dyn MemoryBackend>`.
//!
//! Backends (see EXPERIMENTS.md §Backends for the contract table):
//!
//! | spec                | storage     | aging        | refresh            |
//! |---------------------|-------------|--------------|--------------------|
//! | `mcaimem@V[-noenc]` | functional  | physical     | manager-driven     |
//! | `sram`              | functional  | none         | none               |
//! | `edram2t`           | functional  | none (analytic energy) | self-charged in `tick` |
//! | `rram`              | functional  | none (non-volatile) | none          |
//!
//! "Functional" means `load` returns the bytes `store` put there;
//! "analytic" means the energy/refresh stream is charged from the
//! characterization card rather than simulated per row. The conventional
//! 2T's 1.3 µs C-S/A refresh would be ~10× the event count of MCAIMem's
//! 12.57 µs stream, so its cost is integrated continuously in `tick`
//! (energy-equivalent) instead of being driven row-by-row; its data is kept
//! intact — the baseline refreshes fast enough that it never corrupts.

use std::fmt;
use std::str::FromStr;

use anyhow::{bail, Result};

use super::area::AreaModel;
use super::bank::MemoryMap;
use super::energy::EnergyCard;
use super::mcaimem::{EnergyMeter, MixedCellMemory};
use super::rram::RramCard;
use super::MemKind;

/// Which buffer design to build/evaluate — the one spec type of the repo.
///
/// The grammar is *recursive*: the `tiered=FRONT:BYTES+BACK` combinator
/// composes any two specs into a two-level hierarchy (a small fast
/// write-back buffer in front of a slow-write device — see
/// [`super::tiered::TieredBackend`]), and `Display` is the canonical form
/// every spec round-trips through (`parse(display(s)) == s`, property-
/// tested over random spec trees in `tests/backend_conformance.rs`).
#[derive(Clone, Debug, PartialEq)]
pub enum BackendSpec {
    /// 6T SRAM: no flips, no refresh.
    Sram,
    /// Conventional asymmetric 2T eDRAM with C-S/A (the paper's eDRAM
    /// baseline) — no encoder, 1.3 µs refresh charged analytically.
    Edram2t,
    /// MCAIMem at a given V_REF; `encode = false` is the Fig. 11
    /// "without one-enhancement" ablation; `ecc = true` adds the SECDED
    /// check-byte plane scrubbed on the refresh pass ([`super::ecc`]).
    Mcaimem { vref: f64, encode: bool, ecc: bool },
    /// Chimera-like non-volatile RRAM buffer (Fig. 15b).
    Rram,
    /// STT-MRAM at a retention target (s) — `sttmram[@ret=SECONDS]`,
    /// defaulting to the 10-year archival corner. Relaxing `ret` shrinks
    /// write energy/latency ∝ the thermal stability Δ ([`super::mram`]).
    Sttmram { ret: f64 },
    /// SOT-MRAM at a retention target (s) — `sotmram[@ret=SECONDS]`; the
    /// separate spin-orbit write path starts ~4× cheaper than STT.
    Sotmram { ret: f64 },
    /// Two-level hierarchy: `Tiered(front, front_bytes, back)` — a
    /// `front_bytes` write-back buffer of the front technology in front of
    /// a full-capacity back technology (`tiered=sram:32k+sotmram`).
    Tiered(Box<BackendSpec>, usize, Box<BackendSpec>),
}

impl BackendSpec {
    /// The paper's operating point: V_REF = 0.8 V, encoder on.
    pub const fn mcaimem_default() -> Self {
        BackendSpec::Mcaimem { vref: 0.8, encode: true, ecc: false }
    }

    /// STT/SOT-MRAM spec retention default: the 10-year archival corner.
    pub const RET_DEFAULT: f64 = crate::mem::mram::RET_NOMINAL_S;

    /// Pretty label for tables/reports (the grammar form is `Display`).
    pub fn label(&self) -> String {
        match self {
            BackendSpec::Sram => "SRAM".into(),
            BackendSpec::Edram2t => "eDRAM(2T)".into(),
            BackendSpec::Mcaimem { vref, encode, ecc } => format!(
                "MCAIMem@{vref}{}{}",
                if *encode { "" } else { "-noenc" },
                if *ecc { "+ECC" } else { "" }
            ),
            BackendSpec::Rram => "RRAM".into(),
            BackendSpec::Sttmram { ret } => mram_label("STT-MRAM", *ret),
            BackendSpec::Sotmram { ret } => mram_label("SOT-MRAM", *ret),
            BackendSpec::Tiered(front, bytes, back) => {
                format!("{}:{}→{}", front.label(), size_str(*bytes), back.label())
            }
        }
    }

    /// The circuit-level kind this spec is characterized by (area model,
    /// Table I/II cards). A tiered spec reports its *back* tier — the tier
    /// that holds the full capacity.
    pub fn kind(&self) -> MemKind {
        match self {
            BackendSpec::Sram => MemKind::Sram6t,
            BackendSpec::Edram2t => MemKind::Edram2t,
            BackendSpec::Mcaimem { .. } => MemKind::Mcaimem,
            BackendSpec::Rram => MemKind::Rram,
            BackendSpec::Sttmram { .. } => MemKind::Sttmram,
            BackendSpec::Sotmram { .. } => MemKind::Sotmram,
            BackendSpec::Tiered(_, _, back) => back.kind(),
        }
    }

    /// The Table II characterization card for this spec (the back tier's
    /// card for a tiered spec — the capacity-holding technology).
    pub fn energy_card(&self) -> EnergyCard {
        match self {
            BackendSpec::Sram => EnergyCard::sram(),
            BackendSpec::Edram2t => EnergyCard::edram2t(),
            BackendSpec::Mcaimem { vref, .. } => EnergyCard::mcaimem(*vref),
            BackendSpec::Rram => EnergyCard::rram(),
            BackendSpec::Sttmram { ret } => EnergyCard::sttmram(*ret),
            BackendSpec::Sotmram { ret } => EnergyCard::sotmram(*ret),
            BackendSpec::Tiered(_, _, back) => back.energy_card(),
        }
    }

    /// Does data pass through the one-enhancement encoder in front of the
    /// array?
    pub fn encoded(&self) -> bool {
        match self {
            BackendSpec::Mcaimem { encode, .. } => *encode,
            BackendSpec::Tiered(front, _, back) => front.encoded() || back.encoded(),
            _ => false,
        }
    }

    /// Is this a *leaf* spec the golden oracle models naively (a plain
    /// byte array whose meter is pure card arithmetic — no aging, no
    /// self-charged refresh stream)?
    pub fn oracle_leaf(&self) -> bool {
        matches!(
            self,
            BackendSpec::Sram
                | BackendSpec::Rram
                | BackendSpec::Sttmram { .. }
                | BackendSpec::Sotmram { .. }
        )
    }

    /// Does the golden oracle ([`crate::sim::oracle`]) carry a naive model
    /// of this spec? MCAIMem always; a tiered spec when both members are
    /// naive leaves (the two-level golden model).
    pub fn oracle_modeled(&self) -> bool {
        match self {
            BackendSpec::Mcaimem { .. } => true,
            BackendSpec::Tiered(front, _, back) => front.oracle_leaf() && back.oracle_leaf(),
            _ => false,
        }
    }

    /// Parse a comma-separated sweep list (`"sram,edram2t,mcaimem@0.8"`).
    /// Repeated specs are deduplicated order-preserving (first occurrence
    /// wins), so a sweep like `--backend sram,sram,mcaimem@0.8` doesn't
    /// evaluate — and print — the same column twice. Dedup is keyed on the
    /// canonical `Display` form (the round-trip key for the recursive
    /// grammar), so textual variants (`mcaimem@0.80`, `MCAIMem@0.8`,
    /// `sttmram@ret=315600000`) of one spec collapse too. A failing
    /// element is reported with its list position.
    pub fn parse_list(s: &str) -> std::result::Result<Vec<BackendSpec>, SpecError> {
        let mut specs: Vec<BackendSpec> = Vec::new();
        let mut keys: Vec<String> = Vec::new();
        for (index, part) in s.split(',').enumerate() {
            if part.trim().is_empty() {
                continue;
            }
            let spec: BackendSpec =
                part.parse().map_err(|source: SpecError| SpecError::ListElement {
                    index,
                    element: part.trim().to_string(),
                    source: Box::new(source),
                })?;
            let key = spec.to_string();
            if !keys.contains(&key) {
                keys.push(key);
                specs.push(spec);
            }
        }
        if specs.is_empty() {
            return Err(SpecError::EmptyList { list: s.to_string() });
        }
        Ok(specs)
    }

    /// The default cross-technology sweep (Fig. 15b order).
    pub fn default_sweep() -> Vec<BackendSpec> {
        vec![
            BackendSpec::Sram,
            BackendSpec::Rram,
            BackendSpec::Edram2t,
            BackendSpec::mcaimem_default(),
        ]
    }
}

/// The spec grammar, quoted by every parse error.
pub const GRAMMAR: &str = "sram | edram2t | rram | mcaimem[@VREF[-noenc]][+ecc] | \
     sttmram[@ret=SECONDS] | sotmram[@ret=SECONDS] | tiered=FRONT:BYTES+BACK  \
     (VREF in volts 0.3..=1.1; ret in seconds 1e-6..=3.2e8; BYTES like 32k, 1m)";

/// The leaf keywords of the grammar — the "expected one of" set quoted by
/// [`SpecError`], and the candidate pool for its edit-distance suggestions.
pub const KEYWORDS: [&str; 7] =
    ["sram", "edram2t", "rram", "mcaimem", "sttmram", "sotmram", "tiered"];

/// Structured parse error for the [`BackendSpec`] grammar: every variant
/// carries the byte span of the offending token in the *original* input,
/// and unknown-keyword errors attach a nearest-keyword suggestion (the
/// same edit-distance suggester the CLI uses for unknown options).
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    /// The head token is not a known backend keyword.
    Unknown { token: String, span: (usize, usize), suggest: Option<&'static str> },
    /// A keyword parsed but a parameter (V_REF, retention, `+ecc`
    /// placement, …) is malformed or out of range.
    Param { msg: String, span: (usize, usize) },
    /// A `BYTES` size in a tiered spec is malformed.
    Size { msg: String, span: (usize, usize) },
    /// A `tiered=` combinator is missing a structural piece.
    Structure { msg: String, span: (usize, usize) },
    /// One element of a [`BackendSpec::parse_list`] sweep failed.
    ListElement { index: usize, element: String, source: Box<SpecError> },
    /// A sweep list with no non-empty elements.
    EmptyList { list: String },
}

impl SpecError {
    /// Byte span of the offending token in the original input.
    pub fn span(&self) -> (usize, usize) {
        match self {
            SpecError::Unknown { span, .. }
            | SpecError::Param { span, .. }
            | SpecError::Size { span, .. }
            | SpecError::Structure { span, .. } => *span,
            SpecError::ListElement { source, .. } => source.span(),
            SpecError::EmptyList { .. } => (0, 0),
        }
    }

    /// Shift every span by `base` bytes — how sub-spec errors surface with
    /// coordinates in the *outer* input string.
    fn offset(self, base: usize) -> Self {
        let shift = |(a, b): (usize, usize)| (a + base, b + base);
        match self {
            SpecError::Unknown { token, span, suggest } => {
                SpecError::Unknown { token, span: shift(span), suggest }
            }
            SpecError::Param { msg, span } => SpecError::Param { msg, span: shift(span) },
            SpecError::Size { msg, span } => SpecError::Size { msg, span: shift(span) },
            SpecError::Structure { msg, span } => {
                SpecError::Structure { msg, span: shift(span) }
            }
            other => other,
        }
    }
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::Unknown { token, span, suggest } => {
                write!(f, "unknown backend spec `{token}` at {}..{}", span.0, span.1)?;
                if let Some(s) = suggest {
                    write!(f, " (did you mean `{s}`?)")?;
                }
                write!(f, "; expected one of: {}", KEYWORDS.join(", "))?;
                write!(f, " (grammar: {GRAMMAR})")
            }
            SpecError::Param { msg, span } | SpecError::Size { msg, span } => {
                write!(f, "{msg} at {}..{} (grammar: {GRAMMAR})", span.0, span.1)
            }
            SpecError::Structure { msg, span } => {
                write!(
                    f,
                    "{msg} at {}..{}; expected tiered=FRONT:BYTES+BACK (grammar: {GRAMMAR})",
                    span.0, span.1
                )
            }
            SpecError::ListElement { index, element, source } => {
                write!(f, "backend list element {} (`{element}`): {source}", index + 1)
            }
            SpecError::EmptyList { list } => write!(f, "empty backend list `{list}`"),
        }
    }
}

impl std::error::Error for SpecError {}

/// Parse helpers are span-aware: `base` is the byte offset of `t` inside
/// the original input, so errors from nested sub-specs point at the right
/// place in what the user actually typed.
fn parse_spec(t: &str, base: usize) -> std::result::Result<BackendSpec, SpecError> {
    let span = (base, base + t.len());
    // parenthesized sub-spec (nested tiered members)
    if let Some(inner) = t.strip_prefix('(') {
        let inner = inner.strip_suffix(')').ok_or(SpecError::Structure {
            msg: "unbalanced `(` in backend spec".into(),
            span,
        })?;
        return parse_spec(inner, base + 1);
    }
    if let Some(rest) = t.strip_prefix("tiered=") {
        return parse_tiered(rest, base + "tiered=".len());
    }
    let (body, ecc) = match t.strip_suffix("+ecc") {
        Some(body) => (body, true),
        None => (t, false),
    };
    if ecc && !body.starts_with("mcaimem") {
        return Err(SpecError::Param {
            msg: "`+ecc` applies to mcaimem specs only".into(),
            span: (base + body.len(), base + t.len()),
        });
    }
    match body {
        "sram" => return Ok(BackendSpec::Sram),
        "edram2t" => return Ok(BackendSpec::Edram2t),
        "rram" => return Ok(BackendSpec::Rram),
        "mcaimem" => return Ok(BackendSpec::Mcaimem { vref: 0.8, encode: true, ecc }),
        "sttmram" => return Ok(BackendSpec::Sttmram { ret: BackendSpec::RET_DEFAULT }),
        "sotmram" => return Ok(BackendSpec::Sotmram { ret: BackendSpec::RET_DEFAULT }),
        _ => {}
    }
    if let Some(rest) = body.strip_prefix("mcaimem@") {
        let at = base + "mcaimem@".len();
        let (v, encode) = match rest.strip_suffix("-noenc") {
            Some(v) => (v, false),
            None => (rest, true),
        };
        let vspan = (at, at + v.len());
        let vref: f64 = v.parse().map_err(|_| SpecError::Param {
            msg: format!("bad V_REF `{v}` in backend spec"),
            span: vspan,
        })?;
        if !(0.3..=1.1).contains(&vref) {
            return Err(SpecError::Param {
                msg: format!("V_REF {vref} out of range 0.3..=1.1"),
                span: vspan,
            });
        }
        return Ok(BackendSpec::Mcaimem { vref, encode, ecc });
    }
    for (prefix, kind) in [("sttmram@", MemKind::Sttmram), ("sotmram@", MemKind::Sotmram)] {
        if let Some(rest) = body.strip_prefix(prefix) {
            let at = base + prefix.len();
            let ret = parse_retention(rest, at)?;
            return Ok(match kind {
                MemKind::Sttmram => BackendSpec::Sttmram { ret },
                _ => BackendSpec::Sotmram { ret },
            });
        }
    }
    // unknown keyword: suggest the nearest one (≤ 2 edits, like the CLI)
    let head: String =
        body.chars().take_while(|c| c.is_ascii_alphanumeric()).collect();
    let suggest = crate::cli::args::nearest_keyword(&head, &KEYWORDS);
    Err(SpecError::Unknown { token: t.to_string(), span, suggest })
}

/// Parse the `ret=SECONDS` knob of an MRAM spec.
fn parse_retention(rest: &str, base: usize) -> std::result::Result<f64, SpecError> {
    let span = (base, base + rest.len());
    let v = rest.strip_prefix("ret=").ok_or_else(|| SpecError::Param {
        msg: format!("expected `ret=SECONDS` after `@`, got `{rest}`"),
        span,
    })?;
    let vspan = (base + "ret=".len(), base + rest.len());
    let ret: f64 = v.parse().map_err(|_| SpecError::Param {
        msg: format!("bad retention `{v}` (seconds)"),
        span: vspan,
    })?;
    if !(crate::mem::mram::RET_MIN_S..=3.2e8).contains(&ret) {
        return Err(SpecError::Param {
            msg: format!("retention {ret} s out of range 1e-6..=3.2e8"),
            span: vspan,
        });
    }
    Ok(ret)
}

/// Parse the body of a `tiered=` combinator: `FRONT:BYTES+BACK`, where
/// `:` and `+` split at paren depth 0 so nested tiered members stay whole.
fn parse_tiered(rest: &str, base: usize) -> std::result::Result<BackendSpec, SpecError> {
    let span = (base, base + rest.len());
    let colon = split_at_depth0(rest, ':').ok_or(SpecError::Structure {
        msg: "tiered spec is missing its `:BYTES` buffer size".into(),
        span,
    })?;
    let (front_str, after) = (&rest[..colon], &rest[colon + 1..]);
    let plus = split_at_depth0(after, '+').ok_or(SpecError::Structure {
        msg: "tiered spec is missing its `+BACK` member".into(),
        span,
    })?;
    let (size_str, back_str) = (&after[..plus], &after[plus + 1..]);
    let front = parse_spec(front_str, base)?;
    let bytes = parse_size(size_str, base + colon + 1)?;
    let back = parse_spec(back_str, base + colon + 1 + plus + 1)?;
    Ok(BackendSpec::Tiered(Box::new(front), bytes, Box::new(back)))
}

/// Position of the first `sep` at paren depth 0, or None.
fn split_at_depth0(s: &str, sep: char) -> Option<usize> {
    let mut depth = 0i32;
    for (i, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => depth -= 1,
            c if c == sep && depth == 0 => return Some(i),
            _ => {}
        }
    }
    None
}

/// Parse a buffer size like `32k`, `1m`, or `4096` (binary suffixes). The
/// tier buffer is managed at 64-byte blocks, so it must be a positive
/// multiple of 64.
fn parse_size(s: &str, base: usize) -> std::result::Result<usize, SpecError> {
    use crate::util::units::{KIB, MIB};
    let span = (base, base + s.len());
    let (digits, mult) = match s.strip_suffix(['k', 'm']) {
        Some(d) if s.ends_with('k') => (d, KIB),
        Some(d) => (d, MIB),
        None => (s, 1),
    };
    let n: usize = digits.parse().map_err(|_| SpecError::Size {
        msg: format!("bad buffer size `{s}` (expected BYTES like 32k, 1m, 4096)"),
        span,
    })?;
    let bytes = n * mult;
    if bytes == 0 || bytes % 64 != 0 {
        return Err(SpecError::Size {
            msg: format!("buffer size {bytes} B must be a positive multiple of 64"),
            span,
        });
    }
    Ok(bytes)
}

/// Canonical rendering of a tier buffer size (`32k`, `1m`, raw bytes).
fn size_str(bytes: usize) -> String {
    use crate::util::units::{KIB, MIB};
    if bytes % MIB == 0 {
        format!("{}m", bytes / MIB)
    } else if bytes % KIB == 0 {
        format!("{}k", bytes / KIB)
    } else {
        format!("{bytes}")
    }
}

/// Pretty MRAM label: bare at the archival default, retention-annotated
/// otherwise.
fn mram_label(name: &str, ret: f64) -> String {
    if ret == BackendSpec::RET_DEFAULT {
        name.to_string()
    } else {
        format!("{name}@ret={ret}")
    }
}

impl FromStr for BackendSpec {
    type Err = SpecError;

    fn from_str(s: &str) -> std::result::Result<Self, SpecError> {
        let start = s.len() - s.trim_start().len();
        let t = s.trim().to_ascii_lowercase();
        parse_spec(&t, 0).map_err(|e| e.offset(start))
    }
}

impl fmt::Display for BackendSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BackendSpec::Sram => write!(f, "sram"),
            BackendSpec::Edram2t => write!(f, "edram2t"),
            BackendSpec::Rram => write!(f, "rram"),
            BackendSpec::Mcaimem { vref, encode, ecc } => write!(
                f,
                "mcaimem@{vref}{}{}",
                if *encode { "" } else { "-noenc" },
                if *ecc { "+ecc" } else { "" }
            ),
            BackendSpec::Sttmram { ret } if *ret == Self::RET_DEFAULT => write!(f, "sttmram"),
            BackendSpec::Sttmram { ret } => write!(f, "sttmram@ret={ret}"),
            BackendSpec::Sotmram { ret } if *ret == Self::RET_DEFAULT => write!(f, "sotmram"),
            BackendSpec::Sotmram { ret } => write!(f, "sotmram@ret={ret}"),
            BackendSpec::Tiered(front, bytes, back) => {
                // nested tiered members parenthesize so the recursive
                // grammar re-parses the exact same tree
                let wrap = |m: &BackendSpec| match m {
                    BackendSpec::Tiered(..) => format!("({m})"),
                    _ => m.to_string(),
                };
                write!(f, "tiered={}:{}+{}", wrap(front), size_str(*bytes), wrap(back))
            }
        }
    }
}

/// One device API for every buffer design.
///
/// Contract (property-tested in `tests/backend_conformance.rs`):
///
/// * time is monotone: `store`/`load`/`tick` take an absolute `now` that
///   never decreases; `tick` integrates time-proportional costs (static
///   power, analytic refresh streams) up to `now`;
/// * `load` after `store` round-trips exactly for non-volatile and
///   unaged/fresh volatile state;
/// * every access charges the shared [`EnergyMeter`], whose `total_j` is
///   non-decreasing and whose `bytes_read`/`bytes_written` count payload
///   bytes exactly;
/// * `refresh_due` is the whole-array refresh period the *manager* must
///   honor by driving [`MemoryBackend::refresh_row`] (None = the backend
///   needs no manager-driven refresh — static, non-volatile, or
///   self-charged analytically in `tick`).
///
/// Backends are `Send` (plain simulated state), so a worker pool can own
/// one buffer manager per thread.
pub trait MemoryBackend: Send {
    /// The spec this backend was built from (round-trips through `build`).
    fn spec(&self) -> BackendSpec;

    /// Usable capacity in bytes (rounded up to whole 16 KB banks).
    fn capacity(&self) -> usize;

    /// Current device clock (s).
    fn now(&self) -> f64;

    /// Write `data` at `addr`, time `now`.
    fn store(&mut self, addr: usize, data: &[u8], now: f64);

    /// Read `len` bytes at `addr`, time `now`.
    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8>;

    /// Advance the device clock without an access (integrates static and
    /// any analytic refresh energy).
    fn tick(&mut self, now: f64);

    /// Whole-array refresh period the manager must honor, or None.
    fn refresh_due(&self) -> Option<f64>;

    /// Apply one manager-driven refresh slot (row across all banks).
    /// No-op for backends with `refresh_due() == None`.
    fn refresh_row(&mut self, _row: usize, _now: f64) {}

    /// Rows per bank — how many refresh slots one `refresh_due` period is
    /// divided into. 1 for backends without manager-driven refresh.
    fn rows_per_bank(&self) -> usize {
        1
    }

    /// Per-shard device clocks, furthest-advanced first: a singleton for
    /// flat backends, one entry per shard for striped organizations
    /// ([`crate::mem::sharded::ShardedBackend`] overrides). Refresh-aware
    /// dispatch telemetry — a shard whose clock lags the rest is
    /// quarantined or stalled, and batch windows should not be planned
    /// around its slots.
    fn shard_clocks(&self) -> Vec<f64> {
        vec![self.now()]
    }

    /// Quarantine a failed shard at time `now`, remapping its addresses to
    /// failover storage. Returns whether the request was honored; the
    /// default (single-array backends, or a
    /// [`super::sharded::ShardedBackend`] built without failover
    /// provisioning) ignores it — dying without a standby replica is not a
    /// recoverable event.
    fn quarantine_shard(&mut self, _shard: usize, _now: f64) -> bool {
        false
    }

    /// Attach a telemetry sink (`crate::obs`). `track_base` is the global
    /// shard-track offset for this backend's shards (multi-worker pools
    /// give each worker's backend a disjoint range). The default ignores
    /// it — flat backends have no structural events to report; sharded /
    /// tiered / fault-wrapped backends override to emit failover, tier
    /// traffic and fault firings onto their tracks.
    fn attach_obs(&mut self, _sink: &crate::obs::ObsSink, _track_base: u32) {}

    /// The shared energy/event meter.
    fn meter(&self) -> &EnergyMeter;

    /// Per-shard meter snapshots. Single-array backends report one shard
    /// (their own meter); [`super::sharded::ShardedBackend`] overrides this
    /// with one entry per bank shard so the serving tier can surface
    /// per-shard occupancy/refresh counters.
    fn shard_meters(&self) -> Vec<EnergyMeter> {
        vec![self.meter().clone()]
    }

    /// The Table II characterization card energy is charged from.
    fn energy_card(&self) -> &EnergyCard;

    /// Macro area (m²) of this buffer at its capacity on 45 nm LP.
    fn area(&self) -> f64 {
        AreaModel::lp45().macro_area(self.spec().kind(), self.capacity())
    }

    /// Pretty label (delegates to the spec).
    fn label(&self) -> String {
        self.spec().label()
    }
}

/// Build a backend from its spec: the single construction point every
/// consumer (CLI, buffer manager, server, sweeps) goes through. For the
/// optioned construction paths (geometry, shards, failover, ratio,
/// compiled macros, trace recording) use [`Builder`]; this is the flat
/// factory `Builder` itself bottoms out in.
pub fn build(spec: &BackendSpec, bytes: usize, seed: u64) -> Box<dyn MemoryBackend> {
    match spec {
        BackendSpec::Sram => Box::new(SramBackend::new(bytes)),
        BackendSpec::Edram2t => Box::new(Edram2tBackend::new(bytes)),
        BackendSpec::Rram => Box::new(RramBackend::new(bytes)),
        BackendSpec::Sttmram { .. } | BackendSpec::Sotmram { .. } => {
            Box::new(MramBackend::new(spec.clone(), bytes))
        }
        BackendSpec::Tiered(..) => {
            Box::new(super::tiered::TieredBackend::new(spec.clone(), bytes, seed))
        }
        BackendSpec::Mcaimem { vref, encode, ecc } => {
            let mut b = McaimemBackend::new(bytes, *vref, *encode, seed);
            b.mem.ecc_enabled = *ecc;
            Box::new(b)
        }
    }
}

/// The one optioned construction path for every backend shape the repo can
/// run: flat, banked geometry, sharded (with or without failover
/// provisioning), explicit 1S·NE ratio, compiled macro, and
/// trace-recording variants of all of them.
///
/// This collapses what used to be four ad-hoc constructors — [`build`],
/// [`build_with_geometry`], [`McaimemBackend::with_ratio`]/
/// [`McaimemBackend::from_macro`] and
/// [`super::sharded::ShardedBackend::with_failover`] — into one builder;
/// those remain as thin shims over this type (prefer `Builder` in new
/// code).
///
/// ```text
/// Builder::new(spec, bytes).seed(7).shards(4).failover(true).build()?
/// Builder::new(spec, bytes).geometry(bank).recording()?   // + TraceHandle
/// ```
pub struct Builder {
    spec: BackendSpec,
    bytes: usize,
    seed: u64,
    geometry: Option<crate::mem::bank::BankGeometry>,
    shards: usize,
    failover: bool,
    ratio: Option<u32>,
    compiled: Option<crate::mem::compiler::MacroSpec>,
}

impl Builder {
    /// A flat `spec` backend of `bytes` capacity, seed 0 — every other
    /// option layers on top.
    pub fn new(spec: BackendSpec, bytes: usize) -> Self {
        Builder {
            spec,
            bytes,
            seed: 0,
            geometry: None,
            shards: 0,
            failover: false,
            ratio: None,
            compiled: None,
        }
    }

    /// Deterministic seed for per-cell leakage populations (and, sharded,
    /// the per-shard seed derivation).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// An explicit bank geometry (functional mixed-cell array only).
    pub fn geometry(mut self, bank: crate::mem::bank::BankGeometry) -> Self {
        self.geometry = Some(bank);
        self
    }

    /// Stripe across `n` independently-clocked shards (0 = flat).
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = n;
        self
    }

    /// Provision every shard at 2× for single-shard-outage tolerance
    /// (meaningful only with `shards >= 2`).
    pub fn failover(mut self, on: bool) -> Self {
        self.failover = on;
        self
    }

    /// An explicit 1S·NE cell ratio (mcaimem specs only; byte-tiling
    /// ratios 0/1/3/7).
    pub fn ratio(mut self, n: u32) -> Self {
        self.ratio = Some(n);
        self
    }

    /// Build over a compiled macro's generated bank organization
    /// ([`crate::mem::compiler::MacroSpec`]); capacity and geometry come
    /// from the macro.
    pub fn compiled(mut self, spec: &crate::mem::compiler::MacroSpec) -> Self {
        self.compiled = Some(spec.clone());
        self
    }

    /// Construct the backend.
    pub fn build(self) -> Result<Box<dyn MemoryBackend>> {
        if let Some(mspec) = &self.compiled {
            if self.shards > 0 || self.geometry.is_some() || self.ratio.is_some() {
                bail!("a compiled macro fixes geometry/ratio; drop the conflicting options");
            }
            return Ok(Box::new(McaimemBackend::from_macro(mspec, self.seed)?));
        }
        if self.shards > 0 {
            if self.geometry.is_some() {
                bail!("sharded backends with explicit bank geometry are not supported");
            }
            if self.ratio.is_some() {
                bail!("sharded backends with explicit cell ratio are not supported");
            }
            let sh = if self.failover {
                super::sharded::ShardedBackend::with_failover(
                    &self.spec, self.shards, self.bytes, self.seed,
                )?
            } else {
                super::sharded::ShardedBackend::new(
                    &self.spec, self.shards, self.bytes, self.seed,
                )?
            };
            return Ok(Box::new(sh));
        }
        if self.failover {
            bail!("failover provisioning needs shards >= 2");
        }
        if let Some(bank) = self.geometry {
            if self.ratio.is_some() {
                bail!("pick either an explicit geometry or an explicit ratio, not both");
            }
            return build_with_geometry(&self.spec, self.bytes, bank, self.seed);
        }
        if let Some(n) = self.ratio {
            let BackendSpec::Mcaimem { vref, encode, ecc } = &self.spec else {
                bail!("{} has no mixed-cell ratio to set", self.spec.label());
            };
            let mut b = McaimemBackend::with_ratio(self.bytes, *vref, *encode, n, self.seed);
            b.mem.ecc_enabled = *ecc;
            return Ok(Box::new(b));
        }
        Ok(build(&self.spec, self.bytes, self.seed))
    }

    /// Construct the backend wrapped in a trace recorder: every device-API
    /// call is logged onto the returned [`crate::sim::trace::TraceHandle`]
    /// so the run replays bit- and meter-exactly (`mcaimem conform`).
    pub fn recording(
        self,
    ) -> Result<(Box<dyn MemoryBackend>, crate::sim::trace::TraceHandle)> {
        let (bytes, seed, shards, geometry) =
            (self.bytes, self.seed, self.shards, self.geometry);
        let inner = self.build()?;
        let (traced, handle) =
            crate::sim::trace::TracingBackend::wrap(inner, bytes, seed, shards);
        if let Some(bank) = geometry {
            handle.lock().unwrap().geom = Some(bank);
        }
        Ok((traced, handle))
    }
}

// ---------------------------------------------------------------------------
// MCAIMem — the functional mixed-cell array (full aging path).
// ---------------------------------------------------------------------------

/// The functional mixed-cell array behind the trait: real bit-planes,
/// physical flips, manager-driven refresh-by-read.
pub struct McaimemBackend {
    pub mem: MixedCellMemory,
}

impl McaimemBackend {
    pub fn new(bytes: usize, vref: f64, encode: bool, seed: u64) -> Self {
        Self::with_ratio(bytes, vref, encode, 7, seed)
    }

    /// A functional mixed array at an explicit 1S·NE cell ratio (one of
    /// the byte-tiling ratios 0/1/3/7 — see
    /// [`MixedCellMemory::with_geometry`]). `BackendSpec` deliberately has
    /// no ratio field (the paper's 1S·7E is *the* spec); non-default
    /// ratios are a design-space-exploration construction, so
    /// [`MemoryBackend::spec`] reports the nearest spec while `area` and
    /// `label` reflect the true composition.
    pub fn with_ratio(bytes: usize, vref: f64, encode: bool, ratio: u32, seed: u64) -> Self {
        let mut mem = MixedCellMemory::with_geometry(bytes, vref, ratio, seed);
        mem.encode_enabled = encode;
        McaimemBackend { mem }
    }

    /// A functional array over a compiled macro's generated geometry: the
    /// [`crate::mem::compiler::MacroSpec`]'s bank organization becomes the
    /// runnable memory map, so conformance traces replay through the exact
    /// structure the compiler emitted. Fails on compositions the
    /// byte-oriented functional array cannot represent (non-byte-tiling
    /// ratios — the analytic evaluator covers those) and on row widths the
    /// word-parallel access path cannot scan (must be whole 64-byte words).
    pub fn from_macro(spec: &crate::mem::compiler::MacroSpec, seed: u64) -> crate::Result<Self> {
        let p = &spec.point;
        anyhow::ensure!(
            p.functional_ratio(),
            "1S·{}E does not tile a byte — no functional array for this macro",
            p.ratio
        );
        anyhow::ensure!(
            spec.row_bytes % 64 == 0,
            "compiled row width {} B is not whole 64-byte words",
            spec.row_bytes
        );
        let bank = crate::mem::bank::BankGeometry {
            bytes: spec.rows * spec.row_bytes,
            rows: spec.rows,
            row_bytes: spec.row_bytes,
        };
        let map = crate::mem::bank::MemoryMap::with_geometry(spec.bytes, bank);
        let mut mem = MixedCellMemory::with_map(map, p.vref, p.ratio, seed);
        mem.encode_enabled = p.encode;
        mem.ecc_enabled = p.ecc && p.ratio > 0;
        Ok(McaimemBackend { mem })
    }
}

/// [`build`] with an explicit bank geometry — the conformance campaign's
/// entry point for exercising compiler-generated organizations. Only the
/// functional mixed-cell array is geometry-parameterized; the closed-form
/// baselines have no banked state to re-shape.
pub fn build_with_geometry(
    spec: &BackendSpec,
    bytes: usize,
    bank: crate::mem::bank::BankGeometry,
    seed: u64,
) -> crate::Result<Box<dyn MemoryBackend>> {
    match spec {
        BackendSpec::Mcaimem { vref, encode, ecc } => {
            anyhow::ensure!(
                bank.row_bytes % 64 == 0,
                "row width {} B is not whole 64-byte words",
                bank.row_bytes
            );
            let map = crate::mem::bank::MemoryMap::with_geometry(bytes, bank);
            let mut mem = MixedCellMemory::with_map(map, *vref, 7, seed);
            mem.encode_enabled = *encode;
            mem.ecc_enabled = *ecc;
            Ok(Box::new(McaimemBackend { mem }))
        }
        other => anyhow::bail!("{} has no banked geometry to re-shape", other.label()),
    }
}

impl MemoryBackend for McaimemBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::Mcaimem {
            vref: self.mem.vref,
            encode: self.mem.encode_enabled,
            ecc: self.mem.ecc_enabled,
        }
    }

    fn capacity(&self) -> usize {
        self.mem.capacity()
    }

    fn now(&self) -> f64 {
        self.mem.now()
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        self.mem.write(addr, data, now);
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        self.mem.read(addr, len, now)
    }

    fn tick(&mut self, now: f64) {
        self.mem.advance_to(now);
    }

    fn refresh_due(&self) -> Option<f64> {
        self.mem.card.refresh_period
    }

    fn refresh_row(&mut self, row: usize, now: f64) {
        self.mem.refresh_row(row, now);
    }

    fn rows_per_bank(&self) -> usize {
        self.mem.map.bank.rows
    }

    fn meter(&self) -> &EnergyMeter {
        &self.mem.meter
    }

    fn energy_card(&self) -> &EnergyCard {
        &self.mem.card
    }

    fn area(&self) -> f64 {
        let m = AreaModel::lp45();
        let base = m.macro_area_mixed(self.capacity(), self.mem.ratio);
        if self.mem.ecc_enabled {
            base + m.ecc_overhead(self.capacity())
        } else {
            base
        }
    }

    fn label(&self) -> String {
        if self.mem.ratio == 7 {
            self.spec().label()
        } else {
            format!("{} (1S{}E)", self.spec().label(), self.mem.ratio)
        }
    }
}

// ---------------------------------------------------------------------------
// SRAM — functional bytes, no flips, no refresh.
// ---------------------------------------------------------------------------

/// The 6T SRAM baseline: bytes are stored faithfully forever; energy is
/// charged from the (symmetric) Table II card.
pub struct SramBackend {
    data: Vec<u8>,
    card: EnergyCard,
    meter: EnergyMeter,
    now: f64,
}

impl SramBackend {
    pub fn new(bytes: usize) -> Self {
        let cap = MemoryMap::with_capacity(bytes).capacity();
        SramBackend {
            data: vec![0; cap],
            card: EnergyCard::sram(),
            meter: EnergyMeter::default(),
            now: 0.0,
        }
    }

    fn advance_to(&mut self, now: f64) {
        assert!(now + 1e-15 >= self.now, "time must be monotone");
        let dt = now - self.now;
        if dt > 0.0 {
            // the 6T card is data-symmetric; any ones fraction gives the
            // same static power
            self.meter.static_j += self.card.static_power(self.data.len(), 0.5) * dt;
        }
        self.now = now;
    }
}

impl MemoryBackend for SramBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::Sram
    }

    fn capacity(&self) -> usize {
        self.data.len()
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.data.len(), "write out of range");
        self.advance_to(now);
        self.data[addr..addr + data.len()].copy_from_slice(data);
        self.meter.write_j += self.card.write_energy(data.len(), 0.5);
        self.meter.writes += 1;
        self.meter.bytes_written += data.len() as u64;
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.data.len(), "read out of range");
        self.advance_to(now);
        self.meter.read_j += self.card.read_energy(len, 0.5);
        self.meter.reads += 1;
        self.meter.bytes_read += len as u64;
        self.data[addr..addr + len].to_vec()
    }

    fn tick(&mut self, now: f64) {
        self.advance_to(now);
    }

    fn refresh_due(&self) -> Option<f64> {
        None
    }

    fn meter(&self) -> &EnergyMeter {
        &self.meter
    }

    fn energy_card(&self) -> &EnergyCard {
        &self.card
    }
}

// ---------------------------------------------------------------------------
// Conventional 2T eDRAM — functional bytes, analytic refresh stream.
// ---------------------------------------------------------------------------

/// The conventional asymmetric 2T baseline. Bytes are stored faithfully
/// (its 1.3 µs C-S/A refresh keeps data alive by construction); the price
/// of that refresh stream and the data-dependent static power are charged
/// analytically in `tick` from a live ones census, so the asymmetric card
/// sees the actual resident data.
pub struct Edram2tBackend {
    data: Vec<u8>,
    /// Ones census over all 8 bit-planes (every bit is eDRAM here).
    ones: u64,
    card: EnergyCard,
    meter: EnergyMeter,
    /// Fractional whole-array refresh passes not yet counted in the meter.
    refresh_frac: f64,
    now: f64,
}

impl Edram2tBackend {
    pub fn new(bytes: usize) -> Self {
        let cap = MemoryMap::with_capacity(bytes).capacity();
        Edram2tBackend {
            // power-on state: pull-up leakage parks every cell at bit-1
            data: vec![0xff; cap],
            ones: (cap * 8) as u64,
            card: EnergyCard::edram2t(),
            meter: EnergyMeter::default(),
            refresh_frac: 0.0,
            now: 0.0,
        }
    }

    fn ones_frac(&self) -> f64 {
        self.ones as f64 / (self.data.len() * 8) as f64
    }

    fn advance_to(&mut self, now: f64) {
        assert!(now + 1e-15 >= self.now, "time must be monotone");
        let dt = now - self.now;
        if dt > 0.0 {
            let f = self.ones_frac();
            self.meter.static_j += self.card.static_power(self.data.len(), f) * dt;
            self.meter.refresh_j += self.card.refresh_power(self.data.len(), f) * dt;
            let period = self.card.refresh_period.expect("2T eDRAM refreshes");
            let passes = self.refresh_frac + dt / period;
            self.meter.refreshes += passes as u64;
            self.refresh_frac = passes.fract();
        }
        self.now = now;
    }
}

impl MemoryBackend for Edram2tBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::Edram2t
    }

    fn capacity(&self) -> usize {
        self.data.len()
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.data.len(), "write out of range");
        self.advance_to(now);
        let mut old_ones = 0u64;
        let mut new_ones = 0u64;
        for (slot, &new) in self.data[addr..addr + data.len()].iter_mut().zip(data) {
            old_ones += slot.count_ones() as u64;
            new_ones += new.count_ones() as u64;
            *slot = new;
        }
        self.ones = self.ones + new_ones - old_ones;
        let frac = new_ones as f64 / (data.len() * 8).max(1) as f64;
        self.meter.write_j += self.card.write_energy(data.len(), frac);
        self.meter.writes += 1;
        self.meter.bytes_written += data.len() as u64;
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.data.len(), "read out of range");
        self.advance_to(now);
        let out = self.data[addr..addr + len].to_vec();
        let ones: u64 = out.iter().map(|b| b.count_ones() as u64).sum();
        let frac = ones as f64 / (len * 8).max(1) as f64;
        self.meter.read_j += self.card.read_energy(len, frac);
        self.meter.reads += 1;
        self.meter.bytes_read += len as u64;
        out
    }

    fn tick(&mut self, now: f64) {
        self.advance_to(now);
    }

    /// None: the C-S/A refresh stream is charged analytically in `tick`
    /// (driving its 1.3 µs period per-row would multiply the event count
    /// ~10× over MCAIMem for an energy-identical result).
    fn refresh_due(&self) -> Option<f64> {
        None
    }

    fn meter(&self) -> &EnergyMeter {
        &self.meter
    }

    fn energy_card(&self) -> &EnergyCard {
        &self.card
    }
}

// ---------------------------------------------------------------------------
// RRAM — non-volatile, write-asymmetric.
// ---------------------------------------------------------------------------

/// The Chimera-like non-volatile buffer: zero standby power and no refresh,
/// but the SET/RESET write path is ~100× a read in energy and ~20× in
/// latency — both charged through the shared meter (`busy_s` carries the
/// programming time).
pub struct RramBackend {
    data: Vec<u8>,
    rram: RramCard,
    card: EnergyCard,
    meter: EnergyMeter,
    now: f64,
}

impl RramBackend {
    pub fn new(bytes: usize) -> Self {
        let cap = MemoryMap::with_capacity(bytes).capacity();
        RramBackend {
            data: vec![0; cap],
            rram: RramCard::chimera_like(),
            card: EnergyCard::rram(),
            meter: EnergyMeter::default(),
            now: 0.0,
        }
    }

    fn advance_to(&mut self, now: f64) {
        assert!(now + 1e-15 >= self.now, "time must be monotone");
        // non-volatile: no static power, nothing to integrate
        self.now = now;
    }
}

impl MemoryBackend for RramBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::Rram
    }

    fn capacity(&self) -> usize {
        self.data.len()
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.data.len(), "write out of range");
        self.advance_to(now);
        self.data[addr..addr + data.len()].copy_from_slice(data);
        self.meter.write_j += self.rram.write_energy(data.len());
        self.meter.busy_s += self.rram.write_latency_ns * 1e-9;
        self.meter.writes += 1;
        self.meter.bytes_written += data.len() as u64;
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.data.len(), "read out of range");
        self.advance_to(now);
        self.meter.read_j += self.rram.read_energy(len);
        self.meter.busy_s += self.rram.read_latency_ns * 1e-9;
        self.meter.reads += 1;
        self.meter.bytes_read += len as u64;
        self.data[addr..addr + len].to_vec()
    }

    fn tick(&mut self, now: f64) {
        self.advance_to(now);
    }

    fn refresh_due(&self) -> Option<f64> {
        None
    }

    fn meter(&self) -> &EnergyMeter {
        &self.meter
    }

    fn energy_card(&self) -> &EnergyCard {
        &self.card
    }
}

// ---------------------------------------------------------------------------
// MRAM — non-volatile, retention-tunable write rail.
// ---------------------------------------------------------------------------

/// The STT/SOT-MRAM buffer: zero standby power and no refresh like RRAM,
/// but the write energy/latency scale with the *retention target* — the
/// spec's `@ret=SECONDS` knob ([`crate::mem::mram`]). One struct covers
/// both flavors; the [`crate::mem::mram::MramCard`] carries the per-kind
/// calibration.
pub struct MramBackend {
    spec: BackendSpec,
    data: Vec<u8>,
    mram: crate::mem::mram::MramCard,
    card: EnergyCard,
    meter: EnergyMeter,
    now: f64,
}

impl MramBackend {
    pub fn new(spec: BackendSpec, bytes: usize) -> Self {
        let (mram, card) = match &spec {
            BackendSpec::Sttmram { ret } => {
                (crate::mem::mram::MramCard::stt(*ret), EnergyCard::sttmram(*ret))
            }
            BackendSpec::Sotmram { ret } => {
                (crate::mem::mram::MramCard::sot(*ret), EnergyCard::sotmram(*ret))
            }
            other => panic!("MramBackend::new on non-MRAM spec {other}"),
        };
        let cap = MemoryMap::with_capacity(bytes).capacity();
        MramBackend { spec, data: vec![0; cap], mram, card, meter: EnergyMeter::default(), now: 0.0 }
    }

    fn advance_to(&mut self, now: f64) {
        assert!(now + 1e-15 >= self.now, "time must be monotone");
        // non-volatile: no static power, nothing to integrate
        self.now = now;
    }
}

impl MemoryBackend for MramBackend {
    fn spec(&self) -> BackendSpec {
        self.spec.clone()
    }

    fn capacity(&self) -> usize {
        self.data.len()
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.data.len(), "write out of range");
        self.advance_to(now);
        self.data[addr..addr + data.len()].copy_from_slice(data);
        self.meter.write_j += self.mram.write_energy(data.len());
        self.meter.busy_s += self.mram.write_latency_ns * 1e-9;
        self.meter.writes += 1;
        self.meter.bytes_written += data.len() as u64;
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.data.len(), "read out of range");
        self.advance_to(now);
        self.meter.read_j += self.mram.read_energy(len);
        self.meter.busy_s += self.mram.read_latency_ns * 1e-9;
        self.meter.reads += 1;
        self.meter.bytes_read += len as u64;
        self.data[addr..addr + len].to_vec()
    }

    fn tick(&mut self, now: f64) {
        self.advance_to(now);
    }

    fn refresh_due(&self) -> Option<f64> {
        None
    }

    fn meter(&self) -> &EnergyMeter {
        &self.meter
    }

    fn energy_card(&self) -> &EnergyCard {
        &self.card
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiled_macro_becomes_a_runnable_backend() {
        use crate::dse::space::DesignPoint;
        use crate::mem::compiler::compile;

        // a non-default generated geometry: 512 × 128 B banks
        let point =
            DesignPoint { rows: 512, row_bytes: 128, ecc: true, ..DesignPoint::paper() };
        let mspec = compile(&point, 64 * 1024).unwrap();
        let mut b = McaimemBackend::from_macro(&mspec, 0xC0DE).unwrap();
        assert_eq!(b.capacity(), 64 * 1024);
        assert_eq!(b.mem.map.bank.rows, 512);
        assert_eq!(b.rows_per_bank(), 512);
        assert!(b.mem.ecc_enabled && b.mem.encode_enabled);
        let data: Vec<u8> = (0..256).map(|i| i as u8).collect();
        b.store(777, &data, 1e-9);
        assert_eq!(b.load(777, data.len(), 2e-9), data);

        // the compiler refuses to hand non-representable compositions to
        // the functional array
        let odd = compile(&DesignPoint { ratio: 5, ..DesignPoint::paper() }, 64 * 1024).unwrap();
        assert!(McaimemBackend::from_macro(&odd, 1).is_err());

        // geometry-parameterized build: only the mixed-cell array re-shapes
        let bank = crate::mem::bank::BankGeometry::new(16 * 1024, 128);
        let g = build_with_geometry(&BackendSpec::mcaimem_default(), 64 * 1024, bank, 7);
        assert_eq!(g.unwrap().capacity(), 64 * 1024);
        assert!(build_with_geometry(&BackendSpec::Sram, 64 * 1024, bank, 7).is_err());
    }

    #[test]
    fn spec_roundtrip_canonical_forms() {
        for s in [
            "sram",
            "edram2t",
            "rram",
            "mcaimem@0.8",
            "mcaimem@0.7-noenc",
            "mcaimem@0.55",
            "mcaimem@0.8+ecc",
            "mcaimem@0.7-noenc+ecc",
        ] {
            let spec: BackendSpec = s.parse().unwrap();
            assert_eq!(spec.to_string(), s, "{s}");
            let again: BackendSpec = spec.to_string().parse().unwrap();
            assert_eq!(again, spec, "{s}");
        }
    }

    #[test]
    fn spec_aliases_and_normalization() {
        assert_eq!("mcaimem".parse::<BackendSpec>().unwrap(), BackendSpec::mcaimem_default());
        assert_eq!("MCAIMem@0.80".parse::<BackendSpec>().unwrap().to_string(), "mcaimem@0.8");
        assert_eq!(" SRAM ".parse::<BackendSpec>().unwrap(), BackendSpec::Sram);
    }

    #[test]
    fn spec_grammar_rejects_garbage() {
        for s in [
            "",
            "sram@0.8",
            "mcaimem@",
            "mcaimem@abc",
            "edram",
            "mcaimem@0.8-enc",
            "mcaimem@9.9",
            "sram+ecc",
            "rram+ecc",
            "mcaimem@0.8+ecc2",
        ] {
            assert!(s.parse::<BackendSpec>().is_err(), "`{s}` must not parse");
        }
    }

    #[test]
    fn parse_list_sweeps() {
        let specs = BackendSpec::parse_list("sram, edram2t ,mcaimem@0.8,mcaimem@0.7-noenc").unwrap();
        assert_eq!(specs.len(), 4);
        assert!(BackendSpec::parse_list("  ,, ").is_err());
    }

    #[test]
    fn parse_list_dedupes_order_preserving() {
        // repeated specs collapse to the first occurrence, order kept
        let specs = BackendSpec::parse_list("sram,sram,mcaimem@0.8,sram,edram2t").unwrap();
        assert_eq!(
            specs,
            vec![BackendSpec::Sram, BackendSpec::mcaimem_default(), BackendSpec::Edram2t]
        );
        // dedup is on the parsed value: textual variants of one spec merge
        let specs = BackendSpec::parse_list("mcaimem@0.80,MCAIMem@0.8,mcaimem").unwrap();
        assert_eq!(specs, vec![BackendSpec::mcaimem_default()]);
        // distinct V_REFs / encoder settings are distinct specs
        let specs =
            BackendSpec::parse_list("mcaimem@0.8,mcaimem@0.7,mcaimem@0.8-noenc").unwrap();
        assert_eq!(specs.len(), 3);
    }

    #[test]
    fn ratio_backend_area_and_label() {
        let default = McaimemBackend::new(64 * 1024, 0.8, true, 1);
        let r7 = McaimemBackend::with_ratio(64 * 1024, 0.8, true, 7, 1);
        assert_eq!(
            MemoryBackend::area(&default),
            MemoryBackend::area(&r7),
            "ratio 7 is the default composition"
        );
        assert_eq!(r7.label(), "MCAIMem@0.8");
        let r3 = McaimemBackend::with_ratio(64 * 1024, 0.8, true, 3, 1);
        assert!(
            MemoryBackend::area(&r3) > MemoryBackend::area(&r7),
            "more SRAM cells per byte must cost area"
        );
        assert_eq!(r3.label(), "MCAIMem@0.8 (1S3E)");
        // a ratio-3 array still round-trips data
        let mut r3 = r3;
        let data: Vec<u8> = (0..=255).collect();
        r3.store(0, &data, 1e-9);
        assert_eq!(r3.load(0, 256, 2e-9), data);
    }

    #[test]
    fn factory_builds_every_default_spec() {
        for spec in BackendSpec::default_sweep() {
            let b = build(&spec, 32 * 1024, 1);
            assert_eq!(b.spec(), spec);
            assert_eq!(b.capacity(), 32 * 1024);
            assert!(b.area() > 0.0);
            assert_eq!(b.label(), spec.label());
        }
    }

    #[test]
    fn simple_backends_roundtrip_bytes() {
        for spec in [BackendSpec::Sram, BackendSpec::Edram2t, BackendSpec::Rram] {
            let mut b = build(&spec, 16 * 1024, 3);
            let data: Vec<u8> = (0..=255).collect();
            b.store(100, &data, 1e-6);
            assert_eq!(b.load(100, 256, 2e-6), data, "{spec}");
            assert_eq!(b.meter().bytes_written, 256);
            assert_eq!(b.meter().bytes_read, 256);
        }
    }

    #[test]
    fn sram_and_rram_static_behaviour() {
        let mut s = build(&BackendSpec::Sram, 16 * 1024, 1);
        s.tick(1e-3);
        assert!(s.meter().static_j > 0.0, "SRAM leaks");
        let mut r = build(&BackendSpec::Rram, 16 * 1024, 1);
        r.tick(1e-3);
        assert_eq!(r.meter().static_j, 0.0, "RRAM is non-volatile");
        assert_eq!(r.refresh_due(), None);
        assert_eq!(s.refresh_due(), None);
    }

    #[test]
    fn edram2t_charges_refresh_with_time() {
        let mut e = build(&BackendSpec::Edram2t, 16 * 1024, 1);
        e.tick(13.1e-6); // just past ten 1.3 µs refresh periods
        assert!(e.meter().refresh_j > 0.0);
        assert_eq!(e.meter().refreshes, 10);
        // the all-ones power-on state is the cheap corner of the asymmetric
        // card: writing zeros must raise the static *and* refresh power
        let p0 = e.meter().total_j();
        let zeros = vec![0u8; 4096];
        e.store(0, &zeros, 14e-6);
        e.tick(26e-6);
        let grew_dirty = e.meter().total_j() - p0;
        assert!(grew_dirty > 0.0);
    }

    #[test]
    fn rram_write_asymmetry_through_the_meter() {
        let mut r = build(&BackendSpec::Rram, 16 * 1024, 1);
        r.store(0, &[7u8; 1024], 1e-6);
        let _ = r.load(0, 1024, 2e-6);
        let m = r.meter();
        assert!(m.write_j > 50.0 * m.read_j, "write {} vs read {}", m.write_j, m.read_j);
        assert!(m.busy_s > 0.0, "programming latency must accrue");
    }

    #[test]
    fn mcaimem_backend_is_the_functional_array() {
        let spec = BackendSpec::Mcaimem { vref: 0.8, encode: true, ecc: false };
        let mut b = build(&spec, 16 * 1024, 0xBEEF);
        assert!(b.refresh_due().is_some());
        assert_eq!(b.rows_per_bank(), 256);
        let data: Vec<u8> = (0..64).collect();
        b.store(0, &data, 1e-9);
        assert_eq!(b.load(0, 64, 2e-9), data);
        assert!(b.meter().write_j > 0.0 && b.meter().read_j > 0.0);
    }

    #[test]
    fn ecc_spec_builds_a_protected_array() {
        let spec: BackendSpec = "mcaimem@0.8+ecc".parse().unwrap();
        assert_eq!(spec, BackendSpec::Mcaimem { vref: 0.8, encode: true, ecc: true });
        assert_eq!(spec.label(), "MCAIMem@0.8+ECC");
        let mut b = build(&spec, 16 * 1024, 0xBEEF);
        assert_eq!(b.spec(), spec, "spec round-trips through build");
        // the check plane costs area but keeps the functional contract
        let plain = build(&BackendSpec::mcaimem_default(), 16 * 1024, 0xBEEF);
        assert!(b.area() > plain.area());
        let data: Vec<u8> = (0..64).collect();
        b.store(0, &data, 1e-9);
        assert_eq!(b.load(0, 64, 2e-9), data);
        // quarantine is refused by a flat array (no failover provisioning)
        assert!(!b.quarantine_shard(0, 3e-9));
    }

    #[test]
    fn area_ordering_matches_the_headline() {
        let sram = build(&BackendSpec::Sram, 1024 * 1024, 1).area();
        let ours = build(&BackendSpec::mcaimem_default(), 1024 * 1024, 1).area();
        let red = 1.0 - ours / sram;
        assert!((red - 0.48).abs() < 0.005, "reduction={red}");
    }

    #[test]
    fn mram_specs_roundtrip_and_retention_trades_write_cost() {
        // bare names are the archival default; the knob renders canonically
        for s in ["sttmram", "sotmram"] {
            let spec: BackendSpec = s.parse().unwrap();
            assert_eq!(spec.to_string(), s);
        }
        let spec: BackendSpec = "sotmram@ret=1e-3".parse().unwrap();
        assert_eq!(spec, BackendSpec::Sotmram { ret: 1e-3 });
        // Display is canonical decimal; the value round-trips exactly
        let again: BackendSpec = spec.to_string().parse().unwrap();
        assert_eq!(again, spec);

        // non-volatile: no static burn, no refresh, asymmetric writes
        let mut b = build(&spec, 16 * 1024, 1);
        b.store(0, &[0xA5; 1024], 1e-6);
        let _ = b.load(0, 1024, 2e-6);
        b.tick(1e-3);
        let m = b.meter();
        assert_eq!(m.static_j, 0.0);
        assert_eq!(m.refresh_j, 0.0);
        assert_eq!(b.refresh_due(), None);
        assert!(m.write_j > m.read_j, "MRAM writes dominate reads");
        assert!(m.busy_s > 0.0, "programming latency must accrue");

        // relaxing retention 10 yr → 1 ms must cheapen and speed up writes
        let mut archival = build(&"sotmram".parse().unwrap(), 16 * 1024, 1);
        archival.store(0, &[0xA5; 1024], 1e-6);
        let ma = archival.meter();
        assert!(m.write_j < ma.write_j, "{} !< {}", m.write_j, ma.write_j);
        assert!(m.busy_s < ma.busy_s);
        // while reads are retention-independent
        let _ = archival.load(0, 1024, 2e-6);
        assert_eq!(b.meter().read_j, archival.meter().read_j);
    }

    #[test]
    fn tiered_specs_roundtrip_recursively() {
        let spec: BackendSpec = "tiered=sram:32k+sotmram".parse().unwrap();
        assert_eq!(
            spec,
            BackendSpec::Tiered(
                Box::new(BackendSpec::Sram),
                32 * 1024,
                Box::new(BackendSpec::Sotmram { ret: BackendSpec::RET_DEFAULT }),
            )
        );
        assert_eq!(spec.to_string(), "tiered=sram:32k+sotmram");
        // raw-byte sizes canonicalize (32768 → 32k)
        assert_eq!(
            "tiered=sram:32768+sotmram".parse::<BackendSpec>().unwrap().to_string(),
            "tiered=sram:32k+sotmram"
        );
        // nested members parenthesize and re-parse to the same tree
        let nested: BackendSpec =
            "tiered=(tiered=sram:16k+edram2t):64k+rram".parse().unwrap();
        let printed = nested.to_string();
        assert_eq!(printed, "tiered=(tiered=sram:16k+edram2t):64k+rram");
        assert_eq!(printed.parse::<BackendSpec>().unwrap(), nested);
        // and build() produces a runnable device for the whole family
        let mut b = build(&spec, 64 * 1024, 7);
        let data: Vec<u8> = (0..=255).collect();
        b.store(4096, &data, 1e-6);
        assert_eq!(b.load(4096, 256, 2e-6), data);
        assert_eq!(b.shard_meters().len(), 2, "one meter per tier");

        for bad in [
            "tiered=sram+rram",          // missing :BYTES
            "tiered=sram:32k",           // missing +BACK
            "tiered=sram:33+rram",       // not a multiple of 64
            "tiered=sram:0k+rram",       // empty buffer
            "tiered=(sram:32k+rram",     // unbalanced paren
            "tiered=sram:32k+zzz",       // unknown back member
        ] {
            assert!(bad.parse::<BackendSpec>().is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn spec_errors_carry_spans_and_suggestions() {
        let err = "sttmrm".parse::<BackendSpec>().unwrap_err();
        assert_eq!(
            err,
            SpecError::Unknown {
                token: "sttmrm".into(),
                span: (0, 6),
                suggest: Some("sttmram"),
            }
        );
        let msg = err.to_string();
        assert!(msg.contains("did you mean `sttmram`?"), "{msg}");
        assert!(msg.contains("expected one of"), "{msg}");

        // spans are offsets into what the user actually typed (post-trim)
        let err = "  zzzzzz  ".parse::<BackendSpec>().unwrap_err();
        assert_eq!(err.span(), (2, 8));

        // parameter errors point at the offending parameter, not the head
        let err = "mcaimem@9.9".parse::<BackendSpec>().unwrap_err();
        assert!(matches!(err, SpecError::Param { .. }), "{err:?}");
        assert_eq!(err.span(), (8, 11));

        // a bad member inside a tiered spec keeps outer-string coordinates
        let err = "tiered=sram:32k+sttmrm".parse::<BackendSpec>().unwrap_err();
        assert_eq!(err.span(), (16, 22));
        assert!(err.to_string().contains("sttmram"), "{err}");
    }

    #[test]
    fn parse_list_reports_the_failing_element() {
        let err = BackendSpec::parse_list("sram,sttmrm").unwrap_err();
        let SpecError::ListElement { index, element, source } = &err else {
            panic!("expected ListElement, got {err:?}");
        };
        assert_eq!((*index, element.as_str()), (1, "sttmrm"));
        assert!(matches!(**source, SpecError::Unknown { .. }));
        assert!(err.to_string().contains("element 2"), "{err}");
        // dedupe keys on the canonical rendering: byte and suffix forms of
        // one tiered spec collapse
        let specs = BackendSpec::parse_list(
            "tiered=sram:32k+sotmram,tiered=sram:32768+sotmram",
        )
        .unwrap();
        assert_eq!(specs.len(), 1);
    }

    #[test]
    fn builder_collapses_the_constructor_zoo() {
        let spec = BackendSpec::mcaimem_default();
        // flat: same device the free function makes
        let b = Builder::new(spec.clone(), 32 * 1024).seed(7).build().unwrap();
        assert_eq!(b.spec(), spec);
        assert_eq!(b.capacity(), 32 * 1024);

        // sharded (+failover) in one chain
        let sh = Builder::new(spec.clone(), 64 * 1024)
            .seed(7)
            .shards(4)
            .build()
            .unwrap();
        assert_eq!(sh.shard_meters().len(), 4);
        let mut fo = Builder::new(spec.clone(), 64 * 1024)
            .seed(7)
            .shards(4)
            .failover(true)
            .build()
            .unwrap();
        assert!(fo.quarantine_shard(0, 1e-9), "failover provisioning must accept");

        // explicit ratio is mcaimem-only
        assert!(Builder::new(spec.clone(), 32 * 1024).ratio(3).build().is_ok());
        assert!(Builder::new(BackendSpec::Sram, 32 * 1024).ratio(3).build().is_err());

        // conflicting options are refused, not silently resolved
        let bank = crate::mem::bank::BankGeometry::new(16 * 1024, 128);
        assert!(Builder::new(spec.clone(), 32 * 1024)
            .geometry(bank)
            .ratio(3)
            .build()
            .is_err());
        assert!(Builder::new(spec.clone(), 32 * 1024).failover(true).build().is_err());
        assert!(Builder::new(BackendSpec::Sram, 32 * 1024).geometry(bank).build().is_err());

        // recording wraps any shape and logs geometry into the header
        let (mut traced, handle) = Builder::new(spec, 32 * 1024)
            .seed(7)
            .geometry(bank)
            .recording()
            .unwrap();
        traced.store(0, &[1, 2, 3], 1e-9);
        let t = handle.lock().unwrap();
        assert_eq!(t.geom, Some(bank));
        assert!(!t.entries.is_empty());
    }

    #[test]
    fn builder_builds_tiered_and_mram_specs() {
        for s in ["sttmram", "sotmram@ret=1e-3", "tiered=sram:16k+rram"] {
            let spec: BackendSpec = s.parse().unwrap();
            let b = Builder::new(spec.clone(), 32 * 1024).seed(3).build().unwrap();
            assert_eq!(b.spec(), spec, "{s}");
            assert_eq!(b.capacity(), 32 * 1024, "{s}");
        }
        // striped tiered devices: each shard is a full two-tier stack
        let spec: BackendSpec = "tiered=sram:16k+sotmram".parse().unwrap();
        let sh = Builder::new(spec, 128 * 1024).seed(9).shards(4).build().unwrap();
        assert_eq!(sh.capacity(), 128 * 1024);
        assert_eq!(sh.shard_meters().len(), 4);
    }
}
