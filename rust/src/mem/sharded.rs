//! Banked serving buffer: stripe any [`BackendSpec`] across N shards.
//!
//! The paper's per-macro claims (48 % area, 3.4× energy) deploy, in a real
//! accelerator, as *banked* buffers behind a serving front-end. This module
//! scales the buffer *up* without touching the backend zoo: a
//! [`ShardedBackend`] holds N independently-clocked shards of the same
//! technology and presents them as one [`MemoryBackend`]:
//!
//! * **Striping** — the address space is interleaved at [`STRIPE`]-byte
//!   granularity (the word-parallel block size, so aligned accesses stay on
//!   the SWAR fast path inside each shard): global byte `a` lives in shard
//!   `(a / STRIPE) % n` at local offset `(a / (STRIPE·n))·STRIPE + a %
//!   STRIPE`. A contiguous store/load fans out round-robin, so traffic —
//!   and therefore dynamic energy — balances across shards.
//! * **Independent clocks** — each shard advances its own device clock only
//!   when it is accessed or ticked; `tick` brings all shards to `now`.
//! * **Merged meters** — every shard charges its own [`EnergyMeter`]; the
//!   trait-level [`MemoryBackend::meter`] is the field-wise sum, refreshed
//!   after every mutating call, and [`MemoryBackend::shard_meters`] exposes
//!   the per-shard break-down for serving stats. Striping conserves bytes
//!   and data values, so the merged meter matches an unsharded array of the
//!   same total capacity on identical traffic (within the statistical
//!   wobble of per-shard weak-cell populations — tested to 1 %).
//! * **Staggered refresh** — one manager-driven refresh slot maps to row
//!   `(row + shard·rows/n) mod rows` in each shard, so no two shards
//!   refresh the same row index in the same slot: refresh current draw is
//!   spread evenly across the banks instead of pulsing the whole macro.

use anyhow::{bail, Result};

use super::backend::{self, BackendSpec, MemoryBackend};
use super::energy::EnergyCard;
use super::mcaimem::EnergyMeter;
use crate::util::rng::shard_seeds;

/// Striping granularity (bytes): the word-parallel block size, so aligned
/// traffic stays block-aligned inside every shard.
pub const STRIPE: usize = 64;

/// The row a manager-driven refresh slot `row` maps to in shard `shard` of
/// `n` shards over `rows` rows per bank: `(row + shard·⌊rows/n⌋) mod rows`.
/// For every shard this is a rotation of `0..rows` — a bijection — so one
/// full period of slots refreshes **every row of every shard exactly once**,
/// including when `rows % n != 0` (the phase need not divide `rows`; any
/// constant offset rotates the cycle without dropping or doubling a row).
#[inline]
pub fn staggered_row(row: usize, shard: usize, rows: usize, n: usize) -> usize {
    let phase = (rows / n).max(1);
    (row + shard * phase) % rows
}

/// N independently-clocked shards of one backend technology behind the
/// single-array device API.
///
/// With [`ShardedBackend::with_failover`] the buffer is provisioned for
/// **single-shard-outage tolerance**: every shard is built at twice its
/// logical size, the upper half serving as the mirror region for its
/// *predecessor* — shard `s`'s data is duplicated into shard `(s+1) % n` at
/// local offset `logical + addr`. Stores write both copies (the energy cost
/// of provisioning is metered honestly); after
/// [`MemoryBackend::quarantine_shard`] declares shard `s` dead, loads that
/// would route to it are served from the buddy mirror, dead silicon stops
/// refreshing and ticking, and new stores skip dead primaries/mirrors. One
/// outage is survivable by construction; a second outage may lose the
/// un-mirrored remainder (exactly like RAID-1 degraded mode).
pub struct ShardedBackend {
    spec: BackendSpec,
    shards: Vec<Box<dyn MemoryBackend>>,
    /// Field-wise sum of the shard meters, refreshed after every mutating
    /// call (so `meter()` can hand out a plain reference).
    merged: EnergyMeter,
    card: EnergyCard,
    shard_capacity: usize,
    /// Failover provisioning active (`with_failover` construction).
    failover: bool,
    /// Logical bytes each shard serves in failover mode; also the local
    /// offset where a shard's buddy-mirror region starts.
    mirror_base: usize,
    quarantined: Vec<bool>,
    /// Telemetry sink + global shard-track base (disabled by default;
    /// see [`MemoryBackend::attach_obs`]).
    obs: crate::obs::ObsSink,
    obs_base: u32,
}

impl ShardedBackend {
    /// Build `n` shards of `spec`, `bytes` total (each shard gets
    /// `bytes / n`, rounded up to whole banks by the backend factory).
    /// Shard seeds derive deterministically from `seed`, so each shard has
    /// its own weak-cell population — as N physically distinct banks would.
    pub fn new(spec: &BackendSpec, n: usize, bytes: usize, seed: u64) -> Result<Self> {
        if n == 0 {
            bail!("sharded backend needs at least one shard");
        }
        if bytes % n != 0 {
            bail!("buffer bytes {bytes} not divisible by {n} shards");
        }
        // the striped address map is a bijection only when every shard is
        // a whole number of stripes
        if (bytes / n) % STRIPE != 0 {
            bail!(
                "shard size {} is not a multiple of the {STRIPE}-byte stripe",
                bytes / n
            );
        }
        let seeds = shard_seeds(seed, n);
        let shards: Vec<Box<dyn MemoryBackend>> =
            seeds.iter().map(|&s| backend::build(spec, bytes / n, s)).collect();
        let shard_capacity = shards[0].capacity();
        let mut b = ShardedBackend {
            spec: spec.clone(),
            shards,
            merged: EnergyMeter::default(),
            card: spec.energy_card(),
            shard_capacity,
            failover: false,
            mirror_base: 0,
            quarantined: vec![false; n],
            obs: crate::obs::ObsSink::disabled(),
            obs_base: 0,
        };
        b.remerge();
        Ok(b)
    }

    /// Build `n` shards serving `bytes` logical total, each provisioned at
    /// twice its logical size so the upper half mirrors its predecessor
    /// shard (see the type docs). `n >= 2`: a lone shard has no buddy.
    pub fn with_failover(spec: &BackendSpec, n: usize, bytes: usize, seed: u64) -> Result<Self> {
        if n < 2 {
            bail!("failover provisioning needs at least 2 shards (a lone shard has no buddy)");
        }
        if bytes % n != 0 {
            bail!("buffer bytes {bytes} not divisible by {n} shards");
        }
        if (bytes / n) % STRIPE != 0 {
            bail!("shard size {} is not a multiple of the {STRIPE}-byte stripe", bytes / n)
        }
        let mirror_base = bytes / n;
        let seeds = shard_seeds(seed, n);
        let shards: Vec<Box<dyn MemoryBackend>> =
            seeds.iter().map(|&s| backend::build(spec, 2 * mirror_base, s)).collect();
        let shard_capacity = shards[0].capacity();
        let mut b = ShardedBackend {
            spec: spec.clone(),
            shards,
            merged: EnergyMeter::default(),
            card: spec.energy_card(),
            shard_capacity,
            failover: true,
            mirror_base,
            quarantined: vec![false; n],
            obs: crate::obs::ObsSink::disabled(),
            obs_base: 0,
        };
        b.remerge();
        Ok(b)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shards still in service (all of them until a quarantine fires).
    pub fn alive_shards(&self) -> usize {
        self.quarantined.iter().filter(|&&q| !q).count()
    }

    fn remerge(&mut self) {
        let mut m = EnergyMeter::default();
        for s in &self.shards {
            m.merge(s.meter());
        }
        self.merged = m;
    }

    /// Walk a global `[addr, addr+len)` range as (shard, local_addr,
    /// global_offset, chunk_len) stripe pieces.
    fn chunks(&self, addr: usize, len: usize) -> impl Iterator<Item = (usize, usize, usize, usize)> {
        let n = self.shards.len();
        let mut a = addr;
        let end = addr + len;
        std::iter::from_fn(move || {
            if a >= end {
                return None;
            }
            let block = a / STRIPE;
            let lane = a % STRIPE;
            let shard = block % n;
            let local = (block / n) * STRIPE + lane;
            let take = (STRIPE - lane).min(end - a);
            let piece = (shard, local, a - addr, take);
            a += take;
            Some(piece)
        })
    }
}

impl MemoryBackend for ShardedBackend {
    fn spec(&self) -> BackendSpec {
        self.spec.clone()
    }

    fn capacity(&self) -> usize {
        if self.failover {
            // the mirror half of every shard is provisioning, not capacity
            self.mirror_base * self.shards.len()
        } else {
            self.shard_capacity * self.shards.len()
        }
    }

    fn now(&self) -> f64 {
        // shards are independently clocked; the array-level clock is the
        // furthest-advanced shard
        self.shards.iter().map(|s| s.now()).fold(0.0, f64::max)
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.capacity(), "write out of range");
        let (n, base) = (self.shards.len(), self.mirror_base);
        let pieces: Vec<_> = self.chunks(addr, data.len()).collect();
        for (shard, local, off, len) in pieces {
            let slice = &data[off..off + len];
            if self.failover {
                if !self.quarantined[shard] {
                    self.shards[shard].store(local, slice, now);
                }
                let buddy = (shard + 1) % n;
                if !self.quarantined[buddy] {
                    self.shards[buddy].store(base + local, slice, now);
                }
            } else {
                self.shards[shard].store(local, slice, now);
            }
        }
        self.remerge();
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.capacity(), "read out of range");
        let (n, base) = (self.shards.len(), self.mirror_base);
        let mut out = vec![0u8; len];
        let pieces: Vec<_> = self.chunks(addr, len).collect();
        for (shard, local, off, clen) in pieces {
            let piece = if self.failover && self.quarantined[shard] {
                // degraded mode: the buddy's mirror region serves the read
                self.shards[(shard + 1) % n].load(base + local, clen, now)
            } else {
                self.shards[shard].load(local, clen, now)
            };
            out[off..off + clen].copy_from_slice(&piece);
        }
        self.remerge();
        out
    }

    fn tick(&mut self, now: f64) {
        for (i, s) in self.shards.iter_mut().enumerate() {
            if !self.quarantined[i] {
                s.tick(now);
            }
        }
        self.remerge();
    }

    fn refresh_due(&self) -> Option<f64> {
        self.shards[0].refresh_due()
    }

    /// The shards are independently clocked: a quarantined shard stops
    /// ticking and its clock freezes where it died, while the survivors
    /// keep advancing. A refresh-aware dispatcher reads these to confirm
    /// every live shard sits on the same slot grid before planning batch
    /// windows into the inter-slot slack.
    fn shard_clocks(&self) -> Vec<f64> {
        self.shards.iter().map(|s| s.now()).collect()
    }

    /// One manager slot refreshes a *different* row in every shard
    /// (staggered by `rows/n`), so the whole array still turns over within
    /// one refresh period but no two shards pulse the same row index in
    /// the same slot.
    fn refresh_row(&mut self, row: usize, now: f64) {
        let rows = self.rows_per_bank();
        let n = self.shards.len();
        for (i, s) in self.shards.iter_mut().enumerate() {
            if !self.quarantined[i] {
                s.refresh_row(staggered_row(row, i, rows, n), now);
            }
        }
        self.remerge();
    }

    fn rows_per_bank(&self) -> usize {
        self.shards[0].rows_per_bank()
    }

    fn meter(&self) -> &EnergyMeter {
        &self.merged
    }

    fn shard_meters(&self) -> Vec<EnergyMeter> {
        self.shards.iter().map(|s| s.meter().clone()).collect()
    }

    fn energy_card(&self) -> &EnergyCard {
        &self.card
    }

    /// Declare a shard dead. Honoured only under failover provisioning —
    /// without a mirror there is nowhere to route its data, so the plain
    /// geometry keeps the default no-op contract and returns `false`.
    fn quarantine_shard(&mut self, shard: usize, now: f64) -> bool {
        if !self.failover || shard >= self.shards.len() {
            return false;
        }
        self.quarantined[shard] = true;
        self.obs.emit(crate::obs::Event::instant(
            crate::obs::EventKind::ShardFailover,
            self.obs_base + shard as u32,
            now * 1e6,
            shard as u64,
            ((shard + 1) % self.shards.len()) as u64,
        ));
        true
    }

    fn attach_obs(&mut self, sink: &crate::obs::ObsSink, track_base: u32) {
        self.obs = sink.clone();
        self.obs_base = track_base;
        // leaf shards are flat arrays (the trait default ignores this),
        // but forward anyway so a nested structural backend keeps working
        for (i, s) in self.shards.iter_mut().enumerate() {
            s.attach_obs(sink, track_base + i as u32);
        }
    }

    fn label(&self) -> String {
        let fo = if self.failover { "+failover" } else { "" };
        format!("{}×{}{}", self.spec.label(), self.shards.len(), fo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drive(mem: &mut dyn MemoryBackend, seed: u64) -> Vec<u8> {
        // a deterministic mixed workload: aligned + unaligned stores/loads
        // with interleaved ticks
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let mut t = 0.0;
        let mut echo = Vec::new();
        for i in 0..40 {
            let len = [64usize, 256, 100, 1024][i % 4];
            let addr = (i * 977) % (mem.capacity() - len);
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            t += 1e-6;
            mem.store(addr, &data, t);
            t += 1e-6;
            echo.extend(mem.load(addr, len, t));
            mem.tick(t + 0.5e-6);
            t += 0.5e-6;
        }
        echo
    }

    #[test]
    fn striping_roundtrips_bytes_exactly() {
        for spec in [BackendSpec::Sram, BackendSpec::mcaimem_default()] {
            let mut sh = ShardedBackend::new(&spec, 4, 64 * 1024, 9).unwrap();
            let data: Vec<u8> = (0..997).map(|i| (i * 31) as u8).collect();
            sh.store(129, &data, 1e-6); // deliberately unaligned
            assert_eq!(sh.load(129, data.len(), 2e-6), data, "{spec}");
            assert_eq!(sh.meter().bytes_written, 997);
            assert_eq!(sh.meter().bytes_read, 997);
        }
    }

    #[test]
    fn address_map_is_a_bijection() {
        // every global address maps to a unique (shard, local) slot and
        // chunks tile the range exactly
        let sh = ShardedBackend::new(&BackendSpec::Sram, 4, 64 * 1024, 1).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for a in 0..4096usize {
            let pieces: Vec<_> = sh.chunks(a, 1).collect();
            assert_eq!(pieces.len(), 1);
            let (shard, local, off, len) = pieces[0];
            assert_eq!((off, len), (0, 1));
            assert!(local < sh.shard_capacity);
            assert!(seen.insert((shard, local)), "alias at {a}");
        }
        // one full-stripe-width range covers all shards evenly
        let pieces: Vec<_> = sh.chunks(0, 4 * STRIPE).collect();
        let shards: Vec<usize> = pieces.iter().map(|p| p.0).collect();
        assert_eq!(shards, vec![0, 1, 2, 3]);
    }

    #[test]
    fn merged_meter_matches_unsharded_within_1pct() {
        for spec in
            [BackendSpec::Sram, BackendSpec::Edram2t, BackendSpec::Rram, BackendSpec::mcaimem_default()]
        {
            let mut flat = backend::build(&spec, 64 * 1024, 7);
            let mut sh = ShardedBackend::new(&spec, 4, 64 * 1024, 7).unwrap();
            assert_eq!(flat.capacity(), sh.capacity(), "{spec}");
            let a = drive(flat.as_mut(), 33);
            let b = drive(&mut sh, 33);
            // data round-trips identically except for mcaimem's per-cell
            // weak-bit wobble (different shard seeds → different corners)
            if !matches!(spec, BackendSpec::Mcaimem { .. }) {
                assert_eq!(a, b, "{spec}");
            }
            let (fm, sm) = (flat.meter(), sh.meter());
            assert_eq!(fm.bytes_written, sm.bytes_written, "{spec}");
            assert_eq!(fm.bytes_read, sm.bytes_read, "{spec}");
            let rel = (fm.total_j() - sm.total_j()).abs() / fm.total_j().max(1e-30);
            assert!(rel < 0.01, "{spec}: flat={} sharded={} rel={rel}", fm.total_j(), sm.total_j());
        }
    }

    #[test]
    fn shard_meters_sum_to_the_merged_meter() {
        let mut sh = ShardedBackend::new(&BackendSpec::mcaimem_default(), 4, 64 * 1024, 3).unwrap();
        let _ = drive(&mut sh, 5);
        let per = sh.shard_meters();
        assert_eq!(per.len(), 4);
        let mut sum = EnergyMeter::default();
        for m in &per {
            sum.merge(m);
        }
        assert!((sum.total_j() - sh.meter().total_j()).abs() < 1e-18);
        assert_eq!(sum.bytes_written, sh.meter().bytes_written);
        // striping balances traffic: no shard is starved
        for m in &per {
            assert!(m.bytes_written > 0, "striping must spread writes");
        }
    }

    #[test]
    fn refresh_is_staggered_across_shards() {
        let mut sh = ShardedBackend::new(&BackendSpec::mcaimem_default(), 4, 64 * 1024, 3).unwrap();
        assert!(sh.refresh_due().is_some());
        let rows = sh.rows_per_bank();
        // slot 0 must hit 4 distinct row indices: 0, 64, 128, 192 for 256
        // rows / 4 shards
        let phase = rows / 4;
        let expect: Vec<usize> = (0..4).map(|i| (i * phase) % rows).collect();
        let distinct: std::collections::BTreeSet<_> = expect.iter().collect();
        assert_eq!(distinct.len(), 4, "stagger phases collide");
        let before = sh.shard_meters();
        sh.refresh_row(0, 1e-6);
        let after = sh.shard_meters();
        for (b, a) in before.iter().zip(&after) {
            assert_eq!(a.refreshes, b.refreshes + 1, "every shard refreshes each slot");
        }
    }

    #[test]
    fn stagger_covers_every_row_exactly_once_even_when_rows_dont_divide() {
        // one full period of manager slots (row = 0..rows) must hit every
        // row of every shard exactly once — including shard counts that do
        // NOT divide the 256 rows (the invariant was previously asserted
        // only in prose). The stagger is a rotation, so any phase works;
        // this pins it for the awkward counts.
        let rows = 256;
        for n in [2usize, 3, 5, 6, 7, 9] {
            for shard in 0..n {
                let mut seen = vec![false; rows];
                for row in 0..rows {
                    let r = staggered_row(row, shard, rows, n);
                    assert!(r < rows);
                    assert!(!seen[r], "n={n} shard={shard}: row {r} refreshed twice");
                    seen[r] = true;
                }
                assert!(seen.iter().all(|&s| s), "n={n} shard={shard}: a row was starved");
            }
            // distinct shards refresh distinct rows within one slot while
            // n <= rows/phase (true for all n <= 16 at 256 rows)
            let slot0: std::collections::BTreeSet<usize> =
                (0..n).map(|s| staggered_row(0, s, rows, n)).collect();
            assert_eq!(slot0.len(), n, "n={n}: stagger phases collide in slot 0");
        }
    }

    #[test]
    fn non_divisible_shard_count_refreshes_through_the_device_api() {
        // 3 shards × 16 KB: 256 % 3 != 0 — drive one full period of slots
        // and check every shard saw exactly `rows` refresh ops
        let spec = BackendSpec::mcaimem_default();
        let mut sh = ShardedBackend::new(&spec, 3, 48 * 1024, 5).unwrap();
        let rows = sh.rows_per_bank();
        let slot = sh.refresh_due().unwrap() / rows as f64;
        for row in 0..rows {
            sh.refresh_row(row, (row + 1) as f64 * slot);
        }
        for (i, m) in sh.shard_meters().iter().enumerate() {
            assert_eq!(m.refreshes, rows as u64, "shard {i} must refresh once per slot");
        }
        assert_eq!(sh.meter().refreshes, 3 * rows as u64);
    }

    #[test]
    fn failover_survives_a_shard_outage_with_no_data_loss() {
        let spec = BackendSpec::mcaimem_default();
        let mut sh = ShardedBackend::with_failover(&spec, 4, 64 * 1024, 9).unwrap();
        // the mirror half is provisioning, not served capacity
        assert_eq!(sh.capacity(), 64 * 1024);
        assert_eq!(sh.alive_shards(), 4);
        assert!(sh.label().ends_with("+failover"), "{}", sh.label());
        // ns-scale gaps: every access is inside every cell's retention, so
        // byte-exactness is purely a routing property
        let data: Vec<u8> = (0..4096u32).map(|i| (i * 31 + 7) as u8).collect();
        sh.store(100, &data, 1e-6);
        // mirrored stores are metered honestly: both copies charge writes
        assert_eq!(sh.meter().bytes_written, 2 * 4096);
        assert!(sh.quarantine_shard(1, 1e-6 + 1e-9));
        assert_eq!(sh.alive_shards(), 3);
        // reads that would route to the dead shard come from the buddy
        assert_eq!(sh.load(100, data.len(), 1e-6 + 2e-9), data);
        // dead silicon stops refreshing and ticking
        let before = sh.shard_meters()[1].clone();
        sh.refresh_row(0, 1e-6 + 3e-9);
        sh.tick(1e-6 + 4e-9);
        let after = sh.shard_meters()[1].clone();
        assert_eq!(after.refreshes, before.refreshes);
        assert_eq!(after.static_j.to_bits(), before.static_j.to_bits());
        // degraded-mode stores keep round-tripping
        sh.store(0, &[0xA5; 1024], 1e-6 + 5e-9);
        assert_eq!(sh.load(0, 1024, 1e-6 + 6e-9), vec![0xA5; 1024]);
    }

    #[test]
    fn plain_geometry_refuses_quarantine() {
        // without the mirror provisioning there is nowhere to route data —
        // the default no-op contract holds and nothing changes
        let mut sh = ShardedBackend::new(&BackendSpec::Sram, 4, 64 * 1024, 1).unwrap();
        assert!(!sh.quarantine_shard(0, 1e-6));
        assert_eq!(sh.alive_shards(), 4);
        let data = vec![7u8; 256];
        sh.store(0, &data, 2e-6);
        assert_eq!(sh.load(0, 256, 3e-6), data);
        // failover needs a buddy
        assert!(ShardedBackend::with_failover(&BackendSpec::Sram, 1, 16 * 1024, 1).is_err());
    }

    #[test]
    fn shards_are_independently_clocked() {
        let mut sh = ShardedBackend::new(&BackendSpec::Sram, 2, 32 * 1024, 1).unwrap();
        // an access touching only shard 0 (first stripe) advances only its
        // clock
        sh.store(0, &[1u8; 16], 5e-6);
        assert_eq!(sh.shards[0].now(), 5e-6);
        assert_eq!(sh.shards[1].now(), 0.0);
        assert_eq!(sh.now(), 5e-6);
        sh.tick(7e-6);
        assert_eq!(sh.shards[1].now(), 7e-6);
    }

    #[test]
    fn shard_clocks_expose_the_per_shard_refresh_grid() {
        let mut sh = ShardedBackend::with_failover(&BackendSpec::Sram, 2, 32 * 1024, 1).unwrap();
        assert_eq!(sh.shard_clocks(), vec![0.0, 0.0]);
        sh.tick(3e-6);
        assert_eq!(sh.shard_clocks(), vec![3e-6, 3e-6], "ticked shards share a grid");
        // a quarantined shard's clock freezes where it died — the signal a
        // refresh-aware dispatcher uses to drop it from window planning
        assert!(sh.quarantine_shard(1, 3e-6));
        sh.tick(9e-6);
        assert_eq!(sh.shard_clocks(), vec![9e-6, 3e-6]);
        // flat backends report a singleton via the trait default
        let flat = crate::mem::backend::build(&BackendSpec::Sram, 16 * 1024, 1);
        assert_eq!(flat.shard_clocks().len(), 1);
    }

    #[test]
    fn bad_geometry_is_a_clean_error() {
        assert!(ShardedBackend::new(&BackendSpec::Sram, 0, 64 * 1024, 1).is_err());
        assert!(ShardedBackend::new(&BackendSpec::Sram, 3, 64 * 1024 + 1, 1).is_err());
        // divisible by n but shard size not a whole number of stripes
        assert!(ShardedBackend::new(&BackendSpec::Sram, 2, 192, 1).is_err());
    }
}
