//! RRAM on-chip-buffer baseline (§V-B, Fig. 15b).
//!
//! The paper models a resistive-RAM buffer after Chimera [34]: non-volatile,
//! so **no static power is charged** ("we attribute no static power to RRAM,
//! given that its non-volatile memory can toggle on and off without data
//! loss"), but writes are slow and expensive — which is why it loses by
//! >100× overall on write-heavy DNN buffering (activations are rewritten
//! every layer).

use crate::util::units::PICO;

/// RRAM per-access energy card (per byte).
#[derive(Clone, Copy, Debug)]
pub struct RramCard {
    pub read_j_per_byte: f64,
    pub write_j_per_byte: f64,
    /// Write latency (ns) — carried for completeness; the paper's energy
    /// comparison is the headline, but the latency also gates on-chip
    /// training viability (§I's argument against NVM buffers).
    pub write_latency_ns: f64,
    pub read_latency_ns: f64,
}

impl RramCard {
    /// Foundry ReRAM after [34]-class reporting: reads are SRAM-like in
    /// cost; SET/RESET programming needs multi-pulse write-verify loops —
    /// hundreds of pJ per byte and ~100 ns (Chimera stages data in SRAM
    /// precisely to dodge this write path).
    pub fn chimera_like() -> Self {
        RramCard {
            read_j_per_byte: 3.0 * PICO,
            write_j_per_byte: 300.0 * PICO,
            write_latency_ns: 100.0,
            read_latency_ns: 5.0,
        }
    }

    /// Read energy (J) for `bytes`.
    pub fn read_energy(&self, bytes: usize) -> f64 {
        self.read_j_per_byte * bytes as f64
    }

    /// Write energy (J) for `bytes`.
    pub fn write_energy(&self, bytes: usize) -> f64 {
        self.write_j_per_byte * bytes as f64
    }

    /// RRAM needs no refresh and burns no standby power.
    pub fn static_power(&self) -> f64 {
        0.0
    }

    /// Write-to-read energy asymmetry — the quantity that sinks NVM buffers
    /// for DNN workloads (§I: "the write operation in a nonvolatile memory
    /// is slower and consumes higher energy than the read").
    pub fn write_read_ratio(&self) -> f64 {
        self.write_j_per_byte / self.read_j_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::energy::EnergyCard;

    #[test]
    fn writes_dominate() {
        let r = RramCard::chimera_like();
        assert!(r.write_read_ratio() >= 10.0);
        assert!(r.write_latency_ns > 10.0 * r.read_latency_ns);
    }

    #[test]
    fn rram_write_much_costlier_than_sram() {
        let r = RramCard::chimera_like();
        let s = EnergyCard::sram();
        let ratio = r.write_energy(1024) / s.write_energy(1024, 0.5);
        // Fig. 15b: RRAM loses >100× overall; per-write it is ~25× here and
        // the zero-static advantage cannot recover it on write-heavy layers
        assert!(ratio > 20.0, "ratio={ratio}");
    }

    #[test]
    fn no_static_power() {
        assert_eq!(RramCard::chimera_like().static_power(), 0.0);
    }
}
