//! The two-level hybrid buffer: a small fast write-back tier in front of
//! any slow-write backend, behind the same [`MemoryBackend`] device API.
//!
//! `tiered=sram:32k+sotmram` is the MRAM co-design papers' system answer
//! to the write rail: the SRAM front absorbs the write stream at SRAM
//! energy/latency and only evicted *dirty* 64-byte blocks ever pay the
//! back tier's programming cost. Because [`TieredBackend`] is just another
//! `MemoryBackend`, the buffer manager, [`super::sharded::ShardedBackend`],
//! the worker pool, fault wrapping and trace recording all work on tiered
//! devices with zero call-site changes — and the `tiered=FRONT:BYTES+BACK`
//! spec composes recursively with every other spec.
//!
//! Policy (mirrored exactly, f64-op for f64-op, by the golden oracle's
//! naive two-level model in [`crate::sim::oracle`]):
//!
//! * 64-byte blocks; the front tier is a fully-associative block cache
//!   with exact-LRU replacement (a monotone use counter — no ties).
//! * Writes allocate. A full-block overwrite allocates *without* a back
//!   fill; a partial-block write fills from the back tier first.
//! * Write-back: stores dirty the resident block; the back tier is only
//!   written when a dirty victim is evicted.
//! * Both tiers' clocks advance together (`tick` forwards), the merged
//!   meter is re-derived after every mutating call, and
//!   [`MemoryBackend::shard_meters`] reports `[front, back]` so per-tier
//!   accounting survives the composition.

use std::collections::HashMap;

use super::backend::{build, BackendSpec, MemoryBackend};
use super::energy::EnergyCard;
use super::mcaimem::EnergyMeter;

/// Transfer granularity between the tiers (one cache block, bytes).
pub const BLOCK: usize = 64;

struct Slot {
    /// Back-tier block index resident in this slot.
    block: usize,
    dirty: bool,
    /// Monotone use stamp; the victim is the strict minimum.
    last_use: u64,
}

/// A write-back front tier over a backing tier — see the module docs for
/// the policy contract.
pub struct TieredBackend {
    spec: BackendSpec,
    front: Box<dyn MemoryBackend>,
    back: Box<dyn MemoryBackend>,
    slots: Vec<Option<Slot>>,
    /// back-tier block index → slot index, for resident blocks.
    resident: HashMap<usize, usize>,
    use_clock: u64,
    merged: EnergyMeter,
    now: f64,
    /// Telemetry sink; tier traffic lands on the fixed `tier/front` and
    /// `tier/back` tracks (see [`crate::obs::tier_track`]).
    obs: crate::obs::ObsSink,
}

impl TieredBackend {
    /// Build both tiers from a `BackendSpec::Tiered` spec: the front at
    /// its declared capacity, the back at the requested total `bytes`,
    /// with decorrelated per-tier seeds (`shard_seeds(seed, 2)`).
    pub fn new(spec: BackendSpec, bytes: usize, seed: u64) -> Self {
        let BackendSpec::Tiered(front_spec, front_bytes, back_spec) = &spec else {
            panic!("TieredBackend::new on non-tiered spec {spec}");
        };
        let seeds = crate::util::rng::shard_seeds(seed, 2);
        let front = build(front_spec, *front_bytes, seeds[0]);
        let back = build(back_spec, bytes, seeds[1]);
        let n_slots = front.capacity() / BLOCK;
        assert!(n_slots > 0, "front tier smaller than one {BLOCK} B block");
        let mut slots = Vec::with_capacity(n_slots);
        slots.resize_with(n_slots, || None);
        let mut t = TieredBackend {
            spec,
            front,
            back,
            slots,
            resident: HashMap::new(),
            use_clock: 0,
            merged: EnergyMeter::default(),
            now: 0.0,
            obs: crate::obs::ObsSink::disabled(),
        };
        t.remerge();
        t
    }

    fn remerge(&mut self) {
        let mut m = EnergyMeter::default();
        m.merge(self.front.meter());
        m.merge(self.back.meter());
        self.merged = m;
    }

    fn touch(&mut self, slot: usize) {
        self.use_clock += 1;
        self.slots[slot].as_mut().unwrap().last_use = self.use_clock;
    }

    /// Slot holding `block`, allocating (and filling from the back tier
    /// unless `full_overwrite`) on a miss. Evicts the exact-LRU victim,
    /// writing it back first if dirty.
    fn slot_for(&mut self, block: usize, full_overwrite: bool, now: f64) -> usize {
        if let Some(&slot) = self.resident.get(&block) {
            self.touch(slot);
            return slot;
        }
        // Victim selection: first empty slot, else the strict-LRU minimum.
        let slot = match self.slots.iter().position(|s| s.is_none()) {
            Some(empty) => empty,
            None => {
                let (victim, _) = self
                    .slots
                    .iter()
                    .enumerate()
                    .map(|(i, s)| (i, s.as_ref().unwrap().last_use))
                    .min_by_key(|&(_, stamp)| stamp)
                    .unwrap();
                let evicted = self.slots[victim].take().unwrap();
                self.resident.remove(&evicted.block);
                if evicted.dirty {
                    let data = self.front.load(victim * BLOCK, BLOCK, now);
                    self.back.store(evicted.block * BLOCK, &data, now);
                    self.obs.emit(crate::obs::Event::instant(
                        crate::obs::EventKind::TierEvict,
                        crate::obs::tier_track(1),
                        now * 1e6,
                        evicted.block as u64,
                        victim as u64,
                    ));
                }
                victim
            }
        };
        if !full_overwrite {
            let data = self.back.load(block * BLOCK, BLOCK, now);
            self.front.store(slot * BLOCK, &data, now);
            self.obs.emit(crate::obs::Event::instant(
                crate::obs::EventKind::TierFill,
                crate::obs::tier_track(0),
                now * 1e6,
                block as u64,
                slot as u64,
            ));
        }
        self.use_clock += 1;
        self.slots[slot] = Some(Slot { block, dirty: false, last_use: self.use_clock });
        self.resident.insert(block, slot);
        slot
    }

    fn advance_to(&mut self, now: f64) {
        assert!(now + 1e-15 >= self.now, "time must be monotone");
        self.front.tick(now);
        self.back.tick(now);
        self.now = now;
    }
}

impl MemoryBackend for TieredBackend {
    fn spec(&self) -> BackendSpec {
        self.spec.clone()
    }

    fn capacity(&self) -> usize {
        self.back.capacity()
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn store(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.back.capacity(), "write out of range");
        self.advance_to(now);
        let mut off = 0;
        while off < data.len() {
            let a = addr + off;
            let block = a / BLOCK;
            let within = a % BLOCK;
            let take = (BLOCK - within).min(data.len() - off);
            let slot = self.slot_for(block, within == 0 && take == BLOCK, now);
            self.front.store(slot * BLOCK + within, &data[off..off + take], now);
            self.slots[slot].as_mut().unwrap().dirty = true;
            off += take;
        }
        self.remerge();
    }

    fn load(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.back.capacity(), "read out of range");
        self.advance_to(now);
        let mut out = Vec::with_capacity(len);
        let mut off = 0;
        while off < len {
            let a = addr + off;
            let block = a / BLOCK;
            let within = a % BLOCK;
            let take = (BLOCK - within).min(len - off);
            let slot = self.slot_for(block, false, now);
            out.extend_from_slice(&self.front.load(slot * BLOCK + within, take, now));
            off += take;
        }
        self.remerge();
        out
    }

    fn tick(&mut self, now: f64) {
        self.advance_to(now);
        self.remerge();
    }

    fn refresh_due(&self) -> Option<f64> {
        self.front.refresh_due().or(self.back.refresh_due())
    }

    fn refresh_row(&mut self, row: usize, now: f64) {
        self.advance_to(now);
        if self.back.refresh_due().is_some() {
            self.back.refresh_row(row, now);
        } else {
            self.front.refresh_row(row, now);
        }
        self.remerge();
    }

    fn rows_per_bank(&self) -> usize {
        if self.back.refresh_due().is_some() {
            self.back.rows_per_bank()
        } else if self.front.refresh_due().is_some() {
            self.front.rows_per_bank()
        } else {
            1
        }
    }

    fn attach_obs(&mut self, sink: &crate::obs::ObsSink, track_base: u32) {
        self.obs = sink.clone();
        // nested structural tiers (e.g. a sharded front) keep their events
        self.front.attach_obs(sink, track_base);
        self.back.attach_obs(sink, track_base);
    }

    fn meter(&self) -> &EnergyMeter {
        &self.merged
    }

    fn shard_meters(&self) -> Vec<EnergyMeter> {
        vec![self.front.meter().clone(), self.back.meter().clone()]
    }

    fn energy_card(&self) -> &EnergyCard {
        self.back.energy_card()
    }

    fn area(&self) -> f64 {
        self.front.area() + self.back.area()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiered(spec: &str, bytes: usize, seed: u64) -> TieredBackend {
        TieredBackend::new(spec.parse().unwrap(), bytes, seed)
    }

    #[test]
    fn bytes_round_trip_through_evictions() {
        // Front holds one 16 KiB bank = 256 blocks; write 64 KiB so every
        // block is evicted at least once, then read it all back.
        let mut t = tiered("tiered=sram:16k+sotmram", 64 * 1024, 7);
        let total = t.capacity();
        let pattern: Vec<u8> = (0..total).map(|i| (i % 251) as u8).collect();
        for (i, chunk) in pattern.chunks(160).enumerate() {
            t.store(i * 160, chunk, i as f64 * 1e-6);
        }
        let got = t.load(0, total, 1.0);
        assert_eq!(got, pattern);
    }

    #[test]
    fn write_buffering_cuts_back_tier_writes() {
        // Hammer one hot block: the back tier must see at most the initial
        // fill, never the write stream.
        let mut t = tiered("tiered=sram:16k+sotmram", 64 * 1024, 7);
        for i in 0..1000u64 {
            t.store(128, &[i as u8; 32], i as f64 * 1e-6);
        }
        let tiers = t.shard_meters();
        // 1 fill store + 1000 payload stores, all on the SRAM rail.
        assert_eq!(tiers[0].writes, 1001, "front absorbs the stream");
        assert_eq!(tiers[1].writes, 0, "hot block never written back");
        assert_eq!(tiers[1].write_j, 0.0, "no MRAM programming energy spent");
    }

    #[test]
    fn dirty_victims_write_back_and_survive() {
        // The 16 KiB front rounds to exactly one bank = 256 slots; dirtying
        // 257 distinct blocks forces the LRU victim (block 0) out.
        let mut t = tiered("tiered=sram:16k+sotmram", 64 * 1024, 3);
        assert_eq!(t.slots.len(), 256);
        t.store(0, &[0xAA; 64], 0.0);
        for b in 1..=256usize {
            t.store(b * 64, &[b as u8; 64], b as f64 * 1e-6);
        }
        let tiers = t.shard_meters();
        assert_eq!(tiers[1].writes, 1, "exactly the one LRU victim written back");
        assert_eq!(t.load(0, 64, 1.0), vec![0xAA; 64]); // refills from back
    }

    #[test]
    fn merged_meter_equals_tier_sum() {
        let mut t = tiered("tiered=sram:16k+sttmram@ret=1e-3", 32 * 1024, 11);
        for i in 0..64 {
            t.store(i * 97, &[i as u8; 33], i as f64 * 1e-6);
            t.load(i * 61, 17, (i as f64 + 0.5) * 1e-6);
        }
        let tiers = t.shard_meters();
        let mut sum = EnergyMeter::default();
        sum.merge(&tiers[0]);
        sum.merge(&tiers[1]);
        assert_eq!(sum.total_j(), t.meter().total_j());
        assert_eq!(sum.writes, t.meter().writes);
        assert_eq!(sum.reads, t.meter().reads);
        assert_eq!(sum.busy_s, t.meter().busy_s);
    }

    #[test]
    fn full_block_overwrite_skips_the_fill() {
        let mut t = tiered("tiered=sram:16k+sotmram", 64 * 1024, 7);
        t.store(0, &[1u8; 64], 0.0); // aligned full block: no back read
        assert_eq!(t.shard_meters()[1].reads, 0);
        t.store(100, &[2u8; 8], 1e-6); // partial: fills block 1 from back
        assert_eq!(t.shard_meters()[1].reads, 1);
    }

    #[test]
    fn non_volatile_tiers_report_no_refresh() {
        let t = tiered("tiered=sram:16k+sotmram", 64 * 1024, 7);
        assert_eq!(t.refresh_due(), None);
        assert_eq!(t.rows_per_bank(), 1);
    }

    #[test]
    fn mcaimem_back_tier_keeps_manager_driven_refresh() {
        let t = tiered("tiered=sram:16k+mcaimem@0.8", 64 * 1024, 7);
        assert!(t.refresh_due().is_some());
        assert!(t.rows_per_bank() > 1);
    }
}
