//! Bank geometry and address mapping.
//!
//! The paper's Fig. 13 layout: a 1 MB buffer is 64 banks of 16 KB; each bank
//! is organized as rows of mixed-cell bytes (1 sign bit in the SRAM column
//! group, 7 magnitude bits in the eDRAM column groups). Refresh is issued
//! per row (§III-C "a refresh operation must be performed on each row of
//! MCAIMem within 12.57 µs").

/// Geometry of one bank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BankGeometry {
    pub bytes: usize,
    pub rows: usize,
    /// Bytes per row (columns / 8 bit-planes).
    pub row_bytes: usize,
}

impl BankGeometry {
    /// The paper's 16 KB bank: 256 rows × 64 bytes.
    pub fn bank16k() -> Self {
        BankGeometry { bytes: 16 * 1024, rows: 256, row_bytes: 64 }
    }

    pub fn new(bytes: usize, rows: usize) -> Self {
        assert!(bytes % rows == 0, "rows must divide capacity");
        BankGeometry { bytes, rows, row_bytes: bytes / rows }
    }

    /// Row index of a byte address within this bank.
    #[inline]
    pub fn row_of(&self, addr: usize) -> usize {
        (addr / self.row_bytes) % self.rows
    }
}

/// A multi-bank memory map.
#[derive(Clone, Copy, Debug)]
pub struct MemoryMap {
    pub bank: BankGeometry,
    pub banks: usize,
}

impl MemoryMap {
    /// The paper's 1 MB buffer: 64 × 16 KB banks.
    pub fn mb1() -> Self {
        MemoryMap { bank: BankGeometry::bank16k(), banks: 64 }
    }

    /// A buffer of arbitrary capacity built from 16 KB banks (rounded up) —
    /// how the Eyeriss (108 KB ⇒ 7 banks) and TPUv1 (8 MB ⇒ 512 banks)
    /// configurations are assembled.
    pub fn with_capacity(bytes: usize) -> Self {
        Self::with_geometry(bytes, BankGeometry::bank16k())
    }

    /// A buffer of arbitrary capacity built from `bank`-shaped banks
    /// (rounded up) — how a compiler-generated macro's geometry becomes a
    /// runnable memory map.
    pub fn with_geometry(bytes: usize, bank: BankGeometry) -> Self {
        MemoryMap { bank, banks: bytes.div_ceil(bank.bytes) }
    }

    pub fn capacity(&self) -> usize {
        self.bank.bytes * self.banks
    }

    pub fn total_rows(&self) -> usize {
        self.bank.rows * self.banks
    }

    /// Decompose a flat byte address into (bank, row, byte-in-row).
    #[inline]
    pub fn locate(&self, addr: usize) -> (usize, usize, usize) {
        assert!(addr < self.capacity(), "address {addr} out of range");
        let bank = addr / self.bank.bytes;
        let within = addr % self.bank.bytes;
        (bank, within / self.bank.row_bytes, within % self.bank.row_bytes)
    }

    /// The per-row refresh interval that meets a whole-array refresh period
    /// `t_ref`: the paper's "ordinary refresh cycle interval is calculated by
    /// dividing the refresh time by the number of rows" (§III-C). Banks
    /// refresh in parallel (one row per bank per slot).
    pub fn row_refresh_interval(&self, t_ref: f64) -> f64 {
        t_ref / self.bank.rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_1mb_geometry() {
        let m = MemoryMap::mb1();
        assert_eq!(m.banks, 64);
        assert_eq!(m.capacity(), 1024 * 1024);
        assert_eq!(m.bank.rows, 256);
        assert_eq!(m.bank.row_bytes, 64);
    }

    #[test]
    fn eyeriss_and_tpu_capacities() {
        let ey = MemoryMap::with_capacity(108 * 1024);
        assert_eq!(ey.banks, 7); // 108KB → 7 × 16KB
        assert!(ey.capacity() >= 108 * 1024);
        let tpu = MemoryMap::with_capacity(8 * 1024 * 1024);
        assert_eq!(tpu.banks, 512);
    }

    #[test]
    fn custom_geometry_maps_like_the_default_path() {
        // with_capacity is with_geometry at the paper bank
        let a = MemoryMap::with_capacity(108 * 1024);
        let b = MemoryMap::with_geometry(108 * 1024, BankGeometry::bank16k());
        assert_eq!((a.banks, a.bank), (b.banks, b.bank));
        // a compiled 512×64 B bank: half the banks, same capacity
        let tall = MemoryMap::with_geometry(1024 * 1024, BankGeometry::new(32 * 1024, 512));
        assert_eq!(tall.banks, 32);
        assert_eq!(tall.capacity(), 1024 * 1024);
        assert_eq!(tall.total_rows(), MemoryMap::mb1().total_rows());
    }

    #[test]
    fn locate_roundtrip() {
        let m = MemoryMap::mb1();
        for addr in [0, 63, 64, 16 * 1024 - 1, 16 * 1024, 1024 * 1024 - 1] {
            let (b, r, c) = m.locate(addr);
            let back = b * m.bank.bytes + r * m.bank.row_bytes + c;
            assert_eq!(back, addr);
            assert!(b < m.banks && r < m.bank.rows && c < m.bank.row_bytes);
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn locate_rejects_out_of_range() {
        MemoryMap::mb1().locate(1024 * 1024);
    }

    #[test]
    fn refresh_interval_division() {
        let m = MemoryMap::mb1();
        let iv = m.row_refresh_interval(12.57e-6);
        assert!((iv - 12.57e-6 / 256.0).abs() < 1e-15);
    }

    #[test]
    fn row_of_wraps_within_bank() {
        let g = BankGeometry::bank16k();
        assert_eq!(g.row_of(0), 0);
        assert_eq!(g.row_of(64), 1);
        assert_eq!(g.row_of(16 * 1024), 0);
    }
}
