//! The single source of truth for the bank-shape calibration point.
//!
//! Every geometry-aware model in the repo — the analytic periphery law in
//! [`super::area`], the access-energy line-length scaling in
//! [`crate::dse::eval`], and the bottom-up macro compiler in
//! [`super::compiler`] — is calibrated at the paper's reference bank:
//! 256 rows × 64 bytes (= 512 bit columns), the 16 KB bank of Fig. 13.
//! Before this module the constants were duplicated per consumer; now the
//! reference shape, the periphery normalization and the access scale live
//! here and everyone derives from the same three numbers.

/// Fraction of a memory macro spent on peripheral circuitry (row/col
/// decoders, S/A stripe, write drivers, timing) at the paper's reference
/// bank geometry. Representative of compiled SRAM macros at this capacity.
pub const PERIPHERY_FRAC: f64 = 0.25;

/// Reference bank geometry the periphery fraction is calibrated at: the
/// paper's 16 KB bank, 256 rows × 64 bytes (= 512 bit columns).
pub const REF_ROWS: usize = 256;
pub const REF_COLS: usize = 512;

/// Relative periphery cost of a `rows` × `row_bytes` bank vs the reference
/// shape: periphery splits into row circuitry (word-line drivers + row
/// decoder, amortized over columns) and column circuitry (S/A stripe,
/// write drivers, column mux, amortized over rows), so the per-bit
/// overhead goes as `1/cols + 1/rows`, normalized to 1.0 at the
/// [`REF_ROWS`] × [`REF_COLS`] reference. Multiply by [`PERIPHERY_FRAC`]
/// for the periphery-to-array area ratio.
pub fn periphery_factor(rows: usize, row_bytes: usize) -> f64 {
    let cols = (row_bytes * 8) as f64;
    (1.0 / cols + 1.0 / rows as f64) / (1.0 / REF_COLS as f64 + 1.0 / REF_ROWS as f64)
}

/// Relative per-access dynamic energy of a `rows` × `row_bytes` bank vs
/// the reference shape: word- and bit-lines lengthen linearly with the
/// bank's sides, so access energy scales with the mean of the two
/// normalized dimensions — 1.0 at the reference bank. Bigger banks
/// amortize periphery silicon ([`periphery_factor`]) but pay per access;
/// that opposition is the real compiler trade.
pub fn access_scale(rows: usize, row_bytes: usize) -> f64 {
    0.5 * (rows as f64 / REF_ROWS as f64 + (row_bytes * 8) as f64 / REF_COLS as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_factors_are_unity_at_the_reference_bank() {
        // the calibration contract: at 256 × 64 B the geometry laws are
        // exactly neutral, bit-for-bit (0.5 * (1.0 + 1.0) and x/x are
        // exact in f64 for these dyadic values)
        assert_eq!(periphery_factor(REF_ROWS, 64).to_bits(), 1.0f64.to_bits());
        assert_eq!(access_scale(REF_ROWS, 64).to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn periphery_amortizes_where_access_pays() {
        // the two laws pull opposite ways: growing either dimension
        // amortizes periphery silicon but lengthens the access lines
        for (rows, row_bytes) in [(512, 64), (256, 128), (512, 128), (1024, 256)] {
            assert!(periphery_factor(rows, row_bytes) < 1.0, "{rows}x{row_bytes}");
            assert!(access_scale(rows, row_bytes) > 1.0, "{rows}x{row_bytes}");
        }
        for (rows, row_bytes) in [(128, 64), (256, 32), (128, 32)] {
            assert!(periphery_factor(rows, row_bytes) > 1.0, "{rows}x{row_bytes}");
            assert!(access_scale(rows, row_bytes) < 1.0, "{rows}x{row_bytes}");
        }
    }

    #[test]
    fn periphery_factor_is_symmetric_in_rows_and_columns() {
        // 512 rows × 32 B (256 cols) swaps the two terms of the reference
        // 256 × 512: identical per-bit overhead
        let a = periphery_factor(512, 32);
        let b = periphery_factor(256, 64);
        assert!((a / b - 1.0).abs() < 1e-12, "{a} vs {b}");
    }
}
