//! Energy characterization — Table II and the 1:7 mixed-cell composition.
//!
//! Table II (1 MB designs, 45 nm post-layout SPICE in the paper; the cards
//! here carry those published numbers):
//!
//! | eRAM       | static (mW)      | read (pJ/B)         | write (pJ/B)        |
//! |------------|------------------|---------------------|---------------------|
//! | 6T SRAM    | 19.29            | 0.08                | 0.16                |
//! | 2T eDRAM   | 0.84 … 5.03      | 0.00016 … 0.14      | 0.00016 … 0.0184    |
//! | MCAIMem    | 3.15 … 6.82      | 0.01014 … 0.1325    | 0.02014 … 0.0361    |
//!
//! The asymmetric 2T bounds are data-dependent: *min* is an all-ones array
//! (bit-1 is held at VDD by leakage: nearly free), *max* all-zeros (bit-0
//! leaks and must be driven). The MCAIMem row is exactly
//! `(1·SRAM + 7·eDRAM)/8` — verified by unit + property tests, which is how
//! the paper's own numbers compose.
//!
//! Access-energy unit: Table II's pJ figures are taken **per byte access**
//! (one 8-bit word through the column path). This is the interpretation
//! under which the paper's system-level results reproduce: per *bit* the
//! refresh stream of a 1 MB array at 12.57 µs would alone exceed the SRAM
//! macro's entire static power, contradicting Fig. 15. Refresh senses only
//! the 7 eDRAM planes (the SRAM plane needs none), so a refresh pass costs
//! 7/8 of an eDRAM read per byte — and the conventional 2T additionally
//! pays the write-back the CVSA avoids (§III-B3).

use super::MemKind;
use crate::util::units::{MIB, PICO, MILLI};

/// Data-value-dependent quantity: value at all-ones vs all-zeros, linearly
/// interpolated by the ones fraction (each cell contributes independently).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Asym {
    pub at_ones: f64,
    pub at_zeros: f64,
}

impl Asym {
    pub const fn symmetric(v: f64) -> Self {
        Asym { at_ones: v, at_zeros: v }
    }

    /// Value at a given fraction of one-bits.
    pub fn at(&self, ones_frac: f64) -> f64 {
        debug_assert!((0.0..=1.0 + 1e-9).contains(&ones_frac));
        self.at_zeros + (self.at_ones - self.at_zeros) * ones_frac
    }

    pub fn min(&self) -> f64 {
        self.at_ones.min(self.at_zeros)
    }

    pub fn max(&self) -> f64 {
        self.at_ones.max(self.at_zeros)
    }

    fn scale(&self, k: f64) -> Asym {
        Asym { at_ones: self.at_ones * k, at_zeros: self.at_zeros * k }
    }

    fn blend(&self, other: &Asym, w_self: f64) -> Asym {
        Asym {
            at_ones: self.at_ones * w_self + other.at_ones * (1.0 - w_self),
            at_zeros: self.at_zeros * w_self + other.at_zeros * (1.0 - w_self),
        }
    }
}

/// Energy card for one memory kind, normalized to a 1 MB macro.
#[derive(Clone, Debug)]
pub struct EnergyCard {
    pub kind: MemKind,
    /// Static power of a 1 MB macro (W), data-dependent.
    pub static_w_per_mb: Asym,
    /// Read energy per byte access (J), data-dependent.
    pub read_j_per_byte: Asym,
    /// Write energy per byte access (J), data-dependent.
    pub write_j_per_byte: Asym,
    /// Refresh period at the operating point (s); `None` = no refresh.
    pub refresh_period: Option<f64>,
    /// Fraction of the array's cells that are eDRAM (the cells a refresh
    /// pass must sense): 1.0 for a pure gain-cell array, `N/(N+1)` for a
    /// 1S·NE mixed composition, 0.0 for static/non-volatile arrays.
    pub edram_frac: f64,
}

/// Fraction of the mixed row that is SRAM at the paper's 1S·7E composition
/// (1 of 8 bits — the sign bit).
pub const SRAM_SHARE: f64 = 1.0 / 8.0;

impl EnergyCard {
    /// Table II column 1: 6T SRAM.
    pub fn sram() -> Self {
        EnergyCard {
            kind: MemKind::Sram6t,
            static_w_per_mb: Asym::symmetric(19.29 * MILLI),
            read_j_per_byte: Asym::symmetric(0.08 * PICO),
            write_j_per_byte: Asym::symmetric(0.16 * PICO),
            refresh_period: None,
            edram_frac: 0.0,
        }
    }

    /// Table II column 2: the asymmetric 2T eDRAM (conventional sensing —
    /// C-S/A with a 1.3 µs refresh period; see DESIGN.md §4 for why the
    /// paper's "from 1.3 µs to 12.57 µs" extension fixes this baseline).
    pub fn edram2t() -> Self {
        EnergyCard {
            kind: MemKind::Edram2t,
            static_w_per_mb: Asym { at_ones: 0.84 * MILLI, at_zeros: 5.03 * MILLI },
            read_j_per_byte: Asym { at_ones: 0.00016 * PICO, at_zeros: 0.14 * PICO },
            write_j_per_byte: Asym { at_ones: 0.00016 * PICO, at_zeros: 0.0184 * PICO },
            refresh_period: Some(1.3e-6),
            edram_frac: 1.0,
        }
    }

    /// The mixed-cell memory at a given V_REF: the exact 1:7 composition of
    /// the SRAM and 2T cards, refresh period from the flip model.
    pub fn mcaimem(vref: f64) -> Self {
        Self::mcaimem_ratio(vref, 7)
    }

    /// The 1S·NE mixed-cell card: one SRAM cell per `ratio` eDRAM cells,
    /// so the SRAM share of every per-cell quantity is `1/(ratio+1)` (the
    /// paper's 1:7 composition law generalized — `ratio = 7` reproduces
    /// Table II's MCAIMem row exactly, `ratio = 0` degenerates to the pure
    /// SRAM card with no refresh). Retention physics is per-cell, so the
    /// refresh period depends only on V_REF, not on the ratio.
    pub fn mcaimem_ratio(vref: f64, ratio: u32) -> Self {
        let s = Self::sram();
        let e = Self::edram2t();
        let flip = crate::circuit::flip_model::FlipModel::mcaimem_85c();
        let sram_share = 1.0 / (ratio as f64 + 1.0);
        EnergyCard {
            kind: MemKind::Mcaimem,
            static_w_per_mb: e.static_w_per_mb.blend(&s.static_w_per_mb, 1.0 - sram_share),
            read_j_per_byte: e.read_j_per_byte.blend(&s.read_j_per_byte, 1.0 - sram_share),
            write_j_per_byte: e.write_j_per_byte.blend(&s.write_j_per_byte, 1.0 - sram_share),
            refresh_period: (ratio > 0).then(|| {
                flip.refresh_period(vref, crate::circuit::flip_model::MAX_FLIP_FOR_DNN)
            }),
            edram_frac: 1.0 - sram_share,
        }
    }

    /// MCAIMem at the paper's chosen operating point (V_REF = 0.8 V).
    pub fn mcaimem_default() -> Self {
        Self::mcaimem(0.8)
    }

    /// The Chimera-like RRAM buffer in card form (so the unified
    /// [`crate::mem::backend::MemoryBackend`] surface has one card type):
    /// zero standby power, no refresh, data-independent access energy from
    /// [`crate::mem::rram::RramCard`]. The paper's system-level RRAM
    /// *evaluation policy* (charging a buffer write per operand read — no
    /// cheap staging tier) lives in `energy::system_eval`, not here.
    pub fn rram() -> Self {
        let r = crate::mem::rram::RramCard::chimera_like();
        EnergyCard {
            kind: MemKind::Rram,
            static_w_per_mb: Asym::symmetric(0.0),
            read_j_per_byte: Asym::symmetric(r.read_j_per_byte),
            write_j_per_byte: Asym::symmetric(r.write_j_per_byte),
            refresh_period: None,
            edram_frac: 0.0,
        }
    }

    /// STT-MRAM at a retention target (s) — card form of
    /// [`crate::mem::mram::MramCard::stt`]: non-volatile (zero standby, no
    /// refresh), data-independent access energy, write-asymmetric.
    pub fn sttmram(retention_s: f64) -> Self {
        Self::from_mram(&crate::mem::mram::MramCard::stt(retention_s))
    }

    /// SOT-MRAM at a retention target (s) — card form of
    /// [`crate::mem::mram::MramCard::sot`].
    pub fn sotmram(retention_s: f64) -> Self {
        Self::from_mram(&crate::mem::mram::MramCard::sot(retention_s))
    }

    fn from_mram(m: &crate::mem::mram::MramCard) -> Self {
        EnergyCard {
            kind: m.kind,
            static_w_per_mb: Asym::symmetric(0.0),
            read_j_per_byte: Asym::symmetric(m.read_j_per_byte),
            write_j_per_byte: Asym::symmetric(m.write_j_per_byte),
            refresh_period: None,
            edram_frac: 0.0,
        }
    }

    /// Static power (W) for a buffer of `bytes` holding data with the given
    /// ones fraction. Scales linearly with capacity from the 1 MB macro —
    /// exactly the paper's §V-B procedure ("reducing it to one-tenth … /
    /// augmented … by a factor of eight").
    pub fn static_power(&self, bytes: usize, ones_frac: f64) -> f64 {
        self.static_w_per_mb.at(ones_frac) * bytes as f64 / MIB as f64
    }

    /// Read energy (J) for `bytes` bytes of data with the given ones frac.
    pub fn read_energy(&self, bytes: usize, ones_frac: f64) -> f64 {
        self.read_j_per_byte.at(ones_frac) * bytes as f64
    }

    /// Write energy (J) for `bytes` bytes.
    pub fn write_energy(&self, bytes: usize, ones_frac: f64) -> f64 {
        self.write_j_per_byte.at(ones_frac) * bytes as f64
    }

    /// Energy of one refresh pass over `bytes` bytes. Refresh only touches
    /// the eDRAM cells: for a 1S·NE mixed array that is the `edram_frac`
    /// (= N/(N+1); 7 of 8 bit-planes at the paper's ratio) read through
    /// the CVSA (read *is* the write-back, §III-B3); the conventional 2T
    /// refreshes every bit and pays an explicit write-back after its C-S/A
    /// read (§II-A2).
    pub fn refresh_pass_energy(&self, bytes: usize, ones_frac: f64) -> f64 {
        let edram = EnergyCard::edram2t();
        match self.kind {
            MemKind::Edram2t => {
                self.read_energy(bytes, ones_frac) + self.write_energy(bytes, ones_frac)
            }
            MemKind::Mcaimem => edram.read_energy(bytes, ones_frac) * self.edram_frac,
            _ => self.read_energy(bytes, ones_frac),
        }
    }

    /// Refresh power (W) for a buffer of `bytes` with data `ones_frac`,
    /// refreshing every `refresh_period`. Zero for static memories.
    pub fn refresh_power(&self, bytes: usize, ones_frac: f64) -> f64 {
        match self.refresh_period {
            None => 0.0,
            Some(t) => self.refresh_pass_energy(bytes, ones_frac) / t,
        }
    }

    /// Check-plane write energy riding a data store that touches `words`
    /// 64-bit codewords (one 6T SRAM check byte each) — the per-store cost
    /// of a `mcaimem@V+ecc` spec's SECDED plane ([`super::ecc`]).
    pub fn ecc_write_energy(&self, words: usize) -> f64 {
        EnergyCard::sram().write_energy(words, 0.5)
    }

    /// Check-plane read energy riding one refresh pass over `bytes` data
    /// bytes: the scrub senses one SRAM check byte per
    /// [`super::ecc::WORD_BYTES`]-byte codeword while the CVSA is already
    /// sensing the data row, so only the check-plane column path is extra.
    /// Correction write-backs are data-dependent events charged separately
    /// by the array.
    pub fn ecc_scrub_energy(&self, bytes: usize) -> f64 {
        EnergyCard::sram().read_energy(bytes.div_ceil(super::ecc::WORD_BYTES), 0.5)
    }

    /// Effective ones fraction *inside the storage array*: for MCAIMem, only
    /// the 7 eDRAM bits are data-dependent (the SRAM bit is symmetric), so
    /// the caller passes the eDRAM-plane ones fraction directly; for uniform
    /// arrays the overall fraction. Helper for Table II printing.
    pub fn table2_row(&self) -> (f64, f64, f64, f64, f64, f64) {
        (
            self.static_w_per_mb.min() / MILLI,
            self.static_w_per_mb.max() / MILLI,
            self.read_j_per_byte.min() / PICO,
            self.read_j_per_byte.max() / PICO,
            self.write_j_per_byte.min() / PICO,
            self.write_j_per_byte.max() / PICO,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-9;

    #[test]
    fn table2_mcaimem_is_exact_composition() {
        // paper Table II MCAIMem row: static 3.15–6.82 mW,
        // read 0.01014–0.1325 pJ, write 0.02014–0.0361 pJ
        let m = EnergyCard::mcaimem_default();
        let (smin, smax, rmin, rmax, wmin, wmax) = m.table2_row();
        assert!((smin - 3.15).abs() < 0.01, "smin={smin}");
        assert!((smax - 6.82).abs() < 0.01, "smax={smax}");
        assert!((rmin - 0.01014).abs() < 1e-5, "rmin={rmin}");
        assert!((rmax - 0.1325).abs() < 1e-4, "rmax={rmax}");
        assert!((wmin - 0.02014).abs() < 1e-5, "wmin={wmin}");
        assert!((wmax - 0.0361).abs() < 1e-4, "wmax={wmax}");
    }

    #[test]
    fn static_power_scaling_eyeriss_and_tpu() {
        // §V-B: Eyeriss 108 KB = 1MB × 108/1024; TPUv1 8 MB = ×8
        let s = EnergyCard::sram();
        let p108 = s.static_power(108 * 1024, 0.5);
        assert!((p108 / (19.29e-3 * 108.0 / 1024.0) - 1.0).abs() < EPS);
        let p8m = s.static_power(8 * MIB, 0.5);
        assert!((p8m / (19.29e-3 * 8.0) - 1.0).abs() < EPS);
    }

    #[test]
    fn edram_static_power_falls_with_ones() {
        let e = EnergyCard::edram2t();
        let all0 = e.static_power(MIB, 0.0);
        let all1 = e.static_power(MIB, 1.0);
        assert!((all0 - 5.03e-3).abs() < 1e-6);
        assert!((all1 - 0.84e-3).abs() < 1e-6);
        // paper: 2T offers 5.26× lower static power min-case… vs SRAM at 65nm;
        // at 45nm Table II the all-ones ratio is 19.29/0.84 ≈ 23×
        assert!(EnergyCard::sram().static_power(MIB, 0.5) / all1 > 20.0);
    }

    #[test]
    fn mcaimem_refresh_period_is_12_57us() {
        let m = EnergyCard::mcaimem_default();
        let t = m.refresh_period.unwrap();
        assert!((t - 12.57e-6).abs() / 12.57e-6 < 1e-3, "t={t}");
    }

    #[test]
    fn refresh_power_vref_lever() {
        // Fig. 15a: V_REF=0.8 cuts refresh power ~10× vs V_REF=0.5
        let hi = EnergyCard::mcaimem(0.8);
        let lo = EnergyCard::mcaimem(0.5);
        let f = 0.8; // encoded DNN data ones fraction
        let ratio = lo.refresh_power(MIB, f) / hi.refresh_power(MIB, f);
        assert!(ratio > 9.0 && ratio < 10.5, "ratio={ratio}");
    }

    #[test]
    fn conventional_edram_refresh_costs_double_ops() {
        let e = EnergyCard::edram2t();
        let m = EnergyCard::mcaimem_default();
        // per pass, conventional pays read+write-back on all 8 planes;
        // MCAIMem reads only its 7 eDRAM planes (refresh-by-read)
        let pe = e.refresh_pass_energy(MIB, 0.5);
        assert!((pe - (e.read_energy(MIB, 0.5) + e.write_energy(MIB, 0.5))).abs() < EPS);
        let pm = m.refresh_pass_energy(MIB, 0.5);
        assert!((pm - e.read_energy(MIB, 0.5) * 7.0 / 8.0).abs() < EPS);
        // the refresh *stream* must stay well under the SRAM macro's static
        // power — the sanity check that pins the per-byte interpretation
        assert!(m.refresh_power(MIB, 0.8) < 0.25 * EnergyCard::sram().static_power(MIB, 0.8));
    }

    #[test]
    fn sram_never_refreshes() {
        let s = EnergyCard::sram();
        assert_eq!(s.refresh_power(MIB, 0.3), 0.0);
        assert!(s.refresh_period.is_none());
    }

    #[test]
    fn one_enhancement_reduces_mcaimem_energy() {
        // raising the ones fraction (what the encoder does) must cut both
        // static and refresh power of the mixed array
        let m = EnergyCard::mcaimem_default();
        assert!(m.static_power(MIB, 0.8) < m.static_power(MIB, 0.5));
        assert!(m.refresh_power(MIB, 0.8) < m.refresh_power(MIB, 0.5));
        assert!(m.read_energy(MIB, 0.8) < m.read_energy(MIB, 0.5));
    }

    #[test]
    fn rram_card_matches_the_rram_model() {
        let c = EnergyCard::rram();
        let r = crate::mem::rram::RramCard::chimera_like();
        assert_eq!(c.static_power(MIB, 0.3), 0.0);
        assert_eq!(c.refresh_power(MIB, 0.3), 0.0);
        assert!((c.read_energy(1024, 0.5) - r.read_energy(1024)).abs() < EPS);
        assert!((c.write_energy(1024, 0.5) - r.write_energy(1024)).abs() < EPS);
    }

    #[test]
    fn ratio_card_composition_law() {
        let s = EnergyCard::sram();
        let e = EnergyCard::edram2t();
        // ratio 7 is bit-identical to the Table II MCAIMem card
        let m7 = EnergyCard::mcaimem_ratio(0.8, 7);
        let m = EnergyCard::mcaimem_default();
        assert_eq!(m7.static_w_per_mb, m.static_w_per_mb);
        assert_eq!(m7.read_j_per_byte, m.read_j_per_byte);
        assert_eq!(m7.write_j_per_byte, m.write_j_per_byte);
        assert_eq!(m7.refresh_period, m.refresh_period);
        assert_eq!(m7.edram_frac, 7.0 / 8.0);
        // ratio 0 degenerates to pure SRAM: no refresh, SRAM numbers
        let m0 = EnergyCard::mcaimem_ratio(0.8, 0);
        assert_eq!(m0.refresh_period, None);
        assert_eq!(m0.edram_frac, 0.0);
        assert_eq!(m0.static_power(MIB, 0.3), s.static_power(MIB, 0.3));
        assert_eq!(m0.read_energy(1024, 0.9), s.read_energy(1024, 0.9));
        assert_eq!(m0.refresh_power(MIB, 0.5), 0.0);
        // static power falls monotonically as the eDRAM share grows (at the
        // all-ones corner the 2T cell is ~23× cheaper than SRAM)
        let mut last = f64::INFINITY;
        for n in 0..=15u32 {
            let c = EnergyCard::mcaimem_ratio(0.8, n);
            let p = c.static_power(MIB, 1.0);
            assert!(p < last, "n={n}: {p} !< {last}");
            last = p;
            // the card interpolates between the two Table II columns
            assert!(p >= e.static_power(MIB, 1.0) && p <= s.static_power(MIB, 1.0));
        }
        // refresh pass senses exactly the eDRAM fraction of the cells
        let m3 = EnergyCard::mcaimem_ratio(0.8, 3);
        let pass = m3.refresh_pass_energy(MIB, 0.5);
        assert!((pass - e.read_energy(MIB, 0.5) * 0.75).abs() < EPS);
        // retention physics is per-cell: the period depends on V_REF only
        assert_eq!(m3.refresh_period, m7.refresh_period);
    }

    #[test]
    fn ecc_costs_are_an_sram_check_plane() {
        let m = EnergyCard::mcaimem_default();
        let s = EnergyCard::sram();
        // one check byte per 8-byte codeword, both directions
        assert!((m.ecc_scrub_energy(4096) - s.read_energy(512, 0.5)).abs() < EPS);
        assert!((m.ecc_write_energy(16) - s.write_energy(16, 0.5)).abs() < EPS);
        // the scrub ride-along must stay below the pass it rides on
        // (encoded-data corner: SRAM check reads are pricier per byte than
        // CVSA senses, but there are 8× fewer of them)
        assert!(m.ecc_scrub_energy(MIB) < 0.5 * m.refresh_pass_energy(MIB, 0.8));
    }

    #[test]
    fn asym_interpolation_endpoints_and_midpoint() {
        let a = Asym { at_ones: 1.0, at_zeros: 3.0 };
        assert_eq!(a.at(1.0), 1.0);
        assert_eq!(a.at(0.0), 3.0);
        assert_eq!(a.at(0.5), 2.0);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 3.0);
    }
}
