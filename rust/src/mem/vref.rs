//! The reference-voltage controller (§III-C, §IV-B).
//!
//! The CVSA's single-ended eDRAM read compares the bit-line against V_REF.
//! Raising V_REF widens the voltage band a drifting bit-0 may occupy before
//! it mis-reads, which extends the refresh period (the flip-probability
//! model of Fig. 12b) — at no circuit cost beyond the reference DAC. This
//! controller owns that decision: it maps an accuracy budget (maximum
//! tolerable 0→1 flip rate, 1 % per §IV-A) to the operating V_REF and the
//! resulting refresh period.

use crate::circuit::flip_model::{FlipModel, MAX_FLIP_FOR_DNN, VREF_CANDIDATES};

/// Operating point chosen by the controller.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VrefPoint {
    pub vref: f64,
    pub refresh_period: f64,
    /// Flip probability at exactly one refresh period (= the budget).
    pub flip_at_period: f64,
}

/// The reference-voltage controller.
#[derive(Clone, Debug)]
pub struct VrefController {
    pub model: FlipModel,
    pub max_flip: f64,
}

impl VrefController {
    /// Paper configuration: MCAIMem cell at 85 °C, 1 % flip budget.
    pub fn paper_default() -> Self {
        VrefController { model: FlipModel::mcaimem_85c(), max_flip: MAX_FLIP_FOR_DNN }
    }

    /// Evaluate one candidate V_REF.
    pub fn point(&self, vref: f64) -> VrefPoint {
        let t = self.model.refresh_period(vref, self.max_flip);
        VrefPoint { vref, refresh_period: t, flip_at_period: self.max_flip }
    }

    /// All candidate operating points (the Fig. 15a sweep).
    pub fn candidates(&self) -> Vec<VrefPoint> {
        VREF_CANDIDATES.iter().map(|&v| self.point(v)).collect()
    }

    /// The controller's choice: the candidate maximizing refresh period
    /// (§IV-B: "we choose a V_REF of 0.8 V to maximize bit-0's refresh
    /// period and minimize dynamic refresh operations").
    pub fn choose(&self) -> VrefPoint {
        self.candidates()
            .into_iter()
            .max_by(|a, b| a.refresh_period.partial_cmp(&b.refresh_period).unwrap())
            .unwrap()
    }

    /// Adaptive variant: tightest V_REF that still meets a *given* refresh
    /// period (used when the scheduler wants a fixed refresh cadence and
    /// asks how much reference margin is available).
    pub fn vref_for_period(&self, t_ref: f64) -> Option<VrefPoint> {
        self.candidates()
            .into_iter()
            .filter(|p| p.refresh_period >= t_ref)
            .min_by(|a, b| a.vref.partial_cmp(&b.vref).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chooses_vref_08_with_12_57us() {
        let c = VrefController::paper_default();
        let p = c.choose();
        assert_eq!(p.vref, 0.8);
        assert!((p.refresh_period - 12.57e-6).abs() / 12.57e-6 < 1e-3);
    }

    #[test]
    fn candidates_cover_paper_sweep() {
        let c = VrefController::paper_default();
        let pts = c.candidates();
        assert_eq!(pts.len(), 4);
        assert_eq!(pts[0].vref, 0.5);
        assert!((pts[0].refresh_period - 1.3e-6).abs() / 1.3e-6 < 1e-3);
        // monotone in vref
        for w in pts.windows(2) {
            assert!(w[1].refresh_period > w[0].refresh_period);
        }
    }

    #[test]
    fn vref_for_period_picks_tightest() {
        let c = VrefController::paper_default();
        // a 2 µs cadence is satisfiable by 0.6/0.7/0.8 — tightest wins
        let p = c.vref_for_period(2.0e-6).unwrap();
        assert!(p.vref < 0.8);
        assert!(p.refresh_period >= 2.0e-6);
        // an impossible cadence returns None
        assert!(c.vref_for_period(1.0).is_none());
    }
}
