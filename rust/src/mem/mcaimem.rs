//! The functional MCAIMem array — real bytes, real bit-planes, physical
//! 0→1 retention flips, refresh-by-read (paper Fig. 4/6, §III).
//!
//! Storage layout follows the paper's mapping (Fig. 6): bit 7 of every byte
//! (the sign/control bit of the one-enhancement code) lives in the 6T SRAM
//! plane and never corrupts; bits 6..0 live in 2T eDRAM planes whose stored
//! zeros drift toward one with the calibrated flip law. All data movement
//! passes through the one-enhancement encoder in front of the array
//! (toggleable, so the paper's with/without-encoder ablations run on the
//! same machinery).
//!
//! Aging is tracked per row. Any access that activates a row (read, write,
//! or refresh slot) senses every column through the CVSA and writes the
//! sensed values back (§III-B3's refresh-by-read), so flips that happened
//! before the access are *committed* — exactly the cumulative-error
//! behaviour the paper injects in §IV-A.
//!
//! Leakage is a **persistent per-cell property**: each eDRAM cell draws a
//! z-score once (quantized to 8 bits) representing its lognormal leakage
//! multiple. A stored 0 flips when its staleness `dt` satisfies
//! `mult > t_nom(V_REF)/dt` ⇔ `z > ln(t_nom/dt)/σ` — so a refresh cadence
//! faster than the weakest resident cell keeps data alive *forever*
//! (the property a resampling model would destroy: under independent
//! redraws every cell dies after enough refresh windows). Unwritten cells
//! idle at bit-1, the state pull-up leakage drives them to physically.
//!
//! §Ratio: the 1S·NE mixed composition is a **parameter** (paper default
//! N = 7). SRAM cells stripe at density `1/(N+1)` anchored at the sign
//! bit ([`sram_plane_mask`]); the functional array supports the ratios
//! whose groups tile a byte (N ∈ {0, 1, 3, 7}) — `N = 0` is pure SRAM on
//! identical plumbing — while the analytic design-space evaluator
//! ([`crate::dse`]) covers the full 0..=15 range with the same striping
//! law. Area/energy cards take the ratio through
//! [`super::area::AreaModel::macro_area_mixed`] and
//! [`EnergyCard::mcaimem_ratio`].
//!
//! §Perf: the access hot path is **word-parallel**. Aligned 64-byte blocks
//! move through an 8×64 SWAR bit-matrix transpose ([`super::bitplane`]) —
//! 64 bytes become 8 whole plane words per step — the one-enhancement
//! encode/decode collapses to seven plane-word XORs
//! ([`crate::encode::one_enhancement::encode_words`]), and the ones census
//! feeding the energy model is `count_ones()` per word instead of per-bit
//! masking. Unaligned heads/tails and the `word_parallel = false` toggle
//! fall back to the retained scalar reference path, which is bit-exact
//! against the word path (including `EnergyMeter` totals) — property
//! tested in `tests/property_tests.rs` and raced in
//! `benches/bench_hotpath.rs` (see EXPERIMENTS.md §Perf for numbers).

use super::bank::MemoryMap;
use super::energy::EnergyCard;
use crate::circuit::flip_model::FlipModel;
use crate::util::rng::Pcg64;

/// The SRAM bit positions of one byte for a 1S·NE mixed composition that
/// tiles a byte exactly (`(n+1)` divides 8, i.e. n ∈ {0, 1, 3, 7} —
/// debug-asserted): cells stripe as groups of `n+1` bits whose
/// most-significant bit is the SRAM cell, so bit `i` is SRAM iff
/// `(7 − i) % (n + 1) == 0`. The paper's `n = 7` gives `0x80` — exactly
/// the sign plane. `n = 0` is all-SRAM (`0xff`). This mask is part of the
/// array *specification*: the golden model and the analytic design-space
/// evaluator must stripe identically — for byte-tiling ratios the
/// evaluator's global stripe (`global_cell_index % (n+1) == 0`, see
/// `dse::eval`) reduces to exactly this per-byte mask; non-tiling ratios
/// have no uniform per-byte mask and exist only in the analytic model.
#[inline]
pub fn sram_plane_mask(n: u32) -> u8 {
    debug_assert!(
        n <= 7 && 8 % (n + 1) == 0,
        "per-byte mask defined only for byte-tiling ratios 0/1/3/7, got {n}"
    );
    let group = n + 1;
    let mut mask = 0u8;
    for i in 0..8u32 {
        if (7 - i) % group == 0 {
            mask |= 1 << i;
        }
    }
    mask
}

/// Energy/event meter for one array.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EnergyMeter {
    pub read_j: f64,
    pub write_j: f64,
    pub refresh_j: f64,
    pub static_j: f64,
    pub reads: u64,
    pub writes: u64,
    pub refreshes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub flips_committed: u64,
    /// SECDED single-bit corrections applied by the refresh-ride-along
    /// scrub (`mcaimem@V+ecc` specs only; see [`super::ecc`]).
    pub ecc_corrected: u64,
    /// Access-latency time accrued by slow technologies (s) — only the
    /// RRAM backend's SET/RESET programming path populates this today.
    pub busy_s: f64,
}

impl EnergyMeter {
    pub fn total_j(&self) -> f64 {
        self.read_j + self.write_j + self.refresh_j + self.static_j
    }

    /// Accumulate another meter into this one (field-wise sum) — how the
    /// sharded backend folds per-shard meters into one read-out.
    pub fn merge(&mut self, other: &EnergyMeter) {
        self.read_j += other.read_j;
        self.write_j += other.write_j;
        self.refresh_j += other.refresh_j;
        self.static_j += other.static_j;
        self.reads += other.reads;
        self.writes += other.writes;
        self.refreshes += other.refreshes;
        self.bytes_read += other.bytes_read;
        self.bytes_written += other.bytes_written;
        self.flips_committed += other.flips_committed;
        self.ecc_corrected += other.ecc_corrected;
        self.busy_s += other.busy_s;
    }
}

/// The functional mixed-cell memory.
pub struct MixedCellMemory {
    pub map: MemoryMap,
    pub flip: FlipModel,
    pub vref: f64,
    /// Mixed-cell ratio N of the 1S·NE composition (paper default 7). The
    /// functional array supports the byte-tiling ratios {0, 1, 3, 7}; the
    /// analytic design-space evaluator covers the full 0..=15 range.
    pub ratio: u32,
    pub card: EnergyCard,
    /// One-enhancement encoder in front of the array (paper default: on).
    pub encode_enabled: bool,
    /// When false the eDRAM planes are error-free (used to emulate the SRAM
    /// baseline on identical plumbing).
    pub inject_enabled: bool,
    /// SECDED check-byte plane over every 64-bit stored word
    /// ([`super::ecc`]): stores re-baseline their codewords, the refresh
    /// pass scrubs (single flips corrected, write-back charged). Set at
    /// construction by the `mcaimem@V+ecc` spec — toggling after data has
    /// aged leaves stale check bytes.
    pub ecc_enabled: bool,
    /// Use the word-parallel (SWAR bit-plane transpose) access path for
    /// aligned 64-byte blocks. The scalar byte-at-a-time path is retained
    /// as a bit-exact reference (`word_parallel = false`) for equivalence
    /// tests and the before/after benchmarks.
    pub word_parallel: bool,
    /// Bit-planes, LSB-first; at the paper's ratio plane 7 is the SRAM
    /// (sign) plane, generally [`sram_plane_mask`] selects the SRAM planes.
    /// Packed 64 bytes/word per plane.
    planes: [Vec<u64>; 8],
    /// Bit mask of the eDRAM planes (`!sram_plane_mask(ratio)`).
    edram_mask: u8,
    /// Number of eDRAM planes (`edram_mask.count_ones()`).
    n_edram: usize,
    /// Per-cell quantized leakage z-score, one byte per eDRAM cell
    /// (`leak_z[plane][addr]`), mapping q ∈ [0,255] → z ∈ [−4σ, +4σ].
    leak_z: [Vec<u8>; 7],
    /// Last row-activation time, indexed bank*rows + row (s).
    row_time: Vec<f64>,
    /// Running ones count over the 7 eDRAM planes (static-power estimate).
    edram_ones: u64,
    /// One SECDED check byte per 64-bit stored word (only consulted when
    /// `ecc_enabled`; initialized to the all-ones power-on codeword).
    ecc_check: Vec<u8>,
    pub meter: EnergyMeter,
    now: f64,
}

/// Quantization of the per-cell z-score: q ∈ [0, 255] ↔ z ∈ [−4, 4].
/// Public because it is part of the *specification* of the per-cell
/// leakage population: the golden model ([`crate::sim::oracle`]) must
/// sample bit-identical corners to be a meaningful differential oracle.
#[inline]
pub fn z_to_q(z: f64) -> u8 {
    (((z + 4.0) / 8.0 * 255.0).round()).clamp(0.0, 255.0) as u8
}

impl MixedCellMemory {
    /// A paper-default array (V_REF = 0.8, encoder on) of `bytes` capacity.
    pub fn new(bytes: usize, seed: u64) -> Self {
        Self::with_vref(bytes, 0.8, seed)
    }

    pub fn with_vref(bytes: usize, vref: f64, seed: u64) -> Self {
        Self::with_geometry(bytes, vref, 7, seed)
    }

    /// A mixed array with an explicit 1S·NE cell ratio. Only the ratios
    /// whose `(n+1)`-cell groups tile a byte exactly (n ∈ {0, 1, 3, 7}) are
    /// representable by the byte-oriented functional array; the analytic
    /// evaluator in [`crate::dse`] covers the full 0..=15 range. `n = 0`
    /// behaves as SRAM on identical plumbing (no eDRAM planes, no flips,
    /// no refresh).
    pub fn with_geometry(bytes: usize, vref: f64, ratio: u32, seed: u64) -> Self {
        Self::with_map(MemoryMap::with_capacity(bytes), vref, ratio, seed)
    }

    /// A mixed array over an explicit bank organization — how a compiled
    /// [`crate::mem::compiler::MacroSpec`]'s geometry becomes a runnable
    /// array. The per-cell leakage population depends only on (capacity,
    /// seed), so re-banking the same capacity keeps the same cells in the
    /// same address order (the map changes *where* a row boundary falls,
    /// not *who* leaks).
    pub fn with_map(map: MemoryMap, vref: f64, ratio: u32, seed: u64) -> Self {
        assert!(
            ratio <= 7 && 8 % (ratio + 1) == 0,
            "functional array supports byte-tiling ratios 0/1/3/7, got 1S·{ratio}E \
             (use dse::eval for the analytic full range)"
        );
        assert!(
            map.bank.row_bytes % 64 == 0,
            "row width must be whole 64-byte words (word-parallel row scan), got {} B",
            map.bank.row_bytes
        );
        let edram_mask = !sram_plane_mask(ratio);
        let n_edram = edram_mask.count_ones() as usize;
        let cap = map.capacity();
        let words = cap.div_ceil(64);
        let mut rng = Pcg64::new(seed);
        // Sample each cell's process corner once (Pelgrom mismatch is a
        // manufacturing property, not a per-access event). Sampling is via
        // a 4096-entry inverse-CDF table on 12-bit uniforms — §Perf: the
        // Box–Muller path made 8MB-buffer construction ~10× slower; 12-bit
        // quantile resolution is finer than the 8-bit storage quantization.
        let icdf: Vec<u8> = (0..4096)
            .map(|i| z_to_q(crate::util::stats::normal_quantile((i as f64 + 0.5) / 4096.0)))
            .collect();
        let leak_z: [Vec<u8>; 7] = std::array::from_fn(|_| {
            let mut v = Vec::with_capacity(cap);
            let mut i = 0;
            while i < cap {
                // five 12-bit draws per u64
                let r = rng.next_u64();
                for k in 0..5 {
                    if i >= cap {
                        break;
                    }
                    v.push(icdf[((r >> (12 * k)) & 0xfff) as usize]);
                    i += 1;
                }
            }
            v
        });
        MixedCellMemory {
            map,
            flip: FlipModel::mcaimem_85c(),
            vref,
            ratio,
            card: EnergyCard::mcaimem_ratio(vref, ratio),
            encode_enabled: true,
            inject_enabled: true,
            ecc_enabled: false,
            word_parallel: true,
            // power-on state: pull-up leakage parks every cell at bit-1
            planes: std::array::from_fn(|_| vec![u64::MAX; words]),
            edram_mask,
            n_edram,
            leak_z,
            row_time: vec![0.0; map.total_rows()],
            edram_ones: (cap * n_edram) as u64,
            ecc_check: vec![super::ecc::check_byte(u64::MAX); cap / super::ecc::WORD_BYTES],
            meter: EnergyMeter::default(),
            now: 0.0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.map.capacity()
    }

    /// Current fraction of ones in the eDRAM planes (drives static power).
    /// 0 for a ratio-0 (pure SRAM) array, which has no eDRAM planes.
    pub fn edram_ones_frac(&self) -> f64 {
        self.edram_ones as f64 / (self.capacity() * self.n_edram).max(1) as f64
    }

    /// Advance the wall clock, integrating static energy. Monotone.
    pub fn advance_to(&mut self, now: f64) {
        assert!(now + 1e-15 >= self.now, "time must be monotone");
        let dt = now - self.now;
        if dt > 0.0 {
            self.meter.static_j +=
                self.card.static_power(self.capacity(), self.edram_ones_frac()) * dt;
        }
        self.now = now;
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    #[inline]
    fn get_byte_raw(&self, addr: usize) -> u8 {
        let (w, b) = (addr / 64, addr % 64);
        let mut v = 0u8;
        for (p, plane) in self.planes.iter().enumerate() {
            v |= (((plane[w] >> b) & 1) as u8) << p;
        }
        v
    }

    #[inline]
    fn set_byte_raw(&mut self, addr: usize, value: u8) {
        let (w, b) = (addr / 64, addr % 64);
        let mask = 1u64 << b;
        for (p, plane) in self.planes.iter_mut().enumerate() {
            let old = (plane[w] & mask) != 0;
            let new = (value >> p) & 1 == 1;
            if old != new {
                plane[w] ^= mask;
                if self.edram_mask & (1 << p) != 0 {
                    // maintain the eDRAM ones census
                    if new {
                        self.edram_ones += 1;
                    } else {
                        self.edram_ones -= 1;
                    }
                }
            }
        }
    }

    /// Assemble the stored (post-encode) 64-bit word `w` — little-endian
    /// over bytes `[8w, 8w+8)` — the codeword unit of the SECDED plane.
    #[inline]
    fn word_raw(&self, w: usize) -> u64 {
        let mut v = 0u64;
        for i in 0..super::ecc::WORD_BYTES {
            v |= (self.get_byte_raw(w * super::ecc::WORD_BYTES + i) as u64) << (8 * i);
        }
        v
    }

    /// Recompute the check bytes of every codeword overlapped by
    /// `[addr, addr + len)`, returning how many were touched. A store
    /// re-baselines its codewords: neighbouring bytes of a partially
    /// overwritten word are protected *as currently stored* (any flip they
    /// already carry is frozen in, exactly like real write-allocate ECC).
    fn rewrite_checks(&mut self, addr: usize, len: usize) -> usize {
        if len == 0 {
            return 0;
        }
        let first = addr / super::ecc::WORD_BYTES;
        let last = (addr + len - 1) / super::ecc::WORD_BYTES;
        for w in first..=last {
            self.ecc_check[w] = super::ecc::check_byte(self.word_raw(w));
        }
        last - first + 1
    }

    /// The z-score threshold above which a cell's stored 0 has crossed
    /// V_REF after `dt` seconds: `z > ln(t_nom/dt)/σ`.
    fn z_threshold(&self, dt: f64) -> f64 {
        let t_nom = self
            .flip
            .leak
            .charge_time(self.vref, self.flip.width_mult, self.flip.temp_c);
        (t_nom / dt).ln() / self.flip.leak.sigma_ln
    }

    /// Activate a row at the current time: age its eDRAM bits (a stored 0
    /// flips iff the cell's *persistent* leakage corner exceeds the
    /// staleness threshold), commit the sensed values, and reset the row
    /// timestamp (refresh-by-read).
    fn touch_row(&mut self, bank: usize, row: usize) {
        let idx = bank * self.map.bank.rows + row;
        let dt = self.now - self.row_time[idx];
        self.row_time[idx] = self.now;
        if !self.inject_enabled || dt <= 0.0 {
            return;
        }
        let z_thr = self.z_threshold(dt);
        if z_thr >= 4.0 {
            return; // even a +4σ cell holds this long
        }
        let q_thr = z_to_q(z_thr);
        let start = bank * self.map.bank.bytes + row * self.map.bank.row_bytes;
        let end = start + self.map.bank.row_bytes;
        // eDRAM planes only (0..7): weak cells' zeros flip to ones.
        // Word-level scan (§Perf): rows are word-aligned, and encoded DNN
        // data plus the all-ones idle state make zero bits sparse — test a
        // whole 64-cell word at once and only visit its zero positions.
        // The leak-row slice (and its bounds check) is hoisted out of the
        // bit loop, flips accumulate into a per-word mask, and the census /
        // meter commit once per row instead of per bit.
        debug_assert!(start % 64 == 0 && end % 64 == 0);
        let edram_mask = self.edram_mask;
        let mut committed = 0u64;
        for w in start / 64..end / 64 {
            let base = w * 64;
            for (p, (plane, zplane)) in
                self.planes[..7].iter_mut().zip(self.leak_z.iter()).enumerate()
            {
                if edram_mask & (1 << p) == 0 {
                    continue; // SRAM plane: never corrupts
                }
                let mut zeros = !plane[w];
                if zeros == 0 {
                    continue;
                }
                let zrow = &zplane[base..base + 64];
                let mut flips = 0u64;
                while zeros != 0 {
                    let b = zeros.trailing_zeros() as usize;
                    zeros &= zeros - 1;
                    if zrow[b] > q_thr {
                        flips |= 1u64 << b;
                    }
                }
                if flips != 0 {
                    plane[w] |= flips;
                    committed += flips.count_ones() as u64;
                }
            }
        }
        self.edram_ones += committed;
        self.meter.flips_committed += committed;
    }

    fn touch_range(&mut self, addr: usize, len: usize) {
        if len == 0 {
            return;
        }
        let first = addr / self.map.bank.row_bytes;
        let last = (addr + len - 1) / self.map.bank.row_bytes;
        for flat_row in first..=last {
            let bank = flat_row / self.map.bank.rows;
            let row = flat_row % self.map.bank.rows;
            self.touch_row(bank, row);
        }
    }

    /// Store one byte (encode + plane update), returning its stored eDRAM
    /// ones count — the scalar reference step both paths share for
    /// unaligned heads/tails.
    #[inline]
    fn store_byte(&mut self, addr: usize, raw: u8) -> u64 {
        let stored = if self.encode_enabled {
            crate::encode::one_enhancement::encode_byte(raw)
        } else {
            raw
        };
        self.set_byte_raw(addr, stored);
        (stored & self.edram_mask).count_ones() as u64
    }

    /// Fetch + decode one byte into `out`, returning its stored eDRAM ones
    /// count (counted pre-decode, like the energy model expects).
    #[inline]
    fn fetch_byte(&self, addr: usize, out: &mut Vec<u8>) -> u64 {
        let stored = self.get_byte_raw(addr);
        out.push(if self.encode_enabled {
            crate::encode::one_enhancement::decode_byte(stored)
        } else {
            stored
        });
        (stored & self.edram_mask).count_ones() as u64
    }

    /// Scalar reference store path (byte at a time through every plane).
    fn store_scalar(&mut self, addr: usize, data: &[u8]) -> u64 {
        let mut ones = 0u64;
        for (i, &raw) in data.iter().enumerate() {
            ones += self.store_byte(addr + i, raw);
        }
        ones
    }

    /// Word-parallel store: aligned 64-byte blocks go through the SWAR
    /// transpose + word-level encode; ragged edges reuse the scalar step.
    fn store_words(&mut self, addr: usize, data: &[u8]) -> u64 {
        let _t = crate::obs::profile::phase(crate::obs::profile::Phase::Transpose);
        let end = addr + data.len();
        let mut a = addr;
        let mut ones = 0u64;
        let head_end = end.min((addr + 63) & !63);
        while a < head_end {
            ones += self.store_byte(a, data[a - addr]);
            a += 1;
        }
        while a + 64 <= end {
            let chunk: &[u8; 64] = data[a - addr..a - addr + 64].try_into().unwrap();
            let mut pl = super::bitplane::bytes_to_planes(chunk);
            if self.encode_enabled {
                crate::encode::one_enhancement::encode_words(&mut pl);
            }
            let w = a / 64;
            for (p, &new) in pl.iter().enumerate() {
                if self.edram_mask & (1 << p) != 0 {
                    let newly = new.count_ones() as u64;
                    ones += newly;
                    self.edram_ones += newly;
                    self.edram_ones -= self.planes[p][w].count_ones() as u64;
                }
                self.planes[p][w] = new;
            }
            a += 64;
        }
        while a < end {
            ones += self.store_byte(a, data[a - addr]);
            a += 1;
        }
        ones
    }

    /// Scalar reference fetch path.
    fn fetch_scalar(&self, addr: usize, len: usize, out: &mut Vec<u8>) -> u64 {
        let mut ones = 0u64;
        for i in 0..len {
            ones += self.fetch_byte(addr + i, out);
        }
        ones
    }

    /// Word-parallel fetch: whole plane words → popcount census →
    /// word-level decode → inverse transpose.
    fn fetch_words(&self, addr: usize, len: usize, out: &mut Vec<u8>) -> u64 {
        let _t = crate::obs::profile::phase(crate::obs::profile::Phase::Census);
        let end = addr + len;
        let mut a = addr;
        let mut ones = 0u64;
        let head_end = end.min((addr + 63) & !63);
        while a < head_end {
            ones += self.fetch_byte(a, out);
            a += 1;
        }
        while a + 64 <= end {
            let w = a / 64;
            let mut pl = [0u64; 8];
            for (p, plane) in self.planes.iter().enumerate() {
                pl[p] = plane[w];
                if self.edram_mask & (1 << p) != 0 {
                    ones += plane[w].count_ones() as u64;
                }
            }
            if self.encode_enabled {
                crate::encode::one_enhancement::decode_words(&mut pl);
            }
            out.extend_from_slice(&super::bitplane::planes_to_bytes(&pl));
            a += 64;
        }
        while a < end {
            ones += self.fetch_byte(a, out);
            a += 1;
        }
        ones
    }

    /// Write `data` at `addr`, time `now`. Data is encoded (if enabled)
    /// before hitting the array, as in Fig. 4.
    pub fn write(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.capacity(), "write out of range");
        self.advance_to(now);
        self.touch_range(addr, data.len());
        let ones = if self.word_parallel {
            self.store_words(addr, data)
        } else {
            self.store_scalar(addr, data)
        };
        // `.max(1)` guards the empty write (and the ratio-0 array, which
        // has no eDRAM planes): 0/0 would poison `write_j` with NaN (the
        // read path below has always carried the same guard).
        let frac = ones as f64 / (data.len() * self.n_edram).max(1) as f64;
        self.meter.write_j += self.card.write_energy(data.len(), frac);
        if self.ecc_enabled {
            let words = self.rewrite_checks(addr, data.len());
            self.meter.write_j += self.card.ecc_write_energy(words);
        }
        self.meter.writes += 1;
        self.meter.bytes_written += data.len() as u64;
    }

    /// Read `len` bytes at `addr`, time `now` — decoded, with any retention
    /// flips the elapsed time produced (and committed back to the array).
    pub fn read(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.capacity(), "read out of range");
        self.advance_to(now);
        self.touch_range(addr, len);
        let mut out = Vec::with_capacity(len);
        let ones = if self.word_parallel {
            self.fetch_words(addr, len, &mut out)
        } else {
            self.fetch_scalar(addr, len, &mut out)
        };
        let frac = ones as f64 / (len * self.n_edram).max(1) as f64;
        self.meter.read_j += self.card.read_energy(len, frac);
        self.meter.reads += 1;
        self.meter.bytes_read += len as u64;
        out
    }

    /// Apply one refresh slot (from [`super::refresh::RefreshController`]):
    /// activates the row in every bank in parallel.
    pub fn refresh_row(&mut self, row: usize, now: f64) {
        self.advance_to(now);
        for bank in 0..self.map.banks {
            self.touch_row(bank, row);
        }
        let bytes = self.map.bank.row_bytes * self.map.banks;
        self.meter.refresh_j +=
            self.card.refresh_pass_energy(bytes, self.edram_ones_frac());
        self.meter.refreshes += 1;
        if self.ecc_enabled {
            self.scrub_row(row, bytes);
        }
    }

    /// SECDED scrub riding the refresh pass (§III-C refresh-by-read + ECC):
    /// the CVSA has just sensed (and committed) the row in every bank; the
    /// scrub reads the check plane alongside, corrects any single-bit flip
    /// per codeword, and charges the correction write-backs. Multi-bit
    /// damage is detected but left in place — the differential oracle must
    /// agree on exactly which words stay corrupted.
    fn scrub_row(&mut self, row: usize, bytes: usize) {
        let row_bytes = self.map.bank.row_bytes;
        let mut corrections = 0usize;
        for bank in 0..self.map.banks {
            let start = bank * self.map.bank.bytes + row * row_bytes;
            debug_assert!(start % super::ecc::WORD_BYTES == 0);
            for w in start / super::ecc::WORD_BYTES
                ..(start + row_bytes) / super::ecc::WORD_BYTES
            {
                let stored = self.word_raw(w);
                if let Some((fixed, bit)) = super::ecc::scrub_word(stored, self.ecc_check[w]) {
                    let byte_in_word = (bit / 8) as usize;
                    self.set_byte_raw(
                        w * super::ecc::WORD_BYTES + byte_in_word,
                        (fixed >> (8 * byte_in_word)) as u8,
                    );
                    corrections += 1;
                }
            }
        }
        self.meter.refresh_j += self.card.ecc_scrub_energy(bytes);
        if corrections > 0 {
            self.meter.refresh_j +=
                self.card.write_energy(corrections, self.edram_ones_frac());
            self.meter.ecc_corrected += corrections as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh(bytes: usize) -> MixedCellMemory {
        MixedCellMemory::new(bytes, 0xBEEF)
    }

    #[test]
    fn rebanked_geometry_keeps_the_same_cell_population() {
        // with_map is the compiled-macro entry point: same capacity + seed
        // ⇒ the identical per-cell leakage draw, so re-banking only moves
        // row boundaries. An op sequence that ages the whole array equally
        // must flip the exact same cells under either organization.
        use crate::mem::bank::{BankGeometry, MemoryMap};
        let bytes = 32 * 1024;
        let run = |map: MemoryMap| {
            let mut m = MixedCellMemory::with_map(map, 0.8, 7, 0xBEEF);
            assert_eq!(m.capacity(), bytes);
            let data: Vec<u8> = (0..bytes).map(|i| (i * 31) as u8).collect();
            m.write(0, &data, 1e-9);
            // one whole retention window with no refresh, then read it all
            m.read(0, bytes, 40e-6)
        };
        let flat = run(MemoryMap::with_capacity(bytes));
        let tall = run(MemoryMap::with_geometry(bytes, BankGeometry::new(bytes / 2, 128)));
        assert_eq!(flat, tall, "aging must be a cell property, not a banking property");
    }

    #[test]
    #[should_panic(expected = "64-byte words")]
    fn sub_word_rows_are_rejected() {
        use crate::mem::bank::{BankGeometry, MemoryMap};
        let g = BankGeometry { bytes: 1024, rows: 32, row_bytes: 32 };
        MixedCellMemory::with_map(MemoryMap::with_geometry(4096, g), 0.8, 7, 1);
    }

    #[test]
    fn roundtrip_without_aging_is_exact() {
        let mut m = fresh(4096);
        let data: Vec<u8> = (0..=255u8).collect();
        m.write(100, &data, 1e-9);
        let back = m.read(100, data.len(), 2e-9);
        assert_eq!(back, data);
    }

    #[test]
    fn fresh_data_within_refresh_period_is_safe() {
        let mut m = fresh(4096);
        let data: Vec<u8> = (0..64).map(|i| i as u8).collect();
        m.write(0, &data, 0.0);
        // read just inside the 12.57 µs window: ≤1 % flip per bit-0; with
        // 64 bytes the expected corruption is < 1 byte, usually zero for
        // encoded near-zero data (few stored zeros)
        let back = m.read(0, 64, 12.0e-6);
        let diff = back.iter().zip(&data).filter(|(a, b)| a != b).count();
        assert!(diff <= 4, "diff={diff}");
    }

    #[test]
    fn stale_data_corrupts_and_errors_are_cumulative() {
        let mut m = fresh(4096);
        // store raw zeros with the encoder OFF: stored bytes are 0x00 —
        // all 7 eDRAM bits are 0 and will flip eventually
        m.encode_enabled = false;
        m.write(0, &[0u8; 64], 0.0);
        let back = m.read(0, 64, 200e-6); // ~16 refresh periods stale
        let corrupted = back.iter().filter(|&&b| b != 0).count();
        assert!(corrupted > 56, "corrupted={corrupted}/64");
        // sign plane (bit 7) never flips
        assert!(back.iter().all(|&b| b & 0x80 == 0));
        // errors persist after commit: an immediate re-read returns the
        // same corrupted values
        let again = m.read(0, 64, 200.1e-6);
        assert_eq!(back, again);
    }

    #[test]
    fn encoder_protects_near_zero_data() {
        // the paper's core claim: near-zero DNN data encoded to 1-dominant
        // form survives staleness that destroys unencoded data
        let data: Vec<u8> = (0..64u8).map(|i| (i % 5)).collect(); // small positives
        let stale = 40e-6;

        let mut enc = fresh(4096);
        enc.write(0, &data, 0.0);
        let enc_back = enc.read(0, 64, stale);
        let enc_errs = enc_back.iter().zip(&data).filter(|(a, b)| a != b).count();

        let mut raw = fresh(4096);
        raw.encode_enabled = false;
        raw.write(0, &data, 0.0);
        let raw_back = raw.read(0, 64, stale);
        let raw_errs = raw_back.iter().zip(&data).filter(|(a, b)| a != b).count();

        assert!(enc_errs < raw_errs, "encoded {enc_errs} vs raw {raw_errs}");
    }

    #[test]
    fn refresh_prevents_corruption() {
        let mut m = fresh(4096);
        m.encode_enabled = false; // store worst-case zeros
        m.write(0, &[0u8; 64], 0.0);
        // refresh row 0 every 6 µs for 120 µs (well inside retention)
        let mut t = 0.0;
        for _ in 0..20 {
            t += 6e-6;
            m.refresh_row(0, t);
        }
        let back = m.read(0, 64, t + 1e-6);
        let corrupted = back.iter().filter(|&&b| b != 0).count();
        // each 6 µs window has ~0 flip probability at V_REF 0.8
        assert!(corrupted <= 1, "corrupted={corrupted}");
        assert_eq!(m.meter.refreshes, 20);
    }

    #[test]
    fn bit1_data_is_immortal() {
        let mut m = fresh(4096);
        m.encode_enabled = false;
        m.write(0, &[0x7f; 64], 0.0); // all eDRAM bits = 1
        let back = m.read(0, 64, 1.0); // one full second unrefreshed
        assert!(back.iter().all(|&b| b == 0x7f));
    }

    #[test]
    fn meters_accumulate() {
        let mut m = fresh(4096);
        m.write(0, &[1, 2, 3, 4], 1e-6);
        let _ = m.read(0, 4, 2e-6);
        m.refresh_row(0, 3e-6);
        assert_eq!(m.meter.writes, 1);
        assert_eq!(m.meter.reads, 1);
        assert_eq!(m.meter.refreshes, 1);
        assert!(m.meter.write_j > 0.0);
        assert!(m.meter.read_j > 0.0);
        assert!(m.meter.refresh_j > 0.0);
        assert!(m.meter.static_j > 0.0);
        assert_eq!(m.meter.bytes_written, 4);
    }

    #[test]
    fn ones_census_tracks_writes() {
        let mut m = fresh(4096);
        m.encode_enabled = false;
        assert_eq!(m.edram_ones_frac(), 1.0); // power-on: everything at 1
        m.write(0, &[0x00; 64], 1e-9); // clear 7×64 eDRAM bits
        let expect = 1.0 - (7 * 64) as f64 / (m.capacity() * 7) as f64;
        assert!((m.edram_ones_frac() - expect).abs() < 1e-12);
        m.write(0, &[0x7f; 64], 2e-9);
        assert_eq!(m.edram_ones_frac(), 1.0);
    }

    #[test]
    fn empty_write_does_not_poison_the_meter() {
        // regression: `write` divided by `data.len() * 7` without the
        // `.max(1)` guard its twin `read` carries, so a zero-length write
        // turned `meter.write_j` into NaN forever after
        for word_parallel in [true, false] {
            let mut m = fresh(4096);
            m.word_parallel = word_parallel;
            m.write(0, &[], 1e-9);
            assert!(m.meter.write_j == 0.0, "wp={word_parallel}: {}", m.meter.write_j);
            assert_eq!(m.meter.writes, 1);
            assert_eq!(m.meter.bytes_written, 0);
            m.write(0, &[1, 2, 3], 2e-9);
            assert!(
                m.meter.write_j.is_finite() && m.meter.write_j > 0.0,
                "wp={word_parallel}: {}",
                m.meter.write_j
            );
            let empty = m.read(0, 0, 3e-9);
            assert!(empty.is_empty() && m.meter.read_j == 0.0);
        }
    }

    #[test]
    fn merge_is_exhaustive_over_every_meter_field() {
        let a = EnergyMeter {
            read_j: 1.0,
            write_j: 2.0,
            refresh_j: 3.0,
            static_j: 4.0,
            reads: 5,
            writes: 6,
            refreshes: 7,
            bytes_read: 8,
            bytes_written: 9,
            flips_committed: 10,
            ecc_corrected: 11,
            busy_s: 12.0,
        };
        let b = EnergyMeter {
            read_j: 0.25,
            write_j: 0.5,
            refresh_j: 0.75,
            static_j: 1.25,
            reads: 100,
            writes: 200,
            refreshes: 300,
            bytes_read: 400,
            bytes_written: 500,
            flips_committed: 600,
            ecc_corrected: 700,
            busy_s: 1.5,
        };
        let mut m = a.clone();
        m.merge(&b);
        // full destructuring, no `..`: adding a meter field without updating
        // `merge` (and this test, and the trace/replay serializers listed in
        // the field's doc) fails to compile right here
        let EnergyMeter {
            read_j,
            write_j,
            refresh_j,
            static_j,
            reads,
            writes,
            refreshes,
            bytes_read,
            bytes_written,
            flips_committed,
            ecc_corrected,
            busy_s,
        } = m;
        assert_eq!(read_j, 1.25);
        assert_eq!(write_j, 2.5);
        assert_eq!(refresh_j, 3.75);
        assert_eq!(static_j, 5.25);
        assert_eq!(reads, 105);
        assert_eq!(writes, 206);
        assert_eq!(refreshes, 307);
        assert_eq!(bytes_read, 408);
        assert_eq!(bytes_written, 509);
        assert_eq!(flips_committed, 610);
        assert_eq!(ecc_corrected, 711);
        assert_eq!(busy_s, 13.5);
    }

    #[test]
    fn ecc_scrub_repairs_an_isolated_retention_flip() {
        // grow the refresh gap until the weakest resident cell of one
        // all-zeros codeword flips; the scrub rides the same refresh pass
        // and must write the zero back. The first committed flip is usually
        // isolated, but 8-bit leak quantization can tie cells — so sweep
        // seeds and require the single-flip case to occur (deterministic:
        // the seeds are fixed).
        let mut strong = false;
        for seed in 0..24u64 {
            let mut m = MixedCellMemory::new(4096, seed);
            m.encode_enabled = false;
            m.ecc_enabled = true;
            m.write(0, &[0u8; 8], 0.0);
            let (mut t, mut gap) = (0.0, 4e-6);
            for _ in 0..48 {
                t += gap;
                m.refresh_row(0, t);
                if m.meter.flips_committed > 0 {
                    break;
                }
                gap *= 1.3;
            }
            assert!(m.meter.flips_committed > 0, "seed {seed}: no flip by t={t}");
            assert!(m.meter.ecc_corrected <= m.meter.flips_committed);
            if m.meter.flips_committed == 1 {
                assert_eq!(m.meter.ecc_corrected, 1, "seed {seed}");
                assert_eq!(m.read(0, 8, t + 1e-9), vec![0u8; 8], "seed {seed}");
                strong = true;
            }
        }
        assert!(strong, "no seed produced an isolated single flip");
    }

    #[test]
    fn ecc_on_clean_data_corrects_nothing_but_charges_the_scrub() {
        let mk = |ecc: bool| {
            let mut m = fresh(4096);
            m.ecc_enabled = ecc;
            m.write(0, &[0x55u8; 64], 1e-9);
            m.refresh_row(0, 2e-6); // well inside retention: nothing flips
            m
        };
        let (with, without) = (mk(true), mk(false));
        assert_eq!(with.meter.ecc_corrected, 0);
        assert_eq!(with.meter.flips_committed, 0);
        // scrub + check-plane writes are charged even when nothing corrects
        assert!(with.meter.refresh_j > without.meter.refresh_j);
        assert!(with.meter.write_j > without.meter.write_j);
        // and the data path is untouched
        let mut with = with;
        assert_eq!(with.read(0, 64, 3e-6), vec![0x55u8; 64]);
    }

    #[test]
    fn ecc_word_and_scalar_paths_agree() {
        // the check plane is rebuilt from the post-store raw image, so it
        // must be identical whichever access path stored the data
        let mk = |word_parallel: bool| {
            let mut m = fresh(16 * 1024);
            m.ecc_enabled = true;
            m.word_parallel = word_parallel;
            let data: Vec<u8> = (0..300u32).map(|i| (i * 31 + 5) as u8).collect();
            for (addr, stale) in [(0usize, 1e-6), (13, 20e-6), (64, 45e-6)] {
                let t = m.now() + stale;
                m.write(addr, &data, t);
                m.refresh_row(0, t + 1e-6);
            }
            let back = m.read(0, 512, m.now() + 1e-6);
            (back, m.meter.clone())
        };
        let (a, ma) = mk(true);
        let (b, mb) = mk(false);
        assert_eq!(a, b);
        assert_eq!(ma, mb);
    }

    #[test]
    fn word_parallel_matches_scalar_reference() {
        // same seed → same per-cell leakage corners; identical op sequence
        // through both paths must give identical bytes, meters and census
        // (the heavy randomized version lives in tests/property_tests.rs)
        let mut fast = fresh(16 * 1024);
        let mut slow = fresh(16 * 1024);
        slow.word_parallel = false;
        let data: Vec<u8> = (0..777u32).map(|i| (i * 37 + 11) as u8).collect();
        for (addr, stale) in [(0usize, 1e-6), (13, 20e-6), (64, 1e-6), (100, 45e-6)] {
            let t = fast.now() + stale;
            fast.write(addr, &data, t);
            slow.write(addr, &data, t);
            let t2 = t + stale;
            let a = fast.read(addr, data.len(), t2);
            let b = slow.read(addr, data.len(), t2);
            assert_eq!(a, b, "addr={addr} stale={stale}");
        }
        assert_eq!(fast.meter, slow.meter);
        assert_eq!(fast.edram_ones_frac(), slow.edram_ones_frac());
    }

    #[test]
    fn sram_plane_mask_stripes_from_the_sign_bit() {
        assert_eq!(sram_plane_mask(7), 0x80); // the paper's cell: sign only
        assert_eq!(sram_plane_mask(3), 0x88); // groups of 4: bits 7 and 3
        assert_eq!(sram_plane_mask(1), 0xAA); // groups of 2: odd bits
        assert_eq!(sram_plane_mask(0), 0xFF); // pure SRAM
        for n in [0u32, 1, 3, 7] {
            assert!(sram_plane_mask(n) & 0x80 != 0, "sign always protected in-byte: n={n}");
        }
    }

    #[test]
    fn ratio_controls_which_planes_corrupt() {
        // store raw zeros (encoder off) and age far past retention: only
        // the eDRAM planes flip; every SRAM plane of the stripe holds
        for (ratio, sram_mask) in [(7u32, 0x80u8), (3, 0x88), (1, 0xAA)] {
            let mut m = MixedCellMemory::with_geometry(4096, 0.8, ratio, 0xBEEF);
            m.encode_enabled = false;
            m.write(0, &[0u8; 64], 0.0);
            let back = m.read(0, 64, 500e-6); // ~40 refresh periods stale
            assert!(
                back.iter().all(|&b| b & sram_mask == 0),
                "ratio={ratio}: SRAM planes must hold zeros"
            );
            let corrupted = back.iter().filter(|&&b| b != 0).count();
            assert!(corrupted > 56, "ratio={ratio}: corrupted={corrupted}/64");
        }
    }

    #[test]
    fn ratio0_is_sram_on_identical_plumbing() {
        let mut m = MixedCellMemory::with_geometry(4096, 0.8, 0, 1);
        m.encode_enabled = false;
        assert_eq!(m.card.refresh_period, None);
        assert_eq!(m.edram_ones_frac(), 0.0);
        m.write(0, &[0u8; 64], 0.0);
        let back = m.read(0, 64, 1.0); // a full second unrefreshed
        assert!(back.iter().all(|&b| b == 0), "no eDRAM planes → no flips");
        assert_eq!(m.meter.flips_committed, 0);
    }

    #[test]
    fn ratio_word_and_scalar_paths_agree() {
        for ratio in [1u32, 3] {
            let mut fast = MixedCellMemory::with_geometry(16 * 1024, 0.8, ratio, 7);
            let mut slow = MixedCellMemory::with_geometry(16 * 1024, 0.8, ratio, 7);
            slow.word_parallel = false;
            let data: Vec<u8> = (0..300u32).map(|i| (i * 31 + 5) as u8).collect();
            for (addr, stale) in [(0usize, 1e-6), (13, 30e-6), (64, 45e-6)] {
                let t = fast.now() + stale;
                fast.write(addr, &data, t);
                slow.write(addr, &data, t);
                let a = fast.read(addr, data.len(), t + stale);
                let b = slow.read(addr, data.len(), t + stale);
                assert_eq!(a, b, "ratio={ratio} addr={addr}");
            }
            assert_eq!(fast.meter, slow.meter, "ratio={ratio}");
        }
    }

    #[test]
    #[should_panic(expected = "byte-tiling ratios")]
    fn non_tiling_ratio_rejected_by_the_functional_array() {
        let _ = MixedCellMemory::with_geometry(4096, 0.8, 5, 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn write_bounds_checked() {
        let mut m = fresh(4096);
        let cap = m.capacity();
        m.write(cap - 2, &[0; 4], 0.0);
    }

    #[test]
    fn static_energy_integrates_with_time() {
        let mut m = fresh(16 * 1024);
        m.advance_to(1e-3); // 1 ms idle at the all-ones power-on state
        let e = m.meter.static_j;
        // 16 KB at the all-ones corner: 3.15 mW/MB × (16/1024) MB × 1 ms
        let expect = 3.15e-3 * (16.0 / 1024.0) * 1e-3;
        assert!((e - expect).abs() / expect < 0.01, "e={e} expect={expect}");
    }
}
