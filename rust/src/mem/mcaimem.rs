//! The functional MCAIMem array — real bytes, real bit-planes, physical
//! 0→1 retention flips, refresh-by-read (paper Fig. 4/6, §III).
//!
//! Storage layout follows the paper's mapping (Fig. 6): bit 7 of every byte
//! (the sign/control bit of the one-enhancement code) lives in the 6T SRAM
//! plane and never corrupts; bits 6..0 live in 2T eDRAM planes whose stored
//! zeros drift toward one with the calibrated flip law. All data movement
//! passes through the one-enhancement encoder in front of the array
//! (toggleable, so the paper's with/without-encoder ablations run on the
//! same machinery).
//!
//! Aging is tracked per row. Any access that activates a row (read, write,
//! or refresh slot) senses every column through the CVSA and writes the
//! sensed values back (§III-B3's refresh-by-read), so flips that happened
//! before the access are *committed* — exactly the cumulative-error
//! behaviour the paper injects in §IV-A.
//!
//! Leakage is a **persistent per-cell property**: each eDRAM cell draws a
//! z-score once (quantized to 8 bits) representing its lognormal leakage
//! multiple. A stored 0 flips when its staleness `dt` satisfies
//! `mult > t_nom(V_REF)/dt` ⇔ `z > ln(t_nom/dt)/σ` — so a refresh cadence
//! faster than the weakest resident cell keeps data alive *forever*
//! (the property a resampling model would destroy: under independent
//! redraws every cell dies after enough refresh windows). Unwritten cells
//! idle at bit-1, the state pull-up leakage drives them to physically.

use super::bank::MemoryMap;
use super::energy::EnergyCard;
use crate::circuit::flip_model::FlipModel;
use crate::util::rng::Pcg64;

/// Energy/event meter for one array.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EnergyMeter {
    pub read_j: f64,
    pub write_j: f64,
    pub refresh_j: f64,
    pub static_j: f64,
    pub reads: u64,
    pub writes: u64,
    pub refreshes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub flips_committed: u64,
}

impl EnergyMeter {
    pub fn total_j(&self) -> f64 {
        self.read_j + self.write_j + self.refresh_j + self.static_j
    }
}

/// The functional mixed-cell memory.
pub struct MixedCellMemory {
    pub map: MemoryMap,
    pub flip: FlipModel,
    pub vref: f64,
    pub card: EnergyCard,
    /// One-enhancement encoder in front of the array (paper default: on).
    pub encode_enabled: bool,
    /// When false the eDRAM planes are error-free (used to emulate the SRAM
    /// baseline on identical plumbing).
    pub inject_enabled: bool,
    /// Bit-planes, LSB-first; plane 7 is the SRAM (sign) plane. Packed
    /// 64 bytes/word per plane.
    planes: [Vec<u64>; 8],
    /// Per-cell quantized leakage z-score, one byte per eDRAM cell
    /// (`leak_z[plane][addr]`), mapping q ∈ [0,255] → z ∈ [−4σ, +4σ].
    leak_z: [Vec<u8>; 7],
    /// Last row-activation time, indexed bank*rows + row (s).
    row_time: Vec<f64>,
    /// Running ones count over the 7 eDRAM planes (static-power estimate).
    edram_ones: u64,
    pub meter: EnergyMeter,
    now: f64,
}

/// Quantization of the per-cell z-score: q ∈ [0, 255] ↔ z ∈ [−4, 4].
#[inline]
fn z_to_q(z: f64) -> u8 {
    (((z + 4.0) / 8.0 * 255.0).round()).clamp(0.0, 255.0) as u8
}

impl MixedCellMemory {
    /// A paper-default array (V_REF = 0.8, encoder on) of `bytes` capacity.
    pub fn new(bytes: usize, seed: u64) -> Self {
        Self::with_vref(bytes, 0.8, seed)
    }

    pub fn with_vref(bytes: usize, vref: f64, seed: u64) -> Self {
        let map = MemoryMap::with_capacity(bytes);
        let cap = map.capacity();
        let words = cap.div_ceil(64);
        let mut rng = Pcg64::new(seed);
        // Sample each cell's process corner once (Pelgrom mismatch is a
        // manufacturing property, not a per-access event). Sampling is via
        // a 4096-entry inverse-CDF table on 12-bit uniforms — §Perf: the
        // Box–Muller path made 8MB-buffer construction ~10× slower; 12-bit
        // quantile resolution is finer than the 8-bit storage quantization.
        let icdf: Vec<u8> = (0..4096)
            .map(|i| z_to_q(crate::util::stats::normal_quantile((i as f64 + 0.5) / 4096.0)))
            .collect();
        let leak_z: [Vec<u8>; 7] = std::array::from_fn(|_| {
            let mut v = Vec::with_capacity(cap);
            let mut i = 0;
            while i < cap {
                // five 12-bit draws per u64
                let r = rng.next_u64();
                for k in 0..5 {
                    if i >= cap {
                        break;
                    }
                    v.push(icdf[((r >> (12 * k)) & 0xfff) as usize]);
                    i += 1;
                }
            }
            v
        });
        MixedCellMemory {
            map,
            flip: FlipModel::mcaimem_85c(),
            vref,
            card: EnergyCard::mcaimem(vref),
            encode_enabled: true,
            inject_enabled: true,
            // power-on state: pull-up leakage parks every cell at bit-1
            planes: std::array::from_fn(|_| vec![u64::MAX; words]),
            leak_z,
            row_time: vec![0.0; map.total_rows()],
            edram_ones: (cap * 7) as u64,
            meter: EnergyMeter::default(),
            now: 0.0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.map.capacity()
    }

    /// Current fraction of ones in the eDRAM planes (drives static power).
    pub fn edram_ones_frac(&self) -> f64 {
        self.edram_ones as f64 / (self.capacity() * 7) as f64
    }

    /// Advance the wall clock, integrating static energy. Monotone.
    pub fn advance_to(&mut self, now: f64) {
        assert!(now + 1e-15 >= self.now, "time must be monotone");
        let dt = now - self.now;
        if dt > 0.0 {
            self.meter.static_j +=
                self.card.static_power(self.capacity(), self.edram_ones_frac()) * dt;
        }
        self.now = now;
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    #[inline]
    fn get_byte_raw(&self, addr: usize) -> u8 {
        let (w, b) = (addr / 64, addr % 64);
        let mut v = 0u8;
        for (p, plane) in self.planes.iter().enumerate() {
            v |= (((plane[w] >> b) & 1) as u8) << p;
        }
        v
    }

    #[inline]
    fn set_byte_raw(&mut self, addr: usize, value: u8) {
        let (w, b) = (addr / 64, addr % 64);
        let mask = 1u64 << b;
        for (p, plane) in self.planes.iter_mut().enumerate() {
            let old = (plane[w] & mask) != 0;
            let new = (value >> p) & 1 == 1;
            if old != new {
                plane[w] ^= mask;
                if p < 7 {
                    // maintain the eDRAM ones census
                    if new {
                        self.edram_ones += 1;
                    } else {
                        self.edram_ones -= 1;
                    }
                }
            }
        }
    }

    /// The z-score threshold above which a cell's stored 0 has crossed
    /// V_REF after `dt` seconds: `z > ln(t_nom/dt)/σ`.
    fn z_threshold(&self, dt: f64) -> f64 {
        let t_nom = self
            .flip
            .leak
            .charge_time(self.vref, self.flip.width_mult, self.flip.temp_c);
        (t_nom / dt).ln() / self.flip.leak.sigma_ln
    }

    /// Activate a row at the current time: age its eDRAM bits (a stored 0
    /// flips iff the cell's *persistent* leakage corner exceeds the
    /// staleness threshold), commit the sensed values, and reset the row
    /// timestamp (refresh-by-read).
    fn touch_row(&mut self, bank: usize, row: usize) {
        let idx = bank * self.map.bank.rows + row;
        let dt = self.now - self.row_time[idx];
        self.row_time[idx] = self.now;
        if !self.inject_enabled || dt <= 0.0 {
            return;
        }
        let z_thr = self.z_threshold(dt);
        if z_thr >= 4.0 {
            return; // even a +4σ cell holds this long
        }
        let q_thr = z_to_q(z_thr);
        let start = bank * self.map.bank.bytes + row * self.map.bank.row_bytes;
        let end = start + self.map.bank.row_bytes;
        // eDRAM planes only (0..7): weak cells' zeros flip to ones.
        // Word-level scan (§Perf): rows are word-aligned, and encoded DNN
        // data plus the all-ones idle state make zero bits sparse — test a
        // whole 64-cell word at once and only visit its zero positions.
        debug_assert!(start % 64 == 0 && end % 64 == 0);
        for w in start / 64..end / 64 {
            let base = w * 64;
            for (plane, zplane) in self.planes[..7].iter_mut().zip(self.leak_z.iter()) {
                let mut zeros = !plane[w];
                while zeros != 0 {
                    let b = zeros.trailing_zeros() as usize;
                    zeros &= zeros - 1;
                    if zplane[base + b] > q_thr {
                        plane[w] |= 1u64 << b;
                        self.edram_ones += 1;
                        self.meter.flips_committed += 1;
                    }
                }
            }
        }
    }

    fn touch_range(&mut self, addr: usize, len: usize) {
        if len == 0 {
            return;
        }
        let first = addr / self.map.bank.row_bytes;
        let last = (addr + len - 1) / self.map.bank.row_bytes;
        for flat_row in first..=last {
            let bank = flat_row / self.map.bank.rows;
            let row = flat_row % self.map.bank.rows;
            self.touch_row(bank, row);
        }
    }

    /// Write `data` at `addr`, time `now`. Data is encoded (if enabled)
    /// before hitting the array, as in Fig. 4.
    pub fn write(&mut self, addr: usize, data: &[u8], now: f64) {
        assert!(addr + data.len() <= self.capacity(), "write out of range");
        self.advance_to(now);
        self.touch_range(addr, data.len());
        let mut ones = 0u64;
        for (i, &raw) in data.iter().enumerate() {
            let stored = if self.encode_enabled {
                crate::encode::one_enhancement::encode_byte(raw)
            } else {
                raw
            };
            ones += (stored & 0x7f).count_ones() as u64;
            self.set_byte_raw(addr + i, stored);
        }
        let frac = ones as f64 / (data.len() * 7) as f64;
        self.meter.write_j += self.card.write_energy(data.len(), frac);
        self.meter.writes += 1;
        self.meter.bytes_written += data.len() as u64;
    }

    /// Read `len` bytes at `addr`, time `now` — decoded, with any retention
    /// flips the elapsed time produced (and committed back to the array).
    pub fn read(&mut self, addr: usize, len: usize, now: f64) -> Vec<u8> {
        assert!(addr + len <= self.capacity(), "read out of range");
        self.advance_to(now);
        self.touch_range(addr, len);
        let mut out = Vec::with_capacity(len);
        let mut ones = 0u64;
        for i in 0..len {
            let stored = self.get_byte_raw(addr + i);
            ones += (stored & 0x7f).count_ones() as u64;
            out.push(if self.encode_enabled {
                crate::encode::one_enhancement::decode_byte(stored)
            } else {
                stored
            });
        }
        let frac = ones as f64 / (len * 7).max(1) as f64;
        self.meter.read_j += self.card.read_energy(len, frac);
        self.meter.reads += 1;
        self.meter.bytes_read += len as u64;
        out
    }

    /// Apply one refresh slot (from [`super::refresh::RefreshController`]):
    /// activates the row in every bank in parallel.
    pub fn refresh_row(&mut self, row: usize, now: f64) {
        self.advance_to(now);
        for bank in 0..self.map.banks {
            self.touch_row(bank, row);
        }
        let bytes = self.map.bank.row_bytes * self.map.banks;
        self.meter.refresh_j +=
            self.card.refresh_pass_energy(bytes, self.edram_ones_frac());
        self.meter.refreshes += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh(bytes: usize) -> MixedCellMemory {
        MixedCellMemory::new(bytes, 0xBEEF)
    }

    #[test]
    fn roundtrip_without_aging_is_exact() {
        let mut m = fresh(4096);
        let data: Vec<u8> = (0..=255u8).collect();
        m.write(100, &data, 1e-9);
        let back = m.read(100, data.len(), 2e-9);
        assert_eq!(back, data);
    }

    #[test]
    fn fresh_data_within_refresh_period_is_safe() {
        let mut m = fresh(4096);
        let data: Vec<u8> = (0..64).map(|i| i as u8).collect();
        m.write(0, &data, 0.0);
        // read just inside the 12.57 µs window: ≤1 % flip per bit-0; with
        // 64 bytes the expected corruption is < 1 byte, usually zero for
        // encoded near-zero data (few stored zeros)
        let back = m.read(0, 64, 12.0e-6);
        let diff = back.iter().zip(&data).filter(|(a, b)| a != b).count();
        assert!(diff <= 4, "diff={diff}");
    }

    #[test]
    fn stale_data_corrupts_and_errors_are_cumulative() {
        let mut m = fresh(4096);
        // store raw zeros with the encoder OFF: stored bytes are 0x00 —
        // all 7 eDRAM bits are 0 and will flip eventually
        m.encode_enabled = false;
        m.write(0, &[0u8; 64], 0.0);
        let back = m.read(0, 64, 200e-6); // ~16 refresh periods stale
        let corrupted = back.iter().filter(|&&b| b != 0).count();
        assert!(corrupted > 56, "corrupted={corrupted}/64");
        // sign plane (bit 7) never flips
        assert!(back.iter().all(|&b| b & 0x80 == 0));
        // errors persist after commit: an immediate re-read returns the
        // same corrupted values
        let again = m.read(0, 64, 200.1e-6);
        assert_eq!(back, again);
    }

    #[test]
    fn encoder_protects_near_zero_data() {
        // the paper's core claim: near-zero DNN data encoded to 1-dominant
        // form survives staleness that destroys unencoded data
        let data: Vec<u8> = (0..64u8).map(|i| (i % 5)).collect(); // small positives
        let stale = 40e-6;

        let mut enc = fresh(4096);
        enc.write(0, &data, 0.0);
        let enc_back = enc.read(0, 64, stale);
        let enc_errs = enc_back.iter().zip(&data).filter(|(a, b)| a != b).count();

        let mut raw = fresh(4096);
        raw.encode_enabled = false;
        raw.write(0, &data, 0.0);
        let raw_back = raw.read(0, 64, stale);
        let raw_errs = raw_back.iter().zip(&data).filter(|(a, b)| a != b).count();

        assert!(enc_errs < raw_errs, "encoded {enc_errs} vs raw {raw_errs}");
    }

    #[test]
    fn refresh_prevents_corruption() {
        let mut m = fresh(4096);
        m.encode_enabled = false; // store worst-case zeros
        m.write(0, &[0u8; 64], 0.0);
        // refresh row 0 every 6 µs for 120 µs (well inside retention)
        let mut t = 0.0;
        for _ in 0..20 {
            t += 6e-6;
            m.refresh_row(0, t);
        }
        let back = m.read(0, 64, t + 1e-6);
        let corrupted = back.iter().filter(|&&b| b != 0).count();
        // each 6 µs window has ~0 flip probability at V_REF 0.8
        assert!(corrupted <= 1, "corrupted={corrupted}");
        assert_eq!(m.meter.refreshes, 20);
    }

    #[test]
    fn bit1_data_is_immortal() {
        let mut m = fresh(4096);
        m.encode_enabled = false;
        m.write(0, &[0x7f; 64], 0.0); // all eDRAM bits = 1
        let back = m.read(0, 64, 1.0); // one full second unrefreshed
        assert!(back.iter().all(|&b| b == 0x7f));
    }

    #[test]
    fn meters_accumulate() {
        let mut m = fresh(4096);
        m.write(0, &[1, 2, 3, 4], 1e-6);
        let _ = m.read(0, 4, 2e-6);
        m.refresh_row(0, 3e-6);
        assert_eq!(m.meter.writes, 1);
        assert_eq!(m.meter.reads, 1);
        assert_eq!(m.meter.refreshes, 1);
        assert!(m.meter.write_j > 0.0);
        assert!(m.meter.read_j > 0.0);
        assert!(m.meter.refresh_j > 0.0);
        assert!(m.meter.static_j > 0.0);
        assert_eq!(m.meter.bytes_written, 4);
    }

    #[test]
    fn ones_census_tracks_writes() {
        let mut m = fresh(4096);
        m.encode_enabled = false;
        assert_eq!(m.edram_ones_frac(), 1.0); // power-on: everything at 1
        m.write(0, &[0x00; 64], 1e-9); // clear 7×64 eDRAM bits
        let expect = 1.0 - (7 * 64) as f64 / (m.capacity() * 7) as f64;
        assert!((m.edram_ones_frac() - expect).abs() < 1e-12);
        m.write(0, &[0x7f; 64], 2e-9);
        assert_eq!(m.edram_ones_frac(), 1.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn write_bounds_checked() {
        let mut m = fresh(4096);
        let cap = m.capacity();
        m.write(cap - 2, &[0; 4], 0.0);
    }

    #[test]
    fn static_energy_integrates_with_time() {
        let mut m = fresh(16 * 1024);
        m.advance_to(1e-3); // 1 ms idle at the all-ones power-on state
        let e = m.meter.static_j;
        // 16 KB at the all-ones corner: 3.15 mW/MB × (16/1024) MB × 1 ms
        let expect = 3.15e-3 * (16.0 / 1024.0) * 1e-3;
        assert!((e - expect).abs() / expect < 0.01, "e={e} expect={expect}");
    }
}
