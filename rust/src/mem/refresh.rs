//! The global periodic refresh controller (§III-C).
//!
//! Standard periodic ("global") refresh after [3]: every row must be
//! refreshed within the retention window `t_ref`; the controller walks rows
//! round-robin at interval `t_ref / rows`. Because the CVSA restores the
//! storage node on read (§III-B3), a refresh is a single read operation —
//! the controller just schedules row reads and counts energy.

/// A scheduled refresh action.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RefreshOp {
    pub row: usize,
    /// Sequence number (how many refresh slots have fired since start).
    pub seq: u64,
    /// Absolute time this slot was due (s).
    pub due: f64,
}

/// Round-robin global refresh scheduler over `rows` rows.
#[derive(Clone, Debug)]
pub struct RefreshController {
    pub rows: usize,
    /// Whole-array refresh period (s).
    pub t_ref: f64,
    /// Next row to refresh.
    next_row: usize,
    /// Absolute time the next slot fires (s).
    next_due: f64,
    /// Total refresh operations issued.
    pub issued: u64,
    /// Paused (e.g. the RANA-style optimization when data lifetime is
    /// shorter than retention — kept as an explicit switch).
    pub enabled: bool,
}

impl RefreshController {
    pub fn new(rows: usize, t_ref: f64) -> Self {
        assert!(rows > 0 && t_ref > 0.0);
        RefreshController {
            rows,
            t_ref,
            next_row: 0,
            next_due: t_ref / rows as f64,
            issued: 0,
            enabled: true,
        }
    }

    /// Per-row slot interval.
    pub fn slot(&self) -> f64 {
        self.t_ref / self.rows as f64
    }

    /// Absolute time (s) the next refresh slot fires — what a
    /// refresh-aware dispatcher reads to plan batch windows into the
    /// slack between slots. Slots still tick while the controller is
    /// disabled (they are skipped, not deferred), so this is meaningful
    /// either way.
    pub fn next_due(&self) -> f64 {
        self.next_due
    }

    /// Advance simulated time to `now`, returning every refresh op that
    /// fires in the interval. The caller applies them to the array.
    ///
    /// Catch-up is bounded: a jump spanning more than two full periods
    /// emits (about) `2 * rows` ops and skips the older backlog. Two
    /// periods is enough to walk every row twice; older missed slots add
    /// no information — the rows already aged past `t_ref`, and a
    /// pathological clock jump (a stalled refresh engine, a fault-campaign
    /// time warp) must cost O(rows), not O(elapsed/slot). Skipping keeps
    /// the round-robin phase and the due-time grid, so one further period
    /// still covers every row exactly once. The normal in-window path is
    /// untouched (bit-exact slot arithmetic for recorded traces).
    pub fn advance(&mut self, now: f64) -> Vec<RefreshOp> {
        let mut ops = Vec::new();
        if !self.enabled {
            // time still passes; slots are skipped
            while self.next_due <= now {
                self.next_due += self.slot();
            }
            return ops;
        }
        if self.next_due <= now {
            let cap = 2 * self.rows as u64;
            let pending = ((now - self.next_due) / self.slot()).floor() as u64 + 1;
            if pending > cap {
                let skipped = pending - cap;
                self.next_due += skipped as f64 * self.slot();
                self.next_row =
                    (self.next_row + (skipped % self.rows as u64) as usize) % self.rows;
            }
        }
        while self.next_due <= now {
            ops.push(RefreshOp { row: self.next_row, seq: self.issued, due: self.next_due });
            self.issued += 1;
            self.next_row = (self.next_row + 1) % self.rows;
            self.next_due += self.slot();
        }
        ops
    }

    /// Number of refresh ops expected in a window `dt` (closed form — used
    /// by the energy model without simulating each slot).
    pub fn ops_in(&self, dt: f64) -> f64 {
        if self.enabled {
            dt / self.slot()
        } else {
            0.0
        }
    }

    /// Retention guarantee: with the controller running, no row waits longer
    /// than `t_ref` between refreshes.
    pub fn worst_case_staleness(&self) -> f64 {
        self.t_ref
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walks_all_rows_within_period() {
        let mut rc = RefreshController::new(256, 12.57e-6);
        let ops = rc.advance(12.57e-6);
        assert_eq!(ops.len(), 256);
        let mut rows: Vec<usize> = ops.iter().map(|o| o.row).collect();
        rows.sort_unstable();
        assert_eq!(rows, (0..256).collect::<Vec<_>>());
    }

    #[test]
    fn incremental_advance_matches_closed_form() {
        let mut rc = RefreshController::new(64, 1e-6);
        let mut total = 0;
        for i in 1..=100 {
            total += rc.advance(i as f64 * 0.37e-6).len();
        }
        let expect = rc.ops_in(100.0 * 0.37e-6);
        assert!((total as f64 - expect).abs() <= 1.0, "total={total} expect={expect}");
    }

    #[test]
    fn disabled_controller_skips_but_keeps_time() {
        let mut rc = RefreshController::new(16, 1e-6);
        rc.enabled = false;
        assert!(rc.advance(10e-6).is_empty());
        rc.enabled = true;
        // re-enabling does not replay missed slots
        let ops = rc.advance(10e-6 + rc.slot() * 2.5);
        assert_eq!(ops.len(), 2);
    }

    #[test]
    fn round_robin_wraps() {
        let mut rc = RefreshController::new(4, 4e-6);
        let ops = rc.advance(8e-6); // two full periods
        assert_eq!(ops.len(), 8);
        assert_eq!(
            ops.iter().map(|o| o.row).collect::<Vec<_>>(),
            vec![0, 1, 2, 3, 0, 1, 2, 3]
        );
        // due times are monotone and slot-spaced
        for w in ops.windows(2) {
            assert!((w[1].due - w[0].due - rc.slot()).abs() < 1e-12);
        }
    }

    #[test]
    fn pathological_clock_jump_is_bounded_and_keeps_period_coverage() {
        let rows = 64;
        let mut rc = RefreshController::new(rows, 1e-6);
        // a million-period jump: the old code emitted 64M ops here
        let jump = 1.0; // seconds, vs a 1 µs period
        let ops = rc.advance(jump);
        assert!(
            (2 * rows - 1..=2 * rows + 1).contains(&ops.len()),
            "catch-up must emit ~two periods worth, got {}",
            ops.len()
        );
        // the property that matters after a skip: one further full period
        // covers every row exactly once (round-robin phase survived)
        let mut all = ops;
        all.extend(rc.advance(jump + 1e-6));
        let mut last: Vec<usize> = all[all.len() - rows..].iter().map(|o| o.row).collect();
        last.sort_unstable();
        last.dedup();
        assert_eq!(last.len(), rows, "a full period must cover every row once");
        // seq stays contiguous across the skip (skipped slots are dropped,
        // not issued) and due times stay on the slot grid
        for w in all.windows(2) {
            assert_eq!(w[1].seq, w[0].seq + 1);
            assert!(w[1].due > w[0].due);
        }
        for o in &all {
            let k = (o.due / rc.slot()).round();
            assert!((o.due - k * rc.slot()).abs() < rc.slot() * 1e-3, "off-grid due {}", o.due);
        }
        // in-window behaviour is untouched: a fresh controller advanced by
        // exactly one period still fires every slot
        let mut fresh = RefreshController::new(rows, 1e-6);
        assert_eq!(fresh.advance(1e-6).len(), rows);
    }

    #[test]
    fn seq_is_monotone() {
        let mut rc = RefreshController::new(8, 1e-6);
        let ops = rc.advance(3e-6);
        for w in ops.windows(2) {
            assert_eq!(w[1].seq, w[0].seq + 1);
        }
    }
}
