//! PJRT runtime: load the AOT artifacts and execute them from Rust.
//!
//! Python never runs on the request path. `make artifacts` lowers the L2
//! jax graphs (which call the L1 Pallas kernels) to HLO **text**; this
//! module parses the manifest, loads tensors, compiles each HLO module on
//! the PJRT CPU client (`xla` crate 0.1.6 / xla_extension 0.5.1) and
//! exposes typed `execute` helpers.
//!
//! Interchange is HLO text, not serialized protos — jax ≥ 0.5 emits
//! 64-bit instruction ids that this XLA build rejects; the text parser
//! reassigns them (see /opt/xla-example/README.md).

pub mod artifact;
pub mod executor;

pub use artifact::{Artifacts, TensorData, TensorMeta};
pub use executor::{Executor, ModelRunner};
