//! PJRT runtime: load the AOT artifacts and execute them from Rust.
//!
//! Python never runs on the request path. `make artifacts` lowers the L2
//! jax graphs (which call the L1 Pallas kernels) to HLO **text**; this
//! module parses the manifest, loads tensors, compiles each HLO module on
//! the PJRT CPU client (`xla` crate 0.1.6 / xla_extension 0.5.1) and
//! exposes typed `execute` helpers.
//!
//! Interchange is HLO text, not serialized protos — jax ≥ 0.5 emits
//! 64-bit instruction ids that this XLA build rejects; the text parser
//! reassigns them (see /opt/xla-example/README.md).

pub mod artifact;

/// Real PJRT executor — requires the `pjrt` feature *and* the offline
/// `xla` crate wired in (build.rs emits the `mcaimem_xla` cfg when
/// `MCAIMEM_XLA_DIR` is set and the crate has been added as a path
/// dependency). In every other build — including `--features pjrt` on a
/// machine without the crate, which the CI matrix exercises — an
/// API-identical stub is compiled whose constructors return a clean error,
/// so artifact-dependent tests, the serving tier and the
/// `serve`/`selftest` commands skip gracefully.
#[cfg(all(feature = "pjrt", mcaimem_xla))]
pub mod executor;
#[cfg(not(all(feature = "pjrt", mcaimem_xla)))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use artifact::{Artifacts, TensorData, TensorMeta};
pub use executor::{Executor, ModelRunner};

use crate::mem::backend::BackendSpec;

/// Map a buffer backend to the AOT model artifact that serves it, plus
/// whether that artifact takes flip-candidate masks. Defined here — not in
/// the executor — so the real (`pjrt`) and stub builds share one mapping
/// and cannot drift.
///
/// * `sram` / `rram` hold data faithfully → the clean graph (no masks).
/// * `mcaimem@V` → the one-enhancement-encoded aged graph.
/// * `mcaimem@V-noenc` and `edram2t` → the raw-storage aged graph (the
///   conventional 2T stores unencoded bytes; its sign bit riding the
///   no-flip plane of the export is a modeling limit noted in
///   EXPERIMENTS.md §Backends).
pub fn serving_model(spec: &BackendSpec) -> (&'static str, bool) {
    match spec {
        BackendSpec::Sram | BackendSpec::Rram => ("model_clean", false),
        BackendSpec::Mcaimem { encode: true, .. } => ("model_enc", true),
        BackendSpec::Mcaimem { encode: false, .. } | BackendSpec::Edram2t => ("model_noenc", true),
    }
}

/// Draw one flip-candidate mask tensor: each of the 7 eDRAM bit positions
/// set independently with probability `p` (the physics side of §IV-A; the
/// bitwise application happens inside the L1 kernel). Pure Rust — shared by
/// the real and stub executors so the two builds cannot drift.
pub fn draw_mask(rng: &mut crate::util::rng::Pcg64, len: usize, p: f64) -> Vec<i8> {
    (0..len)
        .map(|_| {
            let mut m = 0u8;
            for bit in 0..7 {
                if rng.bernoulli(p) {
                    m |= 1 << bit;
                }
            }
            m as i8
        })
        .collect()
}
