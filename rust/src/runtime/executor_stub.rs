//! Stub executor used whenever real PJRT execution is not available:
//! built without the `pjrt` feature, or with it but without the offline
//! `xla` crate wired in (`mcaimem_xla` cfg — see `rust/build.rs`; the
//! crate is not vendored into this tree).
//!
//! The public surface mirrors `executor.rs` exactly — [`Executor`],
//! [`ModelRunner`] with its `artifacts` field and methods (taking the same
//! [`BackendSpec`] the real build serves) — so every caller compiles
//! unchanged. Constructors return a clean error,
//! which is the signal the integration tests, the inference server and the
//! `selftest` / `serve` commands already interpret as "skip: PJRT not
//! available". Pure-Rust helpers that don't need PJRT (mask drawing) are
//! implemented for real, so the server/test plumbing around them works.

use anyhow::{bail, Result};

use super::artifact::Artifacts;
use crate::mem::backend::BackendSpec;
use crate::util::rng::Pcg64;

const UNAVAILABLE: &str = "PJRT execution is unavailable in this build \
     (enable `--features pjrt` AND wire the offline `xla` crate via \
     MCAIMEM_XLA_DIR + a path dependency to run AOT artifacts)";

/// Stub of the PJRT CPU client wrapper.
pub struct Executor;

impl Executor {
    pub fn cpu() -> Result<Self> {
        bail!("{UNAVAILABLE}")
    }
}

/// Stub model runner: construction always fails, so artifact-dependent
/// tests and commands skip gracefully.
pub struct ModelRunner {
    pub artifacts: Artifacts,
}

impl ModelRunner {
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        // Loading the manifest first keeps the "artifacts not built" error
        // distinguishable from the "no PJRT" one.
        let _ = Artifacts::load(&dir)?;
        bail!("{UNAVAILABLE}")
    }

    /// Draw one flip-candidate mask tensor (no PJRT needed — delegates to
    /// the implementation shared with the real executor).
    pub fn draw_mask(rng: &mut Pcg64, len: usize, p: f64) -> Vec<i8> {
        super::draw_mask(rng, len, p)
    }

    pub fn infer(
        &mut self,
        _x: &[i8],
        _spec: &BackendSpec,
        _p: f64,
        _rng: &mut Pcg64,
    ) -> Result<Vec<usize>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn accuracy(
        &mut self,
        _spec: &BackendSpec,
        _p: f64,
        _batches: usize,
        _seed: u64,
    ) -> Result<f64> {
        bail!("{UNAVAILABLE}")
    }

    pub fn encoder_roundtrip(&mut self, _x: &[i8], _mask: &[i8]) -> Result<Vec<i8>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn encode_only(&mut self, _x: &[i8]) -> Result<Vec<i8>> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draw_mask_rate() {
        let mut rng = Pcg64::new(1);
        let mask = ModelRunner::draw_mask(&mut rng, 20_000, 0.1);
        let ones: u32 = mask.iter().map(|&m| (m as u8).count_ones()).sum();
        let rate = ones as f64 / (20_000.0 * 7.0);
        assert!((rate - 0.1).abs() < 0.01, "rate={rate}");
        // bit 7 never set (sign plane is SRAM)
        assert!(mask.iter().all(|&m| m >= 0));
    }

    #[test]
    fn serving_model_mapping_covers_every_spec() {
        use crate::runtime::serving_model;
        assert_eq!(serving_model(&BackendSpec::Sram), ("model_clean", false));
        assert_eq!(serving_model(&BackendSpec::Rram), ("model_clean", false));
        assert_eq!(serving_model(&BackendSpec::mcaimem_default()), ("model_enc", true));
        assert_eq!(
            serving_model(&BackendSpec::Mcaimem { vref: 0.7, encode: false, ecc: false }),
            ("model_noenc", true)
        );
        assert_eq!(serving_model(&BackendSpec::Edram2t), ("model_noenc", true));
    }

    #[test]
    fn constructors_fail_cleanly() {
        assert!(Executor::cpu().is_err());
        let err = ModelRunner::new("/nonexistent-artifacts-dir").unwrap_err().to_string();
        // missing artifacts dominates the message so callers can tell the
        // difference from a pjrt-less build with artifacts present
        assert!(err.contains("manifest"), "err={err}");
    }
}
