//! PJRT execution: compile HLO-text modules once, run them many times.
//!
//! [`Executor`] owns the PJRT CPU client; [`ModelRunner`] binds the AOT
//! artifacts to compiled executables and exposes the experiment-facing
//! entry points (clean inference, MCAIMem-aged inference with per-call
//! error masks, encoder round-trip).

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use super::artifact::Artifacts;
use crate::util::rng::Pcg64;

/// Thin wrapper over the PJRT CPU client.
pub struct Executor {
    pub client: PjRtClient,
}

impl Executor {
    pub fn cpu() -> Result<Self> {
        Ok(Executor { client: PjRtClient::cpu()? })
    }

    /// Compile one HLO-text file.
    pub fn load_hlo(&self, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}

/// Build an int8 literal from raw bytes.
pub fn literal_i8(dims: &[usize], data: &[i8]) -> Result<Literal> {
    let bytes: &[u8] = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S8, dims, bytes)?)
}

/// Build an int32 literal from values.
pub fn literal_i32(dims: &[usize], data: &[i32]) -> Result<Literal> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, &bytes)?)
}

/// Run a compiled module, unwrapping the 1-tuple the AOT path always emits.
pub fn run1(exe: &PjRtLoadedExecutable, inputs: &[Literal]) -> Result<Literal> {
    let result = exe.execute::<Literal>(inputs)?[0][0].to_literal_sync()?;
    Ok(result.to_tuple1()?)
}

use crate::mem::backend::BackendSpec;

/// High-level model runner bound to the artifacts directory.
pub struct ModelRunner {
    pub artifacts: Artifacts,
    exec: Executor,
    compiled: BTreeMap<String, PjRtLoadedExecutable>,
    /// Weight/bias literals in export argument order, loaded once.
    weight_literals: Vec<Literal>,
}

impl ModelRunner {
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let artifacts = Artifacts::load(dir)?;
        let exec = Executor::cpu()?;
        let mut weight_literals = Vec::new();
        for name in artifacts.weight_arg_names() {
            let t = artifacts.tensor(&name)?;
            let lit = match t.meta.dtype.as_str() {
                "int8" => literal_i8(&t.meta.shape, &t.as_i8()?)?,
                "int32" => literal_i32(&t.meta.shape, &t.as_i32()?)?,
                other => anyhow::bail!("unexpected weight dtype {other}"),
            };
            weight_literals.push(lit);
        }
        Ok(ModelRunner { artifacts, exec, compiled: BTreeMap::new(), weight_literals })
    }

    /// Compile (once) and fetch a model by manifest name.
    pub fn model(&mut self, name: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let path = self.artifacts.model_path(name)?;
            let exe = self.exec.load_hlo(&path)?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(self.compiled.get(name).unwrap())
    }

    /// Draw one flip-candidate mask tensor (delegates to the implementation
    /// shared with the no-pjrt stub, so the two builds cannot drift).
    pub fn draw_mask(rng: &mut Pcg64, len: usize, p: f64) -> Vec<i8> {
        super::draw_mask(rng, len, p)
    }

    /// Classify one batch (must match the export batch size) as served
    /// from the buffer technology `spec`. Returns the argmax class per row.
    pub fn infer(
        &mut self,
        x: &[i8],
        spec: &BackendSpec,
        p: f64,
        rng: &mut Pcg64,
    ) -> Result<Vec<usize>> {
        let batch = self.artifacts.batch;
        let dim = self.artifacts.input_dim;
        anyhow::ensure!(x.len() == batch * dim, "batch shape mismatch");
        let x_lit = literal_i8(&[batch, dim], x)?;

        let mut inputs = vec![x_lit];
        let (model_name, aged) = super::serving_model(spec);
        if aged {
            for shape in self.artifacts.mask_shapes.clone() {
                let len: usize = shape.iter().product();
                let mask = Self::draw_mask(rng, len, p);
                inputs.push(literal_i8(&shape, &mask)?);
            }
        }
        inputs.extend(self.weight_literals.iter().cloned());

        let exe = self.model(model_name)?;
        let logits = run1(exe, &inputs)?;
        let vals: Vec<i8> = logits.to_vec()?;
        let classes = self.artifacts.num_classes;
        Ok(vals
            .chunks(classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by_key(|(_, &v)| v)
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect())
    }

    /// Accuracy over the exported test set (first `batches` batches)
    /// served from the buffer technology `spec`.
    pub fn accuracy(
        &mut self,
        spec: &BackendSpec,
        p: f64,
        batches: usize,
        seed: u64,
    ) -> Result<f64> {
        let x = self.artifacts.tensor("x_test_i8")?.as_i8()?;
        let y = self.artifacts.tensor("y_test_i32")?.as_i32()?;
        let batch = self.artifacts.batch;
        let dim = self.artifacts.input_dim;
        let avail = y.len() / batch;
        let n = batches.min(avail);
        let mut rng = Pcg64::new(seed);
        let mut correct = 0usize;
        for b in 0..n {
            let xs = &x[b * batch * dim..(b + 1) * batch * dim];
            let pred = self.infer(xs, spec, p, &mut rng)?;
            for (i, &cls) in pred.iter().enumerate() {
                if cls as i32 == y[b * batch + i] {
                    correct += 1;
                }
            }
        }
        Ok(correct as f64 / (n * batch) as f64)
    }

    /// Run the standalone encoder round-trip artifact: store → age → load
    /// for an arbitrary int8 vector + mask (used to cross-check the Rust
    /// and Pallas implementations bit-for-bit).
    pub fn encoder_roundtrip(&mut self, x: &[i8], mask: &[i8]) -> Result<Vec<i8>> {
        anyhow::ensure!(x.len() == mask.len());
        let n = x.len();
        let exe = self.model("encoder_roundtrip")?;
        let out = run1(exe, &[literal_i8(&[n], x)?, literal_i8(&[n], mask)?])?;
        Ok(out.to_vec()?)
    }

    /// Run the standalone encode-only artifact.
    pub fn encode_only(&mut self, x: &[i8]) -> Result<Vec<i8>> {
        let n = x.len();
        let exe = self.model("encode_only")?;
        let out = run1(exe, &[literal_i8(&[n], x)?])?;
        Ok(out.to_vec()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draw_mask_rate() {
        let mut rng = Pcg64::new(1);
        let mask = ModelRunner::draw_mask(&mut rng, 20_000, 0.1);
        let ones: u32 = mask.iter().map(|&m| (m as u8).count_ones()).sum();
        let rate = ones as f64 / (20_000.0 * 7.0);
        assert!((rate - 0.1).abs() < 0.01, "rate={rate}");
        // bit 7 never set (sign plane is SRAM)
        assert!(mask.iter().all(|&m| m >= 0));
    }

    #[test]
    fn literal_roundtrip_i8() {
        let data: Vec<i8> = (-64..64).collect();
        let lit = literal_i8(&[128], &data).unwrap();
        let back: Vec<i8> = lit.to_vec().unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let data = vec![1i32, -2, 3_000_000, i32::MIN];
        let lit = literal_i32(&[4], &data).unwrap();
        let back: Vec<i32> = lit.to_vec().unwrap();
        assert_eq!(back, data);
    }
}
