//! Artifact loading: manifest.json + raw tensor files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Metadata for one serialized tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
    pub file: String,
}

impl TensorMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A loaded tensor (raw bytes + metadata).
#[derive(Clone, Debug)]
pub struct TensorData {
    pub meta: TensorMeta,
    pub bytes: Vec<u8>,
}

impl TensorData {
    pub fn as_i8(&self) -> Result<Vec<i8>> {
        if self.meta.dtype != "int8" {
            bail!("{} is {}, not int8", self.meta.name, self.meta.dtype);
        }
        Ok(self.bytes.iter().map(|&b| b as i8).collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.meta.dtype != "int32" {
            bail!("{} is {}, not int32", self.meta.name, self.meta.dtype);
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// One exported HLO model entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<String>,
}

/// The parsed artifacts directory.
#[derive(Clone, Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub batch: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub layer_sizes: Vec<(usize, usize)>,
    pub mask_shapes: Vec<Vec<usize>>,
    pub requant_scales: Vec<f64>,
    pub act_scales: Vec<f64>,
    pub float_acc: f64,
    pub int8_clean_acc: f64,
    pub tensors: BTreeMap<String, TensorMeta>,
    pub models: BTreeMap<String, ModelMeta>,
}

impl Artifacts {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}; run `make artifacts`"))?;
        let j = Json::parse(&text)?;
        let usize_of = |k: &str| -> Result<usize> {
            j.get(k)?.as_usize().ok_or_else(|| anyhow!("{k} not a number"))
        };
        let farr = |k: &str| -> Result<Vec<f64>> {
            Ok(j.get(k)?
                .as_arr()
                .ok_or_else(|| anyhow!("{k} not an array"))?
                .iter()
                .filter_map(|v| v.as_f64())
                .collect())
        };

        let mut tensors = BTreeMap::new();
        for t in j.get("tensors")?.as_arr().unwrap_or(&[]) {
            let meta = TensorMeta {
                name: t.get("name")?.as_str().unwrap_or_default().to_string(),
                dtype: t.get("dtype")?.as_str().unwrap_or_default().to_string(),
                shape: t
                    .get("shape")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_usize())
                    .collect(),
                file: t.get("file")?.as_str().unwrap_or_default().to_string(),
            };
            tensors.insert(meta.name.clone(), meta);
        }

        let mut models = BTreeMap::new();
        if let Some(obj) = j.get("models")?.as_obj() {
            for (name, m) in obj {
                models.insert(
                    name.clone(),
                    ModelMeta {
                        name: name.clone(),
                        file: m.get("file")?.as_str().unwrap_or_default().to_string(),
                        inputs: m
                            .get("inputs")?
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|v| v.as_str().map(String::from))
                            .collect(),
                    },
                );
            }
        }

        let layer_sizes = j
            .get("layer_sizes")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|p| {
                let a = p.as_arr()?;
                Some((a[0].as_usize()?, a[1].as_usize()?))
            })
            .collect();
        let mask_shapes = j
            .get("mask_shapes")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|s| s.as_arr().unwrap_or(&[]).iter().filter_map(|v| v.as_usize()).collect())
            .collect();

        Ok(Artifacts {
            batch: usize_of("batch")?,
            input_dim: usize_of("input_dim")?,
            num_classes: usize_of("num_classes")?,
            layer_sizes,
            mask_shapes,
            requant_scales: farr("requant_scales")?,
            act_scales: farr("act_scales")?,
            float_acc: j.get("float_acc")?.as_f64().unwrap_or(0.0),
            int8_clean_acc: j.get("int8_clean_acc")?.as_f64().unwrap_or(0.0),
            tensors,
            models,
            dir,
        })
    }

    /// Load one tensor's raw bytes, validating the declared size.
    pub fn tensor(&self, name: &str) -> Result<TensorData> {
        let meta = self
            .tensors
            .get(name)
            .ok_or_else(|| anyhow!("no tensor `{name}` in manifest"))?
            .clone();
        let bytes = std::fs::read(self.dir.join(&meta.file))?;
        let unit = match meta.dtype.as_str() {
            "int8" => 1,
            "int32" | "float32" => 4,
            other => bail!("unsupported dtype {other}"),
        };
        if bytes.len() != meta.elements() * unit {
            bail!(
                "tensor {name}: file has {} bytes, manifest implies {}",
                bytes.len(),
                meta.elements() * unit
            );
        }
        Ok(TensorData { meta, bytes })
    }

    /// Path to one model's HLO text.
    pub fn model_path(&self, name: &str) -> Result<PathBuf> {
        let m = self
            .models
            .get(name)
            .ok_or_else(|| anyhow!("no model `{name}` in manifest"))?;
        Ok(self.dir.join(&m.file))
    }

    /// The weight/bias tensors in the L2 export's argument order
    /// (w0, b0, w1, b1, ...).
    pub fn weight_arg_names(&self) -> Vec<String> {
        (0..self.layer_sizes.len())
            .flat_map(|i| [format!("w{i}"), format!("b{i}")])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn skip_if_unbuilt() -> Option<Artifacts> {
        Artifacts::load(art_dir()).ok()
    }

    #[test]
    fn manifest_loads_and_is_consistent() {
        let Some(a) = skip_if_unbuilt() else { return };
        assert_eq!(a.input_dim, 64);
        assert_eq!(a.num_classes, 10);
        assert_eq!(a.layer_sizes.len(), 3);
        assert_eq!(a.mask_shapes.len(), 6);
        assert_eq!(a.requant_scales.len(), 3);
        assert!(a.int8_clean_acc > 0.9);
        for m in ["model_clean", "model_enc", "model_noenc", "encoder_roundtrip"] {
            assert!(a.models.contains_key(m), "missing model {m}");
            assert!(a.model_path(m).unwrap().exists());
        }
    }

    #[test]
    fn tensors_load_with_declared_shapes() {
        let Some(a) = skip_if_unbuilt() else { return };
        for name in a.weight_arg_names() {
            let t = a.tensor(&name).unwrap();
            assert_eq!(t.bytes.len() > 0, true, "{name}");
        }
        let x = a.tensor("x_test_i8").unwrap();
        assert_eq!(x.meta.shape[1], a.input_dim);
        let y = a.tensor("y_test_i32").unwrap();
        assert_eq!(y.as_i32().unwrap().len(), x.meta.shape[0]);
    }

    #[test]
    fn missing_tensor_is_a_clean_error() {
        let Some(a) = skip_if_unbuilt() else { return };
        let err = a.tensor("nonexistent").unwrap_err().to_string();
        assert!(err.contains("nonexistent"));
    }
}
