//! # MCAIMem — mixed SRAM/eDRAM on-chip AI memory, reproduced as a full stack
//!
//! This crate reproduces *MCAIMem: a Mixed SRAM and eDRAM Cell for Area and
//! Energy-efficient on-chip AI Memory* (Nguyen et al., cs.AR 2023) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the memory-system coordinator plus every
//!   substrate the paper depends on: an analytical device/leakage model
//!   ([`device`]), gain-cell and SRAM circuit models with Monte-Carlo
//!   retention analysis ([`circuit`]), the mixed-cell memory with its area /
//!   energy / refresh / V_REF machinery ([`mem`]), the one-enhancement
//!   encoder ([`encode`]), a SCALE-Sim-style systolic-array simulator
//!   ([`scalesim`]), and system-level energy composition ([`energy`]).
//! * **Layer 2** — a quantized JAX model (`python/compile/model.py`) whose
//!   every tensor is routed through the MCAIMem store path, AOT-lowered to
//!   HLO text and executed from Rust via [`runtime`] (PJRT CPU).
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the
//!   one-enhancement encode/decode, asymmetric retention-error injection and
//!   INT8 matmul, verified against pure-jnp oracles.
//!
//! The [`report`] module regenerates every table and figure of the paper's
//! evaluation; [`coordinator`] hosts the MCAIMem-backed buffer manager,
//! refresh scheduler and batched inference server; [`sim`] is the
//! verification backbone — deterministic trace record/replay plus a
//! golden-model differential oracle (`mcaimem conform`); [`dse`] turns the
//! evaluators into an automated Pareto search over mixed-cell geometries
//! (`mcaimem explore`).
//!
//! See `DESIGN.md` for the substitution table (what the paper measured on
//! SPICE/silicon vs. what this repo simulates) and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod cli;
pub mod circuit;
pub mod coordinator;
pub mod device;
pub mod dse;
pub mod encode;
pub mod energy;
pub mod faults;
pub mod inject;
pub mod mem;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod scalesim;
pub mod sim;
pub mod util;

/// Crate-wide result type (anyhow is the only error crate in the offline set).
pub type Result<T> = anyhow::Result<T>;
