//! Tiny argv parser: positional arguments plus `--key value` / `--flag`
//! options, with typed accessors and unknown-option detection.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct ParsedArgs {
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl ParsedArgs {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got `{v}`")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got `{v}`")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Declarative parser: which options take values, which are flags.
pub struct ArgParser {
    value_opts: Vec<&'static str>,
    flag_opts: Vec<&'static str>,
}

impl ArgParser {
    pub fn new(value_opts: &[&'static str], flag_opts: &[&'static str]) -> Self {
        ArgParser { value_opts: value_opts.to_vec(), flag_opts: flag_opts.to_vec() }
    }

    pub fn parse<I: IntoIterator<Item = String>>(&self, args: I) -> Result<ParsedArgs> {
        let mut out = ParsedArgs::default();
        let mut it = args.into_iter();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if self.flag_opts.contains(&name) {
                    out.flags.push(name.to_string());
                } else if self.value_opts.contains(&name) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("--{name} expects a value"))?;
                    out.options.insert(name.to_string(), v);
                } else {
                    bail!("unknown option --{name}");
                }
            } else {
                out.positionals.push(a);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> ArgParser {
        ArgParser::new(&["csv", "network", "p"], &["quick", "verbose"])
    }

    fn parse(s: &str) -> Result<ParsedArgs> {
        p().parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positionals_options_flags() {
        let a = parse("report fig12 --csv results --quick").unwrap();
        assert_eq!(a.positionals, vec!["report", "fig12"]);
        assert_eq!(a.get("csv"), Some("results"));
        assert!(a.has_flag("quick"));
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse("--p 0.05").unwrap();
        assert_eq!(a.get_f64("p", 0.0).unwrap(), 0.05);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        let bad = parse("--p xyz").unwrap();
        assert!(bad.get_f64("p", 0.0).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse("--nope 1").is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse("--csv").is_err());
    }
}
