//! Tiny argv parser: positional arguments plus `--key value` / `--flag`
//! options, with typed accessors and unknown-option detection that
//! suggests the nearest known option (edit distance ≤ 2).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct ParsedArgs {
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl ParsedArgs {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got `{v}`")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got `{v}`")),
        }
    }

    /// A comma-separated numeric list (`--rates 50000,100000,200000`).
    /// Empty items are skipped, so a trailing comma is harmless.
    pub fn get_f64_list(&self, key: &str) -> Result<Option<Vec<f64>>> {
        let Some(raw) = self.get(key) else { return Ok(None) };
        let xs = raw
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<f64>()
                    .map_err(|_| anyhow!("--{key} expects comma-separated numbers, got `{s}`"))
            })
            .collect::<Result<Vec<f64>>>()?;
        if xs.is_empty() {
            bail!("--{key} expects at least one number");
        }
        Ok(Some(xs))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Declarative parser: which options take values, which are flags.
pub struct ArgParser {
    value_opts: Vec<&'static str>,
    flag_opts: Vec<&'static str>,
}

impl ArgParser {
    pub fn new(value_opts: &[&'static str], flag_opts: &[&'static str]) -> Self {
        ArgParser { value_opts: value_opts.to_vec(), flag_opts: flag_opts.to_vec() }
    }

    pub fn parse<I: IntoIterator<Item = String>>(&self, args: I) -> Result<ParsedArgs> {
        let mut out = ParsedArgs::default();
        let mut it = args.into_iter();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if self.flag_opts.contains(&name) {
                    out.flags.push(name.to_string());
                } else if self.value_opts.contains(&name) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("--{name} expects a value"))?;
                    out.options.insert(name.to_string(), v);
                } else {
                    match self.nearest_option(name) {
                        Some(sugg) => bail!("unknown option --{name} (did you mean --{sugg}?)"),
                        None => bail!("unknown option --{name}"),
                    }
                }
            } else {
                out.positionals.push(a);
            }
        }
        Ok(out)
    }

    /// The known option closest to `name` within edit distance 2, if any
    /// (ties break toward the earliest declared option).
    fn nearest_option(&self, name: &str) -> Option<&'static str> {
        let pool: Vec<&'static str> =
            self.value_opts.iter().chain(self.flag_opts.iter()).copied().collect();
        nearest_keyword(name, &pool)
    }
}

/// The keyword in `candidates` closest to `name` within edit distance 2,
/// if any (ties break toward the earliest candidate) — shared by the
/// unknown-option suggester above and the [`crate::mem::backend`] spec
/// grammar's unknown-keyword hints.
pub fn nearest_keyword(name: &str, candidates: &[&'static str]) -> Option<&'static str> {
    let mut best: Option<(usize, &'static str)> = None;
    for &cand in candidates {
        let d = edit_distance(name, cand);
        let better = match best {
            Some((bd, _)) => d < bd,
            None => true,
        };
        if d <= 2 && better {
            best = Some((d, cand));
        }
    }
    best.map(|(_, cand)| cand)
}

/// Levenshtein distance (insert/delete/substitute, unit costs) — small
/// inputs only, O(|a|·|b|) with a single rolling row.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut row: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut prev_diag = row[0];
        row[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev_diag + usize::from(ca != cb);
            prev_diag = row[j + 1];
            row[j + 1] = sub.min(row[j] + 1).min(prev_diag + 1);
        }
    }
    row[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> ArgParser {
        ArgParser::new(&["csv", "network", "p"], &["quick", "verbose"])
    }

    fn parse(s: &str) -> Result<ParsedArgs> {
        p().parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positionals_options_flags() {
        let a = parse("report fig12 --csv results --quick").unwrap();
        assert_eq!(a.positionals, vec!["report", "fig12"]);
        assert_eq!(a.get("csv"), Some("results"));
        assert!(a.has_flag("quick"));
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse("--p 0.05").unwrap();
        assert_eq!(a.get_f64("p", 0.0).unwrap(), 0.05);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        let bad = parse("--p xyz").unwrap();
        assert!(bad.get_f64("p", 0.0).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse("--nope 1").is_err());
    }

    #[test]
    fn unknown_option_suggests_nearest() {
        // one deletion away from `network`
        let err = parse("--netork LeNet").unwrap_err().to_string();
        assert!(err.contains("did you mean --network?"), "err={err}");
        // one substitution away from the flag `quick`
        let err = parse("--quack").unwrap_err().to_string();
        assert!(err.contains("did you mean --quick?"), "err={err}");
        // two edits away still suggests
        let err = parse("--csvv2 x").unwrap_err().to_string();
        assert!(err.contains("did you mean --csv?"), "err={err}");
    }

    #[test]
    fn far_off_options_get_no_suggestion() {
        let err = parse("--zzzzzzz 1").unwrap_err().to_string();
        assert!(err.contains("unknown option --zzzzzzz"), "err={err}");
        assert!(!err.contains("did you mean"), "err={err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("", ""), 0);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", "ab"), 1);
        assert_eq!(edit_distance("abc", "axc"), 1);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("", "ab"), 2);
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse("--csv").is_err());
    }

    #[test]
    fn f64_lists_parse_and_reject_garbage() {
        let a = ArgParser::new(&["rates"], &[])
            .parse(["--rates".into(), "50000, 100000,200000,".into()])
            .unwrap();
        assert_eq!(a.get_f64_list("rates").unwrap(), Some(vec![50_000.0, 100_000.0, 200_000.0]));
        assert_eq!(a.get_f64_list("absent").unwrap(), None);
        let bad = ArgParser::new(&["rates"], &[])
            .parse(["--rates".into(), "1,abc".into()])
            .unwrap();
        assert!(bad.get_f64_list("rates").is_err());
        let empty = ArgParser::new(&["rates"], &[])
            .parse(["--rates".into(), ",".into()])
            .unwrap();
        assert!(empty.get_f64_list("rates").is_err());
    }
}
