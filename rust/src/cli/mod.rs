//! Command-line interface (hand-rolled — clap is not in the offline crate
//! set). Subcommands mirror the experiment surface:
//!
//! ```text
//! mcaimem report <id|all> [--csv DIR] [--artifacts DIR] [--quick]
//! mcaimem fig11 [--artifacts DIR] [--quick]
//! mcaimem simulate --network NAME [--platform eyeriss|tpuv1] [--vref V]
//! mcaimem serve [--artifacts DIR] [--requests N] [--variant clean|mcaimem|noenc] [--p P]
//! mcaimem selftest [--artifacts DIR]
//! ```

pub mod args;

pub use args::{ArgParser, ParsedArgs};
