//! Command-line interface (hand-rolled — clap is not in the offline crate
//! set). Subcommands mirror the experiment surface, and all of them share
//! one `--backend` flag taking the [`crate::mem::backend::BackendSpec`]
//! grammar (`sram | edram2t | rram | mcaimem[@VREF[-noenc]]`, comma-list
//! where a sweep makes sense):
//!
//! ```text
//! mcaimem report <id|all> [--csv DIR] [--artifacts DIR] [--backend SPECS] [--quick]
//! mcaimem fig11 [--artifacts DIR] [--quick]
//! mcaimem simulate --network NAME [--platform eyeriss|tpuv1] [--backend SPECS] [--json FILE]
//! mcaimem explore [--space SPEC] [--strategy grid|random|halving] [--json FILE] [--quick]
//! mcaimem serve [--backend SPEC] [--shards N] [--workers K] [--target-rps R] [--sweep]
//! mcaimem conform [--backend SPECS] [--ops N] [--seed S] [--quick] [--replay FILE] [--json FILE]
//! mcaimem selftest [--artifacts DIR]
//! ```
//!
//! `explore` additionally takes the design-space grammar of
//! [`crate::dse::space`] (`ratio=1..15,vref=0.6:0.9:0.05,geom=256x64|512x64`).

pub mod args;

pub use args::{ArgParser, ParsedArgs};
