//! Load generation for the serving tier: open-loop Poisson and closed-loop
//! arrivals, per-tenant request mixes across networks.
//!
//! * **Open-loop Poisson** — arrivals fire on an absolute exponential
//!   schedule regardless of completions (the datacenter regime: traffic
//!   does not slow down because the server is slow). Offered rate is the
//!   control knob; the achieved rate and the latency distribution are the
//!   measurements. Above saturation the admission controller sheds the
//!   excess as rejects instead of letting latency collapse.
//! * **Closed-loop** — C clients each keep exactly one request in flight
//!   (submit → wait → resubmit), optionally honouring reject retry-after
//!   hints. This measures the tier's *sustained* service capacity, which is
//!   what the saturation sweep reports.
//! * **Tenant mixes** — each request draws a tenant by weight; a tenant is
//!   a named network with its own input width, so a mix models several
//!   models sharing one serving tier.
//!
//! Everything is seeded ([`Pcg64`]) — the arrival schedule and every
//! payload byte are reproducible run-to-run; only wall-clock timing varies.

use std::time::{Duration, Instant};

use super::pool::{SubmitError, WorkerPool};
use crate::scalesim::network;
use crate::util::rng::Pcg64;
use crate::util::stats::percentile_sorted;

/// Arrival process driving the pool.
#[derive(Clone, Copy, Debug)]
pub enum Arrival {
    /// Open loop: Poisson arrivals at `rps` requests/s, regardless of
    /// completions.
    OpenPoisson { rps: f64 },
    /// Closed loop: `clients` callers, one request in flight each.
    ClosedLoop { clients: usize },
}

/// One tenant of the serving tier: a named model with a request width and
/// a share of the traffic.
#[derive(Clone, Debug)]
pub struct Tenant {
    pub name: String,
    pub weight: f64,
    /// Request payload bytes (the network's input width, clamped to the
    /// staging row).
    pub dim: usize,
}

impl Tenant {
    /// A tenant serving one of the repo's networks (dim = the network's
    /// input size, clamped to a serving row).
    pub fn for_network(name: &str, weight: f64) -> Option<Tenant> {
        let net = network::by_name(name)?;
        let dim = net.layers.first().map(|l| l.input_bytes()).unwrap_or(784).clamp(16, 784);
        Some(Tenant { name: net.name.to_string(), weight, dim })
    }

    /// The default two-tenant mix (vision + language traffic).
    pub fn default_mix() -> Vec<Tenant> {
        ["ResNet50", "I-BERT"]
            .iter()
            .filter_map(|n| Tenant::for_network(n, 1.0))
            .collect()
    }
}

/// Load-generation configuration.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    pub arrival: Arrival,
    /// Tenant mix (weights need not sum to 1; empty = one synthetic
    /// 784-byte tenant).
    pub tenants: Vec<Tenant>,
    /// Total requests to offer.
    pub requests: usize,
    /// Honour reject retry-after hints (closed-loop callers back off and
    /// retry; open-loop arrivals are lost — an open-loop client cannot
    /// defer traffic).
    pub retry_rejects: bool,
    /// Per-request deadline budget for closed-loop retries: the total time
    /// one request may spend in [`retry_backoff`] pauses before the client
    /// abandons it (counted in [`LoadReport::abandoned`]). A budget of zero
    /// abandons on the first reject.
    pub retry_budget: Duration,
    pub seed: u64,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            arrival: Arrival::ClosedLoop { clients: 4 },
            tenants: Vec::new(),
            requests: 512,
            retry_rejects: true,
            retry_budget: Duration::from_secs(5),
            seed: 0x10AD,
        }
    }
}

/// A structurally invalid load configuration.
#[derive(Clone, Debug, PartialEq)]
pub enum LoadError {
    /// A tenant weight is negative or non-finite — there is no sensible
    /// traffic share it could mean. (An *all-zero* mix is legal and draws
    /// uniformly; see [`LoadConfig::validate`].)
    BadWeight { tenant: String, weight: f64 },
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::BadWeight { tenant, weight } => write!(
                f,
                "tenant '{tenant}' has weight {weight}; weights must be finite and >= 0 \
                 (a mix of all zeros draws uniformly)"
            ),
        }
    }
}

impl std::error::Error for LoadError {}

impl LoadConfig {
    /// Validate the tenant mix: every weight must be finite and
    /// non-negative. A mix whose weights sum to zero is accepted — the
    /// generator treats it as a uniform draw over the tenants rather than
    /// silently routing all traffic to the last one.
    pub fn validate(&self) -> Result<(), LoadError> {
        for t in &self.tenants {
            if !t.weight.is_finite() || t.weight < 0.0 {
                return Err(LoadError::BadWeight { tenant: t.name.clone(), weight: t.weight });
            }
        }
        Ok(())
    }

    /// Construction-time validation: `LoadConfig { .. }.validated()?`
    /// surfaces a structured [`LoadError`] before the load ever runs.
    pub fn validated(self) -> Result<Self, LoadError> {
        self.validate()?;
        Ok(self)
    }
}

/// What the load run measured.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Requests the generator actually tried to submit (excluding
    /// retries). On a pool that closes mid-run this is the attempts made
    /// before the generator stopped, **not** the configured request count
    /// — a dead pool must not report traffic it was never offered.
    pub offered: usize,
    /// Requests past admission control.
    pub accepted: usize,
    /// Rejection events (with retries one request can reject many times).
    pub rejected: u64,
    /// Requests answered with a class.
    pub completed: usize,
    /// Requests answered with an inference error.
    pub errors: usize,
    /// Closed-loop requests abandoned after their retry deadline budget
    /// ran out (0 for open-loop runs, which shed instead of retrying).
    pub abandoned: usize,
    pub wall_s: f64,
    /// Completed requests per wall second.
    pub achieved_rps: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    /// Tail-of-the-tail latency — the SLO quantile the refresh-aware
    /// dispatcher is judged on.
    pub p999_latency_us: f64,
    /// p99 of the open-loop generator's *schedule slip* (µs): how late an
    /// arrival actually fired relative to its Poisson due time. Near the
    /// pacing resolution the offered rate is honest; a large value means
    /// the generator itself could not keep the schedule, so the measured
    /// "offered rate" understates the configured one (0 for closed-loop
    /// runs, which have no schedule).
    pub sched_lag_p99_us: f64,
}

impl LoadReport {
    fn from_outcomes(
        offered: usize,
        rejected: u64,
        lat_us: &mut Vec<f64>,
        errors: usize,
        abandoned: usize,
        wall_s: f64,
        lag_us: &mut Vec<f64>,
    ) -> Self {
        lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        lag_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let completed = lat_us.len();
        LoadReport {
            offered,
            accepted: completed + errors,
            rejected,
            completed,
            errors,
            abandoned,
            wall_s,
            achieved_rps: completed as f64 / wall_s.max(1e-9),
            p50_latency_us: if completed == 0 { 0.0 } else { percentile_sorted(lat_us, 50.0) },
            p99_latency_us: if completed == 0 { 0.0 } else { percentile_sorted(lat_us, 99.0) },
            p999_latency_us: if completed == 0 { 0.0 } else { percentile_sorted(lat_us, 99.9) },
            sched_lag_p99_us: if lag_us.is_empty() { 0.0 } else { percentile_sorted(lag_us, 99.0) },
        }
    }

    /// Snapshot into the unified metrics registry
    /// (`mcaimem_loadgen_*` names) — the client-side counterpart of
    /// [`crate::coordinator::server::ServerStats::registry`].
    pub fn registry(&self) -> crate::obs::Registry {
        let mut r = crate::obs::Registry::new();
        r.count("mcaimem_loadgen_offered_total", self.offered as u64);
        r.count("mcaimem_loadgen_accepted_total", self.accepted as u64);
        r.count("mcaimem_loadgen_rejected_total", self.rejected);
        r.count("mcaimem_loadgen_completed_total", self.completed as u64);
        r.count("mcaimem_loadgen_errors_total", self.errors as u64);
        r.count("mcaimem_loadgen_abandoned_total", self.abandoned as u64);
        r.gauge("mcaimem_loadgen_wall_s", self.wall_s);
        r.gauge("mcaimem_loadgen_achieved_rps", self.achieved_rps);
        r.gauge("mcaimem_loadgen_latency_p50_us", self.p50_latency_us);
        r.gauge("mcaimem_loadgen_latency_p99_us", self.p99_latency_us);
        r.gauge("mcaimem_loadgen_latency_p999_us", self.p999_latency_us);
        r.gauge("mcaimem_loadgen_sched_lag_p99_us", self.sched_lag_p99_us);
        r
    }
}

/// The deterministic Poisson arrival schedule: `n` exponential
/// inter-arrival gaps (s) at rate `rps`. Pure function of the seed — the
/// reproducibility the serving tests lean on.
pub fn poisson_interarrivals(seed: u64, rps: f64, n: usize) -> Vec<f64> {
    assert!(rps > 0.0);
    let mut rng = Pcg64::new(seed);
    (0..n).map(|_| -rng.f64_open().ln() / rps).collect()
}

/// Draw a request payload for a weighted-random tenant.
///
/// Degenerate mixes are handled explicitly rather than silently routing
/// to the last tenant: negative/non-finite weights (which
/// [`LoadConfig::validate`] rejects at construction) are clamped to zero
/// here as defense in depth, and a mix whose weights sum to zero draws
/// uniformly.
fn draw_request(rng: &mut Pcg64, tenants: &[Tenant]) -> Vec<i8> {
    let dim = if tenants.is_empty() {
        784
    } else {
        let w = |t: &Tenant| if t.weight.is_finite() { t.weight.max(0.0) } else { 0.0 };
        let total: f64 = tenants.iter().map(w).sum();
        let pick = if total <= 0.0 {
            // zero-total mix: uniform over the tenants
            rng.below(tenants.len() as u64) as usize
        } else {
            let mut x = rng.f64() * total;
            // fall back to the last tenant that can carry traffic, so fp
            // underflow at the end of the walk never lands on a
            // zero-weight tenant
            let mut pick =
                tenants.iter().rposition(|t| w(t) > 0.0).unwrap_or(tenants.len() - 1);
            for (i, t) in tenants.iter().enumerate() {
                if x < w(t) {
                    pick = i;
                    break;
                }
                x -= w(t);
            }
            pick
        };
        tenants[pick].dim
    };
    (0..dim).map(|_| rng.next_u64() as i8).collect()
}

/// Sleep until `target` without burning a core: coarse sleep to ~200 µs
/// short, then yield-spin the remainder.
fn pace_until(target: Instant) {
    loop {
        let now = Instant::now();
        if now >= target {
            return;
        }
        let left = target - now;
        if left > Duration::from_micros(200) {
            std::thread::sleep(left - Duration::from_micros(100));
        } else {
            std::thread::yield_now();
        }
    }
}

/// Drive `pool` with the configured load; blocks until every offered
/// request resolved (completed, errored, or rejected).
pub fn run(pool: &WorkerPool, cfg: &LoadConfig) -> LoadReport {
    match cfg.arrival {
        Arrival::OpenPoisson { rps } => run_open(pool, cfg, rps),
        Arrival::ClosedLoop { clients } => run_closed(pool, cfg, clients),
    }
}

fn run_open(pool: &WorkerPool, cfg: &LoadConfig, rps: f64) -> LoadReport {
    let gaps = poisson_interarrivals(cfg.seed, rps, cfg.requests);
    let mut rng = Pcg64::new(cfg.seed ^ 0xFEED);
    let mut receivers = Vec::with_capacity(cfg.requests);
    let mut rejected = 0u64;
    let mut offered = 0usize;
    let mut lag_us = Vec::with_capacity(cfg.requests);
    let start = Instant::now();
    let mut due = start;
    for gap in gaps {
        due += Duration::from_secs_f64(gap);
        pace_until(due);
        // schedule slip: how late this arrival fires relative to its
        // Poisson due time — at rates the generator cannot pace, this is
        // the honest record that the offered rate fell short
        lag_us.push(Instant::now().saturating_duration_since(due).as_secs_f64() * 1e6);
        let row = draw_request(&mut rng, &cfg.tenants);
        offered += 1;
        match pool.submit(row) {
            Ok(rx) => receivers.push(rx),
            Err(SubmitError::Rejected { .. }) => rejected += 1, // open loop sheds
            // the pool is gone: stop generating — the remaining schedule
            // was never offered and must not be reported as if it were
            Err(SubmitError::Closed) => break,
        }
    }
    // drain: latency was measured worker-side at reply time, so a late
    // collector does not distort it
    let mut lat_us = Vec::with_capacity(receivers.len());
    let mut errors = 0usize;
    for rx in receivers {
        match rx.recv() {
            Ok(Ok((_, d))) => lat_us.push(d.as_secs_f64() * 1e6),
            Ok(Err(_)) => errors += 1,
            Err(_) => errors += 1, // pool died mid-flight
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    LoadReport::from_outcomes(offered, rejected, &mut lat_us, errors, 0, wall_s, &mut lag_us)
}

/// One closed-loop client's reject pacing: honour the server's retry-after
/// hint, escalate exponentially over consecutive rejects of the same
/// request (doubling, capped at 16×), and jitter each pause uniformly over
/// `[0.5, 1.5)×` from the client's own seeded stream — clients that were
/// rejected together must not re-arrive together, or the synchronized
/// retry storm re-trips admission in lockstep.
pub fn retry_backoff(hint: Duration, consecutive: u32, rng: &mut Pcg64) -> Duration {
    let scale = (1u64 << consecutive.min(4)) as f64;
    hint.mul_f64(scale * (0.5 + rng.f64()))
}

fn run_closed(pool: &WorkerPool, cfg: &LoadConfig, clients: usize) -> LoadReport {
    let clients = clients.max(1);
    let start = Instant::now();
    let results: Vec<(Vec<f64>, u64, usize, usize, usize)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(clients);
        for c in 0..clients {
            let share = cfg.requests / clients + usize::from(c < cfg.requests % clients);
            let mut rng = Pcg64::new(cfg.seed ^ (0xC11E47 + c as u64));
            // jitter draws live on their own stream: the payload sequence
            // stays a pure function of the seed no matter how many rejects
            // wall-clock timing happens to produce
            let mut jitter_rng = Pcg64::new(cfg.seed ^ (0xBAC_0FF + c as u64));
            handles.push(scope.spawn(move || {
                let mut lat_us = Vec::with_capacity(share);
                let mut rejected = 0u64;
                let mut errors = 0usize;
                let mut offered = 0usize;
                let mut abandoned = 0usize;
                for _ in 0..share {
                    offered += 1;
                    let row = draw_request(&mut rng, &cfg.tenants);
                    let mut consecutive = 0u32;
                    let mut budget_left = cfg.retry_budget;
                    loop {
                        match pool.submit(row.clone()) {
                            Ok(rx) => {
                                match rx.recv() {
                                    Ok(Ok((_, d))) => lat_us.push(d.as_secs_f64() * 1e6),
                                    _ => errors += 1,
                                }
                                break;
                            }
                            Err(SubmitError::Rejected { retry_after, .. }) => {
                                rejected += 1;
                                if !cfg.retry_rejects {
                                    break;
                                }
                                let pause = retry_backoff(retry_after, consecutive, &mut jitter_rng);
                                consecutive += 1;
                                if pause > budget_left {
                                    abandoned += 1;
                                    break;
                                }
                                budget_left -= pause;
                                std::thread::sleep(pause);
                            }
                            Err(SubmitError::Closed) => break,
                        }
                    }
                }
                (lat_us, rejected, errors, offered, abandoned)
            }));
        }
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let wall_s = start.elapsed().as_secs_f64();
    let mut lat_us = Vec::new();
    let mut rejected = 0u64;
    let mut errors = 0usize;
    let mut offered = 0usize;
    let mut abandoned = 0usize;
    for (l, r, e, o, a) in results {
        lat_us.extend(l);
        rejected += r;
        errors += e;
        offered += o;
        abandoned += a;
    }
    LoadReport::from_outcomes(
        offered,
        rejected,
        &mut lat_us,
        errors,
        abandoned,
        wall_s,
        &mut Vec::new(), // closed loop has no arrival schedule to slip
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_schedule_is_deterministic_with_the_right_mean() {
        let a = poisson_interarrivals(42, 1000.0, 4000);
        let b = poisson_interarrivals(42, 1000.0, 4000);
        assert_eq!(a, b, "same seed, same schedule");
        let c = poisson_interarrivals(43, 1000.0, 4000);
        assert_ne!(a, c, "different seed, different schedule");
        let mean = a.iter().sum::<f64>() / a.len() as f64;
        assert!((mean - 1e-3).abs() < 1e-4, "mean gap {mean} vs 1 ms");
        assert!(a.iter().all(|&g| g > 0.0));
    }

    #[test]
    fn poisson_schedule_is_byte_identical_for_a_fixed_seed() {
        // stronger than value equality: the schedule the serving tests and
        // the open-loop generator replay must be *bit*-identical run to run
        // (f64 == would also accept distinct NaN payloads / -0.0 vs 0.0)
        let a = poisson_interarrivals(0x10AD, 2500.0, 2048);
        let b = poisson_interarrivals(0x10AD, 2500.0, 2048);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&a), bits(&b), "same seed must give the same bytes");
        // and the underlying uniform stream is pinned cross-platform (the
        // ln/div are IEEE-deterministic given identical inputs, and the
        // inputs are the pinned Pcg64 integer stream)
        let mut r = Pcg64::new(0x10AD);
        let u = r.f64_open();
        assert_eq!(a[0].to_bits(), (-u.ln() / 2500.0).to_bits());
    }

    #[test]
    fn tenant_mix_draws_every_tenant() {
        let tenants = vec![
            Tenant { name: "a".into(), weight: 1.0, dim: 16 },
            Tenant { name: "b".into(), weight: 3.0, dim: 32 },
        ];
        let mut rng = Pcg64::new(5);
        let mut counts = [0usize; 2];
        for _ in 0..2000 {
            let row = draw_request(&mut rng, &tenants);
            match row.len() {
                16 => counts[0] += 1,
                32 => counts[1] += 1,
                other => panic!("unexpected dim {other}"),
            }
        }
        let frac_b = counts[1] as f64 / 2000.0;
        assert!((frac_b - 0.75).abs() < 0.05, "weighted draw off: {frac_b}");
    }

    #[test]
    fn retry_backoff_is_seeded_jittered_and_capped() {
        let hint = Duration::from_micros(100);
        let pauses = |seed: u64| -> Vec<Duration> {
            let mut rng = Pcg64::new(seed);
            (0..8).map(|i| retry_backoff(hint, i, &mut rng)).collect()
        };
        assert_eq!(pauses(1), pauses(1), "same seed, same pauses");
        assert_ne!(pauses(1), pauses(2), "different seed, different jitter");
        for (i, d) in pauses(1).into_iter().enumerate() {
            // pause i lives in [0.5, 1.5) × 2^min(i,4) × hint: the hint is
            // honoured (never less than half), escalation doubles, and the
            // envelope caps at 16× so a long reject streak cannot sleep
            // unboundedly past the deadline budget
            let scale = (1u64 << i.min(4)) as f64;
            assert!(d >= hint.mul_f64(scale * 0.5), "attempt {i}: {d:?} under the envelope");
            assert!(d < hint.mul_f64(scale * 1.5), "attempt {i}: {d:?} over the envelope");
        }
    }

    #[test]
    fn deadline_budget_abandons_instead_of_retrying_forever() {
        use crate::coordinator::pool::{PoolConfig, SyntheticEngine, WorkerPool};
        use crate::mem::backend::BackendSpec;
        // high_water 0 rejects every submission unconditionally — the one
        // server state where reject behaviour is timing-independent, which
        // lets the client-side budget logic be asserted exactly
        let cfg = PoolConfig {
            backend: BackendSpec::Sram,
            workers: 1,
            shards: 1,
            buffer_bytes: 16 * 1024,
            high_water: 0,
            seed: 21,
            ..PoolConfig::default()
        };
        let engine = Box::new(SyntheticEngine { exec_latency: Duration::ZERO, ..Default::default() });
        let pool = WorkerPool::start_with_engines(cfg, vec![engine]).unwrap();
        // zero budget: the first reject abandons, no sleeping at all
        let zero = run(
            &pool,
            &LoadConfig {
                arrival: Arrival::ClosedLoop { clients: 1 },
                requests: 4,
                retry_budget: Duration::ZERO,
                seed: 33,
                ..LoadConfig::default()
            },
        );
        assert_eq!(zero.offered, 4);
        assert_eq!(zero.abandoned, 4, "zero budget abandons on the first reject");
        assert_eq!(zero.completed, 0);
        assert_eq!(zero.rejected, 4, "exactly one reject event per request");
        // a small positive budget: clients back off and retry several times
        // (more reject events than requests) before the deadline gives up
        let small = run(
            &pool,
            &LoadConfig {
                arrival: Arrival::ClosedLoop { clients: 2 },
                requests: 6,
                retry_budget: Duration::from_millis(2),
                seed: 34,
                ..LoadConfig::default()
            },
        );
        assert_eq!(small.abandoned, 6, "an unyielding server exhausts every budget");
        assert!(
            small.rejected > 6,
            "a positive budget must retry before abandoning (saw {} rejects)",
            small.rejected
        );
        pool.shutdown();
    }

    #[test]
    fn default_mix_resolves_networks() {
        let mix = Tenant::default_mix();
        assert_eq!(mix.len(), 2);
        assert!(mix.iter().all(|t| (16..=784).contains(&t.dim)));
    }

    #[test]
    fn bad_weights_are_a_structured_error_and_zero_total_draws_uniform() {
        let cfg = LoadConfig {
            tenants: vec![
                Tenant { name: "good".into(), weight: 1.0, dim: 16 },
                Tenant { name: "bad".into(), weight: -2.0, dim: 32 },
            ],
            ..LoadConfig::default()
        };
        match cfg.validate() {
            Err(LoadError::BadWeight { tenant, weight }) => {
                assert_eq!(tenant, "bad");
                assert_eq!(weight, -2.0);
            }
            other => panic!("negative weight must be rejected, got {other:?}"),
        }
        let nan = LoadConfig {
            tenants: vec![Tenant { name: "n".into(), weight: f64::NAN, dim: 16 }],
            ..LoadConfig::default()
        };
        assert!(nan.validated().is_err(), "non-finite weight must be rejected");

        // an all-zero mix is legal and draws uniformly — previously every
        // request silently routed to the last tenant
        let tenants = vec![
            Tenant { name: "a".into(), weight: 0.0, dim: 16 },
            Tenant { name: "b".into(), weight: 0.0, dim: 32 },
            Tenant { name: "c".into(), weight: 0.0, dim: 64 },
        ];
        assert!(LoadConfig { tenants: tenants.clone(), ..LoadConfig::default() }
            .validate()
            .is_ok());
        let mut rng = Pcg64::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            match draw_request(&mut rng, &tenants).len() {
                16 => counts[0] += 1,
                32 => counts[1] += 1,
                64 => counts[2] += 1,
                other => panic!("unexpected dim {other}"),
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            let frac = c as f64 / 3000.0;
            assert!((frac - 1.0 / 3.0).abs() < 0.05, "tenant {i} drew {frac}, want ~1/3");
        }
    }

    #[test]
    fn mixed_zero_weights_never_receive_traffic() {
        // a zero-weight tenant alongside positive ones must get nothing,
        // including via the end-of-walk fp fallback (the old code defaulted
        // to the *last* tenant regardless of its weight)
        let tenants = vec![
            Tenant { name: "hot".into(), weight: 2.0, dim: 16 },
            Tenant { name: "cold".into(), weight: 0.0, dim: 32 },
        ];
        let mut rng = Pcg64::new(12);
        for _ in 0..2000 {
            assert_eq!(draw_request(&mut rng, &tenants).len(), 16);
        }
    }

    #[test]
    fn dead_pool_reports_only_the_attempts_actually_offered() {
        use crate::coordinator::pool::{InferEngine, PoolConfig, WorkerPool};
        use crate::faults::FATAL_MARKER;
        use crate::mem::backend::BackendSpec;

        struct CrashEngine;
        impl InferEngine for CrashEngine {
            fn batch(&self) -> usize {
                1
            }
            fn dim(&self) -> usize {
                16
            }
            fn infer(&mut self, _x: &[i8]) -> anyhow::Result<Vec<usize>> {
                anyhow::bail!(FATAL_MARKER)
            }
        }

        let cfg = PoolConfig {
            backend: BackendSpec::Sram,
            workers: 1,
            shards: 1,
            buffer_bytes: 16 * 1024,
            batch_window: Duration::ZERO,
            seed: 51,
            ..PoolConfig::default()
        };
        let pool = WorkerPool::start_with_engines(cfg, vec![Box::new(CrashEngine)]).unwrap();
        // kill the only worker, then wait for admission to close
        let rx = pool.submit(vec![0i8; 16]).expect("first submit admitted");
        assert!(rx.recv().expect("reply delivered").is_err(), "crash surfaces as an error");
        let deadline = Instant::now() + Duration::from_secs(5);
        while pool.alive_workers() > 0 {
            assert!(Instant::now() < deadline, "worker death must close admission");
            std::thread::sleep(Duration::from_micros(200));
        }

        // the generator is configured for 50 requests, but the first
        // attempt sees Closed and stops: offered must say 1, not 50
        let report = run(
            &pool,
            &LoadConfig {
                arrival: Arrival::OpenPoisson { rps: 1.0e6 },
                requests: 50,
                seed: 52,
                ..LoadConfig::default()
            },
        );
        assert_eq!(report.offered, 1, "only the attempted submit counts as offered");
        assert_eq!(report.completed, 0);
        assert_eq!(report.accepted, 0);
        assert_eq!(report.rejected, 0);
        pool.shutdown();
    }

    #[test]
    fn unkeepable_schedules_report_their_slip() {
        use crate::coordinator::pool::{PoolConfig, SyntheticEngine, WorkerPool};
        use crate::mem::backend::BackendSpec;
        let cfg = PoolConfig {
            backend: BackendSpec::Sram,
            workers: 1,
            shards: 1,
            buffer_bytes: 16 * 1024,
            seed: 61,
            ..PoolConfig::default()
        };
        let engine = Box::new(SyntheticEngine { exec_latency: Duration::ZERO, ..Default::default() });
        let pool = WorkerPool::start_with_engines(cfg, vec![engine]).unwrap();
        // 10M req/s asks for ~0.1 µs gaps — no generator thread can pace
        // that, so the slip must be visible instead of silently absorbed
        let report = run(
            &pool,
            &LoadConfig {
                arrival: Arrival::OpenPoisson { rps: 1.0e7 },
                requests: 2000,
                seed: 62,
                ..LoadConfig::default()
            },
        );
        pool.shutdown();
        assert_eq!(report.offered, 2000);
        assert!(
            report.sched_lag_p99_us > 100.0,
            "a 10M req/s schedule must report real slip, saw {} µs",
            report.sched_lag_p99_us
        );
        assert!(report.p999_latency_us >= report.p99_latency_us);
    }
}
