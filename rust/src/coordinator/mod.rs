//! L3 coordinator — the memory-system role of this paper.
//!
//! MCAIMem is a buffer, so the coordinator owns the buffer: a tensor-level
//! [`buffer_manager`] backed by any [`crate::mem::MemoryBackend`] (the
//! functional mixed-cell array with its refresh controller, or any
//! baseline) with sharded striping for the serving tier; a [`scheduler`]
//! that drives whole-network inference timelines through that buffer on the
//! simulated accelerator clock (the event-driven counterpart of the
//! closed-form energy model — the two are cross-checked in tests); the
//! single-worker batched inference [`server`]; and the production-scale
//! serving tier — a [`pool`] of K workers over N bank shards behind an
//! event-loop dispatcher (per-worker parking, continuous batching,
//! refresh-aware stall placement) with admission control, driven by the
//! [`loadgen`] arrival processes (threads + channels — the offline crate
//! set has no tokio).

pub mod buffer_manager;
pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod scheduler;
pub mod server;

pub use buffer_manager::{BufferManager, TensorHandle};
pub use loadgen::{Arrival, LoadConfig, LoadError, LoadReport, Tenant};
pub use pool::{InferEngine, PoolConfig, SubmitError, SyntheticEngine, WorkerPool};
pub use scheduler::{plan_window, simulate_inference, DispatchMode, SimReport, WindowPlan};
pub use server::{InferenceServer, ServerConfig, ServerStats, ShardStat};
