//! L3 coordinator — the memory-system role of this paper.
//!
//! MCAIMem is a buffer, so the coordinator owns the buffer: a tensor-level
//! [`buffer_manager`] backed by the *functional* mixed-cell array (real
//! bit-planes, real flips) with its refresh controller; a [`scheduler`]
//! that drives whole-network inference timelines through that buffer on the
//! simulated accelerator clock (the event-driven counterpart of the
//! closed-form energy model — the two are cross-checked in tests); and a
//! batched inference [`server`] that executes the AOT model via PJRT while
//! routing request tensors through the buffer path (threads + channels —
//! the offline crate set has no tokio).

pub mod buffer_manager;
pub mod metrics;
pub mod scheduler;
pub mod server;

pub use buffer_manager::{BufferManager, TensorHandle};
pub use scheduler::{simulate_inference, SimReport};
pub use server::{InferenceServer, ServerConfig, ServerStats};
