//! Sharded multi-worker serving tier — the production-scale front-end.
//!
//! Replaces the one-thread/one-buffer/one-queue server for heavy traffic:
//! K workers each own an inference engine plus a [`BufferManager`] over
//! their slice of the tier's N bank shards (a [`ShardedBackend`] stripe —
//! per-shard meters, staggered refresh), fed by an event-loop dispatcher
//! with admission control:
//!
//! * **Event-loop dispatch** — each worker owns a queue + condvar pair and
//!   parks on *its own* condvar when idle; `submit` routes round-robin over
//!   the live workers and signals only the target, and only when it is
//!   actually parked. There is no shared wakeup channel and no periodic
//!   blind poll on the idle path: a fully idle tier burns no CPU, and a
//!   submission wakes exactly one worker. Waits become bounded only while
//!   work is known to be queued somewhere (so a worker can steal from a
//!   busy or dead peer's queue back).
//! * **Continuous batching** — a dispatch window merges whatever compatible
//!   requests are queued instead of always padding to the engine batch: the
//!   window's jobs are grouped by row width and each same-width group runs
//!   as one staged pass. Engines that accept partial batches
//!   ([`InferEngine::supports_partial`]) execute exactly the real rows;
//!   fixed-shape engines are padded transparently by the
//!   [`InferEngine::infer_rows`] default.
//! * **Zero-copy staging** — a request's `i8` payload is staged through the
//!   worker's buffer shards by reinterpreting the bytes in place
//!   ([`BufferManager::store_i8`] / [`BufferManager::load_i8`]); only the
//!   real rows are stored and loaded (a sub-handle over the batch region),
//!   so the hot path never round-trips through a widening copy.
//! * **Refresh-aware admission** — the dispatcher plans every window
//!   against the buffer's refresh slot grid
//!   ([`super::scheduler::plan_window`]). The virtual refresh schedule is
//!   identical in both modes (meters and recorded traces are bit-exact);
//!   what moves is when the modeled wall-clock refresh stall
//!   (`refresh_stall` per slot, default zero) is paid:
//!   [`DispatchMode::Oblivious`] stalls the window's requests before their
//!   replies (the stall lands in their latency tail), while
//!   [`DispatchMode::RefreshAware`] answers first and absorbs the stall in
//!   the inter-window slack the planner computed — refresh work still
//!   happens, but off the request critical path.
//! * **Admission control** — when total queue depth reaches the
//!   `high_water` mark, `submit` refuses with a retry-after hint instead of
//!   letting the queue grow without bound (reject-with-retry-after beats
//!   unbounded latency collapse under overload). The mark is advisory:
//!   concurrent submitters may overshoot it by a few requests.
//! * **Exactly-once replies** — every accepted request is answered exactly
//!   once: with its class on success, or with the batch's inference error
//!   on failure (never a silently dropped channel).
//! * **Graceful degradation** — an inference error carrying
//!   [`crate::faults::FATAL_MARKER`] is unrecoverable for that worker: it
//!   answers its in-flight batch with errors, leaves the pool's live set,
//!   re-routes its queued jobs to the surviving workers, and exits.
//!   Admission then scales the high-water mark by the surviving capacity
//!   (never below one batch), and once *every* worker has died `submit`
//!   refuses with `Closed` while [`WorkerPool::shutdown`] drains any
//!   stranded jobs with error replies — the exactly-once guarantee holds
//!   through total engine loss.
//!
//! Engines: with PJRT artifacts each worker owns a [`ModelRunner`]; without
//! them a [`SyntheticEngine`] classifies deterministically while *really*
//! blocking for the configured accelerator execution latency — so the tier
//! is latency-bound exactly like a PJRT-backed worker, and multi-worker
//! scaling measures true pipeline parallelism, not an idle spin. In both
//! cases every request's payload is staged through the worker's buffer
//! shard (store → compute tick → load), so the chosen memory technology
//! sees the real serving traffic: occupancy, refresh and energy all accrue
//! on the per-shard meters surfaced in [`ServerStats::shards`].

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::buffer_manager::{BufferManager, TensorHandle};
use super::metrics::Metrics;
use super::scheduler::{plan_window, DispatchMode};
use super::server::{Reply, ServerStats, ShardStat};
use crate::mem::backend::BackendSpec;
use crate::mem::mcaimem::EnergyMeter;
use crate::runtime::executor::ModelRunner;
use crate::util::rng::{shard_seeds, Pcg64};
use crate::util::stats::Reservoir;

/// Queue-depth samples kept for the p99 readout (seeded reservoir — the
/// submit hot path stays allocation-bounded no matter how long the run).
const DEPTH_SAMPLE_CAP: usize = 4096;

/// Bound on a wait while work is known to be queued somewhere: a worker
/// wakes at least this often to steal from a busy or dead peer.
const STEAL_POLL: Duration = Duration::from_millis(1);

/// Bound on a fill-window wait: peer pushes don't signal this worker, so
/// while collecting a batch it re-checks the steal path on this cadence.
const FILL_POLL: Duration = Duration::from_micros(200);

/// Serving-tier configuration.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Buffer technology every shard is built from.
    pub backend: BackendSpec,
    /// Worker threads (each owns an engine + its shard slice).
    pub workers: usize,
    /// Bank shards striped across the tier (`shards >= workers`; shards
    /// are dealt to workers round-robin, remainder to the first workers).
    pub shards: usize,
    /// Total buffer capacity across all shards (must divide by `shards`).
    pub buffer_bytes: usize,
    /// Batching window: how long a worker waits to fill a batch.
    pub batch_window: Duration,
    /// Admission high-water mark: total queued requests at or above this
    /// are rejected with a retry-after hint.
    pub high_water: usize,
    /// Virtual buffer-clock advance per executed batch (refresh slots fire,
    /// static energy integrates).
    pub sim_compute_s: f64,
    /// Retention-flip probability fed to aged (PJRT) engines.
    pub flip_p: f64,
    /// Per-batch service-time estimate (µs) scaling the retry-after hint.
    pub est_service_us: u64,
    /// Where the modeled refresh stall is paid relative to replies (the
    /// virtual refresh schedule itself is identical either way).
    pub dispatch: DispatchMode,
    /// Modeled wall-clock stall per refresh slot that fires inside a
    /// dispatched window. Zero (the default) disables stall modeling
    /// entirely — refresh then affects only the virtual meters, exactly
    /// the pre-existing behaviour.
    pub refresh_stall: Duration,
    pub seed: u64,
    /// Telemetry sink threaded through every worker's buffer manager and
    /// backend (disabled by default — the submit/reply hot paths then pay
    /// a single branch and zero allocations; see `crate::obs`).
    pub obs: crate::obs::ObsSink,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            backend: BackendSpec::mcaimem_default(),
            workers: 1,
            shards: 1,
            buffer_bytes: 256 * 1024,
            batch_window: Duration::from_micros(200),
            high_water: 256,
            sim_compute_s: 2e-6,
            flip_p: 0.01,
            est_service_us: 300,
            dispatch: DispatchMode::RefreshAware,
            refresh_stall: Duration::ZERO,
            seed: 0xD00D,
            obs: crate::obs::ObsSink::disabled(),
        }
    }
}

/// Why a submission was refused.
#[derive(Debug)]
pub enum SubmitError {
    /// Queue depth at/above the high-water mark: try again after the hint.
    Rejected { depth: usize, retry_after: Duration },
    /// The pool has shut down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Rejected { depth, retry_after } => write!(
                f,
                "admission refused: queue depth {depth}, retry after {:.1} ms",
                retry_after.as_secs_f64() * 1e3
            ),
            SubmitError::Closed => write!(f, "pool closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One worker's inference engine: turns a staged int8 tensor into per-row
/// class indices.
pub trait InferEngine: Send {
    /// Rows per executed batch.
    fn batch(&self) -> usize;
    /// Bytes per row.
    fn dim(&self) -> usize;
    /// Full-batch inference: `x` is exactly `batch × dim`.
    fn infer(&mut self, x: &[i8]) -> Result<Vec<usize>>;

    /// Whether [`Self::infer_rows`] executes partial batches natively. The
    /// dispatcher uses this for padding accounting: a partial-capable
    /// engine executes `rows` slots, a fixed-shape one executes `batch`.
    fn supports_partial(&self) -> bool {
        false
    }

    /// Inference over the first `rows` rows (`x` is `rows × dim`,
    /// `rows <= batch`). The default pads up to the fixed batch shape and
    /// truncates the classes — engines that can execute a partial batch
    /// directly override this (and [`Self::supports_partial`]).
    fn infer_rows(&mut self, x: &[i8], rows: usize) -> Result<Vec<usize>> {
        let (b, d) = (self.batch(), self.dim());
        anyhow::ensure!(x.len() == rows * d && rows <= b, "partial batch shape mismatch");
        if rows == b {
            return self.infer(x);
        }
        let mut full = vec![0i8; b * d];
        full[..x.len()].copy_from_slice(x);
        let mut classes = self.infer(&full)?;
        anyhow::ensure!(
            classes.len() >= rows,
            "engine returned {} classes for {rows} rows",
            classes.len()
        );
        classes.truncate(rows);
        Ok(classes)
    }
}

/// PJRT-less engine: a deterministic classifier plus a *real* block for the
/// modeled accelerator execution latency, so pool throughput is
/// latency-bound the way a PJRT-backed worker is. The classifier is a
/// stable byte hash — meaningless labels, but bit-reproducible, which is
/// what the serving-tier tests need.
pub struct SyntheticEngine {
    pub batch: usize,
    pub dim: usize,
    pub classes: usize,
    /// Modeled accelerator execution latency per batch (really slept).
    pub exec_latency: Duration,
}

impl Default for SyntheticEngine {
    fn default() -> Self {
        SyntheticEngine {
            batch: 4,
            dim: 784,
            classes: 10,
            exec_latency: Duration::from_micros(250),
        }
    }
}

impl InferEngine for SyntheticEngine {
    fn batch(&self) -> usize {
        self.batch
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn infer(&mut self, x: &[i8]) -> Result<Vec<usize>> {
        anyhow::ensure!(x.len() == self.batch * self.dim, "batch shape mismatch");
        self.infer_rows(x, self.batch)
    }

    fn supports_partial(&self) -> bool {
        true
    }

    fn infer_rows(&mut self, x: &[i8], rows: usize) -> Result<Vec<usize>> {
        anyhow::ensure!(
            x.len() == rows * self.dim && rows <= self.batch,
            "partial batch shape mismatch"
        );
        if !self.exec_latency.is_zero() {
            std::thread::sleep(self.exec_latency);
        }
        Ok(x.chunks(self.dim)
            .map(|row| {
                let mut h = 0xcbf2_9ce4_8422_2325u64;
                for &v in row {
                    h = (h ^ v as u8 as u64).wrapping_mul(0x0000_0100_0000_01B3);
                }
                (h % self.classes as u64) as usize
            })
            .collect())
    }
}

/// PJRT-backed engine: one [`ModelRunner`] per worker (executables are not
/// `Sync`), serving the aged model for the pool's backend spec.
pub struct PjrtEngine {
    runner: ModelRunner,
    spec: BackendSpec,
    flip_p: f64,
    rng: Pcg64,
}

impl PjrtEngine {
    pub fn new(dir: &std::path::Path, spec: BackendSpec, flip_p: f64, seed: u64) -> Result<Self> {
        Ok(PjrtEngine { runner: ModelRunner::new(dir)?, spec, flip_p, rng: Pcg64::new(seed) })
    }
}

impl InferEngine for PjrtEngine {
    fn batch(&self) -> usize {
        self.runner.artifacts.batch
    }

    fn dim(&self) -> usize {
        self.runner.artifacts.input_dim
    }

    fn infer(&mut self, x: &[i8]) -> Result<Vec<usize>> {
        self.runner.infer(x, &self.spec, self.flip_p, &mut self.rng)
    }
}

struct Job {
    /// Stable request id (the pool's admission sequence number) — threads
    /// through the trace so a reply instant names the request it answers.
    id: u64,
    row: Vec<i8>,
    submitted: Instant,
    reply: mpsc::Sender<Reply>,
}

/// One worker's dispatch endpoint: its queue, its private condvar, and the
/// park/live flags the event loop routes by.
struct WorkerSlot {
    q: Mutex<VecDeque<Job>>,
    cv: Condvar,
    /// The owner is blocked on `cv`. Set and cleared while holding `q`'s
    /// lock, so a submitter that pushed under the same lock and then reads
    /// `true` knows its targeted signal cannot be lost.
    parked: AtomicBool,
    /// The owner still serves; cleared when its engine dies fatally.
    /// `submit` routes around dead slots.
    live: AtomicBool,
}

struct Shared {
    /// One dispatch slot per worker (owner pops its queue front, thieves
    /// pop the back).
    slots: Vec<WorkerSlot>,
    /// Total queued (not yet popped) requests — the admission signal.
    depth: AtomicUsize,
    closed: AtomicBool,
    rejected: AtomicU64,
    /// Queue depth sampled at accepted submits (bounded seeded reservoir).
    /// `depth_offers` counts offers so the Algorithm-R keep/drop decision
    /// runs lock-free; the mutex is taken only for kept samples, a rate
    /// that decays to `cap / n`.
    depth_samples: Mutex<Reservoir>,
    depth_offers: AtomicU64,
    depth_seed: u64,
    rr: AtomicUsize,
    /// Workers still serving. A fatally-crashed worker decrements this on
    /// the way out; admission scales its high-water mark by `alive/workers`
    /// and closes entirely at zero.
    alive: AtomicUsize,
    /// Admission sequence: one ticket per submit (accepted or rejected).
    /// Request ids and the pool trace track's logical timebase — wall
    /// clock never enters the trace.
    pool_seq: AtomicU64,
}

impl Shared {
    /// First live worker at or after `start` (wrapping), if any.
    fn route_live(&self, start: usize) -> Option<usize> {
        let n = self.slots.len();
        (0..n).map(|i| (start + i) % n).find(|&k| self.slots[k].live.load(Ordering::SeqCst))
    }

    /// Push a job onto worker `k`'s queue and signal `k` iff it is parked.
    /// Does not touch `depth` — callers account for it.
    fn push_job(&self, k: usize, job: Job) {
        let slot = &self.slots[k];
        let mut q = slot.q.lock().unwrap();
        q.push_back(job);
        // read under the lock: park transitions happen under it too, so
        // "parked now" means the owner is committed to (or inside) a wait
        // on this condvar and the signal cannot be lost
        let parked = slot.parked.load(Ordering::SeqCst);
        drop(q);
        if parked {
            slot.cv.notify_one();
        }
    }

    /// Record one accepted submit's observed depth into the reservoir.
    fn sample_depth(&self, d: usize) {
        let i = self.depth_offers.fetch_add(1, Ordering::Relaxed);
        if let Some(slot) = Reservoir::slot_for(self.depth_seed, i, DEPTH_SAMPLE_CAP) {
            self.depth_samples.lock().unwrap().place(slot, d as f64);
        }
    }

    /// Wake every worker. Each signal is sent while holding that slot's
    /// queue lock, so a worker between its wake-condition check and its
    /// wait cannot miss it (the signal waits for the lock the worker still
    /// holds).
    fn wake_all(&self) {
        for slot in &self.slots {
            let _q = slot.q.lock().unwrap();
            slot.cv.notify_all();
        }
    }

    fn try_pop(&self, k: usize) -> Option<Job> {
        if let Some(j) = self.slots[k].q.lock().unwrap().pop_front() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            return Some(j);
        }
        let n = self.slots.len();
        for i in 1..n {
            if let Some(j) = self.slots[(k + i) % n].q.lock().unwrap().pop_back() {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                return Some(j);
            }
        }
        None
    }

    /// Block until a job is available; `None` once the pool is closed and
    /// every queue has drained.
    ///
    /// Parking protocol: the worker publishes `parked` and enters the wait
    /// while holding its own queue lock. A submitter pushes under that same
    /// lock, so by the time it observes `parked` the worker is committed to
    /// the wait — the targeted signal cannot be lost. When the whole tier
    /// is idle (`depth == 0`, read *after* publishing `parked`) the wait is
    /// untimed: the next submit wakes exactly this worker, and a dying
    /// peer's hand-off or `shutdown` signals through [`Shared::wake_all`].
    /// With work queued somewhere else the wait is bounded by
    /// [`STEAL_POLL`] so this worker can steal from a busy or dead peer.
    fn next_job(&self, k: usize) -> Option<Job> {
        loop {
            if let Some(j) = self.try_pop(k) {
                return Some(j);
            }
            if self.closed.load(Ordering::SeqCst) {
                // final drain check: a job may have landed between the pop
                // and the flag read
                return self.try_pop(k);
            }
            let slot = &self.slots[k];
            let mut q = slot.q.lock().unwrap();
            if let Some(j) = q.pop_front() {
                // a push landed between try_pop and taking the lock
                self.depth.fetch_sub(1, Ordering::Relaxed);
                return Some(j);
            }
            slot.parked.store(true, Ordering::SeqCst);
            // `closed` re-checked after publishing parked: shutdown sets it
            // before wake_all, and wake_all's lock-held signal serializes
            // with this critical section — one of the two is always seen
            if self.closed.load(Ordering::SeqCst) {
                slot.parked.store(false, Ordering::SeqCst);
                continue;
            }
            q = if self.depth.load(Ordering::SeqCst) == 0 {
                slot.cv.wait(q).unwrap()
            } else {
                slot.cv.wait_timeout(q, STEAL_POLL).unwrap().0
            };
            slot.parked.store(false, Ordering::SeqCst);
            if let Some(j) = q.pop_front() {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                return Some(j);
            }
        }
    }
}

struct WorkerReport {
    metrics: Metrics,
    shard_meters: Vec<EnergyMeter>,
}

/// Handle to the running serving tier.
pub struct WorkerPool {
    shared: Arc<Shared>,
    cfg: PoolConfig,
    batch: usize,
    workers: Vec<JoinHandle<WorkerReport>>,
}

impl WorkerPool {
    /// Start with PJRT engines when `artifacts` holds a usable export,
    /// falling back to [`SyntheticEngine`]s (with a note) otherwise — the
    /// path `mcaimem serve` takes.
    pub fn start_with_artifacts(cfg: PoolConfig, artifacts: Option<PathBuf>) -> Result<WorkerPool> {
        let seeds = shard_seeds(cfg.seed ^ 0xE4617E, cfg.workers.max(1));
        if let Some(dir) = artifacts {
            match PjrtEngine::new(&dir, cfg.backend.clone(), cfg.flip_p, seeds[0]) {
                Ok(first) => {
                    let mut engines: Vec<Box<dyn InferEngine>> = vec![Box::new(first)];
                    for &s in &seeds[1..] {
                        engines.push(Box::new(PjrtEngine::new(&dir, cfg.backend.clone(), cfg.flip_p, s)?));
                    }
                    return Self::start_with_engines(cfg, engines);
                }
                Err(e) => {
                    eprintln!("pool: PJRT unavailable ({e:#}); using the synthetic engine");
                }
            }
        }
        Self::start(cfg)
    }

    /// Start with default [`SyntheticEngine`]s (no artifacts needed).
    pub fn start(cfg: PoolConfig) -> Result<WorkerPool> {
        let engines =
            (0..cfg.workers).map(|_| Box::new(SyntheticEngine::default()) as Box<dyn InferEngine>);
        Self::start_with_engines(cfg, engines.collect())
    }

    /// Start with one pre-built engine per worker (tests inject failing or
    /// gated engines here). Builds each worker's sharded buffer slice from
    /// the config and delegates to [`Self::start_with_buffers`].
    pub fn start_with_engines(
        cfg: PoolConfig,
        engines: Vec<Box<dyn InferEngine>>,
    ) -> Result<WorkerPool> {
        if cfg.workers == 0 {
            bail!("pool needs at least one worker");
        }
        // fast-fail before paying per-worker buffer construction (mcaimem
        // backends sample O(capacity) leakage corners per worker)
        if engines.len() != cfg.workers {
            bail!("{} engines for {} workers", engines.len(), cfg.workers);
        }
        if cfg.shards < cfg.workers {
            bail!(
                "{} shards cannot feed {} workers (need shards >= workers)",
                cfg.shards,
                cfg.workers
            );
        }
        if cfg.buffer_bytes % cfg.shards != 0 {
            bail!("buffer bytes {} not divisible by {} shards", cfg.buffer_bytes, cfg.shards);
        }
        // deal shards to workers: shards/workers each, remainder to the
        // first workers
        let base = cfg.shards / cfg.workers;
        let rem = cfg.shards % cfg.workers;
        let shard_bytes = cfg.buffer_bytes / cfg.shards;
        let seeds = shard_seeds(cfg.seed, cfg.workers);
        let buffers = (0..cfg.workers)
            .map(|k| {
                let n_k = base + usize::from(k < rem);
                BufferManager::sharded(&cfg.backend, n_k, n_k * shard_bytes, seeds[k])
            })
            .collect::<Result<Vec<_>>>()?;
        Self::start_with_buffers(cfg, engines, buffers)
    }

    /// Start with one pre-built engine AND buffer manager per worker — the
    /// general form. This is the hook that threads a recording or otherwise
    /// customized backend through the serving tier unchanged: build each
    /// worker's buffer over any [`crate::mem::backend::MemoryBackend`]
    /// (e.g. a [`crate::sim::trace::TracingBackend`]-wrapped shard stripe
    /// via `BufferManager::from_backend`) and the pool stages its real
    /// serving traffic through it.
    pub fn start_with_buffers(
        cfg: PoolConfig,
        engines: Vec<Box<dyn InferEngine>>,
        buffers: Vec<BufferManager>,
    ) -> Result<WorkerPool> {
        if cfg.workers == 0 {
            bail!("pool needs at least one worker");
        }
        if engines.len() != cfg.workers {
            bail!("{} engines for {} workers", engines.len(), cfg.workers);
        }
        if buffers.len() != cfg.workers {
            bail!("{} buffer managers for {} workers", buffers.len(), cfg.workers);
        }
        let batch = engines[0].batch();
        let shared = Arc::new(Shared {
            slots: (0..cfg.workers)
                .map(|_| WorkerSlot {
                    q: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                    parked: AtomicBool::new(false),
                    live: AtomicBool::new(true),
                })
                .collect(),
            depth: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            rejected: AtomicU64::new(0),
            depth_samples: Mutex::new(Reservoir::new(DEPTH_SAMPLE_CAP, cfg.seed ^ 0xDE97)),
            depth_offers: AtomicU64::new(0),
            depth_seed: cfg.seed ^ 0xDE97,
            rr: AtomicUsize::new(0),
            alive: AtomicUsize::new(cfg.workers),
            pool_seq: AtomicU64::new(0),
        });

        let mut workers = Vec::with_capacity(cfg.workers);
        // global shard-track bases: worker k's shards get consecutive
        // trace tracks after all of worker k-1's
        let mut shard_base = 0usize;
        for (k, (engine, mut bm)) in engines.into_iter().zip(buffers).enumerate() {
            if cfg.obs.is_enabled() {
                let n_shards = bm.mem.shard_meters().len();
                bm.attach_obs(
                    &cfg.obs,
                    crate::obs::worker_track(k),
                    crate::obs::shard_track(shard_base),
                );
                shard_base += n_shards;
            }
            let need = engine.batch() * engine.dim();
            if bm.capacity() < need {
                bail!(
                    "worker {k}: shard slice of {} B cannot stage a {} B batch",
                    bm.capacity(),
                    need
                );
            }
            let shared = Arc::clone(&shared);
            let cfgc = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("mcaimem-pool-{k}"))
                    .spawn(move || worker_loop(k, shared, cfgc, engine, bm))?,
            );
        }
        Ok(WorkerPool { shared, cfg, batch, workers })
    }

    /// Rows per batch of the workers' engines.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Current total queue depth (advisory).
    pub fn depth(&self) -> usize {
        self.shared.depth.load(Ordering::Relaxed)
    }

    /// Workers still serving (started workers minus fatal engine crashes).
    pub fn alive_workers(&self) -> usize {
        self.shared.alive.load(Ordering::SeqCst)
    }

    /// Submit one row. `Err(Rejected)` above the high-water mark — callers
    /// should back off for the hinted duration before retrying.
    pub fn submit(&self, row: Vec<i8>) -> std::result::Result<mpsc::Receiver<Reply>, SubmitError> {
        if self.shared.closed.load(Ordering::SeqCst) {
            return Err(SubmitError::Closed);
        }
        let alive = self.shared.alive.load(Ordering::SeqCst);
        if alive == 0 {
            // every worker's engine crashed fatally: nothing can serve, so
            // accepting would only strand the job until shutdown's drain
            return Err(SubmitError::Closed);
        }
        // degraded mode: the high-water mark tracks surviving capacity, but
        // never drops below one batch (a lone survivor must accept work);
        // a healthy pool keeps the configured mark bit-for-bit
        let high_water = if alive == self.cfg.workers {
            self.cfg.high_water
        } else {
            (self.cfg.high_water * alive / self.cfg.workers).max(self.batch)
        };
        let depth = self.shared.depth.load(Ordering::Relaxed);
        if depth >= high_water {
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            if self.cfg.obs.is_enabled() {
                let seq = self.shared.pool_seq.fetch_add(1, Ordering::Relaxed);
                self.cfg.obs.emit(crate::obs::Event::instant(
                    crate::obs::EventKind::Reject,
                    crate::obs::TRACK_POOL,
                    seq as f64,
                    seq,
                    depth as u64,
                ));
            }
            let over = (depth + 1 - high_water) as u64;
            // backlog above the mark, in batches, times the service estimate
            let us =
                (over * self.cfg.est_service_us) / (alive as u64 * self.batch as u64).max(1);
            let floor = (self.cfg.est_service_us / 2).min(50_000);
            let retry_after = Duration::from_micros(us.clamp(floor, 50_000));
            return Err(SubmitError::Rejected { depth, retry_after });
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let seq = self.shared.pool_seq.fetch_add(1, Ordering::Relaxed);
        let job = Job { id: seq, row, submitted: Instant::now(), reply: reply_tx };
        let start = self.shared.rr.fetch_add(1, Ordering::Relaxed) % self.cfg.workers;
        let Some(k) = self.shared.route_live(start) else {
            // the last survivor died between the alive check and routing
            return Err(SubmitError::Closed);
        };
        // count the job before it becomes poppable: a fast worker popping
        // (and decrementing) between push and a late increment would wrap
        // the counter to usize::MAX
        let d = self.shared.depth.fetch_add(1, Ordering::Relaxed) + 1;
        if self.cfg.obs.is_enabled() {
            self.cfg.obs.emit(crate::obs::Event::instant(
                crate::obs::EventKind::Admit,
                crate::obs::TRACK_POOL,
                seq as f64,
                seq,
                d as u64,
            ));
        }
        self.shared.push_job(k, job);
        self.shared.sample_depth(d);
        if !self.shared.slots[k].live.load(Ordering::SeqCst) {
            // the target died between routing and push, and its exit drain
            // may already have run — kick everyone so a survivor (possibly
            // in an untimed park) steals this job instead of it waiting
            // for shutdown
            self.shared.wake_all();
        }
        Ok(reply_rx)
    }

    /// Submit one row and block for its reply.
    pub fn classify(&self, row: Vec<i8>) -> Result<(usize, Duration)> {
        let rx = self.submit(row).map_err(|e| anyhow::anyhow!("{e}"))?;
        rx.recv()?
    }

    /// Stop the tier: close admission, drain every queue, join the workers
    /// and aggregate their metrics plus the per-shard meter break-down.
    pub fn shutdown(self) -> ServerStats {
        self.shared.closed.store(true, Ordering::SeqCst);
        self.shared.wake_all();
        let mut merged = Metrics::default();
        let mut shards = Vec::new();
        for (k, w) in self.workers.into_iter().enumerate() {
            let report = w.join().unwrap_or_else(|_| WorkerReport {
                metrics: Metrics::default(),
                shard_meters: Vec::new(),
            });
            merged.merge(&report.metrics);
            for m in report.shard_meters {
                shards.push((k, m));
            }
        }
        // jobs can be stranded only when workers crashed fatally before the
        // close (nobody left to pop or steal); answer them here so every
        // accepted request still gets exactly one reply
        for slot in &self.shared.slots {
            let mut q = slot.q.lock().unwrap();
            while let Some(job) = q.pop_front() {
                self.shared.depth.fetch_sub(1, Ordering::Relaxed);
                merged.record_error();
                let _ = job
                    .reply
                    .send(Err(anyhow::anyhow!("pool shut down before the request was served")));
            }
        }
        let total_rw: u64 = shards
            .iter()
            .map(|(_, m)| m.bytes_read + m.bytes_written)
            .sum();
        let mut stats = ServerStats::from_metrics(&merged);
        stats.rejected = self.shared.rejected.load(Ordering::Relaxed);
        stats.queue_depth_p99 = self.shared.depth_samples.lock().unwrap().quantile(0.99);
        stats.shards = shards
            .into_iter()
            .enumerate()
            .map(|(i, (worker, m))| {
                let rw = m.bytes_read + m.bytes_written;
                ShardStat {
                    shard: i,
                    worker,
                    bytes_rw: rw,
                    occupancy: rw as f64 / total_rw.max(1) as f64,
                    refreshes: m.refreshes,
                    energy_j: m.total_j(),
                }
            })
            .collect();
        stats
    }
}

/// Serve one same-width group as a single staged pass. Returns `true` if
/// the engine failure (if any) was fatal for this worker.
#[allow(clippy::too_many_arguments)]
fn serve_group(
    group: Vec<Job>,
    engine: &mut dyn InferEngine,
    bm: &mut BufferManager,
    stage: TensorHandle,
    cfg: &PoolConfig,
    metrics: &mut Metrics,
    x: &mut Vec<i8>,
) -> bool {
    let batch = engine.batch();
    let dim = engine.dim();
    let real = group.len();
    let obs_on = bm.obs().is_enabled();
    let track = bm.obs_track();
    x.clear();
    x.resize(real * dim, 0);
    for (i, job) in group.iter().enumerate() {
        let n = job.row.len().min(dim);
        x[i * dim..i * dim + n].copy_from_slice(&job.row[..n]);
        metrics.record_bytes_in(n);
    }
    // continuous batching: a partial-capable engine executes only the real
    // rows; a fixed-shape one still pays (and reports) the padded slots
    metrics.record_batch(real, if engine.supports_partial() { real } else { batch });

    // plan this window against the refresh slot grid before advancing the
    // clock: `ops_due` is the refresh work the window will absorb and
    // `slack_s` the gap to the next slot after it — what a refresh-aware
    // dispatcher schedules the stall into
    let plan = plan_window(bm.next_refresh_due(), bm.refresh.slot(), bm.now(), cfg.sim_compute_s);
    let stall = cfg
        .refresh_stall
        .saturating_mul(plan.ops_due.min(u32::MAX as u64) as u32);
    let stall_us = stall.as_secs_f64() * 1e6;

    // zero-copy staging through this worker's buffer shards: the request
    // bytes are viewed as device bytes in place, and only the real rows go
    // through store → compute tick → load (a sub-handle over the batch
    // region)
    let h = TensorHandle { offset: stage.offset, len: real * dim, id: stage.id };
    if obs_on {
        bm.obs().emit(crate::obs::Event::span_begin(
            crate::obs::EventKind::Stage,
            track,
            bm.obs_now_us(),
            real as u64,
            dim as u64,
        ));
    }
    let staged: Vec<i8> = {
        let _staging = crate::obs::profile::phase(crate::obs::profile::Phase::Staging);
        match bm.store_i8(h, x) {
            Ok(()) => {
                bm.tick(cfg.sim_compute_s);
                bm.load_i8(h)
            }
            Err(_) => x.clone(), // sizes are validated at start; defensive only
        }
    };
    if obs_on {
        bm.obs().emit(crate::obs::Event::span_end(
            crate::obs::EventKind::Stage,
            track,
            bm.obs_now_us(),
            real as u64,
            0,
        ));
    }

    if matches!(cfg.dispatch, DispatchMode::Oblivious) && !stall.is_zero() {
        // refresh-oblivious: the slots that fired inside the window stall
        // the array before the batch completes — every request in the
        // group eats the pause in its latency. On the trace the stall span
        // sits on the request path: it ends exactly where the replies are
        // stamped.
        if obs_on {
            bm.obs().emit(crate::obs::Event::span_begin(
                crate::obs::EventKind::RefreshStall,
                track,
                bm.obs_now_us(),
                plan.ops_due,
                0,
            ));
        }
        std::thread::sleep(stall);
        if obs_on {
            bm.add_obs_lag(stall_us);
            bm.obs().emit(crate::obs::Event::span_end(
                crate::obs::EventKind::RefreshStall,
                track,
                bm.obs_now_us(),
                plan.ops_due,
                0,
            ));
        }
    }

    if obs_on {
        // zero-width under the virtual clock: modeled compute time is the
        // staged tick; the engine's wall latency never enters the trace
        let t = bm.obs_now_us();
        bm.obs().emit(crate::obs::Event::span_begin(
            crate::obs::EventKind::Infer,
            track,
            t,
            real as u64,
            0,
        ));
        bm.obs().emit(crate::obs::Event::span_end(
            crate::obs::EventKind::Infer,
            track,
            t,
            real as u64,
            0,
        ));
    }

    match engine.infer_rows(&staged, real) {
        Ok(classes) => {
            for (i, job) in group.into_iter().enumerate() {
                let latency = job.submitted.elapsed();
                metrics.record_latency(latency);
                metrics.record_refresh_stall(if cfg.dispatch == DispatchMode::Oblivious {
                    stall_us
                } else {
                    0.0
                });
                if obs_on {
                    bm.obs().emit(crate::obs::Event::instant(
                        crate::obs::EventKind::Reply,
                        track,
                        bm.obs_now_us(),
                        job.id,
                        0,
                    ));
                }
                let _ = job.reply.send(Ok((classes[i], latency)));
            }
            if cfg.dispatch == DispatchMode::RefreshAware && !stall.is_zero() {
                // refresh-aware: the same stall is paid *after* the replies
                // left, absorbed into the inter-window slack the planner
                // computed — off every request's critical path. The trace
                // shows the slack span starting at the reply timestamp.
                if obs_on {
                    bm.obs().emit(crate::obs::Event::span_begin(
                        crate::obs::EventKind::RefreshSlack,
                        track,
                        bm.obs_now_us(),
                        plan.ops_due,
                        0,
                    ));
                }
                std::thread::sleep(stall);
                metrics.record_refresh_slack(stall_us);
                if obs_on {
                    bm.add_obs_lag(stall_us);
                    bm.obs().emit(crate::obs::Event::span_end(
                        crate::obs::EventKind::RefreshSlack,
                        track,
                        bm.obs_now_us(),
                        plan.ops_due,
                        0,
                    ));
                }
            }
            false
        }
        Err(e) => {
            // answer every request in the group with the error — exactly
            // once, never a dropped channel
            let msg = format!("inference failed: {e:#}");
            let fatal = msg.contains(crate::faults::FATAL_MARKER);
            for job in group {
                metrics.record_error();
                if obs_on {
                    bm.obs().emit(crate::obs::Event::instant(
                        crate::obs::EventKind::Reply,
                        track,
                        bm.obs_now_us(),
                        job.id,
                        1,
                    ));
                }
                let _ = job.reply.send(Err(anyhow::anyhow!("{msg}")));
            }
            fatal
        }
    }
}

/// Fatal-crash exit path: leave the live set, then re-route everything this
/// worker still holds (un-served jobs from its window plus its queue) to
/// the survivors — they may be in untimed parks and would otherwise never
/// look at a dead peer's queue. With no survivors the jobs stay parked in
/// the dead queue for shutdown's error drain.
fn abandon_worker(k: usize, shared: &Shared, in_hand: Vec<Job>) {
    shared.slots[k].live.store(false, Ordering::SeqCst);
    shared.alive.fetch_sub(1, Ordering::SeqCst);
    // jobs already popped from a queue re-enter one: re-count them
    shared.depth.fetch_add(in_hand.len(), Ordering::Relaxed);
    let queued: Vec<Job> = shared.slots[k].q.lock().unwrap().drain(..).collect();
    for job in in_hand.into_iter().chain(queued) {
        match shared.route_live(k + 1) {
            Some(t) => shared.push_job(t, job),
            None => shared.slots[k].q.lock().unwrap().push_back(job),
        }
    }
}

fn worker_loop(
    k: usize,
    shared: Arc<Shared>,
    cfg: PoolConfig,
    mut engine: Box<dyn InferEngine>,
    mut bm: BufferManager,
) -> WorkerReport {
    let mut metrics = Metrics::default();
    let batch = engine.batch();
    let dim = engine.dim();
    let stage = bm.alloc(batch * dim).expect("stage capacity validated at start");
    // reused staging scratch: the submit → serve hot path allocates only
    // the per-request row and reply channel
    let mut x: Vec<i8> = Vec::with_capacity(batch * dim);

    'serve: while let Some(first) = shared.next_job(k) {
        let mut pending = vec![first];
        let deadline = Instant::now() + cfg.batch_window;
        while pending.len() < batch {
            if let Some(j) = shared.try_pop(k) {
                pending.push(j);
                continue;
            }
            let now = Instant::now();
            if now >= deadline || shared.closed.load(Ordering::SeqCst) {
                break;
            }
            // park on our own condvar for the remaining window (capped so
            // the steal path is re-checked — peer pushes don't signal us)
            let slot = &shared.slots[k];
            let mut q = slot.q.lock().unwrap();
            if q.is_empty() {
                slot.parked.store(true, Ordering::SeqCst);
                q = slot.cv.wait_timeout(q, (deadline - now).min(FILL_POLL)).unwrap().0;
                slot.parked.store(false, Ordering::SeqCst);
            }
            if let Some(j) = q.pop_front() {
                shared.depth.fetch_sub(1, Ordering::Relaxed);
                pending.push(j);
            }
        }

        // continuous batching: merge same-width requests into one staged
        // pass each. The sort is stable, so arrival order survives within
        // a width class.
        pending.sort_by_key(|j| j.row.len());
        let mut jobs = VecDeque::from(pending);
        while !jobs.is_empty() {
            let width = jobs[0].row.len();
            let n = jobs.iter().take_while(|j| j.row.len() == width).count();
            let group: Vec<Job> = jobs.drain(..n).collect();
            let fatal =
                serve_group(group, engine.as_mut(), &mut bm, stage, &cfg, &mut metrics, &mut x);
            if fatal {
                // the engine is gone for good: hand the rest of the window
                // and our queue to the survivors, leave the live set, exit
                abandon_worker(k, &shared, jobs.into_iter().collect());
                break 'serve;
            }
        }
    }

    WorkerReport { metrics, shard_meters: bm.mem.shard_meters() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(workers: usize, shards: usize) -> PoolConfig {
        PoolConfig {
            backend: BackendSpec::Sram,
            workers,
            shards,
            buffer_bytes: shards * 16 * 1024,
            high_water: 10_000,
            seed: 11,
            ..PoolConfig::default()
        }
    }

    fn fast_engines(workers: usize) -> Vec<Box<dyn InferEngine>> {
        (0..workers)
            .map(|_| {
                Box::new(SyntheticEngine { exec_latency: Duration::ZERO, ..Default::default() })
                    as Box<dyn InferEngine>
            })
            .collect()
    }

    #[test]
    fn classify_roundtrips_deterministically() {
        let pool =
            WorkerPool::start_with_engines(quick_cfg(2, 2), fast_engines(2)).unwrap();
        let row = vec![5i8; 784];
        let (a, _) = pool.classify(row.clone()).unwrap();
        let (b, _) = pool.classify(row).unwrap();
        assert_eq!(a, b, "same row, same class");
        let stats = pool.shutdown();
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.shards.len(), 2);
    }

    #[test]
    fn geometry_validation() {
        assert!(WorkerPool::start_with_engines(quick_cfg(0, 1), fast_engines(0)).is_err());
        // fewer shards than workers
        assert!(WorkerPool::start_with_engines(quick_cfg(4, 2), fast_engines(4)).is_err());
        // indivisible buffer
        let mut cfg = quick_cfg(1, 3);
        cfg.buffer_bytes = 100_000;
        assert!(WorkerPool::start_with_engines(cfg, fast_engines(1)).is_err());
    }

    #[test]
    fn custom_buffers_thread_through_the_pool() {
        // the start_with_buffers hook: callers can hand the pool arbitrary
        // pre-built buffers (how sim::trace records serving traffic)
        let cfg = quick_cfg(2, 2);
        let buffers: Vec<BufferManager> = (0..2)
            .map(|k| BufferManager::from_spec(&BackendSpec::Sram, 16 * 1024, k as u64))
            .collect();
        let pool = WorkerPool::start_with_buffers(cfg, fast_engines(2), buffers).unwrap();
        let (a, _) = pool.classify(vec![3i8; 784]).unwrap();
        let (b, _) = pool.classify(vec![3i8; 784]).unwrap();
        assert_eq!(a, b);
        let stats = pool.shutdown();
        assert_eq!(stats.requests, 2);
        // one buffer manager per worker must be enforced
        let short: Vec<BufferManager> =
            vec![BufferManager::from_spec(&BackendSpec::Sram, 16 * 1024, 9)];
        assert!(WorkerPool::start_with_buffers(quick_cfg(2, 2), fast_engines(2), short).is_err());
    }

    fn crash_engine(k: u64) -> Box<dyn InferEngine> {
        let plan: crate::faults::FaultPlan = format!("engine-crash@{k}").parse().unwrap();
        Box::new(crate::faults::FaultyEngine::wrap(
            Box::new(SyntheticEngine { exec_latency: Duration::ZERO, ..Default::default() }),
            &plan,
        ))
    }

    /// Poll until the live-worker count reaches `want` (crash propagation
    /// is asynchronous: the worker decrements on its way out).
    fn wait_alive(pool: &WorkerPool, want: usize) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while pool.alive_workers() != want {
            assert!(Instant::now() < deadline, "alive never reached {want}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn fatal_crash_degrades_the_pool_without_losing_replies() {
        // worker 0's engine dies fatally on its first batch; worker 1 is
        // healthy. Every submitted request must still be answered exactly
        // once, and the pool must keep serving on the survivor.
        let pool = WorkerPool::start_with_engines(
            quick_cfg(2, 2),
            vec![crash_engine(1), fast_engines(1).pop().unwrap()],
        )
        .unwrap();
        let rxs: Vec<_> = (0..16).map(|_| pool.submit(vec![7i8; 784]).unwrap()).collect();
        wait_alive(&pool, 1);
        // the degraded pool still classifies (the dying worker's hand-off
        // and stealing route around the dead queue)
        let (_, _) = pool.classify(vec![9i8; 784]).unwrap();
        let replies: Vec<_> = rxs.into_iter().map(|rx| rx.recv()).collect();
        let lost = replies.iter().filter(|r| r.is_err()).count();
        assert_eq!(lost, 0, "a dropped reply channel means a lost request");
        assert!(
            replies.iter().any(|r| matches!(r, Ok(Err(_)))),
            "the crashed batch must surface as error replies"
        );
        let stats = pool.shutdown();
        assert_eq!(stats.requests + stats.errors, 17, "all submissions accounted for");
    }

    #[test]
    fn total_engine_loss_closes_admission_and_drains_the_queue() {
        // a lone worker crashes on its first batch: the jobs it held get
        // error replies from the worker, everything still queued is drained
        // with error replies at shutdown, and new submissions are refused.
        let pool = WorkerPool::start_with_engines(quick_cfg(1, 1), vec![crash_engine(1)]).unwrap();
        let rxs: Vec<_> = (0..12).map(|_| pool.submit(vec![3i8; 784]).unwrap()).collect();
        wait_alive(&pool, 0);
        assert!(
            matches!(pool.submit(vec![1i8; 784]), Err(SubmitError::Closed)),
            "a pool with no live workers must refuse admission"
        );
        let stats = pool.shutdown();
        let replies: Vec<_> = rxs.into_iter().map(|rx| rx.recv()).collect();
        assert!(replies.iter().all(|r| r.is_ok()), "every request gets exactly one reply");
        assert!(replies.iter().all(|r| matches!(r, Ok(Err(_)))), "none could be served");
        assert_eq!(stats.errors, 12, "crashed-batch + drained errors cover every request");
    }

    #[test]
    fn shard_slices_cover_all_shards() {
        // 5 shards over 2 workers: 3 + 2
        let mut cfg = quick_cfg(2, 5);
        cfg.buffer_bytes = 5 * 16 * 1024;
        let pool = WorkerPool::start_with_engines(cfg, fast_engines(2)).unwrap();
        let _ = pool.classify(vec![1i8; 784]).unwrap();
        let stats = pool.shutdown();
        assert_eq!(stats.shards.len(), 5);
        let by_worker: Vec<usize> =
            (0..2).map(|w| stats.shards.iter().filter(|s| s.worker == w).count()).collect();
        assert_eq!(by_worker, vec![3, 2]);
    }

    /// Engine that records the row count of every `infer_rows` call and can
    /// gate its first call open so a test can queue work behind it.
    struct GroupingProbe {
        calls: Arc<Mutex<Vec<usize>>>,
        gate: Arc<(Mutex<bool>, Condvar)>,
        gated_once: bool,
    }

    impl InferEngine for GroupingProbe {
        fn batch(&self) -> usize {
            4
        }
        fn dim(&self) -> usize {
            32
        }
        fn infer(&mut self, x: &[i8]) -> Result<Vec<usize>> {
            self.infer_rows(x, 4)
        }
        fn supports_partial(&self) -> bool {
            true
        }
        fn infer_rows(&mut self, x: &[i8], rows: usize) -> Result<Vec<usize>> {
            assert_eq!(x.len(), rows * 32);
            self.calls.lock().unwrap().push(rows);
            if !self.gated_once {
                self.gated_once = true;
                let (mx, cv) = &*self.gate;
                let mut open = mx.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            }
            Ok(vec![0; rows])
        }
    }

    #[test]
    fn windows_merge_per_width_groups_without_padding() {
        // block the worker on a first request, queue four more with two
        // distinct widths, release: the worker must drain the window as
        // exactly two partial passes (one per width), not four padded ones.
        let calls = Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let engine = GroupingProbe { calls: Arc::clone(&calls), gate: Arc::clone(&gate), gated_once: false };
        let mut cfg = quick_cfg(1, 1);
        cfg.batch_window = Duration::from_millis(50);
        let pool = WorkerPool::start_with_engines(cfg, vec![Box::new(engine)]).unwrap();

        let blocker = pool.submit(vec![1i8; 32]).unwrap();
        // wait until the worker is inside the gated first call
        let deadline = Instant::now() + Duration::from_secs(5);
        while calls.lock().unwrap().is_empty() {
            assert!(Instant::now() < deadline, "worker never reached the engine");
            std::thread::sleep(Duration::from_millis(1));
        }
        let queued: Vec<_> = [16usize, 32, 16, 32]
            .iter()
            .map(|&w| pool.submit(vec![2i8; w]).unwrap())
            .collect();
        {
            let (mx, cv) = &*gate;
            *mx.lock().unwrap() = true;
            cv.notify_all();
        }
        for rx in std::iter::once(blocker).chain(queued) {
            rx.recv().unwrap().unwrap();
        }
        let stats = pool.shutdown();
        assert_eq!(stats.requests, 5);
        // first call: the gated single request; then the four queued jobs
        // grouped by width — narrow rows first (stable sort by width)
        assert_eq!(*calls.lock().unwrap(), vec![1, 2, 2]);
        // partial-capable engine ⇒ no padded slots reported at all
        assert_eq!(stats.occupancy, 1.0);
    }

    #[test]
    fn refresh_stall_lands_on_requests_only_in_oblivious_mode() {
        // same backend, same traffic, refresh stall modeled at 2 µs/slot:
        // the oblivious dispatcher charges the stall to request latency,
        // the aware one records it as slack and keeps requests clean. The
        // virtual refresh meters must agree bit-for-bit.
        let run = |dispatch: DispatchMode| {
            let cfg = PoolConfig {
                backend: BackendSpec::mcaimem_default(),
                workers: 1,
                shards: 1,
                buffer_bytes: 256 * 1024,
                high_water: 10_000,
                dispatch,
                refresh_stall: Duration::from_micros(2),
                seed: 77,
                ..PoolConfig::default()
            };
            let pool = WorkerPool::start_with_engines(cfg, fast_engines(1)).unwrap();
            for i in 0..8 {
                pool.classify(vec![i as i8; 784]).unwrap();
            }
            pool.shutdown()
        };
        let obl = run(DispatchMode::Oblivious);
        let aware = run(DispatchMode::RefreshAware);
        // mcaimem at sim_compute_s = 2 µs fires ~40 slots per window: the
        // oblivious tier must attribute stall to requests, the aware one
        // must not — it reports the same time as slack instead
        assert!(obl.refresh_stall_p999_us > 0.0, "oblivious stall must hit the tail");
        assert_eq!(aware.refresh_stall_p999_us, 0.0, "aware requests must see zero stall");
        assert!(aware.refresh_slack_total_us > 0.0, "the stall is paid in slack instead");
        // identical virtual schedule: same refresh count on the meters
        let refreshes = |s: &ServerStats| s.shards.iter().map(|sh| sh.refreshes).sum::<u64>();
        assert_eq!(refreshes(&obl), refreshes(&aware), "modes must not change the schedule");
    }

    #[test]
    fn tracing_is_inert_and_places_stall_spans_by_dispatch_mode() {
        use crate::obs::{EventKind, ObsSink, Ph, TRACK_POOL};
        // one run per (mode, sink): the traced run must leave every virtual
        // meter bit-identical to the untraced one, and its trace must put
        // refresh-stall spans on the request path (ending at the reply
        // stamp) under oblivious dispatch, but slack spans *after* the
        // replies under refresh-aware dispatch.
        let run = |dispatch: DispatchMode, obs: ObsSink| {
            let cfg = PoolConfig {
                backend: BackendSpec::mcaimem_default(),
                workers: 1,
                shards: 1,
                buffer_bytes: 256 * 1024,
                high_water: 10_000,
                dispatch,
                refresh_stall: Duration::from_micros(2),
                seed: 77,
                obs,
                ..PoolConfig::default()
            };
            let pool = WorkerPool::start_with_engines(cfg, fast_engines(1)).unwrap();
            for i in 0..8 {
                pool.classify(vec![i as i8; 784]).unwrap();
            }
            pool.shutdown()
        };
        for mode in [DispatchMode::Oblivious, DispatchMode::RefreshAware] {
            let sink = ObsSink::enabled(1 << 14);
            let traced = run(mode, sink.clone());
            let plain = run(mode, ObsSink::disabled());
            // bit-identical meters: tracing must not perturb the simulation
            assert_eq!(traced.requests, plain.requests);
            let energies = |s: &ServerStats| {
                s.shards.iter().map(|sh| sh.energy_j.to_bits()).collect::<Vec<_>>()
            };
            assert_eq!(energies(&traced), energies(&plain), "{mode:?}: meters must be bit-identical");
            let refreshes = |s: &ServerStats| s.shards.iter().map(|sh| sh.refreshes).sum::<u64>();
            assert_eq!(refreshes(&traced), refreshes(&plain), "{mode:?}");

            let events = sink.events();
            assert_eq!(sink.dropped_events(), 0, "ring sized for the run");
            // every serving event type shows up
            let count =
                |k: EventKind| events.iter().filter(|(_, e)| e.kind == k).count();
            assert_eq!(count(EventKind::Admit), 8);
            assert_eq!(count(EventKind::Reply), 8);
            assert!(count(EventKind::Stage) >= 2, "stage begin/end pairs");
            assert!(count(EventKind::RefreshPass) >= 2, "refresh fires in every window");
            // admit instants live on the pool track with the logical timebase
            for (_, e) in events.iter().filter(|(_, e)| e.kind == EventKind::Admit) {
                assert_eq!(e.track, TRACK_POOL);
                assert_eq!(e.t_us, e.a as f64, "pool track time is the admission seq");
            }
            let replies: Vec<f64> = events
                .iter()
                .filter(|(_, e)| e.kind == EventKind::Reply)
                .map(|(_, e)| e.t_us)
                .collect();
            match mode {
                DispatchMode::Oblivious => {
                    let stall_ends: Vec<f64> = events
                        .iter()
                        .filter(|(_, e)| e.kind == EventKind::RefreshStall && e.ph == Ph::E)
                        .map(|(_, e)| e.t_us)
                        .collect();
                    assert!(!stall_ends.is_empty(), "oblivious must trace stall spans");
                    assert_eq!(count(EventKind::RefreshSlack), 0);
                    // the stall ends exactly where its window's replies are
                    // stamped: on the request path
                    for t in &stall_ends {
                        assert!(
                            replies.iter().any(|r| (r - t).abs() < 1e-9),
                            "stall end {t} must coincide with a reply"
                        );
                    }
                }
                DispatchMode::RefreshAware => {
                    let slack_begins: Vec<f64> = events
                        .iter()
                        .filter(|(_, e)| e.kind == EventKind::RefreshSlack && e.ph == Ph::B)
                        .map(|(_, e)| e.t_us)
                        .collect();
                    assert!(!slack_begins.is_empty(), "aware must trace slack spans");
                    assert_eq!(count(EventKind::RefreshStall), 0);
                    // slack starts at the reply stamp — the stall is paid
                    // after the replies left, in inter-window slack
                    for t in &slack_begins {
                        assert!(
                            replies.iter().any(|r| (r - t).abs() < 1e-9),
                            "slack begin {t} must start at a reply stamp"
                        );
                    }
                }
            }
            // the worker track stays monotone despite the lag offsets
            let mut worker_ts: Vec<(u64, f64)> = events
                .iter()
                .filter(|(_, e)| e.track == crate::obs::worker_track(0))
                .map(|&(ticket, e)| (ticket, e.t_us))
                .collect();
            worker_ts.sort_by_key(|&(ticket, _)| ticket);
            for w in worker_ts.windows(2) {
                assert!(w[1].1 >= w[0].1 - 1e-9, "worker track must be monotone in emission order");
            }
        }
    }
}
