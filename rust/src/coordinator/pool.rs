//! Sharded multi-worker serving tier — the production-scale front-end.
//!
//! Replaces the one-thread/one-buffer/one-queue server for heavy traffic:
//! K workers each own an inference engine plus a [`BufferManager`] over
//! their slice of the tier's N bank shards (a [`ShardedBackend`] stripe —
//! per-shard meters, staggered refresh), fed by a bounded work-stealing
//! queue with admission control:
//!
//! * **Work stealing** — each worker has its own deque; submissions land
//!   round-robin, a worker drains its own deque front-first and steals from
//!   the *back* of its neighbours when idle, so a slow worker cannot
//!   strand queued requests.
//! * **Admission control** — when total queue depth reaches the
//!   `high_water` mark, `submit` refuses with a retry-after hint instead of
//!   letting the queue grow without bound (reject-with-retry-after beats
//!   unbounded latency collapse under overload). The mark is advisory:
//!   concurrent submitters may overshoot it by a few requests.
//! * **Exactly-once replies** — every accepted request is answered exactly
//!   once: with its class on success, or with the batch's inference error
//!   on failure (never a silently dropped channel).
//! * **Graceful degradation** — an inference error carrying
//!   [`crate::faults::FATAL_MARKER`] is unrecoverable for that worker: it
//!   answers its in-flight batch with errors, leaves the pool's live set,
//!   and exits. Admission then scales the high-water mark by the surviving
//!   capacity (never below one batch), peers steal the dead worker's queued
//!   jobs, and once *every* worker has died `submit` refuses with `Closed`
//!   while [`WorkerPool::shutdown`] drains any stranded jobs with error
//!   replies — the exactly-once guarantee holds through total engine loss.
//!
//! Engines: with PJRT artifacts each worker owns a [`ModelRunner`]; without
//! them a [`SyntheticEngine`] classifies deterministically while *really*
//! blocking for the configured accelerator execution latency — so the tier
//! is latency-bound exactly like a PJRT-backed worker, and multi-worker
//! scaling measures true pipeline parallelism, not an idle spin. In both
//! cases every request's payload is staged through the worker's buffer
//! shard (store → compute tick → load), so the chosen memory technology
//! sees the real serving traffic: occupancy, refresh and energy all accrue
//! on the per-shard meters surfaced in [`ServerStats::shards`].

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::buffer_manager::BufferManager;
use super::metrics::Metrics;
use super::server::{Reply, ServerStats, ShardStat};
use crate::mem::backend::BackendSpec;
use crate::mem::mcaimem::EnergyMeter;
use crate::runtime::executor::ModelRunner;
use crate::util::rng::{shard_seeds, Pcg64};

/// Serving-tier configuration.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Buffer technology every shard is built from.
    pub backend: BackendSpec,
    /// Worker threads (each owns an engine + its shard slice).
    pub workers: usize,
    /// Bank shards striped across the tier (`shards >= workers`; shards
    /// are dealt to workers round-robin, remainder to the first workers).
    pub shards: usize,
    /// Total buffer capacity across all shards (must divide by `shards`).
    pub buffer_bytes: usize,
    /// Batching window: how long a worker waits to fill a batch.
    pub batch_window: Duration,
    /// Admission high-water mark: total queued requests at or above this
    /// are rejected with a retry-after hint.
    pub high_water: usize,
    /// Virtual buffer-clock advance per executed batch (refresh slots fire,
    /// static energy integrates).
    pub sim_compute_s: f64,
    /// Retention-flip probability fed to aged (PJRT) engines.
    pub flip_p: f64,
    /// Per-batch service-time estimate (µs) scaling the retry-after hint.
    pub est_service_us: u64,
    pub seed: u64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            backend: BackendSpec::mcaimem_default(),
            workers: 1,
            shards: 1,
            buffer_bytes: 256 * 1024,
            batch_window: Duration::from_micros(200),
            high_water: 256,
            sim_compute_s: 2e-6,
            flip_p: 0.01,
            est_service_us: 300,
            seed: 0xD00D,
        }
    }
}

/// Why a submission was refused.
#[derive(Debug)]
pub enum SubmitError {
    /// Queue depth at/above the high-water mark: try again after the hint.
    Rejected { depth: usize, retry_after: Duration },
    /// The pool has shut down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Rejected { depth, retry_after } => write!(
                f,
                "admission refused: queue depth {depth}, retry after {:.1} ms",
                retry_after.as_secs_f64() * 1e3
            ),
            SubmitError::Closed => write!(f, "pool closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One worker's inference engine: turns a staged `batch × dim` int8 tensor
/// into per-row class indices.
pub trait InferEngine: Send {
    /// Rows per executed batch.
    fn batch(&self) -> usize;
    /// Bytes per row.
    fn dim(&self) -> usize;
    fn infer(&mut self, x: &[i8]) -> Result<Vec<usize>>;
}

/// PJRT-less engine: a deterministic classifier plus a *real* block for the
/// modeled accelerator execution latency, so pool throughput is
/// latency-bound the way a PJRT-backed worker is. The classifier is a
/// stable byte hash — meaningless labels, but bit-reproducible, which is
/// what the serving-tier tests need.
pub struct SyntheticEngine {
    pub batch: usize,
    pub dim: usize,
    pub classes: usize,
    /// Modeled accelerator execution latency per batch (really slept).
    pub exec_latency: Duration,
}

impl Default for SyntheticEngine {
    fn default() -> Self {
        SyntheticEngine {
            batch: 4,
            dim: 784,
            classes: 10,
            exec_latency: Duration::from_micros(250),
        }
    }
}

impl InferEngine for SyntheticEngine {
    fn batch(&self) -> usize {
        self.batch
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn infer(&mut self, x: &[i8]) -> Result<Vec<usize>> {
        anyhow::ensure!(x.len() == self.batch * self.dim, "batch shape mismatch");
        if !self.exec_latency.is_zero() {
            std::thread::sleep(self.exec_latency);
        }
        Ok(x.chunks(self.dim)
            .map(|row| {
                let mut h = 0xcbf2_9ce4_8422_2325u64;
                for &v in row {
                    h = (h ^ v as u8 as u64).wrapping_mul(0x0000_0100_0000_01B3);
                }
                (h % self.classes as u64) as usize
            })
            .collect())
    }
}

/// PJRT-backed engine: one [`ModelRunner`] per worker (executables are not
/// `Sync`), serving the aged model for the pool's backend spec.
pub struct PjrtEngine {
    runner: ModelRunner,
    spec: BackendSpec,
    flip_p: f64,
    rng: Pcg64,
}

impl PjrtEngine {
    pub fn new(dir: &std::path::Path, spec: BackendSpec, flip_p: f64, seed: u64) -> Result<Self> {
        Ok(PjrtEngine { runner: ModelRunner::new(dir)?, spec, flip_p, rng: Pcg64::new(seed) })
    }
}

impl InferEngine for PjrtEngine {
    fn batch(&self) -> usize {
        self.runner.artifacts.batch
    }

    fn dim(&self) -> usize {
        self.runner.artifacts.input_dim
    }

    fn infer(&mut self, x: &[i8]) -> Result<Vec<usize>> {
        self.runner.infer(x, &self.spec, self.flip_p, &mut self.rng)
    }
}

struct Job {
    row: Vec<i8>,
    submitted: Instant,
    reply: mpsc::Sender<Reply>,
}

struct Shared {
    /// One deque per worker (owner pops the front, thieves pop the back).
    queues: Vec<Mutex<VecDeque<Job>>>,
    /// Total queued (not yet popped) requests — the admission signal.
    depth: AtomicUsize,
    closed: AtomicBool,
    sleep_mx: Mutex<()>,
    cv: Condvar,
    rejected: AtomicU64,
    /// Queue depth sampled at every accepted submit (for the p99 readout).
    depth_samples: Mutex<Vec<f64>>,
    rr: AtomicUsize,
    /// Workers still serving. A fatally-crashed worker decrements this on
    /// the way out; admission scales its high-water mark by `alive/workers`
    /// and closes entirely at zero.
    alive: AtomicUsize,
}

impl Shared {
    fn try_pop(&self, k: usize) -> Option<Job> {
        if let Some(j) = self.queues[k].lock().unwrap().pop_front() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            return Some(j);
        }
        let n = self.queues.len();
        for i in 1..n {
            if let Some(j) = self.queues[(k + i) % n].lock().unwrap().pop_back() {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                return Some(j);
            }
        }
        None
    }

    /// Block until a job is available; `None` once the pool is closed and
    /// every queue has drained.
    fn pop_or_wait(&self, k: usize) -> Option<Job> {
        loop {
            if let Some(j) = self.try_pop(k) {
                return Some(j);
            }
            if self.closed.load(Ordering::SeqCst) {
                // final drain check: a job may have landed between the pop
                // and the flag read
                return self.try_pop(k);
            }
            let guard = self.sleep_mx.lock().unwrap();
            // the 1 ms timeout bounds any missed-wakeup window
            let _ = self.cv.wait_timeout(guard, Duration::from_millis(1)).unwrap();
        }
    }
}

struct WorkerReport {
    metrics: Metrics,
    shard_meters: Vec<EnergyMeter>,
}

/// Handle to the running serving tier.
pub struct WorkerPool {
    shared: Arc<Shared>,
    cfg: PoolConfig,
    batch: usize,
    workers: Vec<JoinHandle<WorkerReport>>,
}

impl WorkerPool {
    /// Start with PJRT engines when `artifacts` holds a usable export,
    /// falling back to [`SyntheticEngine`]s (with a note) otherwise — the
    /// path `mcaimem serve` takes.
    pub fn start_with_artifacts(cfg: PoolConfig, artifacts: Option<PathBuf>) -> Result<WorkerPool> {
        let seeds = shard_seeds(cfg.seed ^ 0xE4617E, cfg.workers.max(1));
        if let Some(dir) = artifacts {
            match PjrtEngine::new(&dir, cfg.backend.clone(), cfg.flip_p, seeds[0]) {
                Ok(first) => {
                    let mut engines: Vec<Box<dyn InferEngine>> = vec![Box::new(first)];
                    for &s in &seeds[1..] {
                        engines.push(Box::new(PjrtEngine::new(&dir, cfg.backend.clone(), cfg.flip_p, s)?));
                    }
                    return Self::start_with_engines(cfg, engines);
                }
                Err(e) => {
                    eprintln!("pool: PJRT unavailable ({e:#}); using the synthetic engine");
                }
            }
        }
        Self::start(cfg)
    }

    /// Start with default [`SyntheticEngine`]s (no artifacts needed).
    pub fn start(cfg: PoolConfig) -> Result<WorkerPool> {
        let engines =
            (0..cfg.workers).map(|_| Box::new(SyntheticEngine::default()) as Box<dyn InferEngine>);
        Self::start_with_engines(cfg, engines.collect())
    }

    /// Start with one pre-built engine per worker (tests inject failing or
    /// gated engines here). Builds each worker's sharded buffer slice from
    /// the config and delegates to [`Self::start_with_buffers`].
    pub fn start_with_engines(
        cfg: PoolConfig,
        engines: Vec<Box<dyn InferEngine>>,
    ) -> Result<WorkerPool> {
        if cfg.workers == 0 {
            bail!("pool needs at least one worker");
        }
        // fast-fail before paying per-worker buffer construction (mcaimem
        // backends sample O(capacity) leakage corners per worker)
        if engines.len() != cfg.workers {
            bail!("{} engines for {} workers", engines.len(), cfg.workers);
        }
        if cfg.shards < cfg.workers {
            bail!(
                "{} shards cannot feed {} workers (need shards >= workers)",
                cfg.shards,
                cfg.workers
            );
        }
        if cfg.buffer_bytes % cfg.shards != 0 {
            bail!("buffer bytes {} not divisible by {} shards", cfg.buffer_bytes, cfg.shards);
        }
        // deal shards to workers: shards/workers each, remainder to the
        // first workers
        let base = cfg.shards / cfg.workers;
        let rem = cfg.shards % cfg.workers;
        let shard_bytes = cfg.buffer_bytes / cfg.shards;
        let seeds = shard_seeds(cfg.seed, cfg.workers);
        let buffers = (0..cfg.workers)
            .map(|k| {
                let n_k = base + usize::from(k < rem);
                BufferManager::sharded(&cfg.backend, n_k, n_k * shard_bytes, seeds[k])
            })
            .collect::<Result<Vec<_>>>()?;
        Self::start_with_buffers(cfg, engines, buffers)
    }

    /// Start with one pre-built engine AND buffer manager per worker — the
    /// general form. This is the hook that threads a recording or otherwise
    /// customized backend through the serving tier unchanged: build each
    /// worker's buffer over any [`crate::mem::backend::MemoryBackend`]
    /// (e.g. a [`crate::sim::trace::TracingBackend`]-wrapped shard stripe
    /// via `BufferManager::from_backend`) and the pool stages its real
    /// serving traffic through it.
    pub fn start_with_buffers(
        cfg: PoolConfig,
        engines: Vec<Box<dyn InferEngine>>,
        buffers: Vec<BufferManager>,
    ) -> Result<WorkerPool> {
        if cfg.workers == 0 {
            bail!("pool needs at least one worker");
        }
        if engines.len() != cfg.workers {
            bail!("{} engines for {} workers", engines.len(), cfg.workers);
        }
        if buffers.len() != cfg.workers {
            bail!("{} buffer managers for {} workers", buffers.len(), cfg.workers);
        }
        let batch = engines[0].batch();
        let shared = Arc::new(Shared {
            queues: (0..cfg.workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            depth: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            sleep_mx: Mutex::new(()),
            cv: Condvar::new(),
            rejected: AtomicU64::new(0),
            depth_samples: Mutex::new(Vec::new()),
            rr: AtomicUsize::new(0),
            alive: AtomicUsize::new(cfg.workers),
        });

        let mut workers = Vec::with_capacity(cfg.workers);
        for (k, (engine, bm)) in engines.into_iter().zip(buffers).enumerate() {
            let need = engine.batch() * engine.dim();
            if bm.capacity() < need {
                bail!(
                    "worker {k}: shard slice of {} B cannot stage a {} B batch",
                    bm.capacity(),
                    need
                );
            }
            let shared = Arc::clone(&shared);
            let cfgc = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("mcaimem-pool-{k}"))
                    .spawn(move || worker_loop(k, shared, cfgc, engine, bm))?,
            );
        }
        Ok(WorkerPool { shared, cfg, batch, workers })
    }

    /// Rows per batch of the workers' engines.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Current total queue depth (advisory).
    pub fn depth(&self) -> usize {
        self.shared.depth.load(Ordering::Relaxed)
    }

    /// Workers still serving (started workers minus fatal engine crashes).
    pub fn alive_workers(&self) -> usize {
        self.shared.alive.load(Ordering::SeqCst)
    }

    /// Submit one row. `Err(Rejected)` above the high-water mark — callers
    /// should back off for the hinted duration before retrying.
    pub fn submit(&self, row: Vec<i8>) -> std::result::Result<mpsc::Receiver<Reply>, SubmitError> {
        if self.shared.closed.load(Ordering::SeqCst) {
            return Err(SubmitError::Closed);
        }
        let alive = self.shared.alive.load(Ordering::SeqCst);
        if alive == 0 {
            // every worker's engine crashed fatally: nothing can serve, so
            // accepting would only strand the job until shutdown's drain
            return Err(SubmitError::Closed);
        }
        // degraded mode: the high-water mark tracks surviving capacity, but
        // never drops below one batch (a lone survivor must accept work);
        // a healthy pool keeps the configured mark bit-for-bit
        let high_water = if alive == self.cfg.workers {
            self.cfg.high_water
        } else {
            (self.cfg.high_water * alive / self.cfg.workers).max(self.batch)
        };
        let depth = self.shared.depth.load(Ordering::Relaxed);
        if depth >= high_water {
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            let over = (depth + 1 - high_water) as u64;
            // backlog above the mark, in batches, times the service estimate
            let us =
                (over * self.cfg.est_service_us) / (alive as u64 * self.batch as u64).max(1);
            let floor = (self.cfg.est_service_us / 2).min(50_000);
            let retry_after = Duration::from_micros(us.clamp(floor, 50_000));
            return Err(SubmitError::Rejected { depth, retry_after });
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let job = Job { row, submitted: Instant::now(), reply: reply_tx };
        let k = self.shared.rr.fetch_add(1, Ordering::Relaxed) % self.cfg.workers;
        // count the job before it becomes poppable: a fast worker popping
        // (and decrementing) between push and a late increment would wrap
        // the counter to usize::MAX
        let d = self.shared.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.shared.queues[k].lock().unwrap().push_back(job);
        self.shared.depth_samples.lock().unwrap().push(d as f64);
        self.shared.cv.notify_one();
        Ok(reply_rx)
    }

    /// Submit one row and block for its reply.
    pub fn classify(&self, row: Vec<i8>) -> Result<(usize, Duration)> {
        let rx = self.submit(row).map_err(|e| anyhow::anyhow!("{e}"))?;
        rx.recv()?
    }

    /// Stop the tier: close admission, drain every queue, join the workers
    /// and aggregate their metrics plus the per-shard meter break-down.
    pub fn shutdown(self) -> ServerStats {
        self.shared.closed.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        let mut merged = Metrics::default();
        let mut shards = Vec::new();
        for (k, w) in self.workers.into_iter().enumerate() {
            let report = w.join().unwrap_or_else(|_| WorkerReport {
                metrics: Metrics::default(),
                shard_meters: Vec::new(),
            });
            merged.merge(&report.metrics);
            for m in report.shard_meters {
                shards.push((k, m));
            }
        }
        // jobs can be stranded only when workers crashed fatally before the
        // close (nobody left to pop or steal); answer them here so every
        // accepted request still gets exactly one reply
        for q in &self.shared.queues {
            let mut q = q.lock().unwrap();
            while let Some(job) = q.pop_front() {
                self.shared.depth.fetch_sub(1, Ordering::Relaxed);
                merged.record_error();
                let _ = job
                    .reply
                    .send(Err(anyhow::anyhow!("pool shut down before the request was served")));
            }
        }
        let total_rw: u64 = shards
            .iter()
            .map(|(_, m)| m.bytes_read + m.bytes_written)
            .sum();
        let mut stats = ServerStats::from_metrics(&merged);
        stats.rejected = self.shared.rejected.load(Ordering::Relaxed);
        stats.queue_depth_p99 = {
            let mut xs = self.shared.depth_samples.lock().unwrap().clone();
            if xs.is_empty() {
                0.0
            } else {
                xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                crate::util::stats::percentile_sorted(&xs, 99.0)
            }
        };
        stats.shards = shards
            .into_iter()
            .enumerate()
            .map(|(i, (worker, m))| {
                let rw = m.bytes_read + m.bytes_written;
                ShardStat {
                    shard: i,
                    worker,
                    bytes_rw: rw,
                    occupancy: rw as f64 / total_rw.max(1) as f64,
                    refreshes: m.refreshes,
                    energy_j: m.total_j(),
                }
            })
            .collect();
        stats
    }
}

fn worker_loop(
    k: usize,
    shared: Arc<Shared>,
    cfg: PoolConfig,
    mut engine: Box<dyn InferEngine>,
    mut bm: BufferManager,
) -> WorkerReport {
    let mut metrics = Metrics::default();
    let batch = engine.batch();
    let dim = engine.dim();
    let stage = bm.alloc(batch * dim).expect("stage capacity validated at start");

    while let Some(first) = shared.pop_or_wait(k) {
        let mut pending = vec![first];
        let deadline = Instant::now() + cfg.batch_window;
        while pending.len() < batch {
            if let Some(j) = shared.try_pop(k) {
                pending.push(j);
                continue;
            }
            let now = Instant::now();
            if now >= deadline || shared.closed.load(Ordering::SeqCst) {
                break;
            }
            let guard = shared.sleep_mx.lock().unwrap();
            let _ = shared
                .cv
                .wait_timeout(guard, (deadline - now).min(Duration::from_micros(200)))
                .unwrap();
        }

        // assemble the padded batch
        let real = pending.len();
        let mut x = vec![0i8; batch * dim];
        for (i, job) in pending.iter().enumerate() {
            let n = job.row.len().min(dim);
            for (dstv, &srcv) in x[i * dim..i * dim + n].iter_mut().zip(&job.row[..n]) {
                *dstv = srcv;
            }
            metrics.record_bytes_in(n);
        }
        metrics.record_batch(real, batch);

        // stage the batch through this worker's buffer shards: the memory
        // technology sees the serving traffic (store → compute → load)
        let bytes: Vec<u8> = x.iter().map(|&v| v as u8).collect();
        let staged = match bm.store(stage, &bytes) {
            Ok(()) => {
                bm.tick(cfg.sim_compute_s);
                bm.load(stage)
            }
            Err(_) => bytes, // sizes are validated at start; defensive only
        };
        let staged_i8: Vec<i8> = staged.iter().map(|&b| b as i8).collect();

        match engine.infer(&staged_i8) {
            Ok(classes) => {
                for (i, job) in pending.into_iter().enumerate() {
                    let latency = job.submitted.elapsed();
                    metrics.record_latency(latency);
                    let _ = job.reply.send(Ok((classes[i], latency)));
                }
            }
            Err(e) => {
                // answer every pending request with the error — exactly
                // once, never a dropped channel
                let msg = format!("inference failed: {e:#}");
                let fatal = msg.contains(crate::faults::FATAL_MARKER);
                for job in pending {
                    metrics.record_error();
                    let _ = job.reply.send(Err(anyhow::anyhow!("{msg}")));
                }
                if fatal {
                    // the engine is gone for good: leave the live set and
                    // exit. Already-queued jobs survive — peers steal them,
                    // and shutdown drains any leftovers once everyone dies.
                    shared.alive.fetch_sub(1, Ordering::SeqCst);
                    break;
                }
            }
        }
    }

    WorkerReport { metrics, shard_meters: bm.mem.shard_meters() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(workers: usize, shards: usize) -> PoolConfig {
        PoolConfig {
            backend: BackendSpec::Sram,
            workers,
            shards,
            buffer_bytes: shards * 16 * 1024,
            high_water: 10_000,
            seed: 11,
            ..PoolConfig::default()
        }
    }

    fn fast_engines(workers: usize) -> Vec<Box<dyn InferEngine>> {
        (0..workers)
            .map(|_| {
                Box::new(SyntheticEngine { exec_latency: Duration::ZERO, ..Default::default() })
                    as Box<dyn InferEngine>
            })
            .collect()
    }

    #[test]
    fn classify_roundtrips_deterministically() {
        let pool =
            WorkerPool::start_with_engines(quick_cfg(2, 2), fast_engines(2)).unwrap();
        let row = vec![5i8; 784];
        let (a, _) = pool.classify(row.clone()).unwrap();
        let (b, _) = pool.classify(row).unwrap();
        assert_eq!(a, b, "same row, same class");
        let stats = pool.shutdown();
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.shards.len(), 2);
    }

    #[test]
    fn geometry_validation() {
        assert!(WorkerPool::start_with_engines(quick_cfg(0, 1), fast_engines(0)).is_err());
        // fewer shards than workers
        assert!(WorkerPool::start_with_engines(quick_cfg(4, 2), fast_engines(4)).is_err());
        // indivisible buffer
        let mut cfg = quick_cfg(1, 3);
        cfg.buffer_bytes = 100_000;
        assert!(WorkerPool::start_with_engines(cfg, fast_engines(1)).is_err());
    }

    #[test]
    fn custom_buffers_thread_through_the_pool() {
        // the start_with_buffers hook: callers can hand the pool arbitrary
        // pre-built buffers (how sim::trace records serving traffic)
        let cfg = quick_cfg(2, 2);
        let buffers: Vec<BufferManager> = (0..2)
            .map(|k| BufferManager::from_spec(&BackendSpec::Sram, 16 * 1024, k as u64))
            .collect();
        let pool = WorkerPool::start_with_buffers(cfg, fast_engines(2), buffers).unwrap();
        let (a, _) = pool.classify(vec![3i8; 784]).unwrap();
        let (b, _) = pool.classify(vec![3i8; 784]).unwrap();
        assert_eq!(a, b);
        let stats = pool.shutdown();
        assert_eq!(stats.requests, 2);
        // one buffer manager per worker must be enforced
        let short: Vec<BufferManager> =
            vec![BufferManager::from_spec(&BackendSpec::Sram, 16 * 1024, 9)];
        assert!(WorkerPool::start_with_buffers(quick_cfg(2, 2), fast_engines(2), short).is_err());
    }

    fn crash_engine(k: u64) -> Box<dyn InferEngine> {
        let plan: crate::faults::FaultPlan = format!("engine-crash@{k}").parse().unwrap();
        Box::new(crate::faults::FaultyEngine::wrap(
            Box::new(SyntheticEngine { exec_latency: Duration::ZERO, ..Default::default() }),
            &plan,
        ))
    }

    /// Poll until the live-worker count reaches `want` (crash propagation
    /// is asynchronous: the worker decrements on its way out).
    fn wait_alive(pool: &WorkerPool, want: usize) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while pool.alive_workers() != want {
            assert!(Instant::now() < deadline, "alive never reached {want}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn fatal_crash_degrades_the_pool_without_losing_replies() {
        // worker 0's engine dies fatally on its first batch; worker 1 is
        // healthy. Every submitted request must still be answered exactly
        // once, and the pool must keep serving on the survivor.
        let pool = WorkerPool::start_with_engines(
            quick_cfg(2, 2),
            vec![crash_engine(1), fast_engines(1).pop().unwrap()],
        )
        .unwrap();
        let rxs: Vec<_> = (0..16).map(|_| pool.submit(vec![7i8; 784]).unwrap()).collect();
        wait_alive(&pool, 1);
        // the degraded pool still classifies (stealing routes around the
        // dead worker's queue)
        let (_, _) = pool.classify(vec![9i8; 784]).unwrap();
        let replies: Vec<_> = rxs.into_iter().map(|rx| rx.recv()).collect();
        let lost = replies.iter().filter(|r| r.is_err()).count();
        assert_eq!(lost, 0, "a dropped reply channel means a lost request");
        assert!(
            replies.iter().any(|r| matches!(r, Ok(Err(_)))),
            "the crashed batch must surface as error replies"
        );
        let stats = pool.shutdown();
        assert_eq!(stats.requests + stats.errors, 17, "all submissions accounted for");
    }

    #[test]
    fn total_engine_loss_closes_admission_and_drains_the_queue() {
        // a lone worker crashes on its first batch: the jobs it held get
        // error replies from the worker, everything still queued is drained
        // with error replies at shutdown, and new submissions are refused.
        let pool = WorkerPool::start_with_engines(quick_cfg(1, 1), vec![crash_engine(1)]).unwrap();
        let rxs: Vec<_> = (0..12).map(|_| pool.submit(vec![3i8; 784]).unwrap()).collect();
        wait_alive(&pool, 0);
        assert!(
            matches!(pool.submit(vec![1i8; 784]), Err(SubmitError::Closed)),
            "a pool with no live workers must refuse admission"
        );
        let stats = pool.shutdown();
        let replies: Vec<_> = rxs.into_iter().map(|rx| rx.recv()).collect();
        assert!(replies.iter().all(|r| r.is_ok()), "every request gets exactly one reply");
        assert!(replies.iter().all(|r| matches!(r, Ok(Err(_)))), "none could be served");
        assert_eq!(stats.errors, 12, "crashed-batch + drained errors cover every request");
    }

    #[test]
    fn shard_slices_cover_all_shards() {
        // 5 shards over 2 workers: 3 + 2
        let mut cfg = quick_cfg(2, 5);
        cfg.buffer_bytes = 5 * 16 * 1024;
        let pool = WorkerPool::start_with_engines(cfg, fast_engines(2)).unwrap();
        let _ = pool.classify(vec![1i8; 784]).unwrap();
        let stats = pool.shutdown();
        assert_eq!(stats.shards.len(), 5);
        let by_worker: Vec<usize> =
            (0..2).map(|w| stats.shards.iter().filter(|s| s.worker == w).count()).collect();
        assert_eq!(by_worker, vec![3, 2]);
    }
}
