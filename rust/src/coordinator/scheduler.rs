//! Event-driven inference timeline over the functional buffer.
//!
//! Drives one whole-network inference through the [`BufferManager`] on any
//! [`BackendSpec`]: weights are resident; per layer, input activations are
//! loaded, the layer "computes" for the cycle count the systolic model
//! gives it (the buffer clock advances, refresh slots fire, static energy
//! integrates), and outputs are stored. This is the event-driven
//! counterpart of the closed-form model in [`crate::energy::system_eval`],
//! and the "identical scheduler path" a backend sweep
//! (`mcaimem simulate --backend sram,edram2t,rram,mcaimem@0.8`) runs every
//! technology through; tests check the closed form and the event-driven
//! run agree on static + refresh energy to within the discretization
//! error — the cross-validation the paper's methodology implies between
//! its SPICE characterization and its SCALE-Sim system numbers.

use anyhow::Result;

use super::buffer_manager::BufferManager;
use crate::mem::backend::BackendSpec;
use crate::scalesim::accelerator::AcceleratorConfig;
use crate::scalesim::network::Network;
use crate::scalesim::systolic::layer_cost;
use crate::util::rng::Pcg64;

/// How the serving pool places eDRAM refresh stall relative to dispatched
/// batch windows (the serving-tier analogue of the paper's refresh-energy
/// argument: refresh work is unavoidable, refresh *tail latency* is not).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DispatchMode {
    /// Refresh slots that fire inside a dispatched batch window stall the
    /// window: every rider's latency absorbs the refresh pass (the naive
    /// scheduler, kept as the comparison baseline).
    Oblivious,
    /// Batch windows are planned into the slack between staggered refresh
    /// slots: replies leave first and the refresh pass is paid in
    /// inter-window slack, so no request's latency carries refresh stall.
    /// The virtual refresh schedule is identical in both modes — meters,
    /// traces and conformance replay are bit-exact regardless — only the
    /// wall-clock placement of the stall differs.
    #[default]
    RefreshAware,
}

impl std::fmt::Display for DispatchMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            DispatchMode::Oblivious => "oblivious",
            DispatchMode::RefreshAware => "aware",
        })
    }
}

impl std::str::FromStr for DispatchMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "aware" | "refresh-aware" => Ok(DispatchMode::RefreshAware),
            "oblivious" | "refresh-oblivious" => Ok(DispatchMode::Oblivious),
            other => Err(format!("unknown dispatch mode '{other}' (aware | oblivious)")),
        }
    }
}

/// What one upcoming batch window will cost in refresh terms.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowPlan {
    /// Refresh slots due inside the window `(now, now + window_s]` — the
    /// passes that would stall an oblivious dispatch.
    pub ops_due: u64,
    /// Virtual time from the window's end to the next slot after it (the
    /// slack a refresh-aware dispatcher pays deferred stall in);
    /// `f64::INFINITY` when the backend needs no refresh.
    pub slack_s: f64,
}

/// Plan one batch window against the refresh slot grid: given the next
/// slot's due time and the slot pitch (see
/// [`crate::mem::refresh::RefreshController`]), how many slots land
/// inside a window of `window_s` starting at `now`, and how much slack
/// follows it. Pure slot arithmetic, pinned against the controller's own
/// `advance` in tests, so the dispatcher's admission decision and the
/// energy-model's op stream can never drift apart.
pub fn plan_window(next_due: Option<f64>, slot_s: f64, now: f64, window_s: f64) -> WindowPlan {
    let Some(due) = next_due else {
        return WindowPlan { ops_due: 0, slack_s: f64::INFINITY };
    };
    let end = now + window_s;
    if due > end {
        return WindowPlan { ops_due: 0, slack_s: due - end };
    }
    // slots fire at due, due+slot, …; count those ≤ end (the controller
    // fires on `next_due <= now`, so the boundary is inclusive)
    let ops = ((end - due) / slot_s).floor() as u64 + 1;
    let next_after = due + ops as f64 * slot_s;
    WindowPlan { ops_due: ops, slack_s: next_after - end }
}

/// Result of an event-driven inference simulation.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub network: &'static str,
    pub accelerator: &'static str,
    /// Grammar form of the backend this run used (parseable).
    pub backend: String,
    pub sim_time_s: f64,
    pub static_j: f64,
    pub refresh_j: f64,
    pub dynamic_j: f64,
    pub refresh_ops: u64,
    pub flips_committed: u64,
    pub weight_bytes_resident: usize,
    /// Macro area (m²) of the buffer at this capacity on 45 nm LP.
    pub area_m2: f64,
}

impl SimReport {
    pub fn total_j(&self) -> f64 {
        self.static_j + self.refresh_j + self.dynamic_j
    }

    /// Machine-readable form for `mcaimem simulate --json` (serde-free via
    /// [`crate::util::json`]): every meter/area field plus the parseable
    /// backend spec, so DSE runs and CI can diff results without scraping
    /// the rendered table.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("network", Json::Str(self.network.to_string())),
            ("accelerator", Json::Str(self.accelerator.to_string())),
            ("backend", Json::Str(self.backend.clone())),
            ("sim_time_s", Json::Num(self.sim_time_s)),
            ("static_j", Json::Num(self.static_j)),
            ("refresh_j", Json::Num(self.refresh_j)),
            ("dynamic_j", Json::Num(self.dynamic_j)),
            ("total_j", Json::Num(self.total_j())),
            ("refresh_ops", Json::Num(self.refresh_ops as f64)),
            ("flips_committed", Json::Num(self.flips_committed as f64)),
            ("weight_bytes_resident", Json::Num(self.weight_bytes_resident as f64)),
            ("area_m2", Json::Num(self.area_m2)),
        ])
    }
}

/// Simulate one inference of `net` on `acc` with the buffer technology
/// `spec` — every backend runs the identical schedule.
///
/// Weights for the current layer are (re)staged into the buffer when they
/// don't fit wholesale — the double-buffered tiling every real accelerator
/// does; activations ping-pong between two regions.
pub fn simulate_inference(
    net: &Network,
    acc: &AcceleratorConfig,
    spec: &BackendSpec,
    seed: u64,
) -> Result<SimReport> {
    let mut bm = BufferManager::from_spec(spec, acc.buffer_bytes, seed);
    let mut rng = Pcg64::new(seed ^ 0x5EED);

    // activation ping-pong regions sized to the worst layer (clamped to a
    // quarter of the buffer each; bigger layers stream in tiles)
    let max_act = net
        .layers
        .iter()
        .map(|l| l.input_bytes().max(l.output_bytes()))
        .max()
        .unwrap_or(0)
        .min(bm.capacity() / 4)
        .max(1);
    let act_a = bm.alloc(max_act)?;
    let act_b = bm.alloc(max_act)?;

    // weight staging region: the rest of the buffer (minus slack)
    let wregion = (bm.capacity() - 2 * max_act).saturating_sub(64).max(1);
    let weights = bm.alloc(wregion)?;

    // stage the input
    let input_len = net.layers[0].input_bytes().min(max_act);
    let first: Vec<u8> = (0..input_len).map(|_| (rng.normal() * 12.0) as i8 as u8).collect();
    bm.store(
        super::buffer_manager::TensorHandle { offset: act_a.offset, len: input_len, id: act_a.id },
        &first,
    )?;
    let mut src = act_a;
    let mut dst = act_b;

    for l in &net.layers {
        let cost = layer_cost(l, acc);
        // stage this layer's weights (tile-wise if larger than the region)
        let wlen = l.weight_bytes().min(wregion);
        let wdata: Vec<u8> = (0..wlen).map(|_| (rng.normal() * 10.0) as i8 as u8).collect();
        let wh = super::buffer_manager::TensorHandle {
            offset: weights.offset,
            len: wlen,
            id: weights.id,
        };
        bm.store(wh, &wdata)?;

        // the layer reads its input once at start…
        let rlen = l.input_bytes().min(max_act);
        let _ = bm.load(super::buffer_manager::TensorHandle {
            offset: src.offset,
            len: rlen,
            id: src.id,
        });

        // …computes for its cycle count (clock advances, refresh fires)…
        bm.tick(cost.cycles as f64 / acc.clock_hz);

        // …and writes its output.
        let olen = l.output_bytes().min(max_act);
        let out: Vec<u8> = (0..olen).map(|_| (rng.normal() * 12.0) as i8 as u8).collect();
        bm.store(
            super::buffer_manager::TensorHandle { offset: dst.offset, len: olen, id: dst.id },
            &out,
        )?;
        std::mem::swap(&mut src, &mut dst);
    }

    let area_m2 = bm.mem.area();
    let m = bm.mem.meter();
    Ok(SimReport {
        network: net.name,
        accelerator: acc.name,
        backend: spec.to_string(),
        sim_time_s: bm.now(),
        static_j: m.static_j,
        refresh_j: m.refresh_j,
        dynamic_j: m.read_j + m.write_j,
        refresh_ops: m.refreshes,
        flips_committed: m.flips_committed,
        weight_bytes_resident: wregion,
        area_m2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::system_eval::evaluate;
    use crate::scalesim::{network, simulate_network};

    #[test]
    fn sim_report_json_roundtrips() {
        let net = network::lenet();
        let acc = AcceleratorConfig::eyeriss();
        let r = simulate_inference(&net, &acc, &BackendSpec::mcaimem_default(), 5).unwrap();
        let j = crate::util::json::Json::parse(&r.to_json().to_pretty()).unwrap();
        assert_eq!(j.get("backend").unwrap().as_str().unwrap(), "mcaimem@0.8");
        assert_eq!(j.get("network").unwrap().as_str().unwrap(), "LeNet");
        let total = j.get("total_j").unwrap().as_f64().unwrap();
        assert!((total - r.total_j()).abs() <= 1e-12 * r.total_j());
        // the spec string in the artifact parses back to the spec
        let spec: BackendSpec = j.get("backend").unwrap().as_str().unwrap().parse().unwrap();
        assert_eq!(spec, BackendSpec::mcaimem_default());
    }

    #[test]
    fn event_driven_matches_closed_form_static_refresh() {
        // The two models share the same cards and clock, so static and
        // refresh energy must agree closely (the event-driven run's data
        // pattern differs slightly from the closed-form ones-fraction
        // estimate, so allow 30 %).
        let net = network::lenet();
        let acc = AcceleratorConfig::eyeriss();
        let sim = simulate_inference(&net, &acc, &BackendSpec::mcaimem_default(), 42).unwrap();
        let trace = simulate_network(&net, &acc);
        let cf = evaluate(&trace, &acc, &BackendSpec::mcaimem_default());
        let rel = |a: f64, b: f64| (a - b).abs() / b.max(1e-30);
        assert!(rel(sim.sim_time_s, trace.total_time_s) < 1e-9);
        assert!(
            rel(sim.static_j, cf.static_j) < 0.3,
            "static: sim={} cf={}",
            sim.static_j,
            cf.static_j
        );
        // refresh: the closed form charges the whole buffer at DNN-data
        // statistics; the event-driven buffer's unoccupied cells idle at
        // bit-1 (nearly-free refresh), so it must come in *below* the
        // closed form but within the same order of magnitude
        assert!(
            sim.refresh_j < cf.refresh_j && sim.refresh_j > cf.refresh_j / 5.0,
            "refresh: sim={} cf={}",
            sim.refresh_j,
            cf.refresh_j
        );
    }

    #[test]
    fn every_backend_runs_the_identical_schedule() {
        // the sweep promise: one scheduler path, any technology — same
        // wall-clock timeline, per-backend meters/area
        let net = network::lenet();
        let acc = AcceleratorConfig::eyeriss();
        let mut runs = Vec::new();
        for spec in BackendSpec::default_sweep() {
            let r = simulate_inference(&net, &acc, &spec, 9).unwrap();
            assert_eq!(r.backend, spec.to_string());
            assert!(r.area_m2 > 0.0, "{spec}");
            runs.push(r);
        }
        for w in runs.windows(2) {
            assert!((w[0].sim_time_s - w[1].sim_time_s).abs() < 1e-15, "same schedule");
        }
        let by = |s: &str| runs.iter().find(|r| r.backend == s).unwrap();
        assert_eq!(by("sram").refresh_j, 0.0);
        assert_eq!(by("rram").static_j, 0.0);
        assert!(by("edram2t").refresh_j > by("mcaimem@0.8").refresh_j);
        assert!(by("rram").dynamic_j > 50.0 * by("sram").dynamic_j);
    }

    #[test]
    fn refresh_ops_scale_with_runtime() {
        let net = network::lenet();
        let acc = AcceleratorConfig::eyeriss();
        let sim = simulate_inference(&net, &acc, &BackendSpec::mcaimem_default(), 1).unwrap();
        // expected: time / slot-interval
        let t_ref = 12.57e-6;
        let rows = 256.0;
        let expect = sim.sim_time_s / (t_ref / rows);
        let rel = (sim.refresh_ops as f64 - expect).abs() / expect;
        assert!(rel < 0.05, "ops={} expect={expect}", sim.refresh_ops);
    }

    #[test]
    fn dispatch_mode_parses_and_displays() {
        assert_eq!("aware".parse::<DispatchMode>().unwrap(), DispatchMode::RefreshAware);
        assert_eq!("refresh-aware".parse::<DispatchMode>().unwrap(), DispatchMode::RefreshAware);
        assert_eq!("oblivious".parse::<DispatchMode>().unwrap(), DispatchMode::Oblivious);
        assert_eq!(DispatchMode::RefreshAware.to_string(), "aware");
        assert_eq!(DispatchMode::default(), DispatchMode::RefreshAware);
        assert!("sometimes".parse::<DispatchMode>().is_err());
    }

    #[test]
    fn window_plan_matches_the_controller_slot_for_slot() {
        use crate::mem::refresh::RefreshController;
        // walk a controller through a grid of windows; at each step the
        // planner's prediction must equal what advance() actually fires —
        // the invariant that keeps refresh-aware admission honest
        let mut rc = RefreshController::new(256, 12.57e-6); // the paper point
        let window = 2e-6; // the pool's default sim_compute_s
        let mut now = 0.0;
        for _ in 0..200 {
            let plan = plan_window(Some(rc.next_due()), rc.slot(), now, window);
            now += window;
            let fired = rc.advance(now).len() as u64;
            assert_eq!(plan.ops_due, fired, "planner and controller drifted at t={now}");
            assert!(plan.slack_s > 0.0 && plan.slack_s <= rc.slot() + 1e-18);
            // after advancing, the next slot really is past the window
            assert!(rc.next_due() > now);
        }

        // windows shorter than a slot: most have no refresh due, and the
        // slack points at the real gap
        let mut rc = RefreshController::new(16, 16e-6); // slot = 1 µs
        let tiny = 0.25e-6;
        let mut now = 0.0;
        let mut due_total = 0u64;
        // 66 windows end mid-slot (16.5 µs), so the count is robust to
        // float accumulation at the window boundaries
        for _ in 0..66 {
            let plan = plan_window(Some(rc.next_due()), rc.slot(), now, tiny);
            now += tiny;
            assert_eq!(plan.ops_due, rc.advance(now).len() as u64);
            due_total += plan.ops_due;
        }
        assert_eq!(due_total, 16, "66 quarter-slot windows span exactly 16 slots");

        // refresh-free backends plan unbounded slack
        let none = plan_window(None, 1.0, 0.0, 1.0);
        assert_eq!(none.ops_due, 0);
        assert!(none.slack_s.is_infinite());
    }

    #[test]
    fn lower_vref_means_more_refresh_energy() {
        let net = network::lenet();
        let acc = AcceleratorConfig::eyeriss();
        let hi = simulate_inference(&net, &acc, &BackendSpec::mcaimem_default(), 2).unwrap();
        let lo =
            simulate_inference(
                &net,
                &acc,
                &BackendSpec::Mcaimem { vref: 0.5, encode: true, ecc: false },
                2,
            )
                .unwrap();
        assert!(lo.refresh_j > 5.0 * hi.refresh_j, "lo={} hi={}", lo.refresh_j, hi.refresh_j);
        // flips affect only the ~1% weakest cells among freshly written
        // zeros (each flips at most once per write); bound by traffic
        assert!(hi.flips_committed > 0, "the weak-cell tail must exist");
        assert!(
            (hi.flips_committed as f64) < 0.05 * 7.0 * 200_000.0,
            "flips={}",
            hi.flips_committed
        );
    }
}
