//! Batched inference server — the single-worker (K = 1) serving path.
//!
//! One worker thread owns the PJRT executables (they are not `Sync`) and
//! drains an mpsc request queue; requests are grouped into the export batch
//! size with a short batching window, padded when the window closes early,
//! executed through the MCAIMem-aged model, and answered over per-request
//! channels. Every pending request is answered exactly once — a failed
//! `infer` call answers each caller with the error instead of dropping the
//! reply channels (callers must never hang with no context).
//!
//! The production-scale serving tier is [`super::pool::WorkerPool`]: K of
//! these loops over sharded buffers behind one admission-controlled queue.
//! This single-worker server is kept as the minimal PJRT path the
//! end-to-end example drives; [`ServerStats`] is shared between the two.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::Metrics;
use crate::mem::backend::BackendSpec;
use crate::runtime::executor::ModelRunner;
use crate::util::rng::Pcg64;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Batching window: how long to wait for more requests before padding.
    pub batch_window: Duration,
    /// Which buffer technology the served model stores tensors in (same
    /// spec grammar as everywhere else: `sram`, `mcaimem@0.8`, …).
    pub backend: BackendSpec,
    /// Retention-flip probability fed to the aged backends.
    pub flip_p: f64,
    pub seed: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_window: Duration::from_millis(2),
            backend: BackendSpec::mcaimem_default(),
            flip_p: 0.01,
            seed: 0xD00D,
        }
    }
}

/// One reply: class index + request latency, or the inference error that
/// sank the batch this request rode in.
pub type Reply = Result<(usize, Duration)>;

struct Request {
    row: Vec<i8>,
    submitted: Instant,
    reply: mpsc::Sender<Reply>,
}

/// Handle to the running server.
pub struct InferenceServer {
    tx: mpsc::Sender<Request>,
    worker: Option<JoinHandle<Metrics>>,
}

/// Per-shard serving counters (one row of the `ServerStats::shards`
/// break-down; produced by the worker pool from
/// [`crate::mem::backend::MemoryBackend::shard_meters`]).
#[derive(Clone, Debug)]
pub struct ShardStat {
    pub shard: usize,
    /// Which worker owns this shard.
    pub worker: usize,
    /// Payload bytes moved through this shard (reads + writes).
    pub bytes_rw: u64,
    /// Fraction of the tier's total shard traffic this shard carried —
    /// ~1/N when striping balances.
    pub occupancy: f64,
    /// Manager-driven refresh slots this shard executed.
    pub refreshes: u64,
    /// Total energy charged to this shard (J).
    pub energy_j: f64,
}

/// Final statistics after shutdown.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    /// p99.9 request latency (µs) — the SLO tail the serving sweeps gate
    /// on; backed (like p50/p99) by the exact-count log-bucketed
    /// histogram, so it is stable at any completion count.
    pub p999_latency_us: f64,
    pub occupancy: f64,
    /// Request payload bytes accepted over the server's lifetime.
    pub bytes_in: u64,
    /// Sustained request rate (req/s) measured worker-side.
    pub requests_per_s: f64,
    /// Sustained inbound payload throughput (bytes/s) measured worker-side
    /// — the counter that reflects the array's store-path speed.
    pub bytes_per_s: f64,
    /// Requests answered with an inference error (never silently dropped).
    pub errors: u64,
    /// Requests refused by admission control (pool only; 0 for the
    /// single-worker server, which applies no admission control).
    pub rejected: u64,
    /// p99 of the admission-queue depth sampled at every accepted submit
    /// (pool only).
    pub queue_depth_p99: f64,
    /// p99.9 of the per-request refresh stall (µs) — the part of the tail
    /// attributable to refresh slots firing on the request critical path.
    /// Zero under refresh-aware dispatch (the stall moves off-path) and
    /// whenever stall modeling is off (`refresh_stall == 0`).
    pub refresh_stall_p999_us: f64,
    /// Total modeled refresh stall charged to requests (µs, pool only).
    pub refresh_stall_total_us: f64,
    /// Total modeled refresh stall absorbed into inter-window slack
    /// instead of request latency (µs; refresh-aware dispatch only).
    pub refresh_slack_total_us: f64,
    /// Per-shard occupancy/refresh/energy counters (pool only; empty for
    /// the single-worker server, which owns no buffer shards).
    pub shards: Vec<ShardStat>,
    /// The merged request-latency distribution (exact counts, log
    /// buckets) — the p50/p99/p99.9 fields above are read from this; it
    /// rides along so [`ServerStats::registry`] can export the full
    /// quantile summary, not just point readings.
    pub latency_hist: crate::obs::LogHistogram,
    /// The merged per-request refresh-stall distribution (µs).
    pub refresh_stall_hist: crate::obs::LogHistogram,
}

impl ServerStats {
    /// Lift a worker-side accumulator into the user-facing stats (the
    /// pool fills in the admission/shard fields afterwards).
    pub fn from_metrics(m: &Metrics) -> Self {
        ServerStats {
            requests: m.requests,
            batches: m.batches,
            mean_latency_us: m.mean_us(),
            p50_latency_us: m.p50_us(),
            p99_latency_us: m.p99_us(),
            p999_latency_us: m.p999_us(),
            occupancy: m.occupancy(),
            bytes_in: m.bytes_in,
            requests_per_s: m.requests_per_s(),
            bytes_per_s: m.bytes_per_s(),
            errors: m.errors,
            rejected: 0,
            queue_depth_p99: 0.0,
            refresh_stall_p999_us: m.refresh_stall_p999_us(),
            refresh_stall_total_us: m.refresh_stall_total_us,
            refresh_slack_total_us: m.refresh_slack_total_us,
            shards: Vec::new(),
            latency_hist: m.latency_hist().clone(),
            refresh_stall_hist: m.refresh_stall_hist().clone(),
        }
    }

    /// Snapshot into the unified metrics registry
    /// (`mcaimem_serving_*` / `mcaimem_shard_*` names): the export surface
    /// behind `mcaimem serve --metrics-out` (JSON or Prometheus text).
    pub fn registry(&self) -> crate::obs::Registry {
        let mut r = crate::obs::Registry::new();
        r.count("mcaimem_serving_requests_total", self.requests);
        r.count("mcaimem_serving_batches_total", self.batches);
        r.count("mcaimem_serving_bytes_in_total", self.bytes_in);
        r.count("mcaimem_serving_errors_total", self.errors);
        r.count("mcaimem_serving_rejected_total", self.rejected);
        r.gauge("mcaimem_serving_latency_mean_us", self.mean_latency_us);
        r.gauge("mcaimem_serving_latency_p50_us", self.p50_latency_us);
        r.gauge("mcaimem_serving_latency_p99_us", self.p99_latency_us);
        r.gauge("mcaimem_serving_latency_p999_us", self.p999_latency_us);
        r.gauge("mcaimem_serving_occupancy_ratio", self.occupancy);
        r.gauge("mcaimem_serving_requests_per_s", self.requests_per_s);
        r.gauge("mcaimem_serving_bytes_per_s", self.bytes_per_s);
        r.gauge("mcaimem_serving_queue_depth_p99", self.queue_depth_p99);
        r.gauge("mcaimem_serving_refresh_stall_p999_us", self.refresh_stall_p999_us);
        r.gauge("mcaimem_serving_refresh_stall_total_us", self.refresh_stall_total_us);
        r.gauge("mcaimem_serving_refresh_slack_total_us", self.refresh_slack_total_us);
        r.merge_hist("mcaimem_serving_latency_us", &self.latency_hist);
        if self.refresh_stall_hist.count() > 0 {
            r.merge_hist("mcaimem_serving_refresh_stall_us", &self.refresh_stall_hist);
        }
        for s in &self.shards {
            r.count("mcaimem_shard_bytes_rw_total", s.bytes_rw);
            r.count("mcaimem_shard_refresh_ops_total", s.refreshes);
            r.gauge("mcaimem_shard_energy_j", s.energy_j);
        }
        r
    }
}

impl InferenceServer {
    /// Start the worker thread over an artifacts directory.
    pub fn start(artifacts_dir: std::path::PathBuf, cfg: ServerConfig) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let worker = std::thread::Builder::new()
            .name("mcaimem-infer".into())
            .spawn(move || worker_loop(artifacts_dir, cfg, rx))?;
        Ok(InferenceServer { tx, worker: Some(worker) })
    }

    /// Submit one row; blocks until the class comes back (or surfaces the
    /// inference error that sank this request's batch).
    pub fn classify(&self, row: Vec<i8>) -> Result<(usize, Duration)> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request { row, submitted: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx.recv()?
    }

    /// Fire-and-forget submission returning the reply receiver (for load
    /// generation).
    pub fn submit(&self, row: Vec<i8>) -> Result<mpsc::Receiver<Reply>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request { row, submitted: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(reply_rx)
    }

    /// Stop the server and collect metrics.
    pub fn shutdown(mut self) -> ServerStats {
        drop(self.tx);
        let m = self
            .worker
            .take()
            .expect("worker present")
            .join()
            .unwrap_or_default();
        ServerStats::from_metrics(&m)
    }
}

fn worker_loop(dir: std::path::PathBuf, cfg: ServerConfig, rx: mpsc::Receiver<Request>) -> Metrics {
    let mut metrics = Metrics::default();
    let mut runner = match ModelRunner::new(&dir) {
        Ok(r) => r,
        Err(e) => {
            // answer every request (present and future) with the startup
            // error instead of going dark
            let msg = format!("server failed to load artifacts: {e:#}");
            eprintln!("server: {msg}");
            while let Ok(req) = rx.recv() {
                metrics.record_error();
                let _ = req.reply.send(Err(anyhow::anyhow!("{msg}")));
            }
            return metrics;
        }
    };
    let batch = runner.artifacts.batch;
    let dim = runner.artifacts.input_dim;
    let mut rng = Pcg64::new(cfg.seed);

    loop {
        // block for the first request of a batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all senders dropped → shutdown
        };
        let mut pending = vec![first];
        let window_end = Instant::now() + cfg.batch_window;
        while pending.len() < batch {
            let now = Instant::now();
            if now >= window_end {
                break;
            }
            match rx.recv_timeout(window_end - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // assemble padded batch
        let real = pending.len();
        let mut x = vec![0i8; batch * dim];
        for (i, r) in pending.iter().enumerate() {
            let row = &r.row;
            let n = row.len().min(dim);
            x[i * dim..i * dim + n].copy_from_slice(&row[..n]);
            metrics.record_bytes_in(n);
        }
        metrics.record_batch(real, batch);

        match runner.infer(&x, &cfg.backend, cfg.flip_p, &mut rng) {
            Ok(classes) => {
                for (i, req) in pending.into_iter().enumerate() {
                    let latency = req.submitted.elapsed();
                    metrics.record_latency(latency);
                    let _ = req.reply.send(Ok((classes[i], latency)));
                }
            }
            Err(e) => {
                // answer each pending request with the error — concurrent
                // callers must see the failure, not a closed channel
                let msg = format!("inference failed: {e:#}");
                eprintln!("server: {msg}");
                for req in pending {
                    metrics.record_error();
                    let _ = req.reply.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
    metrics
}
