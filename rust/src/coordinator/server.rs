//! Batched inference server — the request loop of the L3 coordinator.
//!
//! A single worker thread owns the PJRT executables (they are not `Sync`)
//! and drains an mpsc request queue; requests are grouped into the export
//! batch size with a short batching window, padded when the window closes
//! early, executed through the MCAIMem-aged model, and answered over
//! per-request channels. Latency/throughput metrics are the numbers the
//! end-to-end example reports (EXPERIMENTS.md §E2E).

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::Metrics;
use crate::mem::backend::BackendSpec;
use crate::runtime::executor::ModelRunner;
use crate::util::rng::Pcg64;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Batching window: how long to wait for more requests before padding.
    pub batch_window: Duration,
    /// Which buffer technology the served model stores tensors in (same
    /// spec grammar as everywhere else: `sram`, `mcaimem@0.8`, …).
    pub backend: BackendSpec,
    /// Retention-flip probability fed to the aged backends.
    pub flip_p: f64,
    pub seed: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_window: Duration::from_millis(2),
            backend: BackendSpec::mcaimem_default(),
            flip_p: 0.01,
            seed: 0xD00D,
        }
    }
}

struct Request {
    row: Vec<i8>,
    submitted: Instant,
    reply: mpsc::Sender<(usize, Duration)>,
}

/// Handle to the running server.
pub struct InferenceServer {
    tx: mpsc::Sender<Request>,
    worker: Option<JoinHandle<Metrics>>,
}

/// Final statistics after shutdown.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    pub occupancy: f64,
    /// Request payload bytes accepted over the server's lifetime.
    pub bytes_in: u64,
    /// Sustained request rate (req/s) measured worker-side.
    pub requests_per_s: f64,
    /// Sustained inbound payload throughput (bytes/s) measured worker-side
    /// — the counter that reflects the array's store-path speed.
    pub bytes_per_s: f64,
}

impl InferenceServer {
    /// Start the worker thread over an artifacts directory.
    pub fn start(artifacts_dir: std::path::PathBuf, cfg: ServerConfig) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let worker = std::thread::Builder::new()
            .name("mcaimem-infer".into())
            .spawn(move || worker_loop(artifacts_dir, cfg, rx))?;
        Ok(InferenceServer { tx, worker: Some(worker) })
    }

    /// Submit one row; blocks until the class comes back.
    pub fn classify(&self, row: Vec<i8>) -> Result<(usize, Duration)> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request { row, submitted: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(reply_rx.recv()?)
    }

    /// Fire-and-forget submission returning the reply receiver (for load
    /// generation).
    pub fn submit(&self, row: Vec<i8>) -> Result<mpsc::Receiver<(usize, Duration)>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request { row, submitted: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(reply_rx)
    }

    /// Stop the server and collect metrics.
    pub fn shutdown(mut self) -> ServerStats {
        drop(self.tx);
        let m = self
            .worker
            .take()
            .expect("worker present")
            .join()
            .unwrap_or_default();
        ServerStats {
            requests: m.requests,
            batches: m.batches,
            mean_latency_us: m.mean_us(),
            p50_latency_us: m.p50_us(),
            p99_latency_us: m.p99_us(),
            occupancy: m.occupancy(),
            bytes_in: m.bytes_in,
            requests_per_s: m.requests_per_s(),
            bytes_per_s: m.bytes_per_s(),
        }
    }
}

fn worker_loop(dir: std::path::PathBuf, cfg: ServerConfig, rx: mpsc::Receiver<Request>) -> Metrics {
    let mut metrics = Metrics::default();
    let mut runner = match ModelRunner::new(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("server: failed to load artifacts: {e:#}");
            return metrics;
        }
    };
    let batch = runner.artifacts.batch;
    let dim = runner.artifacts.input_dim;
    let mut rng = Pcg64::new(cfg.seed);

    loop {
        // block for the first request of a batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all senders dropped → shutdown
        };
        let mut pending = vec![first];
        let window_end = Instant::now() + cfg.batch_window;
        while pending.len() < batch {
            let now = Instant::now();
            if now >= window_end {
                break;
            }
            match rx.recv_timeout(window_end - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // assemble padded batch
        let real = pending.len();
        let mut x = vec![0i8; batch * dim];
        for (i, r) in pending.iter().enumerate() {
            let row = &r.row;
            let n = row.len().min(dim);
            x[i * dim..i * dim + n].copy_from_slice(&row[..n]);
            metrics.record_bytes_in(n);
        }
        metrics.record_batch(real, batch);

        match runner.infer(&x, &cfg.backend, cfg.flip_p, &mut rng) {
            Ok(classes) => {
                for (i, req) in pending.into_iter().enumerate() {
                    let latency = req.submitted.elapsed();
                    metrics.record_latency(latency);
                    let _ = req.reply.send((classes[i], latency));
                }
            }
            Err(e) => {
                eprintln!("server: inference failed: {e:#}");
                // drop replies — callers see a closed channel
            }
        }
    }
    metrics
}
