//! Tensor-level buffer manager over any [`MemoryBackend`].
//!
//! Owns allocation (bump allocator with a coalescing, frontier-reclaiming
//! free list — DNN buffers allocate/release in layer order), the refresh
//! controller wired to the backend's bank geometry (disabled for
//! technologies that need no manager-driven refresh), and the virtual
//! clock. Every `store`/`load` goes through the backend's device API, so
//! anything scheduled on top of this manager sees the *physical* behaviour
//! of the chosen technology — the mixed-cell array's encoder + aging
//! machinery for `mcaimem@…`, plain persistence for `sram`/`rram`, the
//! analytic refresh stream for `edram2t`.

use anyhow::{bail, Result};

use crate::mem::backend::{self, BackendSpec, MemoryBackend};
use crate::mem::refresh::RefreshController;

/// Handle to an allocated tensor region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TensorHandle {
    pub offset: usize,
    pub len: usize,
    pub id: u64,
}

/// The backend-generic buffer manager.
pub struct BufferManager {
    pub mem: Box<dyn MemoryBackend>,
    pub refresh: RefreshController,
    free: Vec<(usize, usize)>,           // (offset, len), sorted by offset
    allocated: Vec<(usize, usize, u64)>, // live regions (offset, len, id)
    /// Bump frontier: no byte at or above this offset has ever been
    /// allocated *and not reclaimed*. Frees that reach the frontier shrink
    /// it back, so layer-order alloc/free cycles cannot leak capacity.
    frontier: usize,
    /// High-water mark of the frontier — the peak footprint.
    peak: usize,
    next_id: u64,
    now: f64,
    /// Telemetry sink + this manager's worker track (see
    /// [`BufferManager::attach_obs`]).
    obs: crate::obs::ObsSink,
    obs_track: u32,
    /// Accumulated modeled stall/slack (µs) on this worker's timeline:
    /// trace timestamps are `now·1e6 + obs_lag_us`, so spans stay monotone
    /// per track even though modeled stalls don't advance the device clock.
    obs_lag_us: f64,
}

impl BufferManager {
    /// A manager over `bytes` of mixed-cell memory at the paper's operating
    /// point (V_REF = 0.8 ⇒ 12.57 µs whole-array refresh).
    pub fn new(bytes: usize, seed: u64) -> Self {
        Self::from_spec(&BackendSpec::mcaimem_default(), bytes, seed)
    }

    /// A manager over any backend spec — the one construction path every
    /// technology shares.
    pub fn from_spec(spec: &BackendSpec, bytes: usize, seed: u64) -> Self {
        Self::from_backend(backend::build(spec, bytes, seed))
    }

    /// A manager over `shards` striped bank shards of `spec` (the serving
    /// tier's banked buffer — see [`crate::mem::sharded`]).
    pub fn sharded(spec: &BackendSpec, shards: usize, bytes: usize, seed: u64) -> Result<Self> {
        Ok(Self::from_backend(Box::new(crate::mem::sharded::ShardedBackend::new(
            spec, shards, bytes, seed,
        )?)))
    }

    /// A manager over an already-built backend (the general form `from_spec`
    /// and `sharded` delegate to).
    pub fn from_backend(mem: Box<dyn MemoryBackend>) -> Self {
        let refresh = match mem.refresh_due() {
            Some(t_ref) => RefreshController::new(mem.rows_per_bank(), t_ref),
            None => {
                // no manager-driven refresh: park a disabled controller so
                // the tick loop stays uniform
                let mut rc = RefreshController::new(1, 1.0);
                rc.enabled = false;
                rc
            }
        };
        BufferManager {
            refresh,
            mem,
            free: Vec::new(),
            allocated: Vec::new(),
            frontier: 0,
            peak: 0,
            next_id: 0,
            now: 0.0,
            obs: crate::obs::ObsSink::disabled(),
            obs_track: 0,
            obs_lag_us: 0.0,
        }
    }

    /// Attach a telemetry sink: refresh passes emitted by [`tick`] land on
    /// `track` (this worker's trace track), and the backend's structural
    /// events (failover, tier traffic, fault firings) on the shard range
    /// starting at `shard_track_base`.
    ///
    /// [`tick`]: BufferManager::tick
    pub fn attach_obs(&mut self, sink: &crate::obs::ObsSink, track: u32, shard_track_base: u32) {
        self.obs = sink.clone();
        self.obs_track = track;
        self.mem.attach_obs(sink, shard_track_base);
    }

    /// This worker's current trace timestamp (µs): device clock plus the
    /// accumulated modeled stall/slack lag.
    pub fn obs_now_us(&self) -> f64 {
        self.now * 1e6 + self.obs_lag_us
    }

    /// Push modeled stall/slack time (µs) onto this worker's trace
    /// timeline (the device clock does not advance for modeled waits).
    pub fn add_obs_lag(&mut self, us: f64) {
        self.obs_lag_us += us;
    }

    /// The attached sink (disabled by default) and track.
    pub fn obs(&self) -> &crate::obs::ObsSink {
        &self.obs
    }

    pub fn obs_track(&self) -> u32 {
        self.obs_track
    }

    pub fn capacity(&self) -> usize {
        self.mem.capacity()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance the virtual clock, firing any due refresh slots into the
    /// backend (each slot refreshes one row across all banks in parallel).
    pub fn tick(&mut self, dt: f64) {
        assert!(dt >= 0.0);
        let _scan = crate::obs::profile::phase(crate::obs::profile::Phase::RefreshScan);
        let target = self.now + dt;
        let ops = self.refresh.advance(target);
        if self.obs.is_enabled() && !ops.is_empty() {
            let ecc_before = self.mem.meter().ecc_corrected;
            let (t0, t1) = (ops[0].due, ops[ops.len() - 1].due.max(ops[0].due));
            self.obs.emit(crate::obs::Event::span_begin(
                crate::obs::EventKind::RefreshPass,
                self.obs_track,
                t0 * 1e6 + self.obs_lag_us,
                ops.len() as u64,
                ops[0].row as u64,
            ));
            for op in &ops {
                self.mem.refresh_row(op.row, op.due);
            }
            let ecc = self.mem.meter().ecc_corrected - ecc_before;
            if ecc > 0 {
                self.obs.emit(crate::obs::Event::instant(
                    crate::obs::EventKind::EccCorrected,
                    self.obs_track,
                    t1 * 1e6 + self.obs_lag_us,
                    ecc,
                    0,
                ));
            }
            self.obs.emit(crate::obs::Event::span_end(
                crate::obs::EventKind::RefreshPass,
                self.obs_track,
                t1 * 1e6 + self.obs_lag_us,
                ops.len() as u64,
                0,
            ));
        } else {
            for op in &ops {
                // fire each slot at its own due time so row staleness never
                // exceeds t_ref even under coarse ticks
                self.mem.refresh_row(op.row, op.due);
            }
        }
        self.mem.tick(target);
        self.now = target;
    }

    /// Allocate a tensor region (first-fit over the free list, else bump).
    pub fn alloc(&mut self, len: usize) -> Result<TensorHandle> {
        if len == 0 {
            bail!("zero-length allocation");
        }
        // first-fit
        if let Some(pos) = self.free.iter().position(|&(_, flen)| flen >= len) {
            let (off, flen) = self.free.remove(pos);
            if flen > len {
                self.free.push((off + len, flen - len));
                self.free.sort_unstable();
            }
            self.next_id += 1;
            self.allocated.push((off, len, self.next_id));
            return Ok(TensorHandle { offset: off, len, id: self.next_id });
        }
        // bump from the frontier
        if self.frontier + len > self.capacity() {
            bail!(
                "out of buffer memory: want {len} at {}, capacity {}",
                self.frontier,
                self.capacity()
            );
        }
        let off = self.frontier;
        self.frontier += len;
        self.peak = self.peak.max(self.frontier);
        self.next_id += 1;
        self.allocated.push((off, len, self.next_id));
        Ok(TensorHandle { offset: off, len, id: self.next_id })
    }

    /// Release a region for reuse: coalesce with adjacent free ranges, and
    /// return any free tail that reaches the bump frontier to the bump
    /// pool — without this, layer-order alloc/free cycles whose sizes grow
    /// leak capacity (a freed block below the frontier is invisible to
    /// bump allocation).
    ///
    /// A handle that does not match a live allocation — double release,
    /// fabricated handle, or a stale handle whose region has since been
    /// handed to a new owner (the `id` disambiguates) — is ignored:
    /// freeing it anyway would insert a range that overlaps live regions
    /// or the bump pool and let two later allocations alias the same bytes.
    pub fn release(&mut self, h: TensorHandle) {
        match self
            .allocated
            .iter()
            .position(|&(o, l, id)| o == h.offset && l == h.len && id == h.id)
        {
            Some(pos) => {
                self.allocated.remove(pos);
            }
            None => return,
        }
        self.free.push((h.offset, h.len));
        self.free.sort_unstable();
        self.coalesce();
        // reclaim the tail: after coalescing, only the last free block can
        // touch the frontier
        while let Some(&(off, len)) = self.free.last() {
            if off + len == self.frontier {
                self.frontier = off;
                self.free.pop();
            } else {
                break;
            }
        }
    }

    fn coalesce(&mut self) {
        let mut merged: Vec<(usize, usize)> = Vec::with_capacity(self.free.len());
        for &(off, len) in self.free.iter() {
            if let Some(last) = merged.last_mut() {
                if last.0 + last.1 == off {
                    last.1 += len;
                    continue;
                }
            }
            merged.push((off, len));
        }
        self.free = merged;
    }

    /// Store tensor bytes at the current clock.
    pub fn store(&mut self, h: TensorHandle, data: &[u8]) -> Result<()> {
        if data.len() != h.len {
            bail!("store size mismatch: handle {} vs data {}", h.len, data.len());
        }
        self.mem.store(h.offset, data, self.now);
        Ok(())
    }

    /// Load tensor bytes at the current clock (ages + commits flips on
    /// backends that age).
    pub fn load(&mut self, h: TensorHandle) -> Vec<u8> {
        self.mem.load(h.offset, h.len, self.now)
    }

    /// Store an `i8` tensor without a conversion copy: `i8` and `u8` have
    /// identical size and alignment, so the payload is viewed in place as
    /// device bytes. This is the serving hot path — a full-batch
    /// `Vec<i8>` → `Vec<u8>` round trip per staged pass is pure waste.
    pub fn store_i8(&mut self, h: TensorHandle, data: &[i8]) -> Result<()> {
        // SAFETY: i8 and u8 have the same size, alignment and validity;
        // reinterpreting a shared slice between them is sound.
        let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr().cast::<u8>(), data.len()) };
        self.store(h, bytes)
    }

    /// Load a tensor as `i8`, reinterpreting the device bytes in place
    /// (no copy; the returned vector owns the backend's buffer).
    pub fn load_i8(&mut self, h: TensorHandle) -> Vec<i8> {
        let mut v = std::mem::ManuallyDrop::new(self.load(h));
        // SAFETY: Vec<u8> → Vec<i8> with identical length/capacity is a
        // pure element-type reinterpretation (same size, same alignment,
        // every bit pattern valid); ManuallyDrop hands ownership of the
        // allocation to the new vector exactly once.
        unsafe { Vec::from_raw_parts(v.as_mut_ptr().cast::<i8>(), v.len(), v.capacity()) }
    }

    /// Absolute virtual time (s) of the next refresh slot, `None` when the
    /// backend needs no manager-driven refresh — the quantity a
    /// refresh-aware dispatcher plans batch windows around.
    pub fn next_refresh_due(&self) -> Option<f64> {
        if self.refresh.enabled {
            Some(self.refresh.next_due())
        } else {
            None
        }
    }

    /// Total refresh slots fired so far (the dispatcher's per-window delta
    /// gives the refresh work that landed inside that window).
    pub fn refresh_issued(&self) -> u64 {
        self.refresh.issued
    }

    /// Fraction of capacity currently allocated.
    pub fn utilization(&self) -> f64 {
        let used: usize = self.allocated.iter().map(|&(_, l, _)| l).sum();
        used as f64 / self.capacity() as f64
    }

    /// Peak footprint (max bump-frontier position) over the manager's
    /// lifetime — the regression metric for free-list fragmentation.
    pub fn peak_usage(&self) -> usize {
        self.peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_load_roundtrip_fresh() {
        let mut bm = BufferManager::new(64 * 1024, 1);
        let h = bm.alloc(256).unwrap();
        let data: Vec<u8> = (0..=255).collect();
        bm.store(h, &data).unwrap();
        bm.tick(1e-6); // well inside retention
        assert_eq!(bm.load(h), data);
    }

    #[test]
    fn refresh_keeps_data_alive_indefinitely() {
        let mut bm = BufferManager::new(16 * 1024, 2);
        let h = bm.alloc(64).unwrap();
        let data = vec![0x05u8; 64]; // small positives — encoder-protected
        bm.store(h, &data).unwrap();
        // 100 ms in 1 µs ticks: ~8000 refresh periods
        for _ in 0..1000 {
            bm.tick(100e-6);
        }
        let back = bm.load(h);
        let errs = back.iter().zip(&data).filter(|(a, b)| a != b).count();
        assert!(errs <= 1, "errs={errs}");
        assert!(bm.refresh.issued > 1000, "refresh must have been running");
    }

    #[test]
    fn every_backend_drives_the_same_manager() {
        for spec in BackendSpec::default_sweep() {
            let mut bm = BufferManager::from_spec(&spec, 32 * 1024, 5);
            let h = bm.alloc(128).unwrap();
            let data: Vec<u8> = (0..128).map(|i| i as u8).collect();
            bm.store(h, &data).unwrap();
            bm.tick(1e-6);
            assert_eq!(bm.load(h), data, "{spec}");
            assert!(bm.mem.meter().write_j > 0.0, "{spec}");
            // static memories never see manager-driven refresh slots
            if bm.mem.refresh_due().is_none() {
                assert_eq!(bm.refresh.issued, 0, "{spec}");
            }
        }
    }

    #[test]
    fn alloc_release_reuse() {
        let mut bm = BufferManager::new(16 * 1024, 3);
        let a = bm.alloc(1000).unwrap();
        let b = bm.alloc(2000).unwrap();
        assert!(b.offset >= a.offset + a.len);
        bm.release(a);
        let c = bm.alloc(500).unwrap();
        assert_eq!(c.offset, 0, "first-fit should reuse the freed region");
        let _ = b;
    }

    #[test]
    fn frees_reaching_the_frontier_are_reclaimed() {
        let mut bm = BufferManager::new(16 * 1024, 4);
        let a = bm.alloc(100).unwrap();
        let b = bm.alloc(100).unwrap();
        bm.release(a);
        bm.release(b); // coalesces to (0, 200), which touches the frontier
        assert!(bm.free.is_empty(), "tail free block must return to the bump pool");
        // a *larger* allocation than either freed block now fits at 0 —
        // the case the old high-water bump leaked on
        let big = bm.alloc(300).unwrap();
        assert_eq!(big.offset, 0);
        assert_eq!(bm.peak_usage(), 300);
    }

    #[test]
    fn grow_shrink_cycles_do_not_leak_capacity() {
        // alloc/free a growing sequence: without frontier reclaim every
        // cycle leaks the previous (smaller) block
        let mut bm = BufferManager::new(16 * 1024, 4);
        for len in [100usize, 200, 400, 800, 1600] {
            let h = bm.alloc(len).unwrap();
            bm.release(h);
        }
        assert_eq!(bm.peak_usage(), 1600);
    }

    #[test]
    fn resnet50_layer_cycle_peak_is_stable_across_passes() {
        // regression for free-list fragmentation: running the full
        // ResNet-50 layer-order alloc/free sequence twice must not grow
        // the peak footprint — pass 2 replays into a fully reclaimed
        // allocator, so any difference is leaked capacity
        let net = crate::scalesim::network::resnet50();
        let mut bm = BufferManager::from_spec(&BackendSpec::Sram, 8 * 1024 * 1024, 1);
        let cap_alloc = |b: usize| b.clamp(1, 1024 * 1024);
        let mut peaks = Vec::new();
        for pass in 0..2 {
            let mut act: Option<TensorHandle> = None;
            for l in &net.layers {
                let w = bm.alloc(cap_alloc(l.weight_bytes())).unwrap();
                let inp = match act.take() {
                    Some(h) => h,
                    None => bm.alloc(cap_alloc(l.input_bytes())).unwrap(),
                };
                let out = bm.alloc(cap_alloc(l.output_bytes())).unwrap();
                bm.release(inp);
                bm.release(w);
                act = Some(out);
            }
            if let Some(h) = act {
                bm.release(h);
            }
            assert_eq!(bm.utilization(), 0.0, "pass {pass}: everything was freed");
            peaks.push(bm.peak_usage());
        }
        assert_eq!(peaks[0], peaks[1], "second pass must not grow the peak footprint");
    }

    #[test]
    fn stale_or_double_release_is_ignored() {
        let mut bm = BufferManager::new(16 * 1024, 7);
        let a = bm.alloc(100).unwrap();
        bm.release(a);
        bm.release(a); // double release: must not poison the free list
        bm.release(TensorHandle { offset: 5000, len: 64, id: 999 }); // fabricated
        let b = bm.alloc(100).unwrap();
        // stale handle whose (offset, len) was re-allocated to `b`: the id
        // mismatch must protect b's live region from being freed
        assert_eq!(b.offset, a.offset);
        bm.release(a);
        let c = bm.alloc(100).unwrap();
        assert_ne!(b.offset, c.offset, "live regions must never alias");
        assert_eq!(bm.peak_usage(), 200);
    }

    #[test]
    fn out_of_memory_is_clean_error() {
        let mut bm = BufferManager::new(16 * 1024, 5);
        let cap = bm.capacity();
        let _a = bm.alloc(cap).unwrap();
        let err = bm.alloc(1).unwrap_err().to_string();
        assert!(err.contains("out of buffer memory"));
    }

    #[test]
    fn utilization_tracks_allocations() {
        let mut bm = BufferManager::new(16 * 1024, 6);
        assert_eq!(bm.utilization(), 0.0);
        let h = bm.alloc(bm.capacity() / 2).unwrap();
        assert!((bm.utilization() - 0.5).abs() < 0.01);
        bm.release(h);
        assert_eq!(bm.utilization(), 0.0);
    }

    #[test]
    fn i8_staging_roundtrips_without_conversion() {
        // the zero-copy path must behave byte-for-byte like store+load
        // through explicit u8 conversion — on SRAM (exact persistence)
        // that means an exact roundtrip including negative values
        let mut bm = BufferManager::from_spec(&BackendSpec::Sram, 16 * 1024, 3);
        let h = bm.alloc(256).unwrap();
        let data: Vec<i8> = (0..256).map(|i| (i as i64 - 128) as i8).collect();
        bm.store_i8(h, &data).unwrap();
        let back = bm.load_i8(h);
        assert_eq!(back, data);
        // and a sub-handle (continuous batching stages `real × dim` into a
        // prefix of the full-batch region) stores/loads the prefix only
        let sub = TensorHandle { offset: h.offset, len: 100, id: h.id };
        bm.store_i8(sub, &data[..100]).unwrap();
        assert_eq!(bm.load_i8(sub), data[..100].to_vec());
    }

    #[test]
    fn refresh_telemetry_tracks_the_slot_grid() {
        // mcaimem at the paper point runs manager-driven refresh
        let mut bm = BufferManager::new(16 * 1024, 4);
        let due0 = bm.next_refresh_due().expect("mcaimem needs refresh");
        assert!(due0 > 0.0);
        assert_eq!(bm.refresh_issued(), 0);
        // ticking past several slots fires them and advances the horizon
        bm.tick(due0 + bm.refresh.slot() * 2.5);
        assert!(bm.refresh_issued() >= 3);
        assert!(bm.next_refresh_due().unwrap() > bm.now());
        // SRAM needs none: the dispatcher sees an empty schedule
        let sram = BufferManager::from_spec(&BackendSpec::Sram, 16 * 1024, 4);
        assert_eq!(sram.next_refresh_due(), None);
    }
}
