//! Tensor-level buffer manager over the functional MCAIMem array.
//!
//! Owns allocation (bump allocator with free-list reuse — DNN buffers
//! allocate/release in layer order), the refresh controller wired to the
//! array's bank geometry, and the virtual clock. Every `store`/`load` goes
//! through the mixed-cell array's encoder + aging machinery, so anything
//! scheduled on top of this manager sees *physical* retention behaviour,
//! not a statistical abstraction.

use anyhow::{bail, Result};

use crate::mem::mcaimem::MixedCellMemory;
use crate::mem::refresh::RefreshController;

/// Handle to an allocated tensor region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TensorHandle {
    pub offset: usize,
    pub len: usize,
    pub id: u64,
}

/// The MCAIMem-backed buffer manager.
pub struct BufferManager {
    pub mem: MixedCellMemory,
    pub refresh: RefreshController,
    free: Vec<(usize, usize)>,      // (offset, len), sorted by offset
    allocated: Vec<(usize, usize)>, // live regions
    next_id: u64,
    now: f64,
}

impl BufferManager {
    /// A manager over `bytes` of mixed-cell memory at the paper's operating
    /// point (V_REF = 0.8 ⇒ 12.57 µs whole-array refresh).
    pub fn new(bytes: usize, seed: u64) -> Self {
        Self::with_vref(bytes, 0.8, seed)
    }

    pub fn with_vref(bytes: usize, vref: f64, seed: u64) -> Self {
        let mem = MixedCellMemory::with_vref(bytes, vref, seed);
        let t_ref = mem.card.refresh_period.expect("mcaimem refreshes");
        let rows = mem.map.bank.rows;
        BufferManager {
            refresh: RefreshController::new(rows, t_ref),
            mem,
            free: Vec::new(),
            allocated: Vec::new(),
            next_id: 0,
            now: 0.0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.mem.capacity()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance the virtual clock, firing any due refresh slots into the
    /// array (each slot refreshes one row across all banks in parallel).
    pub fn tick(&mut self, dt: f64) {
        assert!(dt >= 0.0);
        let target = self.now + dt;
        for op in self.refresh.advance(target) {
            // fire each slot at its own due time so row staleness never
            // exceeds t_ref even under coarse ticks
            self.mem.refresh_row(op.row, op.due);
        }
        self.mem.advance_to(target);
        self.now = target;
    }

    /// Allocate a tensor region (first-fit over the free list, else bump).
    pub fn alloc(&mut self, len: usize) -> Result<TensorHandle> {
        if len == 0 {
            bail!("zero-length allocation");
        }
        // first-fit
        if let Some(pos) = self.free.iter().position(|&(_, flen)| flen >= len) {
            let (off, flen) = self.free.remove(pos);
            if flen > len {
                self.free.push((off + len, flen - len));
                self.free.sort_unstable();
            }
            self.next_id += 1;
            self.allocated.push((off, len));
            return Ok(TensorHandle { offset: off, len, id: self.next_id });
        }
        // bump from the high-water mark (end of last free/used region)
        let used_end = self.high_water();
        if used_end + len > self.capacity() {
            bail!(
                "out of buffer memory: want {len} at {used_end}, capacity {}",
                self.capacity()
            );
        }
        self.allocated.push((used_end, len));
        self.next_id += 1;
        Ok(TensorHandle { offset: used_end, len, id: self.next_id })
    }

    /// Release a region for reuse.
    pub fn release(&mut self, h: TensorHandle) {
        if let Some(pos) = self.allocated.iter().position(|&(o, l)| o == h.offset && l == h.len) {
            self.allocated.remove(pos);
        }
        self.free.push((h.offset, h.len));
        self.free.sort_unstable();
        self.coalesce();
    }

    fn coalesce(&mut self) {
        let mut merged: Vec<(usize, usize)> = Vec::with_capacity(self.free.len());
        for &(off, len) in self.free.iter() {
            if let Some(last) = merged.last_mut() {
                if last.0 + last.1 == off {
                    last.1 += len;
                    continue;
                }
            }
            merged.push((off, len));
        }
        self.free = merged;
    }

    fn high_water(&self) -> usize {
        self.allocated
            .iter()
            .chain(self.free.iter())
            .map(|&(o, l)| o + l)
            .max()
            .unwrap_or(0)
    }

    /// Store tensor bytes at the current clock.
    pub fn store(&mut self, h: TensorHandle, data: &[u8]) -> Result<()> {
        if data.len() != h.len {
            bail!("store size mismatch: handle {} vs data {}", h.len, data.len());
        }
        self.mem.write(h.offset, data, self.now);
        Ok(())
    }

    /// Load tensor bytes at the current clock (ages + commits flips).
    pub fn load(&mut self, h: TensorHandle) -> Vec<u8> {
        self.mem.read(h.offset, h.len, self.now)
    }

    /// Fraction of capacity currently allocated.
    pub fn utilization(&self) -> f64 {
        let used: usize = self.allocated.iter().map(|&(_, l)| l).sum();
        used as f64 / self.capacity() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_load_roundtrip_fresh() {
        let mut bm = BufferManager::new(64 * 1024, 1);
        let h = bm.alloc(256).unwrap();
        let data: Vec<u8> = (0..=255).collect();
        bm.store(h, &data).unwrap();
        bm.tick(1e-6); // well inside retention
        assert_eq!(bm.load(h), data);
    }

    #[test]
    fn refresh_keeps_data_alive_indefinitely() {
        let mut bm = BufferManager::new(16 * 1024, 2);
        let h = bm.alloc(64).unwrap();
        let data = vec![0x05u8; 64]; // small positives — encoder-protected
        bm.store(h, &data).unwrap();
        // 100 ms in 1 µs ticks: ~8000 refresh periods
        for _ in 0..1000 {
            bm.tick(100e-6);
        }
        let back = bm.load(h);
        let errs = back.iter().zip(&data).filter(|(a, b)| a != b).count();
        assert!(errs <= 1, "errs={errs}");
        assert!(bm.refresh.issued > 1000, "refresh must have been running");
    }

    #[test]
    fn alloc_release_reuse() {
        let mut bm = BufferManager::new(16 * 1024, 3);
        let a = bm.alloc(1000).unwrap();
        let b = bm.alloc(2000).unwrap();
        assert!(b.offset >= a.offset + a.len);
        bm.release(a);
        let c = bm.alloc(500).unwrap();
        assert_eq!(c.offset, 0, "first-fit should reuse the freed region");
        let _ = b;
    }

    #[test]
    fn coalescing_merges_adjacent_frees() {
        let mut bm = BufferManager::new(16 * 1024, 4);
        let a = bm.alloc(100).unwrap();
        let b = bm.alloc(100).unwrap();
        bm.release(a);
        bm.release(b);
        assert_eq!(bm.free.len(), 1);
        assert_eq!(bm.free[0], (0, 200));
        let big = bm.alloc(200).unwrap();
        assert_eq!(big.offset, 0);
    }

    #[test]
    fn out_of_memory_is_clean_error() {
        let mut bm = BufferManager::new(16 * 1024, 5);
        let cap = bm.capacity();
        let _a = bm.alloc(cap).unwrap();
        let err = bm.alloc(1).unwrap_err().to_string();
        assert!(err.contains("out of buffer memory"));
    }

    #[test]
    fn utilization_tracks_allocations() {
        let mut bm = BufferManager::new(16 * 1024, 6);
        assert_eq!(bm.utilization(), 0.0);
        let h = bm.alloc(bm.capacity() / 2).unwrap();
        assert!((bm.utilization() - 0.5).abs() < 0.01);
        bm.release(h);
        assert_eq!(bm.utilization(), 0.0);
    }
}
