//! Coordinator metrics: request latencies, throughput, buffer health.

use std::time::{Duration, Instant};

/// Online latency/throughput accumulator.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<f64>,
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    /// Request payload bytes accepted into batches (throughput counter —
    /// with the word-parallel array this, not the store path, should bound
    /// serving rate).
    pub bytes_in: u64,
    /// Requests answered with an inference error (every pending request in
    /// a failed batch — never silently dropped).
    pub errors: u64,
    /// Wall clock of the first and latest activity — the serving window
    /// for sustained-rate figures (an idle tail before shutdown must not
    /// deflate the rates).
    started: Option<Instant>,
    last_activity: Option<Instant>,
}

impl Metrics {
    fn touch(&mut self) {
        let now = Instant::now();
        if self.started.is_none() {
            self.started = Some(now);
        }
        self.last_activity = Some(now);
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.touch();
        self.latencies_us.push(d.as_secs_f64() * 1e6);
        self.requests += 1;
    }

    pub fn record_batch(&mut self, real: usize, padded: usize) {
        self.touch();
        self.batches += 1;
        self.padded_slots += (padded - real) as u64;
    }

    pub fn record_bytes_in(&mut self, bytes: usize) {
        self.touch();
        self.bytes_in += bytes as u64;
    }

    /// A request answered with an error (failed batch). Counts toward the
    /// serving window but not toward latency quantiles.
    pub fn record_error(&mut self) {
        self.touch();
        self.errors += 1;
    }

    /// Fold another worker's accumulator into this one — how the pool
    /// aggregates per-worker metrics at shutdown. Latency samples concat;
    /// the serving window spans the union of both windows.
    pub fn merge(&mut self, other: &Metrics) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.requests += other.requests;
        self.batches += other.batches;
        self.padded_slots += other.padded_slots;
        self.bytes_in += other.bytes_in;
        self.errors += other.errors;
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.last_activity = match (self.last_activity, other.last_activity) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    /// Length of the serving window: first activity → latest activity
    /// (0 if nothing served yet).
    pub fn elapsed_s(&self) -> f64 {
        match (self.started, self.last_activity) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Sustained request rate (req/s) over the serving window.
    pub fn requests_per_s(&self) -> f64 {
        let dt = self.elapsed_s();
        if dt <= 0.0 {
            return 0.0;
        }
        self.requests as f64 / dt
    }

    /// Sustained inbound payload throughput (bytes/s) over the serving
    /// window.
    pub fn bytes_per_s(&self) -> f64 {
        let dt = self.elapsed_s();
        if dt <= 0.0 {
            return 0.0;
        }
        self.bytes_in as f64 / dt
    }

    pub fn p50_us(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99_us(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<f64>() / self.latencies_us.len() as f64
    }

    fn quantile(&self, q: f64) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        let mut xs = self.latencies_us.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        crate::util::stats::percentile_sorted(&xs, q * 100.0)
    }

    /// Batch-occupancy efficiency: fraction of executed slots that carried
    /// real requests.
    pub fn occupancy(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        let total = self.requests + self.padded_slots;
        self.requests as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_and_occupancy() {
        let mut m = Metrics::default();
        for us in [100u64, 200, 300, 400, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        m.record_batch(5, 8);
        assert_eq!(m.requests, 5);
        assert!((m.p50_us() - 300.0).abs() < 1.0);
        assert!(m.p99_us() > 900.0);
        assert!((m.occupancy() - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert_eq!(m.p50_us(), 0.0);
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.requests_per_s(), 0.0);
        assert_eq!(m.bytes_per_s(), 0.0);
    }

    #[test]
    fn merge_concats_samples_and_spans_windows() {
        let mut a = Metrics::default();
        a.record_latency(Duration::from_micros(100));
        a.record_batch(1, 4);
        std::thread::sleep(Duration::from_millis(2));
        let mut b = Metrics::default();
        b.record_latency(Duration::from_micros(300));
        b.record_error();
        a.merge(&b);
        assert_eq!(a.requests, 2);
        assert_eq!(a.errors, 1);
        assert_eq!(a.padded_slots, 3);
        assert!((a.mean_us() - 200.0).abs() < 1.0);
        // the merged window spans a's start to b's last activity
        assert!(a.elapsed_s() >= 0.002);
        let merged_into_empty = {
            let mut m = Metrics::default();
            m.merge(&a);
            m
        };
        assert_eq!(merged_into_empty.requests, 2);
        assert!(merged_into_empty.elapsed_s() > 0.0);
    }

    #[test]
    fn byte_throughput_uses_the_serving_window() {
        let mut m = Metrics::default();
        m.record_batch(2, 4);
        m.record_bytes_in(100);
        m.record_bytes_in(28);
        assert_eq!(m.bytes_in, 128);
        std::thread::sleep(Duration::from_millis(5));
        m.record_latency(Duration::from_micros(250)); // closes the window
        let active = m.elapsed_s();
        assert!(active > 0.0);
        assert!(m.bytes_per_s() > 0.0);
        // an idle tail after the last activity must not deflate the rates
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(m.elapsed_s(), active);
    }
}
