//! Coordinator metrics: request latencies, throughput, buffer health.

use std::time::Duration;

/// Online latency/throughput accumulator.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<f64>,
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
}

impl Metrics {
    pub fn record_latency(&mut self, d: Duration) {
        self.latencies_us.push(d.as_secs_f64() * 1e6);
        self.requests += 1;
    }

    pub fn record_batch(&mut self, real: usize, padded: usize) {
        self.batches += 1;
        self.padded_slots += (padded - real) as u64;
    }

    pub fn p50_us(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99_us(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<f64>() / self.latencies_us.len() as f64
    }

    fn quantile(&self, q: f64) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        let mut xs = self.latencies_us.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        crate::util::stats::percentile_sorted(&xs, q * 100.0)
    }

    /// Batch-occupancy efficiency: fraction of executed slots that carried
    /// real requests.
    pub fn occupancy(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        let total = self.requests + self.padded_slots;
        self.requests as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_and_occupancy() {
        let mut m = Metrics::default();
        for us in [100u64, 200, 300, 400, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        m.record_batch(5, 8);
        assert_eq!(m.requests, 5);
        assert!((m.p50_us() - 300.0).abs() < 1.0);
        assert!(m.p99_us() > 900.0);
        assert!((m.occupancy() - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert_eq!(m.p50_us(), 0.0);
        assert_eq!(m.occupancy(), 0.0);
    }
}
