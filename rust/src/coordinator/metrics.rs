//! Coordinator metrics: request latencies, throughput, buffer health.
//!
//! Quantiles are backed by [`LogHistogram`]s — exact counts with ≤ 1/32
//! relative bucket error, so p99/p99.9 are stable at any completion
//! count and merge exactly across workers. The seeded bounded
//! [`Reservoir`]s are kept purely for raw-sample dumps
//! ([`Metrics::raw_latency_samples`]); they no longer back any quantile.
//! Both structures are allocation-bounded, so a week-long soak holds the
//! same few KiB as a ten-second smoke. [`Metrics::registry`] snapshots
//! the accumulator into the unified [`Registry`] naming scheme — the one
//! aggregation path behind `ServerStats` exports.

use std::time::{Duration, Instant};

use crate::obs::{LogHistogram, Registry};
use crate::util::stats::Reservoir;

/// Online latency/throughput accumulator.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Raw latency samples (bounded, seeded) — kept only for sample dumps;
    /// quantiles read `latency_hist`.
    latencies_us: Reservoir,
    /// Exact-count log-bucketed latency distribution (the quantile path).
    latency_hist: LogHistogram,
    /// Per-request refresh-attributable stall (µs): the share of a
    /// request's latency spent waiting on eDRAM refresh slots that fired
    /// inside its dispatched batch window. A refresh-aware dispatcher
    /// pushes these to zero by paying the stall in inter-window slack.
    refresh_stall_hist: LogHistogram,
    /// Exact running sum of latency samples (the reservoir subsamples, so
    /// the mean is tracked separately).
    latency_sum_us: f64,
    /// Total refresh stall charged to requests (µs).
    pub refresh_stall_total_us: f64,
    /// Refresh stall absorbed in inter-window slack instead (µs) —
    /// the refresh work is still paid, just never inside a window.
    pub refresh_slack_total_us: f64,
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    /// Request payload bytes accepted into batches (throughput counter —
    /// with the word-parallel array this, not the store path, should bound
    /// serving rate).
    pub bytes_in: u64,
    /// Requests answered with an inference error (every pending request in
    /// a failed batch — never silently dropped).
    pub errors: u64,
    /// Wall clock of the first and latest activity — the serving window
    /// for sustained-rate figures (an idle tail before shutdown must not
    /// deflate the rates).
    started: Option<Instant>,
    last_activity: Option<Instant>,
}

impl Metrics {
    fn touch(&mut self) {
        let now = Instant::now();
        if self.started.is_none() {
            self.started = Some(now);
        }
        self.last_activity = Some(now);
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.touch();
        let us = d.as_secs_f64() * 1e6;
        self.latencies_us.push(us);
        self.latency_hist.record(us);
        self.latency_sum_us += us;
        self.requests += 1;
    }

    /// Refresh-attributable stall charged to one request (0 when its
    /// window was refresh-free or the dispatcher deferred the stall).
    pub fn record_refresh_stall(&mut self, us: f64) {
        self.refresh_stall_hist.record(us);
        self.refresh_stall_total_us += us;
    }

    /// Refresh stall paid in inter-window slack (refresh-aware dispatch).
    pub fn record_refresh_slack(&mut self, us: f64) {
        self.refresh_slack_total_us += us;
    }

    pub fn record_batch(&mut self, real: usize, executed: usize) {
        self.touch();
        self.batches += 1;
        self.padded_slots += executed.saturating_sub(real) as u64;
    }

    pub fn record_bytes_in(&mut self, bytes: usize) {
        self.touch();
        self.bytes_in += bytes as u64;
    }

    /// A request answered with an error (failed batch). Counts toward the
    /// serving window but not toward latency quantiles.
    pub fn record_error(&mut self) {
        self.touch();
        self.errors += 1;
    }

    /// Fold another worker's accumulator into this one — how the pool
    /// aggregates per-worker metrics at shutdown. Histograms merge exactly
    /// (bucket-wise count addition); the raw-sample reservoirs merge
    /// weight-preservingly; the serving window spans the union of both
    /// windows.
    pub fn merge(&mut self, other: &Metrics) {
        self.latencies_us.merge(&other.latencies_us);
        self.latency_hist.merge(&other.latency_hist);
        self.refresh_stall_hist.merge(&other.refresh_stall_hist);
        self.latency_sum_us += other.latency_sum_us;
        self.refresh_stall_total_us += other.refresh_stall_total_us;
        self.refresh_slack_total_us += other.refresh_slack_total_us;
        self.requests += other.requests;
        self.batches += other.batches;
        self.padded_slots += other.padded_slots;
        self.bytes_in += other.bytes_in;
        self.errors += other.errors;
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.last_activity = match (self.last_activity, other.last_activity) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    /// Length of the serving window: first activity → latest activity
    /// (0 if nothing served yet).
    pub fn elapsed_s(&self) -> f64 {
        match (self.started, self.last_activity) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Sustained request rate (req/s) over the serving window.
    pub fn requests_per_s(&self) -> f64 {
        let dt = self.elapsed_s();
        if dt <= 0.0 {
            return 0.0;
        }
        self.requests as f64 / dt
    }

    /// Sustained inbound payload throughput (bytes/s) over the serving
    /// window.
    pub fn bytes_per_s(&self) -> f64 {
        let dt = self.elapsed_s();
        if dt <= 0.0 {
            return 0.0;
        }
        self.bytes_in as f64 / dt
    }

    pub fn p50_us(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99_us(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Tail-of-the-tail latency — the SLO the refresh-aware dispatcher is
    /// judged on.
    pub fn p999_us(&self) -> f64 {
        self.quantile(0.999)
    }

    /// p99.9 of per-request refresh-attributable stall (µs).
    pub fn refresh_stall_p999_us(&self) -> f64 {
        self.refresh_stall_hist.quantile(0.999)
    }

    pub fn mean_us(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.latency_sum_us / self.requests as f64
    }

    fn quantile(&self, q: f64) -> f64 {
        self.latency_hist.quantile(q)
    }

    /// Whether quantile `q` is sample-starved: with fewer than
    /// `1/(1-q)` completions the tail bucket holds no genuine tail mass,
    /// so the estimate degenerates to the max sample. The report layer
    /// flags such cells rather than printing them as trustworthy.
    pub fn quantile_starved(&self, q: f64) -> bool {
        (self.requests as f64) * (1.0 - q) < 1.0
    }

    /// The retained raw latency samples (µs) — a bounded, seeded uniform
    /// subsample for dumps and plots. Quantiles do NOT read this; they
    /// come from the exact-count histogram.
    pub fn raw_latency_samples(&self) -> &[f64] {
        self.latencies_us.samples()
    }

    /// Full latency distribution (exact counts, log-bucketed).
    pub fn latency_hist(&self) -> &LogHistogram {
        &self.latency_hist
    }

    /// Full refresh-stall distribution (exact counts, log-bucketed).
    pub fn refresh_stall_hist(&self) -> &LogHistogram {
        &self.refresh_stall_hist
    }

    /// Snapshot into the unified metrics registry
    /// (`mcaimem_serving_*` names): counters for volume, gauges for
    /// rates, histograms for the latency/stall distributions. This is
    /// the one aggregation path `ServerStats` and the exporters read.
    pub fn registry(&self) -> Registry {
        let mut r = Registry::default();
        r.count("mcaimem_serving_requests_total", self.requests);
        r.count("mcaimem_serving_batches_total", self.batches);
        r.count("mcaimem_serving_padded_slots_total", self.padded_slots);
        r.count("mcaimem_serving_bytes_in_total", self.bytes_in);
        r.count("mcaimem_serving_errors_total", self.errors);
        r.gauge("mcaimem_serving_requests_per_s", self.requests_per_s());
        r.gauge("mcaimem_serving_bytes_per_s", self.bytes_per_s());
        r.gauge("mcaimem_serving_occupancy_ratio", self.occupancy());
        r.gauge("mcaimem_serving_window_s", self.elapsed_s());
        r.gauge("mcaimem_serving_refresh_stall_total_us", self.refresh_stall_total_us);
        r.gauge("mcaimem_serving_refresh_slack_total_us", self.refresh_slack_total_us);
        r.merge_hist("mcaimem_serving_latency_us", &self.latency_hist);
        r.merge_hist("mcaimem_serving_refresh_stall_us", &self.refresh_stall_hist);
        r
    }

    /// Batch-occupancy efficiency: fraction of executed slots that carried
    /// real requests.
    pub fn occupancy(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        let total = self.requests + self.padded_slots;
        self.requests as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_and_occupancy() {
        let mut m = Metrics::default();
        for us in [100u64, 200, 300, 400, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        m.record_batch(5, 8);
        assert_eq!(m.requests, 5);
        assert!((m.p50_us() - 300.0).abs() < 1.0);
        assert!(m.p99_us() > 900.0);
        assert!(m.p999_us() >= m.p99_us());
        assert!((m.occupancy() - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert_eq!(m.p50_us(), 0.0);
        assert_eq!(m.p999_us(), 0.0);
        assert_eq!(m.refresh_stall_p999_us(), 0.0);
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.requests_per_s(), 0.0);
        assert_eq!(m.bytes_per_s(), 0.0);
    }

    #[test]
    fn merge_concats_samples_and_spans_windows() {
        let mut a = Metrics::default();
        a.record_latency(Duration::from_micros(100));
        a.record_batch(1, 4);
        std::thread::sleep(Duration::from_millis(2));
        let mut b = Metrics::default();
        b.record_latency(Duration::from_micros(300));
        b.record_error();
        a.merge(&b);
        assert_eq!(a.requests, 2);
        assert_eq!(a.errors, 1);
        assert_eq!(a.padded_slots, 3);
        assert!((a.mean_us() - 200.0).abs() < 1.0);
        // the merged window spans a's start to b's last activity
        assert!(a.elapsed_s() >= 0.002);
        let merged_into_empty = {
            let mut m = Metrics::default();
            m.merge(&a);
            m
        };
        assert_eq!(merged_into_empty.requests, 2);
        assert!(merged_into_empty.elapsed_s() > 0.0);
    }

    #[test]
    fn byte_throughput_uses_the_serving_window() {
        let mut m = Metrics::default();
        m.record_batch(2, 4);
        m.record_bytes_in(100);
        m.record_bytes_in(28);
        assert_eq!(m.bytes_in, 128);
        std::thread::sleep(Duration::from_millis(5));
        m.record_latency(Duration::from_micros(250)); // closes the window
        let active = m.elapsed_s();
        assert!(active > 0.0);
        assert!(m.bytes_per_s() > 0.0);
        // an idle tail after the last activity must not deflate the rates
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(m.elapsed_s(), active);
    }

    #[test]
    fn long_runs_stay_allocation_bounded() {
        // the satellite regression: a million-request soak must not grow
        // the accumulator, and quantiles must stay meaningful
        let mut m = Metrics::default();
        let cap = Reservoir::default().capacity();
        for i in 0..200_000u64 {
            // latency ramp 1..=1000 µs, uniform
            m.record_latency(Duration::from_micros(1 + i % 1000));
            m.record_refresh_stall(if i % 10 == 0 { 50.0 } else { 0.0 });
        }
        assert_eq!(m.requests, 200_000);
        assert!(m.p99_us() > 900.0 && m.p99_us() <= 1000.0, "p99 {}", m.p99_us());
        assert!(m.p999_us() >= m.p99_us());
        assert!((m.mean_us() - 500.5).abs() < 1.0, "exact mean survives subsampling");
        assert!(m.refresh_stall_p999_us() >= 49.0, "stall tail visible");
        // the kept sample is bounded by the reservoir capacity
        let clone_probe = format!("{m:?}");
        assert!(clone_probe.len() < cap * 64, "debug repr bounded (no unbounded vecs)");

        // merging two long-run accumulators stays bounded and keeps the tail
        let m2 = m.clone();
        m.merge(&m2);
        assert_eq!(m.requests, 400_000);
        assert!(m.p999_us() >= m.p99_us());
        assert!(m.p99_us() > 850.0);
    }

    #[test]
    fn quantiles_come_from_the_histogram_not_the_reservoir() {
        // push far past the reservoir capacity with a distribution whose
        // tail a subsample can miss entirely: one 10 ms outlier in 100k
        let mut m = Metrics::default();
        for _ in 0..99_999u64 {
            m.record_latency(Duration::from_micros(100));
        }
        m.record_latency(Duration::from_micros(10_000));
        // rank ceil(0.999999·100000) = 100000 ⇒ the outlier bucket, ±1/32
        let q = m.quantile(0.999999);
        assert!(q > 9_000.0, "exact-count tail must see the outlier, got {q}");
        // raw samples stay bounded by the reservoir
        assert!(m.raw_latency_samples().len() <= Reservoir::default().capacity());
    }

    #[test]
    fn starved_quantiles_are_flagged() {
        let mut m = Metrics::default();
        for _ in 0..500 {
            m.record_latency(Duration::from_micros(100));
        }
        assert!(!m.quantile_starved(0.5));
        assert!(!m.quantile_starved(0.99)); // 500 * 0.01 = 5 ≥ 1
        assert!(m.quantile_starved(0.999)); // 500 * 0.001 = 0.5 < 1
    }

    #[test]
    fn registry_snapshot_carries_counters_and_distributions() {
        let mut m = Metrics::default();
        for us in [100u64, 200, 300] {
            m.record_latency(Duration::from_micros(us));
        }
        m.record_batch(3, 4);
        m.record_bytes_in(96);
        m.record_refresh_stall(25.0);
        let r = m.registry();
        assert_eq!(r.counter("mcaimem_serving_requests_total"), 3);
        assert_eq!(r.counter("mcaimem_serving_bytes_in_total"), 96);
        let h = r.hist("mcaimem_serving_latency_us").expect("latency hist exported");
        assert_eq!(h.count(), 3);
        let stall = r.gauge_value("mcaimem_serving_refresh_stall_total_us").unwrap();
        assert!((stall - 25.0).abs() < 1e-9);
        // merging two snapshots doubles counters and histogram mass
        let mut agg = r.clone();
        agg.merge(&m.registry());
        assert_eq!(agg.counter("mcaimem_serving_requests_total"), 6);
        assert_eq!(agg.hist("mcaimem_serving_latency_us").unwrap().count(), 6);
    }
}
