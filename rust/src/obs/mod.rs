//! Telemetry backbone: structured span tracing, a unified metrics
//! registry, and compile-time-gated profiling hooks.
//!
//! The paper's headline claims (48 % area, 3.4× energy vs SRAM) rest on
//! *attribution* — knowing where refresh energy, stall time and write
//! asymmetry land. This module makes that attribution observable at
//! runtime without perturbing it:
//!
//! * [`ring`] — bounded lock-free event rings ([`EventRing`]): typed
//!   events ([`Event`]) with stable ids and virtual-clock timestamps,
//!   multi-writer safe, overflow drops the oldest event and counts it.
//! * [`export`] — Chrome trace-event JSON (Perfetto-loadable): one track
//!   per worker / shard / tier so refresh windows visually interleave
//!   with batch windows (`mcaimem serve --trace-out trace.json`).
//! * [`hist`] — [`LogHistogram`], an HDR-style log-bucketed histogram
//!   with exact counts and ≤ 1/32 relative bucket error; the one
//!   quantile path behind `ServerStats` p99/p99.9.
//! * [`registry`] — [`Registry`], named counters/gauges/histograms
//!   snapshot-exportable as JSON and Prometheus text format.
//! * [`profile`] — scoped phase timers on the hot paths (transpose,
//!   encode, census, staging, refresh scan), compiled out entirely
//!   unless `--features obs-profile`.
//!
//! **Zero cost when disabled**: every producer holds an [`ObsSink`];
//! the disabled sink is a `None` branch — no allocation, no atomics, no
//! clock reads. **Deterministic**: event timestamps come from the
//! virtual device clock (backends, refresh) or a logical admission
//! sequence (the pool track) — never the wall clock — so traces are
//! diffable across runs under a fixed seed (single-worker runs are
//! byte-identical; multi-worker batch composition is inherently
//! scheduling-dependent).

pub mod export;
pub mod hist;
pub mod profile;
pub mod registry;
pub mod ring;

pub use hist::LogHistogram;
pub use registry::Registry;
pub use ring::EventRing;

use std::sync::Arc;

/// What happened. Span kinds carry a begin/end phase ([`Ph`]); the rest
/// are instants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Request admitted (pool track; `a` = request id, `b` = queue depth).
    Admit,
    /// Request rejected by admission control (pool track; `a` = seq).
    Reject,
    /// Staged store→tick→load pass (worker track; `a` = batch size).
    Stage,
    /// Engine inference (worker track; `a` = batch size).
    Infer,
    /// Reply delivered (worker track; `a` = request id, `b` = 1 on error).
    Reply,
    /// Manager refresh pass (worker track; `a` = rows due).
    RefreshPass,
    /// Modeled refresh stall on the request path (oblivious dispatch).
    RefreshStall,
    /// Modeled refresh stall absorbed in inter-window slack (aware).
    RefreshSlack,
    /// A fault-plan clause fired (`a` = [`fault_code`] value, `b` = detail).
    FaultFired,
    /// ECC scrubbing corrected cells during a refresh pass (`a` = count).
    EccCorrected,
    /// Tiered front fill from the back tier (`a` = block index).
    TierFill,
    /// Tiered dirty-victim write-back eviction (`a` = block index).
    TierEvict,
    /// Shard quarantined, buddy mirror took over (`a` = shard).
    ShardFailover,
    /// Replayed trace op (replay track; `a`/`b` per op kind).
    ReplayStore,
    ReplayLoad,
    ReplayTick,
    ReplayRefresh,
}

impl EventKind {
    /// Stable name used in the exported trace.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::Reject => "reject",
            EventKind::Stage => "stage",
            EventKind::Infer => "infer",
            EventKind::Reply => "reply",
            EventKind::RefreshPass => "refresh_pass",
            EventKind::RefreshStall => "refresh_stall",
            EventKind::RefreshSlack => "refresh_slack",
            EventKind::FaultFired => "fault_fired",
            EventKind::EccCorrected => "ecc_corrected",
            EventKind::TierFill => "tier_fill",
            EventKind::TierEvict => "tier_evict",
            EventKind::ShardFailover => "shard_failover",
            EventKind::ReplayStore => "store",
            EventKind::ReplayLoad => "load",
            EventKind::ReplayTick => "tick",
            EventKind::ReplayRefresh => "refresh_row",
        }
    }
}

/// Trace-event phase: span begin / span end / instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ph {
    B,
    E,
    I,
}

/// One fixed-size telemetry event. `Copy` so ring slots never own heap
/// state; `t_us` is virtual/logical microseconds (never wall clock).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    pub ph: Ph,
    /// Export track (see [`worker_track`] and friends).
    pub track: u32,
    /// Virtual or logical timestamp, µs.
    pub t_us: f64,
    /// Kind-specific payload (request id, rows due, shard, …).
    pub a: u64,
    pub b: u64,
}

impl Event {
    pub fn span_begin(kind: EventKind, track: u32, t_us: f64, a: u64, b: u64) -> Self {
        Event { kind, ph: Ph::B, track, t_us, a, b }
    }
    pub fn span_end(kind: EventKind, track: u32, t_us: f64, a: u64, b: u64) -> Self {
        Event { kind, ph: Ph::E, track, t_us, a, b }
    }
    pub fn instant(kind: EventKind, track: u32, t_us: f64, a: u64, b: u64) -> Self {
        Event { kind, ph: Ph::I, track, t_us, a, b }
    }
}

/// The pool (admission) track: logical submission-sequence timebase.
pub const TRACK_POOL: u32 = 0xFFFF;
/// Replay timeline tracks (`conform --replay --trace-out`).
pub const TRACK_REPLAY_OPS: u32 = 0x3000;
pub const TRACK_REPLAY_CLOCK: u32 = 0x3001;

/// Track of worker `k`.
pub fn worker_track(k: usize) -> u32 {
    k as u32
}
/// Track of global shard `s`.
pub fn shard_track(s: usize) -> u32 {
    0x1000 + s as u32
}
/// Track of tier `j` (0 = front, 1 = back).
pub fn tier_track(j: usize) -> u32 {
    0x2000 + j as u32
}

/// Human-readable track name (becomes the Perfetto thread name).
pub fn track_name(track: u32) -> String {
    match track {
        TRACK_POOL => "pool".to_string(),
        TRACK_REPLAY_OPS => "replay/ops".to_string(),
        TRACK_REPLAY_CLOCK => "replay/clock".to_string(),
        t if t >= 0x2000 => {
            if t == 0x2000 {
                "tier/front".to_string()
            } else {
                "tier/back".to_string()
            }
        }
        t if t >= 0x1000 => format!("shard/{}", t - 0x1000),
        t => format!("worker/{t}"),
    }
}

/// Stable codes for [`EventKind::FaultFired`] payloads.
pub mod fault_code {
    /// `shard-outage` clause fired (`b` = shard index).
    pub const SHARD_OUTAGE: u64 = 1;
    /// `refresh-stall` clause swallowed a refresh slot (`b` = row).
    pub const REFRESH_STALL: u64 = 2;
}

/// Default ring capacity (events) for CLI-enabled tracing.
pub const DEFAULT_RING_EVENTS: usize = 1 << 16;

/// A cheap, cloneable handle every producer holds. Disabled (the
/// default) it is a single `None` branch per emit — no allocation, no
/// atomic traffic — which is what the pinned zero-allocation test pins.
#[derive(Clone, Default)]
pub struct ObsSink {
    ring: Option<Arc<EventRing>>,
}

impl std::fmt::Debug for ObsSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ObsSink {{ enabled: {} }}", self.ring.is_some())
    }
}

impl ObsSink {
    /// The no-op sink (also `Default`).
    pub fn disabled() -> Self {
        ObsSink { ring: None }
    }

    /// An enabled sink over a shared ring of at least `capacity` events.
    pub fn enabled(capacity: usize) -> Self {
        ObsSink { ring: Some(Arc::new(EventRing::new(capacity))) }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.ring.is_some()
    }

    /// Record one event. Never allocates; never blocks.
    #[inline]
    pub fn emit(&self, ev: Event) {
        if let Some(r) = &self.ring {
            r.push(ev);
        }
    }

    /// Published events with their ring tickets (the per-ring sequence
    /// used as the tie-break under equal timestamps). Quiescent snapshot:
    /// call only after every producer has stopped (workers joined).
    pub fn events(&self) -> Vec<(u64, Event)> {
        match &self.ring {
            Some(r) => r.snapshot(),
            None => Vec::new(),
        }
    }

    /// Events lost to ring overflow (drop-oldest) or writer collisions.
    pub fn dropped_events(&self) -> u64 {
        self.ring.as_ref().map_or(0, |r| r.dropped())
    }
}
