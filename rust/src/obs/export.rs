//! Chrome trace-event JSON export (Perfetto-loadable).
//!
//! Emits the `{"traceEvents": [...]}` object format: one process
//! (`pid 1`, named "mcaimem"), one thread per track (`tid` = track id,
//! named via `thread_name` metadata — `worker/0`, `shard/3`,
//! `tier/front`, `pool`, `replay/ops`). Span events use `ph: "B"/"E"`,
//! instants `ph: "i"` (thread scope); timestamps are the events'
//! virtual/logical microseconds, so a fixed seed yields a diffable file.
//!
//! The exporter is defensive about ring overflow: events are sorted per
//! track by `(t_us, ticket)`, unmatched span ends (their begin was
//! overwritten) are dropped, and dangling begins are closed at the
//! track's last timestamp — the emitted file always satisfies the CI
//! schema check (well-formed, per-track monotone timestamps, balanced
//! B/E).

use std::collections::BTreeMap;
use std::path::Path;

use super::{track_name, Event, Ph};
use crate::util::json::Json;
use crate::Result;

fn event_json(ph: &str, track: u32, t_us: f64, ev: &Event) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(ev.kind.name().to_string())),
        ("ph", Json::Str(ph.to_string())),
        ("ts", Json::Num(t_us)),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(track as f64)),
    ];
    if ph == "i" {
        pairs.push(("s", Json::Str("t".to_string())));
    }
    // "E" events carry no args (matched by stack position); everything
    // else ships the typed payload
    if ph != "E" {
        pairs.push((
            "args",
            Json::obj(vec![("a", Json::Num(ev.a as f64)), ("b", Json::Num(ev.b as f64))]),
        ));
    }
    Json::obj(pairs)
}

fn thread_meta(track: u32) -> Json {
    Json::obj(vec![
        ("name", Json::Str("thread_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(track as f64)),
        (
            "args",
            Json::obj(vec![("name", Json::Str(track_name(track)))]),
        ),
    ])
}

/// Build the trace document from `(ticket, event)` pairs (what
/// [`super::ObsSink::events`] returns). `dropped` is the ring's overflow
/// count, recorded top-level so a truncated trace is self-describing.
pub fn chrome_trace(events: &[(u64, Event)], dropped: u64) -> Json {
    // group per track; sort by (t, ticket) so equal timestamps keep
    // emission order
    let mut tracks: BTreeMap<u32, Vec<&(u64, Event)>> = BTreeMap::new();
    for pair in events {
        tracks.entry(pair.1.track).or_default().push(pair);
    }
    let mut out = Vec::with_capacity(events.len() + tracks.len() + 1);
    out.push(Json::obj(vec![
        ("name", Json::Str("process_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(1.0)),
        ("args", Json::obj(vec![("name", Json::Str("mcaimem".to_string()))])),
    ]));
    for (&track, evs) in tracks.iter_mut() {
        evs.sort_by(|x, y| {
            x.1.t_us.partial_cmp(&y.1.t_us).unwrap_or(std::cmp::Ordering::Equal).then(x.0.cmp(&y.0))
        });
        out.push(thread_meta(track));
        // balance pass: overflow can orphan one side of a span — drop
        // end-without-begin, close begin-without-end at the last timestamp
        let mut open: Vec<&Event> = Vec::new();
        let mut last_t = 0.0f64;
        let mut emitted: Vec<Json> = Vec::with_capacity(evs.len());
        for &&(_, ref ev) in evs.iter() {
            last_t = last_t.max(ev.t_us);
            match ev.ph {
                Ph::I => emitted.push(event_json("i", track, ev.t_us, ev)),
                Ph::B => {
                    open.push(ev);
                    emitted.push(event_json("B", track, ev.t_us, ev));
                }
                Ph::E => match open.last() {
                    Some(b) if b.kind == ev.kind => {
                        open.pop();
                        emitted.push(event_json("E", track, ev.t_us, ev));
                    }
                    // mismatched or orphaned end: its begin fell out of the
                    // ring — dropping it keeps the track balanced
                    _ => {}
                },
            }
        }
        for b in open.iter().rev() {
            emitted.push(event_json("E", track, last_t, b));
        }
        out.extend(emitted);
    }
    Json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("dropped_events", Json::Num(dropped as f64)),
        ("traceEvents", Json::Arr(out)),
    ])
}

/// Write the trace file for a sink (pretty-printed, parent dirs created).
pub fn write_chrome_trace(path: &Path, sink: &super::ObsSink) -> Result<usize> {
    let events = sink.events();
    let n = events.len();
    let doc = chrome_trace(&events, sink.dropped_events());
    crate::util::json::save_pretty(path, &doc)?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{worker_track, Event, EventKind, ObsSink};

    #[test]
    fn tracks_are_named_sorted_and_balanced() {
        let sink = ObsSink::enabled(64);
        let w = worker_track(0);
        sink.emit(Event::span_begin(EventKind::Stage, w, 10.0, 4, 0));
        sink.emit(Event::span_end(EventKind::Stage, w, 20.0, 4, 0));
        sink.emit(Event::instant(EventKind::Reply, w, 20.0, 7, 0));
        // a dangling begin must be closed, an orphan end dropped
        sink.emit(Event::span_begin(EventKind::Infer, w, 25.0, 4, 0));
        sink.emit(Event::span_end(EventKind::RefreshPass, w, 30.0, 0, 0));
        let doc = chrome_trace(&sink.events(), sink.dropped_events());
        let text = doc.to_pretty();
        assert_eq!(Json::parse(&text).unwrap(), doc);
        let Json::Obj(top) = &doc else { panic!() };
        let Some(Json::Arr(evs)) = top.get("traceEvents") else { panic!() };
        let mut depth = 0i64;
        let mut last_ts = f64::NEG_INFINITY;
        for e in evs {
            let Json::Obj(o) = e else { panic!() };
            let ph = o.get("ph").and_then(|p| p.as_str()).unwrap();
            if ph == "M" {
                continue;
            }
            let ts = o.get("ts").and_then(|t| t.as_f64()).unwrap();
            assert!(ts >= last_ts, "timestamps must be monotone per track");
            last_ts = ts;
            match ph {
                "B" => depth += 1,
                "E" => depth -= 1,
                _ => {}
            }
            assert!(depth >= 0, "end before begin");
        }
        assert_eq!(depth, 0, "spans must balance");
        assert!(text.contains("worker/0"));
        assert!(text.contains("refresh_pass") == false, "orphan end must be dropped");
        assert!(text.contains("infer"), "dangling begin survives, closed at last ts");
    }
}
