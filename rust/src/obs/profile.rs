//! Scoped phase timers for the hot paths — compiled out by default.
//!
//! With `--features obs-profile` each [`phase`] guard reads the monotonic
//! clock on entry and accumulates elapsed nanoseconds into per-phase
//! global counters on drop, gated by a runtime switch ([`enable`],
//! default off, so even an instrumented binary pays one relaxed atomic
//! load per hook until profiling is turned on). Without the feature every
//! function here is an empty `#[inline(always)]` stub and the guard is a
//! zero-sized type: the hook sites in the transpose/encode/census/
//! staging/refresh-scan paths vanish entirely — the default build adds
//! zero new symbols to the hot-path benches (asserted by the CI
//! `obs-smoke` job).
//!
//! Wall-clock durations are intentional here: profiling measures host
//! cost, unlike the tracing timeline which stays on the deterministic
//! virtual clock.

/// The instrumented hot-path phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// 8×64 SWAR bit-plane transpose.
    Transpose,
    /// One-enhancement encode/decode.
    Encode,
    /// Ones-census popcount.
    Census,
    /// Zero-copy batch staging (store→tick→load).
    Staging,
    /// Manager refresh-pass scan.
    RefreshScan,
}

/// Every phase, in display order.
pub const PHASES: [Phase; 5] =
    [Phase::Transpose, Phase::Encode, Phase::Census, Phase::Staging, Phase::RefreshScan];

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Transpose => "transpose",
            Phase::Encode => "encode",
            Phase::Census => "census",
            Phase::Staging => "staging",
            Phase::RefreshScan => "refresh_scan",
        }
    }

    fn idx(self) -> usize {
        match self {
            Phase::Transpose => 0,
            Phase::Encode => 1,
            Phase::Census => 2,
            Phase::Staging => 3,
            Phase::RefreshScan => 4,
        }
    }
}

/// One accumulated phase reading.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PhaseStat {
    pub phase: Phase,
    pub calls: u64,
    pub total_ns: u64,
}

#[cfg(feature = "obs-profile")]
mod imp {
    use super::{Phase, PhaseStat, PHASES};
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::time::Instant;

    static ENABLED: AtomicBool = AtomicBool::new(false);
    // const-item trick keeps this buildable on older toolchains
    #[allow(clippy::declare_interior_mutable_const)]
    const ZERO: AtomicU64 = AtomicU64::new(0);
    static CALLS: [AtomicU64; 5] = [ZERO; 5];
    static NANOS: [AtomicU64; 5] = [ZERO; 5];

    pub fn enable(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    pub fn reset() {
        for i in 0..PHASES.len() {
            CALLS[i].store(0, Ordering::Relaxed);
            NANOS[i].store(0, Ordering::Relaxed);
        }
    }

    /// RAII phase timer: accumulates on drop when profiling is enabled.
    pub struct PhaseTimer {
        phase: Phase,
        start: Option<Instant>,
    }

    #[inline]
    pub fn phase(p: Phase) -> PhaseTimer {
        PhaseTimer {
            phase: p,
            start: if enabled() { Some(Instant::now()) } else { None },
        }
    }

    impl Drop for PhaseTimer {
        fn drop(&mut self) {
            if let Some(t0) = self.start {
                let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
                let i = self.phase.idx();
                CALLS[i].fetch_add(1, Ordering::Relaxed);
                NANOS[i].fetch_add(ns, Ordering::Relaxed);
            }
        }
    }

    pub fn snapshot() -> Vec<PhaseStat> {
        PHASES
            .iter()
            .map(|&p| PhaseStat {
                phase: p,
                calls: CALLS[p.idx()].load(Ordering::Relaxed),
                total_ns: NANOS[p.idx()].load(Ordering::Relaxed),
            })
            .filter(|s| s.calls > 0)
            .collect()
    }
}

#[cfg(not(feature = "obs-profile"))]
mod imp {
    use super::{Phase, PhaseStat};

    /// Zero-sized stand-in; dropping it is a no-op the optimizer erases.
    pub struct PhaseTimer;

    #[inline(always)]
    pub fn phase(_p: Phase) -> PhaseTimer {
        PhaseTimer
    }

    #[inline(always)]
    pub fn enable(_on: bool) {}

    #[inline(always)]
    pub fn enabled() -> bool {
        false
    }

    #[inline(always)]
    pub fn reset() {}

    #[inline(always)]
    pub fn snapshot() -> Vec<PhaseStat> {
        Vec::new()
    }
}

pub use imp::{enable, enabled, phase, reset, snapshot, PhaseTimer};

/// Phase readings as a JSON array (rides into `BENCH_*.json` so the bench
/// gate can localize a regression to a phase). Empty array when the
/// feature is off or no phase fired.
pub fn snapshot_json() -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::Arr(
        snapshot()
            .into_iter()
            .map(|s| {
                Json::obj(vec![
                    ("phase", Json::Str(s.phase.name().to_string())),
                    ("calls", Json::Num(s.calls as f64)),
                    ("total_ns", Json::Num(s.total_ns as f64)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_profiling_snapshots_empty() {
        reset();
        {
            let _t = phase(Phase::Encode);
        }
        // without the feature: always empty; with it: disabled ⇒ no samples
        assert!(snapshot().is_empty());
    }

    #[cfg(feature = "obs-profile")]
    #[test]
    fn enabled_profiling_accumulates_calls() {
        reset();
        enable(true);
        for _ in 0..3 {
            let _t = phase(Phase::Transpose);
        }
        enable(false);
        let snap = snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].phase, Phase::Transpose);
        assert_eq!(snap[0].calls, 3);
        reset();
    }
}
