//! Log-bucketed histogram with exact counts and bounded relative error.
//!
//! HDR-style integer bucketing at precision 5 (32 sub-buckets per octave):
//! values below 64 get exact unit buckets; a value `v ≥ 64` lands in the
//! bucket addressed by its octave and top five mantissa bits, whose width
//! is `2^(octave-5)` — so the worst-case relative bucket error is 1/32
//! (~3.1 %). Counts are exact (no sampling), histograms merge by
//! element-wise addition, and quantiles interpolate within the bucket, so
//! p99/p99.9 stay stable at low completion counts where reservoir
//! sampling wobbles — the fidelity fix behind `ServerStats`.

/// Sub-bucket precision: 2^5 = 32 mantissa buckets per octave.
const PRECISION: u32 = 5;
const SUB: usize = 1 << PRECISION; // 32
/// Unit-bucket region: values below 2·SUB are exact.
const UNIT: u64 = (2 * SUB) as u64; // 64
/// Bucket count covering all of u64: 64 unit buckets + 58 octaves × 32.
const BUCKETS: usize = 2 * SUB + (63 - PRECISION as usize) * SUB; // 1920

/// Mergeable log-bucketed histogram over non-negative values (µs in this
/// crate). Exact total/sum/min/max; quantiles carry ≤ 1/32 relative
/// bucket error.
#[derive(Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl std::fmt::Debug for LogHistogram {
    // compact on purpose: Metrics' debug repr is pinned to stay bounded
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "LogHistogram {{ total: {}, min: {}, max: {}, p50: {:.1}, p99: {:.1} }}",
            self.total,
            if self.total == 0 { 0 } else { self.min },
            self.max,
            self.quantile(0.5),
            self.quantile(0.99)
        )
    }
}

/// Bucket index of value `v`.
fn index_of(v: u64) -> usize {
    if v < UNIT {
        return v as usize;
    }
    let o = 63 - v.leading_zeros(); // octave, ≥ 6
    let m = (v >> (o - PRECISION)) as usize; // mantissa in [32, 64)
    2 * SUB + (o as usize - 6) * SUB + (m - SUB)
}

/// Inclusive lower bound and width of bucket `idx`.
fn bucket_bounds(idx: usize) -> (u64, u64) {
    if idx < 2 * SUB {
        return (idx as u64, 1);
    }
    let o = 6 + (idx - 2 * SUB) / SUB;
    let m = (SUB + (idx - 2 * SUB) % SUB) as u64;
    (m << (o as u32 - PRECISION), 1u64 << (o as u32 - PRECISION))
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram { counts: vec![0; BUCKETS], total: 0, sum: 0.0, min: u64::MAX, max: 0 }
    }

    /// Worst-case relative quantile error from bucketing alone.
    pub fn relative_error() -> f64 {
        1.0 / SUB as f64
    }

    /// Record one non-negative value (fractional µs round to the nearest
    /// integer; negatives clamp to 0).
    #[inline]
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v.round() as u64 } else { 0 };
        self.record_u64(v);
    }

    #[inline]
    pub fn record_u64(&mut self, v: u64) {
        self.counts[index_of(v)] += 1;
        self.total += 1;
        self.sum += v as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Merge another histogram (element-wise count addition — the
    /// cross-worker aggregation path).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Quantile `q ∈ [0, 1]`: rank-walk over the exact counts, linear
    /// interpolation within the landing bucket, clamped to the observed
    /// [min, max]. Empty histogram → 0.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= rank {
                let (lower, width) = bucket_bounds(idx);
                let pos = (rank - cum - 1) as f64; // 0-based within bucket
                let est = lower as f64 + width as f64 * (pos + 0.5) / c as f64;
                return est.clamp(self.min as f64, self.max as f64);
            }
            cum += c;
        }
        self.max as f64
    }

    /// Non-empty buckets as `(lower_bound, width, count)` — the raw shape
    /// for machine-readable exports.
    pub fn buckets(&self) -> Vec<(u64, u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                let (lo, w) = bucket_bounds(i);
                (lo, w, c)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let mut h = LogHistogram::new();
        for v in [0u64, 1, 5, 63] {
            h.record_u64(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 63);
        // below 64 every bucket is a single integer, so quantiles are exact
        assert_eq!(h.quantile(0.5), 1.5); // rank 2 → bucket [1,2), mid 1.5
        assert_eq!(h.quantile(1.0), 63.5f64.min(63.0)); // clamped to max
    }

    #[test]
    fn bucket_error_is_bounded() {
        for v in [64u64, 100, 991, 4096, 123_456, u32::MAX as u64, 1 << 60] {
            let (lo, w) = bucket_bounds(index_of(v));
            assert!(lo <= v && v < lo + w, "v={v} lo={lo} w={w}");
            assert!(
                (w as f64) / (lo as f64) <= 1.0 / 32.0 + 1e-12,
                "relative width {} at v={v}",
                w as f64 / lo as f64
            );
        }
    }

    #[test]
    fn index_and_bounds_roundtrip_over_all_buckets() {
        for idx in 0..BUCKETS {
            let (lo, w) = bucket_bounds(idx);
            assert_eq!(index_of(lo), idx, "lower bound of {idx}");
            assert_eq!(index_of(lo + w - 1), idx, "upper edge of {idx}");
        }
    }

    #[test]
    fn uniform_ramp_quantiles_land_inside_the_right_bucket() {
        // the Metrics pinned workload: 1..=1000 µs, 200 of each
        let mut h = LogHistogram::new();
        for i in 0..200_000u64 {
            h.record_u64(1 + i % 1000);
        }
        let p99 = h.quantile(0.99);
        assert!(p99 > 900.0 && p99 <= 1000.0, "p99 {p99}");
        assert!(h.quantile(0.999) >= p99);
        assert!((h.mean() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn merge_equals_concatenation() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut both = LogHistogram::new();
        let mut x = 0x9E37_79B9_7F4A_7C15u64;
        for i in 0..4000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = x >> 40;
            if i % 2 == 0 {
                a.record_u64(v);
            } else {
                b.record_u64(v);
            }
            both.record_u64(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.min(), both.min());
        assert_eq!(a.max(), both.max());
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert_eq!(a.quantile(q), both.quantile(q), "q={q}");
        }
    }
}
