//! The unified metrics registry: named counters, gauges and log-bucketed
//! histograms behind one snapshot surface.
//!
//! Naming convention: `mcaimem_<tier>_<thing>_<unit>` — e.g.
//! `mcaimem_serving_requests_total`, `mcaimem_serving_latency_us`,
//! `mcaimem_mem_refresh_ops_total`. Counters are monotone `u64` totals
//! (`_total` suffix), gauges are point-in-time `f64` readings, histograms
//! are [`LogHistogram`]s (exact counts, ≤ 1/32 bucket error, mergeable).
//!
//! Registries merge across workers (counter add, gauge max, histogram
//! element-wise add) and export deterministically — `BTreeMap` keys — as
//! JSON ([`Registry::to_json`]) or Prometheus text exposition format
//! ([`Registry::to_prometheus`]).

use std::collections::BTreeMap;

use super::LogHistogram;
use crate::util::json::Json;

/// Named counters/gauges/histograms; the one aggregation path behind
/// `ServerStats` and `LoadReport` snapshots.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, LogHistogram>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Add to (creating at zero) a monotone counter.
    pub fn count(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Set a point-in-time gauge.
    pub fn gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Record one observation into a histogram.
    pub fn observe(&mut self, name: &str, value: f64) {
        self.hists.entry(name.to_string()).or_default().record(value);
    }

    /// Merge a whole pre-built histogram under `name` (worker hand-off).
    pub fn merge_hist(&mut self, name: &str, h: &LogHistogram) {
        self.hists.entry(name.to_string()).or_default().merge(h);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn hist(&self, name: &str) -> Option<&LogHistogram> {
        self.hists.get(name)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Merge another registry: counters add, gauges keep the maximum
    /// (the conservative cross-worker reading), histograms merge exactly.
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            let e = self.gauges.entry(k.clone()).or_insert(*v);
            *e = e.max(*v);
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    /// Deterministic JSON snapshot (sorted keys; histograms as summary
    /// quantiles plus the raw non-empty buckets).
    pub fn to_json(&self) -> Json {
        let counters =
            Json::Obj(self.counters.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect());
        let gauges =
            Json::Obj(self.gauges.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect());
        let hists = Json::Obj(
            self.hists
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(h.count() as f64)),
                            ("sum", Json::Num(h.sum())),
                            ("min", Json::Num(h.min() as f64)),
                            ("max", Json::Num(h.max() as f64)),
                            ("p50", Json::Num(h.quantile(0.5))),
                            ("p99", Json::Num(h.quantile(0.99))),
                            ("p999", Json::Num(h.quantile(0.999))),
                            (
                                "buckets",
                                Json::Arr(
                                    h.buckets()
                                        .into_iter()
                                        .map(|(lo, w, c)| {
                                            Json::Arr(vec![
                                                Json::Num(lo as f64),
                                                Json::Num(w as f64),
                                                Json::Num(c as f64),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![("counters", counters), ("gauges", gauges), ("histograms", hists)])
    }

    /// Prometheus text exposition format: counters and gauges verbatim,
    /// histograms as summaries (`{quantile="..."}` series + `_sum` /
    /// `_count`).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("# TYPE {k} counter\n{k} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("# TYPE {k} gauge\n{k} {v}\n"));
        }
        for (k, h) in &self.hists {
            out.push_str(&format!("# TYPE {k} summary\n"));
            for (label, q) in [("0.5", 0.5), ("0.99", 0.99), ("0.999", 0.999)] {
                out.push_str(&format!("{k}{{quantile=\"{label}\"}} {}\n", h.quantile(q)));
            }
            out.push_str(&format!("{k}_sum {}\n{k}_count {}\n", h.sum(), h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_hists_round_trip() {
        let mut r = Registry::new();
        r.count("mcaimem_serving_requests_total", 5);
        r.count("mcaimem_serving_requests_total", 3);
        r.gauge("mcaimem_serving_occupancy", 0.75);
        for v in [100.0, 200.0, 300.0] {
            r.observe("mcaimem_serving_latency_us", v);
        }
        assert_eq!(r.counter("mcaimem_serving_requests_total"), 8);
        assert_eq!(r.gauge_value("mcaimem_serving_occupancy"), Some(0.75));
        assert_eq!(r.hist("mcaimem_serving_latency_us").unwrap().count(), 3);

        let doc = r.to_json();
        let parsed = Json::parse(&doc.to_pretty()).unwrap();
        assert_eq!(parsed, doc);

        let prom = r.to_prometheus();
        assert!(prom.contains("# TYPE mcaimem_serving_requests_total counter"));
        assert!(prom.contains("mcaimem_serving_requests_total 8"));
        assert!(prom.contains("mcaimem_serving_latency_us{quantile=\"0.99\"}"));
        assert!(prom.contains("mcaimem_serving_latency_us_count 3"));
    }

    #[test]
    fn merge_adds_counters_and_histograms() {
        let mut a = Registry::new();
        let mut b = Registry::new();
        a.count("x_total", 1);
        b.count("x_total", 2);
        a.gauge("g", 1.0);
        b.gauge("g", 3.0);
        a.observe("h_us", 10.0);
        b.observe("h_us", 20.0);
        a.merge(&b);
        assert_eq!(a.counter("x_total"), 3);
        assert_eq!(a.gauge_value("g"), Some(3.0));
        assert_eq!(a.hist("h_us").unwrap().count(), 2);
    }
}
