//! Bounded lock-free multi-writer event ring.
//!
//! Writers take a ticket from a shared counter and claim `slot = ticket mod
//! capacity` with a CAS that sets a BUSY bit before touching the payload, so
//! two writers lapping each other on the same slot can never interleave
//! (tear) a payload write — the loser drops its event and counts it. A
//! published newer ticket overwriting an older one is the ring's
//! drop-oldest overflow policy, also counted. Draining is a quiescent-time
//! operation (`snapshot` after all producers stopped): published slots are
//! returned sorted by ticket, which doubles as the per-ring sequence number
//! the exporter uses to tie-break equal timestamps.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

use super::Event;

/// Slot state: 0 = empty, `ticket + 1` = published, `BUSY | (ticket + 1)` =
/// a writer is mid-payload.
const BUSY: u64 = 1 << 63;

struct Slot {
    state: AtomicU64,
    ev: UnsafeCell<Event>,
}

/// Fixed-capacity (power-of-two) multi-writer event ring. Overflow keeps
/// the newest events and counts every drop.
pub struct EventRing {
    slots: Box<[Slot]>,
    mask: u64,
    head: AtomicU64,
    dropped: AtomicU64,
}

// SAFETY: the payload cell is only written while the slot's state holds the
// BUSY bit (claimed by exactly one writer via CAS), and only read by
// `snapshot`, which skips BUSY slots and is documented quiescent-time.
unsafe impl Sync for EventRing {}
unsafe impl Send for EventRing {}

impl EventRing {
    /// Ring holding at least `capacity` events (rounded up to a power of
    /// two, minimum 8).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(8).next_power_of_two();
        let mut slots = Vec::with_capacity(cap);
        let zero = Event {
            kind: super::EventKind::Admit,
            ph: super::Ph::I,
            track: 0,
            t_us: 0.0,
            a: 0,
            b: 0,
        };
        slots.resize_with(cap, || Slot {
            state: AtomicU64::new(0),
            ev: UnsafeCell::new(zero),
        });
        EventRing {
            slots: slots.into_boxed_slice(),
            mask: (cap - 1) as u64,
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events dropped: ring overflow (a newer event overwrote a published
    /// older one) plus writer collisions on a lapped slot.
    pub fn dropped(&self) -> u64 {
        self.dropped.fetch_add(0, Ordering::Relaxed)
    }

    /// Total events ever offered (published + dropped).
    pub fn offered(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Record one event. Lock-free, allocation-free; on a full ring the
    /// oldest event in the slot is replaced (and counted as dropped).
    pub fn push(&self, ev: Event) {
        let ticket = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket & self.mask) as usize];
        loop {
            let cur = slot.state.load(Ordering::Acquire);
            if cur & BUSY != 0 {
                // another writer owns this slot right now (we lapped it or
                // it lapped us): losing this event is the only way to keep
                // payload writes exclusive
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
            if cur > ticket + 1 {
                // a full lap already published a newer event here — ours is
                // the older one, so drop-oldest means dropping ours
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
            if slot
                .state
                .compare_exchange_weak(cur, BUSY | (ticket + 1), Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                if cur != 0 {
                    // overwrote a published older event: counted overflow
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
                // SAFETY: the BUSY bit makes this thread the slot's only
                // writer until the release store below.
                unsafe { *slot.ev.get() = ev };
                slot.state.store(ticket + 1, Ordering::Release);
                return;
            }
        }
    }

    /// Published events sorted by ticket. Quiescent-time: callers must
    /// ensure no writer is concurrently pushing (in this crate: after the
    /// worker pool has joined its threads). Slots still marked BUSY by a
    /// writer that never completed are skipped.
    pub fn snapshot(&self) -> Vec<(u64, Event)> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            let st = slot.state.load(Ordering::Acquire);
            if st != 0 && st & BUSY == 0 {
                // SAFETY: quiescent — no concurrent writer (see doc).
                out.push((st - 1, unsafe { *slot.ev.get() }));
            }
        }
        out.sort_by_key(|&(t, _)| t);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Event, EventKind, Ph};

    fn ev(a: u64) -> Event {
        Event { kind: EventKind::Reply, ph: Ph::I, track: 7, t_us: a as f64, a, b: a }
    }

    #[test]
    fn fills_and_snapshots_in_ticket_order() {
        let r = EventRing::new(16);
        for i in 0..10 {
            r.push(ev(i));
        }
        let got = r.snapshot();
        assert_eq!(got.len(), 10);
        assert_eq!(r.dropped(), 0);
        for (i, (ticket, e)) in got.iter().enumerate() {
            assert_eq!(*ticket, i as u64);
            assert_eq!(e.a, i as u64);
        }
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let r = EventRing::new(8);
        for i in 0..20 {
            r.push(ev(i));
        }
        let got = r.snapshot();
        assert_eq!(got.len(), 8);
        assert_eq!(r.dropped(), 12);
        assert_eq!(r.offered(), 20);
        // the survivors are exactly the newest 8, still in order
        let kept: Vec<u64> = got.iter().map(|(_, e)| e.a).collect();
        assert_eq!(kept, (12..20).collect::<Vec<_>>());
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(EventRing::new(100).capacity(), 128);
        assert_eq!(EventRing::new(1).capacity(), 8);
    }
}
