//! Descriptive statistics over Monte-Carlo populations.
//!
//! Used by the retention / SNM / write-yield simulations to summarize sample
//! populations the way the paper's figures do (means, spreads, percentiles,
//! histograms, empirical CDFs).

use crate::util::rng::SplitMix64;

/// Summary statistics of a sample population.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Population standard deviation (the MC populations here are complete).
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p01: f64,
    pub p99: f64,
}

/// Compute a [`Summary`]; returns `None` on an empty slice.
pub fn summarize(xs: &[f64]) -> Option<Summary> {
    if xs.is_empty() {
        return None;
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        median: percentile_sorted(&sorted, 50.0),
        p01: percentile_sorted(&sorted, 1.0),
        p99: percentile_sorted(&sorted, 99.0),
    })
}

/// Percentile (linear interpolation) of a pre-sorted slice, `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Percentile of an unsorted slice (sorts a copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Default capacity for serving-tier [`Reservoir`]s: large enough that
/// p99.9 over a window rests on ≥ 8 kept samples, small enough to sort in
/// microseconds at report time.
pub const DEFAULT_RESERVOIR_CAP: usize = 8192;

/// Seeded fixed-capacity reservoir sample (Vitter's Algorithm R, with the
/// replacement draw hashed from `(seed, index)` instead of a stateful RNG).
///
/// The serving hot path needs quantiles over unbounded sample streams —
/// queue depths, latencies — without unbounded memory and without a lock
/// held on every sample. Because the keep/replace decision for the `i`-th
/// offer depends only on `(seed, i)` ([`Reservoir::slot_for`]), a producer
/// can count offers with an atomic and take a lock **only** for the
/// `cap / i` fraction of offers that actually land, so the lock rate on a
/// shared reservoir decays toward zero as the stream grows. Each kept set
/// is a uniform without-replacement draw from the stream, so quantiles
/// over the kept samples estimate the stream's quantiles; below capacity
/// the sample *is* the stream and quantiles are exact.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    seed: u64,
    /// Offers seen (≥ `samples.len()`) — the Algorithm-R denominator and
    /// the merge weight.
    count: u64,
    samples: Vec<f64>,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir::new(DEFAULT_RESERVOIR_CAP, 0x5EED_0BA5)
    }
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Self {
        assert!(cap > 0, "reservoir capacity must be positive");
        Reservoir { cap, seed, count: 0, samples: Vec::new() }
    }

    /// Kept samples (unordered).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Total offers seen, including ones not kept.
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Where the `index`-th offer of a stream lands: `Some(slot)` to keep
    /// it (dense fill below capacity, hashed replacement above), `None` to
    /// drop it. Pure in `(seed, index, cap)` so callers sharing a
    /// reservoir across threads can decide *outside* the lock.
    pub fn slot_for(seed: u64, index: u64, cap: usize) -> Option<usize> {
        if index < cap as u64 {
            return Some(index as usize);
        }
        let mut sm = SplitMix64::new(seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let j = sm.next_u64() % (index + 1);
        if j < cap as u64 {
            Some(j as usize)
        } else {
            None
        }
    }

    /// Offer one sample (single-producer path).
    pub fn push(&mut self, x: f64) {
        let i = self.count;
        self.count += 1;
        if let Some(slot) = Self::slot_for(self.seed, i, self.cap) {
            self.place(slot, x);
        }
    }

    /// Write a sample into a slot chosen by [`Reservoir::slot_for`]
    /// (multi-producer path: the caller counts offers externally and only
    /// locks when a slot was drawn). Does not advance `count`.
    pub fn place(&mut self, slot: usize, x: f64) {
        if slot == self.samples.len() {
            self.samples.push(x);
        } else if slot < self.samples.len() {
            self.samples[slot] = x;
        }
        // slot > len only if offers were mis-counted; dropping the sample
        // is the safe degradation
    }

    /// Fold another reservoir in, preserving quantile weight: below joint
    /// capacity the kept sets concatenate losslessly; above it each merged
    /// slot draws from either side with probability proportional to its
    /// stream length (deterministic under this reservoir's seed).
    pub fn merge(&mut self, other: &Reservoir) {
        if other.samples.is_empty() {
            self.count += other.count;
            return;
        }
        let na = self.count.max(self.samples.len() as u64);
        let nb = other.count.max(other.samples.len() as u64);
        if self.samples.len() + other.samples.len() <= self.cap {
            self.samples.extend_from_slice(&other.samples);
            self.count = na + nb;
            return;
        }
        let mut sm = SplitMix64::new(self.seed ^ na.rotate_left(32) ^ nb);
        let wa = na as f64 / (na + nb) as f64;
        let mut merged = Vec::with_capacity(self.cap);
        let (mut ia, mut ib) = (0usize, 0usize);
        while merged.len() < self.cap && (ia < self.samples.len() || ib < other.samples.len()) {
            let u = (sm.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
            let from_a = if ia >= self.samples.len() {
                false
            } else if ib >= other.samples.len() {
                true
            } else {
                u < wa
            };
            if from_a {
                merged.push(self.samples[ia]);
                ia += 1;
            } else {
                merged.push(other.samples[ib]);
                ib += 1;
            }
        }
        self.samples = merged;
        self.count = na + nb;
    }

    /// Quantile of the kept sample, `q` in [0, 1]; 0.0 when empty. The
    /// sort is bounded by the capacity, so this is report-time cheap no
    /// matter how long the stream ran.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&sorted, q * 100.0)
    }
}

/// Fixed-width histogram over [lo, hi); values outside are clamped to the
/// edge bins (matches how the paper's retention histograms are drawn).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn from_samples(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Self {
        let mut h = Histogram::new(lo, hi, bins);
        for &x in xs {
            h.add(x);
        }
        h
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * bins as f64) as i64;
        let idx = idx.clamp(0, bins as i64 - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Bin centers, aligned with `counts`.
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + (i as f64 + 0.5) * w)
            .collect()
    }

    /// Normalized densities (fraction per bin).
    pub fn densities(&self) -> Vec<f64> {
        let t = self.total().max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / t).collect()
    }
}

/// Empirical CDF evaluated at `x`: fraction of samples ≤ x.
pub fn ecdf(sorted: &[f64], x: f64) -> f64 {
    // binary search for rightmost index with value <= x
    let mut lo = 0usize;
    let mut hi = sorted.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if sorted[mid] <= x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo as f64 / sorted.len() as f64
}

/// Error function, Abramowitz & Stegun 7.1.26 (|ε| ≤ 1.5e-7) — enough for
/// the flip-probability CDFs, whose calibration anchors are 2-digit.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF Φ(z).
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Inverse standard normal CDF (Acklam's rational approximation, |ε|<1.2e-8
/// in the central region) — used to place Monte-Carlo quantile anchors.
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "quantile domain");
    // Coefficients for Peter Acklam's algorithm.
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Linear interpolation on a monotone (x, y) table; clamps outside the range.
pub fn interp(xs: &[f64], ys: &[f64], x: f64) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    if x <= xs[0] {
        return ys[0];
    }
    if x >= xs[xs.len() - 1] {
        return ys[ys.len() - 1];
    }
    let mut i = 0;
    while xs[i + 1] < x {
        i += 1;
    }
    let t = (x - xs[i]) / (xs[i + 1] - xs[i]);
    ys[i] * (1.0 - t) + ys[i + 1] * t
}

/// Inverse interpolation: find x where the monotone-increasing y(x) table
/// crosses `target`. Returns `None` if never crossed.
pub fn crossing(xs: &[f64], ys: &[f64], target: f64) -> Option<f64> {
    assert_eq!(xs.len(), ys.len());
    for i in 1..xs.len() {
        let (y0, y1) = (ys[i - 1], ys[i]);
        if (y0 <= target && y1 >= target) || (y0 >= target && y1 <= target) {
            if (y1 - y0).abs() < 1e-300 {
                return Some(xs[i - 1]);
            }
            let t = (target - y0) / (y1 - y0);
            return Some(xs[i - 1] + t * (xs[i] - xs[i - 1]));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = summarize(&[2.0; 10]).unwrap();
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(summarize(&[]).is_none());
    }

    #[test]
    fn summary_known_values() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_clamping() {
        let h = Histogram::from_samples(&[-1.0, 0.1, 0.5, 0.9, 2.0], 0.0, 1.0, 2);
        assert_eq!(h.counts, vec![2, 3]); // -1 clamps low; 0.5 rounds into bin 1; 2.0 clamps high
        assert_eq!(h.total(), 5);
    }

    #[test]
    fn histogram_centers() {
        let h = Histogram::new(0.0, 1.0, 4);
        let c = h.centers();
        assert!((c[0] - 0.125).abs() < 1e-12);
        assert!((c[3] - 0.875).abs() < 1e-12);
    }

    #[test]
    fn histogram_densities_sum_to_one() {
        let h = Histogram::from_samples(&[0.1, 0.2, 0.3, 0.7], 0.0, 1.0, 5);
        let total: f64 = h.densities().iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(ecdf(&xs, 0.5), 0.0);
        assert_eq!(ecdf(&xs, 2.0), 0.5);
        assert_eq!(ecdf(&xs, 10.0), 1.0);
    }

    #[test]
    fn interp_and_clamp() {
        let xs = [0.0, 1.0, 2.0];
        let ys = [0.0, 10.0, 40.0];
        assert_eq!(interp(&xs, &ys, -5.0), 0.0);
        assert_eq!(interp(&xs, &ys, 5.0), 40.0);
        assert!((interp(&xs, &ys, 0.5) - 5.0).abs() < 1e-12);
        assert!((interp(&xs, &ys, 1.5) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn erf_known_points() {
        assert!(erf(0.0).abs() < 1e-6);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779).abs() < 1e-6);
    }

    #[test]
    fn normal_cdf_symmetry() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-6);
        for z in [0.5, 1.0, 2.326] {
            assert!((normal_cdf(z) + normal_cdf(-z) - 1.0).abs() < 1e-7);
        }
        // 1% tail at z = -2.3263
        assert!((normal_cdf(-2.3263) - 0.01).abs() < 1e-4);
    }

    #[test]
    fn quantile_inverts_cdf() {
        for p in [0.001, 0.01, 0.25, 0.5, 0.75, 0.99, 0.999] {
            let z = normal_quantile(p);
            assert!((normal_cdf(z) - p).abs() < 1e-6, "p={p} z={z}");
        }
    }

    #[test]
    fn reservoir_below_capacity_matches_exact_quantiles() {
        // the satellite pin: at small n the reservoir *is* the stream, so
        // its p99 equals the exact quantile bit-for-bit
        let xs: Vec<f64> = (0..100).map(|i| ((i * 37) % 100) as f64).collect();
        let mut r = Reservoir::new(4096, 0x5EED);
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.len(), 100);
        assert_eq!(r.quantile(0.99), percentile(&xs, 99.0));
        assert_eq!(r.quantile(0.50), percentile(&xs, 50.0));
        assert_eq!(r.quantile(1.0), 99.0);
    }

    #[test]
    fn reservoir_is_bounded_and_deterministic_under_seed() {
        let mut a = Reservoir::new(512, 7);
        let mut b = Reservoir::new(512, 7);
        for i in 0..100_000u64 {
            a.push(i as f64);
            b.push(i as f64);
        }
        assert_eq!(a.len(), 512, "capacity bounds memory on long runs");
        assert_eq!(a.count(), 100_000);
        assert_eq!(a.samples(), b.samples(), "same seed, same stream → same kept set");
        // a uniform ramp keeps roughly uniform quantiles
        let p50 = a.quantile(0.50);
        assert!((p50 - 50_000.0).abs() < 10_000.0, "p50 {p50}");
    }

    #[test]
    fn reservoir_slot_decisions_are_pure_and_decay() {
        // dense prefix: every offer below capacity lands at its own index
        for i in 0..64u64 {
            assert_eq!(Reservoir::slot_for(1, i, 64), Some(i as usize));
        }
        // above capacity: keeps occur at ~cap/i rate, and the decision is
        // reproducible (the multi-producer contract)
        let hits: Vec<u64> =
            (64..6400).filter(|&i| Reservoir::slot_for(1, i, 64).is_some()).collect();
        assert!(!hits.is_empty() && hits.len() < 1000, "{} hits", hits.len());
        for &i in &hits {
            assert_eq!(Reservoir::slot_for(1, i, 64), Reservoir::slot_for(1, i, 64));
        }
    }

    #[test]
    fn reservoir_merge_preserves_quantile_weight() {
        // below joint capacity: lossless concat
        let mut a = Reservoir::new(1024, 1);
        let mut b = Reservoir::new(1024, 2);
        for i in 0..100 {
            a.push(i as f64);
            b.push(1000.0 + i as f64);
        }
        a.merge(&b);
        assert_eq!(a.len(), 200);
        assert_eq!(a.count(), 200);

        // above joint capacity: bounded, and a side with 9x the stream
        // keeps ~9x the slots so the merged quantiles stay meaningful
        let mut big = Reservoir::new(256, 3);
        let mut small = Reservoir::new(256, 4);
        for i in 0..90_000 {
            big.push(0.0 + (i % 100) as f64); // low population
        }
        for i in 0..10_000 {
            small.push(1000.0 + (i % 100) as f64); // high population
        }
        big.merge(&small);
        assert_eq!(big.len(), 256);
        assert_eq!(big.count(), 100_000);
        let high = big.samples().iter().filter(|&&x| x >= 1000.0).count() as f64;
        let frac = high / big.len() as f64;
        assert!((frac - 0.1).abs() < 0.08, "high-side weight {frac}, want ~0.1");
        assert!(big.quantile(0.99) >= 1000.0, "tail survives the merge");
    }

    #[test]
    fn crossing_finds_threshold() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [0.0, 0.1, 0.5, 0.9];
        let x = crossing(&xs, &ys, 0.3).unwrap();
        assert!((x - 1.5).abs() < 1e-12);
        assert!(crossing(&xs, &ys, 2.0).is_none());
    }
}
