//! ASCII table rendering + CSV output for the report commands.
//!
//! Every `mcaimem report <id>` command prints the paper's rows/series as an
//! aligned text table and mirrors them to `results/<id>.csv`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// An in-memory table: header row + data rows of strings.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table `{}`",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Render with box-drawing rules. First column left-aligned, numeric
    /// columns right-aligned (detected per column over data cells).
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let aligns: Vec<Align> = (0..ncols)
            .map(|i| {
                if i == 0 {
                    Align::Left
                } else if self.rows.iter().all(|r| looks_numeric(&r[i])) && !self.rows.is_empty() {
                    Align::Right
                } else {
                    Align::Left
                }
            })
            .collect();

        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "{}", self.title);
        }
        let sep = |out: &mut String| {
            out.push('+');
            for w in &widths {
                for _ in 0..w + 2 {
                    out.push('-');
                }
                out.push('+');
            }
            out.push('\n');
        };
        let render_row = |out: &mut String, cells: &[String]| {
            out.push('|');
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                out.push(' ');
                match aligns[i] {
                    Align::Left => {
                        out.push_str(c);
                        for _ in 0..pad {
                            out.push(' ');
                        }
                    }
                    Align::Right => {
                        for _ in 0..pad {
                            out.push(' ');
                        }
                        out.push_str(c);
                    }
                }
                out.push_str(" |");
            }
            out.push('\n');
        };
        sep(&mut out);
        render_row(&mut out, &self.header);
        sep(&mut out);
        for row in &self.rows {
            render_row(&mut out, row);
        }
        sep(&mut out);
        out
    }

    /// CSV serialization (RFC 4180 quoting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write the CSV mirror under `dir` (created if needed).
    pub fn write_csv(&self, dir: &Path, name: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{name}.csv")), self.to_csv())?;
        Ok(())
    }
}

fn looks_numeric(s: &str) -> bool {
    let t = s.trim_end_matches(['%', 'x', '×']);
    !t.is_empty() && t.parse::<f64>().is_ok()
}

/// Format a float with `digits` significant decimals, trimming zeros the way
/// the paper's tables print (e.g. `0.00016`, `19.29`, `3.4`).
pub fn fnum(x: f64, digits: usize) -> String {
    let s = format!("{:.*}", digits, x);
    if s.contains('.') {
        let t = s.trim_end_matches('0').trim_end_matches('.');
        t.to_string()
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "val"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("| name      |"));
        assert!(r.contains("| 1.5 |")); // right-aligned numeric
        assert!(r.contains("|  22 |"));
        assert!(r.lines().filter(|l| l.starts_with('+')).count() == 3);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_quotes_specials() {
        let mut t = Table::new("T", &["k", "v"]);
        t.row(vec!["a,b".into(), "say \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn fnum_trims() {
        assert_eq!(fnum(19.29, 2), "19.29");
        assert_eq!(fnum(3.40, 2), "3.4");
        assert_eq!(fnum(0.00016, 5), "0.00016");
        assert_eq!(fnum(5.0, 2), "5");
    }

    #[test]
    fn numeric_detection_handles_units() {
        assert!(looks_numeric("48%"));
        assert!(looks_numeric("3.4x"));
        assert!(!looks_numeric("SRAM"));
    }
}
