//! Engineering units used throughout the memory models.
//!
//! Internal convention (documented once here, relied on everywhere):
//! * time    — seconds
//! * energy  — joules
//! * power   — watts
//! * area    — square metres
//! * voltage — volts
//! * current — amperes
//! * capacitance — farads
//!
//! Paper-facing output uses µs / pJ / mW / µm² — the helpers here convert
//! and pretty-print with SI prefixes.

pub const NANO: f64 = 1e-9;
pub const MICRO: f64 = 1e-6;
pub const MILLI: f64 = 1e-3;
pub const PICO: f64 = 1e-12;
pub const FEMTO: f64 = 1e-15;
pub const KILO: f64 = 1e3;
pub const MEGA: f64 = 1e6;
pub const GIGA: f64 = 1e9;

/// Bytes per kibibyte/mebibyte (the paper's "108KB", "1MB", "8MB" are binary).
pub const KIB: usize = 1024;
pub const MIB: usize = 1024 * 1024;

/// Convert seconds → microseconds.
pub fn to_us(seconds: f64) -> f64 {
    seconds / MICRO
}

/// Convert joules → picojoules.
pub fn to_pj(joules: f64) -> f64 {
    joules / PICO
}

/// Convert watts → milliwatts.
pub fn to_mw(watts: f64) -> f64 {
    watts / MILLI
}

/// Convert m² → µm².
pub fn to_um2(m2: f64) -> f64 {
    m2 / (MICRO * MICRO)
}

/// Pretty-print a value with an SI prefix, e.g. `si(1.23e-5, "s") == "12.3 µs"`.
pub fn si(value: f64, unit: &str) -> String {
    if value == 0.0 {
        return format!("0 {unit}");
    }
    let prefixes: &[(f64, &str)] = &[
        (1e12, "T"),
        (1e9, "G"),
        (1e6, "M"),
        (1e3, "k"),
        (1.0, ""),
        (1e-3, "m"),
        (1e-6, "µ"),
        (1e-9, "n"),
        (1e-12, "p"),
        (1e-15, "f"),
        (1e-18, "a"),
    ];
    let mag = value.abs();
    for &(scale, p) in prefixes {
        if mag >= scale {
            return format!("{} {}{}", super::table::fnum(value / scale, 3), p, unit);
        }
    }
    format!("{value:e} {unit}")
}

/// Boltzmann constant (J/K) — used by the subthreshold slope model.
pub const K_BOLTZMANN: f64 = 1.380_649e-23;
/// Elementary charge (C).
pub const Q_ELECTRON: f64 = 1.602_176_634e-19;

/// Thermal voltage kT/q at a temperature in °C.
pub fn thermal_voltage(temp_c: f64) -> f64 {
    K_BOLTZMANN * (temp_c + 273.15) / Q_ELECTRON
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert!((to_us(12.57e-6) - 12.57).abs() < 1e-9);
        assert!((to_pj(0.08e-12) - 0.08).abs() < 1e-12);
        assert!((to_mw(19.29e-3) - 19.29).abs() < 1e-9);
        assert!((to_um2(35.2e-12) - 35.2).abs() < 1e-9);
    }

    #[test]
    fn si_prefix_selection() {
        assert_eq!(si(12.57e-6, "s"), "12.57 µs");
        assert_eq!(si(19.29e-3, "W"), "19.29 mW");
        assert_eq!(si(0.0, "J"), "0 J");
        assert_eq!(si(1.5e3, "Hz"), "1.5 kHz");
        assert_eq!(si(0.16e-12, "J"), "160 fJ");
    }

    #[test]
    fn thermal_voltage_at_room_and_hot() {
        let vt25 = thermal_voltage(25.0);
        let vt85 = thermal_voltage(85.0);
        assert!((vt25 - 0.0257).abs() < 0.0005, "vt25={vt25}");
        assert!(vt85 > vt25); // leakage worsens when hot
        assert!((vt85 - 0.0309).abs() < 0.0005, "vt85={vt85}");
    }
}
