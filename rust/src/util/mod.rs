//! Offline-environment substrates.
//!
//! The vendored crate set contains only the `xla` closure (no `rand`,
//! `serde`, `clap`, `criterion`, `proptest`), so the pieces a production
//! crate would normally pull from crates.io are implemented — and tested —
//! here: a PCG64 RNG with Gaussian/lognormal draws ([`rng`]), descriptive
//! statistics ([`stats`]), a JSON parser/writer for artifact manifests and
//! result files ([`json`]), ASCII table rendering for the report commands
//! ([`table`]), engineering-unit formatting ([`units`]), and a miniature
//! property-testing framework ([`check`]).

pub mod benchmark;
pub mod check;
pub mod json;
pub mod par;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
