//! Bench harness (criterion is not in the offline crate set).
//!
//! `cargo bench` targets are `harness = false` binaries that use this
//! module: warmup + timed iterations, ns/op statistics, and throughput
//! reporting, printed in a stable grep-friendly format:
//!
//! ```text
//! bench <name> ... iters=N mean=… p50=… min=… [thrpt=…]
//! ```

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let scale = |ns: f64| -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{:.0} ns", ns)
            }
        };
        let mut s = format!(
            "bench {:<44} iters={:<5} mean={:<12} p50={:<12} min={}",
            self.name,
            self.iters,
            scale(self.mean_ns),
            scale(self.p50_ns),
            scale(self.min_ns),
        );
        if let Some(e) = self.elems {
            let per_sec = e / (self.mean_ns / 1e9);
            s.push_str(&format!("  thrpt={:.2} Melem/s", per_sec / 1e6));
        }
        s
    }
}

/// Time `f` for `iters` iterations after `warmup` runs. The closure returns
/// a value that is black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: crate::util::stats::percentile_sorted(&samples, 50.0),
        min_ns: samples[0],
        elems: None,
    }
}

/// Like [`bench`] but annotates elements/iteration for throughput.
pub fn bench_throughput<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    elems: f64,
    f: impl FnMut() -> T,
) -> BenchResult {
    let mut r = bench(name, warmup, iters, f);
    r.elems = Some(elems);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 16, || (0..100u64).sum::<u64>());
        assert_eq!(r.iters, 16);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.mean_ns * 4.0);
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn throughput_reported() {
        let r = bench_throughput("thr", 1, 8, 1000.0, || 42u64);
        assert!(r.report().contains("Melem/s"));
    }
}
