//! Bench harness (criterion is not in the offline crate set).
//!
//! `cargo bench` targets are `harness = false` binaries that use this
//! module: warmup + timed iterations, ns/op statistics, and throughput
//! reporting, printed in a stable grep-friendly format:
//!
//! ```text
//! bench <name> ... iters=N mean=… p50=… min=… [thrpt=…]
//! ```
//!
//! For the perf trajectory across PRs, a [`BenchSuite`] collects results
//! and mirrors them to a machine-readable `BENCH_<suite>.json` (name,
//! ns/iter, elems/s) next to the human report.

use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let scale = |ns: f64| -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{:.0} ns", ns)
            }
        };
        let mut s = format!(
            "bench {:<44} iters={:<5} mean={:<12} p50={:<12} min={}",
            self.name,
            self.iters,
            scale(self.mean_ns),
            scale(self.p50_ns),
            scale(self.min_ns),
        );
        if let Some(e) = self.elems {
            let per_sec = e / (self.mean_ns / 1e9);
            s.push_str(&format!("  thrpt={:.2} Melem/s", per_sec / 1e6));
        }
        s
    }
}

/// Time `f` for `iters` iterations after `warmup` runs. The closure returns
/// a value that is black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: crate::util::stats::percentile_sorted(&samples, 50.0),
        min_ns: samples[0],
        elems: None,
    }
}

impl BenchResult {
    /// Elements per second (bytes/s for byte-counted benches); `None` when
    /// the bench carries no element count.
    pub fn elems_per_s(&self) -> Option<f64> {
        self.elems.map(|e| e / (self.mean_ns / 1e9))
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("ns_per_iter", Json::Num(self.mean_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("min_ns", Json::Num(self.min_ns)),
        ];
        if let Some(e) = self.elems {
            fields.push(("elems", Json::Num(e)));
            fields.push(("elems_per_s", Json::Num(self.elems_per_s().unwrap_or(0.0))));
        }
        Json::obj(fields)
    }
}

/// Collects [`BenchResult`]s and mirrors them to `BENCH_<suite>.json` — the
/// machine-readable perf trajectory tracked across PRs (see EXPERIMENTS.md
/// §Perf).
#[derive(Clone, Debug, Default)]
pub struct BenchSuite {
    pub suite: String,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(suite: &str) -> Self {
        BenchSuite { suite: suite.to_string(), results: Vec::new() }
    }

    /// Record a result, returning it for further use (printing, ratios).
    pub fn record(&mut self, r: BenchResult) -> BenchResult {
        self.results.push(r.clone());
        r
    }

    /// Mean-ns ratio of two recorded benches (`a_ns / b_ns`) — how the
    /// hotpath suite reports scalar-vs-word-parallel speedups.
    pub fn ratio(&self, slow: &str, fast: &str) -> Option<f64> {
        let find = |n: &str| self.results.iter().find(|r| r.name == n);
        Some(find(slow)?.mean_ns / find(fast)?.mean_ns)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("suite", Json::Str(self.suite.clone())),
            ("schema", Json::Num(1.0)),
            ("results", Json::Arr(self.results.iter().map(|r| r.to_json()).collect())),
            // per-phase host-cost breakdown; [] unless the bench binary was
            // built with --features obs-profile and switched profiling on,
            // so default-build artifacts are byte-stable modulo timings
            ("phases", crate::obs::profile::snapshot_json()),
        ])
    }

    /// Write `BENCH_<suite>.json` under `dir`. Best-effort: benches must
    /// not fail on a read-only FS.
    pub fn write_json(&self, dir: &Path) -> Option<std::path::PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.suite));
        match std::fs::write(&path, self.to_json().to_pretty()) {
            Ok(()) => Some(path),
            Err(e) => {
                eprintln!("bench: could not write {}: {e}", path.display());
                None
            }
        }
    }

    /// Write `BENCH_<suite>.json` at the repository root and log where it
    /// landed — the shared epilogue for every `harness = false` bench.
    ///
    /// Root resolution: the compile-time manifest dir's parent (the
    /// workspace root) when the binary still runs in the checkout it was
    /// built from — exact, and immune to stray `Cargo.toml`s above the
    /// repo. If that path no longer exists (relocated/prebuilt binary),
    /// fall back to the nearest enclosing cargo root from the CWD, else
    /// the CWD itself.
    pub fn write_json_at_repo_root(&self) -> Option<std::path::PathBuf> {
        let baked = Path::new(env!("CARGO_MANIFEST_DIR"));
        let root = if baked.join("Cargo.toml").exists() {
            baked
                .parent()
                .filter(|p| p.join("Cargo.toml").exists())
                .unwrap_or(baked)
                .to_path_buf()
        } else {
            let cwd = std::env::current_dir().unwrap_or_else(|_| ".".into());
            cwd.ancestors()
                .find(|a| a.join("Cargo.toml").exists())
                .unwrap_or(&cwd)
                .to_path_buf()
        };
        let written = self.write_json(&root);
        if let Some(p) = &written {
            println!("wrote {}", p.display());
        }
        written
    }
}

impl BenchSuite {
    /// Parse a `BENCH_<suite>.json` file back into a suite (the baseline
    /// side of [`compare`]). Tolerant of *partially* filled files: a result
    /// entry missing fields (a hand-seeded or placeholder baseline) loads
    /// with zero defaults instead of failing the whole gate — [`compare`]
    /// then sidelines zero-ns entries as skip-with-note. A file that does
    /// not parse as JSON, or that lacks the `results` array entirely
    /// (renamed key, truncation), is still a loud error.
    pub fn load_json(path: &Path) -> crate::Result<BenchSuite> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text)?;
        let field = |r: &Json, k: &str| r.get(k).ok().and_then(|v| v.as_f64()).unwrap_or(0.0);
        let suite = j
            .get("suite")
            .ok()
            .and_then(|s| s.as_str())
            .unwrap_or("unknown")
            .to_string();
        // the `results` key itself is NOT optional: a baseline without it
        // (renamed key, truncated file) is schema drift and must fail the
        // gate loudly — only fields *within* an entry are tolerated
        let results_json = j
            .get("results")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("`results` in {} is not an array", path.display()))?;
        let mut results = Vec::new();
        for r in results_json {
            results.push(BenchResult {
                name: r
                    .get("name")
                    .ok()
                    .and_then(|n| n.as_str())
                    .unwrap_or_default()
                    .to_string(),
                iters: field(r, "iters") as usize,
                mean_ns: field(r, "ns_per_iter"),
                p50_ns: field(r, "p50_ns"),
                min_ns: field(r, "min_ns"),
                elems: r.get("elems").ok().and_then(|e| e.as_f64()),
            });
        }
        Ok(BenchSuite { suite, results })
    }
}

/// One baseline-vs-current pair in a [`CompareReport`].
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub name: String,
    pub base_ns: f64,
    pub cur_ns: f64,
}

impl BenchDelta {
    /// Signed change in mean ns/iter: positive = slower than baseline.
    pub fn pct(&self) -> f64 {
        if self.base_ns <= 0.0 {
            return 0.0;
        }
        (self.cur_ns - self.base_ns) / self.base_ns * 100.0
    }
}

/// Baseline-vs-current comparison — the CI bench-regression gate.
#[derive(Clone, Debug, Default)]
pub struct CompareReport {
    pub deltas: Vec<BenchDelta>,
    /// Benches present in the baseline but not the current run.
    pub missing: Vec<String>,
    /// Benches present only in the current run (new, ungated).
    pub added: Vec<String>,
    /// Baseline entries that carry no usable measurement (zero/absent
    /// ns/iter — a partially filled or placeholder baseline). These are
    /// sidelined with a note instead of gating: only an entry with a real
    /// baseline number can regress. An *entirely* empty baseline skips the
    /// whole gate upstream; a partially empty one must not hard-fail it.
    pub skipped: Vec<String>,
}

impl CompareReport {
    /// Deltas slower than `pct` percent among benches whose name matches
    /// `filter` — the gate condition. Regressions only; speedups pass.
    pub fn regressions<'a>(
        &'a self,
        pct: f64,
        filter: impl Fn(&str) -> bool + 'a,
    ) -> Vec<&'a BenchDelta> {
        self.deltas.iter().filter(|d| filter(&d.name) && d.pct() > pct).collect()
    }

    /// Baseline benches matching `filter` that are absent from the current
    /// run. A renamed or deleted gated bench pairs with nothing, so
    /// [`Self::regressions`] (which only sees paired deltas) is blind to
    /// it — the gate must fail on these instead of greening on a vanished
    /// benchmark.
    pub fn gated_missing<'a>(&'a self, filter: impl Fn(&str) -> bool + 'a) -> Vec<&'a str> {
        self.missing.iter().map(String::as_str).filter(|n| filter(n)).collect()
    }

    /// The delta table, markdown-formatted (rendered into the CI job
    /// summary).
    pub fn markdown(&self) -> String {
        let mut s = String::from("| bench | baseline ns | current ns | delta |\n|---|---:|---:|---:|\n");
        for d in &self.deltas {
            s.push_str(&format!(
                "| {} | {:.0} | {:.0} | {}{:.1}% |\n",
                d.name,
                d.base_ns,
                d.cur_ns,
                if d.pct() > 0.0 { "+" } else { "" },
                d.pct()
            ));
        }
        for m in &self.missing {
            s.push_str(&format!("| {m} | — | *missing from current run* | |\n"));
        }
        for k in &self.skipped {
            s.push_str(&format!("| {k} | *no baseline measurement* | *skipped* | |\n"));
        }
        for a in &self.added {
            s.push_str(&format!("| {a} | *new* | | |\n"));
        }
        s
    }

    /// One-line note about entries the gate could not judge (skipped
    /// placeholder baselines, benches missing from the current run) —
    /// empty when every pair was compared for real.
    pub fn skip_note(&self) -> Option<String> {
        if self.skipped.is_empty() && self.missing.is_empty() {
            return None;
        }
        let mut parts = Vec::new();
        if !self.skipped.is_empty() {
            parts.push(format!(
                "{} baseline entr{} without a measurement skipped ({})",
                self.skipped.len(),
                if self.skipped.len() == 1 { "y" } else { "ies" },
                self.skipped.join(", ")
            ));
        }
        if !self.missing.is_empty() {
            parts.push(format!(
                "{} baseline bench(es) missing from this run ({})",
                self.missing.len(),
                self.missing.join(", ")
            ));
        }
        Some(format!(
            "bench gate note: {} — refresh the committed baseline from a full run",
            parts.join("; ")
        ))
    }
}

/// Pair up baseline and current results by bench name. Baseline entries
/// without a usable measurement (ns/iter ≤ 0 — placeholder or hand-seeded
/// partial files) land in `skipped`, not `deltas`: a partially empty
/// baseline degrades to skip-with-note exactly like the fully empty one,
/// never to a hard gate failure.
pub fn compare(baseline: &BenchSuite, current: &BenchSuite) -> CompareReport {
    let mut report = CompareReport::default();
    for b in &baseline.results {
        if b.mean_ns <= 0.0 {
            report.skipped.push(b.name.clone());
            continue;
        }
        match current.results.iter().find(|c| c.name == b.name) {
            Some(c) => report.deltas.push(BenchDelta {
                name: b.name.clone(),
                base_ns: b.mean_ns,
                cur_ns: c.mean_ns,
            }),
            None => report.missing.push(b.name.clone()),
        }
    }
    for c in &current.results {
        if !baseline.results.iter().any(|b| b.name == c.name) {
            report.added.push(c.name.clone());
        }
    }
    report
}

/// Like [`bench`] but annotates elements/iteration for throughput.
pub fn bench_throughput<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    elems: f64,
    f: impl FnMut() -> T,
) -> BenchResult {
    let mut r = bench(name, warmup, iters, f);
    r.elems = Some(elems);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 16, || (0..100u64).sum::<u64>());
        assert_eq!(r.iters, 16);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.mean_ns * 4.0);
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn throughput_reported() {
        let r = bench_throughput("thr", 1, 8, 1000.0, || 42u64);
        assert!(r.report().contains("Melem/s"));
        assert!(r.elems_per_s().unwrap() > 0.0);
    }

    fn res(name: &str, mean_ns: f64) -> BenchResult {
        BenchResult {
            name: name.into(),
            iters: 4,
            mean_ns,
            p50_ns: mean_ns,
            min_ns: mean_ns * 0.9,
            elems: None,
        }
    }

    #[test]
    fn compare_flags_regressions_not_speedups() {
        let mut base = BenchSuite::new("hotpath");
        base.record(res("mem::write 16KB (word-parallel)", 100.0));
        base.record(res("mem::read 16KB (fresh, word-parallel)", 100.0));
        base.record(res("rng::next_u64 ×1M", 50.0));
        base.record(res("gone", 10.0));
        let mut cur = BenchSuite::new("hotpath");
        cur.record(res("mem::write 16KB (word-parallel)", 120.0)); // +20% — regression
        cur.record(res("mem::read 16KB (fresh, word-parallel)", 80.0)); // −20% — speedup
        cur.record(res("rng::next_u64 ×1M", 200.0)); // +300% but filtered out
        cur.record(res("brand-new", 1.0));
        let rep = compare(&base, &cur);
        assert_eq!(rep.deltas.len(), 3);
        assert_eq!(rep.missing, vec!["gone".to_string()]);
        assert_eq!(rep.added, vec!["brand-new".to_string()]);
        let gate = rep.regressions(15.0, |n| n.contains("word-parallel"));
        assert_eq!(gate.len(), 1, "only the write regression trips the gate");
        assert_eq!(gate[0].name, "mem::write 16KB (word-parallel)");
        assert!((gate[0].pct() - 20.0).abs() < 1e-9);
        // within tolerance passes
        assert!(rep.regressions(25.0, |n| n.contains("word-parallel")).is_empty());
        let md = rep.markdown();
        assert!(md.contains("+20.0%"), "{md}");
        assert!(md.contains("missing from current run"), "{md}");
    }

    #[test]
    fn gated_missing_catches_a_renamed_gated_bench() {
        let mut base = BenchSuite::new("hotpath");
        base.record(res("mem::write 16KB (word-parallel)", 100.0));
        base.record(res("rng::next_u64 ×1M", 50.0));
        let mut cur = BenchSuite::new("hotpath");
        cur.record(res("mem::write 16KB (word-parallel v2)", 500.0)); // renamed
        cur.record(res("rng::next_u64 ×1M", 50.0));
        let rep = compare(&base, &cur);
        // the rename leaves no paired delta, so the regression filter alone
        // would wave a 5× slowdown through
        assert!(rep.regressions(15.0, |n| n.contains("word-parallel")).is_empty());
        assert_eq!(
            rep.gated_missing(|n| n.contains("word-parallel")),
            vec!["mem::write 16KB (word-parallel)"]
        );
        // ungated benches may come and go freely
        assert!(rep.gated_missing(|n| n.contains("refresh")).is_empty());
        // an intact bench set reports nothing
        let clean = compare(&base, &base);
        assert!(clean.gated_missing(|n| n.contains("word-parallel")).is_empty());
    }

    #[test]
    fn partially_empty_baseline_skips_with_note_instead_of_gating() {
        // a baseline whose entries carry no measurement (hand-seeded or
        // placeholder partial file) must sideline those entries — never
        // flag them as regressions, never hard-error
        let mut base = BenchSuite::new("hotpath");
        base.record(res("mem::write 16KB (word-parallel)", 100.0));
        base.record(res("mem::read 16KB (fresh, word-parallel)", 0.0)); // placeholder
        let mut cur = BenchSuite::new("hotpath");
        cur.record(res("mem::write 16KB (word-parallel)", 105.0));
        cur.record(res("mem::read 16KB (fresh, word-parallel)", 99999.0));
        let rep = compare(&base, &cur);
        assert_eq!(rep.deltas.len(), 1, "only the measured pair is gated");
        assert_eq!(rep.skipped, vec!["mem::read 16KB (fresh, word-parallel)".to_string()]);
        assert!(rep.regressions(15.0, |n| n.contains("word-parallel")).is_empty());
        let note = rep.skip_note().expect("skips must be surfaced");
        assert!(note.contains("without a measurement"), "{note}");
        assert!(rep.markdown().contains("no baseline measurement"), "{}", rep.markdown());
        // fully measured baselines carry no note
        let clean = compare(&cur, &cur);
        assert!(clean.skip_note().is_none());
        // a baseline where NOTHING is judgeable is distinguishable from the
        // partial case (the gate treats it as schema drift and fails):
        // deltas empty, skips present
        let mut dead = BenchSuite::new("hotpath");
        dead.record(res("mem::write 16KB (word-parallel)", 0.0));
        let drift = compare(&dead, &cur);
        assert!(drift.deltas.is_empty() && !drift.skipped.is_empty());
    }

    #[test]
    fn load_json_tolerates_missing_entry_fields() {
        // entries missing iters/p50/min (a partially filled baseline) must
        // load with defaults, not fail the gate before it starts
        let dir = std::env::temp_dir();
        let path = dir.join("BENCH_partial_gate_test.json");
        std::fs::write(
            &path,
            r#"{"suite": "hotpath", "results": [
                {"name": "only-name"},
                {"name": "with-ns", "ns_per_iter": 42.0}
            ]}"#,
        )
        .unwrap();
        let suite = BenchSuite::load_json(&path).unwrap();
        assert_eq!(suite.results.len(), 2);
        assert_eq!(suite.results[0].mean_ns, 0.0);
        assert_eq!(suite.results[1].mean_ns, 42.0);
        // but a baseline without the `results` key at all is schema drift
        // and must fail loudly, not load as an empty (gate-skipping) suite
        std::fs::write(&path, r#"{"suite": "hotpath"}"#).unwrap();
        assert!(BenchSuite::load_json(&path).is_err());
        // and through compare: the field-less entry is skipped, the real
        // one gates normally
        let mut cur = BenchSuite::new("hotpath");
        cur.record(res("only-name", 10.0));
        cur.record(res("with-ns", 43.0));
        let rep = compare(&suite, &cur);
        assert_eq!(rep.skipped, vec!["only-name".to_string()]);
        assert_eq!(rep.deltas.len(), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn suite_json_loads_back_for_comparison() {
        let mut suite = BenchSuite::new("gatesuite");
        suite.record(BenchResult {
            name: "x".into(),
            iters: 8,
            mean_ns: 123.0,
            p50_ns: 120.0,
            min_ns: 110.0,
            elems: Some(64.0),
        });
        let dir = std::env::temp_dir();
        let path = suite.write_json(&dir).unwrap();
        let back = BenchSuite::load_json(&path).unwrap();
        assert_eq!(back.suite, "gatesuite");
        assert_eq!(back.results.len(), 1);
        assert_eq!(back.results[0].mean_ns, 123.0);
        assert_eq!(back.results[0].elems, Some(64.0));
        let rep = compare(&suite, &back);
        assert!(rep.regressions(0.0, |_| true).is_empty());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn suite_json_roundtrips_and_ratios() {
        let mut suite = BenchSuite::new("testsuite");
        suite.record(BenchResult {
            name: "slow".into(),
            iters: 4,
            mean_ns: 200.0,
            p50_ns: 200.0,
            min_ns: 180.0,
            elems: Some(64.0),
        });
        suite.record(BenchResult {
            name: "fast".into(),
            iters: 4,
            mean_ns: 20.0,
            p50_ns: 20.0,
            min_ns: 19.0,
            elems: Some(64.0),
        });
        assert!((suite.ratio("slow", "fast").unwrap() - 10.0).abs() < 1e-12);
        assert!(suite.ratio("slow", "missing").is_none());
        let j = Json::parse(&suite.to_json().to_pretty()).unwrap();
        assert_eq!(j.get("suite").unwrap().as_str(), Some("testsuite"));
        let rs = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].get("name").unwrap().as_str(), Some("slow"));
        assert_eq!(rs[0].get("ns_per_iter").unwrap().as_f64(), Some(200.0));
        let dir = std::env::temp_dir();
        let path = suite.write_json(&dir).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back, suite.to_json());
        let _ = std::fs::remove_file(path);
    }
}
