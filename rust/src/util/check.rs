//! Miniature property-based testing framework.
//!
//! `proptest`/`quickcheck` are not in the offline crate set; this module
//! provides the subset the test suite needs: seeded generators, a `forall`
//! runner with iteration counts, and shrinking-free but *reproducible*
//! failure reports (the failing case index + seed are printed so a failure
//! replays exactly).

use super::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Seed fixed for CI reproducibility; override per-test when exploring.
        Config { cases: 256, seed: 0x4D43_41A1 }
    }
}

/// Run `prop` over `cases` generated inputs. Panics with the case index and
/// seed on the first counterexample.
pub fn forall<T, G, P>(cfg: Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Pcg64::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property falsified at case {case}/{} (seed {:#x})\ninput: {:?}",
                cfg.cases, cfg.seed, input
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so tests can
/// report *why* a case failed.
pub fn forall_explain<T, G, P>(cfg: Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Pcg64::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property falsified at case {case}/{} (seed {:#x}): {msg}\ninput: {:?}",
                cfg.cases, cfg.seed, input
            );
        }
    }
}

// ---- common generators ----------------------------------------------------

/// Vec of random bytes, length in [0, max_len].
pub fn bytes(rng: &mut Pcg64, max_len: usize) -> Vec<u8> {
    let len = rng.below(max_len as u64 + 1) as usize;
    let mut v = vec![0u8; len];
    rng.fill_bytes(&mut v);
    v
}

/// Vec of i8 drawn from a near-zero-clustered DNN-like distribution:
/// `round(N(0, sigma))` clamped to i8 — matches the paper's observation that
/// quantized DNN data clusters around zero (§II-B).
pub fn dnn_i8(rng: &mut Pcg64, len: usize, sigma: f64) -> Vec<i8> {
    (0..len)
        .map(|_| (rng.normal() * sigma).round().clamp(-128.0, 127.0) as i8)
        .collect()
}

/// Uniform i8 vector (worst case for the encoder).
pub fn uniform_i8(rng: &mut Pcg64, len: usize) -> Vec<i8> {
    (0..len).map(|_| rng.next_u64() as i8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_tautology() {
        forall(Config::default(), |r| r.next_u64(), |_| true);
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn forall_reports_counterexample() {
        forall(
            Config { cases: 50, seed: 1 },
            |r| r.below(10),
            |&x| x < 9, // will hit 9 within 50 cases
        );
    }

    #[test]
    #[should_panic(expected = "even")]
    fn forall_explain_includes_reason() {
        forall_explain(
            Config { cases: 50, seed: 1 },
            |r| r.below(4),
            |&x| {
                if x % 2 == 0 {
                    Err("even".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn dnn_i8_clusters_near_zero() {
        let mut r = Pcg64::new(5);
        let xs = dnn_i8(&mut r, 10_000, 10.0);
        let near = xs.iter().filter(|&&x| x.abs() <= 20).count();
        assert!(near as f64 / xs.len() as f64 > 0.9);
    }

    #[test]
    fn bytes_respects_max_len() {
        let mut r = Pcg64::new(6);
        for _ in 0..100 {
            assert!(bytes(&mut r, 17).len() <= 17);
        }
    }
}
