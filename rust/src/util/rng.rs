//! Deterministic pseudo-random number generation.
//!
//! Monte-Carlo retention analysis (paper §IV-B runs 100 000 samples at 85 °C)
//! needs a fast, reproducible generator with Gaussian and lognormal draws.
//! The offline crate set has no `rand`, so this module implements PCG64
//! (O'Neill, "PCG: A Family of Simple Fast Space-Efficient Statistically
//! Good Algorithms for Random Number Generation") seeded via SplitMix64,
//! plus the Box–Muller transform for normals.

/// PCG-XSL-RR 128/64 generator. 128-bit LCG state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream fixed, state whitened
    /// through SplitMix64 so nearby seeds give unrelated streams).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let i0 = sm.next_u64() as u128;
        let i1 = sm.next_u64() as u128;
        let mut rng = Pcg64 {
            state: (s0 << 64) | s1,
            inc: (((i0 << 64) | i1) << 1) | 1,
        };
        // advance once so the first output depends on the whole state
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (for per-thread / per-bank use).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }

    /// Next raw 64-bit output (XSL-RR output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased for
    /// the n ≪ 2^64 values used here).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal draw (Box–Muller; one value per call, the pair's
    /// second half is discarded to keep the state sequence simple).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean `mu`, standard deviation `sigma`.
    #[inline]
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Lognormal draw: exp(N(mu, sigma)). Process-variation leakage spreads
    /// are lognormal (leakage is exponential in a normal Vth shift).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// `k` decorrelated shard seeds derived from one master seed (SplitMix64
/// whitening) — the per-shard stream assignment for the parallel
/// Monte-Carlo sweeps in [`crate::util::par`]. Depends only on `seed` and
/// the shard index, never on thread scheduling, so sharded results are
/// reproducible on any machine.
pub fn shard_seeds(seed: u64, k: usize) -> Vec<u64> {
    let mut sm = SplitMix64::new(seed ^ 0x9E37_79B9_0000_5EED);
    (0..k).map(|_| sm.next_u64()).collect()
}

/// SplitMix64 — seeding/whitening generator (Steele et al.).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_stream_is_pinned_cross_platform() {
        // integer-only golden values (computed independently from the
        // PCG-XSL-RR 128/64 + SplitMix64 definitions): the anchor that the
        // seeded streams every campaign/loadgen schedule derives from are
        // identical on any platform, toolchain and run
        let mut r = Pcg64::new(42);
        assert_eq!(r.next_u64(), 0x5ca4_4894_240a_7a29);
        assert_eq!(r.next_u64(), 0xc25e_7cc8_40d3_82d5);
        assert_eq!(r.next_u64(), 0x7e55_b87e_5186_1083);
        assert_eq!(r.next_u64(), 0x8493_0f56_b153_348d);
        assert_eq!(
            shard_seeds(7, 3),
            vec![0x66b9_6e24_ad52_7df5, 0x88d9_1db1_da44_d4df, 0x7b46_4d9e_5cff_7792]
        );
    }

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_open_never_zero() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            assert!(r.f64_open() > 0.0 && r.f64_open() <= 1.0);
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        // median of lognormal(mu, sigma) is exp(mu)
        let mut r = Pcg64::new(17);
        let mut xs: Vec<f64> = (0..50_001).map(|_| r.lognormal(1.0, 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        assert!((median - 1.0f64.exp()).abs() / 1.0f64.exp() < 0.03, "median={median}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Pcg64::new(19);
        let n = 100_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.25)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Pcg64::new(29);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn shard_seeds_deterministic_and_distinct() {
        let a = shard_seeds(42, 32);
        let b = shard_seeds(42, 32);
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 32, "seeds must be distinct");
        // different master seed → unrelated shard seeds
        let c = shard_seeds(43, 32);
        assert!(a.iter().zip(&c).all(|(x, y)| x != y));
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Pcg64::new(31);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
