//! Minimal JSON parser / writer.
//!
//! Used for `artifacts/manifest.json` (written by the Python AOT path) and
//! for machine-readable result files under `results/`. `serde` is not in the
//! offline crate set, so this is a small recursive-descent implementation of
//! RFC 8259 (objects, arrays, strings with escapes, numbers, bool, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Err` with the key name when missing (manifest
    /// errors should say what's missing).
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| anyhow!("missing JSON key `{key}`"))
    }

    // -- construction helpers ---------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|&s| Json::Str(s.to_string())).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        // JSON has no NaN/Inf; emit null like most tolerant writers.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected `{}` at byte {}, found `{:?}`",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected byte {:?} at {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            // Surrogate pairs are not needed for manifests;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} found {:?}", other.map(|c| c as char)),
            }
        }
    }
}

/// Write `doc` pretty-printed to `path`, creating any missing parent
/// directories first. Every JSON artifact writer in the CLI (`explore
/// --json`, `compile --json`, conformance failure dumps) funnels through
/// here so `--json out/run7/frontier.json` works on a fresh checkout
/// instead of erroring on the absent directory.
pub fn save_pretty(path: &std::path::Path, doc: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| anyhow!("creating {}: {e}", parent.display()))?;
        }
    }
    std::fs::write(path, doc.to_pretty()).map_err(|e| anyhow!("writing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_pretty_creates_missing_parent_directories() {
        let dir = std::env::temp_dir().join(format!("mcaimem_json_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("deep/nested/artifact.json");
        let doc = Json::obj(vec![("hello", Json::Num(1.0))]);
        save_pretty(&path, &doc).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, doc.to_pretty());
        // and a second write over the now-existing tree still succeeds
        save_pretty(&path, &Json::Null).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "null\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0], Json::Num(1.0));
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string(), src);
    }

    #[test]
    fn roundtrip_pretty_reparses() {
        let v = Json::obj(vec![
            ("name", Json::Str("mcaimem".into())),
            ("vals", Json::arr_f64(&[1.0, 2.0, 3.5])),
            ("nested", Json::obj(vec![("k", Json::Bool(true))])),
        ]);
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn get_missing_key_errors_with_name() {
        let v = Json::parse("{}").unwrap();
        let err = v.get("model").unwrap_err().to_string();
        assert!(err.contains("model"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
