//! Deterministic fork–join helpers over `std::thread` (rayon is not in the
//! offline crate set).
//!
//! Monte-Carlo sweeps are embarrassingly parallel, but reproducibility is a
//! hard requirement (every figure is seeded). The scheme here: work splits
//! into a **fixed** shard count chosen by the caller — *not* derived from
//! the machine — each shard runs on its own scoped thread with its own
//! deterministic RNG substream (see [`crate::util::rng::shard_seeds`]), and
//! results are collected in shard order. Results are therefore identical on
//! a 1-core laptop and a 64-core server; only wall-clock changes.

use std::ops::Range;

/// Default shard count for Monte-Carlo sweeps. Fixed so results are
/// machine-independent; 16 keeps shards coarse enough to amortize thread
/// spawn while saturating typical core counts.
pub const MC_SHARDS: usize = 16;

/// Evaluate `f` over `shards` contiguous index ranges covering `0..n`,
/// one scoped thread per shard, and return the results in shard order.
///
/// `f(shard_index, range)` must depend only on its arguments (plus shared
/// read-only state) for the determinism guarantee to hold.
/// Shard work only when per-item cost × chunk size dwarfs a thread spawn
/// (~tens of µs): true for every current caller — `write_margin` solves
/// are ~0.1–1 ms each, retention draws come ≥4 k at a time. A single shard
/// runs inline with no spawn at all.
pub fn par_shards<T, F>(n: usize, shards: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Range<usize>) -> T + Sync,
{
    let shards = shards.clamp(1, n.max(1));
    let chunk = n.div_ceil(shards);
    if shards == 1 {
        return vec![f(0, 0..n)];
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..shards)
            .map(|i| {
                let f = &f;
                let lo = (i * chunk).min(n);
                let hi = ((i + 1) * chunk).min(n);
                s.spawn(move || f(i, lo..hi))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_exactly_once_in_order() {
        let parts = par_shards(103, 7, |i, r| (i, r.collect::<Vec<usize>>()));
        let mut all = Vec::new();
        for (k, (i, xs)) in parts.iter().enumerate() {
            assert_eq!(k, *i, "shard order preserved");
            all.extend(xs.iter().copied());
        }
        assert_eq!(all, (0..103).collect::<Vec<_>>());
    }

    #[test]
    fn result_independent_of_shard_granularity_for_pure_maps() {
        let sum = |shards: usize| -> u64 {
            par_shards(1000, shards, |_, r| r.map(|x| x as u64 * x as u64).sum::<u64>())
                .iter()
                .sum()
        };
        assert_eq!(sum(1), sum(16));
        assert_eq!(sum(16), sum(1000));
    }

    #[test]
    fn degenerate_sizes() {
        assert_eq!(par_shards(0, 16, |_, r| r.len()), vec![0]);
        assert_eq!(par_shards(3, 16, |_, r| r.len()).iter().sum::<usize>(), 3);
    }
}
