//! `mcaimem` — leader binary: experiment reports, event-driven simulation,
//! the batched inference server, and a self-test over the AOT artifacts.
//!
//! Every subcommand shares one `--backend` flag taking the repo-wide spec
//! grammar (`sram | edram2t | rram | mcaimem[@VREF[-noenc]]`, comma-list
//! where a sweep makes sense), so the same spec string selects the buffer
//! technology in closed-form reports, the event-driven scheduler, and the
//! serving path.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

use mcaimem::cli::ArgParser;
use mcaimem::coordinator::scheduler::simulate_inference;
use mcaimem::coordinator::server::{InferenceServer, ServerConfig};
use mcaimem::mem::backend::BackendSpec;
use mcaimem::runtime::executor::ModelRunner;
use mcaimem::scalesim::accelerator::AcceleratorConfig;
use mcaimem::scalesim::network;
use mcaimem::util::rng::Pcg64;
use mcaimem::util::table::{fnum, Table};

const USAGE: &str = "\
mcaimem — MCAIMem (mixed SRAM + eDRAM AI memory) reproduction

USAGE:
  mcaimem report <id|all> [--csv DIR] [--artifacts DIR] [--backend SPECS] [--quick]
      regenerate a paper table/figure (table1 table2 fig1 fig2 fig5 fig7
      fig9 fig11 fig12 fig13 fig14 fig15a fig15b fig16); --backend overrides
      the backend sweep of fig14/fig15a/fig15b
  mcaimem simulate --network NAME [--platform eyeriss|tpuv1] [--backend SPECS] [--seed N]
      event-driven inference through the functional buffer; SPECS may be a
      comma list — every backend runs the identical schedule and prints its
      energy meter and macro area
  mcaimem serve [--artifacts DIR] [--requests N] [--backend SPEC] [--p P] [--window-ms MS]
      run the batched inference server against a synthetic client load,
      storing tensors in the chosen backend
  mcaimem selftest [--artifacts DIR]
      cross-check the Rust and Pallas implementations through PJRT

BACKEND SPECS:
  sram | edram2t | rram | mcaimem[@VREF[-noenc]]     (default mcaimem@0.8)
  e.g. --backend sram,edram2t,rram,mcaimem@0.8,mcaimem@0.7-noenc
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &mcaimem::cli::ParsedArgs) -> PathBuf {
    args.get("artifacts").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// The shared `--backend` flag as a sweep list (default: the paper's
/// operating point).
fn backend_list(args: &mcaimem::cli::ParsedArgs) -> Result<Vec<BackendSpec>> {
    BackendSpec::parse_list(args.get("backend").unwrap_or("mcaimem@0.8"))
}

/// The shared `--backend` flag where exactly one spec makes sense.
fn backend_single(args: &mcaimem::cli::ParsedArgs) -> Result<BackendSpec> {
    let specs = backend_list(args)?;
    if specs.len() != 1 {
        bail!("this subcommand takes exactly one --backend spec, got {}", specs.len());
    }
    Ok(specs[0])
}

fn run() -> Result<()> {
    let parser = ArgParser::new(
        &[
            "csv", "artifacts", "network", "platform", "backend", "seed", "requests", "p",
            "window-ms",
        ],
        &["quick", "help"],
    );
    let args = parser.parse(std::env::args().skip(1))?;
    if args.has_flag("help") || args.positionals.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }

    match args.positionals[0].as_str() {
        "report" => {
            let id = args
                .positionals
                .get(1)
                .map(String::as_str)
                .unwrap_or("all");
            let csv = args.get("csv").map(PathBuf::from);
            let backends = args
                .get("backend")
                .map(BackendSpec::parse_list)
                .transpose()?;
            let art = artifacts_dir(&args);
            let art_opt = art.join("manifest.json").exists().then_some(art);
            mcaimem::report::run(
                id,
                art_opt.as_deref(),
                csv.as_deref(),
                args.has_flag("quick"),
                backends.as_deref(),
            )
        }
        "fig11" => {
            let art = artifacts_dir(&args);
            let csv = args.get("csv").map(PathBuf::from);
            mcaimem::report::run("fig11", Some(&art), csv.as_deref(), args.has_flag("quick"), None)
        }
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "selftest" => cmd_selftest(&args),
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

fn cmd_simulate(args: &mcaimem::cli::ParsedArgs) -> Result<()> {
    let name = args
        .get("network")
        .ok_or_else(|| anyhow::anyhow!("simulate needs --network (e.g. LeNet, ResNet50)"))?;
    let net = network::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown network `{name}`"))?;
    let acc = match args.get("platform").unwrap_or("eyeriss") {
        "eyeriss" => AcceleratorConfig::eyeriss(),
        "tpuv1" => AcceleratorConfig::tpuv1(),
        other => bail!("unknown platform `{other}`"),
    };
    let specs = backend_list(args)?;
    let seed = args.get_usize("seed", 42)? as u64;

    let mut t = Table::new(
        &format!(
            "event-driven buffer simulation — {} on {} ({} backend{}, identical schedule)",
            net.name,
            acc.name,
            specs.len(),
            if specs.len() == 1 { "" } else { "s" }
        ),
        &[
            "backend",
            "time (ms)",
            "static (µJ)",
            "refresh (µJ)",
            "dynamic (µJ)",
            "total (µJ)",
            "refresh ops",
            "flips",
            "area (mm²)",
        ],
    );
    for spec in &specs {
        let r = simulate_inference(&net, &acc, spec, seed)?;
        t.row(vec![
            spec.label(),
            fnum(r.sim_time_s * 1e3, 3),
            fnum(r.static_j * 1e6, 3),
            fnum(r.refresh_j * 1e6, 3),
            fnum(r.dynamic_j * 1e6, 3),
            fnum(r.total_j() * 1e6, 3),
            r.refresh_ops.to_string(),
            r.flips_committed.to_string(),
            fnum(r.area_m2 * 1e6, 3),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_serve(args: &mcaimem::cli::ParsedArgs) -> Result<()> {
    let art = artifacts_dir(args);
    let requests = args.get_usize("requests", 512)?;
    let backend = backend_single(args)?;
    let cfg = ServerConfig {
        batch_window: Duration::from_millis(args.get_usize("window-ms", 2)? as u64),
        backend,
        flip_p: args.get_f64("p", 0.01)?,
        seed: 0xD00D,
    };

    // load the exported test set as client traffic
    let runner = ModelRunner::new(&art)?;
    let x = runner.artifacts.tensor("x_test_i8")?.as_i8()?;
    let y = runner.artifacts.tensor("y_test_i32")?.as_i32()?;
    let dim = runner.artifacts.input_dim;
    drop(runner);

    println!(
        "starting server ({}, p={}, {requests} requests)...",
        cfg.backend.label(),
        cfg.flip_p
    );
    let server = InferenceServer::start(art, cfg)?;
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(requests);
    for i in 0..requests {
        let row = x[(i % (x.len() / dim)) * dim..][..dim].to_vec();
        rxs.push((i, server.submit(row)?));
    }
    let mut correct = 0usize;
    let total = requests;
    for (i, rx) in rxs {
        let (class, _lat) = rx.recv()?;
        if class as i32 == y[i % y.len()] {
            correct += 1;
        }
    }
    let elapsed = t0.elapsed();
    let stats = server.shutdown();
    println!(
        "served {} requests in {} ms",
        stats.requests,
        fnum(elapsed.as_secs_f64() * 1e3, 1)
    );
    println!(
        "  throughput : {} req/s client-side, {} req/s / {} KB/s worker-side",
        fnum(stats.requests as f64 / elapsed.as_secs_f64(), 0),
        fnum(stats.requests_per_s, 0),
        fnum(stats.bytes_per_s / 1024.0, 1)
    );
    println!(
        "  latency    : mean {} µs  p50 {} µs  p99 {} µs",
        fnum(stats.mean_latency_us, 0),
        fnum(stats.p50_latency_us, 0),
        fnum(stats.p99_latency_us, 0)
    );
    println!(
        "  batches    : {} (occupancy {})",
        stats.batches,
        fnum(stats.occupancy, 3)
    );
    println!("  accuracy   : {}", fnum(correct as f64 / total as f64, 4));
    Ok(())
}

fn cmd_selftest(args: &mcaimem::cli::ParsedArgs) -> Result<()> {
    let art = artifacts_dir(args);
    let mut runner = ModelRunner::new(&art)?;
    let mut rng = Pcg64::new(7);

    // 1) encoder: Pallas (through PJRT) vs the Rust implementation
    let n = 4096;
    let x: Vec<i8> = (0..n).map(|_| rng.next_u64() as i8).collect();
    let pallas_enc = runner.encode_only(&x)?;
    let rust_enc = mcaimem::encode::one_enhancement::encode(&x);
    anyhow::ensure!(pallas_enc == rust_enc, "encode mismatch between Pallas and Rust");
    println!("encode: Pallas == Rust over {n} random bytes OK");

    // 2) store path: encode→age→decode with a shared mask
    let mask = ModelRunner::draw_mask(&mut rng, n, 0.07);
    let pallas_rt = runner.encoder_roundtrip(&x, &mask)?;
    let mut rust_rt = x.clone();
    for (v, m) in rust_rt.iter_mut().zip(&mask) {
        let enc = mcaimem::encode::one_enhancement::encode_byte(*v as u8);
        let aged = enc | (*m as u8 & !enc & 0x7f);
        *v = mcaimem::encode::one_enhancement::decode_byte(aged) as i8;
    }
    anyhow::ensure!(pallas_rt == rust_rt, "store-path mismatch between Pallas and Rust");
    println!("mcaimem_store: Pallas == Rust with shared mask OK");

    // 3) model accuracy gates — served from an ideal (SRAM) buffer vs the
    // aged mixed-cell backends
    let clean = runner.accuracy(&BackendSpec::Sram, 0.0, 4, 1)?;
    anyhow::ensure!(
        (clean - runner.artifacts.int8_clean_acc).abs() < 0.05,
        "clean accuracy {clean} drifted from manifest {}",
        runner.artifacts.int8_clean_acc
    );
    println!(
        "clean accuracy {} matches manifest {} OK",
        fnum(clean, 4),
        fnum(runner.artifacts.int8_clean_acc, 4)
    );

    let enc = runner.accuracy(&BackendSpec::mcaimem_default(), 0.05, 4, 2)?;
    let noenc =
        runner.accuracy(&BackendSpec::Mcaimem { vref: 0.8, encode: false }, 0.05, 4, 2)?;
    anyhow::ensure!(enc > noenc, "one-enhancement must protect accuracy");
    println!(
        "p=5%: with one-enh {} > without {} OK",
        fnum(enc, 4),
        fnum(noenc, 4)
    );
    println!("selftest OK");
    Ok(())
}
