//! `mcaimem` — leader binary: experiment reports, event-driven simulation,
//! the sharded multi-worker serving tier, and a self-test over the AOT
//! artifacts.
//!
//! Every subcommand shares one `--backend` flag taking the repo-wide spec
//! grammar (`sram | edram2t | rram | mcaimem[@VREF[-noenc]][+ecc] |
//! sttmram[@ret=S] | sotmram[@ret=S] | tiered=FRONT:BYTES+BACK`,
//! comma-list where a sweep makes sense), so the same spec string selects
//! the buffer technology in closed-form reports, the event-driven
//! scheduler, and the serving path.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

use mcaimem::cli::ArgParser;
use mcaimem::coordinator::loadgen::{Arrival, LoadConfig, Tenant};
use mcaimem::coordinator::pool::{PoolConfig, WorkerPool};
use mcaimem::coordinator::scheduler::{simulate_inference, DispatchMode};
use mcaimem::mem::backend::BackendSpec;
use mcaimem::runtime::executor::ModelRunner;
use mcaimem::scalesim::accelerator::AcceleratorConfig;
use mcaimem::scalesim::network;
use mcaimem::util::rng::Pcg64;
use mcaimem::util::table::{fnum, Table};

const USAGE: &str = "\
mcaimem — MCAIMem (mixed SRAM + eDRAM AI memory) reproduction

USAGE:
  mcaimem report <id|all> [--csv DIR] [--artifacts DIR] [--backend SPECS] [--quick]
      regenerate a paper table/figure (table1 table2 fig1 fig2 fig5 fig7
      fig9 fig11 fig12 fig13 fig14 fig15a fig15b fig16); --backend overrides
      the backend sweep of fig14/fig15a/fig15b
  mcaimem simulate --network NAME [--platform eyeriss|tpuv1] [--backend SPECS] [--seed N]
                   [--json FILE]
      event-driven inference through the functional buffer; SPECS may be a
      comma list — every backend runs the identical schedule and prints its
      energy meter and macro area; --json mirrors the per-backend reports
      to a machine-readable file
  mcaimem explore [--space SPEC] [--strategy grid|random|halving] [--samples N]
                  [--network NAME] [--platform eyeriss|tpuv1] [--seed N]
                  [--fidelity N] [--json FILE] [--diff FILE] [--quick]
                  [--paper-gate] [--compiled]
      design-space exploration: expand the design grid (SPEC grammar:
      ratio=1..15,vref=0.6:0.9:0.05,enc=on,geom=256x64|512x64,shards=1,
      refresh=periodic|gated,ecc=off|on,tier=none|sram:16k|sram:32k|sram:64k
      — tier puts an SRAM write-back front in front of the array, the
      hierarchy axis of the tiered=... backend combinator), evaluate every
      point in parallel
      through the composed circuit/area/energy/scalesim models, and print the
      Pareto frontier + hypervolume. --json writes the frontier artifact;
      --diff compares against a previous artifact; --quick runs the small
      pinned CI grid and gates on the paper point staying on the frontier
      (--paper-gate adds the same gate to any run). --compiled evaluates
      through the macro compiler (structural per-block models) instead of
      the analytic cards and prints the analytic→compiled frontier diff
  mcaimem compile [--point POINT] [--bytes-kb KB] [--json FILE] [--table]
      compile one design point (the explore point grammar, e.g.
      ratio=7,vref=0.8 — unset axes take the paper's values) into a
      structural macro: tiled bitcell array, sized decoders/muxes, S/A and
      write-driver stripes, V_REF/encoder/ECC periphery, refresh domains,
      with area/energy/timing derived bottom-up per block. Prints the
      block-level breakdown (--table; default when no --json) and/or
      writes the deterministic netlist-summary artifact (--json)
  mcaimem serve [--backend SPEC] [--shards N] [--workers K] [--target-rps R]
                [--requests N] [--clients C] [--high-water H] [--buffer-kb KB]
                [--mix NET,NET] [--p P] [--window-ms MS] [--artifacts DIR]
                [--dispatch aware|oblivious] [--refresh-stall-us US]
                [--sweep] [--rates R1,R2,..] [--json FILE] [--quick] [--no-retry]
                [--trace-out FILE] [--metrics-out FILE]
      run the sharded multi-worker serving tier: K workers over N striped
      bank shards behind an event-loop dispatcher (per-worker parking,
      continuous batching) with admission control. --target-rps > 0 drives
      open-loop Poisson arrivals; otherwise C closed-loop clients (default
      4×K). --dispatch picks where the modeled refresh stall lands
      (aware = off the request path, the default) and --refresh-stall-us
      sets the stall per refresh slot (0 = off). --sweep prints the
      workers×shards saturation sweep; --rates holds the tier at fixed
      offered rates and reads the p99.9 SLO tail (--json writes either
      sweep's artifact; --quick shrinks them for CI). PJRT engines are used
      when --artifacts holds an export; otherwise a latency-faithful
      synthetic engine. --trace-out writes the run's span trace as Chrome
      trace-event JSON (open in Perfetto: one track per worker/shard plus
      the admission track); --metrics-out snapshots the unified metrics
      registry (.prom extension = Prometheus text, otherwise JSON).
  mcaimem conform [--backend SPECS] [--ops N] [--seed S] [--shards N]
                  [--bytes-kb KB] [--no-shrink] [--quick] [--save-dir DIR]
                  [--replay FILE] [--json FILE] [--trace-out FILE]
      seeded randomized conformance campaign: every backend must replay its
      own recorded trace exactly, and MCAIMem + tiered-over-leaf specs must
      match the golden model (sim::oracle) bit- and meter-exactly — flat
      and sharded (×N) geometries. Failures shrink (ddmin; disable with
      --no-shrink) to
      minimal reproducing traces saved under --save-dir. --quick bounds the
      run for CI (<30 s). --replay re-runs a saved failure trace (e.g. a
      CI artifact) locally; with --trace-out the replayed op timeline is
      also exported as Chrome trace-event JSON for Perfetto. --faults PLAN
      runs the whole campaign under a seeded fault schedule (see
      `mcaimem chaos`)
  mcaimem chaos [--faults PLAN] [--seed S] [--ops N] [--shards N] [--workers K]
                [--requests N] [--no-shrink] [--quick] [--save-dir DIR]
                [--replay FILE] [--json FILE] [--trace-out FILE]
      seeded chaos drill across both tiers: the conformance campaign under
      an active fault plan (mcaimem@0.8 and mcaimem@0.8+ecc, flat and
      sharded, fault-aware golden-oracle agreement) plus a degraded-mode
      serving pool (failover shard pairs, injected engine timeouts and one
      fatal crash) asserting zero lost replies. PLAN grammar:
      retention-tail@RATE,stuck-at[@D],vref-drift@P,refresh-stall@K,
      shard-outage@T[/S],engine-timeout@K,engine-crash@K,seed=N
      (default: all six fault classes). Failures ddmin-shrink to minimal
      traces under --save-dir; --replay re-runs one locally. --trace-out
      exports the drill's serving-tier span trace (or, with --replay, the
      replayed op timeline) as Chrome trace-event JSON
  mcaimem selftest [--artifacts DIR]
      cross-check the Rust and Pallas implementations through PJRT

BACKEND SPECS:
  sram | edram2t | rram | mcaimem[@VREF[-noenc]][+ecc]
       | sttmram[@ret=SECONDS] | sotmram[@ret=SECONDS]
       | tiered=FRONT:BYTES+BACK                      (default mcaimem@0.8)
  MRAM retention `ret` (default ~10 years) trades archival retention for
  cheaper, faster writes; `tiered=sram:32k+sotmram` puts a 32 KiB SRAM
  write-back buffer in front of a SOT-MRAM array (BYTES like 32k, 1m).
  e.g. --backend sram,mcaimem@0.8,sotmram@ret=1e-3,tiered=sram:32k+sotmram
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &mcaimem::cli::ParsedArgs) -> PathBuf {
    args.get("artifacts").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// The shared `--backend` flag as a sweep list (default: the paper's
/// operating point).
fn backend_list(args: &mcaimem::cli::ParsedArgs) -> Result<Vec<BackendSpec>> {
    Ok(BackendSpec::parse_list(args.get("backend").unwrap_or("mcaimem@0.8"))?)
}

/// The shared `--backend` flag where exactly one spec makes sense.
fn backend_single(args: &mcaimem::cli::ParsedArgs) -> Result<BackendSpec> {
    let mut specs = backend_list(args)?;
    if specs.len() != 1 {
        bail!("this subcommand takes exactly one --backend spec, got {}", specs.len());
    }
    Ok(specs.swap_remove(0))
}

fn run() -> Result<()> {
    let parser = ArgParser::new(
        &[
            "csv", "artifacts", "network", "platform", "backend", "seed", "requests", "p",
            "window-ms", "shards", "workers", "target-rps", "clients", "high-water",
            "buffer-kb", "mix", "ops", "bytes-kb", "save-dir", "replay", "json", "space",
            "strategy", "samples", "fidelity", "diff", "faults", "point", "rates",
            "dispatch", "refresh-stall-us", "trace-out", "metrics-out",
        ],
        &["quick", "help", "sweep", "no-retry", "no-shrink", "paper-gate", "compiled", "table"],
    );
    let args = parser.parse(std::env::args().skip(1))?;
    if args.has_flag("help") || args.positionals.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }

    match args.positionals[0].as_str() {
        "report" => {
            let id = args
                .positionals
                .get(1)
                .map(String::as_str)
                .unwrap_or("all");
            let csv = args.get("csv").map(PathBuf::from);
            let backends = args
                .get("backend")
                .map(BackendSpec::parse_list)
                .transpose()?;
            let art = artifacts_dir(&args);
            let art_opt = art.join("manifest.json").exists().then_some(art);
            mcaimem::report::run(
                id,
                art_opt.as_deref(),
                csv.as_deref(),
                args.has_flag("quick"),
                backends.as_deref(),
            )
        }
        "fig11" => {
            let art = artifacts_dir(&args);
            let csv = args.get("csv").map(PathBuf::from);
            mcaimem::report::run("fig11", Some(&art), csv.as_deref(), args.has_flag("quick"), None)
        }
        "simulate" => cmd_simulate(&args),
        "explore" => cmd_explore(&args),
        "compile" => cmd_compile(&args),
        "serve" => cmd_serve(&args),
        "conform" => cmd_conform(&args),
        "chaos" => cmd_chaos(&args),
        "selftest" => cmd_selftest(&args),
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

/// The shared `--platform` flag.
fn platform(args: &mcaimem::cli::ParsedArgs) -> Result<AcceleratorConfig> {
    Ok(match args.get("platform").unwrap_or("eyeriss") {
        "eyeriss" => AcceleratorConfig::eyeriss(),
        "tpuv1" => AcceleratorConfig::tpuv1(),
        other => bail!("unknown platform `{other}`"),
    })
}

fn cmd_simulate(args: &mcaimem::cli::ParsedArgs) -> Result<()> {
    let name = args
        .get("network")
        .ok_or_else(|| anyhow::anyhow!("simulate needs --network (e.g. LeNet, ResNet50)"))?;
    let net = network::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown network `{name}`"))?;
    let acc = platform(args)?;
    let specs = backend_list(args)?;
    let seed = args.get_usize("seed", 42)? as u64;

    let mut t = Table::new(
        &format!(
            "event-driven buffer simulation — {} on {} ({} backend{}, identical schedule)",
            net.name,
            acc.name,
            specs.len(),
            if specs.len() == 1 { "" } else { "s" }
        ),
        &[
            "backend",
            "time (ms)",
            "static (µJ)",
            "refresh (µJ)",
            "dynamic (µJ)",
            "total (µJ)",
            "refresh ops",
            "flips",
            "area (mm²)",
        ],
    );
    let mut reports = Vec::with_capacity(specs.len());
    for spec in &specs {
        let r = simulate_inference(&net, &acc, spec, seed)?;
        t.row(vec![
            spec.label(),
            fnum(r.sim_time_s * 1e3, 3),
            fnum(r.static_j * 1e6, 3),
            fnum(r.refresh_j * 1e6, 3),
            fnum(r.dynamic_j * 1e6, 3),
            fnum(r.total_j() * 1e6, 3),
            r.refresh_ops.to_string(),
            r.flips_committed.to_string(),
            fnum(r.area_m2 * 1e6, 3),
        ]);
        reports.push(r);
    }
    println!("{}", t.render());
    if let Some(path) = args.get("json") {
        use mcaimem::util::json::Json;
        let doc = Json::obj(vec![
            ("command", Json::Str("simulate".into())),
            ("network", Json::Str(net.name.into())),
            ("platform", Json::Str(acc.name.into())),
            ("seed", Json::Num(seed as f64)),
            ("reports", Json::Arr(reports.iter().map(|r| r.to_json()).collect())),
        ]);
        mcaimem::util::json::save_pretty(std::path::Path::new(path), &doc)?;
        println!("machine-readable report written to {path}");
    }
    Ok(())
}

fn cmd_explore(args: &mcaimem::cli::ParsedArgs) -> Result<()> {
    use mcaimem::dse::{search, EvalCache, EvalContext, Space};
    use mcaimem::report::pareto::{frontier_from_artifact, render_diff, ExploreOutcome};

    let quick = args.has_flag("quick");
    let spec = args
        .get("space")
        .unwrap_or(if quick { Space::QUICK } else { Space::DEFAULT });
    let space = Space::parse(spec)?;
    let name = args.get("network").unwrap_or("ResNet50");
    let net = network::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown network `{name}`"))?;
    let acc = platform(args)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let fidelity = args.get_usize(
        "fidelity",
        if quick { 1024 } else { EvalContext::DEFAULT_FIDELITY },
    )?;
    let strategy = search::by_name(
        args.get("strategy").unwrap_or("grid"),
        args.get_usize("samples", 64)?,
        seed,
    )?;

    let compiled = args.has_flag("compiled");
    println!(
        "exploring {} design points — {} strategy, {} on {}, seed {}{}",
        space.len(),
        strategy.name(),
        net.name,
        acc.name,
        seed,
        if compiled { ", compiled-macro fidelity" } else { "" }
    );
    let ctx = EvalContext::new(net, acc, seed, fidelity).with_compiled(compiled);
    let cache = EvalCache::new();
    let report = strategy.run(&space, &ctx, &cache)?;
    let outcome = ExploreOutcome::new(report, &ctx, &cache, seed, &space.spec);
    println!("{}", outcome.table().render());

    if compiled {
        // the same strategy over the analytic cards (separate memo keys in
        // the same cache) — the diff is what the structural per-block
        // models see that the interpolated analytic law cannot
        let actx = ctx.clone().with_compiled(false);
        let areport = strategy.run(&space, &actx, &cache)?;
        let aoutcome = ExploreOutcome::new(areport, &actx, &cache, seed, &space.spec);
        let d = mcaimem::dse::pareto::diff(&aoutcome.frontier, &outcome.frontier);
        println!("analytic → compiled frontier:");
        println!("{}", render_diff(&d));
    }

    match outcome.paper_ok() {
        None => println!("paper point 1S7E@0.8 was not part of this space"),
        Some(ok) => println!(
            "paper point 1S7E@0.8: {} — {}% area reduction, {}x energy gain vs SRAM, {} the frontier",
            if ok { "OK" } else { "FAILED" },
            fnum(outcome.paper_area_reduction().unwrap_or(0.0) * 100.0, 1),
            fnum(outcome.paper_energy_gain().unwrap_or(0.0), 2),
            if outcome.frontier.contains(&mcaimem::dse::DesignPoint::paper()) {
                "ON"
            } else {
                "OFF"
            }
        ),
    }

    if let Some(path) = args.get("json") {
        use mcaimem::util::json::Json;
        let mut doc = outcome.to_json();
        if compiled {
            // tag the artifact's objective space so diffs across
            // fidelities are recognizable (readers only require "frontier")
            if let Json::Obj(o) = &mut doc {
                o.insert("eval".into(), Json::Str("compiled".into()));
            }
        }
        mcaimem::util::json::save_pretty(std::path::Path::new(path), &doc)?;
        println!("frontier artifact written to {path}");
    }
    if let Some(old) = args.get("diff") {
        let old_frontier = frontier_from_artifact(&std::fs::read_to_string(old)?)?;
        let d = mcaimem::dse::pareto::diff(&old_frontier, &outcome.frontier);
        println!("{}", render_diff(&d));
    }
    if quick || args.has_flag("paper-gate") {
        match outcome.paper_ok() {
            Some(true) => {}
            // `None` is unreachable (the paper point is force-evaluated),
            // but an explicitly requested gate must never silently pass
            _ => bail!(
                "paper-point gate FAILED: 1S7E@0.8 must stay on the frontier with ≥40% area and ≥3x energy vs SRAM"
            ),
        }
    }
    Ok(())
}

fn cmd_compile(args: &mcaimem::cli::ParsedArgs) -> Result<()> {
    use mcaimem::dse::DesignPoint;
    use mcaimem::mem::compiler;

    // the explore point grammar; axes left unset take the paper's values,
    // so `--point ratio=7,vref=0.8` is the Table I operating point
    let point: DesignPoint = match args.get("point") {
        Some(s) => s.parse()?,
        None => DesignPoint::paper(),
    };
    let bytes = args.get_usize("bytes-kb", 1024)? * 1024;
    let spec = compiler::compile(&point, bytes)?;

    // breakdown table by default; with --json the table only prints when
    // asked for, so scripted runs stay quiet
    if args.has_flag("table") || args.get("json").is_none() {
        for t in mcaimem::report::macro_spec::breakdown(&spec) {
            println!("{}", t.render());
        }
    }
    if let Some(path) = args.get("json") {
        spec.save(std::path::Path::new(path))?;
        println!("netlist summary written to {path}");
    }
    Ok(())
}

fn cmd_serve(args: &mcaimem::cli::ParsedArgs) -> Result<()> {
    use mcaimem::report::serving::{self, RateSweepConfig};

    let backend = backend_single(args)?;
    let requests = args.get_usize("requests", 1024)?;
    let seed = args.get_usize("seed", 0xD00D)? as u64;
    let quick = args.has_flag("quick");
    let dispatch: DispatchMode = match args.get("dispatch") {
        None => DispatchMode::default(),
        Some(s) => s.parse().map_err(|e: String| anyhow::anyhow!(e))?,
    };
    let refresh_stall =
        Duration::from_secs_f64(args.get_f64("refresh-stall-us", 0.0)?.max(0.0) * 1e-6);

    if let Some(rates) = args.get_f64_list("rates")? {
        // open-loop rate sweep: hold the tier at each offered rate and read
        // the p99.9 SLO tail + schedule slip
        let workers = args.get_usize("workers", 4)?;
        let sweep_cfg = RateSweepConfig {
            workers,
            shards: args.get_usize("shards", workers)?,
            requests: if quick { requests.min(1024) } else { requests.max(4096) },
            dispatch,
            refresh_stall,
            seed,
        };
        let (table, points) = serving::rate_sweep(&backend, &rates, &sweep_cfg)?;
        println!("{}", table.render());
        if let Some(path) = args.get("json") {
            let doc = serving::rate_sweep_json(&backend, &sweep_cfg, &points);
            mcaimem::util::json::save_pretty(std::path::Path::new(path), &doc)?;
            println!("rate sweep written to {path}");
        }
        return Ok(());
    }

    if args.has_flag("sweep") {
        let grid: &[(usize, usize)] = if quick {
            &[(1, 1), (2, 2)]
        } else {
            &mcaimem::report::serving::DEFAULT_SWEEP
        };
        let sweep_requests = if quick { requests.min(256) } else { requests };
        let (table, points) =
            mcaimem::report::serving::saturation_sweep(&backend, grid, sweep_requests, seed)?;
        println!("{}", table.render());
        if let (Some(base), Some(peak)) = (points.first(), points.iter().reduce(|a, b| {
            if b.achieved_rps > a.achieved_rps { b } else { a }
        })) {
            println!(
                "peak {} req/s at {} workers × {} shards ({}x over 1×1)",
                fnum(peak.achieved_rps, 0),
                peak.workers,
                peak.shards,
                fnum(peak.achieved_rps / base.achieved_rps.max(1e-9), 2)
            );
        }
        if let Some(path) = args.get("json") {
            let doc = serving::saturation_sweep_json(&backend, &points);
            mcaimem::util::json::save_pretty(std::path::Path::new(path), &doc)?;
            println!("saturation sweep written to {path}");
        }
        return Ok(());
    }

    let workers = args.get_usize("workers", 1)?;
    let shards = args.get_usize("shards", workers)?;
    // tracing is strictly opt-in: without --trace-out the sink stays
    // disabled and the serving path runs its untraced (zero-allocation)
    // fast path — meters are bit-identical either way
    let obs = match args.get("trace-out") {
        Some(_) => mcaimem::obs::ObsSink::enabled(mcaimem::obs::DEFAULT_RING_EVENTS),
        None => mcaimem::obs::ObsSink::disabled(),
    };
    let cfg = PoolConfig {
        backend,
        workers,
        shards,
        buffer_bytes: args.get_usize("buffer-kb", shards * 64)? * 1024,
        batch_window: match args.get_usize("window-ms", 0)? {
            0 => Duration::from_micros(200),
            ms => Duration::from_millis(ms as u64),
        },
        high_water: args.get_usize("high-water", 256)?,
        flip_p: args.get_f64("p", 0.01)?,
        dispatch,
        refresh_stall,
        seed,
        obs: obs.clone(),
        ..PoolConfig::default()
    };

    let art = artifacts_dir(args);
    let art_opt = art.join("manifest.json").exists().then_some(art);
    let target_rps = args.get_f64("target-rps", 0.0)?;
    let tenants = match args.get("mix") {
        None => Vec::new(),
        Some(list) => list
            .split(',')
            .filter(|n| !n.trim().is_empty())
            .map(|n| {
                Tenant::for_network(n.trim(), 1.0)
                    .ok_or_else(|| anyhow::anyhow!("unknown network `{n}` in --mix"))
            })
            .collect::<Result<Vec<_>>>()?,
    };
    let load = LoadConfig {
        arrival: if target_rps > 0.0 {
            Arrival::OpenPoisson { rps: target_rps }
        } else {
            Arrival::ClosedLoop { clients: args.get_usize("clients", 4 * workers)? }
        },
        tenants,
        requests,
        retry_rejects: !args.has_flag("no-retry"),
        seed: seed ^ 0x10AD,
        ..LoadConfig::default()
    }
    .validated()?;

    println!(
        "serving tier: {} × {} workers × {} shards, high-water {}, {}",
        cfg.backend.label(),
        cfg.workers,
        cfg.shards,
        cfg.high_water,
        match load.arrival {
            Arrival::OpenPoisson { rps } => format!("open-loop Poisson @ {} req/s", fnum(rps, 0)),
            Arrival::ClosedLoop { clients } => format!("closed loop × {clients} clients"),
        }
    );
    let pool = WorkerPool::start_with_artifacts(cfg, art_opt)?;
    let report = mcaimem::coordinator::loadgen::run(&pool, &load);
    let stats = pool.shutdown();

    println!(
        "offered {} requests in {} ms: {} completed, {} errors, {} rejected",
        report.offered,
        fnum(report.wall_s * 1e3, 1),
        report.completed,
        report.errors,
        report.rejected
    );
    println!(
        "  achieved   : {} req/s (client)  p50 {} µs  p99 {} µs  p99.9 {} µs",
        fnum(report.achieved_rps, 0),
        fnum(report.p50_latency_us, 0),
        fnum(report.p99_latency_us, 0),
        fnum(report.p999_latency_us, 0)
    );
    if matches!(load.arrival, Arrival::OpenPoisson { .. }) {
        println!("  sched slip : p99 {} µs behind the arrival schedule", fnum(report.sched_lag_p99_us, 0));
    }
    for t in mcaimem::report::serving::stats_tables(&stats) {
        println!("{}", t.render());
    }
    if let Some(path) = args.get("trace-out") {
        let n = mcaimem::obs::export::write_chrome_trace(std::path::Path::new(path), &obs)?;
        println!("span trace written to {path} ({n} events; open in https://ui.perfetto.dev)");
    }
    if let Some(path) = args.get("metrics-out") {
        write_metrics(std::path::Path::new(path), &stats.registry())?;
        println!("metrics snapshot written to {path}");
    }
    Ok(())
}

/// Write a registry snapshot: Prometheus text for `.prom`/`.txt` paths,
/// pretty JSON otherwise.
fn write_metrics(path: &std::path::Path, reg: &mcaimem::obs::Registry) -> Result<()> {
    let prom = matches!(
        path.extension().and_then(|e| e.to_str()),
        Some("prom") | Some("txt")
    );
    if prom {
        std::fs::write(path, reg.to_prometheus())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
    } else {
        mcaimem::util::json::save_pretty(path, &reg.to_json())?;
    }
    Ok(())
}

/// Convert a recorded conformance/chaos trace into the obs event timeline
/// and export it as Chrome trace-event JSON: stores/loads land on the
/// `replay/ops` track, ticks and refresh slots on `replay/clock`, all at
/// the trace's own device timestamps (µs).
fn write_replay_trace(path: &std::path::Path, trace: &mcaimem::sim::trace::Trace) -> Result<usize> {
    use mcaimem::obs::{Event, EventKind, ObsSink, TRACK_REPLAY_CLOCK, TRACK_REPLAY_OPS};
    use mcaimem::sim::trace::Op;

    let sink = ObsSink::enabled((trace.entries.len() + 1).next_power_of_two());
    for (i, entry) in trace.entries.iter().enumerate() {
        let ev = match &entry.op {
            Op::Store { addr, data, t } => Event::instant(
                EventKind::ReplayStore,
                TRACK_REPLAY_OPS,
                t * 1e6,
                *addr as u64,
                data.len() as u64,
            ),
            Op::Load { addr, len, t } => Event::instant(
                EventKind::ReplayLoad,
                TRACK_REPLAY_OPS,
                t * 1e6,
                *addr as u64,
                *len as u64,
            ),
            Op::Tick { t } => {
                Event::instant(EventKind::ReplayTick, TRACK_REPLAY_CLOCK, t * 1e6, i as u64, 0)
            }
            Op::RefreshRow { row, t } => Event::instant(
                EventKind::ReplayRefresh,
                TRACK_REPLAY_CLOCK,
                t * 1e6,
                i as u64,
                *row as u64,
            ),
        };
        sink.emit(ev);
    }
    mcaimem::obs::export::write_chrome_trace(path, &sink)
}

fn cmd_conform(args: &mcaimem::cli::ParsedArgs) -> Result<()> {
    use mcaimem::sim::campaign::{verify_oracle, verify_self, CampaignConfig};
    use mcaimem::sim::trace::Trace;

    // --replay FILE: re-run one saved trace (a CI failure artifact) locally
    if let Some(file) = args.get("replay") {
        let trace = Trace::load(std::path::Path::new(file))?;
        println!(
            "replaying {} ops against {} ({}){}",
            trace.entries.len(),
            trace.spec.label(),
            if trace.shards == 0 { "flat".to_string() } else { format!("sharded×{}", trace.shards) },
            if trace.spec.oracle_modeled() { " + golden model" } else { "" },
        );
        let mut failed = false;
        let rep = verify_self(&trace)?;
        match rep.divergence {
            None => println!("self-replay: exact over {} ops", rep.ops),
            Some(d) => {
                failed = true;
                println!("self-replay DIVERGED at {d}");
            }
        }
        if trace.spec.oracle_modeled() {
            let rep = verify_oracle(&trace)?;
            match rep.divergence {
                None => println!("vs oracle: exact over {} ops", rep.ops),
                Some(d) => {
                    failed = true;
                    println!("vs oracle DIVERGED at {d}");
                }
            }
        }
        // --trace-out: emit the replayed op timeline through the same
        // exporter the serving tier uses — exported even when the replay
        // diverges, since the timeline is exactly what needs inspecting
        if let Some(path) = args.get("trace-out") {
            let n = write_replay_trace(std::path::Path::new(path), &trace)?;
            println!("replay timeline written to {path} ({n} events)");
        }
        if failed {
            bail!("replay diverged");
        }
        return Ok(());
    }

    let specs = BackendSpec::parse_list(
        args.get("backend")
            .unwrap_or("sram,edram2t,rram,mcaimem@0.8,mcaimem@0.7-noenc,sttmram,sotmram@ret=1e-3,tiered=sram:32k+sotmram"),
    )?;
    let mut cfg = CampaignConfig {
        ops: args.get_usize("ops", 20_000)?,
        seed: args.get_usize("seed", 7)? as u64,
        bytes: args.get_usize("bytes-kb", 64)? * 1024,
        shards: args.get_usize("shards", 4)?,
        // on by default so a failing run always leaves a minimal trace
        // artifact; --no-shrink skips the (re-record-heavy) minimization
        // when debugging a long campaign by hand
        shrink: !args.has_flag("no-shrink"),
        faults: args
            .get("faults")
            .map(|s| s.parse::<mcaimem::faults::FaultPlan>())
            .transpose()?,
    };
    if args.has_flag("quick") {
        cfg = cfg.quick();
    }

    let (table, outcomes, ok) = mcaimem::report::conformance::conformance(&specs, &cfg)?;
    println!("{}", table.render());
    if let Some(path) = args.get("json") {
        let doc = mcaimem::report::conformance::outcomes_json(&outcomes, &cfg);
        mcaimem::util::json::save_pretty(std::path::Path::new(path), &doc)?;
        println!("machine-readable report written to {path}");
    }
    if ok {
        println!(
            "conformance OK: {} runs replayed exactly (self + oracle where applicable)",
            outcomes.len()
        );
        return Ok(());
    }
    let dir = std::path::PathBuf::from(args.get("save-dir").unwrap_or("."));
    let written = mcaimem::report::conformance::save_failures(&outcomes, &dir)?;
    for p in &written {
        eprintln!(
            "minimal reproducing trace saved: {} (replay with `mcaimem conform --replay {}`)",
            p.display(),
            p.display()
        );
    }
    bail!("conformance FAILED: {} failing run(s)", outcomes.iter().filter(|o| !o.ok()).count());
}

fn cmd_chaos(args: &mcaimem::cli::ParsedArgs) -> Result<()> {
    use mcaimem::sim::chaos::{ChaosConfig, DEFAULT_DRILL};

    // chaos failure artifacts are conformance traces with a fault-plan
    // header; --replay re-runs one through the same fault-aware path
    if args.get("replay").is_some() {
        return cmd_conform(args);
    }

    let obs = match args.get("trace-out") {
        Some(_) => mcaimem::obs::ObsSink::enabled(mcaimem::obs::DEFAULT_RING_EVENTS),
        None => mcaimem::obs::ObsSink::disabled(),
    };
    let mut cfg = ChaosConfig {
        plan: args.get("faults").unwrap_or(DEFAULT_DRILL).parse()?,
        seed: args.get_usize("seed", 42)? as u64,
        ops: args.get_usize("ops", 6_000)?,
        shards: args.get_usize("shards", 4)?,
        workers: args.get_usize("workers", 2)?,
        requests: args.get_usize("requests", 320)?,
        shrink: !args.has_flag("no-shrink"),
        obs: obs.clone(),
        ..ChaosConfig::default()
    };
    if args.has_flag("quick") {
        cfg = cfg.quick();
    }

    let (table, outcome, ok) = mcaimem::report::chaos::chaos(&cfg)?;
    println!("{}", table.render());
    if let Some(path) = args.get("json") {
        let doc = mcaimem::report::chaos::outcome_json(&outcome, &cfg);
        mcaimem::util::json::save_pretty(std::path::Path::new(path), &doc)?;
        println!("machine-readable report written to {path}");
    }
    if let Some(path) = args.get("trace-out") {
        let n = mcaimem::obs::export::write_chrome_trace(std::path::Path::new(path), &obs)?;
        println!("chaos span trace written to {path} ({n} events)");
    }
    if ok {
        println!(
            "chaos drill OK: conformance held and no reply was lost under `{}`",
            cfg.plan
        );
        return Ok(());
    }
    let dir = std::path::PathBuf::from(args.get("save-dir").unwrap_or("."));
    let written = mcaimem::report::conformance::save_failures(&outcome.memory, &dir)?;
    for p in &written {
        eprintln!(
            "minimal reproducing trace saved: {} (replay with `mcaimem chaos --replay {}`)",
            p.display(),
            p.display()
        );
    }
    bail!(
        "chaos drill FAILED: {} memory-tier failure(s), {} lost replies",
        outcome.memory.iter().filter(|o| !o.ok()).count(),
        outcome.serving.lost
    );
}

fn cmd_selftest(args: &mcaimem::cli::ParsedArgs) -> Result<()> {
    let art = artifacts_dir(args);
    let mut runner = ModelRunner::new(&art)?;
    let mut rng = Pcg64::new(7);

    // 1) encoder: Pallas (through PJRT) vs the Rust implementation
    let n = 4096;
    let x: Vec<i8> = (0..n).map(|_| rng.next_u64() as i8).collect();
    let pallas_enc = runner.encode_only(&x)?;
    let rust_enc = mcaimem::encode::one_enhancement::encode(&x);
    anyhow::ensure!(pallas_enc == rust_enc, "encode mismatch between Pallas and Rust");
    println!("encode: Pallas == Rust over {n} random bytes OK");

    // 2) store path: encode→age→decode with a shared mask
    let mask = ModelRunner::draw_mask(&mut rng, n, 0.07);
    let pallas_rt = runner.encoder_roundtrip(&x, &mask)?;
    let mut rust_rt = x.clone();
    for (v, m) in rust_rt.iter_mut().zip(&mask) {
        let enc = mcaimem::encode::one_enhancement::encode_byte(*v as u8);
        let aged = enc | (*m as u8 & !enc & 0x7f);
        *v = mcaimem::encode::one_enhancement::decode_byte(aged) as i8;
    }
    anyhow::ensure!(pallas_rt == rust_rt, "store-path mismatch between Pallas and Rust");
    println!("mcaimem_store: Pallas == Rust with shared mask OK");

    // 3) model accuracy gates — served from an ideal (SRAM) buffer vs the
    // aged mixed-cell backends
    let clean = runner.accuracy(&BackendSpec::Sram, 0.0, 4, 1)?;
    anyhow::ensure!(
        (clean - runner.artifacts.int8_clean_acc).abs() < 0.05,
        "clean accuracy {clean} drifted from manifest {}",
        runner.artifacts.int8_clean_acc
    );
    println!(
        "clean accuracy {} matches manifest {} OK",
        fnum(clean, 4),
        fnum(runner.artifacts.int8_clean_acc, 4)
    );

    let enc = runner.accuracy(&BackendSpec::mcaimem_default(), 0.05, 4, 2)?;
    let noenc =
        runner.accuracy(&BackendSpec::Mcaimem { vref: 0.8, encode: false, ecc: false }, 0.05, 4, 2)?;
    anyhow::ensure!(enc > noenc, "one-enhancement must protect accuracy");
    println!(
        "p=5%: with one-enh {} > without {} OK",
        fnum(enc, 4),
        fnum(noenc, 4)
    );
    println!("selftest OK");
    Ok(())
}
