//! System-level energy composition (paper §V-B, Figs. 14–16).
//!
//! [`system_eval`] combines a [`crate::scalesim::NetworkTrace`] with the
//! memory characterization cards to produce per-(network, platform,
//! backend) static / refresh / dynamic energy breakdowns — the backend is
//! named by the repo-wide [`crate::mem::backend::BackendSpec`]; [`opswatt`]
//! normalizes the buffer-energy win into the chip-level
//! performance-per-watt gain of Fig. 16.

pub mod opswatt;
pub mod system_eval;

pub use crate::mem::backend::BackendSpec;
pub use system_eval::{evaluate, EnergyBreakdown};
