//! Performance-per-watt normalization (paper Fig. 16).
//!
//! The buffer is only part of the chip: 42.5 % of Eyeriss' power and 37 %
//! of TPUv1's. Replacing the SRAM buffer with MCAIMem shrinks that slice by
//! the buffer-energy ratio; throughput is unchanged (same cycles), so the
//! ops/W gain is
//!
//! ```text
//!   gain = 1 / ((1 − f) + f·ratio) − 1,   ratio = E_mcaimem / E_sram
//! ```
//!
//! With the headline 3.4× buffer ratio this lands at +42.8 % on Eyeriss and
//! +35.4 % on TPUv1 — the paper's "between 35.4 % and a peak of 43.2 %".

use super::system_eval::evaluate;
use crate::mem::backend::BackendSpec;
use crate::scalesim::accelerator::AcceleratorConfig;
use crate::scalesim::simulate::NetworkTrace;

/// Chip-level ops/W improvement from swapping the SRAM buffer for `spec`.
pub fn opswatt_gain(trace: &NetworkTrace, acc: &AcceleratorConfig, spec: &BackendSpec) -> f64 {
    let sram = evaluate(trace, acc, &BackendSpec::Sram).total_j();
    let ours = evaluate(trace, acc, spec).total_j();
    let ratio = ours / sram;
    let f = acc.buffer_power_frac;
    1.0 / ((1.0 - f) + f * ratio) - 1.0
}

/// The closed-form gain for a given buffer-energy ratio (used by tests and
/// the Fig. 16 caption numbers).
pub fn gain_for_ratio(buffer_power_frac: f64, energy_ratio: f64) -> f64 {
    1.0 / ((1.0 - buffer_power_frac) + buffer_power_frac * energy_ratio) - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalesim::{network, simulate_network};

    #[test]
    fn paper_caption_numbers() {
        // 3.4× buffer gain ⇒ +42.8 % (Eyeriss), +35.4 % (TPUv1)
        let r = 1.0 / 3.4;
        let ey = gain_for_ratio(0.425, r);
        let tpu = gain_for_ratio(0.37, r);
        assert!((ey - 0.428).abs() < 0.005, "ey={ey}");
        assert!((tpu - 0.354).abs() < 0.005, "tpu={tpu}");
    }

    #[test]
    fn gains_land_in_paper_band() {
        // Fig. 16: 35.4 % … 43.2 % across benchmarks/platforms
        for acc in AcceleratorConfig::paper_platforms() {
            for net in ["AlexNet", "ResNet50", "VGG16"] {
                let t = simulate_network(&network::by_name(net).unwrap(), &acc);
                let g = opswatt_gain(&t, &acc, &BackendSpec::mcaimem_default());
                assert!(g > 0.25 && g < 0.50, "{net}@{}: gain={g}", acc.name);
            }
        }
    }

    #[test]
    fn identity_ratio_means_no_gain() {
        assert!(gain_for_ratio(0.425, 1.0).abs() < 1e-12);
    }

    #[test]
    fn worse_buffer_means_negative_gain() {
        // RRAM's >100× loss shows up as a large ops/W regression
        assert!(gain_for_ratio(0.425, 100.0) < -0.9);
    }
}
